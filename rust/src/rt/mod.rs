//! Minimal threaded runtime (tokio substitute).
//!
//! Every long-lived component (API server loops, controllers, pbs_server,
//! moms, kubelets, red-box) runs as a named OS thread; coordination is via
//! std mpsc channels, a shared [`Shutdown`] token, and a [`Timers`] service
//! for deadlines (walltime limits, heartbeats, requeue backoff).

pub mod pool;
pub mod timers;

pub use pool::Pool;
pub use timers::Timers;

use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Spawn a named thread (names show up in debuggers/profilers).
pub fn spawn_named<F>(name: &str, f: F) -> JoinHandle<()>
where
    F: FnOnce() + Send + 'static,
{
    std::thread::Builder::new()
        .name(name.to_string())
        .spawn(f)
        .unwrap_or_else(|e| panic!("failed to spawn thread {name}: {e}"))
}

/// Cooperative shutdown token. Clone freely; `trigger()` wakes all waiters.
#[derive(Clone, Default)]
pub struct Shutdown {
    inner: Arc<(Mutex<bool>, Condvar)>,
}

impl Shutdown {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn trigger(&self) {
        let (lock, cv) = &*self.inner;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }

    pub fn is_triggered(&self) -> bool {
        *self.inner.0.lock().unwrap()
    }

    /// Block until triggered.
    pub fn wait(&self) {
        let (lock, cv) = &*self.inner;
        let mut g = lock.lock().unwrap();
        while !*g {
            g = cv.wait(g).unwrap();
        }
    }

    /// Sleep for `d`, returning early with `true` if shutdown triggered.
    /// Returns `false` on a full (uninterrupted) sleep — the normal tick.
    pub fn wait_timeout(&self, d: Duration) -> bool {
        let (lock, cv) = &*self.inner;
        let deadline = Instant::now() + d;
        let mut g = lock.lock().unwrap();
        loop {
            if *g {
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (ng, res) = cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
            if res.timed_out() && !*g {
                return false;
            }
        }
    }
}

/// A one-shot event another thread can wait on (used for request/response
/// rendezvous without spinning).
#[derive(Clone, Default)]
pub struct Notify {
    inner: Arc<(Mutex<bool>, Condvar)>,
}

impl Notify {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn notify(&self) {
        let (lock, cv) = &*self.inner;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }

    pub fn wait_timeout(&self, d: Duration) -> bool {
        let (lock, cv) = &*self.inner;
        let deadline = Instant::now() + d;
        let mut g = lock.lock().unwrap();
        while !*g {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (ng, _) = cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
        }
        true
    }
}

/// Join a set of handles, panicking with the thread name on a poisoned join
/// (a worker panic should fail tests loudly, not hang).
pub fn join_all(handles: Vec<JoinHandle<()>>) {
    for h in handles {
        let name = h.thread().name().unwrap_or("<unnamed>").to_string();
        if let Err(e) = h.join() {
            let msg = e
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| e.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("thread {name} panicked: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn shutdown_wakes_waiters() {
        let s = Shutdown::new();
        let s2 = s.clone();
        let woke = Arc::new(AtomicUsize::new(0));
        let w2 = woke.clone();
        let h = spawn_named("waiter", move || {
            s2.wait();
            w2.fetch_add(1, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(woke.load(Ordering::SeqCst), 0);
        s.trigger();
        h.join().unwrap();
        assert_eq!(woke.load(Ordering::SeqCst), 1);
        assert!(s.is_triggered());
    }

    #[test]
    fn wait_timeout_full_sleep_returns_false() {
        let s = Shutdown::new();
        let t0 = Instant::now();
        assert!(!s.wait_timeout(Duration::from_millis(30)));
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn wait_timeout_interrupted_returns_true() {
        let s = Shutdown::new();
        let s2 = s.clone();
        spawn_named("trigger", move || {
            std::thread::sleep(Duration::from_millis(10));
            s2.trigger();
        });
        let t0 = Instant::now();
        assert!(s.wait_timeout(Duration::from_secs(5)));
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn notify_rendezvous() {
        let n = Notify::new();
        let n2 = n.clone();
        spawn_named("notifier", move || {
            std::thread::sleep(Duration::from_millis(5));
            n2.notify();
        });
        assert!(n.wait_timeout(Duration::from_secs(5)));
        // Already-notified waits return immediately.
        assert!(n.wait_timeout(Duration::from_millis(1)));
    }

    #[test]
    fn notify_timeout() {
        let n = Notify::new();
        assert!(!n.wait_timeout(Duration::from_millis(10)));
    }
}
