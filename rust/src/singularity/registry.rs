//! Image registry: where built SIF images live.
//!
//! Models both the user's directory of `.sif` files (Singularity's model —
//! images are plain files, one reason it suits HPC shared filesystems) and
//! a pull-through cache keyed by reference. Thread-safe; shared by moms,
//! kubelets and the CLI.

use super::image::SifImage;
use crate::util::{Error, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

#[derive(Clone, Default)]
pub struct ImageRegistry {
    inner: Arc<Mutex<BTreeMap<String, Arc<SifImage>>>>,
}

impl ImageRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// A registry pre-loaded with the images the examples/benches use.
    pub fn with_defaults() -> Self {
        let reg = Self::new();
        reg.push(SifImage::lolcow());
        reg.push(SifImage::new(
            "sleep_1s.sif",
            super::image::Payload::Sleep { millis: 1000 },
        ));
        reg
    }

    /// Store an image under its name (overwrites, like rebuilding a .sif).
    pub fn push(&self, img: SifImage) {
        self.inner.lock().unwrap().insert(img.name.clone(), Arc::new(img));
    }

    /// Look up by exact reference.
    pub fn pull(&self, name: &str) -> Result<Arc<SifImage>> {
        self.inner
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::container(format!("image not found: {name}")))
    }

    pub fn exists(&self, name: &str) -> bool {
        self.inner.lock().unwrap().contains_key(name)
    }

    pub fn remove(&self, name: &str) -> bool {
        self.inner.lock().unwrap().remove(name).is_some()
    }

    pub fn list(&self) -> Vec<String> {
        self.inner.lock().unwrap().keys().cloned().collect()
    }

    /// Persist an image to a real `.sif` file on disk.
    pub fn save_to_file(&self, name: &str, path: &std::path::Path) -> Result<()> {
        let img = self.pull(name)?;
        std::fs::write(path, img.to_bytes())?;
        Ok(())
    }

    /// Load a `.sif` file from disk into the registry.
    pub fn load_from_file(&self, path: &std::path::Path) -> Result<String> {
        let bytes = std::fs::read(path)?;
        let img = SifImage::from_bytes(&bytes)?;
        let name = img.name.clone();
        self.push(img);
        Ok(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::singularity::image::Payload;

    #[test]
    fn push_pull_list() {
        let reg = ImageRegistry::new();
        assert!(reg.pull("missing.sif").is_err());
        reg.push(SifImage::lolcow());
        assert!(reg.exists("lolcow_latest.sif"));
        let img = reg.pull("lolcow_latest.sif").unwrap();
        assert!(matches!(img.payload, Payload::Echo { .. }));
        assert_eq!(reg.list(), vec!["lolcow_latest.sif".to_string()]);
        assert!(reg.remove("lolcow_latest.sif"));
        assert!(!reg.remove("lolcow_latest.sif"));
    }

    #[test]
    fn defaults_present() {
        let reg = ImageRegistry::with_defaults();
        assert!(reg.exists("lolcow_latest.sif"));
        assert!(reg.exists("sleep_1s.sif"));
    }

    #[test]
    fn file_roundtrip() {
        let reg = ImageRegistry::with_defaults();
        let dir = std::env::temp_dir().join(format!("hpcorc-sif-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("lolcow.sif");
        reg.save_to_file("lolcow_latest.sif", &path).unwrap();
        let reg2 = ImageRegistry::new();
        let name = reg2.load_from_file(&path).unwrap();
        assert_eq!(name, "lolcow_latest.sif");
        assert_eq!(*reg2.pull(&name).unwrap(), *reg.pull(&name).unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }
}
