"""L1 Pallas kernel: fused tiled matmul + bias + GELU — the transformer MLP
hot spot of the containerised CYBELE-pilot workload.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid is
(m/bm, n/bn, k/bk); each (i, j) output tile accumulates partial products
over the k axis in a float32 VMEM scratch tile, feeding the MXU with
(bm, bk) x (bk, bn) blocks. BlockSpec expresses the HBM->VMEM schedule.
Default tiles are 128x128x128 when the operands allow (128 = MXU lane
width); smaller operands fall back to the largest divisor tile.

Executed with interpret=True — the CPU PJRT plugin cannot run Mosaic
custom-calls — so on this testbed the kernel is a *structural* artifact
whose numerics are validated against ref.matmul_gelu_ref.

Autodiff: pallas_call has no VJP; matmul_gelu is wrapped in a custom_vjp
whose backward uses the analytic formulas (plain XLA matmuls, which XLA
fuses well — the forward is where the fusion win lives).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref

#: Preferred tile edge — the MXU systolic array is 128x128.
MXU_TILE = 128


def _tile(dim: int, preferred: int = MXU_TILE) -> int:
    """Largest divisor of `dim` that is <= preferred (>=1)."""
    t = min(dim, preferred)
    while dim % t != 0:
        t -= 1
    return max(t, 1)


def _kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, nk, activation):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _finish():
        y = acc_ref[...] + b_ref[...]
        if activation == "gelu":
            o_ref[...] = ref.gelu(y)
        else:
            o_ref[...] = y


def matmul_gelu_fwd(x, w, b, *, activation="gelu", bm=None, bn=None, bk=None):
    """Forward pallas call: act(x @ w + b), f32 in/out."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert b.shape == (1, n), f"bias must be (1, {n}), got {b.shape}"
    bm = bm or _tile(m)
    bn = bn or _tile(n)
    bk = bk or _tile(k)
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, activation=activation),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((1, bn), lambda i, j, l: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def matmul_gelu(x, w, b, activation="gelu"):
    """Differentiable fused act(x @ w + b) with a Pallas forward."""
    return matmul_gelu_fwd(x, w, b, activation=activation)


def _vjp_fwd(x, w, b, activation):
    out = matmul_gelu_fwd(x, w, b, activation=activation)
    return out, (x, w, b)


def _vjp_bwd(activation, res, g):
    x, w, b = res
    if activation == "gelu":
        y = x @ w + b  # pre-activation (recomputed: rematerialisation)
        g = g * ref.d_gelu(y)
    dx = g @ w.T
    dw = x.T @ g
    db = g.sum(axis=0, keepdims=True)
    return dx, dw, db


matmul_gelu.defvjp(_vjp_fwd, _vjp_bwd)


def vmem_bytes(m, n, k, bm=None, bn=None, bk=None):
    """Estimated VMEM footprint of one grid step (bytes): x, w, bias, out
    and accumulator tiles, all f32. Used by aot.py --report and DESIGN.md
    roofline estimates."""
    bm = bm or _tile(m)
    bn = bn or _tile(n)
    bk = bk or _tile(k)
    return 4 * (bm * bk + bk * bn + bn + 2 * bm * bn)


def mxu_utilization_estimate(m, n, k, bm=None, bn=None, bk=None):
    """Fraction of MXU-issue slots doing useful work per grid step,
    assuming the 128x128 systolic array: a (bm,bk)x(bk,bn) block keeps
    min(bm,128)*min(bn,128)/128^2 of the array busy."""
    bm = bm or _tile(m)
    bn = bn or _tile(n)
    return (min(bm, MXU_TILE) * min(bn, MXU_TILE)) / float(MXU_TILE * MXU_TILE)
