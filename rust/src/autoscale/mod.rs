//! Elastic autoscaling: metrics pipeline → HPA → cluster autoscaler, with
//! burst-to-WLM overflow.
//!
//! The paper's Torque-Operator bridges a *fixed* split between the
//! Kubernetes partition and the WLM partition. This layer makes the split
//! elastic — the direction of High-Performance Kubernetes (Chazapis et
//! al., arXiv:2409.16919), which runs cloud-native workloads on HPC
//! through virtual-kubelet nodes, and of the Flux Operator's elastically
//! resizable ensembles (Sochat et al., arXiv:2309.17420). Three loops,
//! each a plain controller over the PR 1 `ApiClient` surface:
//!
//! # 1. Metrics pipeline ([`metrics`])
//!
//! Kubelets sample per-pod usage while syncing their node (the
//! metrics-server analogue) and publish `PodMetrics`/`NodeMetrics`
//! objects under `metrics.k8s.io/v1beta1` — the objects `kubectl top
//! nodes|pods` renders. Usage is synthetic but controllable: the
//! live-patchable `autoscale.hpcorc.io/cpu-milli` annotation, then the
//! `CPU_LOAD_MILLI` template env var, then half the pod's request.
//! Samples also land as gauges in the shared [`crate::cluster::Metrics`]
//! registry. Writes are suppressed when nothing changed.
//!
//! # 2. HorizontalPodAutoscaler ([`hpa`])
//!
//! An `autoscaling/v2`-style HPA kind (registered in
//! [`crate::kube::default_scheme`], alias `hpa`) reconciled on the
//! [`crate::kube::Controller`] runtime: classic
//! `desired = ceil(current × utilization / target)` with a ±10%
//! tolerance band, min/max replica clamps, and scale-up/scale-down
//! stabilization windows (damped in the direction of change), driving
//! `Deployment.spec.replicas`.
//!
//! # 3. ClusterAutoscaler ([`cluster_autoscaler`])
//!
//! Watches unschedulable pods (Pending, unbound, no `schedulingGates` —
//! kueue-suspended workloads are *not* capacity pressure). First grows
//! the real node pool through a [`NodeProvisioner`] (the testbed
//! registers live simulated kubelets), up to `max_nodes`. When the
//! Kubernetes partition is at its cap, pods that opted in with the
//! [`BURST_LABEL`] label are flipped onto the tainted virtual WLM node:
//! the pod binds to the virtual node, a `TorqueJob`/`SlurmJob` wrapping
//! its container is created (owned by the pod), and the operator ships
//! it to Torque/Slurm over red-box; the autoscaler mirrors the WLM
//! phases back onto the pod — the virtual-kubelet duty for that node.
//! When load drops it drains: cordon (`spec.unschedulable`), delete
//! movable (Deployment-owned, non-kueue) pods so their controller
//! recreates them elsewhere, and deprovision empty nodes — never below
//! `min_nodes` and **never a node hosting a gang-admitted kueue
//! workload**: evicting one member would break the queue layer's
//! all-or-nothing guarantee, so those nodes are not drain candidates and
//! their quota charges stay untouched until the gang itself finishes.
//!
//! # Kueue interaction
//!
//! The scheduler now gates on generic pod `schedulingGates`; kueue sets
//! its `kueue.x-k8s.io/admission` gate on suspended workloads and clears
//! it at admission (PR 3 inverted that dependency), which is what lets
//! this layer distinguish "waiting for quota" (gated — ignore) from
//! "waiting for capacity" (unschedulable — provision or burst).
//! Provisioning changes physical capacity only; kueue's logical quota
//! ledger is deliberately untouched.
//!
//! The simulator mirrors the elastic loop with
//! [`crate::sim::ElasticParams`] (provision delay + idle window over a
//! min/max node range), and `trace gen --kind diurnal` provides the load
//! shape that makes static-vs-elastic comparisons meaningful.

pub mod cluster_autoscaler;
pub mod hpa;
pub mod metrics;

pub use cluster_autoscaler::{
    CaConfig, CaReport, ClusterAutoscaler, NodeProvisioner, BURST_LABEL, POOL_LABEL,
};
pub use hpa::{
    HpaController, HpaView, MetricSource, MetricTarget, AUTOSCALING_API_VERSION, KIND_HPA,
};
pub use metrics::{
    pod_cpu_usage_milli, publish_node_sample, NodeMetricsView, PodMetricsView,
    CPU_LOAD_ENV, CPU_USAGE_ANNOTATION, KIND_NODEMETRICS, KIND_PODMETRICS,
    METRICS_API_VERSION,
};
