//! Kubernetes-like orchestrator: the big-data cluster of the paper's
//! testbed (Fig. 1). Dynamic object model with CRDs ([`api`]), versioned
//! store with watches ([`store`]), API server with an RPC surface
//! ([`apiserver`]), the scheduler ([`scheduler`]), the node agent
//! ([`kubelet`]), the controller runtime ([`controller`]), a Deployment
//! controller ([`deployment`]), and manifest handling ([`yaml`]).
//!
//! # The API layer: Scheme, ApiClient, `Api<K>`
//!
//! Three pieces make the resource API uniform across kinds and transports:
//!
//! - **[`Scheme`]** ([`scheme`]) is the kind registry: every kind — built-in
//!   or CRD — registers its [`GroupVersionKind`], plural, and short names.
//!   [`default_scheme`] ships Pod/Node/Deployment plus the paper's
//!   `TorqueJob`/`SlurmJob` CRDs under `wlm.sylabs.io/v1alpha1`; the CLI
//!   resolves `kubectl get tj` through it instead of hardcoded aliases.
//! - **[`ApiClient`]** ([`client`]) is the transport trait: the full verb
//!   set (`create`/`get`/`update`/`update_status`/`patch_merge`/`delete`/
//!   `apply`/`list` with [`ListOptions`]/`watch`). The in-process
//!   [`ApiServer`] and the socket-backed [`RemoteApi`] both implement it
//!   with identical semantics (see `tests/api_parity.rs`), so controllers
//!   hold `Arc<dyn ApiClient>` and never care which side of the red-box
//!   socket they run on.
//! - **[`Api<K>`]** is the typed handle: `Api::<PodView>::new(client)`
//!   returns [`PodView`]s instead of raw [`KubeObject`] trees, the kube-rs
//!   shape. Views implement [`ResourceView`]; a view family covering
//!   several kinds (e.g. [`WlmJobView`] for TorqueJob + SlurmJob) picks a
//!   member with `Api::of_kind`.
//!
//! ## Registering a new CRD kind
//!
//! 1. Register the kind in a scheme so tooling resolves its aliases:
//!    `scheme.register_wlm_crd("FlinkJob", "flinkjobs", &["fj"])` (or
//!    [`Scheme::register`] with a custom [`GroupVersionKind`]).
//! 2. Define a typed view implementing [`ResourceView`] (decode
//!    spec/status into a struct; see [`WlmJobView`]).
//! 3. Write a [`Controller`] for the kind and run it with
//!    [`ControllerRunner`] — the store serves unknown kinds natively, so
//!    no server-side change is needed (paper §III-B: the operator
//!    "introduces a new object kind" through the same machinery).

pub mod api;
pub mod apiserver;
pub mod client;
pub mod controller;
pub mod deployment;
pub mod kubelet;
pub mod scheduler;
pub mod scheme;
pub mod store;
pub mod yaml;

pub use api::{
    add_scheduling_gate, remove_scheduling_gate, scheduling_gates, KubeObject, NodeView,
    ObjectMeta, PodPhase, PodView, WlmJobView, KIND_DEPLOYMENT, KIND_NODE, KIND_POD,
    KIND_SLURMJOB, KIND_TORQUEJOB, WLM_API_VERSION,
};
pub use apiserver::{ApiServer, RemoteApi, MAX_CONFLICT_RETRIES};
pub use client::{Api, ApiClient, ListOptions, ObjectList, ResourceView};
pub use controller::{Controller, ControllerRunner, Reconcile};
pub use deployment::DeploymentController;
pub use kubelet::Kubelet;
pub use scheduler::KubeScheduler;
pub use scheme::{default_scheme, GroupVersionKind, KindSpec, Scheme};
pub use store::{Store, WatchEvent};
