//! Scheduling state model and the FIFO / Kubernetes-greedy policies.
//!
//! The unit of allocation is (node, cores, memory): a job asks for `nodes`
//! chunks of `ppn` cores + `mem` bytes each (the Torque `-l nodes=N:ppn=P`
//! model; Slurm's `-N/--ntasks-per-node` and one-pod-per-node Kubernetes
//! jobs reduce to the same shape).

use std::time::Duration;

/// A job awaiting placement.
#[derive(Debug, Clone, PartialEq)]
pub struct PendingJob {
    pub id: u64,
    /// Number of node-chunks required.
    pub nodes: u32,
    /// Cores per chunk.
    pub ppn: u32,
    /// Memory per chunk (bytes).
    pub mem: u64,
    /// Requested walltime — what backfill reservations are computed from.
    pub walltime: Duration,
    /// Higher runs first (PBS `-p`, Slurm `--priority`).
    pub priority: i64,
    /// Submission time, seconds on the caller's clock.
    pub submit_s: f64,
    /// Destination queue/partition (tenant identity for admission layers
    /// such as [`crate::sim::QueueAdmission`]); `None` = unqueued.
    pub queue: Option<String>,
}

impl PendingJob {
    pub fn simple(id: u64, nodes: u32, ppn: u32, walltime_s: u64) -> Self {
        PendingJob {
            id,
            nodes,
            ppn,
            mem: 0,
            walltime: Duration::from_secs(walltime_s),
            priority: 0,
            submit_s: 0.0,
            queue: None,
        }
    }
}

/// One node's free capacity at schedule time.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeState {
    pub id: usize,
    pub total_cores: u32,
    pub free_cores: u32,
    pub total_mem: u64,
    pub free_mem: u64,
}

impl NodeState {
    pub fn whole(id: usize, cores: u32, mem: u64) -> Self {
        NodeState { id, total_cores: cores, free_cores: cores, total_mem: mem, free_mem: mem }
    }

    pub fn fits_chunk(&self, job: &PendingJob) -> bool {
        self.free_cores >= job.ppn && self.free_mem >= job.mem
    }
}

/// A running job's footprint — what backfill uses to predict node release.
#[derive(Debug, Clone, PartialEq)]
pub struct RunningJob {
    pub id: u64,
    /// (node id, cores, mem) per chunk.
    pub placement: Vec<Placement>,
    /// Predicted completion (start + requested walltime), caller-clock secs.
    pub expected_end_s: f64,
}

/// One chunk of an assignment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Placement {
    pub node: usize,
    pub cores: u32,
    pub mem: u64,
}

/// A placement decision for one job.
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    pub job: u64,
    pub placement: Vec<Placement>,
}

/// A scheduling policy: pure function from cluster snapshot to assignments.
pub trait SchedPolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Decide which pending jobs start now. `pending` is in submission
    /// order; implementations re-order internally per their discipline.
    /// Must not over-commit: assignments are applied atomically by callers.
    fn schedule(
        &self,
        now_s: f64,
        pending: &[PendingJob],
        nodes: &[NodeState],
        running: &[RunningJob],
    ) -> Vec<Assignment>;
}

/// Sort key shared by the WLM policies: priority desc, then submit asc,
/// then id asc (PBS/Slurm tie-breaking).
pub fn queue_order(a: &PendingJob, b: &PendingJob) -> std::cmp::Ordering {
    b.priority
        .cmp(&a.priority)
        .then(a.submit_s.partial_cmp(&b.submit_s).unwrap_or(std::cmp::Ordering::Equal))
        .then(a.id.cmp(&b.id))
}

/// Try to place a job on the given free state; on success, mutates
/// `free` and returns the chunks. First-fit over nodes sorted by id
/// (deterministic), one chunk per node (Torque default `nodes=N` semantics:
/// N distinct virtual processors on possibly-distinct hosts — we use
/// distinct hosts, the common configuration).
pub fn try_place(job: &PendingJob, free: &mut [NodeState]) -> Option<Vec<Placement>> {
    let mut chosen = Vec::with_capacity(job.nodes as usize);
    for n in free.iter_mut() {
        if chosen.len() == job.nodes as usize {
            break;
        }
        if n.fits_chunk(job) {
            chosen.push(n.id);
            n.free_cores -= job.ppn;
            n.free_mem -= job.mem;
        }
    }
    if chosen.len() == job.nodes as usize {
        Some(chosen.into_iter().map(|node| Placement { node, cores: job.ppn, mem: job.mem }).collect())
    } else {
        // Roll back partial reservations.
        for p in chosen {
            let n = free.iter_mut().find(|n| n.id == p).unwrap();
            n.free_cores += job.ppn;
            n.free_mem += job.mem;
        }
        None
    }
}

/// Strict FIFO (no backfill): place in queue order, stop at the first job
/// that does not fit. Torque's default pbs_sched discipline.
pub struct FifoPolicy;

impl SchedPolicy for FifoPolicy {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn schedule(
        &self,
        _now_s: f64,
        pending: &[PendingJob],
        nodes: &[NodeState],
        _running: &[RunningJob],
    ) -> Vec<Assignment> {
        let mut queue: Vec<&PendingJob> = pending.iter().collect();
        queue.sort_by(|a, b| queue_order(a, b));
        let mut free: Vec<NodeState> = nodes.to_vec();
        let mut out = Vec::new();
        for job in queue {
            match try_place(job, &mut free) {
                Some(placement) => out.push(Assignment { job: job.id, placement }),
                None => break, // strict: head-of-queue blocks everything
            }
        }
        out
    }
}

/// Kubernetes-default-scheduler approximation for WLM comparisons: every
/// pending pod is tried each cycle (no head-of-queue blocking, no
/// walltime-based reservations — kube-scheduler has no walltime concept),
/// nodes scored least-allocated first (the default NodeResourcesFit
/// LeastAllocated strategy). Wide jobs can therefore starve — the
/// behavioural difference bench E1 surfaces.
pub struct KubeGreedyPolicy;

impl SchedPolicy for KubeGreedyPolicy {
    fn name(&self) -> &'static str {
        "kube-greedy"
    }

    fn schedule(
        &self,
        _now_s: f64,
        pending: &[PendingJob],
        nodes: &[NodeState],
        _running: &[RunningJob],
    ) -> Vec<Assignment> {
        let mut queue: Vec<&PendingJob> = pending.iter().collect();
        queue.sort_by(|a, b| queue_order(a, b));
        let mut free: Vec<NodeState> = nodes.to_vec();
        let mut out = Vec::new();
        for job in queue {
            // Least-allocated scoring: prefer emptier nodes.
            free.sort_by(|a, b| {
                let fa = a.free_cores as f64 / a.total_cores.max(1) as f64;
                let fb = b.free_cores as f64 / b.total_cores.max(1) as f64;
                fb.partial_cmp(&fa).unwrap().then(a.id.cmp(&b.id))
            });
            if let Some(placement) = try_place(job, &mut free) {
                out.push(Assignment { job: job.id, placement });
            }
            // no break: greedy continues past blocked pods
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(n: usize, cores: u32) -> Vec<NodeState> {
        (0..n).map(|i| NodeState::whole(i, cores, 64 << 30)).collect()
    }

    #[test]
    fn try_place_distinct_nodes() {
        let job = PendingJob::simple(1, 2, 4, 60);
        let mut free = nodes(3, 8);
        let placement = try_place(&job, &mut free).unwrap();
        assert_eq!(placement.len(), 2);
        assert_ne!(placement[0].node, placement[1].node);
        assert_eq!(free[0].free_cores, 4);
        assert_eq!(free[1].free_cores, 4);
        assert_eq!(free[2].free_cores, 8);
    }

    #[test]
    fn try_place_rolls_back_on_failure() {
        let job = PendingJob::simple(1, 3, 8, 60);
        let mut free = nodes(2, 8);
        assert!(try_place(&job, &mut free).is_none());
        assert!(free.iter().all(|n| n.free_cores == 8), "rollback restored");
    }

    #[test]
    fn fifo_blocks_behind_wide_job() {
        // head needs 4 nodes (cluster has 2) => nothing behind it runs
        let pending = vec![
            PendingJob::simple(1, 4, 1, 60),
            PendingJob::simple(2, 1, 1, 60),
        ];
        let out = FifoPolicy.schedule(0.0, &pending, &nodes(2, 8), &[]);
        assert!(out.is_empty());
    }

    #[test]
    fn fifo_respects_priority_then_submit() {
        let mut a = PendingJob::simple(1, 1, 8, 60);
        a.submit_s = 0.0;
        let mut b = PendingJob::simple(2, 1, 8, 60);
        b.submit_s = 1.0;
        b.priority = 10;
        // only one node free: priority job wins despite later submit
        let out = FifoPolicy.schedule(2.0, &[a, b], &nodes(1, 8), &[]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].job, 2);
    }

    #[test]
    fn kube_greedy_skips_blocked_wide_job() {
        let pending = vec![
            PendingJob::simple(1, 4, 1, 60), // cannot fit
            PendingJob::simple(2, 1, 1, 60),
            PendingJob::simple(3, 1, 1, 60),
        ];
        let out = KubeGreedyPolicy.schedule(0.0, &pending, &nodes(2, 8), &[]);
        let ids: Vec<u64> = out.iter().map(|a| a.job).collect();
        assert_eq!(ids, vec![2, 3], "greedy passes over the blocked job");
    }

    #[test]
    fn kube_greedy_spreads_least_allocated() {
        let mut ns = nodes(2, 8);
        ns[0].free_cores = 2; // node 0 mostly used
        let pending = vec![PendingJob::simple(1, 1, 1, 60)];
        let out = KubeGreedyPolicy.schedule(0.0, &pending, &ns, &[]);
        assert_eq!(out[0].placement[0].node, 1, "prefers the emptier node");
    }

    #[test]
    fn no_overcommit_single_cycle() {
        let pending: Vec<PendingJob> =
            (0..10).map(|i| PendingJob::simple(i, 1, 8, 60)).collect();
        for policy in [&FifoPolicy as &dyn SchedPolicy, &KubeGreedyPolicy] {
            let out = policy.schedule(0.0, &pending, &nodes(3, 8), &[]);
            assert_eq!(out.len(), 3, "{}: exactly the free capacity", policy.name());
            let mut used: Vec<usize> =
                out.iter().flat_map(|a| a.placement.iter().map(|p| p.node)).collect();
            used.sort();
            used.dedup();
            assert_eq!(used.len(), 3, "distinct nodes");
        }
    }

    #[test]
    fn mem_constraint_respected() {
        let mut job = PendingJob::simple(1, 1, 1, 60);
        job.mem = 128 << 30; // more than node's 64gb
        let out = FifoPolicy.schedule(0.0, &[job], &nodes(4, 8), &[]);
        assert!(out.is_empty());
    }
}
