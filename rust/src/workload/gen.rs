//! Trace generators — Feitelson-style synthetic workloads plus the
//! CYBELE-pilot mix the paper names as its benchmark plan.

use super::trace::{JobKind, Trace, TraceJob};
use crate::util::Rng;

/// Deterministic trace generator (seeded).
pub struct TraceGen {
    rng: Rng,
    next_id: u64,
}

impl TraceGen {
    pub fn new(seed: u64) -> TraceGen {
        TraceGen { rng: Rng::new(seed), next_id: 1 }
    }

    fn id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Poisson arrivals, log-normal runtimes, mixed widths — the classic
    /// batch-HPC model. `load` ≈ offered utilization against
    /// `capacity_cores` (1.0 = saturation).
    pub fn poisson_batch(
        &mut self,
        n_jobs: usize,
        capacity_cores: u32,
        load: f64,
        mean_runtime_s: f64,
    ) -> Trace {
        // mean cores per job from the width mix below:
        // 0.55*1 + 0.25*2 + 0.12*4 + 0.08*8 = 2.17
        let mean_cores = 2.17;
        let rate = (load * capacity_cores as f64) / (mean_cores * mean_runtime_s);
        let mut t = 0.0;
        let jobs = (0..n_jobs)
            .map(|_| {
                t += self.rng.exp(rate.max(1e-9));
                let (nodes, ppn) = self.width_mix();
                // log-normal runtime with sigma .8, mean ≈ mean_runtime_s
                let mu = mean_runtime_s.ln() - 0.32;
                let runtime = self.rng.lognormal(mu, 0.8).clamp(1.0, mean_runtime_s * 20.0);
                // users over-request walltime by 1–5x (empirically typical)
                let walltime = runtime * self.rng.uniform(1.1, 5.0);
                TraceJob::sleep(self.id(), t, nodes, ppn, walltime, runtime)
            })
            .collect();
        Trace::new("poisson-batch", jobs)
    }

    /// Width mix: mostly narrow, a tail of wide jobs (what makes backfill
    /// matter). Mean ≈ 2.17 cores.
    fn width_mix(&mut self) -> (u32, u32) {
        match self.rng.weighted(&[0.55, 0.25, 0.12, 0.08]) {
            0 => (1, 1),
            1 => (1, 2),
            2 => (2, 2),
            _ => (4, 2),
        }
    }

    /// Bursty arrivals: quiet Poisson background + periodic bursts
    /// (service-style churn where the K8s greedy scheduler shines).
    pub fn bursty(&mut self, n_bursts: usize, burst_size: usize, gap_s: f64) -> Trace {
        let mut jobs = Vec::new();
        let mut t = 0.0;
        for _ in 0..n_bursts {
            t += self.rng.exp(1.0 / gap_s.max(1e-9));
            for _ in 0..burst_size {
                let arrival = t + self.rng.uniform(0.0, 1.0);
                let runtime = self.rng.lognormal(2.2, 0.5).clamp(1.0, 120.0);
                jobs.push(TraceJob::sleep(
                    self.id(),
                    arrival,
                    1,
                    1,
                    runtime * 2.0,
                    runtime,
                ));
            }
        }
        Trace::new("bursty", jobs)
    }

    /// The CYBELE-pilot mix: long multi-node training jobs + streams of
    /// short single-node inference jobs (precision-agriculture pipelines).
    pub fn cybele_pilots(&mut self, n_train: usize, n_infer: usize, span_s: f64) -> Trace {
        let mut jobs = Vec::new();
        for _ in 0..n_train {
            let arrival = self.rng.uniform(0.0, span_s * 0.5);
            let runtime = self.rng.uniform(300.0, 1200.0);
            let mut j = TraceJob::sleep(
                self.id(),
                arrival,
                self.rng.range(2, 4) as u32,
                2,
                runtime * 1.5,
                runtime,
            );
            j.kind = JobKind::Compute { artifact: "cropyield_train".into(), steps: 200 };
            jobs.push(j);
        }
        for _ in 0..n_infer {
            let arrival = self.rng.uniform(0.0, span_s);
            let runtime = self.rng.uniform(5.0, 30.0);
            let mut j =
                TraceJob::sleep(self.id(), arrival, 1, 1, runtime * 3.0, runtime);
            j.kind = JobKind::Compute { artifact: "cropyield_infer".into(), steps: 20 };
            jobs.push(j);
        }
        Trace::new("cybele-pilots", jobs)
    }

    /// Multi-tenant trace: a Poisson batch stream where every job carries
    /// a tenant queue label (`TraceJob::queue`), shares skewed Zipf-style
    /// (tenant *i* gets weight 1/(i+1)) so one noisy tenant dominates —
    /// the shape that makes quota admission (`sim::QueueAdmission`, the
    /// kueue layer) measurable against the raw trace.
    pub fn multi_tenant(
        &mut self,
        n_jobs: usize,
        tenants: &[&str],
        capacity_cores: u32,
        load: f64,
        mean_runtime_s: f64,
    ) -> Trace {
        let mut trace = self.poisson_batch(n_jobs, capacity_cores, load, mean_runtime_s);
        let weights: Vec<f64> =
            (0..tenants.len().max(1)).map(|i| 1.0 / (i + 1) as f64).collect();
        for job in &mut trace.jobs {
            let pick = if tenants.is_empty() { None } else { Some(self.rng.weighted(&weights)) };
            job.queue = pick.map(|i| tenants[i].to_string());
        }
        trace.name = "multi-tenant".into();
        trace
    }

    /// Diurnal service load: single-node short jobs whose arrival rate
    /// follows a day/night sine — peak ≈ `peak_load` offered utilization
    /// against `capacity_cores`, trough ≈ 10% of peak, period `period_s`.
    /// Generated by thinning a homogeneous Poisson stream at the peak
    /// rate, so it stays deterministic per seed. The load shape that makes
    /// static-vs-elastic partition comparisons (autoscale layer, PR 3)
    /// meaningful: a static cluster must be provisioned for the peak and
    /// idles through every trough.
    pub fn diurnal(
        &mut self,
        n_jobs: usize,
        capacity_cores: u32,
        peak_load: f64,
        period_s: f64,
        mean_runtime_s: f64,
    ) -> Trace {
        const TROUGH: f64 = 0.1;
        let peak_rate =
            (peak_load * capacity_cores as f64) / mean_runtime_s.max(1e-9);
        let rate_at = |t: f64| {
            // 0 at t=0, peaking mid-period: 0.5*(1-cos) sweeps 0..1.
            let phase = 0.5 * (1.0 - (2.0 * std::f64::consts::PI * t / period_s).cos());
            peak_rate * (TROUGH + (1.0 - TROUGH) * phase)
        };
        let mut t = 0.0;
        let jobs = (0..n_jobs)
            .map(|_| {
                loop {
                    t += self.rng.exp(peak_rate.max(1e-9));
                    if self.rng.uniform(0.0, 1.0) <= rate_at(t) / peak_rate {
                        break;
                    }
                }
                let runtime = self.rng.lognormal(mean_runtime_s.ln() - 0.18, 0.6).clamp(
                    1.0,
                    mean_runtime_s * 10.0,
                );
                TraceJob::sleep(self.id(), t, 1, 1, runtime * self.rng.uniform(1.5, 3.0), runtime)
            })
            .collect();
        Trace::new("diurnal", jobs)
    }

    /// Adversarial-for-FIFO trace: alternating wide long and narrow short
    /// jobs — the textbook case where EASY backfill wins on makespan.
    pub fn backfill_showcase(&mut self, pairs: usize, cluster_nodes: u32) -> Trace {
        let mut jobs = Vec::new();
        let mut t = 0.0;
        for _ in 0..pairs {
            jobs.push(TraceJob::sleep(self.id(), t, cluster_nodes, 1, 700.0, 600.0));
            for _ in 0..4 {
                t += 0.5;
                jobs.push(TraceJob::sleep(self.id(), t, 1, 1, 120.0, 100.0));
            }
            t += 1.0;
        }
        Trace::new("backfill-showcase", jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = TraceGen::new(1).poisson_batch(100, 64, 0.7, 120.0);
        let b = TraceGen::new(1).poisson_batch(100, 64, 0.7, 120.0);
        let c = TraceGen::new(2).poisson_batch(100, 64, 0.7, 120.0);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn poisson_respects_shape() {
        let t = TraceGen::new(3).poisson_batch(500, 64, 0.7, 120.0);
        assert_eq!(t.len(), 500);
        assert!(t.jobs.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        assert!(t.jobs.iter().all(|j| j.walltime_s >= j.runtime_s));
        assert!(t.jobs.iter().all(|j| j.runtime_s >= 1.0));
        // offered load sanity: core-seconds over span ≈ 0.7 * 64, loosely
        let span = t.jobs.last().unwrap().arrival_s;
        let load = t.core_seconds() / (span * 64.0);
        assert!((0.3..1.4).contains(&load), "offered load {load}");
    }

    #[test]
    fn cybele_mix_has_both_kinds() {
        let t = TraceGen::new(4).cybele_pilots(5, 50, 1000.0);
        assert_eq!(t.len(), 55);
        let trains = t
            .jobs
            .iter()
            .filter(|j| matches!(&j.kind, JobKind::Compute { artifact, .. } if artifact.contains("train")))
            .count();
        assert_eq!(trains, 5);
        assert!(t.jobs.iter().all(|j| matches!(j.kind, JobKind::Compute { .. })));
    }

    #[test]
    fn backfill_showcase_structure() {
        let t = TraceGen::new(5).backfill_showcase(3, 8);
        assert_eq!(t.len(), 15);
        assert_eq!(t.jobs.iter().filter(|j| j.nodes == 8).count(), 3);
    }

    #[test]
    fn multi_tenant_labels_all_jobs() {
        let t = TraceGen::new(7).multi_tenant(300, &["a", "b", "c"], 64, 0.7, 100.0);
        assert_eq!(t.len(), 300);
        assert!(t.jobs.iter().all(|j| j.queue.is_some()));
        let count = |q: &str| t.jobs.iter().filter(|j| j.queue.as_deref() == Some(q)).count();
        assert_eq!(count("a") + count("b") + count("c"), 300);
        assert!(count("a") > count("c"), "zipf skew: first tenant dominates");
        // Deterministic per seed, like every other generator.
        let again = TraceGen::new(7).multi_tenant(300, &["a", "b", "c"], 64, 0.7, 100.0);
        assert_eq!(t, again);
    }

    #[test]
    fn diurnal_shape_and_determinism() {
        let period = 1000.0;
        let t = TraceGen::new(11).diurnal(600, 32, 0.8, period, 30.0);
        assert_eq!(t.len(), 600);
        assert!(t.jobs.iter().all(|j| j.nodes == 1 && j.ppn == 1));
        assert!(t.jobs.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        // Density peaks mid-period and troughs at the period boundary:
        // count arrivals falling in peak vs trough windows across the
        // whole trace.
        let bucket = |j: &TraceJob| (j.arrival_s % period) / period;
        let peak = t.jobs.iter().filter(|j| (0.35..0.65).contains(&bucket(j))).count();
        let trough = t
            .jobs
            .iter()
            .filter(|j| {
                let b = bucket(j);
                !(0.15..0.85).contains(&b)
            })
            .count();
        assert!(
            peak > trough * 2,
            "diurnal skew missing: peak {peak} vs trough {trough}"
        );
        let again = TraceGen::new(11).diurnal(600, 32, 0.8, period, 30.0);
        assert_eq!(t, again);
    }

    #[test]
    fn bursty_counts() {
        let t = TraceGen::new(6).bursty(5, 20, 60.0);
        assert_eq!(t.len(), 100);
    }
}
