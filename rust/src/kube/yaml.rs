//! Manifest handling: YAML ⇄ [`KubeObject`] (kubectl apply / get -o yaml).

use super::api::KubeObject;
use crate::encoding::{yaml, Value};
use crate::util::{Error, Result};

/// The paper's Fig. 3 manifest, verbatim — used by tests, the quickstart
/// example, and `hpcorc demo`.
pub const COW_JOB_YAML: &str = r#"apiVersion: wlm.sylabs.io/v1alpha1
kind: TorqueJob
metadata:
  name: cow
spec:
  batch: |
    #!/bin/sh
    #PBS -l walltime=00:30:00
    #PBS -l nodes=1
    #PBS -e $HOME/low.err
    #PBS -o $HOME/low.out
    export PATH=$PATH:/usr/local/bin
    singularity run lolcow_latest.sif
  results:
    from: $HOME/low.out
  mount:
    name: data
    hostPath:
      path: $HOME/
      type: DirectoryOrCreate
"#;

/// Parse a (possibly multi-document) manifest into objects.
pub fn parse_manifest(text: &str) -> Result<Vec<KubeObject>> {
    let docs = yaml::parse_all(text)?;
    docs.iter()
        .filter(|d| !d.is_null())
        .map(|d| {
            validate(d)?;
            KubeObject::decode(d)
        })
        .collect()
}

/// Render an object as kubectl-style YAML.
pub fn to_yaml(obj: &KubeObject) -> String {
    yaml::to_string(&obj.encode())
}

fn validate(v: &Value) -> Result<()> {
    let kind = v
        .opt_str("kind")
        .ok_or_else(|| Error::parse("manifest missing `kind`"))?;
    if kind.is_empty() {
        return Err(Error::parse("manifest `kind` is empty"));
    }
    let name = v
        .path(&["metadata", "name"])
        .and_then(Value::as_str)
        .ok_or_else(|| Error::parse("manifest missing `metadata.name`"))?;
    // RFC 1123 label-ish validation, as the API server enforces.
    if name.is_empty()
        || name.len() > 253
        || !name
            .chars()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-' || c == '.')
        || name.starts_with('-')
        || name.ends_with('-')
    {
        return Err(Error::parse(format!("invalid object name `{name}`")));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_fig3_manifest() {
        let objs = parse_manifest(COW_JOB_YAML).unwrap();
        assert_eq!(objs.len(), 1);
        let o = &objs[0];
        assert_eq!(o.kind, "TorqueJob");
        assert_eq!(o.api_version, "wlm.sylabs.io/v1alpha1");
        assert_eq!(o.meta.name, "cow");
        let view = crate::kube::api::WlmJobView::from_object(o).unwrap();
        assert!(view.batch.contains("#PBS -l walltime=00:30:00"));
        assert!(view.batch.contains("singularity run lolcow_latest.sif"));
        assert_eq!(view.results_from.as_deref(), Some("$HOME/low.out"));
        assert_eq!(view.mount_path.as_deref(), Some("$HOME/"));
    }

    #[test]
    fn yaml_roundtrip() {
        let objs = parse_manifest(COW_JOB_YAML).unwrap();
        let emitted = to_yaml(&objs[0]);
        let back = parse_manifest(&emitted).unwrap();
        assert_eq!(back[0].spec, objs[0].spec);
        assert_eq!(back[0].meta.name, objs[0].meta.name);
    }

    #[test]
    fn multi_document() {
        let text = "kind: Pod\nmetadata:\n  name: a\nspec:\n  containers:\n    - name: c\n      image: i\n---\nkind: Pod\nmetadata:\n  name: b\nspec:\n  containers:\n    - name: c\n      image: i\n";
        let objs = parse_manifest(text).unwrap();
        assert_eq!(objs.len(), 2);
        assert_eq!(objs[1].meta.name, "b");
    }

    #[test]
    fn validation_errors() {
        assert!(parse_manifest("metadata:\n  name: x\n").is_err(), "no kind");
        assert!(parse_manifest("kind: Pod\n").is_err(), "no name");
        assert!(parse_manifest("kind: Pod\nmetadata:\n  name: Bad_Name\n").is_err());
        assert!(parse_manifest("kind: Pod\nmetadata:\n  name: -lead\n").is_err());
        assert!(parse_manifest("kind: Pod\nmetadata:\n  name: ok-name\n").is_ok());
    }
}
