//! red-box client: synchronous request/response over the Unix socket,
//! thread-safe (a mutex serializes frames per connection — the operator's
//! call pattern is low-rate control traffic), with lazy reconnect.

use super::proto::{read_frame, write_frame, Request, Response};
use crate::encoding::Value;
use crate::util::{Error, Result};
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

pub struct RedboxClient {
    path: PathBuf,
    conn: Mutex<Option<UnixStream>>,
    next_id: AtomicU64,
}

impl RedboxClient {
    /// Connect now; fails fast if the server socket is absent.
    pub fn connect(path: impl AsRef<Path>) -> Result<RedboxClient> {
        let path = path.as_ref().to_path_buf();
        let stream = UnixStream::connect(&path)
            .map_err(|e| Error::rpc(format!("connect {}: {e}", path.display())))?;
        Ok(RedboxClient {
            path,
            conn: Mutex::new(Some(stream)),
            next_id: AtomicU64::new(1),
        })
    }

    /// Connect with retry — used at testbed boot where daemon start order
    /// is not guaranteed.
    pub fn connect_retry(path: impl AsRef<Path>, timeout: Duration) -> Result<RedboxClient> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            match Self::connect(path.as_ref()) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if std::time::Instant::now() >= deadline {
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        }
    }

    /// Issue `Service/Method` with a JSON body; returns the response body.
    /// One transparent reconnect+retry on transport failure (the server may
    /// have restarted — red-box "future work: more stable deployments").
    pub fn call(&self, method: &str, body: Value) -> Result<Value> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request { id, method: method.to_string(), body };
        let mut guard = self.conn.lock().unwrap();
        match Self::round_trip(&mut guard, &self.path, &req) {
            Ok(resp) => resp.into_result(),
            Err(first) => {
                // transport-level failure: reconnect once
                *guard = None;
                match Self::round_trip(&mut guard, &self.path, &req) {
                    Ok(resp) => resp.into_result(),
                    Err(_) => Err(first),
                }
            }
        }
    }

    fn round_trip(
        conn: &mut Option<UnixStream>,
        path: &Path,
        req: &Request,
    ) -> Result<Response> {
        if conn.is_none() {
            let stream = UnixStream::connect(path)
                .map_err(|e| Error::rpc(format!("reconnect {}: {e}", path.display())))?;
            *conn = Some(stream);
        }
        let stream = conn.as_mut().unwrap();
        let result: Result<Response> = (|| {
            write_frame(stream, &req.encode())?;
            let frame = read_frame(stream)?
                .ok_or_else(|| Error::rpc("server closed connection"))?;
            Response::decode(&frame)
        })();
        if result.is_err() {
            *conn = None; // poison the connection
        }
        let resp = result?;
        if resp.id != req.id {
            *conn = None;
            return Err(Error::rpc(format!(
                "response id mismatch: sent {} got {}",
                req.id, resp.id
            )));
        }
        Ok(resp)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Metrics;
    use crate::redbox::server::{FnService, RedboxServer};
    use crate::rt::Shutdown;
    use std::sync::Arc;

    fn sock(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("hpcorc-cli-{tag}-{}.sock", std::process::id()))
    }

    #[test]
    fn connect_fails_without_server() {
        assert!(RedboxClient::connect("/tmp/does-not-exist-hpcorc.sock").is_err());
    }

    #[test]
    fn reconnects_after_server_restart() {
        let path = sock("restart");
        let sd1 = Shutdown::new();
        let mut srv1 = RedboxServer::start(&path, sd1.clone(), Metrics::new()).unwrap();
        srv1.register("s.S", Arc::new(FnService(|_: &str, _: &Value| Ok(Value::Int(1)))));
        let client = RedboxClient::connect(&path).unwrap();
        assert_eq!(client.call("s.S/m", Value::Null).unwrap(), Value::Int(1));
        srv1.stop();
        // Server gone: a fresh server comes up on the same socket.
        let sd2 = Shutdown::new();
        let mut srv2 = RedboxServer::start(&path, sd2.clone(), Metrics::new()).unwrap();
        srv2.register("s.S", Arc::new(FnService(|_: &str, _: &Value| Ok(Value::Int(2)))));
        // The old connection is dead; call() reconnects transparently.
        assert_eq!(client.call("s.S/m", Value::Null).unwrap(), Value::Int(2));
        srv2.stop();
    }

    #[test]
    fn connect_retry_waits_for_server() {
        let path = sock("retry");
        let p2 = path.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            let sd = Shutdown::new();
            let mut srv = RedboxServer::start(&p2, sd, Metrics::new()).unwrap();
            srv.register("s.S", Arc::new(FnService(|_: &str, _: &Value| Ok(Value::Null))));
            std::thread::sleep(Duration::from_millis(200));
            srv.stop();
        });
        let c = RedboxClient::connect_retry(&path, Duration::from_secs(5)).unwrap();
        assert!(c.call("s.S/m", Value::Null).is_ok());
        t.join().unwrap();
    }
}
