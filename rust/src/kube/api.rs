//! Kubernetes object model.
//!
//! Objects are dynamic (`kind` + metadata + spec/status [`Value`] trees),
//! exactly how the real API machinery treats CRDs — which is what lets
//! Torque-Operator "introduce a new object kind, i.e. Torquejob" (paper
//! §III-B) without touching the store. Typed views (PodView, NodeView,
//! TorqueJobView) parse the dynamic tree on demand.

use super::client::ResourceView;
use crate::cluster::Resources;
use crate::encoding::{decode_str_map, encode_str_map, json, Value};
use crate::util::{Error, Result};

/// Standard object kinds (CRD kinds are plain strings beyond these).
pub const KIND_POD: &str = "Pod";
pub const KIND_NODE: &str = "Node";
pub const KIND_DEPLOYMENT: &str = "Deployment";
pub const KIND_TORQUEJOB: &str = "TorqueJob";
pub const KIND_SLURMJOB: &str = "SlurmJob";
pub const KIND_PODDISRUPTIONBUDGET: &str = "PodDisruptionBudget";
pub const KIND_CUSTOMRESOURCEDEFINITION: &str = "CustomResourceDefinition";

/// The apiVersion Torque-Operator registers its CRDs under (paper Fig. 3).
pub const WLM_API_VERSION: &str = "wlm.sylabs.io/v1alpha1";
/// apiVersion of PodDisruptionBudget (k8s `policy/v1`).
pub const POLICY_API_VERSION: &str = "policy/v1";
/// apiVersion of CustomResourceDefinition (k8s `apiextensions.k8s.io/v1`).
pub const APIEXTENSIONS_API_VERSION: &str = "apiextensions.k8s.io/v1";

#[derive(Debug, Clone, PartialEq, Default)]
pub struct ObjectMeta {
    pub name: String,
    pub uid: u64,
    pub resource_version: u64,
    /// Seconds since apiserver epoch (for AGE columns).
    pub creation_s: f64,
    pub labels: Vec<(String, String)>,
    pub annotations: Vec<(String, String)>,
    /// Owner reference (kind, name) — drives cascade deletion.
    pub owner: Option<(String, String)>,
}

impl ObjectMeta {
    pub fn named(name: impl Into<String>) -> Self {
        ObjectMeta { name: name.into(), ..Default::default() }
    }

    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    pub fn set_label(&mut self, key: &str, val: &str) {
        for (k, v) in self.labels.iter_mut() {
            if k == key {
                *v = val.to_string();
                return;
            }
        }
        self.labels.push((key.to_string(), val.to_string()));
    }

    pub fn annotation(&self, key: &str) -> Option<&str> {
        self.annotations.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    pub fn set_annotation(&mut self, key: &str, val: &str) {
        for (k, v) in self.annotations.iter_mut() {
            if k == key {
                *v = val.to_string();
                return;
            }
        }
        self.annotations.push((key.to_string(), val.to_string()));
    }
}

/// A dynamic API object.
#[derive(Debug, Clone, PartialEq)]
pub struct KubeObject {
    pub kind: String,
    pub api_version: String,
    pub meta: ObjectMeta,
    pub spec: Value,
    pub status: Value,
}

impl KubeObject {
    pub fn new(kind: impl Into<String>, name: impl Into<String>, spec: Value) -> Self {
        KubeObject {
            kind: kind.into(),
            api_version: "v1".into(),
            meta: ObjectMeta::named(name),
            spec,
            status: Value::map(),
        }
    }

    pub fn name(&self) -> &str {
        &self.meta.name
    }

    /// Encode to the canonical Value tree (JSON/YAML-facing).
    pub fn encode(&self) -> Value {
        let mut meta = Value::map()
            .with("name", self.meta.name.clone())
            .with("uid", self.meta.uid)
            .with("resourceVersion", self.meta.resource_version)
            .with("creationSeconds", self.meta.creation_s);
        if !self.meta.labels.is_empty() {
            meta.insert("labels", encode_str_map(&self.meta.labels));
        }
        if !self.meta.annotations.is_empty() {
            meta.insert("annotations", encode_str_map(&self.meta.annotations));
        }
        if let Some((k, n)) = &self.meta.owner {
            meta.insert(
                "ownerReferences",
                Value::Seq(vec![Value::map().with("kind", k.clone()).with("name", n.clone())]),
            );
        }
        Value::map()
            .with("apiVersion", self.api_version.clone())
            .with("kind", self.kind.clone())
            .with("metadata", meta)
            .with("spec", self.spec.clone())
            .with("status", self.status.clone())
    }

    /// Decode from a manifest/storage Value tree.
    pub fn decode(v: &Value) -> Result<KubeObject> {
        let kind = v.req_str("kind")?.to_string();
        let meta_v = v.req("metadata")?;
        let meta = ObjectMeta {
            name: meta_v.req_str("name")?.to_string(),
            uid: meta_v.opt_int("uid").unwrap_or(0) as u64,
            resource_version: meta_v.opt_int("resourceVersion").unwrap_or(0) as u64,
            creation_s: meta_v.get("creationSeconds").and_then(Value::as_f64).unwrap_or(0.0),
            labels: meta_v.get("labels").map(decode_str_map).unwrap_or_default(),
            annotations: meta_v.get("annotations").map(decode_str_map).unwrap_or_default(),
            owner: meta_v
                .get("ownerReferences")
                .and_then(Value::as_seq)
                .and_then(|s| s.first())
                .and_then(|o| {
                    Some((o.opt_str("kind")?.to_string(), o.opt_str("name")?.to_string()))
                }),
        };
        Ok(KubeObject {
            kind,
            api_version: v.opt_str("apiVersion").unwrap_or("v1").to_string(),
            meta,
            spec: v.get("spec").cloned().unwrap_or_else(Value::map),
            status: v.get("status").cloned().unwrap_or_else(Value::map),
        })
    }

    pub fn to_json(&self) -> String {
        json::to_string(&self.encode())
    }

    pub fn from_json(s: &str) -> Result<KubeObject> {
        KubeObject::decode(&json::parse(s)?)
    }
}

// ------------------------------------------------------------------- Pods

/// Pod phases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PodPhase {
    Pending,
    Running,
    Succeeded,
    Failed,
}

impl PodPhase {
    pub fn as_str(&self) -> &'static str {
        match self {
            PodPhase::Pending => "Pending",
            PodPhase::Running => "Running",
            PodPhase::Succeeded => "Succeeded",
            PodPhase::Failed => "Failed",
        }
    }

    pub fn parse(s: &str) -> PodPhase {
        match s {
            "Running" => PodPhase::Running,
            "Succeeded" => PodPhase::Succeeded,
            "Failed" => PodPhase::Failed,
            _ => PodPhase::Pending,
        }
    }

    pub fn terminal(&self) -> bool {
        matches!(self, PodPhase::Succeeded | PodPhase::Failed)
    }
}

/// Typed view over a Pod's spec/status.
#[derive(Debug, Clone, PartialEq)]
pub struct PodView {
    pub name: String,
    pub image: String,
    pub env: Vec<(String, String)>,
    pub requests: Resources,
    pub node_name: Option<String>,
    pub node_selector: Vec<(String, String)>,
    pub tolerations: Vec<String>,
    /// `spec.schedulingGates` names: a pod with any gate present is held
    /// by the scheduler until every gate is removed (k8s scheduling
    /// gates). Admission layers (kueue) set/clear their own gate instead
    /// of the scheduler knowing about them.
    pub scheduling_gates: Vec<String>,
    pub phase: PodPhase,
    pub exit_code: Option<i32>,
}

impl PodView {
    pub fn from_object(o: &KubeObject) -> Result<PodView> {
        if o.kind != KIND_POD {
            return Err(Error::parse(format!("expected Pod, got {}", o.kind)));
        }
        let containers = o
            .spec
            .get("containers")
            .and_then(Value::as_seq)
            .ok_or_else(|| Error::parse("pod spec.containers missing"))?;
        let c0 = containers
            .first()
            .ok_or_else(|| Error::parse("pod needs at least one container"))?;
        let requests = c0
            .path(&["resources", "requests"])
            .map(|r| -> Result<Resources> {
                Ok(Resources {
                    cpu_milli: r
                        .opt_str("cpu")
                        .map(Resources::parse_cpu)
                        .transpose()?
                        .unwrap_or(0),
                    mem_bytes: r
                        .opt_str("memory")
                        .map(Resources::parse_mem_k8s)
                        .transpose()?
                        .unwrap_or(0),
                    gpus: r.opt_int("gpu").unwrap_or(0) as u32,
                })
            })
            .transpose()?
            .unwrap_or(Resources::ZERO);
        Ok(PodView {
            name: o.meta.name.clone(),
            image: c0.req_str("image")?.to_string(),
            env: c0.get("env").map(decode_str_map).unwrap_or_default(),
            requests,
            node_name: o.spec.opt_str("nodeName").map(String::from),
            node_selector: o.spec.get("nodeSelector").map(decode_str_map).unwrap_or_default(),
            tolerations: o
                .spec
                .get("tolerations")
                .and_then(Value::as_seq)
                .map(|s| {
                    s.iter().filter_map(|t| t.opt_str("key").map(String::from)).collect()
                })
                .unwrap_or_default(),
            scheduling_gates: scheduling_gates(o),
            phase: PodPhase::parse(o.status.opt_str("phase").unwrap_or("Pending")),
            exit_code: o.status.opt_int("exitCode").map(|i| i as i32),
        })
    }

    /// Build a Pod object from this view (status is phase-only).
    pub fn build(
        name: &str,
        image: &str,
        requests: Resources,
        env: &[(String, String)],
    ) -> KubeObject {
        let mut container = Value::map().with("name", "main").with("image", image);
        if !env.is_empty() {
            container.insert("env", encode_str_map(env));
        }
        let mut req = Value::map();
        if requests.cpu_milli > 0 {
            req.insert("cpu", format!("{}m", requests.cpu_milli));
        }
        if requests.mem_bytes > 0 {
            req.insert("memory", format!("{}Mi", requests.mem_bytes >> 20));
        }
        if requests.gpus > 0 {
            req.insert("gpu", requests.gpus as u64);
        }
        container.insert("resources", Value::map().with("requests", req));
        let spec = Value::map().with("containers", Value::Seq(vec![container]));
        KubeObject::new(KIND_POD, name, spec)
    }
}

impl ResourceView for PodView {
    fn kinds() -> &'static [&'static str] {
        &[KIND_POD]
    }
    fn from_object(obj: &KubeObject) -> Result<PodView> {
        PodView::from_object(obj)
    }
}

// -------------------------------------------------------- scheduling gates

/// The gate names in `spec.schedulingGates` (k8s `[{name: ...}]` shape).
pub fn scheduling_gates(obj: &KubeObject) -> Vec<String> {
    obj.spec
        .get("schedulingGates")
        .and_then(Value::as_seq)
        .map(|s| s.iter().filter_map(|g| g.opt_str("name").map(String::from)).collect())
        .unwrap_or_default()
}

/// Add a named scheduling gate (idempotent). Gated pods are skipped by the
/// scheduler until every gate is removed.
pub fn add_scheduling_gate(obj: &mut KubeObject, name: &str) {
    if scheduling_gates(obj).iter().any(|g| g == name) {
        return;
    }
    if !matches!(obj.spec.get("schedulingGates"), Some(Value::Seq(_))) {
        obj.spec.insert("schedulingGates", Value::Seq(Vec::new()));
    }
    if let Some(Value::Seq(gates)) = obj.spec.get_mut("schedulingGates") {
        gates.push(Value::map().with("name", name));
    }
}

/// Remove a named scheduling gate; drops the list entirely once empty so
/// ungated pods encode exactly as before gates existed.
pub fn remove_scheduling_gate(obj: &mut KubeObject, name: &str) {
    let remaining: Vec<String> =
        scheduling_gates(obj).into_iter().filter(|g| g != name).collect();
    if remaining.is_empty() {
        obj.spec.remove("schedulingGates");
    } else {
        obj.spec.insert(
            "schedulingGates",
            Value::Seq(remaining.into_iter().map(|g| Value::map().with("name", g)).collect()),
        );
    }
}

// ------------------------------------------------------------------ Nodes

/// Typed view over a Node object.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeView {
    pub name: String,
    pub capacity: Resources,
    pub labels: Vec<(String, String)>,
    /// Taint keys with NoSchedule effect (virtual nodes carry
    /// `virtual-kubelet`).
    pub taints: Vec<String>,
    /// Cordoned (`spec.unschedulable`, `kubectl cordon`): the scheduler
    /// places nothing new here — how the cluster autoscaler drains a node
    /// before deprovisioning it.
    pub unschedulable: bool,
    pub ready: bool,
    /// Reported runtime, e.g. `singularity-cri`.
    pub runtime: String,
}

impl NodeView {
    pub fn from_object(o: &KubeObject) -> Result<NodeView> {
        if o.kind != KIND_NODE {
            return Err(Error::parse(format!("expected Node, got {}", o.kind)));
        }
        let cap = o.spec.get("capacity");
        Ok(NodeView {
            name: o.meta.name.clone(),
            capacity: Resources {
                cpu_milli: cap
                    .and_then(|c| c.opt_str("cpu"))
                    .map(Resources::parse_cpu)
                    .transpose()?
                    .unwrap_or(0),
                mem_bytes: cap
                    .and_then(|c| c.opt_str("memory"))
                    .map(Resources::parse_mem_k8s)
                    .transpose()?
                    .unwrap_or(0),
                gpus: cap.and_then(|c| c.opt_int("gpu")).unwrap_or(0) as u32,
            },
            labels: o.meta.labels.clone(),
            taints: o
                .spec
                .get("taints")
                .and_then(Value::as_seq)
                .map(|s| {
                    s.iter().filter_map(|t| t.opt_str("key").map(String::from)).collect()
                })
                .unwrap_or_default(),
            unschedulable: o.spec.get("unschedulable").and_then(Value::as_bool).unwrap_or(false),
            ready: o.status.opt_str("phase").unwrap_or("Ready") == "Ready",
            runtime: o.status.opt_str("runtime").unwrap_or("").to_string(),
        })
    }

    pub fn build(name: &str, capacity: Resources, taints: &[&str]) -> KubeObject {
        let cap = Value::map()
            .with("cpu", format!("{}m", capacity.cpu_milli))
            .with("memory", format!("{}Mi", capacity.mem_bytes >> 20))
            .with("gpu", capacity.gpus as u64);
        let mut spec = Value::map().with("capacity", cap);
        if !taints.is_empty() {
            spec.insert(
                "taints",
                Value::Seq(
                    taints
                        .iter()
                        .map(|t| Value::map().with("key", *t).with("effect", "NoSchedule"))
                        .collect(),
                ),
            );
        }
        let mut node = KubeObject::new(KIND_NODE, name, spec);
        node.status = Value::map().with("phase", "Ready");
        node
    }
}

impl ResourceView for NodeView {
    fn kinds() -> &'static [&'static str] {
        &[KIND_NODE]
    }
    fn from_object(obj: &KubeObject) -> Result<NodeView> {
        NodeView::from_object(obj)
    }
}

// ------------------------------------------------- PodDisruptionBudget

/// Typed view over a `policy/v1 PodDisruptionBudget`. Exactly one of
/// `min_available`/`max_unavailable` is normally set; when both are, the
/// stricter `min_available` wins (matching the validation real k8s would
/// reject — we keep evaluation total instead of failing the eviction).
#[derive(Debug, Clone, PartialEq)]
pub struct PdbView {
    pub name: String,
    /// `spec.selector.matchLabels` — pods whose labels include every pair
    /// are covered by this budget.
    pub selector: Vec<(String, String)>,
    pub min_available: Option<i64>,
    pub max_unavailable: Option<i64>,
    /// `status.disruptionsAllowed` as last computed by the server.
    pub disruptions_allowed: i64,
}

impl PdbView {
    pub fn from_object(o: &KubeObject) -> Result<PdbView> {
        if o.kind != KIND_PODDISRUPTIONBUDGET {
            return Err(Error::parse(format!("expected PodDisruptionBudget, got {}", o.kind)));
        }
        Ok(PdbView {
            name: o.meta.name.clone(),
            selector: o
                .spec
                .path(&["selector", "matchLabels"])
                .map(decode_str_map)
                .unwrap_or_default(),
            min_available: o.spec.opt_int("minAvailable"),
            max_unavailable: o.spec.opt_int("maxUnavailable"),
            disruptions_allowed: o.status.opt_int("disruptionsAllowed").unwrap_or(0),
        })
    }

    /// True when `labels` satisfies the budget's selector (empty selector
    /// matches nothing — a PDB must name the pods it protects).
    pub fn matches(&self, labels: &[(String, String)]) -> bool {
        !self.selector.is_empty()
            && self
                .selector
                .iter()
                .all(|(k, v)| labels.iter().any(|(lk, lv)| lk == k && lv == v))
    }

    /// Build a PDB with `minAvailable` semantics.
    pub fn build_min_available(
        name: &str,
        selector: &[(String, String)],
        min_available: i64,
    ) -> KubeObject {
        let spec = Value::map()
            .with("selector", Value::map().with("matchLabels", encode_str_map(selector)))
            .with("minAvailable", min_available as u64);
        let mut o = KubeObject::new(KIND_PODDISRUPTIONBUDGET, name, spec);
        o.api_version = POLICY_API_VERSION.into();
        o
    }

    /// Build a PDB with `maxUnavailable` semantics.
    pub fn build_max_unavailable(
        name: &str,
        selector: &[(String, String)],
        max_unavailable: i64,
    ) -> KubeObject {
        let spec = Value::map()
            .with("selector", Value::map().with("matchLabels", encode_str_map(selector)))
            .with("maxUnavailable", max_unavailable as u64);
        let mut o = KubeObject::new(KIND_PODDISRUPTIONBUDGET, name, spec);
        o.api_version = POLICY_API_VERSION.into();
        o
    }
}

impl ResourceView for PdbView {
    fn kinds() -> &'static [&'static str] {
        &[KIND_PODDISRUPTIONBUDGET]
    }
    fn from_object(obj: &KubeObject) -> Result<PdbView> {
        PdbView::from_object(obj)
    }
}

/// Healthy = Running: the PDB notion of an available replica.
fn pod_healthy(pod: &KubeObject) -> bool {
    pod.status.opt_str("phase").unwrap_or("Pending") == "Running"
}

/// PDB admission verdict for evicting `victim`: the name of the first
/// budget the disruption would violate, or `None` when every matching
/// budget (possibly none) allows it. Evicting a pod that is not currently
/// healthy costs no availability — but a budget already below its floor
/// blocks *all* evictions of its pods, matching `disruptionsAllowed: 0`.
pub fn pdb_blocking(
    pdbs: &[KubeObject],
    pods: &[KubeObject],
    victim: &KubeObject,
) -> Option<String> {
    let disruption = pod_healthy(victim) as i64;
    for po in pdbs {
        let Ok(pdb) = PdbView::from_object(po) else { continue };
        if !pdb.matches(&victim.meta.labels) {
            continue;
        }
        let matching: Vec<&KubeObject> =
            pods.iter().filter(|p| pdb.matches(&p.meta.labels)).collect();
        let healthy = matching.iter().filter(|p| pod_healthy(p)).count() as i64;
        let total = matching.len() as i64;
        if let Some(min) = pdb.min_available {
            if healthy - disruption < min {
                return Some(pdb.name);
            }
        } else if let Some(max) = pdb.max_unavailable {
            if (total - healthy) + disruption > max {
                return Some(pdb.name);
            }
        }
    }
    None
}

/// How many more voluntary disruptions a PDB allows, given the current pod
/// set — the `status.disruptionsAllowed` number the server maintains.
pub fn pdb_disruptions_allowed(pdb: &PdbView, pods: &[KubeObject]) -> i64 {
    let matching: Vec<&KubeObject> =
        pods.iter().filter(|p| pdb.matches(&p.meta.labels)).collect();
    let healthy = matching.iter().filter(|p| pod_healthy(p)).count() as i64;
    let total = matching.len() as i64;
    if let Some(min) = pdb.min_available {
        (healthy - min).max(0)
    } else if let Some(max) = pdb.max_unavailable {
        (max - (total - healthy)).max(0)
    } else {
        healthy
    }
}

/// The requeue-mode eviction mutation: unbind the pod, reset it to
/// Pending, and park it behind `gate` so the scheduler cannot re-bind it
/// before the admission layer re-admits — applied atomically inside the
/// server's eviction path (kueue preemption uses this instead of delete).
pub fn requeue_evict_mutation(obj: &mut KubeObject, gate: &str) {
    obj.spec.remove("nodeName");
    obj.status.insert("phase", "Pending");
    add_scheduling_gate(obj, gate);
}

// ------------------------------------------- CustomResourceDefinition

/// Typed view over an `apiextensions.k8s.io/v1 CustomResourceDefinition`.
/// Creating/applying one against the API server registers the named kind
/// in the server's *runtime* scheme, so `kubectl get <plural|short>`
/// resolves it exactly like a built-in.
#[derive(Debug, Clone, PartialEq)]
pub struct CrdView {
    pub name: String,
    /// API group (e.g. `stable.example.com`).
    pub group: String,
    /// Served version (e.g. `v1`).
    pub version: String,
    /// CamelCase kind the CRD introduces (e.g. `FlinkJob`).
    pub kind: String,
    pub plural: String,
    pub short_names: Vec<String>,
}

impl CrdView {
    pub fn from_object(o: &KubeObject) -> Result<CrdView> {
        if o.kind != KIND_CUSTOMRESOURCEDEFINITION {
            return Err(Error::parse(format!(
                "expected CustomResourceDefinition, got {}",
                o.kind
            )));
        }
        let names = o.spec.req("names").map_err(|_| Error::parse("crd spec.names missing"))?;
        Ok(CrdView {
            name: o.meta.name.clone(),
            group: o.spec.req_str("group")?.to_string(),
            version: o.spec.opt_str("version").unwrap_or("v1").to_string(),
            kind: names.req_str("kind")?.to_string(),
            plural: names.req_str("plural")?.to_string(),
            short_names: names
                .get("shortNames")
                .and_then(Value::as_seq)
                .map(|s| s.iter().filter_map(|v| v.as_str().map(String::from)).collect())
                .unwrap_or_default(),
        })
    }

    /// The `group/version` apiVersion objects of this CRD carry.
    pub fn api_version(&self) -> String {
        format!("{}/{}", self.group, self.version)
    }

    /// Build a CRD object; the conventional object name is
    /// `<plural>.<group>`.
    pub fn build(group: &str, version: &str, kind: &str, plural: &str, shorts: &[&str]) -> KubeObject {
        let mut names = Value::map().with("kind", kind).with("plural", plural);
        if !shorts.is_empty() {
            names.insert(
                "shortNames",
                Value::Seq(shorts.iter().map(|s| Value::from(*s)).collect()),
            );
        }
        let spec = Value::map().with("group", group).with("version", version).with("names", names);
        let mut o = KubeObject::new(KIND_CUSTOMRESOURCEDEFINITION, &format!("{plural}.{group}"), spec);
        o.api_version = APIEXTENSIONS_API_VERSION.into();
        o
    }
}

impl ResourceView for CrdView {
    fn kinds() -> &'static [&'static str] {
        &[KIND_CUSTOMRESOURCEDEFINITION]
    }
    fn from_object(obj: &KubeObject) -> Result<CrdView> {
        CrdView::from_object(obj)
    }
}

// -------------------------------------------------------------- TorqueJob

/// Typed view over the paper's TorqueJob CRD (Fig. 3) and the analogous
/// SlurmJob (WLM-Operator).
#[derive(Debug, Clone, PartialEq)]
pub struct WlmJobView {
    pub name: String,
    /// The embedded batch script (`spec.batch`, a block literal).
    pub batch: String,
    /// `spec.results.from`: file to collect after completion.
    pub results_from: Option<String>,
    /// `spec.mount.hostPath.path`: where results are staged.
    pub mount_path: Option<String>,
    pub status: String,
    /// WLM-side job id once submitted (`status.jobId`).
    pub wlm_job_id: Option<String>,
}

impl WlmJobView {
    pub fn from_object(o: &KubeObject) -> Result<WlmJobView> {
        if o.kind != KIND_TORQUEJOB && o.kind != KIND_SLURMJOB {
            return Err(Error::parse(format!("expected TorqueJob/SlurmJob, got {}", o.kind)));
        }
        Ok(WlmJobView {
            name: o.meta.name.clone(),
            batch: o
                .spec
                .req_str("batch")
                .map_err(|_| Error::parse("TorqueJob spec.batch missing"))?
                .to_string(),
            results_from: o
                .spec
                .path(&["results", "from"])
                .and_then(Value::as_str)
                .filter(|s| !s.is_empty())
                .map(String::from),
            mount_path: o
                .spec
                .path(&["mount", "hostPath", "path"])
                .and_then(Value::as_str)
                .filter(|s| !s.is_empty())
                .map(String::from),
            status: o.status.opt_str("phase").unwrap_or("").to_string(),
            wlm_job_id: o.status.opt_str("jobId").map(String::from),
        })
    }

    /// Build a TorqueJob object like the paper's cow_job.yaml. Empty
    /// `results_from`/`mount_path` mean "no results collection".
    pub fn build_torquejob(name: &str, batch: &str, results_from: &str, mount_path: &str) -> KubeObject {
        let mut spec = Value::map().with("batch", batch);
        if !results_from.is_empty() {
            spec.insert("results", Value::map().with("from", results_from));
        }
        if !mount_path.is_empty() {
            spec.insert(
                "mount",
                Value::map().with("name", "data").with(
                    "hostPath",
                    Value::map().with("path", mount_path).with("type", "DirectoryOrCreate"),
                ),
            );
        }
        let mut o = KubeObject::new(KIND_TORQUEJOB, name, spec);
        o.api_version = WLM_API_VERSION.into();
        o
    }
}

impl ResourceView for WlmJobView {
    /// TorqueJob first: it is the paper's contribution and the default for
    /// `Api::<WlmJobView>::new`; pick SlurmJob with `Api::of_kind`.
    fn kinds() -> &'static [&'static str] {
        &[KIND_TORQUEJOB, KIND_SLURMJOB]
    }
    fn from_object(obj: &KubeObject) -> Result<WlmJobView> {
        WlmJobView::from_object(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_json_roundtrip() {
        let mut o = KubeObject::new(KIND_POD, "p1", Value::map().with("x", 1i64));
        o.meta.uid = 42;
        o.meta.resource_version = 7;
        o.meta.set_label("app", "web");
        o.meta.owner = Some((KIND_DEPLOYMENT.into(), "web".into()));
        o.status = Value::map().with("phase", "Running");
        let back = KubeObject::from_json(&o.to_json()).unwrap();
        assert_eq!(back, o);
    }

    #[test]
    fn pod_view_roundtrip() {
        let pod = PodView::build(
            "p",
            "lolcow_latest.sif",
            Resources::new(500, 256 << 20, 0),
            &[("A".into(), "1".into())],
        );
        let v = PodView::from_object(&pod).unwrap();
        assert_eq!(v.image, "lolcow_latest.sif");
        assert_eq!(v.requests.cpu_milli, 500);
        assert_eq!(v.requests.mem_bytes, 256 << 20);
        assert_eq!(v.env, vec![("A".to_string(), "1".to_string())]);
        assert_eq!(v.phase, PodPhase::Pending);
        assert!(v.node_name.is_none());
    }

    #[test]
    fn pod_view_rejects_wrong_kind() {
        let o = KubeObject::new(KIND_NODE, "n", Value::map());
        assert!(PodView::from_object(&o).is_err());
        let o = KubeObject::new(KIND_POD, "p", Value::map());
        assert!(PodView::from_object(&o).is_err(), "no containers");
    }

    #[test]
    fn node_view_roundtrip() {
        let node = NodeView::build("vn-batch", Resources::cores(64, 256 << 30), &["virtual-kubelet"]);
        let v = NodeView::from_object(&node).unwrap();
        assert_eq!(v.name, "vn-batch");
        assert_eq!(v.capacity.cpu_milli, 64_000);
        assert_eq!(v.taints, vec!["virtual-kubelet"]);
        assert!(v.ready);
    }

    #[test]
    fn torquejob_view_matches_fig3() {
        let o = WlmJobView::build_torquejob(
            "cow",
            "#!/bin/sh\n#PBS -l nodes=1\nsingularity run lolcow_latest.sif\n",
            "$HOME/low.out",
            "$HOME/",
        );
        assert_eq!(o.api_version, WLM_API_VERSION);
        assert_eq!(o.kind, KIND_TORQUEJOB);
        let v = WlmJobView::from_object(&o).unwrap();
        assert_eq!(v.name, "cow");
        assert!(v.batch.contains("#PBS -l nodes=1"));
        assert_eq!(v.results_from.as_deref(), Some("$HOME/low.out"));
        assert_eq!(v.mount_path.as_deref(), Some("$HOME/"));
        assert_eq!(v.status, "");
    }

    #[test]
    fn scheduling_gate_roundtrip() {
        let mut pod = PodView::build("p", "img.sif", Resources::ZERO, &[]);
        assert!(scheduling_gates(&pod).is_empty());
        add_scheduling_gate(&mut pod, "kueue.x-k8s.io/admission");
        add_scheduling_gate(&mut pod, "kueue.x-k8s.io/admission"); // idempotent
        add_scheduling_gate(&mut pod, "other");
        assert_eq!(
            PodView::from_object(&pod).unwrap().scheduling_gates,
            vec!["kueue.x-k8s.io/admission", "other"]
        );
        // Gates survive the JSON roundtrip (they live in spec).
        let back = KubeObject::from_json(&pod.to_json()).unwrap();
        assert_eq!(scheduling_gates(&back).len(), 2);
        remove_scheduling_gate(&mut pod, "other");
        assert_eq!(scheduling_gates(&pod), vec!["kueue.x-k8s.io/admission"]);
        remove_scheduling_gate(&mut pod, "kueue.x-k8s.io/admission");
        assert!(scheduling_gates(&pod).is_empty());
        assert!(pod.spec.get("schedulingGates").is_none(), "empty list dropped");
    }

    #[test]
    fn node_cordon_flag() {
        let mut node = NodeView::build("n", Resources::cores(8, 32 << 30), &[]);
        assert!(!NodeView::from_object(&node).unwrap().unschedulable);
        node.spec.insert("unschedulable", true);
        assert!(NodeView::from_object(&node).unwrap().unschedulable);
    }

    #[test]
    fn pdb_view_roundtrip_and_selector() {
        let sel = vec![("app".to_string(), "web".to_string())];
        let o = PdbView::build_min_available("keep-two", &sel, 2);
        assert_eq!(o.api_version, POLICY_API_VERSION);
        let v = PdbView::from_object(&o).unwrap();
        assert_eq!(v.min_available, Some(2));
        assert_eq!(v.max_unavailable, None);
        assert!(v.matches(&[("app".into(), "web".into()), ("x".into(), "y".into())]));
        assert!(!v.matches(&[("app".into(), "db".into())]));
        assert!(!v.matches(&[]));
        let o2 = PdbView::build_max_unavailable("burst", &sel, 1);
        assert_eq!(PdbView::from_object(&o2).unwrap().max_unavailable, Some(1));
        // Empty selector matches nothing, not everything.
        let loose = PdbView::build_min_available("loose", &[], 1);
        assert!(!PdbView::from_object(&loose).unwrap().matches(&[("a".into(), "b".into())]));
    }

    #[test]
    fn crd_view_roundtrip() {
        let o = CrdView::build("stable.example.com", "v1", "FlinkJob", "flinkjobs", &["fj"]);
        assert_eq!(o.meta.name, "flinkjobs.stable.example.com");
        assert_eq!(o.api_version, APIEXTENSIONS_API_VERSION);
        let v = CrdView::from_object(&o).unwrap();
        assert_eq!(v.kind, "FlinkJob");
        assert_eq!(v.plural, "flinkjobs");
        assert_eq!(v.short_names, vec!["fj"]);
        assert_eq!(v.api_version(), "stable.example.com/v1");
    }

    #[test]
    fn phase_parse() {
        assert_eq!(PodPhase::parse("Running"), PodPhase::Running);
        assert_eq!(PodPhase::parse("garbage"), PodPhase::Pending);
        assert!(PodPhase::Succeeded.terminal());
        assert!(!PodPhase::Running.terminal());
    }
}
