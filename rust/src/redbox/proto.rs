//! red-box wire protocol.
//!
//! WLM-Operator's red-box is a gRPC proxy over a Unix socket; ours is the
//! same shape without the protoc toolchain: a **service/method** envelope,
//! length-prefixed frames, JSON bodies. Method names are `Service/Method`
//! (e.g. `torque.Workload/SubmitJob`), mirroring gRPC paths, and services
//! are defined as Rust traits in [`super::server`].
//!
//! Frame layout: `u32 LE body length | body bytes` where body is the JSON
//! encoding of a [`Frame`]:
//!
//! - [`Request`] / [`Response`] — the classic unary pair, encoded
//!   *untagged* (no `frame` key) so pre-stream peers interoperate
//!   unchanged.
//! - [`Frame::StreamItem`] — one pushed element of a server stream. `id`
//!   is the id of the request that opened the stream; `seq` counts items
//!   from 0 with no gaps (receivers treat a gap as stream corruption).
//! - [`Frame::StreamEnd`] — the stream is over. Server→client it carries
//!   the reason ([`END_COMPLETE`], [`END_GONE`], ...); client→server it
//!   is the cancel signal (the consumer went away, stop producing).
//!
//! Streams multiplex: one connection carries any number of concurrent
//! requests and live streams, demultiplexed by `id` — the gRPC
//! server-streaming shape over the same socket.

use crate::encoding::{json, Value};
use crate::util::{Error, Result};
use std::io::{Read, Write};

/// Maximum accepted frame (defensive; PBS scripts are small).
pub const MAX_FRAME: u32 = 16 * 1024 * 1024;

#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Client-assigned id, echoed in the response.
    pub id: u64,
    /// `Service/Method`, e.g. `torque.Workload/SubmitJob`.
    pub method: String,
    pub body: Value,
    /// Caller's trace context (`obs::TraceContext::to_wire`), absent when
    /// no trace is active. Optional on the wire, so old peers that never
    /// send (or don't understand) it interoperate unchanged.
    pub trace: Option<String>,
    /// Caller's actor identity (`obs::current_actor`) for audit
    /// attribution — e.g. `kubectl`, `kube-scheduler`. Optional on the
    /// wire with the same old-peer interop stance as `trace`.
    pub actor: Option<String>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    /// Ok ⇒ `body` is the result; Err ⇒ `error` holds the message.
    pub ok: bool,
    pub body: Value,
    pub error: String,
    /// Structured error ([`Error::encode_wire`]; `Null` when absent) so
    /// typed errors survive the socket — the gRPC-status equivalent.
    pub detail: Value,
}

impl Request {
    pub fn encode(&self) -> Value {
        let mut v = Value::map()
            .with("id", self.id)
            .with("method", self.method.clone())
            .with("body", self.body.clone());
        if let Some(t) = &self.trace {
            v.insert("trace", t.clone());
        }
        if let Some(a) = &self.actor {
            v.insert("actor", a.clone());
        }
        v
    }

    pub fn decode(v: &Value) -> Result<Request> {
        Ok(Request {
            id: v.req_int("id")? as u64,
            method: v.req_str("method")?.to_string(),
            body: v.get("body").cloned().unwrap_or(Value::Null),
            trace: v.opt_str("trace").map(String::from),
            actor: v.opt_str("actor").map(String::from),
        })
    }

    /// Split `Service/Method`.
    pub fn split_method(&self) -> Result<(&str, &str)> {
        self.method
            .split_once('/')
            .ok_or_else(|| Error::rpc(format!("malformed method `{}`", self.method)))
    }
}

impl Response {
    pub fn ok(id: u64, body: Value) -> Response {
        Response { id, ok: true, body, error: String::new(), detail: Value::Null }
    }

    pub fn err(id: u64, error: impl Into<String>) -> Response {
        Response { id, ok: false, body: Value::Null, error: error.into(), detail: Value::Null }
    }

    /// Error response carrying the typed error structurally, so the client
    /// reconstructs the exact [`Error`] variant instead of an opaque
    /// `Error::Rpc` string.
    pub fn err_typed(id: u64, e: &Error) -> Response {
        Response {
            id,
            ok: false,
            body: Value::Null,
            error: e.to_string(),
            detail: e.encode_wire(),
        }
    }

    pub fn encode(&self) -> Value {
        let mut v = Value::map()
            .with("id", self.id)
            .with("ok", self.ok)
            .with("body", self.body.clone())
            .with("error", self.error.clone());
        if !self.detail.is_null() {
            v.insert("detail", self.detail.clone());
        }
        v
    }

    pub fn decode(v: &Value) -> Result<Response> {
        Ok(Response {
            id: v.req_int("id")? as u64,
            ok: v.opt_bool("ok").unwrap_or(false),
            body: v.get("body").cloned().unwrap_or(Value::Null),
            error: v.opt_str("error").unwrap_or("").to_string(),
            detail: v.get("detail").cloned().unwrap_or(Value::Null),
        })
    }

    /// Convert into a Result, mapping transported errors back — typed when
    /// the envelope carries a structured detail, `Error::Rpc` otherwise.
    pub fn into_result(self) -> Result<Value> {
        if self.ok {
            Ok(self.body)
        } else if let Some(e) = Error::decode_wire(&self.detail) {
            Err(e)
        } else {
            Err(Error::rpc(self.error))
        }
    }
}

/// Stream ended because the producer is done (clean end of data).
pub const END_COMPLETE: &str = "complete";
/// Stream ended because the requested bookmark fell out of the server's
/// retained history window — the 410-Gone signal of the k8s watch API.
/// The consumer must relist and rewatch.
pub const END_GONE: &str = "gone";
/// Stream ended because the receiving side cancelled it.
pub const END_CANCELLED: &str = "cancelled";

/// One wire frame. `Request`/`Response` stay untagged on the wire; stream
/// frames carry a `"frame":"item"|"end"` discriminator, which untagged
/// peers never emit — so the tag space is collision-free.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Request(Request),
    Response(Response),
    /// One element of server stream `id`; `seq` counts from 0, gapless.
    StreamItem { id: u64, seq: u64, body: Value },
    /// Stream `id` is over (server→client: `reason` says why;
    /// client→server: cancel).
    StreamEnd { id: u64, reason: String },
}

impl Frame {
    pub fn encode(&self) -> Value {
        match self {
            Frame::Request(r) => r.encode(),
            Frame::Response(r) => r.encode(),
            Frame::StreamItem { id, seq, body } => Value::map()
                .with("frame", "item")
                .with("id", *id)
                .with("seq", *seq)
                .with("body", body.clone()),
            Frame::StreamEnd { id, reason } => Value::map()
                .with("frame", "end")
                .with("id", *id)
                .with("reason", reason.clone()),
        }
    }

    /// Decode a frame. Untagged maps are a [`Request`] when they name a
    /// `method`, a [`Response`] otherwise — the pre-stream wire shapes.
    pub fn decode(v: &Value) -> Result<Frame> {
        match v.opt_str("frame") {
            Some("item") => Ok(Frame::StreamItem {
                id: v.req_int("id")? as u64,
                seq: v.req_int("seq")? as u64,
                body: v.get("body").cloned().unwrap_or(Value::Null),
            }),
            Some("end") => Ok(Frame::StreamEnd {
                id: v.req_int("id")? as u64,
                reason: v.opt_str("reason").unwrap_or("").to_string(),
            }),
            Some(other) => Err(Error::rpc(format!("unknown frame tag `{other}`"))),
            None => {
                if v.get("method").is_some() {
                    Ok(Frame::Request(Request::decode(v)?))
                } else {
                    Ok(Frame::Response(Response::decode(v)?))
                }
            }
        }
    }
}

/// Write one frame.
pub fn write_frame(w: &mut impl Write, v: &Value) -> Result<()> {
    let body = json::to_string(v);
    let bytes = body.as_bytes();
    if bytes.len() as u64 > MAX_FRAME as u64 {
        return Err(Error::rpc(format!("frame too large: {} bytes", bytes.len())));
    }
    w.write_all(&(bytes.len() as u32).to_le_bytes())?;
    w.write_all(bytes)?;
    w.flush()?;
    Ok(())
}

/// Read one frame; `Ok(None)` on clean EOF at a frame boundary.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Value>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(Error::rpc(format!("oversized frame: {len} bytes")));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    let text = std::str::from_utf8(&body).map_err(|_| Error::rpc("frame not utf-8"))?;
    Ok(Some(json::parse(text)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let req = Request {
            id: 7,
            method: "torque.Workload/SubmitJob".into(),
            body: Value::map().with("script", "#PBS -l nodes=1"),
            trace: Some("00000000000000ab-00000000000000cd".into()),
            actor: Some("kubectl".into()),
        };
        let back = Request::decode(&req.encode()).unwrap();
        assert_eq!(back, req);
        assert_eq!(back.split_method().unwrap(), ("torque.Workload", "SubmitJob"));
    }

    #[test]
    fn response_roundtrip_and_result() {
        let ok = Response::ok(1, Value::str("42.torque-head"));
        assert_eq!(Response::decode(&ok.encode()).unwrap(), ok);
        assert_eq!(ok.clone().into_result().unwrap(), Value::str("42.torque-head"));
        let err = Response::err(2, "queue not found");
        assert!(Response::decode(&err.encode()).unwrap().into_result().is_err());
    }

    #[test]
    fn typed_errors_survive_the_envelope() {
        let e = Error::not_found("Pod", "p1");
        let resp = Response::err_typed(3, &e);
        let back = Response::decode(&resp.encode()).unwrap();
        assert_eq!(back, resp);
        let got = back.into_result().unwrap_err();
        assert_eq!(got, e, "variant reconstructed, not stringly Rpc");
        assert!(got.is_not_found());
        // Untyped err still degrades to Error::Rpc.
        let plain = Response::err(4, "boom").into_result().unwrap_err();
        assert!(matches!(plain, Error::Rpc(_)));
    }

    #[test]
    fn frame_roundtrip_all_variants() {
        let frames = vec![
            Frame::Request(Request {
                id: 1,
                method: "kube.Api/Watch".into(),
                body: Value::map().with("stream", true),
                trace: None,
                actor: None,
            }),
            Frame::Response(Response::ok(1, Value::map().with("streaming", true))),
            Frame::StreamItem { id: 1, seq: 0, body: Value::str("ev") },
            Frame::StreamItem { id: 1, seq: 1, body: Value::Null },
            Frame::StreamEnd { id: 1, reason: END_GONE.into() },
        ];
        for f in frames {
            assert_eq!(Frame::decode(&f.encode()).unwrap(), f);
        }
        // Untagged maps keep decoding as the classic pair.
        let req =
            Request { id: 2, method: "a.B/C".into(), body: Value::Null, trace: None, actor: None };
        assert_eq!(Frame::decode(&req.encode()).unwrap(), Frame::Request(req));
        let resp = Response::err(3, "boom");
        assert_eq!(Frame::decode(&resp.encode()).unwrap(), Frame::Response(resp));
        // Unknown tags are rejected, not misread as unary traffic.
        assert!(Frame::decode(&Value::map().with("frame", "novel")).is_err());
    }

    #[test]
    fn malformed_method() {
        let req =
            Request { id: 1, method: "nope".into(), body: Value::Null, trace: None, actor: None };
        assert!(req.split_method().is_err());
    }

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        let v = Value::map().with("hello", "world");
        write_frame(&mut buf, &v).unwrap();
        write_frame(&mut buf, &Value::Int(5)).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), Some(v));
        assert_eq!(read_frame(&mut r).unwrap(), Some(Value::Int(5)));
        assert_eq!(read_frame(&mut r).unwrap(), None); // clean EOF
    }

    #[test]
    fn truncated_frame_is_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Value::str("x")).unwrap();
        buf.truncate(buf.len() - 1);
        let mut r = &buf[..];
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        let mut r = &buf[..];
        assert!(read_frame(&mut r).is_err());
    }
}
