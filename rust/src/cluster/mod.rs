//! Cluster substrate: simulated machines, resource vectors, shared
//! filesystem, and the metrics registry.

pub mod fs;
pub mod metrics;
pub mod node;
pub mod resources;

pub use fs::SharedFs;
pub use metrics::{canonical_key, split_key, Metrics};
pub use node::{NodeRole, NodeSpec};
pub use resources::Resources;
