//! Crate-wide error type.
//!
//! Every subsystem reports failures through [`Error`]; the variants mirror
//! the boundaries of the system (API server, WLM, RPC, runtime, parsing) so
//! callers can branch on *where* something failed without string matching.

use std::fmt;

/// Unified error for all hpcorc subsystems.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Error {
    /// Malformed input: YAML/JSON/PBS script/manifest parse failures.
    Parse(String),
    /// Object/store errors from the kube API server (not found, conflict...).
    Api(ApiError),
    /// Workload-manager rejections (unknown queue, limit exceeded, bad state).
    Wlm(String),
    /// red-box / RPC transport failures.
    Rpc(String),
    /// Container image / runtime failures.
    Container(String),
    /// PJRT / XLA execution failures.
    Compute(String),
    /// I/O wrapper (socket, file staging).
    Io(String),
    /// Configuration errors (testbed topology, CLI args).
    Config(String),
    /// Internal invariant violations — a bug, not a user error.
    Internal(String),
}

/// Kubernetes-style API error reasons.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ApiError {
    NotFound { kind: String, name: String },
    AlreadyExists { kind: String, name: String },
    /// Optimistic-concurrency failure: resourceVersion mismatch.
    Conflict { kind: String, name: String },
    Invalid(String),
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApiError::NotFound { kind, name } => write!(f, "{kind} \"{name}\" not found"),
            ApiError::AlreadyExists { kind, name } => {
                write!(f, "{kind} \"{name}\" already exists")
            }
            ApiError::Conflict { kind, name } => write!(
                f,
                "operation cannot be fulfilled on {kind} \"{name}\": object was modified"
            ),
            ApiError::Invalid(msg) => write!(f, "invalid object: {msg}"),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Api(e) => write!(f, "api error: {e}"),
            Error::Wlm(m) => write!(f, "wlm error: {m}"),
            Error::Rpc(m) => write!(f, "rpc error: {m}"),
            Error::Container(m) => write!(f, "container error: {m}"),
            Error::Compute(m) => write!(f, "compute error: {m}"),
            Error::Io(m) => write!(f, "io error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Internal(m) => write!(f, "internal error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

impl From<ApiError> for Error {
    fn from(e: ApiError) -> Self {
        Error::Api(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Shorthand constructors used across the crate.
impl Error {
    pub fn parse(m: impl Into<String>) -> Self {
        Error::Parse(m.into())
    }
    pub fn wlm(m: impl Into<String>) -> Self {
        Error::Wlm(m.into())
    }
    pub fn rpc(m: impl Into<String>) -> Self {
        Error::Rpc(m.into())
    }
    pub fn container(m: impl Into<String>) -> Self {
        Error::Container(m.into())
    }
    pub fn compute(m: impl Into<String>) -> Self {
        Error::Compute(m.into())
    }
    pub fn config(m: impl Into<String>) -> Self {
        Error::Config(m.into())
    }
    pub fn internal(m: impl Into<String>) -> Self {
        Error::Internal(m.into())
    }
    pub fn not_found(kind: impl Into<String>, name: impl Into<String>) -> Self {
        Error::Api(ApiError::NotFound { kind: kind.into(), name: name.into() })
    }
    pub fn already_exists(kind: impl Into<String>, name: impl Into<String>) -> Self {
        Error::Api(ApiError::AlreadyExists { kind: kind.into(), name: name.into() })
    }
    pub fn conflict(kind: impl Into<String>, name: impl Into<String>) -> Self {
        Error::Api(ApiError::Conflict { kind: kind.into(), name: name.into() })
    }

    /// True if this is a NotFound API error (common branch in controllers).
    pub fn is_not_found(&self) -> bool {
        matches!(self, Error::Api(ApiError::NotFound { .. }))
    }
    /// True if this is an optimistic-concurrency conflict (controllers retry).
    pub fn is_conflict(&self) -> bool {
        matches!(self, Error::Api(ApiError::Conflict { .. }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::not_found("TorqueJob", "cow");
        assert_eq!(e.to_string(), "api error: TorqueJob \"cow\" not found");
        assert!(e.is_not_found());
        assert!(!e.is_conflict());
    }

    #[test]
    fn conflict_detection() {
        let e = Error::conflict("Pod", "p1");
        assert!(e.is_conflict());
        let io: Error = std::io::Error::new(std::io::ErrorKind::Other, "boom").into();
        assert!(matches!(io, Error::Io(_)));
    }
}
