//! Failure-injection walkthrough — the paper's §V concern ("future work
//! will focus on optimization of Torque-Operator that can offer more
//! stable deployments") exercised against our implementation:
//!
//!  1. a TorqueJob whose PBS job exceeds walltime → `timeout`
//!  2. a TorqueJob deleted mid-run → WLM job cancelled (qdel via red-box)
//!  3. a job referencing a missing image → `failed`, error surfaced
//!  4. controller retry: results file missing at first collect → backoff
//!     retries until the job still terminates deterministically
//!
//! Run: cargo run --release --example operator_failover

use hpcorc::hybrid::{Testbed, TestbedConfig};
use hpcorc::kube::{Api, WlmJobView};
use std::time::Duration;

fn main() {
    println!("=== operator failure injection ===\n");
    let tb = Testbed::start(TestbedConfig::default()).expect("boot");
    // Typed handle over the unified ApiClient (default kind: TorqueJob).
    let jobs: Api<WlmJobView> = Api::new(tb.client());

    // 1. walltime timeout (5s nominal walltime, 60s nominal job).
    jobs.create(WlmJobView::build_torquejob(
        "too-long",
        "#PBS -l walltime=0:05\nsleep 60\n",
        "$HOME/x",
        "$HOME/",
    ))
    .unwrap();
    let p = tb.wait_torquejob("too-long", Duration::from_secs(30)).unwrap();
    println!("1. walltime exceeded      -> phase `{p}` (expected timeout)");
    assert_eq!(p, "timeout");

    // 2. delete mid-run cancels the WLM job.
    jobs.create(WlmJobView::build_torquejob(
        "doomed",
        "sleep 600\n",
        "$HOME/x",
        "$HOME/",
    ))
    .unwrap();
    // wait until it has a WLM job id (the typed view carries it)
    let job_id = loop {
        if let Some(id) = jobs.get("doomed").unwrap().wlm_job_id {
            break id;
        }
        std::thread::sleep(Duration::from_millis(5));
    };
    jobs.delete("doomed").unwrap();
    let seq = hpcorc::util::JobId::parse(&job_id).unwrap().seq;
    let job = tb.pbs.wait_for(seq, Duration::from_secs(30)).unwrap();
    println!("2. kubectl delete mid-run -> torque job {job_id} cancelled={} ✓", job.cancelled);
    assert!(job.cancelled);

    // 3. missing image fails cleanly.
    jobs.create(WlmJobView::build_torquejob(
        "ghost",
        "#PBS -o $HOME/ghost.out\nsingularity run no_such_image.sif\n",
        "$HOME/ghost.out",
        "$HOME/",
    ))
    .unwrap();
    let p = tb.wait_torquejob("ghost", Duration::from_secs(30)).unwrap();
    let exit = jobs.get_raw("ghost").unwrap().status.opt_int("exitCode");
    println!("3. missing image          -> phase `{p}`, exitCode {exit:?} (expected failed/255)");
    assert_eq!(p, "failed");

    // 4. results file outside the job's outputs: collect fails, operator
    //    retries with backoff, job still ends terminal (failed reconcile
    //    does not wedge the controller).
    jobs.create(WlmJobView::build_torquejob(
        "no-results",
        "echo done\n",
        "$HOME/never-written.out",
        "$HOME/",
    ))
    .unwrap();
    match tb.wait_torquejob("no-results", Duration::from_secs(10)) {
        Ok(p) => println!("4. missing results file   -> phase `{p}`"),
        Err(_) => {
            // Still stuck in transferring-results with retries — write the
            // file (operator converges on the next reconcile).
            tb.fs.write("$HOME/never-written.out", b"late output\n").unwrap();
            let p = tb.wait_torquejob("no-results", Duration::from_secs(30)).unwrap();
            println!("4. late results file      -> operator retried, phase `{p}` ✓");
            assert_eq!(p, "completed");
        }
    }

    let m = tb.metrics.snapshot();
    for (k, v) in m {
        if k.starts_with("operator.") || k == "controller.reconcile_errors" {
            println!("   metric {k:<30} {v}");
        }
    }
    tb.stop();
    println!("\noperator_failover OK");
}
