//! Quota accounting: a pure ledger over ClusterQueues and their cohorts.
//!
//! The ledger answers one question — *can this gang be charged to this
//! queue right now?* — under the Kueue capacity model:
//!
//! - a queue may always use up to its **nominal** quota;
//! - beyond nominal it **borrows**, capped by its own `borrowingLimit`
//!   (absent = unlimited) and by the cohort's total capacity (the sum of
//!   members' nominal quotas — borrowing consumes peers' *idle* nominal
//!   capacity, never conjures new capacity);
//! - a queue without a cohort has nobody to borrow from: nominal is its
//!   ceiling.
//!
//! The ledger is pure state (no API calls), so the admission controller,
//! the simulator's `QueueAdmission` layer, and the preemption victim
//! search can all run the same arithmetic — preemption simulates
//! evictions on a cloned ledger before touching any object.

use super::types::{ClusterQueueView, QueueResources};

/// One queue's live accounting entry.
#[derive(Debug, Clone)]
pub struct QueueState {
    pub view: ClusterQueueView,
    /// Demand of everything currently admitted through this queue.
    pub usage: QueueResources,
}

impl QueueState {
    /// Usage beyond nominal (what this queue currently borrows).
    pub fn borrowed(&self) -> QueueResources {
        self.usage.saturating_sub(&self.view.nominal)
    }

    /// Is any dimension over nominal?
    pub fn is_borrowing(&self) -> bool {
        !self.view.nominal.covers(&self.usage)
    }
}

/// Why a gang cannot be charged (or that it can).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fit {
    /// Admissible now; `borrowed` is how far past nominal the queue's
    /// usage would land.
    Ok { borrowed: bool },
    /// Blocked, but the gang alone is within the queue's nominal quota —
    /// preemption (reclaim / within-queue) could clear the way.
    BlockedWithinNominal,
    /// Blocked and the gang needs capacity beyond what preemption may
    /// reclaim for it: it simply waits (borrowing gangs never preempt).
    Blocked,
    /// The queue is not registered in this ledger.
    UnknownQueue,
}

impl Fit {
    pub fn admissible(&self) -> bool {
        matches!(self, Fit::Ok { .. })
    }
}

/// The cohort-aware quota ledger.
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    queues: Vec<QueueState>,
}

impl Ledger {
    pub fn new(views: Vec<ClusterQueueView>) -> Ledger {
        Ledger {
            queues: views
                .into_iter()
                .map(|view| QueueState { view, usage: QueueResources::ZERO })
                .collect(),
        }
    }

    pub fn queue(&self, name: &str) -> Option<&QueueState> {
        self.queues.iter().find(|q| q.view.name == name)
    }

    fn queue_mut(&mut self, name: &str) -> Option<&mut QueueState> {
        self.queues.iter_mut().find(|q| q.view.name == name)
    }

    /// Charge admitted demand to a queue (no capacity check — callers
    /// rebuild the ledger from observed admitted state, which must be
    /// represented faithfully even if a quota was shrunk under it).
    pub fn charge(&mut self, queue: &str, demand: &QueueResources) {
        if let Some(q) = self.queue_mut(queue) {
            q.usage = q.usage.saturating_add(demand);
        }
    }

    /// Release demand (eviction / completion during a preemption search).
    pub fn uncharge(&mut self, queue: &str, demand: &QueueResources) {
        if let Some(q) = self.queue_mut(queue) {
            q.usage = q.usage.saturating_sub(demand);
        }
    }

    /// Total nominal capacity of a cohort (what borrowing draws on).
    pub fn cohort_capacity(&self, cohort: &str) -> QueueResources {
        self.queues
            .iter()
            .filter(|q| q.view.cohort.as_deref() == Some(cohort))
            .fold(QueueResources::ZERO, |acc, q| acc.saturating_add(&q.view.nominal))
    }

    /// Total usage charged across a cohort. Usage above a member's
    /// nominal still consumes cohort capacity, so this is a plain sum.
    pub fn cohort_usage(&self, cohort: &str) -> QueueResources {
        self.queues
            .iter()
            .filter(|q| q.view.cohort.as_deref() == Some(cohort))
            .fold(QueueResources::ZERO, |acc, q| acc.saturating_add(&q.usage))
    }

    /// Can `demand` be charged to `queue` right now, all-or-nothing?
    pub fn fit(&self, queue: &str, demand: &QueueResources) -> Fit {
        let Some(q) = self.queue(queue) else { return Fit::UnknownQueue };
        let after = q.usage.saturating_add(demand);
        let ceiling = match (&q.view.cohort, &q.view.borrowing_limit) {
            // No cohort: nobody to borrow from, nominal is the ceiling.
            (None, _) => q.view.nominal,
            (Some(_), Some(limit)) => q.view.nominal.saturating_add(limit),
            (Some(_), None) => QueueResources::UNBOUNDED,
        };
        let cohort_ok = match &q.view.cohort {
            None => true,
            Some(c) => self
                .cohort_capacity(c)
                .covers(&self.cohort_usage(c).saturating_add(demand)),
        };
        if ceiling.covers(&after) && cohort_ok {
            return Fit::Ok { borrowed: !q.view.nominal.covers(&after) };
        }
        // Within nominal on its own (usage aside): preemption could help.
        if q.view.nominal.covers(demand) {
            Fit::BlockedWithinNominal
        } else {
            Fit::Blocked
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kueue::types::{PreemptionPolicy, QueueOrdering};

    fn cq(
        name: &str,
        cohort: Option<&str>,
        nominal_nodes: u32,
        borrow_nodes: Option<u32>,
    ) -> ClusterQueueView {
        ClusterQueueView::from_object(&ClusterQueueView::build_full(
            name,
            cohort,
            QueueResources::nodes(nominal_nodes),
            borrow_nodes.map(QueueResources::nodes),
            QueueOrdering::Fifo,
            PreemptionPolicy::default(),
        ))
        .unwrap()
    }

    fn nodes(n: u32) -> QueueResources {
        QueueResources { nodes: n, cpu_milli: 0, mem_bytes: 0 }
    }

    #[test]
    fn nominal_is_ceiling_without_cohort() {
        let mut l = Ledger::new(vec![cq("a", None, 3, None)]);
        assert_eq!(l.fit("a", &nodes(3)), Fit::Ok { borrowed: false });
        assert_eq!(l.fit("a", &nodes(4)), Fit::Blocked, "no cohort, no borrowing");
        l.charge("a", &nodes(2));
        assert_eq!(l.fit("a", &nodes(1)), Fit::Ok { borrowed: false });
        assert_eq!(
            l.fit("a", &nodes(2)),
            Fit::BlockedWithinNominal,
            "fits nominal alone, blocked by usage"
        );
        l.uncharge("a", &nodes(2));
        assert_eq!(l.fit("a", &nodes(3)), Fit::Ok { borrowed: false });
        assert_eq!(l.fit("ghost", &nodes(1)), Fit::UnknownQueue);
    }

    #[test]
    fn borrowing_from_idle_cohort_peer() {
        let mut l = Ledger::new(vec![cq("a", Some("pool"), 2, None), cq("b", Some("pool"), 2, None)]);
        // a can reach 4 (cohort capacity) while b idles.
        assert_eq!(l.fit("a", &nodes(3)), Fit::Ok { borrowed: true });
        assert_eq!(l.fit("a", &nodes(4)), Fit::Ok { borrowed: true });
        assert_eq!(l.fit("a", &nodes(5)), Fit::Blocked, "cohort capacity is the hard cap");
        l.charge("a", &nodes(3));
        assert!(l.queue("a").unwrap().is_borrowing());
        assert_eq!(l.queue("a").unwrap().borrowed(), nodes(1));
        // b's nominal is promised but partially consumed by a's borrow.
        assert_eq!(l.fit("b", &nodes(1)), Fit::Ok { borrowed: false });
        assert_eq!(
            l.fit("b", &nodes(2)),
            Fit::BlockedWithinNominal,
            "within b's nominal -> reclaim candidate"
        );
    }

    #[test]
    fn borrowing_limit_caps_overdraft() {
        let l = Ledger::new(vec![
            cq("a", Some("pool"), 2, Some(1)),
            cq("b", Some("pool"), 4, None),
        ]);
        assert_eq!(l.fit("a", &nodes(3)), Fit::Ok { borrowed: true });
        assert_eq!(l.fit("a", &nodes(4)), Fit::Blocked, "borrowingLimit 1 caps at 3");
    }

    #[test]
    fn cohort_capacity_and_usage_sum_members() {
        let mut l = Ledger::new(vec![
            cq("a", Some("pool"), 2, None),
            cq("b", Some("pool"), 3, None),
            cq("c", None, 7, None),
        ]);
        assert_eq!(l.cohort_capacity("pool").nodes, 5);
        l.charge("a", &nodes(1));
        l.charge("b", &nodes(2));
        l.charge("c", &nodes(7)); // not in the cohort
        assert_eq!(l.cohort_usage("pool").nodes, 3);
    }

    #[test]
    fn multi_dimensional_fit() {
        let view = ClusterQueueView::from_object(&ClusterQueueView::build(
            "a",
            QueueResources { nodes: 4, cpu_milli: 4000, mem_bytes: 4 << 30 },
        ))
        .unwrap();
        let l = Ledger::new(vec![view]);
        // Node-count fits but cpu does not.
        let d = QueueResources { nodes: 1, cpu_milli: 8000, mem_bytes: 1 << 30 };
        assert_eq!(l.fit("a", &d), Fit::Blocked);
        let d = QueueResources { nodes: 2, cpu_milli: 2000, mem_bytes: 1 << 30 };
        assert_eq!(l.fit("a", &d), Fit::Ok { borrowed: false });
    }
}
