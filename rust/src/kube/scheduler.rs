//! kube-scheduler: assigns pending pods to nodes.
//!
//! The standard two-phase cycle: **filter** (resource fit, nodeSelector,
//! taints/tolerations, node Ready) then **score** (least-allocated), then
//! **bind** (set `spec.nodeName`). Virtual nodes carry the
//! `virtual-kubelet` taint, so only the operator's dummy pods — which
//! tolerate it — land there (paper Fig. 2).
//!
//! Written against typed [`Api`] handles over any [`ApiClient`], so the
//! scheduler could equally run out-of-process against a remote API server.

use super::api::{KubeObject, NodeView, PodPhase, PodView};
use super::client::{Api, ApiClient, ListOptions};
use crate::cluster::{Metrics, Resources};
use crate::rt::{self, Shutdown};
use std::sync::Arc;
use std::time::Duration;

pub struct KubeScheduler {
    nodes: Api<NodeView>,
    pods: Api<PodView>,
    metrics: Metrics,
}

impl KubeScheduler {
    pub fn new(client: Arc<dyn ApiClient>, metrics: Metrics) -> KubeScheduler {
        KubeScheduler {
            nodes: Api::new(client.clone()),
            pods: Api::new(client),
            metrics,
        }
    }

    /// Run as a daemon: a scheduling cycle per period.
    pub fn start(self, period: Duration, shutdown: Shutdown) {
        rt::pool::spawn_ticker("kube-sched", period, shutdown, move || {
            self.run_cycle();
        });
    }

    /// One full scheduling cycle; returns the number of pods bound.
    /// Public for deterministic stepping in tests/benches.
    pub fn run_cycle(&self) -> usize {
        let t0 = std::time::Instant::now();
        // A broken transport must not masquerade as "nothing to schedule".
        // (Undecodable objects are skipped below, so a malformed
        // hand-written manifest cannot wedge the cycle either.)
        let (nodes, pods) = match (
            self.nodes.list(&ListOptions::all()),
            self.pods.list_raw(&ListOptions::all()),
        ) {
            (Ok(n), Ok(p)) => (n, p.items),
            (Err(e), _) | (_, Err(e)) => {
                self.metrics.inc("kube.sched.list_errors");
                crate::warn!("kube-sched", "list failed, skipping cycle: {e}");
                return 0;
            }
        };
        // Usage per node from bound, non-terminal pods.
        let mut used: Vec<(String, Resources)> =
            nodes.iter().map(|n| (n.name.clone(), Resources::ZERO)).collect();
        let mut pending: Vec<PodView> = Vec::new();
        for obj in &pods {
            let Ok(view) = PodView::from_object(obj) else { continue };
            match (&view.node_name, view.phase) {
                (Some(node), phase) if !phase.terminal() => {
                    if let Some((_, u)) = used.iter_mut().find(|(n, _)| n == node) {
                        *u += view.requests;
                    }
                }
                (None, PodPhase::Pending) => {
                    // Scheduling gates (k8s `spec.schedulingGates`): a pod
                    // with any gate present is not scheduler-ready.
                    // Admission layers (kueue, PR 2/3) set and clear their
                    // own gates — the scheduler knows nothing about them.
                    if !view.scheduling_gates.is_empty() {
                        self.metrics.inc("kube.sched.gated");
                        continue;
                    }
                    pending.push(view);
                }
                _ => {}
            }
        }
        // Sort pending by creation (FIFO-ish, as the real scheduler's
        // priority queue without priorities).
        pending.sort_by(|a, b| a.name.cmp(&b.name));

        let mut bound = 0;
        for pod in pending {
            let mut candidates: Vec<(&NodeView, Resources)> = nodes
                .iter()
                .filter(|n| n.ready)
                // cordoned nodes (autoscaler drain) accept nothing new
                .filter(|n| !n.unschedulable)
                // taints: pod must tolerate every NoSchedule taint
                .filter(|n| n.taints.iter().all(|t| pod.tolerations.contains(t)))
                // nodeSelector: all pairs must match node labels
                .filter(|n| {
                    pod.node_selector.iter().all(|(k, v)| {
                        n.labels.iter().any(|(nk, nv)| nk == k && nv == v)
                    })
                })
                .filter_map(|n| {
                    let u = used
                        .iter()
                        .find(|(name, _)| name == &n.name)
                        .map(|(_, u)| *u)
                        .unwrap_or(Resources::ZERO);
                    let free = n.capacity.saturating_sub(&u);
                    free.fits(&pod.requests).then_some((n, u))
                })
                .collect();
            if candidates.is_empty() {
                self.metrics.inc("kube.sched.unschedulable");
                continue;
            }
            // Score: least allocated (lowest dominant fraction after adding).
            candidates.sort_by(|(na, ua), (nb, ub)| {
                let fa = (*ua + pod.requests).dominant_fraction(&na.capacity);
                let fb = (*ub + pod.requests).dominant_fraction(&nb.capacity);
                fa.partial_cmp(&fb).unwrap().then(na.name.cmp(&nb.name))
            });
            let chosen = candidates[0].0.name.clone();
            // Bind.
            let ok = self
                .pods
                .update_status(&pod.name, &|o| {
                    o.spec.insert("nodeName", chosen.clone());
                })
                .is_ok();
            if ok {
                if let Some((_, u)) = used.iter_mut().find(|(n, _)| n == &chosen) {
                    *u += pod.requests;
                }
                bound += 1;
                self.metrics.inc("kube.sched.bound");
            }
        }
        self.metrics.observe("kube.sched.cycle_ns", t0.elapsed().as_nanos() as u64);
        bound
    }
}

/// Helper for building schedulable pods in tests and the operator.
pub fn pod_with_tolerations(mut pod: KubeObject, tolerations: &[&str]) -> KubeObject {
    if !tolerations.is_empty() {
        pod.spec.insert(
            "tolerations",
            crate::encoding::Value::Seq(
                tolerations
                    .iter()
                    .map(|t| {
                        crate::encoding::Value::map()
                            .with("key", *t)
                            .with("operator", "Exists")
                    })
                    .collect(),
            ),
        );
    }
    pod
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kube::api::{NodeView, PodView, KIND_NODE, KIND_POD};
    use crate::kube::apiserver::ApiServer;

    fn setup() -> (ApiServer, KubeScheduler) {
        let api = ApiServer::new(Metrics::new());
        let sched = KubeScheduler::new(api.client(), Metrics::new());
        (api, sched)
    }

    fn add_node(api: &ApiServer, name: &str, cores: u32) {
        api.create(NodeView::build(name, Resources::cores(cores, 32 << 30), &[])).unwrap();
    }

    fn add_pod(api: &ApiServer, name: &str, cpu_milli: u64) -> KubeObject {
        let pod = PodView::build(
            name,
            "lolcow_latest.sif",
            Resources::new(cpu_milli, 1 << 30, 0),
            &[],
        );
        api.create(pod).unwrap()
    }

    fn node_of(api: &ApiServer, pod: &str) -> Option<String> {
        api.get(KIND_POD, pod).unwrap().spec.opt_str("nodeName").map(String::from)
    }

    #[test]
    fn binds_pending_pods() {
        let (api, sched) = setup();
        add_node(&api, "w1", 8);
        add_pod(&api, "p1", 1000);
        assert_eq!(sched.run_cycle(), 1);
        assert_eq!(node_of(&api, "p1").as_deref(), Some("w1"));
        // Second cycle: nothing to do.
        assert_eq!(sched.run_cycle(), 0);
    }

    #[test]
    fn respects_capacity() {
        let (api, sched) = setup();
        add_node(&api, "w1", 2); // 2000m
        add_pod(&api, "p1", 1500);
        add_pod(&api, "p2", 1500); // doesn't fit alongside p1
        assert_eq!(sched.run_cycle(), 1);
        assert!(node_of(&api, "p2").is_none(), "p2 unschedulable");
        // Free capacity by completing p1.
        api.update_status(KIND_POD, "p1", |o| {
            o.status.insert("phase", "Succeeded");
        })
        .unwrap();
        assert_eq!(sched.run_cycle(), 1);
        assert_eq!(node_of(&api, "p2").as_deref(), Some("w1"));
    }

    #[test]
    fn least_allocated_spreads() {
        let (api, sched) = setup();
        add_node(&api, "w1", 8);
        add_node(&api, "w2", 8);
        add_pod(&api, "p1", 1000);
        add_pod(&api, "p2", 1000);
        sched.run_cycle();
        let n1 = node_of(&api, "p1").unwrap();
        let n2 = node_of(&api, "p2").unwrap();
        assert_ne!(n1, n2, "pods spread across nodes");
    }

    #[test]
    fn taints_require_toleration() {
        let (api, sched) = setup();
        api.create(NodeView::build(
            "vnode-batch",
            Resources::cores(64, 256 << 30),
            &["virtual-kubelet"],
        ))
        .unwrap();
        add_pod(&api, "plain", 100);
        assert_eq!(sched.run_cycle(), 0, "plain pod cannot land on tainted node");
        let dummy = pod_with_tolerations(
            PodView::build("dummy", "lolcow_latest.sif", Resources::ZERO, &[]),
            &["virtual-kubelet"],
        );
        api.create(dummy).unwrap();
        assert_eq!(sched.run_cycle(), 1);
        assert_eq!(node_of(&api, "dummy").as_deref(), Some("vnode-batch"));
    }

    #[test]
    fn node_selector_filters() {
        let (api, sched) = setup();
        add_node(&api, "w1", 8);
        let mut gpu_node = NodeView::build("w2", Resources::cores(8, 32 << 30), &[]);
        gpu_node.meta.set_label("accelerator", "gpu");
        api.create(gpu_node).unwrap();
        let mut pod = PodView::build("gp", "img", Resources::new(100, 0, 0), &[]);
        pod.spec.insert(
            "nodeSelector",
            crate::encoding::Value::map().with("accelerator", "gpu"),
        );
        api.create(pod).unwrap();
        sched.run_cycle();
        assert_eq!(node_of(&api, "gp").as_deref(), Some("w2"));
    }

    #[test]
    fn scheduling_gated_pod_held_until_gates_clear() {
        use crate::kube::api::{add_scheduling_gate, remove_scheduling_gate};
        let (api, sched) = setup();
        add_node(&api, "w1", 8);
        let mut pod = PodView::build("gated", "img", Resources::new(100, 1 << 20, 0), &[]);
        add_scheduling_gate(&mut pod, "kueue.x-k8s.io/admission");
        add_scheduling_gate(&mut pod, "other-layer");
        api.create(pod).unwrap();
        assert_eq!(sched.run_cycle(), 0, "gated pod must not bind");
        // One gate down, one to go: still held.
        api.update_status(KIND_POD, "gated", |o| {
            remove_scheduling_gate(o, "kueue.x-k8s.io/admission");
        })
        .unwrap();
        assert_eq!(sched.run_cycle(), 0, "every gate must clear");
        api.update_status(KIND_POD, "gated", |o| {
            remove_scheduling_gate(o, "other-layer");
        })
        .unwrap();
        assert_eq!(sched.run_cycle(), 1);
        assert_eq!(node_of(&api, "gated").as_deref(), Some("w1"));
    }

    #[test]
    fn cordoned_node_excluded() {
        let (api, sched) = setup();
        add_node(&api, "w1", 8);
        add_node(&api, "w2", 8);
        api.update_status(KIND_NODE, "w1", |o| {
            o.spec.insert("unschedulable", true);
        })
        .unwrap();
        add_pod(&api, "p1", 100);
        add_pod(&api, "p2", 100);
        assert_eq!(sched.run_cycle(), 2);
        assert_eq!(node_of(&api, "p1").as_deref(), Some("w2"), "cordoned node skipped");
        assert_eq!(node_of(&api, "p2").as_deref(), Some("w2"));
    }

    #[test]
    fn not_ready_node_excluded() {
        let (api, sched) = setup();
        add_node(&api, "w1", 8);
        api.update_status(KIND_NODE, "w1", |o| {
            o.status.insert("phase", "NotReady");
        })
        .unwrap();
        add_pod(&api, "p1", 100);
        assert_eq!(sched.run_cycle(), 0);
    }
}
