//! Scheduler throughput (PR 9): what do the fit/score index and the
//! batched bind path actually buy at fleet scale?
//!
//! - **flash-crowd drain, indexed vs brute** — pods scheduled per second
//!   at 1k and 10k nodes. The indexed path is `run_cycle` (SchedIndex
//!   candidates + one `update_status_batch` per cycle); the baseline is
//!   `run_cycle_brute`, the pre-PR-9 pass kept verbatim (O(nodes)
//!   filter/score per pod, linear `used` lookups, one `update_status`
//!   round trip per bind). Pod creation happens outside the timed
//!   window — only the scheduling cycle is measured.
//! - **index maintenance per delta** — cost of folding one informer
//!   delta (node heartbeat) into the index.
//! - **bind round trips, batched vs single** — red-box requests crossing
//!   the socket to commit a 64-pod burst.
//!
//! Ends with `{"bench":...}` JSON lines for the perf trajectory and the
//! PR 9 acceptance asserts: indexed ≥ 10× brute pods/sec at 10k nodes,
//! and the 64-pod batch commits in ≤ 2 round trips.

use hpcorc::bench::fmt_ns;
use hpcorc::cluster::{Metrics, Resources};
use hpcorc::encoding::Value;
use hpcorc::kube::{
    ApiClient, ApiServer, BatchPatchItem, KubeScheduler, NodeView, PodView,
    RemoteApi, SharedInformerFactory, KIND_NODE, KIND_POD,
};
use hpcorc::redbox::RedboxServer;
use hpcorc::rt::Shutdown;
use std::sync::Arc;
use std::time::Instant;

/// A uniform fleet of `n` 64-core workers behind a warm scheduler (the
/// seed cycle pays the informer list + initial index build up front).
fn fleet(n: usize) -> (ApiServer, SharedInformerFactory, KubeScheduler) {
    let api = ApiServer::new(Metrics::new());
    for i in 0..n {
        api.create(NodeView::build(&format!("w{i:05}"), Resources::cores(64, 256 << 30), &[]))
            .unwrap();
    }
    let informers = SharedInformerFactory::new(api.client(), Metrics::new());
    let sched = KubeScheduler::new(&informers, Metrics::new());
    assert_eq!(sched.run_cycle(), 0);
    (api, informers, sched)
}

/// Drain `reps` bursts of `burst` pods each through one cycle per burst,
/// timing only the cycles. Returns pods scheduled per second.
fn drain_rate(
    label: &str,
    api: &ApiServer,
    sched: &KubeScheduler,
    burst: usize,
    reps: usize,
    brute: bool,
) -> f64 {
    let mut seq = 0usize;
    let mut total_ns = 0u128;
    let mut total_pods = 0usize;
    for _ in 0..reps {
        for _ in 0..burst {
            seq += 1;
            api.create(PodView::build(
                &format!("p{seq:07}"),
                "lolcow_latest.sif",
                Resources::new(100, 1 << 20, 0),
                &[],
            ))
            .unwrap();
        }
        let t0 = Instant::now();
        let bound = if brute { sched.run_cycle_brute() } else { sched.run_cycle() };
        total_ns += t0.elapsed().as_nanos();
        assert_eq!(bound, burst, "{label}: whole burst must bind");
        total_pods += bound;
    }
    let mean_cycle = total_ns as f64 / reps as f64;
    let rate = total_pods as f64 / (total_ns as f64 / 1e9).max(1e-12);
    println!("{label:<44} {:>10}/cycle   {rate:>12.0} pods/s", fmt_ns(mean_cycle));
    println!(
        "{{\"bench\":\"{label}\",\"pods\":{total_pods},\"mean_cycle_ns\":{mean_cycle:.0},\"pods_per_sec\":{rate:.0}}}"
    );
    rate
}

fn main() {
    println!("=== scheduler throughput: fit/score index + batched binds ===");

    // Flash-crowd drain at both fleet scales. Separate fleets per mode:
    // index state and bound-pod caches must not leak across baselines.
    // The brute burst shrinks at 10k — it is O(pods × nodes²) and exists
    // to be beaten, not waited on.
    let mut indexed_10k = 0.0f64;
    let mut brute_10k = 0.0f64;
    for n in [1_000usize, 10_000] {
        let (api, _inf, sched) = fleet(n);
        let r = drain_rate(&format!("drain indexed ({n} nodes)"), &api, &sched, 64, 3, false);
        if n == 10_000 {
            indexed_10k = r;
        }
        let (api, _inf, sched) = fleet(n);
        let burst = if n >= 10_000 { 8 } else { 64 };
        let r =
            drain_rate(&format!("drain brute-force ({n} nodes)"), &api, &sched, burst, 2, true);
        if n == 10_000 {
            brute_10k = r;
        }
    }

    // Index maintenance: fold a batch of node-heartbeat deltas and charge
    // the refresh per delta. Writes and informer sync stay untimed — the
    // row is the index's own cost, not the transport's.
    let (api, informers, sched) = fleet(1_000);
    let nodes = informers.informer(KIND_NODE);
    let index = sched.index();
    const DELTAS: usize = 100;
    let mut beat = 0u64;
    let mut per_delta = Vec::new();
    for _ in 0..20 {
        for i in 0..DELTAS {
            beat += 1;
            api.update_status(KIND_NODE, &format!("w{i:05}"), |o| {
                o.status.insert("beat", beat);
            })
            .unwrap();
        }
        nodes.sync().unwrap();
        let t0 = Instant::now();
        index.refresh();
        per_delta.push(t0.elapsed().as_nanos() as u64 / DELTAS as u64);
    }
    let mean = per_delta.iter().sum::<u64>() as f64 / per_delta.len() as f64;
    println!("{:<44} {:>10}/delta", "index maintenance (1k nodes)", fmt_ns(mean));
    println!(
        "{{\"bench\":\"index maintenance per delta (1k nodes)\",\"deltas\":{},\"mean_ns\":{mean:.0}}}",
        DELTAS * per_delta.len()
    );

    // Bind round trips over a real socket: one 64-item batch vs 64
    // singles, counted at the server (`redbox.requests`).
    let sd = Shutdown::new();
    let sock = std::env::temp_dir()
        .join(format!("hpcorc-bench-scheduler-{}.sock", std::process::id()));
    let server_metrics = Metrics::new();
    let mut srv = RedboxServer::start(&sock, sd.clone(), server_metrics.clone()).unwrap();
    let api = ApiServer::new(Metrics::new());
    srv.register("kube.Api", api.rpc_service());
    let remote: Arc<dyn ApiClient> = Arc::new(RemoteApi::connect(&sock).unwrap());
    for i in 0..64 {
        for prefix in ["bp", "sp"] {
            api.create(PodView::build(
                &format!("{prefix}{i:03}"),
                "lolcow_latest.sif",
                Resources::new(100, 1 << 20, 0),
                &[],
            ))
            .unwrap();
        }
    }
    let bind = |node: &str| Value::map().with("spec", Value::map().with("nodeName", node));
    let items: Vec<BatchPatchItem> =
        (0..64).map(|i| BatchPatchItem::new(KIND_POD, &format!("bp{i:03}"), bind("w1"))).collect();
    let base = server_metrics.counter_value("redbox.requests");
    let results = remote.update_status_batch(&items).unwrap();
    assert!(results.iter().all(|r| r.is_ok()), "every batched bind lands");
    let batched_rpcs = server_metrics.counter_value("redbox.requests") - base;
    let base = server_metrics.counter_value("redbox.requests");
    for i in 0..64 {
        remote.patch_merge(KIND_POD, &format!("sp{i:03}"), &bind("w1")).unwrap();
    }
    let single_rpcs = server_metrics.counter_value("redbox.requests") - base;
    srv.stop();
    println!(
        "{{\"bench\":\"bind round trips (64-pod burst)\",\"batched_rpcs\":{batched_rpcs},\"single_rpcs\":{single_rpcs}}}"
    );

    // Acceptance (ISSUE 9).
    let ratio = indexed_10k / brute_10k.max(1.0);
    println!(
        "{{\"bench\":\"sched speedup indexed vs brute (10k nodes)\",\"indexed_pods_per_sec\":{indexed_10k:.0},\"brute_pods_per_sec\":{brute_10k:.0},\"ratio_x\":{ratio:.1}}}"
    );
    assert!(
        ratio >= 10.0,
        "indexed scheduling must be >=10x brute-force pods/sec at 10k nodes (got {ratio:.1}x)"
    );
    assert!(
        batched_rpcs <= 2,
        "a 64-pod burst must commit in <=2 round trips (got {batched_rpcs})"
    );
    assert!(single_rpcs >= 64, "singles baseline pays one RPC per bind (got {single_rpcs})");
}
