//! Foundation utilities: errors, ids, RNG, histograms, logging, time helpers.

pub mod error;
pub mod hist;
pub mod id;
pub mod log;
pub mod rng;

pub use error::{ApiError, Error, Result};
pub use hist::Hist;
pub use id::{IdGen, JobId};
pub use rng::Rng;

use std::time::Duration;

/// Format a duration as HH:MM:SS (PBS walltime style).
pub fn fmt_walltime(d: Duration) -> String {
    let s = d.as_secs();
    format!("{:02}:{:02}:{:02}", s / 3600, (s % 3600) / 60, s % 60)
}

/// Parse a PBS walltime `HH:MM:SS` (or `MM:SS`, or plain seconds).
pub fn parse_walltime(s: &str) -> Option<Duration> {
    let parts: Vec<&str> = s.split(':').collect();
    let nums: Option<Vec<u64>> = parts.iter().map(|p| p.parse().ok()).collect();
    let nums = nums?;
    let secs = match nums.as_slice() {
        [s] => *s,
        [m, s] => m * 60 + s,
        [h, m, s] => h * 3600 + m * 60 + s,
        _ => return None,
    };
    Some(Duration::from_secs(secs))
}

/// Parse a memory size like `4gb`, `512mb`, `100kb`, `1024b`, or plain bytes.
/// Torque's `-l mem=` accepts these suffixes (case-insensitive).
pub fn parse_mem(s: &str) -> Option<u64> {
    let s = s.trim().to_ascii_lowercase();
    let (num, mult) = if let Some(n) = s.strip_suffix("tb") {
        (n, 1u64 << 40)
    } else if let Some(n) = s.strip_suffix("gb") {
        (n, 1u64 << 30)
    } else if let Some(n) = s.strip_suffix("mb") {
        (n, 1u64 << 20)
    } else if let Some(n) = s.strip_suffix("kb") {
        (n, 1u64 << 10)
    } else if let Some(n) = s.strip_suffix('b') {
        (n, 1)
    } else {
        (s.as_str(), 1)
    };
    let v: f64 = num.trim().parse().ok()?;
    if v < 0.0 {
        return None;
    }
    Some((v * mult as f64) as u64)
}

/// Format bytes with a binary suffix (for qstat/kubectl output).
pub fn fmt_mem(bytes: u64) -> String {
    const UNITS: [(&str, u64); 4] =
        [("tb", 1 << 40), ("gb", 1 << 30), ("mb", 1 << 20), ("kb", 1 << 10)];
    for (suffix, mult) in UNITS {
        if bytes >= mult && bytes % mult == 0 {
            return format!("{}{}", bytes / mult, suffix);
        }
    }
    for (suffix, mult) in UNITS {
        if bytes >= mult {
            return format!("{:.1}{}", bytes as f64 / mult as f64, suffix);
        }
    }
    format!("{bytes}b")
}

/// Format an age like kubectl (`2s`, `5m`, `3h`, `2d`).
pub fn fmt_age(d: Duration) -> String {
    let s = d.as_secs();
    if s < 60 {
        format!("{s}s")
    } else if s < 3600 {
        format!("{}m", s / 60)
    } else if s < 86_400 {
        format!("{}h", s / 3600)
    } else {
        format!("{}d", s / 86_400)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn walltime_roundtrip() {
        assert_eq!(parse_walltime("00:30:00"), Some(Duration::from_secs(1800)));
        assert_eq!(parse_walltime("01:02:03"), Some(Duration::from_secs(3723)));
        assert_eq!(parse_walltime("90"), Some(Duration::from_secs(90)));
        assert_eq!(parse_walltime("5:00"), Some(Duration::from_secs(300)));
        assert_eq!(parse_walltime("x"), None);
        assert_eq!(fmt_walltime(Duration::from_secs(3723)), "01:02:03");
    }

    #[test]
    fn mem_roundtrip() {
        assert_eq!(parse_mem("4gb"), Some(4 << 30));
        assert_eq!(parse_mem("512MB"), Some(512 << 20));
        assert_eq!(parse_mem("100kb"), Some(100 << 10));
        assert_eq!(parse_mem("12345"), Some(12345));
        assert_eq!(parse_mem("1.5gb"), Some((1.5 * (1u64 << 30) as f64) as u64));
        assert_eq!(parse_mem("-1gb"), None);
        assert_eq!(fmt_mem(4 << 30), "4gb");
        assert_eq!(fmt_mem(512 << 20), "512mb");
    }

    #[test]
    fn age_format() {
        assert_eq!(fmt_age(Duration::from_secs(2)), "2s");
        assert_eq!(fmt_age(Duration::from_secs(300)), "5m");
        assert_eq!(fmt_age(Duration::from_secs(7200)), "2h");
        assert_eq!(fmt_age(Duration::from_secs(200_000)), "2d");
    }
}
