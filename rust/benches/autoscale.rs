//! Autoscale-layer cost at scale:
//!
//! - **hpa reconcile** — one HPA pass over a 1k-pod Deployment with a
//!   full metrics pipeline behind it (list + per-pod metrics gets + the
//!   recommendation math), the recurring price of every poll tick;
//! - **ca cycle** — one cluster-autoscaler pass with 1k pending pods
//!   (the fit simulation + bin-packing, no provisioning);
//! - **scale-up convergence** — wall time from "1k unschedulable pods"
//!   to "every pod placeable", provisioning pool nodes and re-running
//!   scheduler+CA cycles until quiet.
//!
//! Ends with one JSON line per stat (`{"bench":...}`) for the perf
//! trajectory.

use hpcorc::autoscale::{
    publish_node_sample, CaConfig, ClusterAutoscaler, HpaController, HpaView, NodeProvisioner,
};
use hpcorc::bench::{header, Bench, Stats};
use hpcorc::cluster::{Metrics, Resources};
use hpcorc::kube::{
    ApiServer, Controller, DeploymentController, KubeScheduler, NodeView,
    SharedInformerFactory, KIND_POD,
};
use hpcorc::util::Result;
use std::time::Duration;

const PODS: usize = 1_000;

/// Creates bare Node objects — bench measures control-loop cost, not
/// kubelet startup.
struct ObjectProvisioner {
    api: ApiServer,
    capacity: Resources,
}

impl NodeProvisioner for ObjectProvisioner {
    fn provision(&self, name: &str, labels: &[(&str, &str)]) -> Result<()> {
        let mut node = NodeView::build(name, self.capacity, &[]);
        for (k, v) in labels {
            node.meta.set_label(k, v);
        }
        self.api.create(node)?;
        Ok(())
    }
    fn deprovision(&self, name: &str) -> Result<()> {
        let _ = name;
        Ok(())
    }
}

/// A 1k-pod Deployment, every pod Running on a big node with a published
/// metrics sample.
fn hpa_setup() -> ApiServer {
    let api = ApiServer::new(Metrics::new());
    api.create(NodeView::build("big", Resources::cores(4096, 1 << 44), &[])).unwrap();
    api.create(DeploymentController::build(
        "web",
        PODS as u32,
        "svc.sif",
        Resources::new(1000, 64 << 20, 0),
    ))
    .unwrap();
    let informers = SharedInformerFactory::new(api.client(), Metrics::new());
    DeploymentController::new(&informers).reconcile(&api, "web").unwrap();
    for pod in api.list(KIND_POD, &[]) {
        api.update_status(KIND_POD, &pod.meta.name, |o| {
            o.spec.insert("nodeName", "big");
            o.status.insert("phase", "Running");
        })
        .unwrap();
    }
    publish_node_sample(
        &api,
        &informers.informer(hpcorc::autoscale::KIND_PODMETRICS),
        "big",
        Resources::cores(4096, 1 << 44),
        &api.list(KIND_POD, &[]),
        &Metrics::new(),
    );
    api
}

fn main() {
    println!("=== autoscale layer: HPA + cluster autoscaler at {PODS} pods ===");
    println!("{}", header());
    let mut stats: Vec<Stats> = Vec::new();

    // --- HPA reconcile over 1k sampled pods --------------------------
    let api = hpa_setup();
    // Target 50% vs the default 50%-of-request usage: desired == current,
    // so the steady-state pass is measured (no write amplification).
    api.create(HpaView::build("h", "web", 1, PODS as u32 * 2, 50, Duration::ZERO)).unwrap();
    let hpa = HpaController::new(
        &SharedInformerFactory::new(api.client(), Metrics::new()),
        Duration::from_millis(1),
        Metrics::new(),
    );
    stats.push(Bench::new(format!("hpa reconcile ({PODS} pods)")).warmup(2).iters(15).run(
        || {
            hpa.reconcile(&api, "h").unwrap();
        },
    ));

    // --- CA cycle with 1k pending pods, nothing provisionable --------
    let api = ApiServer::new(Metrics::new());
    for i in 0..PODS {
        api.create(hpcorc::kube::PodView::build(
            &format!("p{i:04}"),
            "img.sif",
            Resources::new(1000, 1 << 20, 0),
            &[],
        ))
        .unwrap();
    }
    let ca = ClusterAutoscaler::new(
        &SharedInformerFactory::new(api.client(), Metrics::new()),
        std::sync::Arc::new(ObjectProvisioner {
            api: api.clone(),
            capacity: Resources::cores(8, 64 << 30),
        }),
        CaConfig { max_nodes: 0, ..CaConfig::default() },
        Metrics::new(),
    );
    stats.push(
        Bench::new(format!("ca cycle ({PODS} pending, pool capped)"))
            .warmup(2)
            .iters(15)
            .run(|| {
                let r = ca.run_cycle().unwrap();
                assert_eq!(r.unschedulable, PODS);
            }),
    );

    // --- Scale-up convergence: 1k pods -> pool grows until placeable --
    let api = ApiServer::new(Metrics::new());
    for i in 0..PODS {
        api.create(hpcorc::kube::PodView::build(
            &format!("p{i:04}"),
            "img.sif",
            Resources::new(1000, 1 << 20, 0),
            &[],
        ))
        .unwrap();
    }
    let informers = SharedInformerFactory::new(api.client(), Metrics::new());
    let sched = KubeScheduler::new(&informers, Metrics::new());
    let ca = ClusterAutoscaler::new(
        &informers,
        std::sync::Arc::new(ObjectProvisioner {
            api: api.clone(),
            capacity: Resources::cores(8, 64 << 30),
        }),
        CaConfig {
            max_nodes: PODS / 8 + 1,
            burst_wlm: None,
            ..CaConfig::default()
        },
        Metrics::new(),
    );
    stats.push(
        Bench::new(format!("scale-up convergence ({PODS} pods, 8-core nodes)"))
            .warmup(0)
            .iters(1)
            .run(|| {
                loop {
                    let bound = sched.run_cycle();
                    let r = ca.run_cycle().unwrap();
                    if bound == 0 && r.unschedulable == 0 && r.provisioned.is_empty() {
                        break;
                    }
                }
            }),
    );

    println!();
    for s in &stats {
        println!("{}", s.json());
    }
}
