//! Controller runtime: watch → workqueue → reconcile, with rate-limited
//! retries. The machinery under the Deployment controller and both
//! operators (Torque-Operator, WLM-Operator).
//!
//! Controllers are written against the transport-agnostic [`ApiClient`]
//! trait, so the same reconcile loop runs in-process next to the store or
//! across the red-box socket against a remote API server.

use super::client::ApiClient;
use super::informer::{Informer, InformerEvent};
use crate::cluster::Metrics;
use crate::rt::{self, Shutdown};
use crate::util::Result;
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// What a reconcile asks the runtime to do next.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Reconcile {
    /// Done; drop the item until the next watch event.
    Ok,
    /// Re-enqueue after the given delay (polling external state — e.g. the
    /// operator polling qstat through red-box).
    RequeueAfter(Duration),
}

/// A controller reconciles one object kind by name.
pub trait Controller: Send + Sync + 'static {
    fn kind(&self) -> &str;
    /// Reconcile the named object. The object may no longer exist — that is
    /// a valid state (handle deletion).
    fn reconcile(&self, api: &dyn ApiClient, name: &str) -> Result<Reconcile>;
}

#[derive(Default)]
struct Queue {
    /// Names ready to process now (deduped).
    ready: VecDeque<String>,
    /// Names scheduled for later.
    delayed: Vec<(Instant, String)>,
    /// Consecutive failures per name (exponential backoff).
    failures: HashMap<String, u32>,
}

/// Runs one controller against any [`ApiClient`] transport.
pub struct ControllerRunner {
    api: Arc<dyn ApiClient>,
    controller: Arc<dyn Controller>,
    queue: Arc<(Mutex<Queue>, Condvar)>,
    metrics: Metrics,
}

impl ControllerRunner {
    pub fn new(
        api: Arc<dyn ApiClient>,
        controller: Arc<dyn Controller>,
        metrics: Metrics,
    ) -> Self {
        ControllerRunner {
            api,
            controller,
            queue: Arc::new((Mutex::new(Queue::default()), Condvar::new())),
            metrics,
        }
    }

    /// Start the event thread + worker thread, fed by the shared informer
    /// for the controller's kind.
    ///
    /// The event thread never lists: the informer's subscription replays
    /// the cached objects as `Applied` events and then streams deltas
    /// straight into the work queue — reconciles are level-triggered and
    /// the queue dedupes, so duplicates are free. On
    /// [`InformerEvent::Resync`] (the reflector lost its watch stream and
    /// relisted — events may be lost) the thread enqueues the union of
    /// the names it believed to exist and the names now cached: a relist
    /// cannot name deleted objects, but (known − cached) can, and
    /// reconcile()'s NotFound branch does the cleanup.
    pub fn start(self: Arc<Self>, informer: Informer, shutdown: Shutdown) {
        let kind = self.controller.kind().to_string();
        debug_assert_eq!(informer.kind(), kind, "informer kind must match the controller");
        let this = self.clone();
        let sd = shutdown.clone();
        rt::spawn_named(&format!("ctrl-{kind}-watch"), move || {
            let rx = informer.subscribe();
            // Names believed to exist (maintained from events; reconciled
            // against the cache on every resync).
            let mut known: HashSet<String> = HashSet::new();
            loop {
                if sd.is_triggered() {
                    return;
                }
                // Pump the reflector: a no-op when the factory's pump
                // thread is running, the sole driver when it is not.
                if let Err(e) = informer.sync() {
                    crate::warn!("controller", "{kind} informer sync failed: {e}");
                    if sd.wait_timeout(Duration::from_millis(100)) {
                        return;
                    }
                    continue;
                }
                match rx.recv_timeout(Duration::from_millis(20)) {
                    Ok(InformerEvent::Applied(o)) => {
                        known.insert(o.meta.name.clone());
                        this.enqueue(o.meta.name);
                    }
                    Ok(InformerEvent::Deleted(o)) => {
                        known.remove(&o.meta.name);
                        this.enqueue(o.meta.name);
                    }
                    Ok(InformerEvent::Resync { .. }) => {
                        let cached: HashSet<String> = informer.names().into_iter().collect();
                        for gone in known.difference(&cached) {
                            this.enqueue(gone.clone());
                        }
                        for name in &cached {
                            this.enqueue(name.clone());
                        }
                        known = cached;
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                    // The reflector was dropped — nothing left to watch.
                    Err(_) => return,
                }
            }
        });
        let this = self.clone();
        rt::spawn_named(&format!("ctrl-{kind}-worker"), move || {
            this.worker_loop(shutdown);
        });
    }

    /// Add a name to the ready queue (deduped).
    pub fn enqueue(&self, name: String) {
        let (lock, cv) = &*self.queue;
        let mut q = lock.lock().unwrap();
        if !q.ready.contains(&name) {
            q.ready.push_back(name);
            cv.notify_one();
        }
    }

    fn enqueue_after(&self, name: String, delay: Duration) {
        let (lock, cv) = &*self.queue;
        let mut q = lock.lock().unwrap();
        q.delayed.push((Instant::now() + delay, name));
        cv.notify_one();
    }

    /// Process one item if available; returns whether anything was done.
    /// Public for deterministic stepping in tests.
    pub fn process_one(&self) -> bool {
        let name = {
            let (lock, _) = &*self.queue;
            let mut q = lock.lock().unwrap();
            promote_due(&mut q);
            q.ready.pop_front()
        };
        let Some(name) = name else { return false };
        self.metrics.inc("controller.reconciles");
        match self.controller.reconcile(self.api.as_ref(), &name) {
            Ok(Reconcile::Ok) => {
                self.queue.0.lock().unwrap().failures.remove(&name);
            }
            Ok(Reconcile::RequeueAfter(d)) => {
                self.queue.0.lock().unwrap().failures.remove(&name);
                self.enqueue_after(name, d);
            }
            Err(_) => {
                self.metrics.inc("controller.reconcile_errors");
                let mut q = self.queue.0.lock().unwrap();
                let fails = q.failures.entry(name.clone()).or_insert(0);
                *fails += 1;
                // Exponential backoff: 5ms * 2^n, capped at 1s.
                let delay =
                    Duration::from_millis(5u64.saturating_mul(1 << (*fails).min(8))).min(
                        Duration::from_secs(1),
                    );
                drop(q);
                self.enqueue_after(name, delay);
            }
        }
        true
    }

    fn worker_loop(&self, shutdown: Shutdown) {
        loop {
            if shutdown.is_triggered() {
                return;
            }
            if !self.process_one() {
                // Nothing ready: sleep until next delayed item or new work.
                let (lock, cv) = &*self.queue;
                let q = lock.lock().unwrap();
                let wait = q
                    .delayed
                    .iter()
                    .map(|(t, _)| t.saturating_duration_since(Instant::now()))
                    .min()
                    .unwrap_or(Duration::from_millis(20))
                    .min(Duration::from_millis(20));
                let _ = cv.wait_timeout(q, wait.max(Duration::from_micros(200))).unwrap();
            }
        }
    }
}

fn promote_due(q: &mut Queue) {
    let now = Instant::now();
    let mut i = 0;
    while i < q.delayed.len() {
        if q.delayed[i].0 <= now {
            let (_, name) = q.delayed.remove(i);
            if !q.ready.contains(&name) {
                q.ready.push_back(name);
            }
        } else {
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::Value;
    use crate::kube::api::KubeObject;
    use crate::kube::apiserver::ApiServer;
    use crate::util::Error;
    use std::sync::atomic::{AtomicU32, Ordering};

    struct CountingController {
        kind: String,
        count: AtomicU32,
        fail_first: AtomicU32,
        requeue_until: u32,
    }

    impl Controller for CountingController {
        fn kind(&self) -> &str {
            &self.kind
        }

        fn reconcile(&self, _api: &dyn ApiClient, _name: &str) -> Result<Reconcile> {
            let n = self.count.fetch_add(1, Ordering::SeqCst) + 1;
            if self.fail_first.load(Ordering::SeqCst) >= n {
                return Err(Error::internal("transient"));
            }
            if n < self.requeue_until {
                return Ok(Reconcile::RequeueAfter(Duration::from_millis(1)));
            }
            Ok(Reconcile::Ok)
        }
    }

    fn runner(ctrl: Arc<CountingController>) -> (ApiServer, Arc<ControllerRunner>) {
        let api = ApiServer::new(Metrics::new());
        let r = Arc::new(ControllerRunner::new(api.client(), ctrl, Metrics::new()));
        (api, r)
    }

    #[test]
    fn reconciles_on_events_deduped() {
        let ctrl = Arc::new(CountingController {
            kind: "Widget".into(),
            count: AtomicU32::new(0),
            fail_first: AtomicU32::new(0),
            requeue_until: 0,
        });
        let (api, r) = runner(ctrl.clone());
        // Three rapid events for the same object → one queued item.
        api.create(KubeObject::new("Widget", "w", Value::map())).unwrap();
        r.enqueue("w".into());
        r.enqueue("w".into());
        r.enqueue("w".into());
        assert!(r.process_one());
        assert!(!r.process_one(), "deduped");
        assert_eq!(ctrl.count.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn retries_with_backoff_on_error() {
        let ctrl = Arc::new(CountingController {
            kind: "Widget".into(),
            count: AtomicU32::new(0),
            fail_first: AtomicU32::new(2),
            requeue_until: 0,
        });
        let (_api, r) = runner(ctrl.clone());
        r.enqueue("w".into());
        assert!(r.process_one()); // fails (1)
        // Delayed by backoff; not ready immediately.
        assert!(!r.process_one());
        std::thread::sleep(Duration::from_millis(15));
        assert!(r.process_one()); // fails (2)
        std::thread::sleep(Duration::from_millis(30));
        assert!(r.process_one()); // succeeds (3)
        assert!(!r.process_one());
        assert_eq!(ctrl.count.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn requeue_after_polls() {
        let ctrl = Arc::new(CountingController {
            kind: "Widget".into(),
            count: AtomicU32::new(0),
            fail_first: AtomicU32::new(0),
            requeue_until: 4,
        });
        let (_api, r) = runner(ctrl.clone());
        r.enqueue("w".into());
        let deadline = Instant::now() + Duration::from_secs(5);
        while ctrl.count.load(Ordering::SeqCst) < 4 {
            assert!(Instant::now() < deadline);
            r.process_one();
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn daemon_mode_end_to_end() {
        let ctrl = Arc::new(CountingController {
            kind: "Widget".into(),
            count: AtomicU32::new(0),
            fail_first: AtomicU32::new(0),
            requeue_until: 0,
        });
        let (api, r) = runner(ctrl.clone());
        let sd = Shutdown::new();
        let factory =
            crate::kube::SharedInformerFactory::new(api.client(), Metrics::new());
        r.clone().start(factory.informer("Widget"), sd.clone());
        api.create(KubeObject::new("Widget", "a", Value::map())).unwrap();
        api.create(KubeObject::new("Widget", "b", Value::map())).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while ctrl.count.load(Ordering::SeqCst) < 2 {
            assert!(Instant::now() < deadline, "controller never reconciled");
            std::thread::sleep(Duration::from_millis(5));
        }
        sd.trigger();
    }
}
