//! SIF-style container images.
//!
//! Singularity packs a container into a single SIF file; ours is a compact
//! stand-in: a magic header, a JSON descriptor (name, payload, labels,
//! environment), and an integrity checksum. Images carry an executable
//! [`Payload`] instead of a rootfs — the runscript equivalent — so
//! containerised jobs do *real work* (PJRT compute, output generation)
//! without a kernel namespace substrate.

use crate::encoding::{json, Decode, Encode, Value};
use crate::util::{Error, Result};

/// Magic bytes heading every image file.
pub const SIF_MAGIC: &[u8; 8] = b"SIFHPC\x01\n";

/// What running the container does (the %runscript).
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// Print a message (the paper's `lolcow` demo image).
    Echo { message: String },
    /// Busy-wait/sleep for a duration — synthetic HPC job body.
    /// `millis` is *nominal* job length; the runtime may scale it.
    Sleep { millis: u64 },
    /// Run an AOT-compiled artifact via PJRT: the CYBELE-pilot stand-in.
    /// `steps` train/infer iterations of `artifact` (see artifacts/manifest).
    Compute { artifact: String, steps: u32 },
    /// Interpret a small shell script (lines of the supported subset).
    Script { lines: Vec<String> },
    /// Exit with a code — failure injection.
    Fail { exit_code: i32 },
}

impl Payload {
    pub fn kind(&self) -> &'static str {
        match self {
            Payload::Echo { .. } => "echo",
            Payload::Sleep { .. } => "sleep",
            Payload::Compute { .. } => "compute",
            Payload::Script { .. } => "script",
            Payload::Fail { .. } => "fail",
        }
    }
}

impl Encode for Payload {
    fn encode(&self) -> Value {
        match self {
            Payload::Echo { message } => {
                Value::map().with("kind", "echo").with("message", message.clone())
            }
            Payload::Sleep { millis } => {
                Value::map().with("kind", "sleep").with("millis", *millis)
            }
            Payload::Compute { artifact, steps } => Value::map()
                .with("kind", "compute")
                .with("artifact", artifact.clone())
                .with("steps", *steps as u64),
            Payload::Script { lines } => Value::map().with("kind", "script").with(
                "lines",
                Value::Seq(lines.iter().map(|l| Value::str(l.clone())).collect()),
            ),
            Payload::Fail { exit_code } => {
                Value::map().with("kind", "fail").with("exitCode", *exit_code as i64)
            }
        }
    }
}

impl Decode for Payload {
    fn decode(v: &Value) -> Result<Self> {
        Ok(match v.req_str("kind")? {
            "echo" => Payload::Echo { message: v.req_str("message")?.to_string() },
            "sleep" => Payload::Sleep { millis: v.req_int("millis")? as u64 },
            "compute" => Payload::Compute {
                artifact: v.req_str("artifact")?.to_string(),
                steps: v.req_int("steps")? as u32,
            },
            "script" => Payload::Script {
                lines: v
                    .req("lines")?
                    .as_seq()
                    .ok_or_else(|| Error::parse("script lines must be a list"))?
                    .iter()
                    .filter_map(|l| l.as_str().map(String::from))
                    .collect(),
            },
            "fail" => Payload::Fail { exit_code: v.req_int("exitCode")? as i32 },
            k => return Err(Error::parse(format!("unknown payload kind `{k}`"))),
        })
    }
}

/// A built image.
#[derive(Debug, Clone, PartialEq)]
pub struct SifImage {
    /// Reference, e.g. `lolcow_latest.sif` or `cropyield:v1`.
    pub name: String,
    pub payload: Payload,
    pub labels: Vec<(String, String)>,
    /// Environment baked at build time (%environment section).
    pub env: Vec<(String, String)>,
}

impl SifImage {
    pub fn new(name: impl Into<String>, payload: Payload) -> Self {
        SifImage { name: name.into(), payload, labels: Vec::new(), env: Vec::new() }
    }

    /// The paper's demo image.
    pub fn lolcow() -> Self {
        SifImage::new(
            "lolcow_latest.sif",
            Payload::Echo {
                message: concat!(
                    " _________________________________\n",
                    "< Moo-ve over, HPC — containers!  >\n",
                    " ---------------------------------\n",
                    "        \\   ^__^\n",
                    "         \\  (oo)\\_______\n",
                    "            (__)\\       )\\/\\\n",
                    "                ||----w |\n",
                    "                ||     ||\n"
                )
                .to_string(),
            },
        )
    }

    /// Serialize to SIF bytes: magic + u32 length + JSON + u32 checksum.
    pub fn to_bytes(&self) -> Vec<u8> {
        let body = json::to_string(&self.encode());
        let mut out = Vec::with_capacity(body.len() + 16);
        out.extend_from_slice(SIF_MAGIC);
        out.extend_from_slice(&(body.len() as u32).to_le_bytes());
        out.extend_from_slice(body.as_bytes());
        out.extend_from_slice(&fletcher32(body.as_bytes()).to_le_bytes());
        out
    }

    /// Parse SIF bytes, verifying magic and checksum.
    pub fn from_bytes(bytes: &[u8]) -> Result<SifImage> {
        if bytes.len() < 16 || &bytes[..8] != SIF_MAGIC {
            return Err(Error::container("not a SIF image (bad magic)"));
        }
        let len = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        if bytes.len() < 12 + len + 4 {
            return Err(Error::container("truncated SIF image"));
        }
        let body = &bytes[12..12 + len];
        let want = u32::from_le_bytes(bytes[12 + len..16 + len].try_into().unwrap());
        if fletcher32(body) != want {
            return Err(Error::container("SIF checksum mismatch"));
        }
        let text =
            std::str::from_utf8(body).map_err(|_| Error::container("SIF body not utf-8"))?;
        SifImage::decode(&json::parse(text)?)
    }
}

impl Encode for SifImage {
    fn encode(&self) -> Value {
        Value::map()
            .with("name", self.name.clone())
            .with("payload", self.payload.encode())
            .with("labels", crate::encoding::encode_str_map(&self.labels))
            .with("env", crate::encoding::encode_str_map(&self.env))
    }
}

impl Decode for SifImage {
    fn decode(v: &Value) -> Result<Self> {
        Ok(SifImage {
            name: v.req_str("name")?.to_string(),
            payload: Payload::decode(v.req("payload")?)?,
            labels: v.get("labels").map(crate::encoding::decode_str_map).unwrap_or_default(),
            env: v.get("env").map(crate::encoding::decode_str_map).unwrap_or_default(),
        })
    }
}

fn fletcher32(data: &[u8]) -> u32 {
    let mut a: u32 = 0;
    let mut b: u32 = 0;
    for chunk in data.chunks(360) {
        for &byte in chunk {
            a += byte as u32;
            b += a;
        }
        a %= 65535;
        b %= 65535;
    }
    (b << 16) | a
}

/// Parse a Singularity definition file (the subset we support):
///
/// ```text
/// Bootstrap: payload
/// From: compute            # echo | sleep | compute | script | fail
///
/// %labels
///     author hlrs
/// %environment
///     export MODEL=cropyield
/// %runscript
///     artifact=cropyield_train steps=200   # compute
/// ```
pub fn parse_definition(name: &str, def: &str) -> Result<SifImage> {
    let mut kind = String::new();
    let mut section = String::new();
    let mut labels = Vec::new();
    let mut env = Vec::new();
    let mut run_lines: Vec<String> = Vec::new();
    for raw in def.lines() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(rest) = line.strip_prefix('%') {
            section = rest.split_whitespace().next().unwrap_or("").to_string();
            continue;
        }
        if section.is_empty() {
            if let Some(v) = line.strip_prefix("Bootstrap:") {
                if v.trim() != "payload" {
                    return Err(Error::parse(format!("unsupported Bootstrap `{}`", v.trim())));
                }
            } else if let Some(v) = line.strip_prefix("From:") {
                kind = v.trim().to_string();
            }
            continue;
        }
        match section.as_str() {
            "labels" => {
                if let Some((k, v)) = line.split_once(char::is_whitespace) {
                    labels.push((k.to_string(), v.trim().to_string()));
                }
            }
            "environment" => {
                let line = line.strip_prefix("export ").unwrap_or(line);
                if let Some((k, v)) = line.split_once('=') {
                    env.push((k.trim().to_string(), v.trim().to_string()));
                }
            }
            "runscript" => run_lines.push(line.to_string()),
            _ => {} // ignore unknown sections (%post, %files...)
        }
    }
    let args: Vec<(String, String)> = run_lines
        .iter()
        .flat_map(|l| l.split_whitespace())
        .filter_map(|tok| tok.split_once('=').map(|(k, v)| (k.to_string(), v.to_string())))
        .collect();
    let get = |key: &str| args.iter().find(|(k, _)| k == key).map(|(_, v)| v.clone());
    let payload = match kind.as_str() {
        "echo" => Payload::Echo {
            message: get("message").unwrap_or_else(|| "hello from hpcorc".into()),
        },
        "sleep" => Payload::Sleep {
            millis: get("millis")
                .and_then(|v| v.parse().ok())
                .ok_or_else(|| Error::parse("sleep payload needs millis=<n>"))?,
        },
        "compute" => Payload::Compute {
            artifact: get("artifact").ok_or_else(|| Error::parse("compute needs artifact="))?,
            steps: get("steps").and_then(|v| v.parse().ok()).unwrap_or(1),
        },
        "script" => Payload::Script { lines: run_lines.clone() },
        "fail" => Payload::Fail {
            exit_code: get("exit_code").and_then(|v| v.parse().ok()).unwrap_or(1),
        },
        k => return Err(Error::parse(format!("unknown payload kind `{k}`"))),
    };
    let mut img = SifImage::new(name, payload);
    img.labels = labels;
    img.env = env;
    Ok(img)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_roundtrip() {
        let img = SifImage::lolcow();
        let bytes = img.to_bytes();
        let back = SifImage::from_bytes(&bytes).unwrap();
        assert_eq!(back, img);
    }

    #[test]
    fn corrupt_image_rejected() {
        let img = SifImage::lolcow();
        let mut bytes = img.to_bytes();
        assert!(SifImage::from_bytes(&bytes[..10]).is_err(), "truncated");
        let n = bytes.len();
        bytes[n / 2] ^= 0xFF;
        assert!(SifImage::from_bytes(&bytes).is_err(), "checksum");
        let mut bad_magic = img.to_bytes();
        bad_magic[0] = b'X';
        assert!(SifImage::from_bytes(&bad_magic).is_err(), "magic");
    }

    #[test]
    fn payload_encode_roundtrip() {
        for p in [
            Payload::Echo { message: "hi".into() },
            Payload::Sleep { millis: 1500 },
            Payload::Compute { artifact: "cropyield_train".into(), steps: 200 },
            Payload::Script { lines: vec!["echo a".into(), "sleep 1".into()] },
            Payload::Fail { exit_code: 3 },
        ] {
            assert_eq!(Payload::decode(&p.encode()).unwrap(), p);
        }
    }

    #[test]
    fn definition_file_compute() {
        let def = "\
Bootstrap: payload
From: compute

%labels
    author hlrs
    project cybele
%environment
    export MODEL=cropyield
%runscript
    artifact=cropyield_train steps=200
";
        let img = parse_definition("cropyield:v1", def).unwrap();
        assert_eq!(img.name, "cropyield:v1");
        assert_eq!(
            img.payload,
            Payload::Compute { artifact: "cropyield_train".into(), steps: 200 }
        );
        assert_eq!(img.labels[0], ("author".into(), "hlrs".into()));
        assert_eq!(img.env[0], ("MODEL".into(), "cropyield".into()));
    }

    #[test]
    fn definition_errors() {
        assert!(parse_definition("x", "Bootstrap: docker\nFrom: echo\n").is_err());
        assert!(parse_definition("x", "Bootstrap: payload\nFrom: nope\n").is_err());
        assert!(
            parse_definition("x", "Bootstrap: payload\nFrom: compute\n%runscript\n  steps=2\n")
                .is_err(),
            "compute without artifact"
        );
    }

    #[test]
    fn fletcher_known_values() {
        assert_eq!(fletcher32(b""), 0);
        assert_ne!(fletcher32(b"abcde"), fletcher32(b"abcdf"));
    }
}
