//! Virtual nodes: one Kubernetes Node object per WLM queue/partition.
//!
//! "The operator creates virtual nodes which correspond to each Slurm
//! partition … it enables users to connect Kubernetes to other APIs"
//! (paper §II); Torque-Operator does the same per Torque queue (Fig. 2:
//! the virtual node corresponds to the `batch` queue). Virtual nodes are
//! tainted `virtual-kubelet` so only the operator's dummy pods (which
//! tolerate the taint) schedule onto them.

use super::redbox_svc::WlmBridge;
use crate::cluster::Resources;
use crate::kube::{ApiClient, NodeView, KIND_NODE};
use crate::util::Result;

/// The taint key carried by every virtual node.
pub const VIRTUAL_KUBELET_TAINT: &str = "virtual-kubelet";

/// Label keys set on virtual nodes (used by dummy-pod nodeSelectors).
pub const LABEL_QUEUE: &str = "wlm/queue";
pub const LABEL_WLM: &str = "wlm/backend";

/// Virtual node name for a queue.
pub fn vnode_name(wlm: &str, queue: &str) -> String {
    format!("vnode-{wlm}-{queue}")
}

/// Register one virtual node per WLM queue. `capacity` is deliberately
/// generous: the real capacity gate is the WLM's own scheduler — the
/// virtual node only needs to admit dummy pods (which request ~nothing),
/// exactly as virtual-kubelet reports large synthetic capacity.
pub fn register_virtual_nodes(
    api: &dyn ApiClient,
    bridge: &dyn WlmBridge,
    wlm: &str,
) -> Result<Vec<String>> {
    let mut created = Vec::new();
    for queue in bridge.queues()? {
        let name = vnode_name(wlm, &queue);
        let mut node = NodeView::build(
            &name,
            Resources::cores(1024, 1 << 40),
            &[VIRTUAL_KUBELET_TAINT],
        );
        node.meta.set_label(LABEL_QUEUE, &queue);
        node.meta.set_label(LABEL_WLM, wlm);
        node.status.insert("runtime", "virtual-kubelet");
        match api.create(node) {
            Ok(_) => created.push(name),
            // Already registered (operator restart) — and only that. Any
            // other API error (invalid object, conflict-exhausted, a
            // transport fault surfacing as an API error) must propagate:
            // swallowing it would report virtual nodes that do not exist
            // and strand every dummy pod targeting them.
            Err(e)
                if matches!(
                    &e,
                    crate::util::Error::Api(crate::util::ApiError::AlreadyExists { .. })
                ) || e.is_conflict() =>
            {
                created.push(name);
            }
            Err(e) => return Err(e),
        }
    }
    Ok(created)
}

/// Find the virtual node for a queue (None = queue has no virtual node).
pub fn lookup_vnode(api: &dyn ApiClient, wlm: &str, queue: &str) -> Option<String> {
    let name = vnode_name(wlm, queue);
    api.get(KIND_NODE, &name).ok().map(|_| name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Metrics;
    use crate::kube::ApiServer;
    use crate::operator::redbox_svc::WlmStatus;
    use crate::util::Error;

    /// Bridge stub with fixed queues.
    struct FakeBridge(Vec<String>);

    impl WlmBridge for FakeBridge {
        fn submit(&self, _: &str, _: &str) -> Result<String> {
            Err(Error::wlm("not implemented"))
        }
        fn status(&self, _: &str) -> Result<WlmStatus> {
            Err(Error::wlm("not implemented"))
        }
        fn cancel(&self, _: &str) -> Result<()> {
            Ok(())
        }
        fn read_file(&self, _: &str) -> Result<String> {
            Err(Error::wlm("not implemented"))
        }
        fn write_file(&self, _: &str, _: &str) -> Result<()> {
            Ok(())
        }
        fn queues(&self) -> Result<Vec<String>> {
            Ok(self.0.clone())
        }
    }

    #[test]
    fn registers_node_per_queue_with_taint() {
        let api = ApiServer::new(Metrics::new());
        let bridge = FakeBridge(vec!["batch".into(), "gpu".into()]);
        let created = register_virtual_nodes(&api, &bridge, "torque").unwrap();
        assert_eq!(created, vec!["vnode-torque-batch", "vnode-torque-gpu"]);
        let node = NodeView::from_object(&api.get(KIND_NODE, "vnode-torque-batch").unwrap())
            .unwrap();
        assert_eq!(node.taints, vec![VIRTUAL_KUBELET_TAINT]);
        assert_eq!(node.labels.iter().find(|(k, _)| k == LABEL_QUEUE).unwrap().1, "batch");
        assert_eq!(node.runtime, "virtual-kubelet");
    }

    #[test]
    fn idempotent_on_restart() {
        let api = ApiServer::new(Metrics::new());
        let bridge = FakeBridge(vec!["batch".into()]);
        register_virtual_nodes(&api, &bridge, "torque").unwrap();
        let again = register_virtual_nodes(&api, &bridge, "torque").unwrap();
        assert_eq!(again, vec!["vnode-torque-batch"]);
        assert_eq!(api.list(KIND_NODE, &[]).len(), 1);
    }

    /// Regression (PR 3): non-NotFound API errors other than
    /// already-exists/conflict used to be swallowed as "already
    /// registered"; they must propagate.
    #[test]
    fn non_conflict_api_errors_propagate() {
        use crate::kube::{ApiClient, KubeObject, ListOptions, ObjectList, WatchEvent};
        use crate::util::ApiError;
        use std::sync::mpsc::Receiver;

        /// ApiClient whose create always fails with the given error.
        struct FailingApi(Error);
        impl ApiClient for FailingApi {
            fn create(&self, _obj: KubeObject) -> Result<KubeObject> {
                Err(self.0.clone())
            }
            fn get(&self, kind: &str, name: &str) -> Result<KubeObject> {
                Err(Error::not_found(kind, name))
            }
            fn update(&self, _obj: KubeObject) -> Result<KubeObject> {
                Err(self.0.clone())
            }
            fn update_status(
                &self,
                _kind: &str,
                _name: &str,
                _f: &dyn Fn(&mut KubeObject),
            ) -> Result<KubeObject> {
                Err(self.0.clone())
            }
            fn patch_merge(
                &self,
                _kind: &str,
                _name: &str,
                _patch: &crate::encoding::Value,
            ) -> Result<KubeObject> {
                Err(self.0.clone())
            }
            fn delete(&self, _kind: &str, _name: &str) -> Result<KubeObject> {
                Err(self.0.clone())
            }
            fn apply(&self, _obj: KubeObject) -> Result<KubeObject> {
                Err(self.0.clone())
            }
            fn list(&self, _kind: &str, _opts: &ListOptions) -> Result<ObjectList> {
                Err(self.0.clone())
            }
            fn watch(&self, _kind: Option<&str>, _v: u64) -> Result<Receiver<WatchEvent>> {
                Err(self.0.clone())
            }
            fn server_time_s(&self) -> Result<f64> {
                Ok(0.0)
            }
        }

        let bridge = FakeBridge(vec!["batch".into()]);
        // Invalid object: must propagate, not read as "already there".
        let api = FailingApi(Error::Api(ApiError::Invalid("bad node".into())));
        assert!(register_virtual_nodes(&api, &bridge, "torque").is_err());
        // Pathological contention: a retry loop already gave up — propagate.
        let api = FailingApi(Error::conflict_exhausted("Node", "vnode-torque-batch", 16));
        assert!(register_virtual_nodes(&api, &bridge, "torque").is_err());
        // AlreadyExists and routine conflicts still read as registered.
        let api = FailingApi(Error::already_exists("Node", "vnode-torque-batch"));
        assert_eq!(
            register_virtual_nodes(&api, &bridge, "torque").unwrap(),
            vec!["vnode-torque-batch"]
        );
        let api = FailingApi(Error::conflict("Node", "vnode-torque-batch"));
        assert!(register_virtual_nodes(&api, &bridge, "torque").is_ok());
    }

    #[test]
    fn lookup() {
        let api = ApiServer::new(Metrics::new());
        let bridge = FakeBridge(vec!["batch".into()]);
        register_virtual_nodes(&api, &bridge, "torque").unwrap();
        assert_eq!(
            lookup_vnode(&api, "torque", "batch").as_deref(),
            Some("vnode-torque-batch")
        );
        assert!(lookup_vnode(&api, "torque", "nope").is_none());
    }
}
