//! HorizontalPodAutoscaler: metric-target scaling of Deployments.
//!
//! An `autoscaling/v2`-style HPA object names a Deployment
//! (`spec.scaleTargetRef.name`), a metric + target
//! (`spec.metrics[0].resource`: `cpu` or `memory`, targeted either as
//! `Utilization` — usage/request percent over the pods the Deployment
//! owns — or as `AverageValue` — absolute per-pod usage, milli-cores or
//! bytes; the legacy `spec.targetCPUUtilizationPercent` shorthand still
//! parses as cpu/Utilization), replica clamps (`minReplicas`/
//! `maxReplicas`), and stabilization windows
//! (`spec.behavior.{scaleUp,scaleDown}.stabilizationWindowSeconds`).
//!
//! The controller runs on the ordinary [`Controller`] runtime and
//! re-polls via `RequeueAfter` (metrics change without object events).
//! Each reconcile recomputes the classic recommendation
//!
//! ```text
//! desired = ceil(current * observedUtilization / target)
//! ```
//!
//! with a ±10% tolerance band, then filters it through the stabilization
//! windows: a scale-up uses the *smallest* recommendation seen inside the
//! up-window (don't chase a single spike), a scale-down the *largest*
//! inside the down-window (don't collapse on a single trough — the k8s
//! downscale-stabilization behaviour). Windows are wall-clock seconds;
//! both default to 0 (immediate) / 30 (damped) respectively.

use super::metrics::{PodMetricsView, KIND_PODMETRICS};
use crate::cluster::Metrics;
use crate::encoding::Value;
use crate::kube::{
    ApiClient, Controller, EventRecorder, Informer, KubeObject, PodView, Reconcile,
    SharedInformerFactory, EVENT_NORMAL, KIND_DEPLOYMENT,
};
use crate::util::{Error, Result};
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// The apiVersion the HPA kind is served under.
pub const AUTOSCALING_API_VERSION: &str = "autoscaling/v2";
pub const KIND_HPA: &str = "HorizontalPodAutoscaler";

/// Recommendations within ±10% of the target hold the current size
/// (the kube-controller-manager default tolerance).
const TOLERANCE: f64 = 0.10;

/// Component name stamped on events and audit records this controller
/// writes.
const COMPONENT: &str = "horizontal-pod-autoscaler";

/// Which pod resource the HPA measures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricSource {
    Cpu,
    Memory,
}

/// How the measured resource is targeted: as a percent of pod requests,
/// or as an absolute per-pod average (milli-cores for cpu, bytes for
/// memory).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricTarget {
    Utilization(u64),
    AverageValue(u64),
}

/// Typed view over an HPA object.
#[derive(Debug, Clone, PartialEq)]
pub struct HpaView {
    pub name: String,
    /// Target Deployment name (`spec.scaleTargetRef.name`).
    pub target: String,
    pub min_replicas: u32,
    pub max_replicas: u32,
    /// Measured resource (`spec.metrics[0].resource.name`).
    pub metric: MetricSource,
    /// Scaling target (`spec.metrics[0].resource.target`).
    pub metric_target: MetricTarget,
    pub scale_up_window: Duration,
    pub scale_down_window: Duration,
    /// Status mirrors (written by the controller).
    pub current_utilization_pct: Option<u64>,
    pub current_average_value: Option<u64>,
    pub desired_replicas: Option<u32>,
}

impl HpaView {
    pub fn from_object(o: &KubeObject) -> Result<HpaView> {
        if o.kind != KIND_HPA {
            return Err(Error::parse(format!("expected HorizontalPodAutoscaler, got {}", o.kind)));
        }
        let target = o
            .spec
            .path(&["scaleTargetRef", "name"])
            .and_then(Value::as_str)
            .ok_or_else(|| Error::parse("hpa spec.scaleTargetRef.name missing"))?
            .to_string();
        let window = |arm: &str, default_s: u64| {
            Duration::from_secs(
                o.spec
                    .path(&["behavior", arm, "stabilizationWindowSeconds"])
                    .and_then(Value::as_int)
                    .map(|v| v.max(0) as u64)
                    .unwrap_or(default_s),
            )
        };
        let min_replicas = o.spec.opt_int("minReplicas").unwrap_or(1).max(0) as u32;
        let (metric, metric_target) = Self::parse_metric(o)?;
        Ok(HpaView {
            name: o.meta.name.clone(),
            target,
            min_replicas,
            // A max below min would make the clamp panic; treat the min
            // as authoritative (the k8s API rejects such specs outright).
            max_replicas: (o.spec.opt_int("maxReplicas").unwrap_or(10).max(1) as u32)
                .max(min_replicas),
            metric,
            metric_target,
            scale_up_window: window("scaleUp", 0),
            scale_down_window: window("scaleDown", 30),
            current_utilization_pct: o.status.opt_int("currentUtilizationPct").map(|v| v as u64),
            current_average_value: o.status.opt_int("currentAverageValue").map(|v| v as u64),
            desired_replicas: o.status.opt_int("desiredReplicas").map(|v| v as u32),
        })
    }

    /// `spec.metrics[0].resource` in the autoscaling/v2 shape, with the
    /// legacy `spec.targetCPUUtilizationPercent` shorthand (and a bare
    /// spec's 80% default) parsing as cpu/Utilization.
    fn parse_metric(o: &KubeObject) -> Result<(MetricSource, MetricTarget)> {
        let Some(entry) = o.spec.get("metrics").and_then(Value::as_seq).and_then(|s| s.first())
        else {
            let pct =
                o.spec.opt_int("targetCPUUtilizationPercent").unwrap_or(80).max(1) as u64;
            return Ok((MetricSource::Cpu, MetricTarget::Utilization(pct)));
        };
        let res = entry
            .get("resource")
            .ok_or_else(|| Error::parse("hpa metrics[0].resource missing"))?;
        let metric = match res.opt_str("name").unwrap_or("cpu") {
            "cpu" => MetricSource::Cpu,
            "memory" => MetricSource::Memory,
            other => return Err(Error::parse(format!("hpa metric resource `{other}`"))),
        };
        let t = res
            .get("target")
            .ok_or_else(|| Error::parse("hpa metrics[0].resource.target missing"))?;
        let metric_target = match t.opt_str("type").unwrap_or("Utilization") {
            "Utilization" => {
                MetricTarget::Utilization(t.opt_int("averageUtilization").unwrap_or(80).max(1)
                    as u64)
            }
            "AverageValue" => {
                let v = t
                    .opt_int("averageValue")
                    .filter(|v| *v > 0)
                    .ok_or_else(|| Error::parse("hpa AverageValue target needs averageValue"))?;
                MetricTarget::AverageValue(v as u64)
            }
            other => return Err(Error::parse(format!("hpa metric target type `{other}`"))),
        };
        Ok((metric, metric_target))
    }

    /// Build an HPA object with immediate (0s) scale-up and the given
    /// scale-down window.
    pub fn build(
        name: &str,
        target: &str,
        min: u32,
        max: u32,
        target_pct: u64,
        scale_down_window: Duration,
    ) -> KubeObject {
        let spec = Value::map()
            .with(
                "scaleTargetRef",
                Value::map().with("kind", KIND_DEPLOYMENT).with("name", target),
            )
            .with("minReplicas", min as u64)
            .with("maxReplicas", max as u64)
            .with("targetCPUUtilizationPercent", target_pct)
            .with(
                "behavior",
                Value::map()
                    .with(
                        "scaleUp",
                        Value::map().with("stabilizationWindowSeconds", 0u64),
                    )
                    .with(
                        "scaleDown",
                        Value::map().with(
                            "stabilizationWindowSeconds",
                            scale_down_window.as_secs(),
                        ),
                    ),
            );
        let mut o = KubeObject::new(KIND_HPA, name, spec);
        o.api_version = AUTOSCALING_API_VERSION.into();
        o
    }

    /// Build an HPA with an explicit autoscaling/v2 metric entry
    /// (`spec.metrics[0].resource`): cpu or memory, utilization-percent
    /// or absolute per-pod average target.
    pub fn build_metric(
        name: &str,
        target: &str,
        min: u32,
        max: u32,
        metric: MetricSource,
        metric_target: MetricTarget,
        scale_down_window: Duration,
    ) -> KubeObject {
        let target_v = match metric_target {
            MetricTarget::Utilization(pct) => {
                Value::map().with("type", "Utilization").with("averageUtilization", pct)
            }
            MetricTarget::AverageValue(v) => {
                Value::map().with("type", "AverageValue").with("averageValue", v)
            }
        };
        let resource = Value::map()
            .with(
                "name",
                match metric {
                    MetricSource::Cpu => "cpu",
                    MetricSource::Memory => "memory",
                },
            )
            .with("target", target_v);
        let entry = Value::map().with("type", "Resource").with("resource", resource);
        let spec = Value::map()
            .with(
                "scaleTargetRef",
                Value::map().with("kind", KIND_DEPLOYMENT).with("name", target),
            )
            .with("minReplicas", min as u64)
            .with("maxReplicas", max as u64)
            .with("metrics", Value::Seq(vec![entry]))
            .with(
                "behavior",
                Value::map()
                    .with(
                        "scaleUp",
                        Value::map().with("stabilizationWindowSeconds", 0u64),
                    )
                    .with(
                        "scaleDown",
                        Value::map().with(
                            "stabilizationWindowSeconds",
                            scale_down_window.as_secs(),
                        ),
                    ),
            );
        let mut o = KubeObject::new(KIND_HPA, name, spec);
        o.api_version = AUTOSCALING_API_VERSION.into();
        o
    }
}

impl crate::kube::ResourceView for HpaView {
    fn kinds() -> &'static [&'static str] {
        &[KIND_HPA]
    }
    fn from_object(obj: &KubeObject) -> Result<HpaView> {
        HpaView::from_object(obj)
    }
}

/// The HPA controller. Holds per-HPA recommendation history (the only
/// state; losing it across a restart merely restarts the stabilization
/// windows, it cannot mis-scale). Target pods and their metrics samples
/// are read from the shared informer caches — a reconcile issues no list
/// RPCs.
pub struct HpaController {
    pods: Informer,
    samples: Informer,
    poll: Duration,
    history: Mutex<HashMap<String, Vec<(Instant, u32)>>>,
    events: EventRecorder,
    metrics: Metrics,
}

impl HpaController {
    pub fn new(
        informers: &SharedInformerFactory,
        poll: Duration,
        metrics: Metrics,
    ) -> HpaController {
        HpaController {
            pods: informers.informer(crate::kube::KIND_POD),
            samples: informers.informer(KIND_PODMETRICS),
            poll,
            history: Mutex::new(HashMap::new()),
            events: EventRecorder::new(COMPONENT, metrics.clone()),
            metrics,
        }
    }

    /// Stabilized recommendation: record `raw`, prune entries older than
    /// the larger window, and damp in the direction of change.
    fn stabilize(&self, hpa: &HpaView, current: u32, raw: u32) -> u32 {
        let now = Instant::now();
        let keep = hpa.scale_up_window.max(hpa.scale_down_window);
        let mut hist = self.history.lock().unwrap();
        let recs = hist.entry(hpa.name.clone()).or_default();
        recs.push((now, raw));
        recs.retain(|(t, _)| now.duration_since(*t) <= keep);
        if raw > current {
            let floor = recs
                .iter()
                .filter(|(t, _)| now.duration_since(*t) <= hpa.scale_up_window)
                .map(|(_, r)| *r)
                .min()
                .unwrap_or(raw);
            floor.max(current)
        } else {
            let ceil = recs
                .iter()
                .filter(|(t, _)| now.duration_since(*t) <= hpa.scale_down_window)
                .map(|(_, r)| *r)
                .max()
                .unwrap_or(raw);
            ceil.min(current)
        }
    }
}

impl Controller for HpaController {
    fn kind(&self) -> &str {
        KIND_HPA
    }

    fn reconcile(&self, api: &dyn ApiClient, name: &str) -> Result<Reconcile> {
        // Every write this pass makes is attributed to the HPA in the API
        // server's audit trail (PR 8).
        let _actor = crate::obs::push_actor(COMPONENT);
        let obj = match api.get(KIND_HPA, name) {
            Ok(o) => o,
            Err(e) if e.is_not_found() => {
                self.history.lock().unwrap().remove(name);
                return Ok(Reconcile::Ok);
            }
            Err(e) => return Err(e),
        };
        let hpa = HpaView::from_object(&obj)?;
        let deploy = match api.get(KIND_DEPLOYMENT, &hpa.target) {
            Ok(d) => d,
            // Target not created yet: keep polling, it may appear.
            Err(e) if e.is_not_found() => return Ok(Reconcile::RequeueAfter(self.poll)),
            Err(e) => return Err(e),
        };
        let current = deploy.spec.opt_int("replicas").unwrap_or(0).max(0) as u32;

        // Observed signal: usage of the measured resource summed over the
        // target's non-terminal pods that have a metrics sample — both
        // read from the shared caches (label-indexed pods, sample gets).
        self.pods.sync()?;
        self.samples.sync()?;
        let pods = self.pods.list_labelled("deployment", &hpa.target);
        let utilization_mode = matches!(hpa.metric_target, MetricTarget::Utilization(_));
        let mut usage = 0u64; // milli-cores (cpu) or bytes (memory)
        let mut requested = 0u64;
        let mut unsampled_requested = 0u64;
        let mut sampled = 0u32;
        let mut unsampled = 0u32;
        for pod in &pods {
            let Ok(view) = PodView::from_object(pod) else { continue };
            let request = match hpa.metric {
                MetricSource::Cpu => view.requests.cpu_milli,
                MetricSource::Memory => view.requests.mem_bytes,
            };
            // Utilization is usage/request — a request-less pod has no
            // denominator. AverageValue is absolute; every pod counts.
            if view.phase.terminal() || (utilization_mode && request == 0) {
                continue;
            }
            match self
                .samples
                .get(&view.name)
                .filter(|m| m.kind == KIND_PODMETRICS)
                .and_then(|m| PodMetricsView::from_object(&m).ok())
            {
                Some(m) => {
                    usage += match hpa.metric {
                        MetricSource::Cpu => m.cpu_milli,
                        MetricSource::Memory => m.mem_bytes,
                    };
                    requested += request;
                    sampled += 1;
                }
                // Pod exists but has no sample yet (Pending/unscheduled or
                // a cold pipeline).
                None => {
                    unsampled_requested += request;
                    unsampled += 1;
                }
            }
        }
        if sampled == 0 || (utilization_mode && requested == 0) {
            // No signal at all: poll.
            return Ok(Reconcile::RequeueAfter(self.poll));
        }
        // The k8s conservative rule, applied on the way up in both
        // modes: before scaling up, metric-less pods count as 0 usage.
        // Otherwise a capacity-starved deployment (few Running pods hot,
        // the rest Pending and sample-less) measures only its hot pods
        // and ratchets straight to maxReplicas, amplifying the very
        // starvation it is reacting to. If the assumption flips the
        // direction entirely, hold — never shrink on made-up zeros.
        let mut hold = false;
        let (ratio, signal) = match hpa.metric_target {
            MetricTarget::Utilization(pct) => {
                let mut utilization = usage as f64 / requested as f64 * 100.0;
                if utilization > pct as f64 && unsampled_requested > 0 {
                    utilization =
                        usage as f64 / (requested + unsampled_requested) as f64 * 100.0;
                    hold = utilization <= pct as f64;
                }
                (utilization / pct as f64, utilization)
            }
            MetricTarget::AverageValue(target_value) => {
                let mut average = usage as f64 / sampled as f64;
                if average > target_value as f64 && unsampled > 0 {
                    average = usage as f64 / (sampled + unsampled) as f64;
                    hold = average <= target_value as f64;
                }
                (average / target_value as f64, average)
            }
        };

        let raw = if hold || (ratio - 1.0).abs() <= TOLERANCE {
            current
        } else {
            (current as f64 * ratio).ceil() as u32
        };
        let desired =
            self.stabilize(&hpa, current, raw).clamp(hpa.min_replicas, hpa.max_replicas);

        if desired != current {
            api.update_status(KIND_DEPLOYMENT, &hpa.target, &|o| {
                o.spec.insert("replicas", desired as u64);
            })?;
            self.metrics.inc(if desired > current {
                "autoscale.hpa.scale_ups"
            } else {
                "autoscale.hpa.scale_downs"
            });
            let reason = if desired > current { "ScaledUp" } else { "ScaledDown" };
            let _ = self.events.event(
                api,
                &deploy,
                EVENT_NORMAL,
                reason,
                &format!(
                    "Scaled {} from {current} to {desired} replicas (observed {} vs target {})",
                    hpa.target,
                    signal.round() as u64,
                    match hpa.metric_target {
                        MetricTarget::Utilization(pct) => format!("{pct}%"),
                        MetricTarget::AverageValue(v) => v.to_string(),
                    }
                ),
            );
        }
        let signal = signal.round() as u64;
        let changed = hpa.desired_replicas != Some(desired)
            || match hpa.metric_target {
                MetricTarget::Utilization(_) => hpa.current_utilization_pct != Some(signal),
                MetricTarget::AverageValue(_) => hpa.current_average_value != Some(signal),
            };
        if changed {
            api.update_status(KIND_HPA, name, &|o| {
                o.status.insert("currentReplicas", current as u64);
                o.status.insert("desiredReplicas", desired as u64);
                match hpa.metric_target {
                    MetricTarget::Utilization(_) => {
                        o.status.insert("currentUtilizationPct", signal)
                    }
                    MetricTarget::AverageValue(_) => {
                        o.status.insert("currentAverageValue", signal)
                    }
                };
            })?;
        }
        Ok(Reconcile::RequeueAfter(self.poll))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscale::metrics::{publish_node_sample, CPU_USAGE_ANNOTATION};
    use crate::cluster::Resources;
    use crate::kube::{ApiServer, DeploymentController, KIND_POD};

    fn factory(api: &ApiServer) -> SharedInformerFactory {
        SharedInformerFactory::new(api.client(), Metrics::new())
    }

    fn hpa_ctl(api: &ApiServer) -> HpaController {
        HpaController::new(&factory(api), Duration::from_millis(1), Metrics::new())
    }

    /// Deployment + pods marked Running + one metrics sample per pod.
    fn seed(api: &ApiServer, replicas: u32, load_milli: u64) {
        api.create(DeploymentController::build(
            "web",
            replicas,
            "svc.sif",
            Resources::new(1000, 64 << 20, 0),
        ))
        .unwrap();
        DeploymentController::new(&factory(api)).reconcile(api, "web").unwrap();
        for pod in api.list(KIND_POD, &[]) {
            api.update_status(KIND_POD, &pod.meta.name, |o| {
                o.spec.insert("nodeName", "w1");
                o.status.insert("phase", "Running");
                o.meta
                    .annotations
                    .push((CPU_USAGE_ANNOTATION.to_string(), load_milli.to_string()));
            })
            .unwrap();
        }
        publish_node_sample(
            api,
            &factory(api).informer(KIND_PODMETRICS),
            "w1",
            Resources::cores(64, 256 << 30),
            &api.list(KIND_POD, &[]),
            &Metrics::new(),
        );
    }

    fn replicas(api: &ApiServer) -> u32 {
        api.get(crate::kube::KIND_DEPLOYMENT, "web")
            .unwrap()
            .spec
            .opt_int("replicas")
            .unwrap_or(0) as u32
    }

    #[test]
    fn hpa_view_roundtrip_and_defaults() {
        let o = HpaView::build("h", "web", 2, 8, 60, Duration::from_secs(12));
        assert_eq!(o.api_version, AUTOSCALING_API_VERSION);
        let v = HpaView::from_object(&o).unwrap();
        assert_eq!(v.target, "web");
        assert_eq!((v.min_replicas, v.max_replicas), (2, 8));
        assert_eq!(v.metric, MetricSource::Cpu);
        assert_eq!(v.metric_target, MetricTarget::Utilization(60));
        assert_eq!(v.scale_up_window, Duration::ZERO);
        assert_eq!(v.scale_down_window, Duration::from_secs(12));
        // Bare spec gets the documented defaults.
        let mut bare = KubeObject::new(
            KIND_HPA,
            "b",
            Value::map().with("scaleTargetRef", Value::map().with("name", "web")),
        );
        bare.api_version = AUTOSCALING_API_VERSION.into();
        let v = HpaView::from_object(&bare).unwrap();
        assert_eq!((v.min_replicas, v.max_replicas), (1, 10));
        assert_eq!(v.metric_target, MetricTarget::Utilization(80));
        assert_eq!(v.scale_down_window, Duration::from_secs(30));
        // The v2 metrics entry round-trips both sources and both target
        // shapes.
        let o = HpaView::build_metric(
            "m",
            "web",
            1,
            8,
            MetricSource::Memory,
            MetricTarget::AverageValue(32 << 20),
            Duration::ZERO,
        );
        let v = HpaView::from_object(&o).unwrap();
        assert_eq!(v.metric, MetricSource::Memory);
        assert_eq!(v.metric_target, MetricTarget::AverageValue(32 << 20));
        let o = HpaView::build_metric(
            "u",
            "web",
            1,
            8,
            MetricSource::Memory,
            MetricTarget::Utilization(50),
            Duration::ZERO,
        );
        assert_eq!(HpaView::from_object(&o).unwrap().metric_target, MetricTarget::Utilization(50));
        // An AverageValue target without a value is a parse error, not a
        // silent default.
        let mut bad = HpaView::build_metric(
            "bad",
            "web",
            1,
            8,
            MetricSource::Cpu,
            MetricTarget::AverageValue(1),
            Duration::ZERO,
        );
        let mut entry = bad.spec.get("metrics").and_then(Value::as_seq).unwrap()[0].clone();
        if let Some(res) = entry.get_mut("resource") {
            if let Some(t) = res.get_mut("target") {
                t.remove("averageValue");
            }
        }
        bad.spec.insert("metrics", Value::Seq(vec![entry]));
        assert!(HpaView::from_object(&bad).is_err());
    }

    #[test]
    fn scales_up_on_high_utilization() {
        let api = ApiServer::new(Metrics::new());
        seed(&api, 2, 1000); // 100% of request vs target 50% -> double
        api.create(HpaView::build("h", "web", 1, 8, 50, Duration::ZERO)).unwrap();
        let ctl = hpa_ctl(&api);
        assert!(matches!(ctl.reconcile(&api, "h").unwrap(), Reconcile::RequeueAfter(_)));
        assert_eq!(replicas(&api), 4);
        let h = HpaView::from_object(&api.get(KIND_HPA, "h").unwrap()).unwrap();
        assert_eq!(h.current_utilization_pct, Some(100));
        assert_eq!(h.desired_replicas, Some(4));
        // The scale decision is narrated as an event on the Deployment.
        let ev = api
            .list(crate::kube::KIND_EVENT, &[])
            .iter()
            .map(|o| crate::kube::EventView::from_object(o).unwrap())
            .find(|e| e.reason == "ScaledUp")
            .expect("ScaledUp event");
        assert_eq!(ev.regarding_kind, crate::kube::KIND_DEPLOYMENT);
        assert_eq!(ev.regarding_name, "web");
        assert_eq!(ev.reporting_controller, COMPONENT);
        assert!(ev.note.contains("from 2 to 4"), "{}", ev.note);
    }

    #[test]
    fn respects_max_clamp_and_tolerance() {
        let api = ApiServer::new(Metrics::new());
        seed(&api, 2, 1000);
        api.create(HpaView::build("h", "web", 1, 3, 50, Duration::ZERO)).unwrap();
        let ctl = hpa_ctl(&api);
        ctl.reconcile(&api, "h").unwrap();
        assert_eq!(replicas(&api), 3, "clamped at maxReplicas");

        // Within the ±10% band nothing moves: 105% of a 100% target.
        let api = ApiServer::new(Metrics::new());
        seed(&api, 2, 1050);
        api.create(HpaView::build("h", "web", 1, 8, 100, Duration::ZERO)).unwrap();
        hpa_ctl(&api).reconcile(&api, "h").unwrap();
        assert_eq!(replicas(&api), 2, "tolerance band holds");
    }

    /// Re-point every pod's live usage annotation and republish metrics.
    fn set_pod_load(api: &ApiServer, load_milli: u64) {
        for pod in api.list(KIND_POD, &[]) {
            api.update_status(KIND_POD, &pod.meta.name, |o| {
                o.meta.annotations.retain(|(k, _)| k != CPU_USAGE_ANNOTATION);
                o.meta
                    .annotations
                    .push((CPU_USAGE_ANNOTATION.to_string(), load_milli.to_string()));
            })
            .unwrap();
        }
        publish_node_sample(
            api,
            &factory(api).informer(KIND_PODMETRICS),
            "w1",
            Resources::cores(64, 256 << 30),
            &api.list(KIND_POD, &[]),
            &Metrics::new(),
        );
    }

    #[test]
    fn scale_down_damped_by_window() {
        // On-target load records a "stay at 4" recommendation; when the
        // load then collapses, the 300s down-window still holds it.
        let api = ApiServer::new(Metrics::new());
        seed(&api, 4, 500); // 50% of request = exactly the 50% target
        api.create(HpaView::build("h", "web", 1, 8, 50, Duration::from_secs(300))).unwrap();
        let ctl = hpa_ctl(&api);
        ctl.reconcile(&api, "h").unwrap();
        assert_eq!(replicas(&api), 4);
        set_pod_load(&api, 100); // 10% -> wants 1
        ctl.reconcile(&api, "h").unwrap();
        assert_eq!(replicas(&api), 4, "down-window holds the floor high");

        // With a zero window the same signal collapses immediately.
        let api = ApiServer::new(Metrics::new());
        seed(&api, 4, 500);
        api.create(HpaView::build("h", "web", 1, 8, 50, Duration::ZERO)).unwrap();
        let ctl = hpa_ctl(&api);
        ctl.reconcile(&api, "h").unwrap();
        set_pod_load(&api, 100);
        // A zero window only considers recommendations from this very
        // instant; step past the first one's timestamp.
        std::thread::sleep(Duration::from_millis(3));
        ctl.reconcile(&api, "h").unwrap();
        assert_eq!(replicas(&api), 1);
    }

    /// Regression: a capacity-starved deployment (hot Running pods,
    /// the rest Pending with no samples) must not measure only its hot
    /// pods and ratchet to maxReplicas — metric-less pods count as idle
    /// on the way up.
    #[test]
    fn metricless_pending_pods_damp_scale_up() {
        let api = ApiServer::new(Metrics::new());
        seed(&api, 2, 1000); // two Running pods at 100% of request
        // Surge to 4: the two new replicas stay Pending and sample-less.
        api.update_status(crate::kube::KIND_DEPLOYMENT, "web", |o| {
            o.spec.insert("replicas", 4u64);
        })
        .unwrap();
        DeploymentController::new(&factory(&api)).reconcile(&api, "web").unwrap();
        api.create(HpaView::build("h", "web", 1, 16, 50, Duration::ZERO)).unwrap();
        hpa_ctl(&api).reconcile(&api, "h").unwrap();
        assert_eq!(
            replicas(&api),
            4,
            "2 hot + 2 idle-assumed pods average exactly onto the target"
        );
    }

    #[test]
    fn min_clamp_and_no_metrics_noop() {
        let api = ApiServer::new(Metrics::new());
        seed(&api, 3, 0); // zero usage -> wants 0, min 2 clamps
        api.create(HpaView::build("h", "web", 2, 8, 50, Duration::ZERO)).unwrap();
        hpa_ctl(&api).reconcile(&api, "h").unwrap();
        assert_eq!(replicas(&api), 2);

        // No metrics at all: a fresh deployment must not be touched.
        let api = ApiServer::new(Metrics::new());
        api.create(DeploymentController::build(
            "web",
            3,
            "svc.sif",
            Resources::new(1000, 64 << 20, 0),
        ))
        .unwrap();
        api.create(HpaView::build("h", "web", 1, 8, 50, Duration::ZERO)).unwrap();
        assert!(matches!(
            hpa_ctl(&api).reconcile(&api, "h").unwrap(),
            Reconcile::RequeueAfter(_)
        ));
        assert_eq!(replicas(&api), 3, "cold pipeline: hands off");
    }

    #[test]
    fn memory_utilization_target_scales() {
        // The metrics publisher samples a Running pod's memory usage at
        // its request, so memory utilization observes 100%; against a
        // 50% target the deployment doubles.
        let api = ApiServer::new(Metrics::new());
        seed(&api, 2, 100);
        api.create(HpaView::build_metric(
            "h",
            "web",
            1,
            8,
            MetricSource::Memory,
            MetricTarget::Utilization(50),
            Duration::ZERO,
        ))
        .unwrap();
        hpa_ctl(&api).reconcile(&api, "h").unwrap();
        assert_eq!(replicas(&api), 4);
        let h = HpaView::from_object(&api.get(KIND_HPA, "h").unwrap()).unwrap();
        assert_eq!(h.current_utilization_pct, Some(100));
        assert_eq!(h.desired_replicas, Some(4));
    }

    #[test]
    fn average_value_target_scales_and_reports() {
        // Each pod uses 1000 milli-cores; an AverageValue target of 250m
        // wants 4x the replicas (clamped at 8 here).
        let api = ApiServer::new(Metrics::new());
        seed(&api, 2, 1000);
        api.create(HpaView::build_metric(
            "h",
            "web",
            1,
            16,
            MetricSource::Cpu,
            MetricTarget::AverageValue(250),
            Duration::ZERO,
        ))
        .unwrap();
        hpa_ctl(&api).reconcile(&api, "h").unwrap();
        assert_eq!(replicas(&api), 8, "avg 1000m vs 250m target quadruples");
        let h = HpaView::from_object(&api.get(KIND_HPA, "h").unwrap()).unwrap();
        assert_eq!(h.current_average_value, Some(1000));
        assert_eq!(h.current_utilization_pct, None, "average mode reports averageValue");

        // Within tolerance nothing moves: 260m vs 250m is inside ±10%.
        let api = ApiServer::new(Metrics::new());
        seed(&api, 2, 260);
        api.create(HpaView::build_metric(
            "h",
            "web",
            1,
            16,
            MetricSource::Cpu,
            MetricTarget::AverageValue(250),
            Duration::ZERO,
        ))
        .unwrap();
        hpa_ctl(&api).reconcile(&api, "h").unwrap();
        assert_eq!(replicas(&api), 2, "tolerance band holds in average mode");
    }

    #[test]
    fn average_value_counts_metricless_pods_on_the_way_up() {
        // 2 hot pods at 1000m + 2 sample-less Pending pods: the
        // conservative rule averages over all 4 (500m vs 500m target) and
        // holds instead of ratcheting up.
        let api = ApiServer::new(Metrics::new());
        seed(&api, 2, 1000);
        api.update_status(crate::kube::KIND_DEPLOYMENT, "web", |o| {
            o.spec.insert("replicas", 4u64);
        })
        .unwrap();
        DeploymentController::new(&factory(&api)).reconcile(&api, "web").unwrap();
        api.create(HpaView::build_metric(
            "h",
            "web",
            1,
            16,
            MetricSource::Cpu,
            MetricTarget::AverageValue(500),
            Duration::ZERO,
        ))
        .unwrap();
        hpa_ctl(&api).reconcile(&api, "h").unwrap();
        assert_eq!(replicas(&api), 4, "metric-less pods damp average-value scale-up");
    }

    #[test]
    fn deleted_hpa_reconciles_ok_and_drops_history() {
        let api = ApiServer::new(Metrics::new());
        let ctl = hpa_ctl(&api);
        assert_eq!(ctl.reconcile(&api, "ghost").unwrap(), Reconcile::Ok);
    }
}
