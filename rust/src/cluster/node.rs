//! Simulated machine descriptions — the substrate both clusters run on.
//!
//! A [`NodeSpec`] stands in for a physical host (paper Fig. 1: Torque compute
//! nodes, Kubernetes worker nodes, and the shared login node). Nodes here
//! are *capacity + identity*; the live daemons (pbs_mom, kubelet) hold the
//! mutable allocation state.

use crate::cluster::Resources;
use crate::encoding::{Decode, Encode, Value};
use crate::util::Result;

/// Role of a node in the hybrid testbed (paper Fig. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeRole {
    /// Torque head node (runs pbs_server + scheduler).
    TorqueHead,
    /// Torque compute node (runs pbs_mom).
    TorqueCompute,
    /// Kubernetes master (API server + scheduler + controllers).
    KubeMaster,
    /// Kubernetes worker (kubelet + CRI).
    KubeWorker,
    /// The shared login node: member of BOTH clusters; hosts red-box and the
    /// virtual-kubelet (paper: "The login node belongs to both Kubernetes
    /// and Torque clusters").
    Login,
}

impl NodeRole {
    pub fn as_str(&self) -> &'static str {
        match self {
            NodeRole::TorqueHead => "torque-head",
            NodeRole::TorqueCompute => "torque-compute",
            NodeRole::KubeMaster => "kube-master",
            NodeRole::KubeWorker => "kube-worker",
            NodeRole::Login => "login",
        }
    }

    pub fn parse(s: &str) -> Option<NodeRole> {
        Some(match s {
            "torque-head" => NodeRole::TorqueHead,
            "torque-compute" => NodeRole::TorqueCompute,
            "kube-master" => NodeRole::KubeMaster,
            "kube-worker" => NodeRole::KubeWorker,
            "login" => NodeRole::Login,
            _ => return None,
        })
    }
}

/// Description of one simulated host.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    pub name: String,
    pub role: NodeRole,
    pub capacity: Resources,
    /// Torque node properties / k8s labels (e.g. `bigmem`, `gpu`).
    pub features: Vec<String>,
}

impl NodeSpec {
    pub fn new(name: impl Into<String>, role: NodeRole, capacity: Resources) -> Self {
        NodeSpec { name: name.into(), role, capacity, features: Vec::new() }
    }

    pub fn with_features(mut self, features: &[&str]) -> Self {
        self.features = features.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn has_feature(&self, f: &str) -> bool {
        self.features.iter().any(|x| x == f)
    }
}

impl Encode for NodeSpec {
    fn encode(&self) -> Value {
        Value::map()
            .with("name", self.name.clone())
            .with("role", self.role.as_str())
            .with("capacity", self.capacity.encode())
            .with(
                "features",
                Value::Seq(self.features.iter().map(|f| Value::str(f.clone())).collect()),
            )
    }
}

impl Decode for NodeSpec {
    fn decode(v: &Value) -> Result<Self> {
        let role = NodeRole::parse(v.req_str("role")?)
            .ok_or_else(|| crate::util::Error::parse("bad node role"))?;
        Ok(NodeSpec {
            name: v.req_str("name")?.to_string(),
            role,
            capacity: Resources::decode(v.req("capacity")?)?,
            features: v
                .get("features")
                .and_then(Value::as_seq)
                .map(|s| s.iter().filter_map(|f| f.as_str().map(String::from)).collect())
                .unwrap_or_default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roles_roundtrip() {
        for r in [
            NodeRole::TorqueHead,
            NodeRole::TorqueCompute,
            NodeRole::KubeMaster,
            NodeRole::KubeWorker,
            NodeRole::Login,
        ] {
            assert_eq!(NodeRole::parse(r.as_str()), Some(r));
        }
        assert_eq!(NodeRole::parse("nope"), None);
    }

    #[test]
    fn spec_encode_roundtrip() {
        let spec = NodeSpec::new("cn01", NodeRole::TorqueCompute, Resources::cores(16, 64 << 30))
            .with_features(&["bigmem", "infiniband"]);
        let v = spec.encode();
        let back = NodeSpec::decode(&v).unwrap();
        assert_eq!(back, spec);
        assert!(back.has_feature("bigmem"));
        assert!(!back.has_feature("gpu"));
    }
}
