//! Minimal argv parser: positionals + `--flag[=| ]value` + boolean flags.

use crate::util::{Error, Result};
use std::collections::BTreeMap;

pub struct Args {
    positionals: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse argv (program name already stripped). Flags may appear
    /// anywhere; `--k v`, `--k=v`, and bare `--k` are accepted.
    pub fn new(argv: Vec<String>) -> Args {
        let mut positionals = Vec::new();
        let mut flags = BTreeMap::new();
        let mut bools = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    bools.push(name.to_string());
                }
            } else if a == "-f" || a == "-o" || a == "-l" {
                // kubectl-isms (-l = label selector)
                if i + 1 < argv.len() {
                    flags.insert(a.trim_start_matches('-').to_string(), argv[i + 1].clone());
                    i += 1;
                }
            } else {
                positionals.push(a.clone());
            }
            i += 1;
        }
        Args { positionals, flags, bools }
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }

    pub fn req_positional(&self, i: usize, what: &str) -> Result<&str> {
        self.positional(i)
            .ok_or_else(|| Error::config(format!("missing argument: {what}")))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn req_flag(&self, name: &str) -> Result<&str> {
        self.flag(name).ok_or_else(|| Error::config(format!("missing --{name}")))
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn num<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.flag(name) {
            Some(v) => {
                v.parse().map_err(|_| Error::config(format!("bad value for --{name}: `{v}`")))
            }
            None => Ok(default),
        }
    }

    pub fn bool(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name) || self.flags.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::new(s.split_whitespace().map(String::from).collect())
    }

    #[test]
    fn positionals_and_flags() {
        let a = args("kubectl get torquejob --socket /tmp/x.sock -o yaml");
        assert_eq!(a.positional(0), Some("kubectl"));
        assert_eq!(a.positional(1), Some("get"));
        assert_eq!(a.positional(2), Some("torquejob"));
        assert_eq!(a.flag("socket"), Some("/tmp/x.sock"));
        assert_eq!(a.flag("o"), Some("yaml"));
        assert!(a.positional(3).is_none());
    }

    #[test]
    fn label_selector_flag() {
        let a = args("kubectl get pods -l app=web,tier=db --socket /tmp/x.sock");
        assert_eq!(a.flag("l"), Some("app=web,tier=db"));
        assert_eq!(a.positional(2), Some("pods"));
    }

    #[test]
    fn equals_and_bool_flags() {
        let a = args("sim --policy=easy --nodes 16 --verbose");
        assert_eq!(a.flag("policy"), Some("easy"));
        assert_eq!(a.num::<u32>("nodes", 0).unwrap(), 16);
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
        assert_eq!(a.num::<u32>("missing", 7).unwrap(), 7);
        assert!(args("x --n abc").num::<u32>("n", 0).is_err());
    }

    #[test]
    fn required_errors() {
        let a = args("qsub");
        assert!(a.req_positional(1, "script").is_err());
        assert!(a.req_flag("socket").is_err());
    }
}
