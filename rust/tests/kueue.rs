//! Queue-layer integration: gang atomicity, cohort borrowing, and
//! preemption, end to end through the real admission controller, the
//! Kubernetes scheduler, and the operator's red-box submission path
//! (with a recording bridge standing in for the WLM, so "nothing crossed
//! red-box" is a hard assertion, not an inference).

use hpcorc::cluster::{Metrics, Resources};
use hpcorc::kube::{
    ApiServer, Controller, KubeObject, KubeScheduler, NodeView, PodView,
    SharedInformerFactory, WlmJobView, KIND_POD, KIND_TORQUEJOB,
};
use hpcorc::kueue::{
    is_admitted, is_evicted, AdmissionCore, ClusterQueueView, LocalQueueView,
    PreemptionPolicy, QueueOrdering, QueueResources, POD_GROUP_COUNT_ANNOTATION,
    POD_GROUP_LABEL, PRIORITY_LABEL, QUEUE_NAME_LABEL,
};
use hpcorc::operator::{
    register_virtual_nodes, OperatorConfig, WlmBridge, WlmJobOperator, WlmStatus,
};
use hpcorc::pbs::PbsScript;
use hpcorc::util::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// WLM bridge that records everything crossing the (simulated) red-box
/// boundary instead of running a PBS server.
#[derive(Default)]
struct RecordingBridge {
    submits: Mutex<Vec<String>>,
    cancels: Mutex<Vec<String>>,
    next: AtomicU64,
}

impl RecordingBridge {
    fn submits(&self) -> Vec<String> {
        self.submits.lock().unwrap().clone()
    }
    fn cancels(&self) -> Vec<String> {
        self.cancels.lock().unwrap().clone()
    }
}

impl WlmBridge for RecordingBridge {
    fn submit(&self, script: &str, _user: &str) -> Result<String> {
        self.submits.lock().unwrap().push(script.to_string());
        let n = self.next.fetch_add(1, Ordering::SeqCst);
        Ok(format!("{n}.rec-head"))
    }
    fn status(&self, _job_id: &str) -> Result<WlmStatus> {
        Ok(WlmStatus::Queued)
    }
    fn cancel(&self, job_id: &str) -> Result<()> {
        self.cancels.lock().unwrap().push(job_id.to_string());
        Ok(())
    }
    fn read_file(&self, _path: &str) -> Result<String> {
        Ok(String::new())
    }
    fn write_file(&self, _path: &str, _content: &str) -> Result<()> {
        Ok(())
    }
    fn queues(&self) -> Result<Vec<String>> {
        Ok(vec!["batch".into()])
    }
}

struct Env {
    api: ApiServer,
    core: AdmissionCore,
    sched: KubeScheduler,
    operator: Arc<WlmJobOperator>,
    bridge: Arc<RecordingBridge>,
}

fn env() -> Env {
    let api = ApiServer::new(Metrics::new());
    let bridge = Arc::new(RecordingBridge::default());
    register_virtual_nodes(&api, bridge.as_ref(), "torque").unwrap();
    let informers = SharedInformerFactory::new(api.client(), Metrics::new());
    let sched = KubeScheduler::new(&informers, Metrics::new());
    let wlm: Arc<dyn WlmBridge> = bridge.clone();
    let operator = WlmJobOperator::new(OperatorConfig::torque(), wlm, Metrics::new());
    Env { api, core: AdmissionCore::new(&informers, Metrics::new()), sched, operator, bridge }
}

fn queued_pod(name: &str, queue: &str) -> KubeObject {
    let mut p = PodView::build(name, "img.sif", Resources::new(100, 1 << 20, 0), &[]);
    // Sets the queue label AND the kueue scheduling gate, so the pod is
    // born suspended (PR 3: the scheduler gates on generic
    // schedulingGates; kueue owns its gate).
    hpcorc::kueue::queue_workload(&mut p, queue);
    p
}

fn pod_group(queue: &str, group: &str, n: usize) -> Vec<KubeObject> {
    (0..n)
        .map(|i| {
            let mut p = queued_pod(&format!("{group}-{i}"), queue);
            p.meta.set_label(POD_GROUP_LABEL, group);
            p.meta
                .annotations
                .push((POD_GROUP_COUNT_ANNOTATION.to_string(), n.to_string()));
            p
        })
        .collect()
}

fn wide_torquejob(name: &str, nodes: u32, queue: &str) -> KubeObject {
    let mut o = WlmJobView::build_torquejob(
        name,
        &format!("#!/bin/sh\n#PBS -l nodes={nodes}:ppn=1\nsleep 5\n"),
        "",
        "",
    );
    o.meta.set_label(QUEUE_NAME_LABEL, queue);
    o
}

/// Acceptance: a 4-node WlmJob against a 3-node-free quota admits zero
/// pods and submits nothing over red-box; once the quota frees it admits
/// all-at-once and submits exactly one 4-node job.
#[test]
fn gang_admission_is_all_or_nothing_over_redbox() {
    let e = env();
    e.api
        .create(ClusterQueueView::build("cq-a", QueueResources::nodes(4)))
        .unwrap();
    e.api.create(LocalQueueView::build("tenant-a", "cq-a")).unwrap();

    // An admitted 1-node pod leaves 3 nodes of quota.
    e.api.create(queued_pod("occ", "tenant-a")).unwrap();
    e.core.cycle(&e.api).unwrap();
    assert!(is_admitted(&e.api.get(KIND_POD, "occ").unwrap()));

    // The 4-node gang arrives against 3 free quota nodes.
    e.api.create(wide_torquejob("wide", 4, "tenant-a")).unwrap();
    for _ in 0..5 {
        e.core.cycle(&e.api).unwrap();
        e.operator.reconcile(&e.api, "wide").unwrap();
        e.sched.run_cycle();
    }
    assert!(
        e.api.get(KIND_POD, "wide-submit").unwrap_err().is_not_found(),
        "gang admitted zero pods"
    );
    assert!(e.bridge.submits().is_empty(), "nothing crossed red-box");
    let obj = e.api.get(KIND_TORQUEJOB, "wide").unwrap();
    assert!(!is_admitted(&obj));
    assert_eq!(obj.status.opt_str("phase").unwrap_or(""), "", "held suspended");

    // Quota frees (the occupant completes) → the gang admits atomically.
    e.api
        .update_status(KIND_POD, "occ", |o| o.status.insert("phase", "Succeeded"))
        .unwrap();
    let r = e.core.cycle(&e.api).unwrap();
    assert_eq!(r.admitted, 1);
    e.operator.reconcile(&e.api, "wide").unwrap(); // dummy pod created
    assert_eq!(e.sched.run_cycle(), 1, "dummy pod binds to the virtual node");
    e.operator.reconcile(&e.api, "wide").unwrap(); // submits over red-box
    let submits = e.bridge.submits();
    assert_eq!(submits.len(), 1, "exactly one all-at-once submission");
    assert_eq!(PbsScript::parse(&submits[0]).unwrap().nodes, 4);
    let obj = e.api.get(KIND_TORQUEJOB, "wide").unwrap();
    assert_eq!(obj.status.opt_str("phase"), Some("queued"));
}

/// Cohort borrowing: an idle peer's nominal capacity is borrowable, and
/// the cohort's total capacity is the hard cap.
#[test]
fn cohort_borrowing_admits_beyond_nominal() {
    let e = env();
    for name in ["cq-a", "cq-b"] {
        e.api
            .create(ClusterQueueView::build_full(
                name,
                Some("pool"),
                QueueResources::nodes(2),
                None,
                QueueOrdering::Fifo,
                PreemptionPolicy::default(),
            ))
            .unwrap();
    }
    e.api.create(LocalQueueView::build("tenant-a", "cq-a")).unwrap();
    e.api.create(LocalQueueView::build("tenant-b", "cq-b")).unwrap();

    // 3-pod gang on tenant-a: borrows 1 node from idle cq-b.
    for p in pod_group("tenant-a", "grp-a", 3) {
        e.api.create(p).unwrap();
    }
    let r = e.core.cycle(&e.api).unwrap();
    assert_eq!(r.admitted, 3, "borrowed idle cohort capacity");
    for i in 0..3 {
        assert!(is_admitted(&e.api.get(KIND_POD, &format!("grp-a-{i}")).unwrap()));
    }

    // tenant-b's own 2-pod gang no longer fits (cohort 3+2 > 4) and
    // cq-b has no preemption policy: it waits.
    for p in pod_group("tenant-b", "grp-b", 2) {
        e.api.create(p).unwrap();
    }
    let r = e.core.cycle(&e.api).unwrap();
    assert_eq!(r.admitted, 0);
    assert_eq!(r.pending, 2);
    assert!(!is_admitted(&e.api.get(KIND_POD, "grp-b-0").unwrap()));
}

/// Preemption (reclaim): a within-nominal gang evicts the cohort peer's
/// borrowing gang — whole-gang eviction, lender made whole.
#[test]
fn preemption_reclaims_borrowed_capacity() {
    let e = env();
    e.api
        .create(ClusterQueueView::build_full(
            "cq-a",
            Some("pool"),
            QueueResources::nodes(2),
            None,
            QueueOrdering::Fifo,
            PreemptionPolicy::default(),
        ))
        .unwrap();
    e.api
        .create(ClusterQueueView::build_full(
            "cq-b",
            Some("pool"),
            QueueResources::nodes(2),
            None,
            QueueOrdering::Fifo,
            PreemptionPolicy { reclaim_within_cohort: true, within_queue: false },
        ))
        .unwrap();
    e.api.create(LocalQueueView::build("tenant-a", "cq-a")).unwrap();
    e.api.create(LocalQueueView::build("tenant-b", "cq-b")).unwrap();

    for p in pod_group("tenant-a", "grp-a", 3) {
        e.api.create(p).unwrap();
    }
    assert_eq!(e.core.cycle(&e.api).unwrap().admitted, 3);

    for p in pod_group("tenant-b", "grp-b", 2) {
        e.api.create(p).unwrap();
    }
    let r = e.core.cycle(&e.api).unwrap();
    assert_eq!(r.preempted, 3, "whole borrowing gang evicted");
    assert_eq!(r.admitted, 2, "reclaimer admitted in the same cycle");
    for i in 0..3 {
        let p = e.api.get(KIND_POD, &format!("grp-a-{i}")).unwrap();
        assert!(!is_admitted(&p));
        assert!(is_evicted(&p));
        assert!(p.spec.opt_str("nodeName").is_none(), "evicted pods are unbound");
        assert_eq!(
            hpcorc::kube::scheduling_gates(&p),
            vec![hpcorc::kueue::SCHEDULING_GATE.to_string()],
            "eviction re-gates the pod against the scheduler"
        );
    }
    for i in 0..2 {
        assert!(is_admitted(&e.api.get(KIND_POD, &format!("grp-b-{i}")).unwrap()));
    }
}

/// Preemption unwinds an already-submitted WLM job: the operator cancels
/// it over red-box and resubmits after re-admission.
#[test]
fn preemption_cancels_submitted_wlm_job_and_resubmits() {
    let e = env();
    e.api
        .create(ClusterQueueView::build_full(
            "cq-a",
            Some("pool"),
            QueueResources::nodes(2),
            None,
            QueueOrdering::Fifo,
            PreemptionPolicy::default(),
        ))
        .unwrap();
    e.api
        .create(ClusterQueueView::build_full(
            "cq-b",
            Some("pool"),
            QueueResources::nodes(2),
            None,
            QueueOrdering::Fifo,
            PreemptionPolicy { reclaim_within_cohort: true, within_queue: false },
        ))
        .unwrap();
    e.api.create(LocalQueueView::build("tenant-a", "cq-a")).unwrap();
    e.api.create(LocalQueueView::build("tenant-b", "cq-b")).unwrap();

    // tenant-a's 3-node TorqueJob borrows and goes all the way to qsub.
    e.api.create(wide_torquejob("borrower", 3, "tenant-a")).unwrap();
    e.core.cycle(&e.api).unwrap();
    e.operator.reconcile(&e.api, "borrower").unwrap();
    e.sched.run_cycle();
    e.operator.reconcile(&e.api, "borrower").unwrap();
    assert_eq!(e.bridge.submits().len(), 1, "borrower submitted");
    let job_id = e
        .api
        .get(KIND_TORQUEJOB, "borrower")
        .unwrap()
        .status
        .opt_str("jobId")
        .unwrap()
        .to_string();

    // tenant-b reclaims its nominal capacity.
    e.api.create(wide_torquejob("rightful", 2, "tenant-b")).unwrap();
    let r = e.core.cycle(&e.api).unwrap();
    assert_eq!(r.preempted, 1);
    assert_eq!(r.admitted, 1);
    // The operator observes the eviction and unwinds the submission.
    e.operator.reconcile(&e.api, "borrower").unwrap();
    assert_eq!(e.bridge.cancels(), vec![job_id], "cancelled over red-box");
    let obj = e.api.get(KIND_TORQUEJOB, "borrower").unwrap();
    assert_eq!(obj.status.opt_str("phase").unwrap_or(""), "", "reset for resubmission");
    assert!(obj.status.opt_str("jobId").is_none());

    // The rightful gang proceeds; the borrower stays suspended (cohort
    // has no room: 2 + 3 > 4).
    e.operator.reconcile(&e.api, "rightful").unwrap();
    e.sched.run_cycle();
    e.operator.reconcile(&e.api, "rightful").unwrap();
    assert_eq!(e.bridge.submits().len(), 2, "rightful submitted");
    e.core.cycle(&e.api).unwrap();
    e.operator.reconcile(&e.api, "borrower").unwrap();
    assert_eq!(e.bridge.submits().len(), 2, "borrower must not resubmit while evicted");
}

/// Within-queue preemption: a higher-priority gang evicts the cheapest
/// lower-priority gang in the same ClusterQueue.
#[test]
fn within_queue_priority_preemption() {
    let e = env();
    e.api
        .create(ClusterQueueView::build_full(
            "cq",
            None,
            QueueResources::nodes(2),
            None,
            QueueOrdering::Priority,
            PreemptionPolicy { reclaim_within_cohort: false, within_queue: true },
        ))
        .unwrap();
    e.api.create(LocalQueueView::build("team", "cq")).unwrap();

    for p in pod_group("team", "low", 2) {
        e.api.create(p).unwrap();
    }
    assert_eq!(e.core.cycle(&e.api).unwrap().admitted, 2);

    let mut high = pod_group("team", "high", 2);
    for p in &mut high {
        p.meta.set_label(PRIORITY_LABEL, "10");
    }
    for p in high {
        e.api.create(p).unwrap();
    }
    let r = e.core.cycle(&e.api).unwrap();
    assert_eq!(r.preempted, 2);
    assert_eq!(r.admitted, 2);
    assert!(is_admitted(&e.api.get(KIND_POD, "high-0").unwrap()));
    assert!(is_evicted(&e.api.get(KIND_POD, "low-0").unwrap()));
}

/// Pod-group gangs: members are held until the declared count is present,
/// then admitted (and scheduled) together.
#[test]
fn pod_group_admits_only_when_complete() {
    let e = env();
    e.api
        .create(NodeView::build("w1", Resources::cores(8, 32 << 30), &[]))
        .unwrap();
    e.api
        .create(ClusterQueueView::build("cq", QueueResources::nodes(10)))
        .unwrap();
    e.api.create(LocalQueueView::build("team", "cq")).unwrap();

    let members = pod_group("team", "gang", 2);
    e.api.create(members[0].clone()).unwrap();
    let r = e.core.cycle(&e.api).unwrap();
    assert_eq!(r.admitted, 0, "incomplete group held");
    assert_eq!(e.sched.run_cycle(), 0, "gated member must not bind");

    e.api.create(members[1].clone()).unwrap();
    let r = e.core.cycle(&e.api).unwrap();
    assert_eq!(r.admitted, 2, "whole gang admitted in one cycle");
    assert_eq!(e.sched.run_cycle(), 2, "both members bind");
    for i in 0..2 {
        let p = e.api.get(KIND_POD, &format!("gang-{i}")).unwrap();
        assert_eq!(p.spec.opt_str("nodeName"), Some("w1"));
    }
}
