//! Serialization substrate: dynamic [`Value`] tree with JSON and YAML codecs.
//!
//! The offline environment provides no serde/serde_json/serde_yaml, so the
//! kube API store, red-box wire protocol, manifests, and artifacts manifest
//! all speak through these hand-rolled codecs.
//!
//! Conventions:
//! - JSON (compact) is the canonical wire + storage form.
//! - YAML is the human form (manifests in, `-o yaml` out).
//! - Typed objects implement [`Encode`]/[`Decode`] to convert to/from
//!   [`Value`] (our serde substitute).

pub mod json;
pub mod value;
pub mod yaml;

pub use value::Value;

use crate::util::Result;

/// Convert a typed object into a [`Value`] tree.
pub trait Encode {
    fn encode(&self) -> Value;
}

/// Build a typed object from a [`Value`] tree.
pub trait Decode: Sized {
    fn decode(v: &Value) -> Result<Self>;
}

impl Encode for Value {
    fn encode(&self) -> Value {
        self.clone()
    }
}

impl Decode for Value {
    fn decode(v: &Value) -> Result<Self> {
        Ok(v.clone())
    }
}

/// Encode a string map (common in labels/annotations/env).
pub fn encode_str_map(m: &[(String, String)]) -> Value {
    Value::Map(m.iter().map(|(k, v)| (k.clone(), Value::str(v.clone()))).collect())
}

/// Decode a string map, ignoring non-string values.
pub fn decode_str_map(v: &Value) -> Vec<(String, String)> {
    v.as_map()
        .map(|m| {
            m.iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect()
        })
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn yaml_json_cross_roundtrip() {
        // A manifest parsed from YAML, stored as JSON, re-read, re-emitted.
        let y = "kind: Pod\nmeta:\n  labels:\n    app: web\nspec:\n  replicas: 3\n";
        let v = yaml::parse(y).unwrap();
        let j = json::to_string(&v);
        let v2 = json::parse(&j).unwrap();
        assert_eq!(v, v2);
        let y2 = yaml::to_string(&v2);
        assert_eq!(yaml::parse(&y2).unwrap(), v);
    }

    #[test]
    fn str_map_helpers() {
        let m = vec![("a".to_string(), "1".to_string()), ("b".to_string(), "x".to_string())];
        let v = encode_str_map(&m);
        assert_eq!(decode_str_map(&v), m);
    }
}
