//! Store scale harness (PR 6): the sharded store + WAL backend at 100k
//! objects. Tracks create/get/list/watch-fanout latency, delta-list cost
//! vs a full list, WAL append + replay time, and the shard-isolation
//! contract: node reads must not stall while a foreign kind (pods)
//! churns — per-kind locks mean cross-kind contention is bounded by the
//! brief global commit section, never by the churning shard's lock.
//!
//! Object count defaults to 100_000; override with STORE_SCALE_N for
//! quick local runs.

use hpcorc::bench::{header, Bench};
use hpcorc::cluster::{Metrics, Resources};
use hpcorc::kube::{
    ApiServer, KubeObject, ListOptions, NodeView, PodView, WalBackend, KIND_NODE, KIND_POD,
};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn n_objects() -> usize {
    std::env::var("STORE_SCALE_N").ok().and_then(|v| v.parse().ok()).unwrap_or(100_000)
}

fn pod(i: usize) -> KubeObject {
    PodView::build(
        &format!("pod-{i:06}"),
        "lolcow_latest.sif",
        Resources::new(100, 1 << 20, 0),
        &[],
    )
}

fn node(i: usize) -> KubeObject {
    NodeView::build(&format!("node-{i:03}"), Resources::cores(64, 256 << 30), &[])
}

fn seed(api: &ApiServer, n: usize, nodes: usize) {
    for i in 0..n {
        api.create(pod(i)).unwrap();
    }
    for i in 0..nodes {
        api.create(node(i)).unwrap();
    }
}

fn main() {
    let n = n_objects();
    println!("=== store scale: {n} pods + 64 nodes, sharded store + WAL (PR 6) ===");
    println!("{}", header());
    let mut stats = Vec::new();

    // Create throughput into a fresh in-memory server.
    stats.push(Bench::new(format!("store.create x{n}")).warmup(0).iters(2).run_throughput(
        n as u32,
        |_| {
            let api = ApiServer::new(Metrics::new());
            for i in 0..n {
                api.create(pod(i)).unwrap();
            }
            std::hint::black_box(api.current_version());
        },
    ));

    // One server seeded at scale for the read-side benches.
    let api = ApiServer::new(Metrics::new());
    seed(&api, n, 64);
    let mid = format!("pod-{:06}", n / 2);

    stats.push(Bench::new(format!("store.get @{n}")).warmup(200).iters(5000).run(|| {
        api.get(KIND_POD, &mid).unwrap();
    }));

    stats.push(Bench::new(format!("store.list full @{n}")).warmup(1).iters(5).run(|| {
        let l = api.list_opts(KIND_POD, &ListOptions::all()).unwrap();
        assert_eq!(l.items.len(), n);
    }));

    // Delta list: after 128 changes, a relist ships 128 objects, not n.
    let floor = api.current_version();
    for i in 0..128 {
        api.update_status(KIND_POD, &format!("pod-{i:06}"), |o| {
            o.status.insert("phase", "Running");
        })
        .unwrap();
    }
    stats.push(
        Bench::new(format!("store.list delta(128) @{n}")).warmup(5).iters(200).run(|| {
            let l = api.list_opts(KIND_POD, &ListOptions::all().delta_since(floor)).unwrap();
            assert!(l.delta);
            assert_eq!(l.items.len(), 128);
        }),
    );

    // Watch fan-out: one update delivered to 64 per-kind watchers.
    let watchers: Vec<_> =
        (0..64).map(|_| api.watch(Some(KIND_POD), api.current_version())).collect();
    stats.push(Bench::new("watch.fanout-64 update+drain").warmup(20).iters(500).run(|| {
        api.update_status(KIND_POD, &mid, |o| {
            o.status.insert("phase", "Running");
        })
        .unwrap();
        for rx in &watchers {
            while rx.try_recv().is_ok() {}
        }
    }));
    drop(watchers);

    // WAL: durable create throughput, then a cold open replaying it all.
    // Compaction threshold above n keeps this an append-rate measurement
    // (snapshot cost is the compaction row's business, not this one's).
    let dir = std::env::temp_dir().join(format!("hpcorc-store-scale-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    stats.push(Bench::new(format!("wal.create x{n}")).warmup(0).iters(1).run_throughput(
        n as u32,
        |_| {
            let _ = std::fs::remove_dir_all(&dir);
            let backend =
                Box::new(WalBackend::open(&dir).unwrap().with_compact_threshold(n * 2));
            let api = ApiServer::with_backend(Metrics::new(), backend, 4096).unwrap();
            for i in 0..n {
                api.create(pod(i)).unwrap();
            }
        },
    ));
    stats.push(Bench::new(format!("wal.open+replay x{n}")).warmup(0).iters(2).run(|| {
        let backend = Box::new(WalBackend::open(&dir).unwrap().with_compact_threshold(n * 2));
        let api = ApiServer::with_backend(Metrics::new(), backend, 4096).unwrap();
        assert_eq!(api.list(KIND_POD, &[]).len(), n);
    }));
    let _ = std::fs::remove_dir_all(&dir);

    // Shard isolation: node reads while the pod shard churns. Per-kind
    // locks keep the read path off the churning shard entirely; the only
    // shared section is the global commit lock the reader never takes.
    let base = Bench::new("node.get baseline").warmup(200).iters(5000).run(|| {
        api.get(KIND_NODE, "node-032").unwrap();
    });
    let stop = Arc::new(AtomicBool::new(false));
    let churn_api = api.clone();
    let churn_stop = stop.clone();
    let churner = std::thread::spawn(move || {
        let mut i = 0u64;
        while !churn_stop.load(Ordering::Relaxed) {
            let name = format!("pod-{:06}", i % 1024);
            let _ = churn_api.update_status(KIND_POD, &name, |o| {
                o.status.insert("beat", i);
            });
            i += 1;
        }
        i
    });
    let under = Bench::new("node.get under-pod-churn").warmup(200).iters(5000).run(|| {
        api.get(KIND_NODE, "node-032").unwrap();
    });
    stop.store(true, Ordering::Relaxed);
    let churned = churner.join().unwrap();
    let ratio = under.p99_ns as f64 / base.p99_ns.max(1) as f64;
    stats.push(base);
    stats.push(under);

    println!();
    for s in &stats {
        println!("{}", s.json());
    }
    println!(
        "{{\"bench\":\"node-read p99 under pod churn @{n}\",\"baseline_p99_ns\":{},\"churn_p99_ns\":{},\"ratio\":{ratio:.2},\"churn_writes\":{churned}}}",
        stats[stats.len() - 2].p99_ns,
        stats[stats.len() - 1].p99_ns,
    );
}
