//! Container runtime: executes image payloads on a node.
//!
//! Singularity's security model is the paper's reason for choosing it:
//! "execution of a Singularity container only demands a user privilege,
//! while a Docker container ... requires root permission" (§III). We model
//! the *cost* of that difference: [`RuntimeKind::Singularity`] starts a
//! container as a plain process (no daemon), [`RuntimeKind::DockerSim`]
//! pays a daemon round-trip plus root setup/teardown. Bench E5 measures it.

use super::image::{Payload, SifImage};
use super::registry::ImageRegistry;
use crate::cluster::{Metrics, SharedFs};
use crate::rt::Shutdown;
use crate::util::{Error, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Cancellation token for in-flight containers (qdel/pod delete/walltime).
pub type CancelToken = Shutdown;

/// Engine that executes AOT compute artifacts (implemented by
/// `runtime::PjrtRuntime`; injected to avoid a module cycle).
pub trait ComputeEngine: Send + Sync {
    /// Run `steps` iterations of `artifact`. `on_step(step, metric)` is
    /// called per iteration; returning `false` cancels.
    fn run(
        &self,
        artifact: &str,
        steps: u32,
        seed: u64,
        on_step: &mut dyn FnMut(u32, f32) -> bool,
    ) -> Result<ComputeSummary>;
}

#[derive(Debug, Clone, PartialEq)]
pub struct ComputeSummary {
    pub steps_done: u32,
    pub first_metric: f32,
    pub last_metric: f32,
    /// e.g. "loss" for train artifacts, "logit_norm" for inference.
    pub metric_name: String,
}

/// Which container runtime flavour a node runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RuntimeKind {
    /// User-privilege, daemonless (Singularity): tiny start overhead.
    Singularity,
    /// Root daemon model (Docker): client→daemon round trip + namespace
    /// setup at start, teardown at stop.
    DockerSim,
    /// No containerisation (bare process) — baseline for bench E5.
    Native,
}

impl RuntimeKind {
    /// Modeled start/stop overheads, calibrated to the order of magnitude
    /// reported for the real runtimes (Singularity exec ~O(100ms) cold but
    /// dominated by image open on shared FS; Docker run ~O(1s)). Scaled
    /// down 100x so tests stay fast; ratios are what bench E5 validates.
    pub fn start_overhead(&self) -> Duration {
        match self {
            RuntimeKind::Singularity => Duration::from_micros(900),
            RuntimeKind::DockerSim => Duration::from_micros(12_000),
            RuntimeKind::Native => Duration::ZERO,
        }
    }

    pub fn stop_overhead(&self) -> Duration {
        match self {
            RuntimeKind::Singularity => Duration::from_micros(200),
            RuntimeKind::DockerSim => Duration::from_micros(4_000),
            RuntimeKind::Native => Duration::ZERO,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            RuntimeKind::Singularity => "singularity",
            RuntimeKind::DockerSim => "docker-sim",
            RuntimeKind::Native => "native",
        }
    }
}

/// A container run request.
#[derive(Debug, Clone)]
pub struct RunRequest {
    pub image: String,
    /// Extra environment on top of the image's baked env.
    pub env: Vec<(String, String)>,
    /// Deterministic seed for compute payloads.
    pub seed: u64,
    /// Scale factor for Sleep payloads (testbeds compress walltime).
    pub time_scale: f64,
}

impl RunRequest {
    pub fn new(image: impl Into<String>) -> Self {
        RunRequest { image: image.into(), env: Vec::new(), seed: 0, time_scale: 1.0 }
    }
}

/// Outcome of a container run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunResult {
    pub exit_code: i32,
    pub stdout: String,
    pub stderr: String,
    pub wall: Duration,
    pub cancelled: bool,
}

impl RunResult {
    pub fn success(&self) -> bool {
        self.exit_code == 0 && !self.cancelled
    }
}

/// The node-local container runtime.
#[derive(Clone)]
pub struct Runtime {
    pub kind: RuntimeKind,
    registry: ImageRegistry,
    compute: Option<Arc<dyn ComputeEngine>>,
    metrics: Metrics,
}

impl Runtime {
    pub fn new(kind: RuntimeKind, registry: ImageRegistry, metrics: Metrics) -> Self {
        Runtime { kind, registry, compute: None, metrics }
    }

    /// Attach the PJRT compute engine (absent in pure-scheduling benches).
    pub fn with_compute(mut self, engine: Arc<dyn ComputeEngine>) -> Self {
        self.compute = Some(engine);
        self
    }

    pub fn registry(&self) -> &ImageRegistry {
        &self.registry
    }

    /// Run a container to completion (blocking; callers run on mom/kubelet
    /// worker threads). `fs` is the node's view of the shared filesystem,
    /// used by Script payloads for redirects.
    pub fn run(
        &self,
        req: &RunRequest,
        fs: &SharedFs,
        cancel: &CancelToken,
    ) -> Result<RunResult> {
        let t0 = Instant::now();
        let image = self.registry.pull(&req.image)?;
        // Start overhead: daemon round-trip / namespace setup.
        if spin_sleep(self.kind.start_overhead(), cancel) {
            return Ok(cancelled_result(t0));
        }
        self.metrics.inc("container.starts");
        let mut result = self.execute_payload(&image, req, fs, cancel, t0)?;
        if spin_sleep(self.kind.stop_overhead(), cancel) {
            result.cancelled = true;
        }
        result.wall = t0.elapsed();
        self.metrics.observe("container.wall_ns", result.wall.as_nanos() as u64);
        if result.exit_code != 0 {
            self.metrics.inc("container.failures");
        }
        Ok(result)
    }

    fn execute_payload(
        &self,
        image: &SifImage,
        req: &RunRequest,
        fs: &SharedFs,
        cancel: &CancelToken,
        t0: Instant,
    ) -> Result<RunResult> {
        match &image.payload {
            Payload::Echo { message } => Ok(RunResult {
                exit_code: 0,
                stdout: message.clone(),
                stderr: String::new(),
                wall: t0.elapsed(),
                cancelled: false,
            }),
            Payload::Sleep { millis } => {
                let scaled = Duration::from_secs_f64(
                    (*millis as f64 / 1000.0) * req.time_scale.max(0.0),
                );
                let cancelled = cancel.wait_timeout(scaled);
                Ok(RunResult {
                    exit_code: if cancelled { 137 } else { 0 }, // SIGKILL convention
                    stdout: String::new(),
                    stderr: if cancelled { "killed".into() } else { String::new() },
                    wall: t0.elapsed(),
                    cancelled,
                })
            }
            Payload::Compute { artifact, steps } => {
                let engine = self.compute.as_ref().ok_or_else(|| {
                    Error::container("no compute engine attached to runtime")
                })?;
                let mut log = String::new();
                let cancel2 = cancel.clone();
                let summary = engine.run(artifact, *steps, req.seed, &mut |step, metric| {
                    if step == 0 || (step + 1) % 10 == 0 {
                        log.push_str(&format!("step {:>5}  metric {:.6}\n", step + 1, metric));
                    }
                    !cancel2.is_triggered()
                })?;
                let cancelled = summary.steps_done < *steps;
                log.push_str(&format!(
                    "{}: {:.6} -> {:.6} over {} steps\n",
                    summary.metric_name, summary.first_metric, summary.last_metric,
                    summary.steps_done
                ));
                Ok(RunResult {
                    exit_code: if cancelled { 137 } else { 0 },
                    stdout: log,
                    stderr: String::new(),
                    wall: t0.elapsed(),
                    cancelled,
                })
            }
            Payload::Script { lines } => {
                let mut ctx = super::shell::ShellCtx::new(fs.clone(), self.clone(), cancel.clone());
                for (k, v) in &image.env {
                    ctx.env.insert(k.clone(), v.clone());
                }
                for (k, v) in &req.env {
                    ctx.env.insert(k.clone(), v.clone());
                }
                ctx.time_scale = req.time_scale;
                ctx.seed = req.seed;
                let code = ctx.run_script(lines);
                Ok(RunResult {
                    exit_code: code,
                    stdout: ctx.stdout,
                    stderr: ctx.stderr,
                    wall: t0.elapsed(),
                    cancelled: cancel.is_triggered(),
                })
            }
            Payload::Fail { exit_code } => Ok(RunResult {
                exit_code: *exit_code,
                stdout: String::new(),
                stderr: format!("payload failed with exit code {exit_code}"),
                wall: t0.elapsed(),
                cancelled: false,
            }),
        }
    }
}

fn cancelled_result(t0: Instant) -> RunResult {
    RunResult {
        exit_code: 137,
        stdout: String::new(),
        stderr: "killed before start".into(),
        wall: t0.elapsed(),
        cancelled: true,
    }
}

/// Sleep that honours cancellation; returns true if cancelled.
fn spin_sleep(d: Duration, cancel: &CancelToken) -> bool {
    if d.is_zero() {
        return cancel.is_triggered();
    }
    cancel.wait_timeout(d)
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;

    /// Deterministic fake engine: metric decays exponentially from 1.0.
    pub struct FakeEngine {
        pub step_delay: Duration,
    }

    impl ComputeEngine for FakeEngine {
        fn run(
            &self,
            artifact: &str,
            steps: u32,
            seed: u64,
            on_step: &mut dyn FnMut(u32, f32) -> bool,
        ) -> Result<ComputeSummary> {
            if artifact == "missing" {
                return Err(Error::compute("unknown artifact"));
            }
            let mut metric = 1.0f32 + (seed % 7) as f32 * 0.01;
            let first = metric;
            let mut done = 0;
            for s in 0..steps {
                if !self.step_delay.is_zero() {
                    std::thread::sleep(self.step_delay);
                }
                metric *= 0.99;
                done = s + 1;
                if !on_step(s, metric) {
                    break;
                }
            }
            Ok(ComputeSummary {
                steps_done: done,
                first_metric: first,
                last_metric: metric,
                metric_name: "loss".into(),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::FakeEngine;
    use super::*;

    fn rt(kind: RuntimeKind) -> Runtime {
        Runtime::new(kind, ImageRegistry::with_defaults(), Metrics::new())
            .with_compute(Arc::new(FakeEngine { step_delay: Duration::ZERO }))
    }

    #[test]
    fn echo_runs() {
        let rt = rt(RuntimeKind::Singularity);
        let fs = SharedFs::new();
        let res = rt.run(&RunRequest::new("lolcow_latest.sif"), &fs, &CancelToken::new()).unwrap();
        assert!(res.success());
        assert!(res.stdout.contains("Moo"));
    }

    #[test]
    fn missing_image_errors() {
        let rt = rt(RuntimeKind::Singularity);
        let fs = SharedFs::new();
        assert!(rt.run(&RunRequest::new("nope.sif"), &fs, &CancelToken::new()).is_err());
    }

    #[test]
    fn sleep_scales_with_time_scale() {
        let rt = rt(RuntimeKind::Native);
        let fs = SharedFs::new();
        let mut req = RunRequest::new("sleep_1s.sif");
        req.time_scale = 0.01; // 1s -> 10ms
        let t0 = Instant::now();
        let res = rt.run(&req, &fs, &CancelToken::new()).unwrap();
        assert!(res.success());
        assert!(t0.elapsed() < Duration::from_millis(500));
        assert!(t0.elapsed() >= Duration::from_millis(9));
    }

    #[test]
    fn sleep_cancellation() {
        let rt = rt(RuntimeKind::Native);
        let fs = SharedFs::new();
        let cancel = CancelToken::new();
        let c2 = cancel.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            c2.trigger();
        });
        let res = rt.run(&RunRequest::new("sleep_1s.sif"), &fs, &cancel).unwrap();
        assert!(res.cancelled);
        assert_eq!(res.exit_code, 137);
    }

    #[test]
    fn compute_payload_logs_metric() {
        let reg = ImageRegistry::with_defaults();
        reg.push(SifImage::new(
            "train.sif",
            Payload::Compute { artifact: "cropyield_train".into(), steps: 25 },
        ));
        let rt = Runtime::new(RuntimeKind::Singularity, reg, Metrics::new())
            .with_compute(Arc::new(FakeEngine { step_delay: Duration::ZERO }));
        let fs = SharedFs::new();
        let res = rt.run(&RunRequest::new("train.sif"), &fs, &CancelToken::new()).unwrap();
        assert!(res.success(), "{res:?}");
        assert!(res.stdout.contains("loss:"));
        assert!(res.stdout.contains("25 steps"));
    }

    #[test]
    fn compute_without_engine_errors() {
        let reg = ImageRegistry::new();
        reg.push(SifImage::new(
            "t.sif",
            Payload::Compute { artifact: "a".into(), steps: 1 },
        ));
        let rt = Runtime::new(RuntimeKind::Singularity, reg, Metrics::new());
        let fs = SharedFs::new();
        assert!(rt.run(&RunRequest::new("t.sif"), &fs, &CancelToken::new()).is_err());
    }

    #[test]
    fn fail_payload_exit_code() {
        let reg = ImageRegistry::new();
        reg.push(SifImage::new("bad.sif", Payload::Fail { exit_code: 3 }));
        let rt = Runtime::new(RuntimeKind::Singularity, reg, Metrics::new());
        let fs = SharedFs::new();
        let res = rt.run(&RunRequest::new("bad.sif"), &fs, &CancelToken::new()).unwrap();
        assert_eq!(res.exit_code, 3);
        assert!(!res.success());
    }

    #[test]
    fn docker_sim_slower_start_than_singularity() {
        // The ratio the paper's §III motivates; bench E5 measures it properly.
        assert!(
            RuntimeKind::DockerSim.start_overhead()
                > RuntimeKind::Singularity.start_overhead() * 5
        );
        assert_eq!(RuntimeKind::Native.start_overhead(), Duration::ZERO);
    }
}
