//! Discrete-event cluster simulator for the large-scale scheduling
//! evaluation (experiment E1/E3: "compare efficiency of scheduling the
//! container jobs by Kubernetes and Torque", paper §V).
//!
//! Reuses the *same* [`crate::sched`] policy code the live daemons run —
//! the simulator only replaces wallclock and process machinery, not the
//! decision logic. Deterministic: same trace + policy ⇒ same report.

pub mod admission;
pub mod engine;

pub use admission::QueueAdmission;
pub use engine::{simulate, ElasticParams, OperatorModel, SimParams, SimReport};
