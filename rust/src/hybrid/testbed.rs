//! Testbed assembly and lifecycle.

use crate::autoscale::{CaConfig, ClusterAutoscaler, HpaController, NodeProvisioner, KIND_HPA};
use crate::cluster::{Metrics, NodeRole, NodeSpec, Resources, SharedFs};
use crate::kube::{
    ApiClient, ApiServer, ControllerRunner, DeploymentController, KubeObject, KubeScheduler,
    Kubelet, PodPhase, SharedInformerFactory, WlmJobView, KIND_DEPLOYMENT, KIND_POD,
    KIND_SLURMJOB, KIND_TORQUEJOB,
};
use crate::operator::{
    self, phase, RedboxBridge, SlurmLoginService, TorqueLoginService, WlmBridge,
};
use crate::pbs::{PbsConfig, PbsServer, QueueConfig};
use crate::redbox::{RedboxClient, RedboxServer};
use crate::rt::{Shutdown, Timers};
use crate::singularity::{
    ComputeEngine, ImageRegistry, Payload, Runtime, RuntimeKind, SifImage, SingularityCri,
};
use crate::slurm::{Partition, SlurmConfig, Slurmctld};
use crate::util::{Error, Result};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Testbed shape (paper Fig. 1 defaults: one head node, compute nodes in a
/// `batch` queue, a kube master + workers, the shared login node).
pub struct TestbedConfig {
    /// Torque compute nodes.
    pub torque_nodes: usize,
    pub torque_cores: u32,
    /// Kubernetes worker nodes (the login node is additionally a worker,
    /// as in the paper).
    pub kube_workers: usize,
    pub kube_cores: u32,
    /// Extra queues beyond `batch` (name, priority).
    pub extra_queues: Vec<(String, i64)>,
    /// Also boot a Slurm cluster + WLM-Operator (for comparisons).
    pub with_slurm: bool,
    /// Nominal→real time compression.
    pub time_scale: f64,
    /// Attach the PJRT compute engine from this artifacts dir.
    pub artifacts_dir: Option<PathBuf>,
    /// Deploy the operator's 4 service containers (paper §III-B) as a
    /// Kubernetes Deployment.
    pub operator_deployment: bool,
    /// Unix socket path for red-box (default: per-pid temp path).
    pub socket: Option<PathBuf>,
    /// Watch-history window of the API server's store (PR 4). Sized well
    /// above the store default: every kubelet sync, admission cycle, and
    /// autoscaler pass writes, and a burst larger than the window forces
    /// every informer into a spurious relist — exactly the O(cluster)
    /// cost the informer layer removes.
    pub watch_history_cap: usize,
    /// Elastic autoscaling (PR 3): when set, kubelets already feed the
    /// metrics pipeline, and the testbed additionally runs the HPA
    /// controller plus a cluster autoscaler managing a pool of live
    /// simulated kubelets (provisioned/drained on demand, bursting
    /// labelled overflow onto the WLM partition).
    pub autoscale: Option<CaConfig>,
    /// Durable API server state (PR 6): WAL + snapshot directory. When
    /// set, every commit is persisted and booting over a non-empty
    /// directory recovers all objects and resource versions — restart
    /// the testbed on the same dir and `kubectl get` picks up where it
    /// left off. Bootstrap writes (node registration, the operator
    /// deployment) are applies, so recovery does not trip AlreadyExists.
    pub wal_dir: Option<PathBuf>,
    /// Audit trail file sink (PR 8): when set, every mutating API request
    /// is additionally appended to this file as one JSON record per line
    /// (the in-memory ring serves `hpcorc audit` regardless).
    pub audit_log: Option<PathBuf>,
    /// Chaos seam (PR 10): wrap the operators' WLM bridges before use.
    /// `crate::chaos::FaultyWlm` plugs in here to inject seeded latency
    /// and transient submit/status failures between the operator and the
    /// HPC cluster without touching either side.
    #[allow(clippy::type_complexity)]
    pub wlm_shim: Option<Arc<dyn Fn(Arc<dyn WlmBridge>) -> Arc<dyn WlmBridge> + Send + Sync>>,
}

impl Default for TestbedConfig {
    fn default() -> Self {
        TestbedConfig {
            torque_nodes: 4,
            torque_cores: 8,
            kube_workers: 2,
            kube_cores: 8,
            extra_queues: Vec::new(),
            with_slurm: false,
            time_scale: 0.001,
            artifacts_dir: None,
            operator_deployment: false,
            socket: None,
            watch_history_cap: 1 << 16,
            autoscale: None,
            wal_dir: None,
            audit_log: None,
            wlm_shim: None,
        }
    }
}

/// [`NodeProvisioner`] that registers a live simulated kubelet per pool
/// node — scale-up gives the scheduler a real node with a real container
/// runtime behind it, and drain tears the kubelet daemon down again.
pub struct KubeletProvisioner {
    informers: SharedInformerFactory,
    runtime: crate::singularity::Runtime,
    fs: SharedFs,
    node_capacity: Resources,
    time_scale: f64,
    metrics: Metrics,
    /// Testbed-wide shutdown; every provisioned kubelet also stops here.
    shutdown: Shutdown,
    node_shutdowns: Arc<std::sync::Mutex<std::collections::HashMap<String, Shutdown>>>,
    /// Lazily starts the single chain thread that fans the testbed
    /// shutdown out to every live per-node shutdown — one thread total,
    /// not one per provision (elastic churn would leak them otherwise).
    chain_started: std::sync::Once,
}

impl NodeProvisioner for KubeletProvisioner {
    fn provision(&self, name: &str, labels: &[(&str, &str)]) -> Result<()> {
        let cri = SingularityCri::new(self.runtime.clone());
        let kubelet = Kubelet::register(
            &self.informers,
            name,
            self.node_capacity,
            labels,
            cri,
            self.fs.clone(),
            self.time_scale,
            self.metrics.clone(),
        )?;
        // Per-node shutdown: drain stops just this kubelet; the chain
        // thread below takes all of them down with the testbed. (The
        // cluster autoscaler's ticker itself stops on the testbed
        // shutdown, so no provisions race in after the fan-out.)
        let sd = Shutdown::new();
        self.node_shutdowns.lock().unwrap().insert(name.to_string(), sd.clone());
        self.chain_started.call_once(|| {
            let global = self.shutdown.clone();
            let nodes = self.node_shutdowns.clone();
            crate::rt::spawn_named("ka-shutdown-chain", move || {
                global.wait();
                for sd in nodes.lock().unwrap().values() {
                    sd.trigger();
                }
            });
        });
        kubelet.start(Duration::from_millis(1), sd);
        Ok(())
    }

    fn deprovision(&self, name: &str) -> Result<()> {
        if let Some(sd) = self.node_shutdowns.lock().unwrap().remove(name) {
            sd.trigger();
        }
        Ok(())
    }
}

/// The running testbed.
pub struct Testbed {
    pub api: ApiServer,
    pub pbs: PbsServer,
    pub slurm: Option<Slurmctld>,
    pub fs: SharedFs,
    pub metrics: Metrics,
    pub shutdown: Shutdown,
    pub images: ImageRegistry,
    redbox: RedboxServer,
    socket: PathBuf,
    time_scale: f64,
    /// Per-static-worker kubelet shutdowns — the chaos kubelet-death lever.
    worker_shutdowns: Arc<std::sync::Mutex<std::collections::HashMap<String, Shutdown>>>,
    /// True when this testbed attached the process-wide span-log sink
    /// (WAL runs); `stop()` then detaches it so later boots start clean.
    owns_span_sink: bool,
}

impl Testbed {
    /// Boot everything. Daemons run until `shutdown()`.
    pub fn start(config: TestbedConfig) -> Result<Testbed> {
        let shutdown = Shutdown::new();
        let metrics = Metrics::new();
        let fs = SharedFs::new();
        let (timers, _timer_handle) = Timers::start(shutdown.clone());

        // ---- images: paper demo + service images + compute payloads ----
        let images = ImageRegistry::with_defaults();
        images.push(SifImage::new("wlm-dummy.sif", Payload::Echo { message: "transfer".into() }));
        images.push(SifImage::new("wlm-collect.sif", Payload::Echo { message: "collect".into() }));
        images.push(SifImage::new(
            "torque-operator.sif",
            Payload::Echo { message: "torque-operator service".into() },
        ));
        for variant in ["tiny", "small"] {
            for steps in [20u32, 50, 100, 200, 300] {
                images.push(SifImage::new(
                    format!("cropyield_train_{variant}_{steps}.sif"),
                    Payload::Compute {
                        artifact: format!("cropyield_train_{variant}"),
                        steps,
                    },
                ));
                images.push(SifImage::new(
                    format!("cropyield_infer_{variant}_{steps}.sif"),
                    Payload::Compute {
                        artifact: format!("cropyield_infer_{variant}"),
                        steps,
                    },
                ));
            }
        }

        // ---- container runtime (+ optional PJRT compute engine) ----
        let mut runtime =
            Runtime::new(RuntimeKind::Singularity, images.clone(), metrics.clone());
        if let Some(dir) = &config.artifacts_dir {
            let engine: Arc<dyn ComputeEngine> = Arc::new(crate::runtime::start_pjrt_host(
                dir,
                metrics.clone(),
                shutdown.clone(),
            )?);
            runtime = runtime.with_compute(engine);
        }

        // ---- HPC cluster: pbs_server + moms (Fig. 1 left) ----
        let torque_node_names: Vec<String> =
            (0..config.torque_nodes).map(|i| format!("cn{i:02}")).collect();
        let torque_nodes: Vec<NodeSpec> = torque_node_names
            .iter()
            .map(|n| {
                NodeSpec::new(
                    n.clone(),
                    NodeRole::TorqueCompute,
                    Resources::cores(config.torque_cores, 64 << 30),
                )
            })
            .collect();
        let mut queues = vec![QueueConfig::batch(
            &torque_node_names.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        )];
        for (name, prio) in &config.extra_queues {
            queues.push(
                QueueConfig::new(name.clone())
                    .with_priority(*prio)
                    .with_nodes(&torque_node_names.iter().map(|s| s.as_str()).collect::<Vec<_>>()),
            );
        }
        let pbs = PbsServer::start(
            PbsConfig {
                server_name: "torque-head".into(),
                queues,
                sched_period: Duration::from_millis(1),
                time_scale: config.time_scale,
            },
            torque_nodes,
            runtime.clone(),
            fs.clone(),
            Box::new(crate::sched::EasyBackfill),
            timers.clone(),
            metrics.clone(),
            shutdown.clone(),
        )?;

        // ---- optional Slurm cluster (WLM-Operator baseline) ----
        let slurm = if config.with_slurm {
            let names: Vec<String> =
                (0..config.torque_nodes).map(|i| format!("sn{i:02}")).collect();
            let nodes: Vec<NodeSpec> = names
                .iter()
                .map(|n| {
                    NodeSpec::new(
                        n.clone(),
                        NodeRole::TorqueCompute,
                        Resources::cores(config.torque_cores, 64 << 30),
                    )
                })
                .collect();
            Some(Slurmctld::start(
                SlurmConfig {
                    cluster_name: "slurm".into(),
                    partitions: vec![Partition::new(
                        "normal",
                        &names.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
                    )
                    .default_partition()],
                    sched_period: Duration::from_millis(1),
                    time_scale: config.time_scale,
                },
                nodes,
                runtime.clone(),
                fs.clone(),
                Box::new(crate::sched::EasyBackfill),
                timers.clone(),
                metrics.clone(),
                shutdown.clone(),
            )?)
        } else {
            None
        };

        // ---- login node: red-box socket + services (Fig. 2) ----
        static SOCKET_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let socket = config.socket.clone().unwrap_or_else(|| {
            std::env::temp_dir().join(format!(
                "hpcorc-redbox-{}-{}.sock",
                std::process::id(),
                SOCKET_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
            ))
        });
        let redbox = RedboxServer::start(&socket, shutdown.clone(), metrics.clone())?;
        redbox.register("torque.Workload", TorqueLoginService::new(pbs.clone()));
        if let Some(ctld) = &slurm {
            redbox.register("slurm.Workload", SlurmLoginService::new(ctld.clone()));
        }

        // ---- big-data cluster: API server + scheduler + kubelets ----
        // Watch-history window sized for testbed event bursts (PR 4).
        // With a WAL dir the store commits through the durable backend
        // (PR 6) and recovers any state a previous run left there.
        let api = match &config.wal_dir {
            Some(dir) => ApiServer::with_backend(
                metrics.clone(),
                Box::new(crate::kube::WalBackend::open(dir)?),
                config.watch_history_cap,
            )?,
            None => ApiServer::with_history_cap(metrics.clone(), config.watch_history_cap),
        };
        // Mutating admission (PR 4 satellite): pods born with a bare
        // kueue queue-name label are gated at creation — no one-cycle
        // race window for the scheduler.
        api.register_mutating_hook(crate::kueue::admission_mutating_hook());
        redbox.register("kube.Api", api.rpc_service());
        // Telemetry plane (PR 7/8): metrics snapshots, span export and the
        // audit trail over the same socket (`obs.Metrics` / `obs.Spans` /
        // `obs.Audit`).
        crate::obs::register(&redbox, metrics.clone(), api.audit_log().clone());
        if let Some(path) = &config.audit_log {
            api.audit_log().attach_file_sink(path)?;
        }
        // Durable spans (PR 8): completed spans persist next to the WAL so
        // `hpcorc trace KIND/NAME` still reconstructs a timeline after a
        // restart. Replay BEFORE attaching the sink — the replay pushes
        // straight into the ring and must not re-append to the log.
        let owns_span_sink = config.wal_dir.is_some();
        if let Some(dir) = &config.wal_dir {
            let span_log = dir.join("spans.jsonl");
            crate::obs::replay_span_log(&span_log);
            crate::obs::attach_span_log(&span_log)?;
        }
        // Every in-process component talks through the transport-agnostic
        // client handle — the same trait the remote CLI uses — and reads
        // through the shared informer caches (PR 4): one watch stream per
        // kind for the whole testbed, zero steady-state list RPCs.
        let client: Arc<dyn ApiClient> = api.client();
        let informers = SharedInformerFactory::new(client.clone(), metrics.clone());
        informers.start(Duration::from_millis(1), shutdown.clone());
        KubeScheduler::new(&informers, metrics.clone())
            .start(Duration::from_millis(1), shutdown.clone());
        // Queue layer (PR 2): quota-aware gang admission. A no-op until
        // someone applies ClusterQueue/LocalQueue objects — label-less
        // workloads bypass it entirely.
        crate::kueue::start_admission(&informers, metrics.clone(), shutdown.clone());
        // Event TTL GC (PR 8): the coalescing recorder bounds the Event
        // object count per (object, reason); this ticker bounds their age.
        {
            let gc_client = api.client();
            let gc_metrics = metrics.clone();
            let sd = shutdown.clone();
            crate::rt::spawn_named("event-gc", move || {
                let _actor = crate::obs::push_actor("event-gc");
                while !sd.wait_timeout(Duration::from_millis(250)) {
                    let _ = crate::kube::gc_expired(gc_client.as_ref(), &gc_metrics, 3600.0);
                }
            });
        }
        // Workers + the login node (which is also a kube worker, Fig. 1).
        // Each static kubelet gets its OWN shutdown handle (chained to
        // the testbed-wide one below) so chaos scenarios can kill one
        // node agent without taking the testbed down — see
        // [`Testbed::kill_kubelet`].
        let mut worker_names: Vec<String> =
            (0..config.kube_workers).map(|i| format!("kw{i:02}")).collect();
        worker_names.push("login".into());
        let worker_shutdowns: Arc<std::sync::Mutex<std::collections::HashMap<String, Shutdown>>> =
            Arc::new(std::sync::Mutex::new(std::collections::HashMap::new()));
        for name in &worker_names {
            let cri = SingularityCri::new(runtime.clone());
            let kubelet = Kubelet::register(
                &informers,
                name,
                Resources::cores(config.kube_cores, 64 << 30),
                &[],
                cri,
                fs.clone(),
                config.time_scale,
                metrics.clone(),
            )?;
            let sd = Shutdown::new();
            worker_shutdowns.lock().unwrap().insert(name.clone(), sd.clone());
            kubelet.start(Duration::from_millis(1), sd);
        }
        {
            // One chain thread fans the testbed shutdown out to every
            // still-alive static kubelet (mirrors KubeletProvisioner).
            let global = shutdown.clone();
            let nodes = worker_shutdowns.clone();
            crate::rt::spawn_named("tb-kubelet-chain", move || {
                global.wait();
                for sd in nodes.lock().unwrap().values() {
                    sd.trigger();
                }
            });
        }

        // ---- operators + virtual nodes ----
        let mut torque_bridge: Arc<dyn WlmBridge> = Arc::new(RedboxBridge::torque(
            RedboxClient::connect_retry(&socket, Duration::from_secs(5))?,
        ));
        operator::register_virtual_nodes(&api, torque_bridge.as_ref(), "torque")?;
        // Chaos seam: the operator talks to the WLM through the shimmed
        // bridge; node registration above used the clean one so boot
        // never depends on an injected fault schedule.
        if let Some(shim) = &config.wlm_shim {
            torque_bridge = shim(torque_bridge);
        }
        let torque_op = operator::torque_operator(torque_bridge, metrics.clone());
        Arc::new(ControllerRunner::new(client.clone(), torque_op, metrics.clone()))
            .start(informers.informer(KIND_TORQUEJOB), shutdown.clone());
        if slurm.is_some() {
            let mut slurm_bridge: Arc<dyn WlmBridge> = Arc::new(RedboxBridge::slurm(
                RedboxClient::connect_retry(&socket, Duration::from_secs(5))?,
            ));
            operator::register_virtual_nodes(&api, slurm_bridge.as_ref(), "slurm")?;
            if let Some(shim) = &config.wlm_shim {
                slurm_bridge = shim(slurm_bridge);
            }
            let slurm_op = operator::wlm_operator(slurm_bridge, metrics.clone());
            Arc::new(ControllerRunner::new(client.clone(), slurm_op, metrics.clone()))
                .start(informers.informer(KIND_SLURMJOB), shutdown.clone());
        }
        // Deployment controller (+ the operator's own service deployment,
        // "four Singularity containers … deployed by Kubernetes" §III-B).
        Arc::new(ControllerRunner::new(
            client.clone(),
            Arc::new(DeploymentController::new(&informers)),
            metrics.clone(),
        ))
        .start(informers.informer(KIND_DEPLOYMENT), shutdown.clone());
        if config.operator_deployment {
            // Apply, not create: a WAL-recovered boot already holds the
            // deployment (and its pods) from the previous run.
            api.apply(DeploymentController::build(
                "torque-operator",
                4,
                "torque-operator.sif",
                Resources::new(100, 64 << 20, 0),
            ))?;
        }

        // ---- elastic autoscaling (PR 3) -------------------------------
        // Kubelets feed the metrics pipeline unconditionally; the HPA
        // controller and cluster autoscaler only run when asked for.
        if let Some(ca_cfg) = config.autoscale.clone() {
            Arc::new(ControllerRunner::new(
                client.clone(),
                Arc::new(HpaController::new(
                    &informers,
                    Duration::from_millis(1),
                    metrics.clone(),
                )),
                metrics.clone(),
            ))
            .start(informers.informer(KIND_HPA), shutdown.clone());
            let provisioner: Arc<dyn NodeProvisioner> = Arc::new(KubeletProvisioner {
                informers: informers.clone(),
                runtime: runtime.clone(),
                fs: fs.clone(),
                node_capacity: ca_cfg.node_capacity,
                time_scale: config.time_scale,
                metrics: metrics.clone(),
                shutdown: shutdown.clone(),
                node_shutdowns: Arc::new(std::sync::Mutex::new(
                    std::collections::HashMap::new(),
                )),
                chain_started: std::sync::Once::new(),
            });
            ClusterAutoscaler::new(&informers, provisioner, ca_cfg, metrics.clone())
                .start(Duration::from_millis(2), shutdown.clone());
        }

        Ok(Testbed {
            api,
            pbs,
            slurm,
            fs,
            metrics,
            shutdown,
            images,
            redbox,
            socket,
            time_scale: config.time_scale,
            worker_shutdowns,
            owns_span_sink,
        })
    }

    pub fn socket(&self) -> &std::path::Path {
        &self.socket
    }

    /// A transport-agnostic client for this testbed's API server — the
    /// handle to build typed `Api<K>` views or hand to controllers.
    pub fn client(&self) -> Arc<dyn ApiClient> {
        self.api.client()
    }

    pub fn time_scale(&self) -> f64 {
        self.time_scale
    }

    /// Chaos lever (PR 10): kill one static worker's kubelet daemon. The
    /// Node object stays registered, the node's containers keep running
    /// unmanaged (orphaned), and nothing updates its pods' status again —
    /// the failure mode a real node agent crash leaves behind. Recovery
    /// is the caller's job (drain through the eviction subresource, then
    /// delete the Node). Returns false if no such live kubelet.
    pub fn kill_kubelet(&self, node: &str) -> bool {
        match self.worker_shutdowns.lock().unwrap().remove(node) {
            Some(sd) => {
                sd.trigger();
                true
            }
            None => false,
        }
    }

    /// `kubectl apply -f` for a manifest string; returns created names.
    pub fn kubectl_apply(&self, manifest: &str) -> Result<Vec<String>> {
        let objs = crate::kube::yaml::parse_manifest(manifest)?;
        let mut names = Vec::new();
        for obj in objs {
            let created = self.api.apply(obj)?;
            names.push(created.meta.name.clone());
        }
        Ok(names)
    }

    /// Wait until a TorqueJob/SlurmJob reaches a terminal phase.
    pub fn wait_wlm_job(&self, kind: &str, name: &str, timeout: Duration) -> Result<String> {
        let deadline = Instant::now() + timeout;
        loop {
            let obj = self.api.get(kind, name)?;
            let p = obj.status.opt_str("phase").unwrap_or("").to_string();
            if phase::terminal(&p) {
                return Ok(p);
            }
            if Instant::now() >= deadline {
                return Err(Error::wlm(format!("timeout waiting for {kind}/{name} (phase `{p}`)")));
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    pub fn wait_torquejob(&self, name: &str, timeout: Duration) -> Result<String> {
        self.wait_wlm_job(KIND_TORQUEJOB, name, timeout)
    }

    pub fn wait_slurmjob(&self, name: &str, timeout: Duration) -> Result<String> {
        self.wait_wlm_job(KIND_SLURMJOB, name, timeout)
    }

    /// Wait for a plain pod to finish.
    pub fn wait_pod(&self, name: &str, timeout: Duration) -> Result<KubeObject> {
        let deadline = Instant::now() + timeout;
        loop {
            let obj = self.api.get(KIND_POD, name)?;
            let p = PodPhase::parse(obj.status.opt_str("phase").unwrap_or(""));
            if p.terminal() {
                return Ok(obj);
            }
            if Instant::now() >= deadline {
                return Err(Error::wlm(format!("timeout waiting for pod {name}")));
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    /// Build a TorqueJob object (programmatic alternative to YAML).
    pub fn torquejob(name: &str, batch: &str, results_from: &str, mount: &str) -> KubeObject {
        WlmJobView::build_torquejob(name, batch, results_from, mount)
    }

    /// Stop every daemon and remove the socket.
    pub fn stop(mut self) {
        self.shutdown.trigger();
        self.redbox.stop();
        if self.owns_span_sink {
            // Release the span-log sink this testbed attached (WAL runs)
            // so a later boot on a different dir starts clean.
            crate::obs::set_span_sink(None);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn testbed_boots_and_runs_cow_job() {
        let tb = Testbed::start(TestbedConfig::default()).unwrap();
        // The paper's Fig. 3 manifest, via kubectl apply.
        let names = tb.kubectl_apply(crate::kube::yaml::COW_JOB_YAML).unwrap();
        assert_eq!(names, vec!["cow"]);
        let phase = tb.wait_torquejob("cow", Duration::from_secs(30)).unwrap();
        assert_eq!(phase, "completed");
        // Fig. 5 output staged to the mount dir.
        let out = tb.fs.read_string("$HOME/low.out").unwrap();
        assert!(out.contains("Moo"));
        tb.stop();
    }

    #[test]
    fn operator_deployment_creates_service_pods() {
        let mut cfg = TestbedConfig::default();
        cfg.operator_deployment = true;
        let tb = Testbed::start(cfg).unwrap();
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            let pods = tb.api.list(
                KIND_POD,
                &[("deployment".to_string(), "torque-operator".to_string())],
            );
            let running = pods
                .iter()
                .filter(|p| {
                    matches!(
                        PodPhase::parse(p.status.opt_str("phase").unwrap_or("")),
                        PodPhase::Running | PodPhase::Succeeded
                    )
                })
                .count();
            if running == 4 {
                break;
            }
            assert!(Instant::now() < deadline, "operator deployment never ready");
            std::thread::sleep(Duration::from_millis(5));
        }
        tb.stop();
    }

    /// Elastic layer smoke test through the real daemons: a loaded
    /// Deployment scales past the static workers, the cluster autoscaler
    /// provisions live pool kubelets, and the metrics pipeline serves
    /// NodeMetrics for every node.
    #[test]
    fn elastic_testbed_scales_deployment_onto_provisioned_nodes() {
        use crate::autoscale::{HpaView, KIND_NODEMETRICS, POOL_LABEL};
        let mut cfg = TestbedConfig::default();
        cfg.kube_workers = 1; // + login = 2 static workers x 2 cores
        cfg.kube_cores = 2;
        cfg.autoscale = Some(crate::autoscale::CaConfig {
            node_capacity: Resources::cores(2, 64 << 30),
            max_nodes: 2,
            // No shrink during the smoke test.
            scale_down_idle: Duration::from_secs(3600),
            ..Default::default()
        });
        let tb = Testbed::start(cfg).unwrap();
        // Long-running service payload (nominal 10 000s ≈ 10s real here).
        tb.images.push(SifImage::new(
            "svc-long.sif",
            Payload::Sleep { millis: 10_000_000 },
        ));
        let mut deploy = DeploymentController::build(
            "web",
            1,
            "svc-long.sif",
            Resources::new(1000, 64 << 20, 0),
        );
        deploy.spec.get_mut("template").unwrap().insert(
            "env",
            crate::encoding::Value::map().with("CPU_LOAD_MILLI", "1000"),
        );
        tb.api.create(deploy).unwrap();
        tb.api
            .create(HpaView::build("h", "web", 1, 6, 50, Duration::ZERO))
            .unwrap();
        // 6 x 1000m > 4000m static capacity: the pool must grow and every
        // replica must end up Running somewhere.
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let pods = tb.api.list(
                KIND_POD,
                &[("deployment".to_string(), "web".to_string())],
            );
            let running = pods
                .iter()
                .filter(|p| p.status.opt_str("phase") == Some("Running"))
                .count();
            let pool = tb
                .api
                .list(crate::kube::KIND_NODE, &[])
                .iter()
                .filter(|n| n.meta.label(POOL_LABEL).is_some())
                .count();
            if running == 6 && pool >= 1 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "elastic testbed never converged: {running} running, {pool} pool nodes"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        // The metrics pipeline published a NodeMetrics object for at
        // least one loaded node.
        let metrics_objs = tb.api.list(KIND_NODEMETRICS, &[]);
        assert!(!metrics_objs.is_empty(), "kubelets publish NodeMetrics");
        tb.stop();
    }

    #[test]
    fn slurm_side_runs_slurmjob() {
        let mut cfg = TestbedConfig::default();
        cfg.with_slurm = true;
        let tb = Testbed::start(cfg).unwrap();
        let mut obj = WlmJobView::build_torquejob(
            "scow",
            "#!/bin/sh\n#SBATCH --nodes=1\n#SBATCH -o $HOME/s.out\nsingularity run lolcow_latest.sif\n",
            "$HOME/s.out",
            "$HOME/sres/",
        );
        obj.kind = KIND_SLURMJOB.into();
        tb.api.create(obj).unwrap();
        let phase = tb.wait_slurmjob("scow", Duration::from_secs(30)).unwrap();
        assert_eq!(phase, "completed");
        assert!(tb.fs.read_string("$HOME/sres/s.out").unwrap().contains("Moo"));
        tb.stop();
    }
}
