//! API server: the front door of the Kubernetes cluster.
//!
//! In-process callers (scheduler, kubelets, controllers, operators) and
//! remote callers (the `hpcorc kubectl` CLI over the red-box socket) see
//! the *same* surface: both [`ApiServer`] and [`RemoteApi`] implement
//! [`ApiClient`], mirroring how the paper's login node hosts both the k8s
//! master and the Unix-socket bridge. The RPC service (`kube.Api/*`)
//! covers the full verb set including watch, so a controller written
//! against `Arc<dyn ApiClient>` runs unchanged on either side of the
//! socket.
//!
//! # The remote watch (ISSUE 5): server-push streaming frames
//!
//! `kube.Api/Watch` with `stream: true` is a **server-streaming** method
//! over red-box's multiplexed frame layer: the server subscribes to the
//! store's event feed and pushes each event as a `StreamItem`, plus
//! periodic `BOOKMARK` items when *other* kinds advance the store version
//! (so the client's bookmark never silently staleness-drifts), and a
//! `gone` `StreamEnd` when the requested bookmark has fallen out of the
//! retained history window — the 410-Gone signal. An idle stream
//! transmits **nothing**: no polls, no keepalives.
//!
//! [`RemoteApi::watch`] negotiates streaming by default and keeps the old
//! poll loop only as an explicit fallback ([`WatchConfig::force_poll`],
//! or a server that answers the poll shape). Either way stream loss
//! surfaces as the same ended-receiver reset signal, so `Reflector`
//! relist/epoch-bump machinery is transport-agnostic.

use super::api::{
    pdb_blocking, pdb_disruptions_allowed, requeue_evict_mutation, CrdView, KubeObject,
    PdbView, KIND_CUSTOMRESOURCEDEFINITION, KIND_POD, KIND_PODDISRUPTIONBUDGET,
};
use super::client::{ApiClient, BatchPatchItem, EvictionMode, ListOptions, ObjectList};
use super::scheme::SchemeRegistry;
use super::store::{Store, WatchEvent};
use crate::cluster::Metrics;
use crate::encoding::Value;
use crate::obs::AuditLog;
use crate::redbox::{RedboxClient, Reply, Service, StreamMsg, END_COMPLETE, END_GONE};
use crate::rt;
use crate::util::{Error, Result};
use std::collections::{BTreeMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Bounded attempts for retry-on-conflict loops (`update_status`, merge
/// patch) — shared by both transports so their failure behavior matches.
pub const MAX_CONFLICT_RETRIES: u32 = 16;

/// Default poll cadences for the *fallback* poll watch (see
/// [`WatchConfig`]): poll fast while events flow, back off toward the
/// idle max while nothing happens (an abandoned-but-undetectable receiver
/// then costs ~10 RPCs/s instead of 500).
const WATCH_POLL_PERIOD: Duration = Duration::from_millis(2);
const WATCH_POLL_IDLE_MAX: Duration = Duration::from_millis(100);

/// How often an *idle* streaming watch producer wakes to check whether
/// other kinds advanced the store version (and pushes a `BOOKMARK` item
/// if so). A fully idle store pushes nothing at all.
const WATCH_BOOKMARK_PERIOD: Duration = Duration::from_millis(200);

/// How a [`RemoteApi`] watch moves events across the socket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WatchMode {
    /// Server-push streaming frames: zero idle traffic, sub-poll latency.
    Streaming,
    /// Poll loop — the explicit fallback for servers without stream
    /// support.
    Poll,
}

/// Remote-watch tuning. The poll cadences used to be hardcoded (ISSUE 5
/// satellite); streaming is preferred whenever the server offers it.
#[derive(Debug, Clone)]
pub struct WatchConfig {
    /// Poll cadence while events are flowing (poll mode only).
    pub poll_active: Duration,
    /// Ceiling the poll backs off to while idle (poll mode only).
    pub poll_idle_max: Duration,
    /// Skip stream negotiation and always poll — the explicit old-server
    /// fallback (also what the parity/bench suites use to pin the mode).
    pub force_poll: bool,
}

impl Default for WatchConfig {
    fn default() -> WatchConfig {
        WatchConfig {
            poll_active: WATCH_POLL_PERIOD,
            poll_idle_max: WATCH_POLL_IDLE_MAX,
            force_poll: false,
        }
    }
}

/// A mutating-admission hook: runs on every object entering through the
/// create path (both `create` and the create arm of `apply`, local or
/// RPC), *before* the store assigns identity — the k8s mutating-webhook
/// shape. Hooks mutate in place and cannot reject (validation stays the
/// store's job); they must be cheap and idempotent.
pub type MutatingHook = Arc<dyn Fn(&mut KubeObject) + Send + Sync>;

/// The API server handle (cheap clone; shares the store).
#[derive(Clone)]
pub struct ApiServer {
    store: Store,
    metrics: Metrics,
    hooks: Arc<Mutex<Vec<MutatingHook>>>,
    audit: AuditLog,
    /// The server-owned kind registry: seeded from the process defaults,
    /// extended at runtime by CustomResourceDefinition create/apply.
    scheme: SchemeRegistry,
}

impl ApiServer {
    pub fn new(metrics: Metrics) -> ApiServer {
        let mut store = Store::new();
        store.set_metrics(metrics.clone());
        ApiServer {
            store,
            metrics,
            hooks: Arc::new(Mutex::new(Vec::new())),
            audit: AuditLog::new(),
            scheme: SchemeRegistry::with_defaults(),
        }
    }

    /// An API server whose store retains `cap` watch events (see
    /// [`Store::with_history_cap`]): size it above the largest write burst
    /// expected between watcher polls, or reflectors are forced into
    /// spurious 410-Gone relists.
    pub fn with_history_cap(metrics: Metrics, cap: usize) -> ApiServer {
        let mut store = Store::with_history_cap(cap);
        store.set_metrics(metrics.clone());
        ApiServer {
            store,
            metrics,
            hooks: Arc::new(Mutex::new(Vec::new())),
            audit: AuditLog::new(),
            scheme: SchemeRegistry::with_defaults(),
        }
    }

    /// An API server over a durability backend (PR 6): every commit is
    /// appended to the backend before it becomes visible, and opening
    /// over a previously-written [`super::persist::WalBackend`] directory
    /// recovers all objects, resource versions, and the server clock —
    /// clients cannot tell a recovered server from one that never died
    /// (watchers with pre-restart bookmarks even get delta replays from
    /// the recovered WAL tail).
    pub fn with_backend(
        metrics: Metrics,
        backend: Box<dyn super::persist::StoreBackend>,
        cap: usize,
    ) -> Result<ApiServer> {
        let mut store = Store::with_backend(backend, cap)?;
        store.set_metrics(metrics.clone());
        let api = ApiServer {
            store,
            metrics,
            hooks: Arc::new(Mutex::new(Vec::new())),
            audit: AuditLog::new(),
            scheme: SchemeRegistry::with_defaults(),
        };
        // Recovered CRD objects re-extend the scheme: a restarted server
        // serves every dynamically-registered kind its WAL remembers.
        for o in api.store.list(KIND_CUSTOMRESOURCEDEFINITION, &[]) {
            if let Ok(crd) = CrdView::from_object(&o) {
                let _ = api.scheme.register_crd(&crd);
            }
        }
        Ok(api)
    }

    /// The server-owned kind registry (grown by CRD create/apply).
    pub fn scheme(&self) -> &SchemeRegistry {
        &self.scheme
    }

    /// The server's audit trail (PR 8): every mutating verb appends one
    /// record; register it remotely via `obs::register(&redbox, metrics,
    /// api.audit_log().clone())`.
    pub fn audit_log(&self) -> &AuditLog {
        &self.audit
    }

    /// Register a mutating-admission hook (applied in registration order
    /// to every object entering through the create path). Registration is
    /// live: existing clones of this handle see the hook immediately.
    pub fn register_mutating_hook(&self, hook: MutatingHook) {
        self.hooks.lock().unwrap().push(hook);
    }

    fn admit_mutate(&self, obj: &mut KubeObject) {
        let hooks = self.hooks.lock().unwrap();
        if hooks.is_empty() {
            return;
        }
        for hook in hooks.iter() {
            hook(obj);
        }
        self.metrics.inc("kube.api.admission_mutations");
    }

    /// This server as a shared transport-agnostic client.
    pub fn client(&self) -> Arc<dyn ApiClient> {
        Arc::new(self.clone())
    }

    pub fn now_s(&self) -> f64 {
        self.store.now_s()
    }

    /// Stamp the active trace context and the server wall clock onto an
    /// object entering through the create path (PR 7). The annotations
    /// ride inside the object through store → WAL → watch → informer, so
    /// admission/scheduler/operator spans can rejoin the originating
    /// trace, and the scheduler can observe the create→bound SLO without
    /// sharing a monotonic clock with the creator.
    fn stamp_observability(&self, obj: &mut KubeObject) {
        if obj.meta.annotation(crate::obs::TRACE_ANNOTATION).is_none() {
            if let Some(ctx) = crate::obs::current() {
                obj.meta.set_annotation(crate::obs::TRACE_ANNOTATION, &ctx.to_wire());
            }
        }
        if obj.meta.annotation(crate::obs::CREATED_WALL_ANNOTATION).is_none() {
            let wall_ns = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap_or_default()
                .as_nanos() as u64;
            obj.meta.set_annotation(crate::obs::CREATED_WALL_ANNOTATION, &wall_ns.to_string());
        }
    }

    /// The GVK label value for a kind: the registered plural
    /// (`Pod` → `pods`), or the lowercased kind for unregistered CRDs —
    /// labels stay low-cardinality either way. Reads the *server's*
    /// registry, so dynamically-registered kinds label by their plural.
    fn gvk_label(&self, kind: &str) -> String {
        self.scheme.gvk_label(kind)
    }

    /// Canonicalize a user-facing kind alias through the server's
    /// registry (`po` → `Pod`, a CRD's plural/short name → its kind);
    /// unknown aliases pass through verbatim. This is what makes
    /// `kubectl get <alias>` of a *runtime-registered* kind work: the CLI
    /// cannot know server-side registrations, so the server resolves.
    fn canonical(&self, kind: &str) -> String {
        self.scheme.canonical_kind(kind).unwrap_or_else(|| kind.to_string())
    }

    /// Audit middleware (PR 8): every mutating verb funnels through here.
    /// Runs the body, then appends one [`crate::obs::AuditRecord`] —
    /// verb, object, thread-local actor, active trace id, outcome,
    /// latency — to the server's audit trail. Verb counters stay at the
    /// call sites (their success-vs-entry semantics predate the audit).
    fn audited<T>(
        &self,
        verb: &str,
        kind: &str,
        name: &str,
        body: impl FnOnce() -> Result<T>,
    ) -> Result<T> {
        let start = Instant::now();
        let res = body();
        let outcome = match &res {
            Ok(_) => "ok".to_string(),
            Err(e) => e.to_string(),
        };
        let trace = crate::obs::current().map(|ctx| format!("{:016x}", ctx.trace_id));
        self.audit.record(verb, kind, name, trace, outcome, start.elapsed().as_nanos() as u64);
        self.metrics.inc("kube.api.audit_records");
        res
    }

    /// CustomResourceDefinition serving (ISSUE 10): a CRD entering through
    /// create/apply extends the server's runtime scheme *before* it is
    /// stored — a malformed or conflicting CRD is rejected as `Invalid`
    /// and never becomes an object. Non-CRD kinds pass straight through.
    fn maybe_register_crd(&self, obj: &KubeObject) -> Result<()> {
        if obj.kind != KIND_CUSTOMRESOURCEDEFINITION {
            return Ok(());
        }
        let crd = CrdView::from_object(obj)
            .map_err(|e| Error::Api(crate::util::ApiError::Invalid(e.to_string())))?;
        self.scheme
            .register_crd(&crd)
            .map_err(|e| Error::Api(crate::util::ApiError::Invalid(e.to_string())))?;
        self.metrics.inc("kube.api.crds_registered");
        Ok(())
    }

    pub fn create(&self, mut obj: KubeObject) -> Result<KubeObject> {
        self.metrics.inc_with("kube.api.create", &[("gvk", &self.gvk_label(&obj.kind))]);
        let _span = crate::obs::span("apiserver", &format!("create {}/{}", obj.kind, obj.meta.name));
        let (kind, name) = (obj.kind.clone(), obj.meta.name.clone());
        self.audited("create", &kind, &name, move || {
            self.maybe_register_crd(&obj)?;
            self.admit_mutate(&mut obj);
            self.stamp_observability(&mut obj);
            self.store.create(obj)
        })
    }

    pub fn get(&self, kind: &str, name: &str) -> Result<KubeObject> {
        let kind = self.canonical(kind);
        self.metrics.inc_with("kube.api.get", &[("gvk", &self.gvk_label(&kind))]);
        self.store.get(&kind, name)
    }

    /// Full update (spec + status) with optimistic concurrency.
    pub fn update(&self, obj: KubeObject) -> Result<KubeObject> {
        self.metrics.inc_with("kube.api.update", &[("gvk", &self.gvk_label(&obj.kind))]);
        let _span = crate::obs::span("apiserver", &format!("update {}/{}", obj.kind, obj.meta.name));
        let (kind, name) = (obj.kind.clone(), obj.meta.name.clone());
        self.audited("update", &kind, &name, move || self.store.update(obj))
    }

    /// Bounded retry-on-conflict commit loop shared by `update_status` and
    /// `patch_merge`: fetch the latest object, apply `mutate`, commit;
    /// retry on conflict. Exhausting the attempts returns
    /// `ConflictExhausted`, not a plain conflict, so callers can tell
    /// pathological contention from a routine race.
    fn retry_on_conflict(
        &self,
        kind: &str,
        name: &str,
        metric: &'static str,
        mutate: impl Fn(&mut KubeObject),
    ) -> Result<KubeObject> {
        let _span = crate::obs::span("apiserver", &format!("{metric} {kind}/{name}"));
        let verb = metric.strip_prefix("kube.api.").unwrap_or(metric);
        self.audited(verb, kind, name, || {
            for _ in 0..MAX_CONFLICT_RETRIES {
                let mut obj = self.store.get(kind, name)?;
                mutate(&mut obj);
                match self.store.update(obj) {
                    Ok(o) => {
                        self.metrics.inc_with(metric, &[("gvk", &self.gvk_label(kind))]);
                        return Ok(o);
                    }
                    Err(e) if e.is_conflict() => continue,
                    Err(e) => return Err(e),
                }
            }
            Err(Error::conflict_exhausted(kind, name, MAX_CONFLICT_RETRIES))
        })
    }

    /// Status-subresource style update with retry-on-conflict (see
    /// [`ApiServer::retry_on_conflict`] for the loop semantics).
    pub fn update_status(
        &self,
        kind: &str,
        name: &str,
        f: impl Fn(&mut KubeObject),
    ) -> Result<KubeObject> {
        self.retry_on_conflict(kind, name, "kube.api.update_status", f)
    }

    /// JSON-merge-patch over spec/status/labels/annotations, committed with
    /// the same bounded retry-on-conflict loop as `update_status`.
    pub fn patch_merge(&self, kind: &str, name: &str, patch: &Value) -> Result<KubeObject> {
        self.retry_on_conflict(kind, name, "kube.api.patch", |obj| {
            apply_merge_patch(obj, patch)
        })
    }

    /// Batched status commits (PR 9): every item applies inside ONE
    /// store lock section ([`Store::update_batch`]), so no concurrent
    /// writer can slip between two binds — the retry-on-conflict loop is
    /// unnecessary by construction. Results are per item and positional:
    /// a NotFound on one bind never poisons its batch-mates. Each item
    /// still appends its own `update_status` audit record, so the trail
    /// reads like N single calls apart from timing.
    pub fn update_status_batch(&self, items: &[BatchPatchItem]) -> Vec<Result<KubeObject>> {
        let _span =
            crate::obs::span("apiserver", &format!("update_status_batch x{}", items.len()));
        self.metrics.inc("kube.api.update_status_batch");
        let start = Instant::now();
        let keys: Vec<(String, String)> =
            items.iter().map(|it| (it.kind.clone(), it.name.clone())).collect();
        let results =
            self.store.update_batch(&keys, &|i, obj| apply_merge_patch(obj, &items[i].patch));
        // Latency attribution: the lock section is shared, so each record
        // carries the per-item average rather than the whole batch.
        let latency = start.elapsed().as_nanos() as u64 / items.len().max(1) as u64;
        let trace = crate::obs::current().map(|ctx| format!("{:016x}", ctx.trace_id));
        for (it, res) in items.iter().zip(&results) {
            let outcome = match res {
                Ok(_) => {
                    self.metrics.inc_with(
                        "kube.api.update_status",
                        &[("gvk", &self.gvk_label(&it.kind))],
                    );
                    "ok".to_string()
                }
                Err(e) => e.to_string(),
            };
            self.audit.record(
                "update_status",
                &it.kind,
                &it.name,
                trace.clone(),
                outcome,
                latency,
            );
            self.metrics.inc("kube.api.audit_records");
        }
        results
    }

    /// Delete with transitive cascade: the full ownership closure of the
    /// object (children, grandchildren, ...) is deleted, children before
    /// parents. A visited set makes ownership cycles terminate instead of
    /// recursing forever.
    pub fn delete(&self, kind: &str, name: &str) -> Result<KubeObject> {
        let kind = &self.canonical(kind);
        self.metrics.inc_with("kube.api.delete", &[("gvk", &self.gvk_label(kind))]);
        let _span = crate::obs::span("apiserver", &format!("delete {kind}/{name}"));
        self.audited("delete", kind, name, || {
            // The root must exist before the cascade walks anything: deleting a
            // nonexistent name must be a NotFound no-op, not a purge of objects
            // that happen to name it as owner.
            self.store.get(kind, name)?;
            let all = self.store.list_all();
            let root = (kind.to_string(), name.to_string());
            let mut visited: HashSet<(String, String)> = HashSet::new();
            visited.insert(root.clone());
            let mut order: Vec<(String, String)> = Vec::new();
            let mut frontier = vec![root];
            while let Some((pk, pn)) = frontier.pop() {
                for o in &all {
                    let owned =
                        o.meta.owner.as_ref().map(|(k, n)| *k == pk && *n == pn).unwrap_or(false);
                    if owned {
                        let key = (o.kind.clone(), o.meta.name.clone());
                        if visited.insert(key.clone()) {
                            order.push(key.clone());
                            frontier.push(key);
                        }
                    }
                }
            }
            // Discovery order puts ancestors first; delete in reverse so every
            // child is gone before its owner.
            for (k, n) in order.iter().rev() {
                if self.store.delete(k, n).is_ok() {
                    self.metrics.inc("kube.api.cascade_deleted");
                }
            }
            self.store.delete(kind, name)
        })
    }

    /// List objects of a kind filtered by a label selector (all pairs must
    /// match). Shorthand for [`ApiServer::list_opts`] kept for in-process
    /// callers and tests.
    pub fn list(&self, kind: &str, selector: &[(String, String)]) -> Vec<KubeObject> {
        self.metrics.inc_with("kube.api.list", &[("gvk", &self.gvk_label(kind))]);
        self.store.list(kind, selector)
    }

    /// Evict a pod through the `pods/eviction` subresource, enforcing
    /// every matching PodDisruptionBudget (see [`ApiClient::evict`] for
    /// the caller contract). All three reads plus the verdict happen
    /// against the live store here, so this override is authoritative
    /// where the trait's composed default is merely consistent. After the
    /// attempt — allowed or blocked — the matched budgets' status
    /// (`disruptionsAllowed`, `currentHealthy`, `expectedPods`) is
    /// refreshed so `kubectl get pdb` shows live numbers.
    pub fn evict(&self, name: &str, mode: &EvictionMode) -> Result<KubeObject> {
        self.metrics.inc_with("kube.api.evict", &[("gvk", &self.gvk_label(KIND_POD))]);
        let _span = crate::obs::span("apiserver", &format!("evict pod/{name}"));
        let res = self.audited("evict", KIND_POD, name, || {
            let victim = self.store.get(KIND_POD, name)?;
            let pods = self.store.list(KIND_POD, &[]);
            let pdbs = self.store.list(KIND_PODDISRUPTIONBUDGET, &[]);
            if let Some(budget) = pdb_blocking(&pdbs, &pods, &victim) {
                self.metrics.inc("kube.api.evictions_blocked");
                return Err(Error::disruption_budget_exceeded(KIND_POD, name, budget));
            }
            match mode {
                EvictionMode::Delete => self.store.delete(KIND_POD, name),
                EvictionMode::Requeue { gate } => {
                    for _ in 0..MAX_CONFLICT_RETRIES {
                        let mut obj = self.store.get(KIND_POD, name)?;
                        requeue_evict_mutation(&mut obj, gate);
                        match self.store.update(obj) {
                            Ok(o) => return Ok(o),
                            Err(e) if e.is_conflict() => continue,
                            Err(e) => return Err(e),
                        }
                    }
                    Err(Error::conflict_exhausted(KIND_POD, name, MAX_CONFLICT_RETRIES))
                }
            }
        });
        self.refresh_pdb_status();
        res
    }

    /// Recompute `status.disruptionsAllowed` (plus the health counters)
    /// for every PodDisruptionBudget. Server bookkeeping, not a client
    /// verb: writes go straight to the store, only when the numbers
    /// actually changed, and a racing conflict is simply skipped — the
    /// next eviction attempt refreshes again.
    fn refresh_pdb_status(&self) {
        let pdbs = self.store.list(KIND_PODDISRUPTIONBUDGET, &[]);
        if pdbs.is_empty() {
            return;
        }
        let pods = self.store.list(KIND_POD, &[]);
        for mut obj in pdbs {
            let Ok(view) = PdbView::from_object(&obj) else { continue };
            let matching: Vec<&KubeObject> =
                pods.iter().filter(|p| view.matches(&p.meta.labels)).collect();
            let healthy = matching
                .iter()
                .filter(|p| p.status.opt_str("phase").unwrap_or("Pending") == "Running")
                .count() as u64;
            let allowed = pdb_disruptions_allowed(&view, &pods).max(0) as u64;
            let fresh = Value::map()
                .with("disruptionsAllowed", allowed)
                .with("currentHealthy", healthy)
                .with("expectedPods", matching.len() as u64);
            if obj.status != fresh {
                obj.status = fresh;
                let _ = self.store.update(obj);
            }
        }
    }

    /// Full list API: label + field selectors, a freshness floor, and
    /// name-cursor paging (`limit`/`continue`).
    pub fn list_opts(&self, kind: &str, opts: &ListOptions) -> Result<ObjectList> {
        let kind = &self.canonical(kind);
        self.metrics.inc_with("kube.api.list", &[("gvk", &self.gvk_label(kind))]);
        // Version snapshot BEFORE listing: a write racing the list may then
        // show up both in items and in a subsequent watch replay from this
        // version — duplicates are fine (consumers are level-triggered),
        // missed events are not.
        let resource_version = self.store.current_version();
        if let Some(min) = opts.min_resource_version {
            if resource_version < min {
                return Err(Error::conflict(kind, format!("list@{min}")));
            }
        }
        // Delta mode: answer from the shard's watch history instead of the
        // object set — changed objects plus deleted names since the floor.
        // Best-effort: when the floor fell out of the retained window the
        // answer silently degrades to a full list (`delta: false`).
        if let Some(floor) = opts.delta_floor {
            if let Some(list) = self.delta_list(kind, floor, opts) {
                return Ok(list);
            }
        }
        // Store order is (kind, name) — already the stable name order the
        // continue cursor pages through.
        let mut items: Vec<KubeObject> = self
            .store
            .list(kind, &opts.label_selector)
            .into_iter()
            .filter(|o| opts.matches_fields(o))
            .collect();
        if let Some(token) = &opts.continue_token {
            items.retain(|o| o.meta.name.as_str() > token.as_str());
        }
        let mut continue_token = None;
        if let Some(limit) = opts.limit {
            if limit > 0 && items.len() > limit {
                items.truncate(limit);
                continue_token = items.last().map(|o| o.meta.name.clone());
            }
        }
        Ok(ObjectList::full(self.now_s(), resource_version, items, continue_token))
    }

    /// Serve a delta list from the shard's retained watch history, or
    /// `None` when the floor is out of window (caller falls back to a full
    /// list). Events coalesce per name — only the final state of each
    /// object since the floor ships, with deletions as bare names.
    fn delta_list(&self, kind: &str, floor: u64, opts: &ListOptions) -> Option<ObjectList> {
        let (rv, events, reset) = self.store.events_since(Some(kind), floor);
        if reset {
            return None;
        }
        self.metrics.inc("kube.api.delta_list");
        // Last event per name wins; a name that reappears after a delete
        // leaves the deleted set again.
        let mut latest: BTreeMap<String, WatchEvent> = BTreeMap::new();
        for ev in events {
            let name = match &ev {
                WatchEvent::Added(o) | WatchEvent::Modified(o) | WatchEvent::Deleted(o) => {
                    o.meta.name.clone()
                }
            };
            latest.insert(name, ev);
        }
        let mut items = Vec::new();
        let mut deleted = Vec::new();
        for (name, ev) in latest {
            match ev {
                WatchEvent::Added(o) | WatchEvent::Modified(o) => {
                    if opts.matches(&o) {
                        items.push(o);
                    }
                }
                WatchEvent::Deleted(_) => deleted.push(name),
            }
        }
        Some(ObjectList {
            server_s: self.now_s(),
            resource_version: rv,
            items,
            continue_token: None,
            delta: true,
            deleted,
        })
    }

    pub fn current_version(&self) -> u64 {
        self.store.current_version()
    }

    pub fn watch(&self, kind: Option<&str>, from_version: u64) -> Receiver<WatchEvent> {
        self.metrics.inc("kube.api.watch");
        self.store.watch(kind, from_version)
    }

    /// Watch with the atomic 410 verdict (see [`Store::try_watch`]): the
    /// streaming RPC path uses this to answer a stale bookmark with an
    /// explicit `gone` StreamEnd instead of a silently-ended stream.
    pub fn try_watch(
        &self,
        kind: Option<&str>,
        from_version: u64,
    ) -> (u64, Option<Receiver<WatchEvent>>) {
        self.metrics.inc("kube.api.watch");
        self.store.try_watch(kind, from_version)
    }

    /// One-shot watch replay (the RPC transport's poll primitive). The
    /// third element is the 410-Gone-style reset flag: `from_version` fell
    /// out of the retained history window and the caller must relist.
    pub fn events_since(
        &self,
        kind: Option<&str>,
        from_version: u64,
    ) -> (u64, Vec<WatchEvent>, bool) {
        self.metrics.inc("kube.api.watch_poll");
        self.store.events_since(kind, from_version)
    }

    /// `kubectl apply`: create, or update (spec-merge) when it exists.
    /// The create arm runs the mutating-admission hooks — an applied
    /// manifest is as much an object birth as a direct create.
    pub fn apply(&self, mut obj: KubeObject) -> Result<KubeObject> {
        self.metrics.inc_with("kube.api.apply", &[("gvk", &self.gvk_label(&obj.kind))]);
        let _span = crate::obs::span("apiserver", &format!("apply {}/{}", obj.kind, obj.meta.name));
        let (kind, name) = (obj.kind.clone(), obj.meta.name.clone());
        self.audited("apply", &kind, &name, move || {
            self.maybe_register_crd(&obj)?;
            match self.store.get(&obj.kind, &obj.meta.name) {
            Ok(existing) => {
                let mut merged = existing.clone();
                merged.spec = obj.spec;
                merged.meta.labels = obj.meta.labels;
                merged.meta.annotations = obj.meta.annotations;
                // An applied manifest replaces annotations wholesale;
                // carry the observability stamps forward so a re-apply
                // does not orphan the object from its originating trace.
                for key in [crate::obs::TRACE_ANNOTATION, crate::obs::CREATED_WALL_ANNOTATION] {
                    if merged.meta.annotation(key).is_none() {
                        if let Some(v) = existing.meta.annotation(key) {
                            let v = v.to_string();
                            merged.meta.set_annotation(key, &v);
                        }
                    }
                }
                self.store.update(merged)
            }
            Err(e) if e.is_not_found() => {
                self.admit_mutate(&mut obj);
                self.stamp_observability(&mut obj);
                self.store.create(obj)
            }
            Err(e) => Err(e),
            }
        })
    }

    /// Expose this API over a red-box service registry name `kube.Api`.
    pub fn rpc_service(&self) -> Arc<dyn Service> {
        Arc::new(ApiService { api: self.clone() })
    }
}

impl ApiClient for ApiServer {
    fn create(&self, obj: KubeObject) -> Result<KubeObject> {
        ApiServer::create(self, obj)
    }
    fn get(&self, kind: &str, name: &str) -> Result<KubeObject> {
        ApiServer::get(self, kind, name)
    }
    fn update(&self, obj: KubeObject) -> Result<KubeObject> {
        ApiServer::update(self, obj)
    }
    fn update_status(
        &self,
        kind: &str,
        name: &str,
        f: &dyn Fn(&mut KubeObject),
    ) -> Result<KubeObject> {
        ApiServer::update_status(self, kind, name, f)
    }
    fn patch_merge(&self, kind: &str, name: &str, patch: &Value) -> Result<KubeObject> {
        ApiServer::patch_merge(self, kind, name, patch)
    }
    fn update_status_batch(
        &self,
        items: &[BatchPatchItem],
    ) -> Result<Vec<Result<KubeObject>>> {
        Ok(ApiServer::update_status_batch(self, items))
    }
    fn delete(&self, kind: &str, name: &str) -> Result<KubeObject> {
        ApiServer::delete(self, kind, name)
    }
    fn evict(&self, name: &str, mode: &EvictionMode) -> Result<KubeObject> {
        ApiServer::evict(self, name, mode)
    }
    fn apply(&self, obj: KubeObject) -> Result<KubeObject> {
        ApiServer::apply(self, obj)
    }
    fn list(&self, kind: &str, opts: &ListOptions) -> Result<ObjectList> {
        self.list_opts(kind, opts)
    }
    fn watch(&self, kind: Option<&str>, from_version: u64) -> Result<Receiver<WatchEvent>> {
        // 410-Gone parity with the remote transport lives in Store::watch
        // (checked under the store lock), so inherent and trait callers
        // get identical semantics.
        Ok(ApiServer::watch(self, kind, from_version))
    }
    fn server_time_s(&self) -> Result<f64> {
        Ok(self.now_s())
    }
}

/// Recursive JSON merge patch (RFC 7386): maps merge key-wise, `null`
/// removes a key, scalars and sequences replace. A map patch landing on a
/// non-map target replaces it with a fresh map merged from the patch, so
/// `null` members are stripped rather than stored literally.
fn merge_value(dst: &mut Value, patch: &Value) {
    let Some(entries) = patch.as_map() else {
        *dst = patch.clone();
        return;
    };
    if dst.as_map().is_none() {
        *dst = Value::map();
    }
    for (k, pv) in entries {
        if pv.is_null() {
            dst.remove(k);
        } else if pv.as_map().is_some() {
            if dst.get(k).map(|v| v.as_map().is_none()).unwrap_or(true) {
                dst.insert(k, Value::map());
            }
            merge_value(dst.get_mut(k).unwrap(), pv);
        } else {
            dst.insert(k, pv.clone());
        }
    }
}

fn merge_str_pairs(pairs: &mut Vec<(String, String)>, patch: &Value) {
    let Some(entries) = patch.as_map() else { return };
    for (k, v) in entries {
        if v.is_null() {
            pairs.retain(|(pk, _)| pk != k);
            continue;
        }
        let val = v.as_str().map(String::from).unwrap_or_else(|| v.to_string());
        match pairs.iter_mut().find(|(pk, _)| pk == k) {
            Some((_, slot)) => *slot = val,
            None => pairs.push((k.clone(), val)),
        }
    }
}

fn apply_merge_patch(obj: &mut KubeObject, patch: &Value) {
    if let Some(p) = patch.get("spec") {
        merge_value(&mut obj.spec, p);
    }
    if let Some(p) = patch.get("status") {
        merge_value(&mut obj.status, p);
    }
    if let Some(meta) = patch.get("metadata") {
        if let Some(labels) = meta.get("labels") {
            merge_str_pairs(&mut obj.meta.labels, labels);
        }
        if let Some(ann) = meta.get("annotations") {
            merge_str_pairs(&mut obj.meta.annotations, ann);
        }
    }
}

struct ApiService {
    api: ApiServer,
}

impl ApiService {
    /// The server-streaming Watch: subscribe to the store's event feed
    /// and push every event as a stream item. A stale bookmark answers
    /// with an immediate `gone` StreamEnd (410). While the watched kind
    /// is idle but *other* kinds move the store version, periodic
    /// `BOOKMARK` items keep the client's bookmark fresh; a fully idle
    /// store pushes nothing at all.
    fn watch_stream_reply(&self, body: &Value) -> Reply {
        let kind = body.opt_str("kind").map(String::from);
        let from = body.opt_int("fromVersion").unwrap_or(0) as u64;
        self.api.metrics.inc("kube.api.watch_stream");
        let (rv, maybe_rx) = self.api.try_watch(kind.as_deref(), from);
        let initial = Value::map().with("streaming", true).with("resourceVersion", rv);
        match maybe_rx {
            // 410: the bookmark predates retained history. End at once —
            // the client surfaces the reset and its consumer relists.
            None => Reply::stream(initial, |sink| sink.end(END_GONE)),
            Some(rx) => {
                let api = self.api.clone();
                Reply::stream(initial, move |mut sink| {
                    // Highest version the client is known to have seen.
                    let mut last = rv;
                    loop {
                        match rx.recv_timeout(WATCH_BOOKMARK_PERIOD) {
                            Ok(ev) => {
                                last = last.max(ev.object().meta.resource_version);
                                if !sink.item(ev.encode()) {
                                    return; // cancelled / connection gone
                                }
                            }
                            Err(RecvTimeoutError::Timeout) => {
                                if sink.is_cancelled() {
                                    return;
                                }
                                let v = api.current_version();
                                if v > last {
                                    last = v;
                                    let bookmark = Value::map()
                                        .with("type", "BOOKMARK")
                                        .with("resourceVersion", v);
                                    if !sink.item(bookmark) {
                                        return;
                                    }
                                }
                            }
                            Err(RecvTimeoutError::Disconnected) => {
                                sink.end(END_COMPLETE);
                                return;
                            }
                        }
                    }
                })
            }
        }
    }
}

impl Service for ApiService {
    fn call(&self, method: &str, body: &Value) -> Result<Value> {
        match method {
            "Create" => Ok(self.api.create(KubeObject::decode(body)?)?.encode()),
            "Apply" => Ok(self.api.apply(KubeObject::decode(body)?)?.encode()),
            "Update" => Ok(self.api.update(KubeObject::decode(body)?)?.encode()),
            "Get" => {
                let o = self.api.get(body.req_str("kind")?, body.req_str("name")?)?;
                Ok(o.encode())
            }
            "Patch" => {
                let o = self.api.patch_merge(
                    body.req_str("kind")?,
                    body.req_str("name")?,
                    body.req("patch")?,
                )?;
                Ok(o.encode())
            }
            "Delete" => {
                let o = self.api.delete(body.req_str("kind")?, body.req_str("name")?)?;
                Ok(o.encode())
            }
            "Evict" => {
                let mode = EvictionMode::from_value(body)?;
                let o = self.api.evict(body.req_str("name")?, &mode)?;
                Ok(o.encode())
            }
            "UpdateStatusBatch" => {
                let items = body
                    .get("items")
                    .and_then(Value::as_seq)
                    .map(|s| {
                        s.iter().map(BatchPatchItem::from_value).collect::<Result<Vec<_>>>()
                    })
                    .transpose()?
                    .unwrap_or_default();
                let results = self.api.update_status_batch(&items);
                // Per-item results ride inside a successful reply: an
                // `object` member on success, a structured `error` detail
                // (same encoding the envelope uses) on failure.
                Ok(Value::map().with(
                    "results",
                    Value::Seq(
                        results
                            .iter()
                            .map(|r| match r {
                                Ok(o) => Value::map().with("object", o.encode()),
                                Err(e) => Value::map().with("error", e.encode_wire()),
                            })
                            .collect(),
                    ),
                ))
            }
            "List" => {
                let kind = body.req_str("kind")?;
                let opts = ListOptions::from_value(body);
                let list = self.api.list_opts(kind, &opts)?;
                let mut resp = Value::map()
                    .with("serverSeconds", list.server_s)
                    .with("resourceVersion", list.resource_version)
                    .with(
                        "items",
                        Value::Seq(list.items.iter().map(|o| o.encode()).collect()),
                    );
                if let Some(token) = &list.continue_token {
                    resp.insert("continue", token.clone());
                }
                if list.delta {
                    resp.insert("delta", true);
                    resp.insert(
                        "deleted",
                        Value::Seq(list.deleted.iter().map(|n| n.as_str().into()).collect()),
                    );
                }
                Ok(resp)
            }
            "Watch" => {
                let kind = body.opt_str("kind");
                let from = body.opt_int("fromVersion").unwrap_or(0) as u64;
                let (rv, events, reset) = self.api.events_since(kind, from);
                Ok(Value::map()
                    .with("resourceVersion", rv)
                    .with("reset", reset)
                    .with(
                        "events",
                        Value::Seq(events.iter().map(WatchEvent::encode).collect()),
                    ))
            }
            "ServerTime" => Ok(Value::map().with("serverSeconds", self.api.now_s())),
            other => Err(Error::rpc(format!("kube.Api has no method `{other}`"))),
        }
    }

    /// Streaming-capable dispatch: `Watch` with `stream: true` becomes a
    /// server stream; everything else (including the poll-shaped `Watch`
    /// kept for old clients) stays unary.
    fn call_full(&self, method: &str, body: &Value) -> Result<Reply> {
        if method == "Watch" && body.opt_bool("stream") == Some(true) {
            return Ok(self.watch_stream_reply(body));
        }
        self.call(method, body).map(Reply::Unary)
    }
}

/// Client-side mirror of the RPC surface: [`ApiClient`] over a red-box
/// socket. Error *types* survive the hop: the red-box envelope carries a
/// structured detail ([`crate::util::Error::encode_wire`]) that
/// `RedboxClient` decodes back into the exact variant, so a remote
/// caller's `is_not_found()`/`is_conflict()` behave like an in-process
/// caller's.
///
/// Watch is **push-based**: `watch()` opens a server stream on the shared
/// multiplexed connection and a demux thread feeds the returned channel —
/// an idle watch transmits nothing. Servers that answer the poll shape
/// (no `streaming: true` in the response) fall back to the poll loop, as
/// does [`WatchConfig::force_poll`]. In both modes the stream/poll thread
/// ends — and the receiver observes the hangup, the reset signal — on
/// server loss, a 410-Gone end, or a dropped receiver.
pub struct RemoteApi {
    client: RedboxClient,
    watch_cfg: WatchConfig,
    /// Mode of the most recently opened watch (parity tests print this).
    last_watch_mode: Mutex<Option<WatchMode>>,
    /// Highest `BOOKMARK` resourceVersion observed on any streaming
    /// watch — proves idle bookmark frames keep the client current.
    watch_bookmark: Arc<AtomicU64>,
}

impl RemoteApi {
    pub fn new(client: RedboxClient) -> RemoteApi {
        RemoteApi {
            client,
            watch_cfg: WatchConfig::default(),
            last_watch_mode: Mutex::new(None),
            watch_bookmark: Arc::new(AtomicU64::new(0)),
        }
    }

    pub fn connect(path: impl AsRef<std::path::Path>) -> Result<RemoteApi> {
        Ok(RemoteApi::new(RedboxClient::connect(path)?))
    }

    /// Override the watch tuning (poll cadences / forced poll fallback).
    pub fn with_watch_config(mut self, cfg: WatchConfig) -> RemoteApi {
        self.watch_cfg = cfg;
        self
    }

    /// Which transport mode the most recent `watch()` negotiated.
    pub fn last_watch_mode(&self) -> Option<WatchMode> {
        *self.last_watch_mode.lock().unwrap()
    }

    /// Highest bookmark version pushed by any streaming watch so far.
    pub fn watch_bookmark(&self) -> u64 {
        self.watch_bookmark.load(Ordering::Relaxed)
    }

    fn obj_call(&self, method: &str, body: Value) -> Result<KubeObject> {
        KubeObject::decode(&self.client.call(&format!("kube.Api/{method}"), body)?)
    }

    /// Try the streaming watch. `Ok(None)` = the server answered the poll
    /// shape (no stream support): caller falls back. Transport errors
    /// propagate so reflectors retry like any other failed watch.
    fn watch_streaming(
        &self,
        kind: Option<&str>,
        from_version: u64,
    ) -> Result<Option<Receiver<WatchEvent>>> {
        let mut body = Value::map().with("fromVersion", from_version).with("stream", true);
        if let Some(k) = kind {
            body.insert("kind", k);
        }
        let (initial, stream) = self.client.open_stream("kube.Api/Watch", body)?;
        if initial.opt_bool("streaming") != Some(true) {
            return Ok(None); // old server: it replayed the poll shape
        }
        let (tx, rx) = channel();
        let bookmark = self.watch_bookmark.clone();
        rt::spawn_named("kube-remote-watch-stream", move || loop {
            match stream.recv() {
                Ok(StreamMsg::Item(v)) => {
                    if v.opt_str("type") == Some("BOOKMARK") {
                        if let Some(rv) = v.opt_int("resourceVersion") {
                            bookmark.fetch_max(rv as u64, Ordering::Relaxed);
                        }
                        continue; // bookmarks never reach the consumer
                    }
                    match WatchEvent::decode(&v) {
                        Ok(ev) => {
                            if tx.send(ev).is_err() {
                                return; // receiver dropped: unsubscribes
                            }
                        }
                        // Undecodable event (version skew): end the
                        // stream so the consumer relists instead of
                        // silently losing it.
                        Err(_) => return,
                    }
                }
                // Explicit end (gone / complete / cancelled) and
                // connection loss both surface identically: the dropped
                // sender is the reset signal consumers already handle.
                Ok(StreamMsg::End(_)) | Err(_) => return,
            }
        });
        Ok(Some(rx))
    }

    /// The legacy poll loop, kept as the explicit fallback. Cadences come
    /// from [`WatchConfig`] instead of hardcoded constants.
    fn watch_poll(
        &self,
        kind: Option<&str>,
        from_version: u64,
    ) -> Result<Receiver<WatchEvent>> {
        let (tx, rx) = channel();
        // Dedicated connection so the poll loop never competes with this
        // handle's request traffic on very old servers.
        let client = RedboxClient::connect(self.client.path())?;
        let kind = kind.map(String::from);
        let mut from = from_version;
        let cfg = self.watch_cfg.clone();
        let mut period = cfg.poll_active;
        rt::spawn_named("kube-remote-watch", move || loop {
            let mut body = Value::map().with("fromVersion", from);
            if let Some(k) = &kind {
                body.insert("kind", k.clone());
            }
            let resp = match client.call("kube.Api/Watch", body) {
                Ok(v) => v,
                // Server gone: end of stream; the receiver observes the
                // hangup exactly as it would a dropped local watcher.
                Err(_) => return,
            };
            // 410 Gone: the bookmark fell out of the server's retained
            // history, so events may be lost. End the stream — consumers
            // (e.g. ControllerRunner) respond by relisting + rewatching.
            if resp.opt_bool("reset").unwrap_or(false) {
                return;
            }
            if let Some(rv) = resp.opt_int("resourceVersion") {
                let rv = rv as u64;
                // Server version below our bookmark: the server restarted
                // with a fresh store. Filtering by `> from` would silently
                // drop everything until it caught up — end the stream so
                // consumers relist instead.
                if rv < from {
                    return;
                }
                from = rv;
            }
            let events = resp.get("events").and_then(Value::as_seq).unwrap_or(&[]);
            let drained = !events.is_empty();
            for ev_v in events {
                match WatchEvent::decode(ev_v) {
                    Ok(ev) => {
                        if tx.send(ev).is_err() {
                            return; // receiver dropped
                        }
                    }
                    // Undecodable event (client/server version skew): the
                    // bookmark already moved past it, so end the stream —
                    // consumers relist instead of silently losing it.
                    Err(_) => return,
                }
            }
            // Backoff invariant (audited for ISSUE-2): any event batch
            // snaps the next poll back to the active cadence; only empty
            // polls back off (doubling toward the idle max). The server
            // replays *every* event since the bookmark in a single
            // response, so one active-cadence poll fully drains a burst
            // that accumulated while backed off — and every poll sleeps
            // at least the active period, keeping a sustained stream
            // paced instead of becoming a busy RPC loop.
            period = if drained {
                cfg.poll_active
            } else {
                (period * 2).min(cfg.poll_idle_max)
            };
            std::thread::sleep(period);
        });
        Ok(rx)
    }
}

impl ApiClient for RemoteApi {
    fn create(&self, obj: KubeObject) -> Result<KubeObject> {
        self.obj_call("Create", obj.encode())
    }

    fn get(&self, kind: &str, name: &str) -> Result<KubeObject> {
        self.obj_call("Get", Value::map().with("kind", kind).with("name", name))
    }

    fn update(&self, obj: KubeObject) -> Result<KubeObject> {
        self.obj_call("Update", obj.encode())
    }

    /// Client-side retry loop (closures cannot cross the socket), with the
    /// same attempt bound and exhaustion error as the in-process server.
    fn update_status(
        &self,
        kind: &str,
        name: &str,
        f: &dyn Fn(&mut KubeObject),
    ) -> Result<KubeObject> {
        for _ in 0..MAX_CONFLICT_RETRIES {
            let mut obj = ApiClient::get(self, kind, name)?;
            f(&mut obj);
            match ApiClient::update(self, obj) {
                Ok(o) => return Ok(o),
                Err(e) if e.is_conflict() => continue,
                Err(e) => return Err(e),
            }
        }
        Err(Error::conflict_exhausted(kind, name, MAX_CONFLICT_RETRIES))
    }

    fn patch_merge(&self, kind: &str, name: &str, patch: &Value) -> Result<KubeObject> {
        self.obj_call(
            "Patch",
            Value::map().with("kind", kind).with("name", name).with("patch", patch.clone()),
        )
    }

    /// The whole batch crosses the socket as ONE `UpdateStatusBatch` RPC;
    /// per-item errors come back as structured details and decode into
    /// the exact [`Error`] variant an in-process caller would see.
    fn update_status_batch(
        &self,
        items: &[BatchPatchItem],
    ) -> Result<Vec<Result<KubeObject>>> {
        let body = Value::map()
            .with("items", Value::Seq(items.iter().map(BatchPatchItem::to_value).collect()));
        let v = self.client.call("kube.Api/UpdateStatusBatch", body)?;
        let results = v
            .get("results")
            .and_then(Value::as_seq)
            .map(|s| {
                s.iter()
                    .map(|r| match r.get("object") {
                        Some(o) => KubeObject::decode(o),
                        None => Err(r
                            .get("error")
                            .and_then(Error::decode_wire)
                            .unwrap_or_else(|| {
                                Error::rpc("UpdateStatusBatch result had neither object nor error")
                            })),
                    })
                    .collect()
            })
            .unwrap_or_default();
        Ok(results)
    }

    fn delete(&self, kind: &str, name: &str) -> Result<KubeObject> {
        self.obj_call("Delete", Value::map().with("kind", kind).with("name", name))
    }

    /// One `kube.Api/Evict` RPC; a PDB refusal crosses the socket as the
    /// typed `DisruptionBudgetExceeded` detail, so remote drain loops
    /// branch on `is_disruption_budget_exceeded()` like in-process ones.
    fn evict(&self, name: &str, mode: &EvictionMode) -> Result<KubeObject> {
        let mut body = mode.to_value();
        body.insert("name", name);
        self.obj_call("Evict", body)
    }

    fn apply(&self, obj: KubeObject) -> Result<KubeObject> {
        self.obj_call("Apply", obj.encode())
    }

    fn list(&self, kind: &str, opts: &ListOptions) -> Result<ObjectList> {
        let mut body = opts.to_value();
        body.insert("kind", kind);
        let v = self.client.call("kube.Api/List", body)?;
        let items = v
            .get("items")
            .and_then(Value::as_seq)
            .map(|s| s.iter().map(KubeObject::decode).collect::<Result<Vec<_>>>())
            .transpose()?
            .unwrap_or_default();
        let deleted = v
            .get("deleted")
            .and_then(Value::as_seq)
            .map(|s| s.iter().filter_map(|n| n.as_str().map(String::from)).collect())
            .unwrap_or_default();
        Ok(ObjectList {
            server_s: v.get("serverSeconds").and_then(Value::as_f64).unwrap_or(0.0),
            resource_version: v.opt_int("resourceVersion").unwrap_or(0) as u64,
            items,
            continue_token: v.opt_str("continue").map(String::from),
            delta: v.opt_bool("delta").unwrap_or(false),
            deleted,
        })
    }

    fn watch(&self, kind: Option<&str>, from_version: u64) -> Result<Receiver<WatchEvent>> {
        if !self.watch_cfg.force_poll {
            if let Some(rx) = self.watch_streaming(kind, from_version)? {
                *self.last_watch_mode.lock().unwrap() = Some(WatchMode::Streaming);
                return Ok(rx);
            }
        }
        *self.last_watch_mode.lock().unwrap() = Some(WatchMode::Poll);
        self.watch_poll(kind, from_version)
    }

    fn server_time_s(&self) -> Result<f64> {
        let v = self.client.call("kube.Api/ServerTime", Value::map())?;
        Ok(v.get("serverSeconds").and_then(Value::as_f64).unwrap_or(0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::Value;
    use crate::kube::api::{KIND_DEPLOYMENT, KIND_POD};
    use crate::redbox::{FnService, RedboxServer};
    use crate::rt::Shutdown;
    use std::time::Instant;

    fn api() -> ApiServer {
        ApiServer::new(Metrics::new())
    }

    fn pod(name: &str) -> KubeObject {
        KubeObject::new(KIND_POD, name, Value::map().with("v", 1i64))
    }

    fn owned(kind: &str, name: &str, owner: (&str, &str)) -> KubeObject {
        let mut o = KubeObject::new(kind, name, Value::map());
        o.meta.owner = Some((owner.0.to_string(), owner.1.to_string()));
        o
    }

    #[test]
    fn mutating_verbs_audit_with_actor_trace_and_outcome() {
        let _serial = crate::obs::trace::test_serial();
        crate::obs::set_enabled(true);
        let a = api();
        let trace_hex;
        {
            let _actor = crate::obs::push_actor("kube-test");
            let g = crate::obs::span("test", "audited create");
            trace_hex = format!("{:016x}", g.context().unwrap().trace_id);
            a.create(pod("p")).unwrap();
        }
        a.update_status(KIND_POD, "p", |o| {
            o.status.insert("phase", "Running");
        })
        .unwrap();
        // Failed mutation still audits, with the error as outcome.
        assert!(a.delete(KIND_POD, "ghost").is_err());

        let records = a.audit_log().snapshot();
        assert_eq!(records.len(), 3, "create + update_status + failed delete");
        assert_eq!(records[0].verb, "create");
        assert_eq!(records[0].kind, KIND_POD);
        assert_eq!(records[0].name, "p");
        assert_eq!(records[0].actor, "kube-test");
        assert_eq!(records[0].trace.as_deref(), Some(trace_hex.as_str()));
        assert_eq!(records[0].outcome, "ok");
        assert_eq!(records[1].verb, "update_status");
        assert_eq!(
            records[1].actor,
            crate::obs::UNATTRIBUTED,
            "no pinned actor -> unattributed"
        );
        assert_eq!(records[2].verb, "delete");
        assert!(records[2].outcome.contains("not found"), "{}", records[2].outcome);
        // Reads never audit.
        a.get(KIND_POD, "p").unwrap();
        assert_eq!(a.audit_log().last_seq(), 3);
        // Verb counters carry the GVK label (and still sum per family).
        assert_eq!(a.metrics.counter_value_with("kube.api.create", &[("gvk", "pods")]), 1);
        assert_eq!(a.metrics.counter_value("kube.api.create"), 1);
    }

    #[test]
    fn update_status_retries_conflicts() {
        let a = api();
        a.create(pod("p")).unwrap();
        // Interleave an update between get and commit by doing it inside f
        // on the first call only.
        let api2 = a.clone();
        let first = std::sync::atomic::AtomicBool::new(true);
        let out = a
            .update_status(KIND_POD, "p", |o| {
                if first.swap(false, std::sync::atomic::Ordering::SeqCst) {
                    // racey writer bumps the version under us
                    api2.update_status(KIND_POD, "p", |o2| {
                        o2.status.insert("other", "x");
                    })
                    .unwrap();
                }
                o.status.insert("phase", "Running");
            })
            .unwrap();
        assert_eq!(out.status.opt_str("phase"), Some("Running"));
        assert_eq!(out.status.opt_str("other"), Some("x"), "racey write preserved");
    }

    #[test]
    fn update_status_exhaustion_is_distinguishable() {
        let a = api();
        a.create(pod("p")).unwrap();
        // A writer that always wins the race: every attempt conflicts.
        let api2 = a.clone();
        let err = a
            .update_status(KIND_POD, "p", |o| {
                api2.update_status(KIND_POD, "p", |o2| {
                    o2.status.insert("winner", "other");
                })
                .unwrap();
                o.status.insert("phase", "Running");
            })
            .unwrap_err();
        assert!(err.is_conflict_exhausted(), "got {err}");
        assert!(!err.is_conflict(), "must not be mistaken for a retryable conflict");
        assert!(err.to_string().contains("16 consecutive"));
    }

    #[test]
    fn mutating_hook_runs_on_create_and_apply_create_only() {
        let a = api();
        a.register_mutating_hook(Arc::new(|o: &mut KubeObject| {
            if o.kind == KIND_POD {
                o.meta.set_label("admitted-by", "hook");
            }
        }));
        // Plain create is mutated.
        let o = a.create(pod("p1")).unwrap();
        assert_eq!(o.meta.label("admitted-by"), Some("hook"));
        // Apply's create arm is mutated too...
        let o = a.apply(pod("p2")).unwrap();
        assert_eq!(o.meta.label("admitted-by"), Some("hook"));
        // ...but the update arm re-applies the manifest's labels verbatim
        // (an existing object is not re-born; re-gating live objects is
        // the controllers' job, not admission's).
        let o = a.apply(pod("p2")).unwrap();
        assert_eq!(o.meta.label("admitted-by"), None, "update arm skips hooks");
        // Non-matching kinds pass through untouched.
        let n = a.create(KubeObject::new("Node", "n1", Value::map())).unwrap();
        assert_eq!(n.meta.label("admitted-by"), None);
    }

    #[test]
    fn history_cap_constructor_plumbs_through() {
        let a = ApiServer::with_history_cap(Metrics::new(), 64);
        a.create(pod("seed")).unwrap();
        let bookmark = a.current_version();
        for i in 0..100 {
            a.update_status(KIND_POD, "seed", |o| {
                o.status.insert("n", i as u64);
            })
            .unwrap();
        }
        let (_, _, reset) = a.events_since(None, bookmark);
        assert!(reset, "64-event window must trim a 100-write burst");
    }

    #[test]
    fn cascade_delete_by_owner() {
        let a = api();
        a.create(KubeObject::new(KIND_DEPLOYMENT, "web", Value::map())).unwrap();
        a.create(owned(KIND_POD, "web-1", (KIND_DEPLOYMENT, "web"))).unwrap();
        a.create(pod("standalone")).unwrap();
        a.delete(KIND_DEPLOYMENT, "web").unwrap();
        assert!(a.get(KIND_POD, "web-1").unwrap_err().is_not_found());
        assert!(a.get(KIND_POD, "standalone").is_ok());
    }

    #[test]
    fn cascade_delete_follows_owners_transitively() {
        let a = api();
        a.create(KubeObject::new(KIND_DEPLOYMENT, "web", Value::map())).unwrap();
        a.create(owned(KIND_POD, "web-1", (KIND_DEPLOYMENT, "web"))).unwrap();
        // Grandchild and great-grandchild (a CRD kind, to cross kinds).
        a.create(owned("Widget", "w1", (KIND_POD, "web-1"))).unwrap();
        a.create(owned("Widget", "w2", ("Widget", "w1"))).unwrap();
        // Unrelated object owned by nothing in the chain.
        a.create(owned("Widget", "other", (KIND_POD, "not-here"))).unwrap();
        a.delete(KIND_DEPLOYMENT, "web").unwrap();
        for (kind, name) in [(KIND_POD, "web-1"), ("Widget", "w1"), ("Widget", "w2")] {
            assert!(a.get(kind, name).unwrap_err().is_not_found(), "{kind}/{name} orphaned");
        }
        assert!(a.get("Widget", "other").is_ok());
        // Deleting a nonexistent root is a NotFound no-op — it must NOT
        // cascade into objects that name the missing root as owner.
        assert!(a.delete(KIND_POD, "not-here").unwrap_err().is_not_found());
        assert!(a.get("Widget", "other").is_ok(), "dangling-owner object survived");
    }

    #[test]
    fn cascade_delete_terminates_on_ownership_cycles() {
        let a = api();
        a.create(KubeObject::new("Widget", "a", Value::map())).unwrap();
        a.create(owned("Widget", "b", ("Widget", "a"))).unwrap();
        // Close the cycle: a is owned by b.
        a.update_status("Widget", "a", |o| {
            o.meta.owner = Some(("Widget".to_string(), "b".to_string()));
        })
        .unwrap();
        a.delete("Widget", "a").unwrap();
        assert!(a.get("Widget", "a").unwrap_err().is_not_found());
        assert!(a.get("Widget", "b").unwrap_err().is_not_found());
    }

    #[test]
    fn apply_create_then_merge() {
        let a = api();
        let o1 = a.apply(pod("p")).unwrap();
        a.update_status(KIND_POD, "p", |o| o.status.insert("phase", "Running")).unwrap();
        // Re-apply with changed spec: spec replaced, status preserved.
        let mut newer = pod("p");
        newer.spec.insert("v", 2i64);
        let o2 = a.apply(newer).unwrap();
        assert!(o2.meta.resource_version > o1.meta.resource_version);
        assert_eq!(o2.spec.opt_int("v"), Some(2));
        assert_eq!(o2.status.opt_str("phase"), Some("Running"));
    }

    #[test]
    fn merge_patch_semantics() {
        let a = api();
        let mut p = pod("p");
        p.spec.insert("keep", "yes");
        p.spec.insert("drop", "soon");
        p.spec.insert("nest", Value::map().with("a", 1i64).with("b", 2i64));
        a.create(p).unwrap();
        let patch = Value::map()
            .with(
                "spec",
                Value::map()
                    .with("drop", Value::Null)
                    .with("nest", Value::map().with("b", 9i64).with("c", 3i64)),
            )
            .with("status", Value::map().with("phase", "Running"))
            .with(
                "metadata",
                Value::map().with("labels", Value::map().with("app", "web")),
            );
        let o = a.patch_merge(KIND_POD, "p", &patch).unwrap();
        assert_eq!(o.spec.opt_str("keep"), Some("yes"), "untouched keys survive");
        assert!(o.spec.get("drop").is_none(), "null removes");
        assert_eq!(o.spec.path(&["nest", "a"]).and_then(Value::as_int), Some(1));
        assert_eq!(o.spec.path(&["nest", "b"]).and_then(Value::as_int), Some(9));
        assert_eq!(o.spec.path(&["nest", "c"]).and_then(Value::as_int), Some(3));
        assert_eq!(o.status.opt_str("phase"), Some("Running"));
        assert_eq!(o.meta.label("app"), Some("web"));
        // Label removal via null.
        let o = a
            .patch_merge(
                KIND_POD,
                "p",
                &Value::map().with(
                    "metadata",
                    Value::map().with("labels", Value::map().with("app", Value::Null)),
                ),
            )
            .unwrap();
        assert_eq!(o.meta.label("app"), None);
        // RFC 7386: a map patch replacing a scalar strips its null members
        // instead of storing literal nulls.
        let o = a
            .patch_merge(
                KIND_POD,
                "p",
                &Value::map().with(
                    "spec",
                    Value::map().with(
                        "keep", // currently the scalar "yes"
                        Value::map().with("x", Value::Null).with("y", 1i64),
                    ),
                ),
            )
            .unwrap();
        assert!(o.spec.path(&["keep", "x"]).is_none(), "null member stripped");
        assert_eq!(o.spec.path(&["keep", "y"]).and_then(Value::as_int), Some(1));
    }

    #[test]
    fn list_opts_field_selector_and_freshness() {
        let a = api();
        let mut p1 = pod("p1");
        p1.spec.insert("nodeName", "w1");
        a.create(p1).unwrap();
        let mut p2 = pod("p2");
        p2.spec.insert("nodeName", "w2");
        a.create(p2).unwrap();
        let list = a
            .list_opts(KIND_POD, &ListOptions::all().with_field("spec.nodeName", "w1"))
            .unwrap();
        assert_eq!(list.items.len(), 1);
        assert_eq!(list.items[0].meta.name, "p1");
        assert_eq!(list.resource_version, a.current_version());
        assert!(list.server_s >= 0.0);
        // Freshness floor: asking for a future version is a conflict.
        let err = a
            .list_opts(KIND_POD, &ListOptions::all().not_older_than(a.current_version() + 10))
            .unwrap_err();
        assert!(err.is_conflict());
    }

    #[test]
    fn paged_list_walks_all_objects() {
        let a = api();
        for i in 0..7 {
            a.create(pod(&format!("p{i}"))).unwrap();
        }
        let mut seen = Vec::new();
        let mut opts = ListOptions::all().with_limit(3);
        let mut pages = 0;
        loop {
            let page = a.list_opts(KIND_POD, &opts).unwrap();
            assert!(page.items.len() <= 3);
            pages += 1;
            seen.extend(page.items.iter().map(|o| o.meta.name.clone()));
            match page.continue_token {
                Some(t) => opts = ListOptions::all().with_limit(3).continue_from(&t),
                None => break,
            }
        }
        assert_eq!(pages, 3, "7 items at limit 3");
        assert_eq!(seen, (0..7).map(|i| format!("p{i}")).collect::<Vec<_>>());
        // limit 0 = unlimited; an exact-fit page carries no token.
        let all = a.list_opts(KIND_POD, &ListOptions::all().with_limit(0)).unwrap();
        assert_eq!(all.items.len(), 7);
        assert!(all.continue_token.is_none());
        let exact = a.list_opts(KIND_POD, &ListOptions::all().with_limit(7)).unwrap();
        assert_eq!(exact.items.len(), 7);
        assert!(exact.continue_token.is_none(), "exact fit is the final page");
    }

    fn rpc_pair(tag: &str) -> (Shutdown, RedboxServer, ApiServer, RemoteApi) {
        let sd = Shutdown::new();
        let path = std::env::temp_dir().join(format!(
            "hpcorc-kubeapi-{tag}-{}.sock",
            std::process::id()
        ));
        let srv = RedboxServer::start(&path, sd.clone(), Metrics::new()).unwrap();
        let a = api();
        srv.register("kube.Api", a.rpc_service());
        let remote = RemoteApi::connect(&path).unwrap();
        (sd, srv, a, remote)
    }

    #[test]
    fn update_status_batch_is_per_item_over_both_transports() {
        let (_sd, mut srv, a, remote) = rpc_pair("batch");
        a.create(pod("b1")).unwrap();
        a.create(pod("b2")).unwrap();
        let bind = |node: &str| Value::map().with("spec", Value::map().with("nodeName", node));
        let items = vec![
            BatchPatchItem::new(KIND_POD, "b1", bind("n1")),
            BatchPatchItem::new(KIND_POD, "ghost", bind("n2")),
            BatchPatchItem::new(KIND_POD, "b2", bind("n3")),
        ];
        // One RPC, three positional results; the middle failure is the
        // same typed NotFound an in-process caller gets, and it does not
        // poison its batch-mates.
        let res = ApiClient::update_status_batch(&remote, &items).unwrap();
        assert_eq!(res.len(), 3);
        assert_eq!(res[0].as_ref().unwrap().spec.opt_str("nodeName"), Some("n1"));
        assert!(res[1].as_ref().unwrap_err().is_not_found());
        assert_eq!(res[2].as_ref().unwrap().spec.opt_str("nodeName"), Some("n3"));
        assert_eq!(a.get(KIND_POD, "b1").unwrap().spec.opt_str("nodeName"), Some("n1"));
        assert_eq!(a.get(KIND_POD, "b2").unwrap().spec.opt_str("nodeName"), Some("n3"));
        // The audit trail reads like N single update_status calls.
        let records = a.audit_log().snapshot();
        let batch_verbs: Vec<_> =
            records.iter().filter(|r| r.verb == "update_status").collect();
        assert_eq!(batch_verbs.len(), 3);
        assert_eq!(batch_verbs[1].name, "ghost");
        assert_ne!(batch_verbs[1].outcome, "ok");
        assert_eq!(a.metrics.counter_value("kube.api.update_status_batch"), 1);
        assert_eq!(a.metrics.counter_value("kube.api.update_status"), 2, "successes only");
        srv.stop();
    }

    #[test]
    fn delta_list_ships_changes_and_deletions_over_rpc() {
        let (_sd, mut srv, a, remote) = rpc_pair("delta");
        a.create(pod("pa")).unwrap();
        let mut b = a.create(pod("pb")).unwrap();
        a.create(pod("pc")).unwrap();
        let floor = a.current_version();

        b.spec.insert("v", 2i64);
        a.update(b).unwrap();
        a.delete(KIND_POD, "pc").unwrap();
        a.create(pod("pd")).unwrap();

        let dl =
            ApiClient::list(&remote, KIND_POD, &ListOptions::all().delta_since(floor)).unwrap();
        assert!(dl.delta, "floor is inside the window: expected a delta answer");
        let names: Vec<&str> = dl.items.iter().map(|o| o.meta.name.as_str()).collect();
        assert_eq!(names, vec!["pb", "pd"], "only changed objects ship");
        assert_eq!(dl.deleted, vec!["pc".to_string()]);
        assert_eq!(dl.resource_version, a.current_version());

        // Both transports answer a delta list identically.
        let local = a.list_opts(KIND_POD, &ListOptions::all().delta_since(floor)).unwrap();
        assert!(local.delta);
        assert_eq!(
            local.items.iter().map(|o| o.meta.name.as_str()).collect::<Vec<_>>(),
            names
        );
        assert_eq!(local.deleted, dl.deleted);
        srv.stop();
    }

    #[test]
    fn delta_list_falls_back_to_full_when_floor_out_of_window() {
        let a = ApiServer::with_history_cap(Metrics::new(), 4);
        a.create(pod("p0")).unwrap();
        let floor = a.current_version();
        for i in 0..20i64 {
            a.update_status(KIND_POD, "p0", |o| {
                o.status.insert("i", i);
            })
            .unwrap();
        }
        let l = a.list_opts(KIND_POD, &ListOptions::all().delta_since(floor)).unwrap();
        assert!(!l.delta, "trimmed floor must degrade to a full list");
        assert!(l.deleted.is_empty());
        assert_eq!(l.items.len(), 1);
    }

    #[test]
    fn rpc_surface_end_to_end() {
        let (_sd, mut srv, _a, remote) = rpc_pair("e2e");

        let created = remote.apply(pod("rp")).unwrap();
        assert!(created.meta.uid > 0);
        let got = ApiClient::get(&remote, KIND_POD, "rp").unwrap();
        assert_eq!(got.meta.uid, created.meta.uid);

        // Full update through the socket.
        let mut fresh = got.clone();
        fresh.spec.insert("v", 2i64);
        let updated = ApiClient::update(&remote, fresh).unwrap();
        assert_eq!(updated.spec.opt_int("v"), Some(2));

        // update_status (client-side retry loop) and merge patch.
        let o = remote
            .update_status(KIND_POD, "rp", &|o| {
                o.status.insert("phase", "Running");
            })
            .unwrap();
        assert_eq!(o.status.opt_str("phase"), Some("Running"));
        let o = remote
            .patch_merge(
                KIND_POD,
                "rp",
                &Value::map().with(
                    "metadata",
                    Value::map().with("labels", Value::map().with("app", "web")),
                ),
            )
            .unwrap();
        assert_eq!(o.meta.label("app"), Some("web"));

        // List with a label selector + server time.
        remote.create(pod("other")).unwrap();
        let list = ApiClient::list(
            &remote,
            KIND_POD,
            &ListOptions::all().with_label("app", "web"),
        )
        .unwrap();
        assert_eq!(list.items.len(), 1);
        assert_eq!(list.items[0].meta.name, "rp");
        assert!(list.resource_version > 0);
        assert!(remote.server_time_s().unwrap() >= 0.0);

        ApiClient::delete(&remote, KIND_POD, "rp").unwrap();
        assert!(ApiClient::get(&remote, KIND_POD, "rp").is_err());
        srv.stop();
    }

    #[test]
    fn rpc_errors_recover_their_type() {
        let (_sd, mut srv, a, remote) = rpc_pair("retype");
        let e = ApiClient::get(&remote, KIND_POD, "ghost").unwrap_err();
        assert!(e.is_not_found(), "got {e}");
        a.create(pod("p")).unwrap();
        let e = remote.create(pod("p")).unwrap_err();
        assert!(
            matches!(e, Error::Api(crate::util::ApiError::AlreadyExists { .. })),
            "got {e}"
        );
        // Stale update conflicts across the socket, typed.
        let stored = ApiClient::get(&remote, KIND_POD, "p").unwrap();
        a.update_status(KIND_POD, "p", |o| o.status.insert("x", 1i64)).unwrap();
        let e = ApiClient::update(&remote, stored).unwrap_err();
        assert!(e.is_conflict(), "got {e}");
        // Unknown RPC method stays an untyped transport error.
        let e = remote.client.call("kube.Api/Nope", Value::map()).unwrap_err();
        assert!(matches!(e, Error::Rpc(_)), "got {e}");
        srv.stop();
    }

    #[test]
    fn streaming_watch_pushes_without_polling() {
        let (_sd, mut srv, a, remote) = rpc_pair("push");
        let rx = ApiClient::watch(&remote, Some(KIND_POD), 0).unwrap();
        assert_eq!(remote.last_watch_mode(), Some(WatchMode::Streaming));
        // Idle: nothing crosses the socket (the poll path issued ~10-500
        // requests per second here).
        let base = srv.metrics().counter_value("redbox.requests");
        std::thread::sleep(Duration::from_millis(120));
        assert_eq!(
            srv.metrics().counter_value("redbox.requests"),
            base,
            "an idle streaming watch must transmit nothing"
        );
        // Events are pushed, still without a single extra request.
        a.create(pod("w")).unwrap();
        let ev = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(ev.object().meta.name, "w");
        assert_eq!(
            srv.metrics().counter_value("redbox.requests"),
            base,
            "event delivery is server-push, not poll"
        );
        srv.stop();
    }

    #[test]
    fn streaming_negotiation_falls_back_to_poll_on_old_server() {
        let sd = Shutdown::new();
        let path = std::env::temp_dir()
            .join(format!("hpcorc-kubeapi-fallback-{}.sock", std::process::id()));
        let mut srv = RedboxServer::start(&path, sd.clone(), Metrics::new()).unwrap();
        let a = api();
        // An "old" kube.Api: strictly unary, poll-shaped Watch only —
        // it silently ignores the `stream` flag like any pre-frame peer.
        let poll_api = a.clone();
        srv.register(
            "kube.Api",
            Arc::new(FnService(move |method: &str, body: &Value| {
                match method {
                    "Watch" => {
                        let kind = body.opt_str("kind");
                        let from = body.opt_int("fromVersion").unwrap_or(0) as u64;
                        let (rv, events, reset) = poll_api.events_since(kind, from);
                        Ok(Value::map()
                            .with("resourceVersion", rv)
                            .with("reset", reset)
                            .with(
                                "events",
                                Value::Seq(events.iter().map(WatchEvent::encode).collect()),
                            ))
                    }
                    other => Err(Error::rpc(format!("old server has no `{other}`"))),
                }
            })),
        );
        let remote = RemoteApi::connect(&path).unwrap();
        let rx = ApiClient::watch(&remote, Some(KIND_POD), 0).unwrap();
        assert_eq!(remote.last_watch_mode(), Some(WatchMode::Poll), "negotiation fell back");
        a.create(pod("p")).unwrap();
        let ev = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(ev.object().meta.name, "p");
        srv.stop();
    }

    #[test]
    fn streaming_watch_stale_bookmark_gets_gone_end() {
        let sd = Shutdown::new();
        let path = std::env::temp_dir()
            .join(format!("hpcorc-kubeapi-gone-{}.sock", std::process::id()));
        let mut srv = RedboxServer::start(&path, sd.clone(), Metrics::new()).unwrap();
        let a = ApiServer::with_history_cap(Metrics::new(), 16);
        srv.register("kube.Api", a.rpc_service());
        a.create(pod("seed")).unwrap();
        for i in 0..50 {
            a.update_status(KIND_POD, "seed", |o| {
                o.status.insert("n", i as u64);
            })
            .unwrap();
        }
        let remote = RemoteApi::connect(&path).unwrap();
        // Bookmark 1 predates the 16-event window: the server answers
        // with an immediate `gone` StreamEnd; the receiver is simply an
        // ended stream with zero events — identical to the in-process
        // stale-watch contract.
        let rx = ApiClient::watch(&remote, Some(KIND_POD), 1).unwrap();
        assert_eq!(remote.last_watch_mode(), Some(WatchMode::Streaming));
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(ev) => panic!("410 stream must replay nothing, got {ev:?}"),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    assert!(Instant::now() < deadline, "stream never ended");
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        srv.stop();
    }

    #[test]
    fn bookmarks_track_foreign_kind_churn() {
        let (_sd, mut srv, a, remote) = rpc_pair("bookmark");
        // Watch Pods from the current version, then churn only Nodes:
        // no Pod events exist, but periodic BOOKMARK frames must keep
        // the client's bookmark at the store's version.
        let rx = ApiClient::watch(&remote, Some(KIND_POD), a.current_version()).unwrap();
        for i in 0..5 {
            a.create(KubeObject::new("Node", format!("n{i}"), Value::map())).unwrap();
        }
        let target = a.current_version();
        let deadline = Instant::now() + Duration::from_secs(5);
        while remote.watch_bookmark() < target {
            assert!(
                Instant::now() < deadline,
                "bookmark stuck at {} (want {target})",
                remote.watch_bookmark()
            );
            std::thread::sleep(Duration::from_millis(20));
        }
        // The foreign churn never surfaced as events.
        assert!(
            matches!(rx.try_recv(), Err(std::sync::mpsc::TryRecvError::Empty)),
            "bookmarks must be invisible to the event consumer"
        );
        srv.stop();
    }

    #[test]
    fn remote_watch_streams_events() {
        let (_sd, mut srv, a, remote) = rpc_pair("watch");
        // Subscribe from version 0 so creation history replays too.
        let rx = ApiClient::watch(&remote, Some(KIND_POD), 0).unwrap();
        a.create(pod("w1")).unwrap();
        a.update_status(KIND_POD, "w1", |o| o.status.insert("phase", "Running")).unwrap();
        a.create(KubeObject::new("Node", "n1", Value::map())).unwrap(); // filtered out
        a.delete(KIND_POD, "w1").unwrap();

        let mut events = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        while events.len() < 3 {
            assert!(Instant::now() < deadline, "only saw {events:?}");
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(ev) => events.push((ev.type_str(), ev.object().meta.name.clone())),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(e) => panic!("watch stream died early: {e}"),
            }
        }
        assert_eq!(
            events,
            vec![
                ("ADDED", "w1".to_string()),
                ("MODIFIED", "w1".to_string()),
                ("DELETED", "w1".to_string()),
            ]
        );
        srv.stop();
    }
}
