//! E2 — per-job overhead added by the Torque-Operator path vs native qsub,
//! with the component breakdown (apiserver, kube-scheduler bind, red-box
//! submit, status-poll observation lag).

use hpcorc::bench::{fmt_ns, header, Bench};
use hpcorc::encoding::Value;
use hpcorc::hybrid::{Testbed, TestbedConfig};
use hpcorc::kube::{WlmJobView, KIND_POD, KIND_TORQUEJOB};
use hpcorc::redbox::RedboxClient;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

static SEQ: AtomicU64 = AtomicU64::new(0);

fn main() {
    println!("=== E2: operator overhead (TorqueJob-via-operator vs direct qsub) ===");
    println!("{}", header());
    let tb = Testbed::start(TestbedConfig::default()).expect("boot");

    // Use an instant job body so orchestration dominates.
    let script = |n: u64| format!("#PBS -N o{n}\necho x\n");

    let direct = Bench::new("direct qsub -> completed").warmup(5).iters(60).run(|| {
        let n = SEQ.fetch_add(1, Ordering::Relaxed);
        let id = tb.pbs.qsub(&script(n), "bench").unwrap();
        tb.pbs.wait_for(id.seq, Duration::from_secs(30)).unwrap();
    });

    let operator = Bench::new("torquejob via operator -> completed").warmup(5).iters(60).run(|| {
        let n = SEQ.fetch_add(1, Ordering::Relaxed);
        let name = format!("op-{n}");
        tb.api
            .create(WlmJobView::build_torquejob(&name, &script(n), "", ""))
            .unwrap();
        tb.wait_torquejob(&name, Duration::from_secs(30)).unwrap();
    });

    println!(
        "\noperator overhead (mean): {} per job",
        fmt_ns(operator.mean_ns - direct.mean_ns)
    );

    // Component breakdown on one instrumented job.
    let n = SEQ.fetch_add(1, Ordering::Relaxed);
    let name = format!("trace-{n}");
    let t0 = Instant::now();
    tb.api
        .create(WlmJobView::build_torquejob(&name, &script(n), "", ""))
        .unwrap();
    let t_created = t0.elapsed();
    // wait for dummy pod bind
    let t_bound = loop {
        if let Ok(pod) = tb.api.get(KIND_POD, &format!("{name}-submit")) {
            if pod.spec.opt_str("nodeName").is_some() {
                break t0.elapsed();
            }
        }
        std::thread::sleep(Duration::from_micros(200));
    };
    let t_submitted = loop {
        let o = tb.api.get(KIND_TORQUEJOB, &name).unwrap();
        if o.status.opt_str("jobId").is_some() {
            break t0.elapsed();
        }
        std::thread::sleep(Duration::from_micros(200));
    };
    tb.wait_torquejob(&name, Duration::from_secs(30)).unwrap();
    let t_done = t0.elapsed();
    println!("\nbreakdown of one operator job:");
    println!("  api create            {:>10}", fmt_ns(t_created.as_nanos() as f64));
    println!("  dummy pod bound       {:>10}", fmt_ns(t_bound.as_nanos() as f64));
    println!("  qsub via red-box      {:>10}", fmt_ns(t_submitted.as_nanos() as f64));
    println!("  completed observed    {:>10}", fmt_ns(t_done.as_nanos() as f64));

    // Raw red-box hop for reference (the socket cost itself).
    let client = RedboxClient::connect(tb.socket()).unwrap();
    Bench::new("red-box JobStatus round trip").warmup(10).iters(200).run(|| {
        let _ = client.call(
            "torque.Workload/JobStatus",
            Value::map().with("jobId", "1.torque-head"),
        );
    });

    tb.stop();
}
