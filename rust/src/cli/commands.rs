//! Command implementations.

use super::args::Args;
use crate::encoding::Value;
use crate::hybrid::{Testbed, TestbedConfig};
use crate::kube::{
    default_scheme, Api, ApiClient, EventView, KubeObject, ListOptions, NodeView, RemoteApi,
    KIND_EVENT, KIND_TORQUEJOB,
};
use crate::kueue::{ClusterQueueView, QueueOrdering, QueueResources};
use crate::redbox::RedboxClient;
use crate::sched::{EasyBackfill, FifoPolicy, KubeGreedyPolicy, SchedPolicy};
use crate::sim::{simulate, QueueAdmission, SimParams};
use crate::util::{fmt_age, Error, Result};
use crate::workload::{Trace, TraceGen};
use std::time::Duration;

pub const USAGE: &str = "\
hpcorc — Container Orchestration on HPC Systems (Torque-Operator reproduction)

USAGE: hpcorc <command> [args]

Testbed:
  up        [--nodes N] [--cores C] [--workers W] [--slurm] [--artifacts DIR]
            [--time-scale S] [--socket PATH] [--run-for SECS] [--wal-dir DIR]
            [--audit-log FILE]
            [--autoscale-max N [--autoscale-min N] [--autoscale-cores C]]
            boot the hybrid testbed (Fig. 1) and serve until stopped;
            --autoscale-max enables the elastic layer (metrics pipeline +
            HPA + cluster autoscaler with burst-to-WLM); --wal-dir makes
            the API server durable (WAL + snapshots) — boot again on the
            same dir to recover every object and resource version;
            --audit-log additionally appends every mutating API request
            to FILE as one JSON record per line
  demo      run the paper's Fig. 3-5 test case end to end and print it

Kubernetes surface (against a running testbed; KIND accepts kubectl-style
aliases — pods/po, nodes/no, deploy, torquejobs/tj, slurmjobs/sj,
clusterqueues/cq, localqueues/lq, hpa, nodemetrics, podmetrics,
events/ev, poddisruptionbudgets/pdb, crds/crd — plus any alias of a
CustomResourceDefinition registered through the API):
  kubectl apply -f FILE --socket PATH
  kubectl get KIND [NAME] [--socket PATH] [-o yaml|json] [-l k=v,...]
            `kubectl get events` renders the cluster event table
            (LAST SEEN / TYPE / REASON / OBJECT / COMPONENT / COUNT)
  kubectl describe KIND/NAME --socket PATH
            the object, its events, and its causal trace timeline
  kubectl top nodes|pods --socket PATH
  kubectl delete KIND NAME --socket PATH
  kubectl logs POD --socket PATH

Torque surface (against a running testbed):
  qsub FILE --socket PATH        submit a PBS script
  qstat JOBID --socket PATH      show WLM job status
  qdel JOBID --socket PATH       cancel

Workload tooling:
  trace gen --kind poisson|bursty|cybele|showcase|tenants|diurnal
            [--jobs N] [--seed S] [--tenants N] [--capacity CORES]
            [--load L] [--mean-runtime SECS] [--period SECS] [--out FILE]
  sim --trace FILE|--kind K --policy fifo|easy|kube [--nodes N] [--cores C]
            [--quota-nodes Q [--cohort]]
            [--elastic-max M [--elastic-min N] [--provision-delay S]
             [--idle-window S]]
            run the discrete-event simulator, print the report row.
            --quota-nodes meters each tenant queue found in the trace
            through a Q-node ClusterQueue (kueue admission in front of the
            policy); --cohort pools the quotas so idle capacity is
            borrowable — compare the admitted row against the raw one.
            --elastic-max runs an elastic cluster (min..max nodes, grown
            after --provision-delay, shrunk past --idle-window) — compare
            a static partition against an elastic one on a diurnal trace
  sing list                      list built-in container images
  version [--components]         versions (Table I inventory)

Observability (against a running testbed, PR 7/8):
  metrics --socket PATH [--prom|--json]
            scrape the daemon's metric registry over the socket; --prom
            prints Prometheus text exposition (labelled families), --json
            the structured snapshot, default a flat listing with
            histogram summaries
  trace KIND/NAME --socket PATH [--json]
            reconstruct the object's lifecycle timeline from its
            originating trace (create -> admit -> schedule -> bind -> run);
            --json dumps Chrome trace-event JSON (Perfetto-loadable)
  audit --socket PATH [--since SEQ] [--kind KIND] [--json]
            the API server's mutating-request audit trail (verb, object,
            actor, trace id, outcome, latency), oldest first; --since is
            an exclusive sequence-number cursor for incremental reads

Fault injection (PR 10; self-contained — boots its own testbeds):
  chaos     [--scenario NAME] [--seed N] [--json]
            run the named deterministic fault-injection scenario (default:
            all of them) against a live testbed and diff the converged
            state against a clean run's golden transcript; same seed, same
            faults, same transcript. Scenarios: redbox-drop,
            apiserver-restart, wlm-slow, kubelet-death, watch-overflow.
            Exits non-zero if any scenario diverges
";

fn policy_by_name(name: &str) -> Result<Box<dyn SchedPolicy>> {
    Ok(match name {
        "fifo" => Box::new(FifoPolicy),
        "easy" | "backfill" => Box::new(EasyBackfill),
        "kube" | "greedy" => Box::new(KubeGreedyPolicy),
        other => return Err(Error::config(format!("unknown policy `{other}`"))),
    })
}

fn testbed_config(args: &Args) -> Result<TestbedConfig> {
    let mut cfg = TestbedConfig::default();
    cfg.torque_nodes = args.num("nodes", cfg.torque_nodes)?;
    cfg.torque_cores = args.num("cores", cfg.torque_cores)?;
    cfg.kube_workers = args.num("workers", cfg.kube_workers)?;
    cfg.with_slurm = args.bool("slurm");
    cfg.time_scale = args.num("time-scale", cfg.time_scale)?;
    cfg.operator_deployment = args.bool("operator-deployment");
    if let Some(dir) = args.flag("artifacts") {
        cfg.artifacts_dir = Some(dir.into());
    }
    if let Some(sock) = args.flag("socket") {
        cfg.socket = Some(sock.into());
    }
    if let Some(dir) = args.flag("wal-dir") {
        cfg.wal_dir = Some(dir.into());
    }
    if let Some(file) = args.flag("audit-log") {
        cfg.audit_log = Some(file.into());
    }
    let autoscale_max: usize = args.num("autoscale-max", 0)?;
    if autoscale_max > 0 {
        let cores: u32 = args.num("autoscale-cores", cfg.kube_cores)?;
        cfg.autoscale = Some(crate::autoscale::CaConfig {
            max_nodes: autoscale_max,
            min_nodes: args.num("autoscale-min", 0)?,
            node_capacity: crate::cluster::Resources::cores(cores, 64 << 30),
            ..crate::autoscale::CaConfig::default()
        });
    }
    Ok(cfg)
}

pub fn cmd_up(args: &mut Args) -> Result<()> {
    let cfg = testbed_config(args)?;
    let run_for: f64 = args.num("run-for", 0.0)?;
    let tb = Testbed::start(cfg)?;
    println!("hpcorc testbed up");
    println!("  red-box socket : {}", tb.socket().display());
    println!("  torque         : server `{}`, queues {:?}", tb.pbs.server_name(), tb.pbs.queues().names());
    let nodes = Api::<NodeView>::new(tb.client());
    println!(
        "  kubernetes     : {} node objects",
        nodes.list(&ListOptions::all()).map(|n| n.len()).unwrap_or(0)
    );
    if tb.slurm.is_some() {
        println!("  slurm          : cluster `slurm` (WLM-Operator baseline)");
    }
    println!("  time scale     : {} (nominal->real)", tb.time_scale());
    if run_for > 0.0 {
        std::thread::sleep(Duration::from_secs_f64(run_for));
    } else {
        println!("running until killed (pass --run-for SECS to bound)");
        tb.shutdown.wait();
    }
    for (k, v) in tb.metrics.snapshot() {
        println!("  metric {k} = {v}");
    }
    tb.stop();
    Ok(())
}

pub fn cmd_demo(args: &mut Args) -> Result<()> {
    let mut cfg = testbed_config(args)?;
    cfg.operator_deployment = true;
    let tb = Testbed::start(cfg)?;
    println!("$ kubectl apply -f cow_job.yaml");
    tb.kubectl_apply(crate::kube::yaml::COW_JOB_YAML)?;
    // Fig. 4: poll and print the status table on each phase change.
    let mut last = String::new();
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    loop {
        let obj = tb.api.get(KIND_TORQUEJOB, "cow")?;
        let phase = obj.status.opt_str("phase").unwrap_or("").to_string();
        if phase != last {
            println!("\n$ kubectl get torquejob");
            println!("{:<6} {:<5} {:<10}", "NAME", "AGE", "STATUS");
            let age = fmt_age(Duration::from_secs_f64(
                (tb.api.now_s() - obj.meta.creation_s).max(0.0),
            ));
            println!("{:<6} {:<5} {:<10}", "cow", age, phase);
            last = phase.clone();
        }
        if crate::operator::phase::terminal(&phase) {
            break;
        }
        if std::time::Instant::now() > deadline {
            return Err(Error::wlm("demo timed out"));
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    println!("\n$ cat $HOME/low.out        # staged by the results pod (Fig. 5)");
    print!("{}", tb.fs.read_string("$HOME/low.out")?);
    tb.stop();
    Ok(())
}

/// The remote transport as the unified client trait — `cmd_kubectl` is
/// written against `ApiClient` only and would work unchanged in-process.
fn remote(args: &Args) -> Result<RemoteApi> {
    let sock = args.req_flag("socket")?;
    RemoteApi::connect(sock)
}

/// Resolve a user-facing kind alias through the scheme; unknown aliases
/// pass through verbatim so unregistered CRD kinds still work end to end.
fn resolve_kind(alias: &str) -> String {
    default_scheme()
        .canonical_kind(alias)
        .map(String::from)
        .unwrap_or_else(|| alias.to_string())
}

pub fn cmd_kubectl(args: &mut Args) -> Result<()> {
    let sub = args.req_positional(1, "kubectl subcommand")?.to_string();
    // Attribute every request this command makes — the actor rides the
    // red-box envelope and lands in the server's audit trail.
    let _actor = crate::obs::push_actor("kubectl");
    match sub.as_str() {
        "apply" => {
            let file = args.req_flag("f")?;
            let text = std::fs::read_to_string(file)?;
            let api = remote(args)?;
            // Root the trace on the user action: every object in this
            // apply shares one trace_id, which the client stamps onto the
            // RPCs and the server bakes into the objects' annotations.
            let _span = crate::obs::span("cli", "kubectl apply");
            for obj in crate::kube::yaml::parse_manifest(&text)? {
                let created = api.apply(obj)?;
                println!("{}/{} created", created.kind.to_lowercase(), created.meta.name);
            }
            Ok(())
        }
        "get" => {
            let kind = resolve_kind(args.req_positional(2, "kind")?);
            let api = remote(args)?;
            match args.positional(3) {
                Some(name) => {
                    let obj = api.get(&kind, name)?;
                    print_object(&obj, args.flag("o"))
                }
                None => {
                    let mut opts = ListOptions::all();
                    if let Some(sel) = args.flag("l") {
                        opts.label_selector = ListOptions::parse_selector(sel)?;
                    }
                    let list = api.list(&kind, &opts)?;
                    print_table(&kind, list.server_s, &list.items);
                    Ok(())
                }
            }
        }
        "delete" => {
            let kind = resolve_kind(args.req_positional(2, "kind")?);
            let name = args.req_positional(3, "name")?.to_string();
            let api = remote(args)?;
            api.delete(&kind, &name)?;
            println!("{}/{} deleted", kind.to_lowercase(), name);
            Ok(())
        }
        "logs" => {
            let name = args.req_positional(2, "pod name")?.to_string();
            let api = remote(args)?;
            let obj = api.get(crate::kube::KIND_POD, &name)?;
            print!("{}", obj.status.opt_str("log").unwrap_or(""));
            if let Some(err) = obj.status.opt_str("logErr") {
                eprint!("{err}");
            }
            Ok(())
        }
        "top" => {
            let what = args.req_positional(2, "nodes|pods")?.to_string();
            let api = remote(args)?;
            cmd_kubectl_top(&api, &what)
        }
        "describe" => {
            let spec = args.req_positional(2, "KIND/NAME")?.to_string();
            let (alias, name) = spec
                .split_once('/')
                .ok_or_else(|| Error::config("expected KIND/NAME"))?;
            let kind = resolve_kind(alias);
            let api = remote(args)?;
            let obj = api.get(&kind, name)?;
            cmd_kubectl_describe(args, &api, &obj)
        }
        other => Err(Error::config(format!("unknown kubectl subcommand `{other}`"))),
    }
}

/// `kubectl top nodes|pods`: render the metrics pipeline's
/// NodeMetrics/PodMetrics objects (autoscale layer).
fn cmd_kubectl_top(api: &dyn ApiClient, what: &str) -> Result<()> {
    use crate::autoscale::{NodeMetricsView, PodMetricsView, KIND_NODEMETRICS, KIND_PODMETRICS};
    match what {
        "nodes" | "node" | "no" => {
            println!(
                "{:<20} {:>10} {:>6} {:>12} {:>8}",
                "NAME", "CPU(m)", "CPU%", "MEMORY", "MEM%"
            );
            let mut items: Vec<NodeMetricsView> = api
                .list(KIND_NODEMETRICS, &ListOptions::all())?
                .items
                .iter()
                .filter_map(|o| NodeMetricsView::from_object(o).ok())
                .collect();
            items.sort_by(|a, b| a.name.cmp(&b.name));
            for m in items {
                let pct = |used: u64, cap: u64| {
                    if cap > 0 { format!("{}%", used * 100 / cap) } else { "-".into() }
                };
                println!(
                    "{:<20} {:>10} {:>6} {:>12} {:>8}",
                    m.name,
                    m.usage_cpu_milli,
                    pct(m.usage_cpu_milli, m.capacity.cpu_milli),
                    crate::util::fmt_mem(m.usage_mem_bytes),
                    pct(m.usage_mem_bytes, m.capacity.mem_bytes),
                );
            }
            Ok(())
        }
        "pods" | "pod" | "po" => {
            println!("{:<24} {:<16} {:>10} {:>12}", "NAME", "NODE", "CPU(m)", "MEMORY");
            let mut items: Vec<PodMetricsView> = api
                .list(KIND_PODMETRICS, &ListOptions::all())?
                .items
                .iter()
                .filter_map(|o| PodMetricsView::from_object(o).ok())
                .collect();
            items.sort_by(|a, b| a.name.cmp(&b.name));
            for m in items {
                println!(
                    "{:<24} {:<16} {:>10} {:>12}",
                    m.name,
                    m.node_name,
                    m.cpu_milli,
                    crate::util::fmt_mem(m.mem_bytes),
                );
            }
            Ok(())
        }
        other => Err(Error::config(format!("kubectl top: unknown resource `{other}`"))),
    }
}

/// `kubectl describe KIND/NAME`: the object's headline fields, every
/// cluster event regarding it (oldest first), and — when the object
/// carries a trace annotation — its causal span timeline. One command
/// answers "what happened to this pod", across components.
fn cmd_kubectl_describe(args: &Args, api: &dyn ApiClient, obj: &KubeObject) -> Result<()> {
    println!("Name:         {}", obj.meta.name);
    println!("Kind:         {} ({})", obj.kind, obj.api_version);
    if let Some(phase) = obj.status.opt_str("phase") {
        println!("Phase:        {phase}");
    }
    if let Some(node) = obj.spec.opt_str("nodeName") {
        println!("Node:         {node}");
    }
    if !obj.meta.labels.is_empty() {
        let rendered: Vec<String> =
            obj.meta.labels.iter().map(|(k, v)| format!("{k}={v}")).collect();
        println!("Labels:       {}", rendered.join(","));
    }
    for (k, v) in &obj.meta.annotations {
        println!("Annotation:   {k}={v}");
    }
    let list = api.list(KIND_EVENT, &ListOptions::all())?;
    let mut evs: Vec<EventView> = list
        .items
        .iter()
        .filter_map(|o| EventView::from_object(o).ok())
        .filter(|e| e.regarding_kind == obj.kind && e.regarding_name == obj.meta.name)
        .collect();
    evs.sort_by(|a, b| a.last_seen_s.total_cmp(&b.last_seen_s));
    println!("\nEvents:");
    if evs.is_empty() {
        println!("  <none>");
    } else {
        println!(
            "  {:<8} {:<20} {:<10} {:<28} {:>5}  MESSAGE",
            "TYPE", "REASON", "AGE", "FROM", "COUNT"
        );
        for e in &evs {
            println!(
                "  {:<8} {:<20} {:<10} {:<28} {:>5}  {}",
                e.etype,
                e.reason,
                fmt_age(Duration::from_secs_f64((list.server_s - e.last_seen_s).max(0.0))),
                e.reporting_controller,
                e.count,
                e.note
            );
        }
    }
    if obj.meta.annotation(crate::obs::TRACE_ANNOTATION).is_some() {
        println!();
        print_trace_timeline(args, &obj.kind, &obj.meta.name, obj)?;
    }
    Ok(())
}

fn print_object(obj: &KubeObject, output: Option<&str>) -> Result<()> {
    match output.unwrap_or("yaml") {
        "json" => println!("{}", crate::encoding::json::to_string_pretty(&obj.encode())),
        _ => print!("{}", crate::kube::yaml::to_yaml(obj)),
    }
    Ok(())
}

/// The Fig. 4 table (NAME / AGE / STATUS), generalized per kind.
fn print_table(kind: &str, server_now: f64, items: &[KubeObject]) {
    match kind {
        "Pod" => {
            println!("{:<24} {:<6} {:<11} {:<14}", "NAME", "AGE", "STATUS", "NODE");
            for o in items {
                println!(
                    "{:<24} {:<6} {:<11} {:<14}",
                    o.meta.name,
                    fmt_age(Duration::from_secs_f64((server_now - o.meta.creation_s).max(0.0))),
                    o.status.opt_str("phase").unwrap_or("Pending"),
                    o.spec.opt_str("nodeName").unwrap_or("<none>")
                );
            }
        }
        "Node" => {
            println!("{:<20} {:<6} {:<9} {:<18}", "NAME", "AGE", "STATUS", "RUNTIME");
            for o in items {
                println!(
                    "{:<20} {:<6} {:<9} {:<18}",
                    o.meta.name,
                    fmt_age(Duration::from_secs_f64((server_now - o.meta.creation_s).max(0.0))),
                    o.status.opt_str("phase").unwrap_or(""),
                    o.status.opt_str("runtime").unwrap_or("")
                );
            }
        }
        "ClusterQueue" => {
            println!(
                "{:<16} {:<10} {:<12} {:>8} {:>9}",
                "NAME", "COHORT", "NOMINAL", "PENDING", "ADMITTED"
            );
            for o in items {
                let nominal = o
                    .spec
                    .path(&["quota", "nodes"])
                    .and_then(crate::encoding::Value::as_int)
                    .map(|n| format!("{n} nodes"))
                    .unwrap_or_else(|| "unbounded".into());
                println!(
                    "{:<16} {:<10} {:<12} {:>8} {:>9}",
                    o.meta.name,
                    o.spec.opt_str("cohort").unwrap_or("<none>"),
                    nominal,
                    o.status.opt_int("pending").unwrap_or(0),
                    o.status.opt_int("admitted").unwrap_or(0)
                );
            }
        }
        "Event" => {
            let mut evs: Vec<EventView> =
                items.iter().filter_map(|o| EventView::from_object(o).ok()).collect();
            evs.sort_by(|a, b| a.last_seen_s.total_cmp(&b.last_seen_s));
            println!(
                "{:<10} {:<8} {:<20} {:<26} {:<26} {:>5}  MESSAGE",
                "LAST SEEN", "TYPE", "REASON", "OBJECT", "COMPONENT", "COUNT"
            );
            for e in &evs {
                println!(
                    "{:<10} {:<8} {:<20} {:<26} {:<26} {:>5}  {}",
                    fmt_age(Duration::from_secs_f64((server_now - e.last_seen_s).max(0.0))),
                    e.etype,
                    e.reason,
                    format!("{}/{}", e.regarding_kind.to_lowercase(), e.regarding_name),
                    e.reporting_controller,
                    e.count,
                    e.note
                );
            }
        }
        "PodDisruptionBudget" => {
            println!(
                "{:<20} {:<6} {:<13} {:<15} {:>7}",
                "NAME", "AGE", "MIN-AVAILABLE", "MAX-UNAVAILABLE", "ALLOWED"
            );
            for o in items {
                let fmt = |v: Option<i64>| v.map(|n| n.to_string()).unwrap_or_else(|| "N/A".into());
                println!(
                    "{:<20} {:<6} {:<13} {:<15} {:>7}",
                    o.meta.name,
                    fmt_age(Duration::from_secs_f64((server_now - o.meta.creation_s).max(0.0))),
                    fmt(o.spec.opt_int("minAvailable")),
                    fmt(o.spec.opt_int("maxUnavailable")),
                    o.status.opt_int("disruptionsAllowed").unwrap_or(0)
                );
            }
        }
        "CustomResourceDefinition" => {
            println!("{:<28} {:<6} {:<16} {:<16}", "NAME", "AGE", "KIND", "PLURAL");
            for o in items {
                let names = o.spec.get("names");
                let name_of = |k: &str| {
                    names.and_then(|n| n.opt_str(k)).unwrap_or("").to_string()
                };
                println!(
                    "{:<28} {:<6} {:<16} {:<16}",
                    o.meta.name,
                    fmt_age(Duration::from_secs_f64((server_now - o.meta.creation_s).max(0.0))),
                    name_of("kind"),
                    name_of("plural")
                );
            }
        }
        "LocalQueue" => {
            println!(
                "{:<16} {:<16} {:>8} {:>9}",
                "NAME", "CLUSTERQUEUE", "PENDING", "ADMITTED"
            );
            for o in items {
                println!(
                    "{:<16} {:<16} {:>8} {:>9}",
                    o.meta.name,
                    o.spec.opt_str("clusterQueue").unwrap_or("<none>"),
                    o.status.opt_int("pending").unwrap_or(0),
                    o.status.opt_int("admitted").unwrap_or(0)
                );
            }
        }
        _ => {
            println!("{:<16} {:<6} {:<12}", "NAME", "AGE", "STATUS");
            for o in items {
                println!(
                    "{:<16} {:<6} {:<12}",
                    o.meta.name,
                    fmt_age(Duration::from_secs_f64((server_now - o.meta.creation_s).max(0.0))),
                    o.status.opt_str("phase").unwrap_or("")
                );
            }
        }
    }
}

fn wlm_call(args: &Args, method: &str, body: Value) -> Result<Value> {
    let sock = args.req_flag("socket")?;
    let client = RedboxClient::connect(sock)?;
    client.call(&format!("torque.Workload/{method}"), body)
}

pub fn cmd_qsub(args: &mut Args) -> Result<()> {
    let file = args.req_positional(1, "script file")?;
    let script = std::fs::read_to_string(file)?;
    let out = wlm_call(
        args,
        "SubmitJob",
        Value::map().with("script", script).with("user", args.flag_or("user", "cli")),
    )?;
    println!("{}", out.opt_str("jobId").unwrap_or(""));
    Ok(())
}

pub fn cmd_qstat(args: &mut Args) -> Result<()> {
    let job = args.req_positional(1, "job id")?;
    let out = wlm_call(args, "JobStatus", Value::map().with("jobId", job))?;
    println!(
        "{} {}",
        job,
        out.opt_str("state").unwrap_or("unknown")
    );
    Ok(())
}

pub fn cmd_qdel(args: &mut Args) -> Result<()> {
    let job = args.req_positional(1, "job id")?;
    wlm_call(args, "CancelJob", Value::map().with("jobId", job))?;
    println!("{job} deleted");
    Ok(())
}

/// Build a trace from `--kind` and its knobs — shared by `trace gen` and
/// `sim --kind K` (the latter was advertised in the usage text but never
/// implemented; the CI smoke run now exercises exactly this path).
fn gen_trace(kind: &str, args: &Args) -> Result<Trace> {
    let seed: u64 = args.num("seed", 42)?;
    let jobs: usize = args.num("jobs", 200)?;
    let mut g = TraceGen::new(seed);
    Ok(match kind {
        "poisson" => g.poisson_batch(jobs, args.num("capacity", 64)?, args.num("load", 0.7)?, args.num("mean-runtime", 120.0)?),
        "bursty" => g.bursty(jobs / 20, 20, 60.0),
        "cybele" => g.cybele_pilots(jobs / 10, jobs - jobs / 10, 1000.0),
        "showcase" => g.backfill_showcase(jobs / 5, args.num("capacity", 8)?),
        "tenants" => {
            let n: usize = args.num("tenants", 3)?;
            let names: Vec<String> = (0..n).map(|i| format!("tenant-{i:02}")).collect();
            let refs: Vec<&str> = names.iter().map(String::as_str).collect();
            g.multi_tenant(
                jobs,
                &refs,
                args.num("capacity", 64)?,
                args.num("load", 0.7)?,
                args.num("mean-runtime", 120.0)?,
            )
        }
        "diurnal" => g.diurnal(
            jobs,
            args.num("capacity", 64)?,
            args.num("load", 0.8)?,
            args.num("period", 3600.0)?,
            args.num("mean-runtime", 60.0)?,
        ),
        other => return Err(Error::config(format!("unknown trace kind `{other}`"))),
    })
}

/// `hpcorc metrics --socket PATH [--prom|--json]`: scrape a running
/// daemon's registry over the red-box socket (the `obs.Metrics` service).
pub fn cmd_metrics(args: &mut Args) -> Result<()> {
    let sock = args.req_flag("socket")?;
    let client = RedboxClient::connect(sock)?;
    if args.bool("prom") {
        let out = client.call("obs.Metrics/Prom", Value::Null)?;
        print!("{}", out.opt_str("text").unwrap_or(""));
        return Ok(());
    }
    let snap = client.call("obs.Metrics/Snapshot", Value::Null)?;
    if args.bool("json") {
        println!("{}", crate::encoding::json::to_string_pretty(&snap));
        return Ok(());
    }
    // Default: flat `name = value` listing (counters and gauges), then
    // histogram summaries.
    for section in ["counters", "gauges"] {
        if let Some(Value::Map(entries)) = snap.get(section) {
            for (k, v) in entries {
                println!("{k} = {}", crate::encoding::json::to_string(v));
            }
        }
    }
    if let Some(Value::Map(hists)) = snap.get("hists") {
        for (k, h) in hists {
            println!(
                "{k}: count={} mean={:.0} p50={} p95={} p99={} max={}",
                h.opt_int("count").unwrap_or(0),
                h.get("mean").and_then(Value::as_f64).unwrap_or(0.0),
                h.opt_int("p50").unwrap_or(0),
                h.opt_int("p95").unwrap_or(0),
                h.opt_int("p99").unwrap_or(0),
                h.opt_int("max").unwrap_or(0),
            );
        }
    }
    Ok(())
}

/// `hpcorc trace KIND/NAME --socket PATH`: reconstruct an object's
/// lifecycle timeline from its originating trace (the `hpcorc.io/trace`
/// annotation) and the daemon's span ring (`obs.Spans/ByTrace`).
fn cmd_trace_timeline(args: &Args, kind_name: &str) -> Result<()> {
    let (alias, name) = kind_name
        .split_once('/')
        .ok_or_else(|| Error::config("expected KIND/NAME"))?;
    let kind = resolve_kind(alias);
    let api = remote(args)?;
    let obj = api.get(&kind, name)?;
    print_trace_timeline(args, &kind, name, &obj)
}

/// Fetch + render the span timeline for an already-fetched object —
/// shared by `hpcorc trace KIND/NAME` and `kubectl describe`.
fn print_trace_timeline(args: &Args, kind: &str, name: &str, obj: &KubeObject) -> Result<()> {
    let Some(wire) = obj.meta.annotation(crate::obs::TRACE_ANNOTATION) else {
        return Err(Error::config(format!(
            "{kind}/{name} carries no `{}` annotation (created before tracing, or tracing disabled)",
            crate::obs::TRACE_ANNOTATION
        )));
    };
    let ctx = crate::obs::TraceContext::parse_wire(wire)
        .ok_or_else(|| Error::parse(format!("malformed trace annotation `{wire}`")))?;
    let sock = args.req_flag("socket")?;
    let client = RedboxClient::connect(sock)?;
    let out = client.call(
        "obs.Spans/ByTrace",
        Value::map().with("trace", format!("{:016x}", ctx.trace_id)),
    )?;
    let events = out.get("events").and_then(Value::as_seq).map(<[Value]>::to_vec).unwrap_or_default();
    if args.bool("json") {
        // Raw Chrome trace-event JSON — load it straight into Perfetto.
        println!("{}", crate::encoding::json::to_string_pretty(&Value::Seq(events)));
        return Ok(());
    }
    if events.is_empty() {
        println!(
            "trace {:016x}: no spans retained (the ring holds the last {} spans)",
            ctx.trace_id,
            crate::obs::trace::RING_CAPACITY
        );
        return Ok(());
    }
    // Rebuild the causal tree: ts-sorted rows, indented by parent depth.
    let field = |e: &Value, k: &str| -> u64 {
        e.get("args")
            .and_then(|a| a.opt_str(k).map(String::from))
            .and_then(|s| u64::from_str_radix(&s, 16).ok())
            .unwrap_or(0)
    };
    let mut rows: Vec<(u64, u64, u64, String, String, i64)> = events
        .iter()
        .map(|e| {
            (
                field(e, "span_id"),
                field(e, "parent"),
                e.opt_int("ts").unwrap_or(0) as u64,
                e.opt_str("cat").unwrap_or("?").to_string(),
                e.opt_str("name").unwrap_or("?").to_string(),
                e.opt_int("dur").unwrap_or(0),
            )
        })
        .collect();
    rows.sort_by_key(|r| (r.2, r.0));
    let ids: std::collections::BTreeMap<u64, u64> =
        rows.iter().map(|r| (r.0, r.1)).collect();
    let depth = |mut span: u64| -> usize {
        let mut d = 0;
        // Parent chain walk; the ring may have evicted ancestors, so a
        // missing parent just terminates the walk.
        while let Some(&p) = ids.get(&span) {
            if p == 0 || !ids.contains_key(&p) || d > 32 {
                break;
            }
            d += 1;
            span = p;
        }
        d
    };
    let t0 = rows.iter().map(|r| r.2).min().unwrap_or(0);
    println!("trace {:016x} — {kind}/{name} ({} spans)", ctx.trace_id, rows.len());
    for (span_id, _, ts, cat, sname, dur) in &rows {
        println!(
            "{:>10.3}ms {}{} [{cat}] {sname} ({dur}us)",
            (*ts - t0) as f64 / 1000.0,
            "  ".repeat(depth(*span_id)),
            if depth(*span_id) == 0 { "•" } else { "└" },
        );
    }
    Ok(())
}

/// `hpcorc audit --socket PATH [--since SEQ] [--kind KIND] [--json]`:
/// query the daemon's mutating-request audit trail (the `obs.Audit`
/// red-box service). `--since` is an exclusive sequence cursor —
/// re-running with the last printed SEQ yields only new records.
pub fn cmd_audit(args: &mut Args) -> Result<()> {
    let sock = args.req_flag("socket")?;
    let client = RedboxClient::connect(sock)?;
    let since: u64 = args.num("since", 0)?;
    let mut body = Value::map().with("since", since);
    if let Some(kind) = args.flag("kind") {
        body.insert("kind", resolve_kind(kind));
    }
    let out = client.call("obs.Audit/Query", body)?;
    let records = out
        .get("records")
        .and_then(Value::as_seq)
        .map(<[Value]>::to_vec)
        .unwrap_or_default();
    if args.bool("json") {
        println!("{}", crate::encoding::json::to_string_pretty(&Value::Seq(records)));
        return Ok(());
    }
    println!(
        "{:>5} {:<13} {:<14} {:<26} {:<26} {:<10} {:>9}  TRACE",
        "SEQ", "VERB", "KIND", "NAME", "ACTOR", "OUTCOME", "LATENCY"
    );
    for r in &records {
        let lat_us = r.opt_int("latencyNs").unwrap_or(0) as f64 / 1000.0;
        println!(
            "{:>5} {:<13} {:<14} {:<26} {:<26} {:<10} {:>7.1}us  {}",
            r.opt_int("seq").unwrap_or(0),
            r.opt_str("verb").unwrap_or("?"),
            r.opt_str("kind").unwrap_or("?"),
            r.opt_str("name").unwrap_or("?"),
            r.opt_str("actor").unwrap_or("?"),
            r.opt_str("outcome").unwrap_or("?"),
            lat_us,
            r.opt_str("trace").unwrap_or("-"),
        );
    }
    Ok(())
}

pub fn cmd_chaos(args: &mut Args) -> Result<()> {
    let seed: u64 = args.num("seed", 7)?;
    let json = args.bool("json");
    let reports = match args.flag("scenario") {
        Some(name) => vec![crate::chaos::run_scenario(name, seed)?],
        None => {
            let mut out = Vec::new();
            for sc in crate::chaos::scenarios() {
                out.push(crate::chaos::run_scenario(sc.name, seed)?);
            }
            out
        }
    };
    let mut diverged = 0usize;
    for r in &reports {
        if json {
            println!("{}", r.to_json());
        } else {
            print!("{}", r.render());
        }
        if !r.converged() {
            diverged += 1;
        }
    }
    if diverged > 0 {
        return Err(Error::internal(format!(
            "{diverged}/{} chaos scenarios diverged from the golden transcript",
            reports.len()
        )));
    }
    Ok(())
}

pub fn cmd_trace(args: &mut Args) -> Result<()> {
    let sub = args.req_positional(1, "trace subcommand")?.to_string();
    // `trace Pod/my-pod --socket S` reads a lifecycle timeline off a
    // running daemon; `trace gen` synthesizes workload traces.
    if sub.contains('/') {
        return cmd_trace_timeline(args, &sub);
    }
    if sub != "gen" {
        return Err(Error::config("expected `trace gen` or `trace KIND/NAME --socket PATH`"));
    }
    let trace = gen_trace(&args.flag_or("kind", "poisson"), args)?;
    let text = trace.to_json();
    match args.flag("out") {
        Some(path) => {
            std::fs::write(path, text)?;
            println!("wrote {} jobs to {path}", trace.len());
        }
        None => println!("{text}"),
    }
    Ok(())
}

pub fn cmd_sim(args: &mut Args) -> Result<()> {
    // `--trace FILE` replays a file; otherwise generate in place with the
    // same defaults as `trace gen` — bare `sim` and `sim --kind poisson`
    // must run the identical workload (e.g. `sim --kind tenants
    // --quota-nodes 4` for the kueue path).
    let trace = match (args.flag("trace"), args.flag("kind")) {
        (Some(path), _) => Trace::from_json(&std::fs::read_to_string(path)?)?,
        (None, kind) => gen_trace(kind.unwrap_or("poisson"), args)?,
    };
    let elastic_max: usize = args.num("elastic-max", 0)?;
    let params = SimParams {
        nodes: args.num("nodes", 16)?,
        cores_per_node: args.num("cores", 8)?,
        elastic: (elastic_max > 0).then_some(crate::sim::ElasticParams {
            min_nodes: args.num("elastic-min", 1)?,
            max_nodes: elastic_max,
            provision_delay_s: args.num("provision-delay", 30.0)?,
            scale_down_idle_s: args.num("idle-window", 300.0)?,
        }),
        ..SimParams::default()
    };
    let mut policy = policy_by_name(&args.flag_or("policy", "easy"))?;
    // Queue layer (PR 2): meter every tenant queue in the trace through a
    // ClusterQueue of --quota-nodes, optionally pooled into one cohort.
    let quota_nodes: u32 = args.num("quota-nodes", 0)?;
    if quota_nodes > 0 {
        let cohort = args.bool("cohort").then_some("pool");
        let mut tenants: Vec<String> =
            trace.jobs.iter().filter_map(|j| j.queue.clone()).collect();
        tenants.sort();
        tenants.dedup();
        if tenants.is_empty() {
            return Err(Error::config(
                "--quota-nodes needs a trace with per-tenant queue labels (trace gen --kind tenants)",
            ));
        }
        let queues = tenants
            .iter()
            .map(|t| {
                ClusterQueueView::from_object(&ClusterQueueView::build_full(
                    t,
                    cohort,
                    QueueResources::nodes(quota_nodes),
                    None,
                    QueueOrdering::Fifo,
                    Default::default(),
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        policy = Box::new(QueueAdmission::new(queues, policy));
    }
    let report = simulate(&trace, &params, policy.as_ref());
    println!("{}", report.row());
    Ok(())
}

pub fn cmd_sing(args: &mut Args) -> Result<()> {
    let sub = args.req_positional(1, "sing subcommand")?;
    match sub {
        "list" => {
            let images = crate::singularity::ImageRegistry::with_defaults();
            for name in images.list() {
                println!("{name}");
            }
            Ok(())
        }
        other => Err(Error::config(format!("unknown sing subcommand `{other}`"))),
    }
}

pub fn cmd_version(args: &mut Args) -> Result<()> {
    println!("hpcorc {} — Torque-Operator reproduction", env!("CARGO_PKG_VERSION"));
    if args.bool("components") {
        // Paper Table I: the core applications of the testbed → our modules.
        println!("\nTable I — core applications of the testbed:");
        println!("  {:<34} {}", "Orchestrator", "kube (Kubernetes-like), pbs (Torque)");
        println!("  {:<34} {}", "Container runtime & its support", "singularity (SIF runtime), singularity::cri (Singularity-CRI)");
        println!("  {:<34} {}", "Operator", "operator (Torque-Operator, WLM-Operator)");
        println!("  {:<34} {}", "Compiler", "rustc (Golang in the paper); python/jax AOT for payloads");
    }
    Ok(())
}
