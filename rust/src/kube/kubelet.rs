//! kubelet: the per-worker node agent.
//!
//! Registers its Node object, then reconciles pods bound to it: starts
//! containers through the CRI (Singularity-CRI here — paper Table I),
//! tracks them to completion, and writes phase/exit-code/logs back through
//! the API server.

use super::api::{NodeView, PodPhase, PodView, KIND_NODE, KIND_POD};
use super::client::ApiClient;
use super::events::{EventRecorder, EVENT_NORMAL, EVENT_WARNING};
use super::informer::{Informer, SharedInformerFactory};
use crate::cluster::{Metrics, Resources, SharedFs};
use crate::rt::{self, Shutdown};
use crate::singularity::{ContainerId, ContainerSpec, ContainerStatus, Cri};
use crate::util::Result;
use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Component name stamped on events and audit records this agent writes.
const COMPONENT: &str = "kubelet";

pub struct Kubelet<C: Cri> {
    api: Arc<dyn ApiClient>,
    /// Shared pod cache, read through the `spec.nodeName` index — the
    /// kubelet never lists; it sees exactly its node's pods.
    pods: Informer,
    /// Shared PodMetrics cache for write-suppressed sample publishing.
    podmetrics: Informer,
    node_name: String,
    capacity: Resources,
    cri: C,
    fs: SharedFs,
    time_scale: f64,
    /// pod name → (container, owning pod uid). The uid guards against a
    /// pod deleted and recreated under the same name between syncs: the
    /// new pod must never adopt the old pod's container.
    running: Arc<Mutex<HashMap<String, (ContainerId, u64)>>>,
    /// Pods whose container was ordered stopped by the reap path but has
    /// not exited yet — the adoption arm must not resurrect these.
    stopping: Arc<Mutex<HashSet<String>>>,
    /// pod name → the pod's `hpcorc.io/trace` annotation, remembered at
    /// start time so Killing/Reaped events still carry the trace after
    /// the pod object itself has been deleted from the store.
    traces: Arc<Mutex<HashMap<String, String>>>,
    events: EventRecorder,
    metrics: Metrics,
}

impl<C: Cri + Clone + Send + 'static> Kubelet<C> {
    /// Register the Node object and return the kubelet. Reads (this
    /// node's pods, metrics samples) come from the factory's shared
    /// caches; writes go through the factory's client.
    pub fn register(
        informers: &SharedInformerFactory,
        node_name: &str,
        capacity: Resources,
        labels: &[(&str, &str)],
        cri: C,
        fs: SharedFs,
        time_scale: f64,
        metrics: Metrics,
    ) -> Result<Kubelet<C>> {
        let api = informers.client();
        let pods = informers.informer(KIND_POD);
        pods.ensure_field_index("spec.nodeName");
        let podmetrics = informers.informer(crate::autoscale::KIND_PODMETRICS);
        podmetrics.ensure_field_index("spec.nodeName");
        let mut node = NodeView::build(node_name, capacity, &[]);
        for (k, v) in labels {
            node.meta.set_label(k, v);
        }
        node.status.insert("runtime", cri.runtime_name());
        // Apply, not create: re-registration over a WAL-recovered store
        // (PR 6) — or a kubelet restart — refreshes the existing Node
        // instead of failing AlreadyExists.
        api.apply(node)?;
        Ok(Kubelet {
            api,
            pods,
            podmetrics,
            node_name: node_name.to_string(),
            capacity,
            cri,
            fs,
            time_scale,
            running: Arc::new(Mutex::new(HashMap::new())),
            stopping: Arc::new(Mutex::new(HashSet::new())),
            traces: Arc::new(Mutex::new(HashMap::new())),
            events: EventRecorder::new(COMPONENT, metrics.clone()),
            metrics,
        })
    }

    /// Run as a daemon with the given sync period.
    pub fn start(self, period: Duration, shutdown: Shutdown)
    where
        C: Sync,
    {
        let name = format!("kubelet-{}", self.node_name);
        rt::spawn_named(&name, move || loop {
            if shutdown.wait_timeout(period) {
                return;
            }
            self.sync_once();
        });
    }

    /// One reconcile pass; returns (started, completed). Public for
    /// deterministic stepping.
    pub fn sync_once(&self) -> (usize, usize) {
        let mut started = 0;
        let mut completed = 0;
        // Every write this pass makes is attributed to the kubelet in the
        // API server's audit trail (PR 8).
        let _actor = crate::obs::push_actor(COMPONENT);
        // Node-indexed cache read: only pods bound to this node, straight
        // off the shared informer's `spec.nodeName` index — no list RPC,
        // and the kubelet never sees the rest of the cluster.
        if let Err(e) = self.pods.sync() {
            // A broken transport must not masquerade as an idle node.
            self.metrics.inc("kubelet.list_errors");
            crate::warn!("kubelet", "{}: pod informer sync failed: {e}", self.node_name);
            return (0, 0);
        }
        let bound = self.pods.list_by_field("spec.nodeName", &self.node_name);
        for obj in &bound {
            let Ok(view) = PodView::from_object(obj) else { continue };
            let pod_name = view.name.clone();
            let has_container = self.running.lock().unwrap().contains_key(&pod_name);
            match (view.phase, has_container) {
                (PodPhase::Pending, false) => {
                    let mut spec = ContainerSpec::new(&pod_name, &view.image);
                    spec.env = view.env.clone();
                    spec.seed = obj.meta.uid;
                    spec.time_scale = self.time_scale;
                    match self.cri.start(spec, self.fs.clone()) {
                        Ok(id) => {
                            self.running
                                .lock()
                                .unwrap()
                                .insert(pod_name.clone(), (id, obj.meta.uid));
                            let _ = self.api.update_status(KIND_POD, &pod_name, &|o| {
                                o.status.insert("phase", "Running");
                                o.status.insert("hostNode", self.node_name.clone());
                            });
                            self.metrics.inc("kubelet.pods_started");
                            if let Some(t) =
                                obj.meta.annotation(crate::obs::TRACE_ANNOTATION)
                            {
                                self.traces
                                    .lock()
                                    .unwrap()
                                    .insert(pod_name.clone(), t.to_string());
                            }
                            let _ = self.events.event(
                                &self.api,
                                obj,
                                EVENT_NORMAL,
                                "Pulled",
                                &format!(
                                    "Container image \"{}\" already present on machine",
                                    view.image
                                ),
                            );
                            let _ = self.events.event(
                                &self.api,
                                obj,
                                EVENT_NORMAL,
                                "Started",
                                &format!(
                                    "Started container {pod_name} (image {}) on {}",
                                    view.image, self.node_name
                                ),
                            );
                            started += 1;
                        }
                        Err(e) => {
                            let msg = e.to_string();
                            let _ = self.api.update_status(KIND_POD, &pod_name, &|o| {
                                o.status.insert("phase", "Failed");
                                o.status.insert("reason", msg.clone());
                            });
                            self.metrics.inc("kubelet.pod_start_failures");
                            let _ = self.events.event(
                                &self.api,
                                obj,
                                EVENT_WARNING,
                                "FailedStart",
                                &format!("Failed to start container: {msg}"),
                            );
                        }
                    }
                }
                (PodPhase::Running, true) => {
                    let (id, _) = *self.running.lock().unwrap().get(&pod_name).unwrap();
                    match self.cri.status(id) {
                        Ok(ContainerStatus::Exited(res)) => {
                            let phase =
                                if res.success() { "Succeeded" } else { "Failed" };
                            let _ = self.api.update_status(KIND_POD, &pod_name, &|o| {
                                o.status.insert("phase", phase);
                                o.status.insert("exitCode", res.exit_code as i64);
                                o.status.insert("log", res.stdout.clone());
                                if !res.stderr.is_empty() {
                                    o.status.insert("logErr", res.stderr.clone());
                                }
                            });
                            let _ = self.cri.remove(id);
                            self.running.lock().unwrap().remove(&pod_name);
                            self.traces.lock().unwrap().remove(&pod_name);
                            self.metrics.inc("kubelet.pods_completed");
                            completed += 1;
                        }
                        Ok(ContainerStatus::Failed(msg)) => {
                            let _ = self.api.update_status(KIND_POD, &pod_name, &|o| {
                                o.status.insert("phase", "Failed");
                                o.status.insert("reason", msg.clone());
                            });
                            let _ = self.cri.remove(id);
                            self.running.lock().unwrap().remove(&pod_name);
                            self.traces.lock().unwrap().remove(&pod_name);
                            completed += 1;
                        }
                        _ => {}
                    }
                }
                (PodPhase::Pending, true) => {
                    let (id, owner_uid) = *self.running.lock().unwrap().get(&pod_name).unwrap();
                    let stale = owner_uid != obj.meta.uid
                        || self.stopping.lock().unwrap().contains(&pod_name);
                    if stale {
                        // Dying (reap already ordered a stop) or owned by
                        // a deleted pod that was recreated under the same
                        // name: never adopt — stop it and finish the
                        // teardown so a later sync starts a fresh one.
                        let _ = self.cri.stop(id);
                        if self.stopping.lock().unwrap().insert(pod_name.clone()) {
                            self.kill_event(&pod_name, "Killing", "Stopping container: pod was deleted and recreated under the same name");
                        }
                        if matches!(self.cri.status(id), Ok(ContainerStatus::Exited(_))) {
                            let _ = self.cri.remove(id);
                            self.running.lock().unwrap().remove(&pod_name);
                            self.stopping.lock().unwrap().remove(&pod_name);
                            self.kill_event(&pod_name, "Reaped", "Removed stale container");
                            self.traces.lock().unwrap().remove(&pod_name);
                        }
                    } else {
                        // The phase=Running write from a previous start
                        // failed. The container is ours and healthy, so
                        // adopt it — retry the write instead of killing
                        // it; completion flows through the normal
                        // (Running, true) arm on a later sync.
                        let _ = self.api.update_status(KIND_POD, &pod_name, &|o| {
                            o.status.insert("phase", "Running");
                            o.status.insert("hostNode", self.node_name.clone());
                        });
                    }
                }
                _ => {}
            }
        }
        // Reap containers whose pods were deleted out from under us
        // (absent from the cache) or are no longer bound to this node —
        // an evicted (queue-layer preemption) or rebound pod must not
        // leave a zombie container running off the scheduler's books. The
        // cache is authoritative here: a sync failure returned above, so
        // a transport error can never read as "stop every container on
        // the node".
        let dangling: Vec<(String, ContainerId)> = {
            let running = self.running.lock().unwrap();
            running
                .iter()
                .filter(|(pod, _)| match self.pods.get(pod) {
                    None => true,
                    Some(o) => o.spec.opt_str("nodeName") != Some(self.node_name.as_str()),
                })
                .map(|(p, (id, _))| (p.clone(), *id))
                .collect()
        };
        for (pod, id) in dangling {
            let _ = self.cri.stop(id);
            if self.stopping.lock().unwrap().insert(pod.clone()) {
                self.kill_event(&pod, "Killing", "Stopping container: pod deleted or no longer bound to this node");
            }
            // remove() once it exits; next sync pass will retry until then.
            if matches!(self.cri.status(id), Ok(ContainerStatus::Exited(_))) {
                let _ = self.cri.remove(id);
                self.running.lock().unwrap().remove(&pod);
                self.stopping.lock().unwrap().remove(&pod);
                self.kill_event(&pod, "Reaped", "Removed container for deleted/unbound pod");
                self.traces.lock().unwrap().remove(&pod);
            }
        }
        // Metrics pipeline (autoscale layer): sample this node's pods and
        // publish NodeMetrics/PodMetrics — write-free when nothing
        // changed, and read-free too: both the pod view and the existing
        // samples come from shared caches. A phase written above is
        // observed one sync later, the usual level-triggered lag.
        crate::autoscale::publish_node_sample(
            self.api.as_ref(),
            &self.podmetrics,
            &self.node_name,
            self.capacity,
            &bound,
            &self.metrics,
        );
        (started, completed)
    }

    /// Emit a teardown-path event for `pod`. The pod object is usually
    /// gone from the store by now, so the event references it by name and
    /// carries the trace remembered at start time.
    fn kill_event(&self, pod: &str, reason: &str, note: &str) {
        let trace = self.traces.lock().unwrap().get(pod).cloned();
        let _ = self.events.event_ref(
            &self.api,
            KIND_POD,
            pod,
            trace.as_deref(),
            EVENT_NORMAL,
            reason,
            note,
        );
    }

    /// Heartbeat the Node object (mark Ready).
    pub fn heartbeat(&self) {
        let _ = self.api.update_status(KIND_NODE, &self.node_name, &|o| {
            o.status.insert("phase", "Ready");
        });
    }

    pub fn node_name(&self) -> &str {
        &self.node_name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kube::apiserver::ApiServer;
    use crate::singularity::{
        ImageRegistry, Payload, Runtime, RuntimeKind, SifImage, SingularityCri,
    };

    fn setup() -> (ApiServer, Kubelet<Arc<SingularityCri>>) {
        let api = ApiServer::new(Metrics::new());
        let reg = ImageRegistry::with_defaults();
        reg.push(SifImage::new("slow.sif", Payload::Sleep { millis: 60_000 }));
        reg.push(SifImage::new("bad.sif", Payload::Fail { exit_code: 3 }));
        let cri = SingularityCri::new(Runtime::new(
            RuntimeKind::Singularity,
            reg,
            Metrics::new(),
        ));
        let informers = SharedInformerFactory::new(api.client(), Metrics::new());
        let kubelet = Kubelet::register(
            &informers,
            "w1",
            Resources::cores(8, 32 << 30),
            &[],
            cri,
            SharedFs::new(),
            1.0,
            Metrics::new(),
        )
        .unwrap();
        (api, kubelet)
    }

    fn bound_pod(api: &ApiServer, name: &str, image: &str) {
        let mut pod = PodView::build(name, image, Resources::ZERO, &[]);
        pod.spec.insert("nodeName", "w1");
        api.create(pod).unwrap();
    }

    fn phase(api: &ApiServer, name: &str) -> String {
        api.get(KIND_POD, name).unwrap().status.opt_str("phase").unwrap_or("").to_string()
    }

    fn drive_until<F: Fn() -> bool>(kubelet: &Kubelet<Arc<SingularityCri>>, pred: F) {
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while !pred() {
            assert!(std::time::Instant::now() < deadline, "kubelet never converged");
            kubelet.sync_once();
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn node_registered_with_runtime() {
        let (api, _kubelet) = setup();
        let node = NodeView::from_object(&api.get(KIND_NODE, "w1").unwrap()).unwrap();
        assert_eq!(node.runtime, "singularity-cri");
        assert_eq!(node.capacity.cpu_milli, 8000);
    }

    #[test]
    fn pod_lifecycle_success() {
        let (api, kubelet) = setup();
        bound_pod(&api, "p1", "lolcow_latest.sif");
        let (started, _) = kubelet.sync_once();
        assert_eq!(started, 1);
        assert_eq!(phase(&api, "p1"), "Running");
        drive_until(&kubelet, || phase(&api, "p1") == "Succeeded");
        let o = api.get(KIND_POD, "p1").unwrap();
        assert_eq!(o.status.opt_int("exitCode"), Some(0));
        assert!(o.status.opt_str("log").unwrap().contains("Moo"));
    }

    #[test]
    fn pod_failure_reported() {
        let (api, kubelet) = setup();
        bound_pod(&api, "pf", "bad.sif");
        drive_until(&kubelet, || phase(&api, "pf") == "Failed");
        assert_eq!(api.get(KIND_POD, "pf").unwrap().status.opt_int("exitCode"), Some(3));
    }

    #[test]
    fn missing_image_fails_fast() {
        let (api, kubelet) = setup();
        bound_pod(&api, "px", "ghost.sif");
        kubelet.sync_once();
        assert_eq!(phase(&api, "px"), "Failed");
        assert!(api
            .get(KIND_POD, "px")
            .unwrap()
            .status
            .opt_str("reason")
            .unwrap()
            .contains("image not found"));
    }

    #[test]
    fn ignores_pods_for_other_nodes() {
        let (api, kubelet) = setup();
        let mut pod = PodView::build("other", "lolcow_latest.sif", Resources::ZERO, &[]);
        pod.spec.insert("nodeName", "w2");
        api.create(pod).unwrap();
        let (started, _) = kubelet.sync_once();
        assert_eq!(started, 0);
    }

    #[test]
    fn unbound_pod_container_reaped_and_pod_restartable() {
        let (api, kubelet) = setup();
        bound_pod(&api, "pe", "slow.sif");
        kubelet.sync_once();
        assert_eq!(phase(&api, "pe"), "Running");
        // Queue-layer eviction: unbind and reset the phase (what
        // kueue::evict_gang writes). The container must be reaped.
        api.update_status(KIND_POD, "pe", |o| {
            o.spec.remove("nodeName");
            o.status.insert("phase", "Pending");
        })
        .unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while kubelet.running.lock().unwrap().contains_key("pe") {
            assert!(std::time::Instant::now() < deadline, "zombie container never reaped");
            kubelet.sync_once();
            std::thread::sleep(Duration::from_millis(2));
        }
        // Re-admission re-binds the pod: a fresh container starts (the
        // pod must not wedge in Pending on its old container entry).
        api.update_status(KIND_POD, "pe", |o| {
            o.spec.insert("nodeName", "w1");
        })
        .unwrap();
        let (started, _) = kubelet.sync_once();
        assert_eq!(started, 1, "evicted pod restarts after re-binding");
        assert_eq!(phase(&api, "pe"), "Running");
    }

    #[test]
    fn sync_publishes_node_and_pod_metrics() {
        use crate::autoscale::{NodeMetricsView, KIND_NODEMETRICS, KIND_PODMETRICS};
        let (api, kubelet) = setup();
        let mut pod = PodView::build(
            "pm",
            "slow.sif",
            Resources::new(750, 1 << 20, 0),
            &[(crate::autoscale::CPU_LOAD_ENV.to_string(), "600".to_string())],
        );
        pod.spec.insert("nodeName", "w1");
        api.create(pod).unwrap();
        kubelet.sync_once(); // starts the container (phase -> Running)
        kubelet.sync_once(); // observes Running, publishes the sample
        let nm = NodeMetricsView::from_object(&api.get(KIND_NODEMETRICS, "w1").unwrap())
            .unwrap();
        assert_eq!(nm.usage_cpu_milli, 600);
        assert_eq!(nm.capacity.cpu_milli, 8000);
        assert!(api.get(KIND_PODMETRICS, "pm").is_ok());
        // Once the pod stops running its metrics are reaped.
        api.update_status(KIND_POD, "pm", |o| {
            o.status.insert("phase", "Succeeded");
        })
        .unwrap();
        kubelet.sync_once();
        assert!(api.get(KIND_PODMETRICS, "pm").is_err(), "stale sample reaped");
    }

    #[test]
    fn lifecycle_emits_started_killing_reaped_with_trace() {
        use crate::kube::events::{EventView, EVENT_NORMAL, KIND_EVENT};
        use crate::kube::client::ListOptions;
        let (api, kubelet) = setup();
        let mut pod = PodView::build("pt", "slow.sif", Resources::ZERO, &[]);
        pod.spec.insert("nodeName", "w1");
        pod.meta.set_annotation(
            crate::obs::TRACE_ANNOTATION,
            "00000000deadbeef-0000000000000001",
        );
        api.create(pod).unwrap();
        kubelet.sync_once();
        assert_eq!(phase(&api, "pt"), "Running");
        api.delete(KIND_POD, "pt").unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while kubelet.running.lock().unwrap().contains_key("pt") {
            assert!(std::time::Instant::now() < deadline);
            kubelet.sync_once();
            std::thread::sleep(Duration::from_millis(2));
        }
        let events: Vec<EventView> = api
            .client()
            .list(KIND_EVENT, &ListOptions::all())
            .unwrap()
            .items
            .iter()
            .map(|o| EventView::from_object(o).unwrap())
            .collect();
        for reason in ["Started", "Killing", "Reaped"] {
            let ev = events
                .iter()
                .find(|e| e.reason == reason)
                .unwrap_or_else(|| panic!("missing {reason} event"));
            assert_eq!(ev.regarding_kind, KIND_POD);
            assert_eq!(ev.regarding_name, "pt");
            assert_eq!(ev.etype, EVENT_NORMAL);
            assert_eq!(ev.reporting_controller, COMPONENT);
            assert_eq!(
                ev.trace.as_deref(),
                Some("00000000deadbeef-0000000000000001"),
                "{reason} event must carry the pod's trace even after deletion"
            );
        }
    }

    #[test]
    fn deleted_pod_container_reaped() {
        let (api, kubelet) = setup();
        bound_pod(&api, "pd", "slow.sif");
        kubelet.sync_once();
        assert_eq!(phase(&api, "pd"), "Running");
        api.delete(KIND_POD, "pd").unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while kubelet.running.lock().unwrap().contains_key("pd") {
            assert!(std::time::Instant::now() < deadline);
            kubelet.sync_once();
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}
