"""L2: the crop-yield forecasting transformer (the CYBELE-pilot stand-in).

The paper's testbed serves the EU CYBELE project (precision agriculture);
its pilots are the intended benchmarks (§V). As the substitution, the
containerised HPC jobs train/serve this model: a small encoder transformer
regressing crop yield from a season of synthetic weather/soil observations.

Shape: x (batch, seq, features) -> dense embed -> L x [pre-LN attention
(Pallas kernel) + pre-LN MLP (Pallas fused matmul+GELU)] -> mean-pool ->
linear head -> yhat (batch,).

Ground truth comes from a frozen random *teacher* network, so the loss has
real signal and the e2e example's loss curve demonstrably decreases.

Everything here runs at BUILD TIME only: aot.py lowers `init_fn`,
`train_step_fn` and `infer_fn` to HLO text executed from Rust via PJRT.
The train step generates its own batch from the step index, so the Rust
hot path passes only (params..., step).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.attention import attention
from .kernels.matmul_gelu import matmul_gelu
from .kernels import ref

CONFIGS = {
    # name: (d_model, n_heads, n_layers, d_ff, seq, features, batch, lr)
    "tiny": dict(d_model=64, n_heads=4, n_layers=2, d_ff=128, seq=16, features=8, batch=16, lr=3e-2),
    "small": dict(d_model=128, n_heads=8, n_layers=2, d_ff=256, seq=16, features=8, batch=32, lr=2e-2),
    # 'base' approaches real pilot scale; exported with aot.py --full.
    "base": dict(d_model=512, n_heads=8, n_layers=8, d_ff=2048, seq=32, features=8, batch=32, lr=1e-2),
}


# ------------------------------------------------------------------ params

def init_params(key, cfg):
    """Initialise parameters as a flat list of arrays (PJRT-friendly)."""
    d, ff, layers = cfg["d_model"], cfg["d_ff"], cfg["n_layers"]
    feats = cfg["features"]
    keys = jax.random.split(key, 4 + layers * 8)
    scale = lambda fan_in: 1.0 / jnp.sqrt(jnp.float32(fan_in))
    params = [
        jax.random.normal(keys[0], (feats, d)) * scale(feats),  # embed w
        jnp.zeros((1, d)),                                      # embed b
    ]
    ki = 4
    for _ in range(layers):
        params += [
            jax.random.normal(keys[ki], (d, 3 * d)) * scale(d),   # qkv
            jnp.zeros((1, 3 * d)),
            jax.random.normal(keys[ki + 1], (d, d)) * scale(d),   # attn out
            jnp.zeros((1, d)),
            jax.random.normal(keys[ki + 2], (d, ff)) * scale(d),  # mlp in
            jnp.zeros((1, ff)),
            jax.random.normal(keys[ki + 3], (ff, d)) * scale(ff), # mlp out
            jnp.zeros((1, d)),
        ]
        ki += 4
    params += [
        jax.random.normal(keys[1], (d, 1)) * scale(d),  # head w
        jnp.zeros((1, 1)),                              # head b
    ]
    return params


def n_layer_params():
    return 8


# ----------------------------------------------------------------- forward

def _layernorm(x):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-6)


def forward(params, x, cfg):
    """x: (batch, seq, features) f32 -> yhat (batch,) f32."""
    d, heads, layers = cfg["d_model"], cfg["n_heads"], cfg["n_layers"]
    b, s, _ = x.shape
    hd = d // heads
    embed_w, embed_b = params[0], params[1]
    # Embedding projection via the fused kernel (no activation).
    h = matmul_gelu(x.reshape(b * s, -1), embed_w, embed_b, "none").reshape(b, s, d)
    idx = 2
    for _ in range(layers):
        qkv_w, qkv_b, out_w, out_b, in_w, in_b, dn_w, dn_b = params[idx : idx + 8]
        idx += 8
        # --- attention block (pre-LN, residual) ---
        hn = _layernorm(h)
        qkv = matmul_gelu(hn.reshape(b * s, d), qkv_w, qkv_b, "none")
        qkv = qkv.reshape(b, s, 3, heads, hd)
        # (b, s, 3, H, hd) -> three (b*H, s, hd)
        q = qkv[:, :, 0].transpose(0, 2, 1, 3).reshape(b * heads, s, hd)
        k = qkv[:, :, 1].transpose(0, 2, 1, 3).reshape(b * heads, s, hd)
        v = qkv[:, :, 2].transpose(0, 2, 1, 3).reshape(b * heads, s, hd)
        att = attention(q, k, v, False)  # Pallas online-softmax kernel
        att = att.reshape(b, heads, s, hd).transpose(0, 2, 1, 3).reshape(b * s, d)
        h = h + matmul_gelu(att, out_w, out_b, "none").reshape(b, s, d)
        # --- MLP block (pre-LN, residual); fused matmul+GELU kernel ---
        hn = _layernorm(h).reshape(b * s, d)
        mid = matmul_gelu(hn, in_w, in_b, "gelu")
        h = h + matmul_gelu(mid, dn_w, dn_b, "none").reshape(b, s, d)
    pooled = _layernorm(h).mean(axis=1)  # (b, d)
    head_w, head_b = params[-2], params[-1]
    yhat = pooled @ head_w + head_b
    return yhat[:, 0]


def forward_ref(params, x, cfg):
    """Same network with pure-jnp oracles instead of Pallas kernels —
    the L2 correctness ground truth used by python/tests."""
    d, heads, layers = cfg["d_model"], cfg["n_heads"], cfg["n_layers"]
    b, s, _ = x.shape
    hd = d // heads
    h = ref.matmul_gelu_ref(x.reshape(b * s, -1), params[0], params[1], "none").reshape(b, s, d)
    idx = 2
    for _ in range(layers):
        qkv_w, qkv_b, out_w, out_b, in_w, in_b, dn_w, dn_b = params[idx : idx + 8]
        idx += 8
        hn = _layernorm(h)
        qkv = ref.matmul_gelu_ref(hn.reshape(b * s, d), qkv_w, qkv_b, "none")
        qkv = qkv.reshape(b, s, 3, heads, hd)
        q = qkv[:, :, 0].transpose(0, 2, 1, 3).reshape(b * heads, s, hd)
        k = qkv[:, :, 1].transpose(0, 2, 1, 3).reshape(b * heads, s, hd)
        v = qkv[:, :, 2].transpose(0, 2, 1, 3).reshape(b * heads, s, hd)
        att = ref.attention_ref(q, k, v)
        att = att.reshape(b, heads, s, hd).transpose(0, 2, 1, 3).reshape(b * s, d)
        h = h + ref.matmul_gelu_ref(att, out_w, out_b, "none").reshape(b, s, d)
        hn = _layernorm(h).reshape(b * s, d)
        mid = ref.matmul_gelu_ref(hn, in_w, in_b, "gelu")
        h = h + ref.matmul_gelu_ref(mid, dn_w, dn_b, "none").reshape(b, s, d)
    pooled = _layernorm(h).mean(axis=1)
    return (pooled @ params[-2] + params[-1])[:, 0]


# ------------------------------------------------------------ teacher data

def synth_batch(step, cfg, seed=0):
    """Deterministic synthetic 'season of observations' batch.

    y comes from a frozen random teacher MLP over pooled features, so the
    regression problem is learnable and the loss curve is meaningful.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    b, s, f = cfg["batch"], cfg["seq"], cfg["features"]
    x = jax.random.normal(key, (b, s, f))
    tkey = jax.random.PRNGKey(7)  # frozen teacher
    t1 = jax.random.normal(tkey, (f, 16)) / jnp.sqrt(jnp.float32(f))
    t2 = jax.random.normal(jax.random.fold_in(tkey, 1), (16, 1)) / 4.0
    pooled = x.mean(axis=1)
    y = (jnp.tanh(pooled @ t1) @ t2)[:, 0]
    return x, y


# ------------------------------------------------------- exported programs

def loss_fn(params, x, y, cfg):
    yhat = forward(params, x, cfg)
    return jnp.mean((yhat - y) ** 2)


def make_init_fn(cfg):
    def init_fn(seed):
        return tuple(init_params(jax.random.PRNGKey(seed), cfg))

    return init_fn


def make_train_step_fn(cfg):
    lr = cfg["lr"]

    def train_step(step, *params):
        params = list(params)
        x, y = synth_batch(step, cfg)
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y, cfg)
        new_params = [p - lr * g for p, g in zip(params, grads)]
        return tuple(new_params) + (loss,)

    return train_step


def make_infer_fn(cfg):
    def infer(step, *params):
        params = list(params)
        x, y = synth_batch(step, cfg, seed=1)  # held-out stream
        yhat = forward(params, x, cfg)
        mse = jnp.mean((yhat - y) ** 2)
        return (yhat, mse)

    return infer


def param_specs(cfg):
    """ShapeDtypeStructs of the flat parameter list."""
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    return [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]


def flops_per_step(cfg):
    """Rough forward+backward FLOP count per train step (for DESIGN.md
    roofline estimates)."""
    d, ff, layers = cfg["d_model"], cfg["d_ff"], cfg["n_layers"]
    b, s, f = cfg["batch"], cfg["seq"], cfg["features"]
    tokens = b * s
    per_layer = 2 * tokens * (d * 3 * d + d * d + d * ff + ff * d) + 2 * b * s * s * d
    fwd = 2 * tokens * f * d + layers * per_layer + 2 * b * d
    return 3 * fwd  # fwd + ~2x bwd
