//! Slurm batch script parser: the `#SBATCH` directive dialect.
//!
//! WLM-Operator (which Torque-Operator extends, paper §II) wraps exactly
//! these scripts. Supported directives:
//!
//! ```text
//! #SBATCH -J name / --job-name=name
//! #SBATCH -p part / --partition=part
//! #SBATCH -N 2 / --nodes=2
//! #SBATCH --ntasks-per-node=8
//! #SBATCH --mem=4G
//! #SBATCH -t 30 / --time=1-02:03:04    (min | h:m:s | d-h:m:s)
//! #SBATCH -o out / --output=out, -e / --error
//! #SBATCH --nice=-10                   (lower nice = higher priority)
//! #SBATCH --export=A=1,B=2
//! #SBATCH -C gpu / --constraint=gpu
//! ```

use crate::util::{Error, Result};
use std::time::Duration;

#[derive(Debug, Clone, PartialEq)]
pub struct SlurmScript {
    pub name: Option<String>,
    pub partition: Option<String>,
    pub nodes: u32,
    pub tasks_per_node: u32,
    pub mem: u64,
    pub time: Duration,
    /// Priority derived from --nice (negated: lower nice → higher priority).
    pub priority: i64,
    pub output: Option<String>,
    pub error: Option<String>,
    pub env: Vec<(String, String)>,
    pub constraints: Vec<String>,
    pub body: Vec<String>,
}

impl Default for SlurmScript {
    fn default() -> Self {
        SlurmScript {
            name: None,
            partition: None,
            nodes: 1,
            tasks_per_node: 1,
            mem: 0,
            time: Duration::from_secs(3600),
            priority: 0,
            output: None,
            error: None,
            env: Vec::new(),
            constraints: Vec::new(),
            body: Vec::new(),
        }
    }
}

/// Parse Slurm `--time`: `M`, `M:S`, `H:M:S`, `D-H`, `D-H:M`, `D-H:M:S`.
pub fn parse_slurm_time(s: &str) -> Option<Duration> {
    let s = s.trim();
    if let Some((days, rest)) = s.split_once('-') {
        let d: u64 = days.parse().ok()?;
        let parts: Vec<u64> = rest.split(':').map(|p| p.parse().ok()).collect::<Option<_>>()?;
        let secs = match parts.as_slice() {
            [h] => h * 3600,
            [h, m] => h * 3600 + m * 60,
            [h, m, sec] => h * 3600 + m * 60 + sec,
            _ => return None,
        };
        return Some(Duration::from_secs(d * 86_400 + secs));
    }
    let parts: Vec<u64> = s.split(':').map(|p| p.parse().ok()).collect::<Option<_>>()?;
    let secs = match parts.as_slice() {
        [m] => m * 60, // bare number = minutes in Slurm
        [m, sec] => m * 60 + sec,
        [h, m, sec] => h * 3600 + m * 60 + sec,
        _ => return None,
    };
    Some(Duration::from_secs(secs))
}

/// Parse Slurm `--mem`: `4G`, `512M`, `1024K`, plain MB.
pub fn parse_slurm_mem(s: &str) -> Option<u64> {
    let s = s.trim().to_ascii_uppercase();
    let (num, mult) = if let Some(n) = s.strip_suffix('T') {
        (n.to_string(), 1u64 << 40)
    } else if let Some(n) = s.strip_suffix('G') {
        (n.to_string(), 1u64 << 30)
    } else if let Some(n) = s.strip_suffix('M') {
        (n.to_string(), 1u64 << 20)
    } else if let Some(n) = s.strip_suffix('K') {
        (n.to_string(), 1u64 << 10)
    } else {
        (s, 1u64 << 20) // default unit is MB
    };
    let v: f64 = num.trim().parse().ok()?;
    if v < 0.0 {
        return None;
    }
    Some((v * mult as f64) as u64)
}

impl SlurmScript {
    pub fn parse(text: &str) -> Result<SlurmScript> {
        let mut s = SlurmScript::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim_end();
            if let Some(directive) = line.trim_start().strip_prefix("#SBATCH") {
                s.apply(directive.trim())
                    .map_err(|e| Error::parse(format!("line {}: {e}", lineno + 1)))?;
            } else {
                s.body.push(line.to_string());
            }
        }
        while s.body.first().map(|l| l.trim().is_empty()) == Some(true) {
            s.body.remove(0);
        }
        while s.body.last().map(|l| l.trim().is_empty()) == Some(true) {
            s.body.pop();
        }
        Ok(s)
    }

    fn apply(&mut self, directive: &str) -> Result<()> {
        // Normalize `--opt=value` and `-X value` into (opt, value).
        let (opt, val) = if let Some(rest) = directive.strip_prefix("--") {
            match rest.split_once('=') {
                Some((o, v)) => (format!("--{o}"), v.to_string()),
                None => {
                    let (o, v) = rest
                        .split_once(char::is_whitespace)
                        .unwrap_or((rest, ""));
                    (format!("--{o}"), v.trim().to_string())
                }
            }
        } else {
            let (o, v) = directive
                .split_once(char::is_whitespace)
                .unwrap_or((directive, ""));
            (o.to_string(), v.trim().to_string())
        };
        let need = |name: &str| -> Result<&str> {
            if val.is_empty() {
                Err(Error::parse(format!("`{name}` needs a value")))
            } else {
                Ok(val.as_str())
            }
        };
        match opt.as_str() {
            "-J" | "--job-name" => self.name = Some(need(&opt)?.to_string()),
            "-p" | "--partition" => self.partition = Some(need(&opt)?.to_string()),
            "-N" | "--nodes" => {
                self.nodes = need(&opt)?
                    .parse()
                    .map_err(|_| Error::parse(format!("bad node count `{val}`")))?;
                if self.nodes == 0 {
                    return Err(Error::parse("nodes must be >= 1"));
                }
            }
            "--ntasks-per-node" => {
                self.tasks_per_node = need(&opt)?
                    .parse()
                    .map_err(|_| Error::parse(format!("bad ntasks-per-node `{val}`")))?;
                if self.tasks_per_node == 0 {
                    return Err(Error::parse("ntasks-per-node must be >= 1"));
                }
            }
            "--mem" => {
                self.mem = parse_slurm_mem(need(&opt)?)
                    .ok_or_else(|| Error::parse(format!("bad mem `{val}`")))?
            }
            "-t" | "--time" => {
                self.time = parse_slurm_time(need(&opt)?)
                    .ok_or_else(|| Error::parse(format!("bad time `{val}`")))?
            }
            "-o" | "--output" => self.output = Some(need(&opt)?.to_string()),
            "-e" | "--error" => self.error = Some(need(&opt)?.to_string()),
            "--nice" => {
                let nice: i64 = need(&opt)?
                    .parse()
                    .map_err(|_| Error::parse(format!("bad nice `{val}`")))?;
                self.priority = -nice;
            }
            "--export" => {
                for pair in val.split(',') {
                    if pair.trim().eq_ignore_ascii_case("ALL") || pair.trim().is_empty() {
                        continue;
                    }
                    if let Some((k, v)) = pair.split_once('=') {
                        self.env.push((k.trim().to_string(), v.trim().to_string()));
                    }
                }
            }
            "-C" | "--constraint" => {
                self.constraints.extend(
                    need(&opt)?.split('&').map(|c| c.trim().to_string()),
                );
            }
            // accepted-and-ignored
            "-n" | "--ntasks" | "--cpus-per-task" | "-A" | "--account" | "--mail-type"
            | "--mail-user" | "--requeue" | "--exclusive" => {}
            other => return Err(Error::parse(format!("unknown directive `{other}`"))),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wlm_operator_style_script() {
        let text = "#!/bin/sh\n#SBATCH --nodes=1\n#SBATCH --time=00:30:00\n#SBATCH -o /home/user/low.out\nsingularity run lolcow_latest.sif\n";
        let s = SlurmScript::parse(text).unwrap();
        assert_eq!(s.nodes, 1);
        assert_eq!(s.time, Duration::from_secs(1800));
        assert_eq!(s.output.as_deref(), Some("/home/user/low.out"));
        assert_eq!(s.body, vec!["#!/bin/sh", "singularity run lolcow_latest.sif"]);
    }

    #[test]
    fn long_and_short_forms() {
        let text = "#SBATCH -J myjob\n#SBATCH -p gpu\n#SBATCH -N 4\n#SBATCH --ntasks-per-node=8\n#SBATCH --mem=16G\n#SBATCH -t 30\n#SBATCH --nice=-5\n#SBATCH --export=A=1,B=two\n#SBATCH -C gpu&bigmem\necho hi\n";
        let s = SlurmScript::parse(text).unwrap();
        assert_eq!(s.name.as_deref(), Some("myjob"));
        assert_eq!(s.partition.as_deref(), Some("gpu"));
        assert_eq!(s.nodes, 4);
        assert_eq!(s.tasks_per_node, 8);
        assert_eq!(s.mem, 16 << 30);
        assert_eq!(s.time, Duration::from_secs(1800), "bare number = minutes");
        assert_eq!(s.priority, 5, "nice -5 -> priority +5");
        assert_eq!(s.env.len(), 2);
        assert_eq!(s.constraints, vec!["gpu", "bigmem"]);
    }

    #[test]
    fn time_formats() {
        assert_eq!(parse_slurm_time("90"), Some(Duration::from_secs(5400)));
        assert_eq!(parse_slurm_time("10:30"), Some(Duration::from_secs(630)));
        assert_eq!(parse_slurm_time("1:02:03"), Some(Duration::from_secs(3723)));
        assert_eq!(parse_slurm_time("1-2"), Some(Duration::from_secs(93600)));
        assert_eq!(parse_slurm_time("1-2:30"), Some(Duration::from_secs(95400)));
        assert_eq!(
            parse_slurm_time("2-01:02:03"),
            Some(Duration::from_secs(2 * 86400 + 3723))
        );
        assert_eq!(parse_slurm_time("abc"), None);
    }

    #[test]
    fn mem_formats() {
        assert_eq!(parse_slurm_mem("4G"), Some(4 << 30));
        assert_eq!(parse_slurm_mem("512M"), Some(512 << 20));
        assert_eq!(parse_slurm_mem("100"), Some(100 << 20), "default MB");
        assert_eq!(parse_slurm_mem("2g"), Some(2 << 30), "case-insensitive");
        assert_eq!(parse_slurm_mem("x"), None);
    }

    #[test]
    fn errors() {
        assert!(SlurmScript::parse("#SBATCH --nodes=0\n").is_err());
        assert!(SlurmScript::parse("#SBATCH --time=zz\n").is_err());
        assert!(SlurmScript::parse("#SBATCH --frobnicate=1\n").is_err());
        assert!(SlurmScript::parse("#SBATCH -J\n").is_err());
        let err = SlurmScript::parse("echo a\n#SBATCH --mem=bad\n").unwrap_err();
        assert!(err.to_string().contains("line 2"));
    }

    #[test]
    fn ignored_options() {
        let s = SlurmScript::parse("#SBATCH --exclusive\n#SBATCH -n 16\necho x\n").unwrap();
        assert_eq!(s.body, vec!["echo x"]);
    }

    #[test]
    fn export_all_skipped() {
        let s = SlurmScript::parse("#SBATCH --export=ALL,X=1\n").unwrap();
        assert_eq!(s.env, vec![("X".to_string(), "1".to_string())]);
    }
}
