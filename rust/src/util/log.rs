//! Tiny leveled logger (no `tracing`/`log` crates in the offline registry).
//!
//! Components log as `LEVEL ts component: message`. The level is set once at
//! startup (`HPCORC_LOG=debug|info|warn|error`, default `warn` so tests and
//! benches stay quiet). Logging goes to stderr; the CLI's user-facing output
//! goes to stdout and never through here.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Warn
static INIT: std::sync::Once = std::sync::Once::new();

/// Initialize level from the HPCORC_LOG env var (idempotent).
pub fn init_from_env() {
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("HPCORC_LOG") {
            set_level(match v.to_ascii_lowercase().as_str() {
                "debug" => Level::Debug,
                "info" => Level::Info,
                "warn" => Level::Warn,
                "error" => Level::Error,
                _ => Level::Warn,
            });
        }
    });
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    l as u8 >= LEVEL.load(Ordering::Relaxed)
}

#[doc(hidden)]
pub fn write(level: Level, component: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let now = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let tag = match level {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    eprintln!("{tag} {}.{:03} {component}: {msg}", now.as_secs(), now.subsec_millis());
}

#[macro_export]
macro_rules! debug {
    ($comp:expr, $($arg:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Debug, $comp, format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! info {
    ($comp:expr, $($arg:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Info, $comp, format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! warn {
    ($comp:expr, $($arg:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Warn, $comp, format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! error {
    ($comp:expr, $($arg:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Error, $comp, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        set_level(Level::Warn);
    }
}
