"""AOT path: manifest consistency and HLO-text round-trip.

The round-trip check re-parses the emitted HLO text with the same
xla_client that produced it — guarding the interchange contract the Rust
loader (`HloModuleProto::from_text_file`) depends on.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_structure():
    m = manifest()
    assert m["formatVersion"] == 1
    arts = m["artifacts"]
    for variant in ("tiny", "small"):
        for role in ("init", "train", "infer"):
            assert f"cropyield_{role}_{variant}" in arts, f"missing {role}_{variant}"
    train = arts["cropyield_train_tiny"]
    # (step, params...) -> (params..., loss)
    assert len(train["inputs"]) == train["paramCount"] + 1
    assert len(train["outputs"]) == train["paramCount"] + 1
    assert train["outputs"][-1] == {"shape": [], "dtype": "float32"}
    assert train["metricOutputIndex"] == train["paramCount"]
    init = arts["cropyield_init_tiny"]
    # init outputs == train param inputs
    assert init["outputs"] == train["inputs"][1:]


def test_artifact_files_exist_and_are_hlo_text():
    m = manifest()
    for name, entry in m["artifacts"].items():
        path = os.path.join(ART, entry["file"])
        assert os.path.exists(path), f"{name}: {path} missing"
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"{name} does not look like HLO text"


def test_hlo_text_roundtrips_through_parser():
    """The text we write must parse back to an XlaComputation — the same
    contract the rust `xla` crate's from_text_file relies on."""
    spec = jax.ShapeDtypeStruct((), jnp.int32)
    cfg = model.CONFIGS["tiny"]
    pspecs = model.param_specs(cfg)
    lowered = jax.jit(model.make_infer_fn(cfg)).lower(spec, *pspecs)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None


def test_report_mode():
    rep = aot.report(["tiny"])
    assert rep["tiny"]["mlp_kernel"]["vmem_bytes"] > 0
    assert 0 < rep["tiny"]["mlp_kernel"]["mxu_utilization"] <= 1.0
    assert rep["tiny"]["flops_per_train_step"] > 1e6
