//! Queue-layer object model: the ClusterQueue / LocalQueue CRDs, the
//! quota vector they meter, and the labels/conditions a workload carries
//! through admission.
//!
//! Mirrors the Kueue API shape (`kueue.x-k8s.io`): a **ClusterQueue** owns
//! per-resource quotas (`nominal` plus an optional `borrowingLimit`) and
//! may pool spare capacity with cohort peers; a **LocalQueue** is the
//! namespace-facing handle that points workloads at a ClusterQueue.
//! Workloads opt in with the `kueue.x-k8s.io/queue-name` label and are
//! held suspended until the admission controller flips their
//! `QuotaReserved`/`Admitted` conditions.

use crate::encoding::Value;
use crate::kube::{KubeObject, PodPhase, PodView, ResourceView, WlmJobView, KIND_POD,
    KIND_SLURMJOB, KIND_TORQUEJOB};
use crate::util::{Error, Result};

/// The apiVersion the queue-layer CRDs are served under.
pub const KUEUE_API_VERSION: &str = "kueue.x-k8s.io/v1beta1";

pub const KIND_CLUSTERQUEUE: &str = "ClusterQueue";
pub const KIND_LOCALQUEUE: &str = "LocalQueue";

/// Label a workload carries to request admission through a LocalQueue
/// (the value may also name a ClusterQueue directly — convenient for the
/// simulator, which has no namespaces).
pub const QUEUE_NAME_LABEL: &str = "kueue.x-k8s.io/queue-name";
/// Optional integer priority label (higher admits first under `Priority`
/// ordering and wins within-queue preemption).
pub const PRIORITY_LABEL: &str = "kueue.x-k8s.io/priority";
/// Pods sharing this label form one gang ("pod group"): they are admitted
/// all-or-nothing once the declared member count is present.
pub const POD_GROUP_LABEL: &str = "kueue.x-k8s.io/pod-group-name";
/// Annotation (on at least one group member) declaring the gang size.
/// A group is held — never partially admitted — until a member carrying
/// this annotation exists and the declared count of members is present.
pub const POD_GROUP_COUNT_ANNOTATION: &str = "kueue.x-k8s.io/pod-group-total-count";

/// The pod scheduling gate kueue owns (`spec.schedulingGates`): set on
/// suspended queue-labelled pods, cleared at admission, re-set on
/// eviction. The scheduler holds any gated pod without knowing whose
/// gate it is — the generic mechanism future admission layers compose
/// through (PR 3 inverted the old direct `admission_gated` dependency).
pub const SCHEDULING_GATE: &str = "kueue.x-k8s.io/admission";

/// Condition types the admission controller flips on workloads.
pub const COND_QUOTA_RESERVED: &str = "QuotaReserved";
pub const COND_ADMITTED: &str = "Admitted";
pub const COND_EVICTED: &str = "Evicted";

/// Kinds the admission controller watches for the queue-name label.
pub const WORKLOAD_KINDS: &[&str] = &[KIND_POD, KIND_TORQUEJOB, KIND_SLURMJOB];

// --------------------------------------------------------- quota vector

/// The resource vector quotas are expressed in. `nodes` is the gang
/// dimension (a multi-node WlmJob consumes N); cpu/memory aggregate over
/// all chunks of the gang.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct QueueResources {
    pub nodes: u32,
    pub cpu_milli: u64,
    pub mem_bytes: u64,
}

impl QueueResources {
    pub const ZERO: QueueResources = QueueResources { nodes: 0, cpu_milli: 0, mem_bytes: 0 };

    /// A quota that never constrains (cohort-unbounded borrowing, CLI
    /// node-only quotas).
    pub const UNBOUNDED: QueueResources =
        QueueResources { nodes: u32::MAX, cpu_milli: u64::MAX, mem_bytes: u64::MAX };

    pub fn nodes(n: u32) -> QueueResources {
        QueueResources { nodes: n, ..QueueResources::UNBOUNDED }
    }

    /// Does this amount cover `other` in every dimension?
    pub fn covers(&self, other: &QueueResources) -> bool {
        self.nodes >= other.nodes
            && self.cpu_milli >= other.cpu_milli
            && self.mem_bytes >= other.mem_bytes
    }

    pub fn saturating_add(&self, other: &QueueResources) -> QueueResources {
        QueueResources {
            nodes: self.nodes.saturating_add(other.nodes),
            cpu_milli: self.cpu_milli.saturating_add(other.cpu_milli),
            mem_bytes: self.mem_bytes.saturating_add(other.mem_bytes),
        }
    }

    pub fn saturating_sub(&self, other: &QueueResources) -> QueueResources {
        QueueResources {
            nodes: self.nodes.saturating_sub(other.nodes),
            cpu_milli: self.cpu_milli.saturating_sub(other.cpu_milli),
            mem_bytes: self.mem_bytes.saturating_sub(other.mem_bytes),
        }
    }

    pub fn is_zero(&self) -> bool {
        *self == QueueResources::ZERO
    }

    /// Encode for a CRD spec tree (`{nodes, cpu, memory}`, plain integers;
    /// cpu in millicores, memory in bytes). Unbounded dimensions are
    /// omitted — the decode side reads missing as unbounded, so a
    /// node-only quota round-trips as `quota: {nodes: 3}`.
    pub fn encode(&self) -> Value {
        let mut v = Value::map();
        if self.nodes != u32::MAX {
            v.insert("nodes", self.nodes as u64);
        }
        if self.cpu_milli != u64::MAX {
            v.insert("cpu", self.cpu_milli);
        }
        if self.mem_bytes != u64::MAX {
            v.insert("memory", self.mem_bytes);
        }
        v
    }

    /// Decode a spec tree; missing dimensions are unbounded so a
    /// node-only quota (`quota: {nodes: 3}`) reads naturally.
    pub fn decode(v: &Value) -> QueueResources {
        QueueResources {
            nodes: v.opt_int("nodes").map(|n| n as u32).unwrap_or(u32::MAX),
            cpu_milli: v.opt_int("cpu").map(|n| n as u64).unwrap_or(u64::MAX),
            mem_bytes: v.opt_int("memory").map(|n| n as u64).unwrap_or(u64::MAX),
        }
    }
}

// ------------------------------------------------------------ CRD views

/// Admission order within one ClusterQueue. Both are *strict*: a blocked
/// head gang holds everything behind it in the same queue (the quota
/// analogue of FIFO head-of-queue blocking; EASY-style relaxations belong
/// to the node scheduler, not the quota layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueOrdering {
    #[default]
    Fifo,
    Priority,
}

impl QueueOrdering {
    pub fn as_str(&self) -> &'static str {
        match self {
            QueueOrdering::Fifo => "fifo",
            QueueOrdering::Priority => "priority",
        }
    }

    pub fn parse(s: &str) -> QueueOrdering {
        if s.eq_ignore_ascii_case("priority") {
            QueueOrdering::Priority
        } else {
            QueueOrdering::Fifo
        }
    }
}

/// What an incoming (within-nominal) gang of this queue may evict.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PreemptionPolicy {
    /// Evict cohort peers' workloads that push the peer over its nominal
    /// quota (reclaim borrowed capacity).
    pub reclaim_within_cohort: bool,
    /// Evict lower-priority workloads admitted through this same queue.
    pub within_queue: bool,
}

/// Typed view over a ClusterQueue object.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterQueueView {
    pub name: String,
    /// Queues naming the same cohort pool unused nominal capacity.
    pub cohort: Option<String>,
    pub nominal: QueueResources,
    /// Cap on usage beyond nominal (None = unlimited borrowing, bounded
    /// only by the cohort's total capacity).
    pub borrowing_limit: Option<QueueResources>,
    pub ordering: QueueOrdering,
    pub preemption: PreemptionPolicy,
    /// Status counts maintained by the admission controller.
    pub pending: u64,
    pub admitted: u64,
}

impl ClusterQueueView {
    pub fn from_object(o: &KubeObject) -> Result<ClusterQueueView> {
        if o.kind != KIND_CLUSTERQUEUE {
            return Err(Error::parse(format!("expected ClusterQueue, got {}", o.kind)));
        }
        Ok(ClusterQueueView {
            name: o.meta.name.clone(),
            cohort: o.spec.opt_str("cohort").filter(|s| !s.is_empty()).map(String::from),
            nominal: o
                .spec
                .get("quota")
                .map(QueueResources::decode)
                .unwrap_or(QueueResources::UNBOUNDED),
            borrowing_limit: o.spec.get("borrowingLimit").map(QueueResources::decode),
            ordering: QueueOrdering::parse(o.spec.opt_str("ordering").unwrap_or("fifo")),
            preemption: PreemptionPolicy {
                reclaim_within_cohort: o
                    .spec
                    .path(&["preemption", "reclaimWithinCohort"])
                    .and_then(Value::as_bool)
                    .unwrap_or(false),
                within_queue: o
                    .spec
                    .path(&["preemption", "withinClusterQueue"])
                    .and_then(Value::as_bool)
                    .unwrap_or(false),
            },
            pending: o.status.opt_int("pending").unwrap_or(0) as u64,
            admitted: o.status.opt_int("admitted").unwrap_or(0) as u64,
        })
    }

    /// Build a ClusterQueue object (FIFO, no cohort, no preemption).
    pub fn build(name: &str, nominal: QueueResources) -> KubeObject {
        Self::build_full(name, None, nominal, None, QueueOrdering::Fifo, PreemptionPolicy::default())
    }

    pub fn build_full(
        name: &str,
        cohort: Option<&str>,
        nominal: QueueResources,
        borrowing_limit: Option<QueueResources>,
        ordering: QueueOrdering,
        preemption: PreemptionPolicy,
    ) -> KubeObject {
        let mut spec = Value::map().with("quota", nominal.encode());
        if let Some(c) = cohort {
            spec.insert("cohort", c);
        }
        if let Some(b) = borrowing_limit {
            spec.insert("borrowingLimit", b.encode());
        }
        spec.insert("ordering", ordering.as_str());
        spec.insert(
            "preemption",
            Value::map()
                .with("reclaimWithinCohort", preemption.reclaim_within_cohort)
                .with("withinClusterQueue", preemption.within_queue),
        );
        let mut o = KubeObject::new(KIND_CLUSTERQUEUE, name, spec);
        o.api_version = KUEUE_API_VERSION.into();
        o
    }
}

impl ResourceView for ClusterQueueView {
    fn kinds() -> &'static [&'static str] {
        &[KIND_CLUSTERQUEUE]
    }
    fn from_object(obj: &KubeObject) -> Result<ClusterQueueView> {
        ClusterQueueView::from_object(obj)
    }
}

/// Typed view over a LocalQueue object (namespace → ClusterQueue binding).
#[derive(Debug, Clone, PartialEq)]
pub struct LocalQueueView {
    pub name: String,
    pub cluster_queue: String,
    pub pending: u64,
    pub admitted: u64,
}

impl LocalQueueView {
    pub fn from_object(o: &KubeObject) -> Result<LocalQueueView> {
        if o.kind != KIND_LOCALQUEUE {
            return Err(Error::parse(format!("expected LocalQueue, got {}", o.kind)));
        }
        Ok(LocalQueueView {
            name: o.meta.name.clone(),
            cluster_queue: o
                .spec
                .req_str("clusterQueue")
                .map_err(|_| Error::parse("LocalQueue spec.clusterQueue missing"))?
                .to_string(),
            pending: o.status.opt_int("pending").unwrap_or(0) as u64,
            admitted: o.status.opt_int("admitted").unwrap_or(0) as u64,
        })
    }

    pub fn build(name: &str, cluster_queue: &str) -> KubeObject {
        let mut o = KubeObject::new(
            KIND_LOCALQUEUE,
            name,
            Value::map().with("clusterQueue", cluster_queue),
        );
        o.api_version = KUEUE_API_VERSION.into();
        o
    }
}

impl ResourceView for LocalQueueView {
    fn kinds() -> &'static [&'static str] {
        &[KIND_LOCALQUEUE]
    }
    fn from_object(obj: &KubeObject) -> Result<LocalQueueView> {
        LocalQueueView::from_object(obj)
    }
}

// ------------------------------------------- workload-side introspection

/// The LocalQueue (or ClusterQueue) name a workload requests, if any.
pub fn queue_name(obj: &KubeObject) -> Option<&str> {
    obj.meta.label(QUEUE_NAME_LABEL)
}

/// Workload priority from the priority label (0 when absent/garbage).
pub fn workload_priority(obj: &KubeObject) -> i64 {
    obj.meta.label(PRIORITY_LABEL).and_then(|v| v.parse().ok()).unwrap_or(0)
}

/// Read a status condition (`None` = never set).
pub fn get_condition(obj: &KubeObject, cond_type: &str) -> Option<bool> {
    obj.status.get("conditions").and_then(Value::as_seq).and_then(|conds| {
        conds
            .iter()
            .find(|c| c.opt_str("type") == Some(cond_type))
            .map(|c| c.opt_str("status") == Some("True"))
    })
}

/// Set a condition in a status tree (for use inside `update_status`
/// closures). Updates in place or appends.
pub fn set_condition(status: &mut Value, cond_type: &str, val: bool) {
    let entry =
        Value::map().with("type", cond_type).with("status", if val { "True" } else { "False" });
    if !matches!(status.get("conditions"), Some(Value::Seq(_))) {
        status.insert("conditions", Value::Seq(Vec::new()));
    }
    let Some(Value::Seq(conds)) = status.get_mut("conditions") else { return };
    if let Some(c) = conds.iter_mut().find(|c| c.opt_str("type") == Some(cond_type)) {
        *c = entry;
    } else {
        conds.push(entry);
    }
}

/// Has the admission controller admitted this workload?
pub fn is_admitted(obj: &KubeObject) -> bool {
    get_condition(obj, COND_ADMITTED) == Some(true)
}

/// Was this workload preempted out of its quota reservation?
pub fn is_evicted(obj: &KubeObject) -> bool {
    get_condition(obj, COND_EVICTED) == Some(true)
}

/// Should the operator hold this workload? True when it opted into
/// queueing (queue-name label present) and has not been admitted.
/// Label-less workloads bypass the queue layer entirely. (Pods are held
/// through the generic `schedulingGates` mechanism instead — see
/// [`SCHEDULING_GATE`] and [`queue_workload`]; this predicate remains the
/// suspension check for non-schedulable kinds like TorqueJob/SlurmJob,
/// and the admission controller's own notion of "pending".)
pub fn admission_gated(obj: &KubeObject) -> bool {
    queue_name(obj).is_some() && !is_admitted(obj)
}

/// Opt a workload into a queue: sets the queue-name label and — for pods
/// — the kueue scheduling gate, so the workload is born suspended with no
/// window for the scheduler to race the admission controller (the
/// mutating-webhook duty in real Kueue). The admission cycle also
/// back-fills the gate on labelled pods created without it.
pub fn queue_workload(obj: &mut KubeObject, queue: &str) {
    obj.meta.set_label(QUEUE_NAME_LABEL, queue);
    if obj.kind == KIND_POD {
        crate::kube::add_scheduling_gate(obj, SCHEDULING_GATE);
    }
}

/// The kueue mutating-admission hook for
/// [`crate::kube::ApiServer::register_mutating_hook`]: a pod entering the
/// create path with a bare queue-name label (applied manifest, direct
/// create — anything that bypassed [`queue_workload`]) is gated *at
/// creation*, so there is no window in which the scheduler could bind a
/// suspended pod before the first admission cycle back-fills its gate.
/// The cycle's back-fill stays as the converging safety net for objects
/// born before the hook was registered.
pub fn admission_mutating_hook() -> crate::kube::MutatingHook {
    std::sync::Arc::new(|obj: &mut KubeObject| {
        if obj.kind == KIND_POD
            && queue_name(obj).is_some()
            && !is_admitted(obj)
            && !workload_terminal(obj)
        {
            crate::kube::add_scheduling_gate(obj, SCHEDULING_GATE);
        }
    })
}

/// Is the workload finished (its quota charge released)?
pub fn workload_terminal(obj: &KubeObject) -> bool {
    match obj.kind.as_str() {
        KIND_POD => PodPhase::parse(obj.status.opt_str("phase").unwrap_or("")).terminal(),
        KIND_TORQUEJOB | KIND_SLURMJOB => {
            crate::operator::phase::terminal(obj.status.opt_str("phase").unwrap_or(""))
        }
        _ => false,
    }
}

/// Normalized quota demand of one workload object.
///
/// - Pod: one node-chunk carrying its container resource requests.
/// - TorqueJob/SlurmJob: the batch script's `-l nodes=N:ppn=P[,mem=M]`
///   (resp. `-N/--ntasks-per-node/--mem`), aggregated over all N chunks —
///   this is what makes a multi-node WlmJob one indivisible gang.
pub fn workload_demand(obj: &KubeObject) -> Result<QueueResources> {
    match obj.kind.as_str() {
        KIND_POD => {
            let p = PodView::from_object(obj)?;
            Ok(QueueResources {
                nodes: 1,
                cpu_milli: p.requests.cpu_milli,
                mem_bytes: p.requests.mem_bytes,
            })
        }
        KIND_TORQUEJOB => {
            let v = WlmJobView::from_object(obj)?;
            let s = crate::pbs::PbsScript::parse(&v.batch)?;
            Ok(QueueResources {
                nodes: s.nodes,
                cpu_milli: (s.nodes as u64 * s.ppn as u64) * 1000,
                mem_bytes: s.nodes as u64 * s.mem,
            })
        }
        KIND_SLURMJOB => {
            let v = WlmJobView::from_object(obj)?;
            let s = crate::slurm::SlurmScript::parse(&v.batch)?;
            Ok(QueueResources {
                nodes: s.nodes,
                cpu_milli: (s.nodes as u64 * s.tasks_per_node as u64) * 1000,
                mem_bytes: s.nodes as u64 * s.mem,
            })
        }
        other => Err(Error::config(format!("kind `{other}` is not a queueable workload"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Resources;

    #[test]
    fn quota_vector_math() {
        let q = QueueResources { nodes: 3, cpu_milli: 4000, mem_bytes: 1 << 30 };
        let d = QueueResources { nodes: 2, cpu_milli: 1000, mem_bytes: 1 << 20 };
        assert!(q.covers(&d));
        assert!(!d.covers(&q));
        assert_eq!(q.saturating_sub(&q), QueueResources::ZERO);
        assert!(QueueResources::UNBOUNDED.covers(&q));
        assert_eq!(
            d.saturating_add(&QueueResources::UNBOUNDED).nodes,
            u32::MAX,
            "saturates, not wraps"
        );
        // Node-only quota decodes with unbounded cpu/mem.
        let back = QueueResources::decode(&Value::map().with("nodes", 3u64));
        assert_eq!(back.nodes, 3);
        assert_eq!(back.cpu_milli, u64::MAX);
        // Full encode/decode roundtrip.
        assert_eq!(QueueResources::decode(&q.encode()), q);
    }

    #[test]
    fn cluster_queue_view_roundtrip() {
        let o = ClusterQueueView::build_full(
            "tenant-a",
            Some("pool"),
            QueueResources::nodes(3),
            Some(QueueResources::nodes(2)),
            QueueOrdering::Priority,
            PreemptionPolicy { reclaim_within_cohort: true, within_queue: false },
        );
        assert_eq!(o.api_version, KUEUE_API_VERSION);
        let v = ClusterQueueView::from_object(&o).unwrap();
        assert_eq!(v.name, "tenant-a");
        assert_eq!(v.cohort.as_deref(), Some("pool"));
        assert_eq!(v.nominal.nodes, 3);
        assert_eq!(v.borrowing_limit.unwrap().nodes, 2);
        assert_eq!(v.ordering, QueueOrdering::Priority);
        assert!(v.preemption.reclaim_within_cohort);
        assert!(!v.preemption.within_queue);
        // Minimal build: FIFO, no cohort, unlimited-borrow-irrelevant.
        let v = ClusterQueueView::from_object(&ClusterQueueView::build(
            "b",
            QueueResources::nodes(1),
        ))
        .unwrap();
        assert_eq!(v.ordering, QueueOrdering::Fifo);
        assert!(v.cohort.is_none());
        assert!(v.borrowing_limit.is_none());
    }

    #[test]
    fn local_queue_view_roundtrip() {
        let o = LocalQueueView::build("team-x", "tenant-a");
        let v = LocalQueueView::from_object(&o).unwrap();
        assert_eq!(v.cluster_queue, "tenant-a");
        assert!(LocalQueueView::from_object(&KubeObject::new(
            KIND_LOCALQUEUE,
            "bad",
            Value::map()
        ))
        .is_err());
    }

    #[test]
    fn conditions_set_get() {
        let mut o = KubeObject::new(KIND_POD, "p", Value::map());
        assert_eq!(get_condition(&o, COND_ADMITTED), None);
        set_condition(&mut o.status, COND_QUOTA_RESERVED, true);
        set_condition(&mut o.status, COND_ADMITTED, true);
        assert!(is_admitted(&o));
        set_condition(&mut o.status, COND_ADMITTED, false);
        assert_eq!(get_condition(&o, COND_ADMITTED), Some(false));
        assert!(!is_admitted(&o));
        assert_eq!(get_condition(&o, COND_QUOTA_RESERVED), Some(true), "other conds intact");
    }

    #[test]
    fn gating_logic() {
        let mut pod = PodView::build("p", "img.sif", Resources::new(500, 1 << 20, 0), &[]);
        assert!(!admission_gated(&pod), "label-less workloads bypass the queue layer");
        pod.meta.set_label(QUEUE_NAME_LABEL, "tenant-a");
        assert!(admission_gated(&pod));
        set_condition(&mut pod.status, COND_ADMITTED, true);
        assert!(!admission_gated(&pod));
    }

    #[test]
    fn queue_workload_gates_pods_but_not_wlm_jobs() {
        let mut pod = PodView::build("p", "img.sif", Resources::new(500, 1 << 20, 0), &[]);
        queue_workload(&mut pod, "tenant-a");
        assert_eq!(queue_name(&pod), Some("tenant-a"));
        assert_eq!(crate::kube::scheduling_gates(&pod), vec![SCHEDULING_GATE]);
        // WlmJobs never schedule as pods, so they carry no gate.
        let mut tj = WlmJobView::build_torquejob("t", "echo x\n", "", "");
        queue_workload(&mut tj, "tenant-a");
        assert!(crate::kube::scheduling_gates(&tj).is_empty());
        assert!(admission_gated(&tj));
    }

    /// ISSUE 4 satellite: a pod created with a bare queue-name label (no
    /// gate) used to race the scheduler for one admission cycle. The
    /// mutating hook closes it: the pod is born gated.
    #[test]
    fn mutating_hook_gates_bare_labelled_pods_at_creation() {
        use crate::cluster::Metrics;
        use crate::kube::{scheduling_gates, ApiServer};
        let api = ApiServer::new(Metrics::new());
        api.register_mutating_hook(admission_mutating_hook());
        // Bare label, no gate — the exact race shape.
        let mut bare = PodView::build("bare", "img.sif", Resources::new(100, 1 << 20, 0), &[]);
        bare.meta.set_label(QUEUE_NAME_LABEL, "team");
        let stored = api.create(bare).unwrap();
        assert_eq!(scheduling_gates(&stored), vec![SCHEDULING_GATE.to_string()]);
        // Unlabelled pods are untouched.
        let plain = api
            .create(PodView::build("plain", "img.sif", Resources::ZERO, &[]))
            .unwrap();
        assert!(scheduling_gates(&plain).is_empty());
        // WLM jobs gate through the Admitted condition, never pod gates.
        let mut tj = WlmJobView::build_torquejob("tj", "echo x\n", "", "");
        tj.meta.set_label(QUEUE_NAME_LABEL, "team");
        let stored = api.create(tj).unwrap();
        assert!(scheduling_gates(&stored).is_empty());
        // Idempotent against queue_workload-built pods (no double gate).
        let mut built = PodView::build("built", "img.sif", Resources::ZERO, &[]);
        queue_workload(&mut built, "team");
        let stored = api.create(built).unwrap();
        assert_eq!(scheduling_gates(&stored).len(), 1);
    }

    #[test]
    fn priority_label_parse() {
        let mut pod = PodView::build("p", "img.sif", Resources::ZERO, &[]);
        assert_eq!(workload_priority(&pod), 0);
        pod.meta.set_label(PRIORITY_LABEL, "17");
        assert_eq!(workload_priority(&pod), 17);
        pod.meta.set_label(PRIORITY_LABEL, "not-a-number");
        assert_eq!(workload_priority(&pod), 0);
    }

    #[test]
    fn demand_extraction() {
        let pod = PodView::build("p", "img.sif", Resources::new(500, 256 << 20, 0), &[]);
        let d = workload_demand(&pod).unwrap();
        assert_eq!(d, QueueResources { nodes: 1, cpu_milli: 500, mem_bytes: 256 << 20 });

        let tj = WlmJobView::build_torquejob(
            "wide",
            "#!/bin/sh\n#PBS -l nodes=4:ppn=2\n#PBS -l mem=1gb\nsleep 5\n",
            "",
            "",
        );
        let d = workload_demand(&tj).unwrap();
        assert_eq!(d.nodes, 4);
        assert_eq!(d.cpu_milli, 8000);
        assert_eq!(d.mem_bytes, 4 << 30);

        let node = crate::kube::NodeView::build("n", Resources::cores(1, 1 << 30), &[]);
        assert!(workload_demand(&node).is_err());
    }

    #[test]
    fn terminal_detection() {
        let mut pod = PodView::build("p", "img.sif", Resources::ZERO, &[]);
        assert!(!workload_terminal(&pod));
        pod.status.insert("phase", "Succeeded");
        assert!(workload_terminal(&pod));
        let mut tj = WlmJobView::build_torquejob("t", "echo x\n", "", "");
        assert!(!workload_terminal(&tj));
        tj.status.insert("phase", crate::operator::phase::COMPLETED);
        assert!(workload_terminal(&tj));
    }
}
