//! kube-scheduler: assigns pending pods to nodes.
//!
//! The standard two-phase cycle: **filter** (resource fit, nodeSelector,
//! taints/tolerations, node Ready) then **score** (least-allocated), then
//! **bind** (set `spec.nodeName`). Virtual nodes carry the
//! `virtual-kubelet` taint, so only the operator's dummy pods — which
//! tolerate it — land there (paper Fig. 2).
//!
//! Reads come from the shared informer caches (PR 4) — a scheduling
//! cycle issues zero list RPCs. Since PR 9 the filter/score pass runs
//! against the incrementally-maintained [`SchedIndex`] (candidates in
//! O(log n + matches) instead of an O(nodes) scan per pod), and binds
//! **batch**: a cycle reserves each placement in the index, then commits
//! every `spec.nodeName` patch through one
//! [`ApiClient::update_status_batch`] call — inline when stepped
//! directly (tests/benches), via a background committer thread in
//! daemon mode so the next cycle never waits on the API. Failed binds
//! un-reserve and requeue through the informer echo. The daemon loop is
//! event-driven: pod/node events wake it, with a periodic sweep as the
//! level-triggered safety net.

use super::api::{KubeObject, NodeView, PodPhase, PodView};
use super::client::{ApiClient, BatchPatchItem};
use super::events::{EventRecorder, EVENT_NORMAL, EVENT_WARNING};
use super::informer::{Informer, SharedInformerFactory};
use super::sched_index::SchedIndex;
use crate::cluster::{Metrics, Resources};
use crate::encoding::Value;
use crate::rt::{self, Shutdown};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// The audit actor and event reportingController of this component.
const COMPONENT: &str = "kube-scheduler";

/// One placement decided by a cycle: the bind is already reserved in
/// the index; committing (and un-reserving on failure) is the batch
/// path's job.
struct Placement {
    pod: String,
    node: String,
    origin_trace: Option<crate::obs::TraceContext>,
    created_ns: Option<u64>,
}

pub struct KubeScheduler {
    client: Arc<dyn ApiClient>,
    nodes: Informer,
    pods: Informer,
    metrics: Metrics,
    events: EventRecorder,
    index: Arc<SchedIndex>,
    /// Set in daemon mode ([`KubeScheduler::start`]): cycles hand their
    /// placement batches here instead of committing inline.
    committer: Mutex<Option<Sender<Vec<Placement>>>>,
}

impl KubeScheduler {
    pub fn new(informers: &SharedInformerFactory, metrics: Metrics) -> KubeScheduler {
        KubeScheduler {
            client: informers.client(),
            nodes: informers.informer(super::api::KIND_NODE),
            pods: informers.informer(super::api::KIND_POD),
            events: EventRecorder::new(COMPONENT, metrics.clone()),
            index: Arc::new(SchedIndex::new(informers, metrics.clone())),
            committer: Mutex::new(None),
            metrics,
        }
    }

    /// The scheduler's fit/score index (tests, benches, diagnostics).
    pub fn index(&self) -> &Arc<SchedIndex> {
        &self.index
    }

    /// Run as a daemon. Event-driven: any pod or node event wakes a
    /// cycle immediately (events coalesce — a burst triggers one pass);
    /// `period` is only the fallback sweep when nothing happens. Bind
    /// commits move to a background committer thread: a cycle's
    /// placements are reserved in the index and queued, so scheduling
    /// latency never includes the API round trip.
    pub fn start(self, period: Duration, shutdown: Shutdown) {
        rt::spawn_named("kube-sched", move || {
            let (ctx, crx) = std::sync::mpsc::channel::<Vec<Placement>>();
            {
                let client = self.client.clone();
                let index = self.index.clone();
                let metrics = self.metrics.clone();
                let events = self.events.clone();
                // Exits when the scheduler loop (sole sender) returns.
                rt::spawn_named("kube-sched-commit", move || {
                    while let Ok(mut batch) = crx.recv() {
                        // Backpressure coalescing: under sustained overload
                        // the scheduler produces batches faster than the
                        // committer drains them. Merge everything already
                        // queued into ONE store commit — cross-cycle
                        // placements never conflict (a pod is reserved
                        // until its bind echoes, so no pod appears twice),
                        // and one big batch is one round trip instead of N.
                        let mut coalesced = 0u64;
                        while let Ok(next) = crx.try_recv() {
                            coalesced += 1;
                            batch.extend(next);
                        }
                        if coalesced > 0 {
                            metrics.add("kube.sched.commit_batches_coalesced", coalesced);
                        }
                        let _actor = crate::obs::push_actor(COMPONENT);
                        commit_bindings(&client, &index, &metrics, &events, batch);
                    }
                });
            }
            *self.committer.lock().unwrap() = Some(ctx);
            // Payload-free wake-ups: the scheduler only needs "something
            // changed, run a cycle" — never the event objects themselves.
            let (tx, rx) = std::sync::mpsc::channel();
            self.pods.subscribe_notify(tx.clone());
            self.nodes.subscribe_notify(tx);
            loop {
                if shutdown.is_triggered() {
                    return;
                }
                self.run_cycle();
                // Sleep until the next event or the fallback tick, then
                // coalesce everything pending into one cycle.
                match rx.recv_timeout(period) {
                    Ok(_) => {
                        self.metrics.inc("kube.sched.wakeups");
                        while rx.try_recv().is_ok() {}
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                    Err(_) => return,
                }
            }
        });
    }

    /// One full scheduling cycle; returns the number of pods placed
    /// (== bound, when committing inline). Public for deterministic
    /// stepping in tests/benches.
    pub fn run_cycle(&self) -> usize {
        let t0 = std::time::Instant::now();
        // Audit attribution: every write this cycle makes runs as us.
        let _actor = crate::obs::push_actor(COMPONENT);
        // A broken transport must not masquerade as "nothing to schedule":
        // if the informers cannot seed/stay current, skip the cycle.
        // (Undecodable objects are skipped below, so a malformed
        // hand-written manifest cannot wedge the cycle either.)
        if let Err(e) = self.nodes.sync().and_then(|()| self.pods.sync()) {
            self.metrics.inc("kube.sched.list_errors");
            crate::warn!("kube-sched", "informer sync failed, skipping cycle: {e}");
            return 0;
        }
        // Fold the synced deltas into the fit/score index, then snapshot
        // the in-flight reservations (their pods are placed, not pending).
        self.index.refresh();
        let reserved = self.index.reserved_pods();

        let mut pending: Vec<PodView> = Vec::new();
        // Observability sidecar per pending pod: originating trace context
        // and creation wall clock, read off the annotations in the same
        // pass (PodView itself stays annotation-free).
        let mut origins: std::collections::BTreeMap<
            String,
            (Option<crate::obs::TraceContext>, Option<u64>),
        > = std::collections::BTreeMap::new();
        let mut gated = 0u64;
        self.pods.read(|objs| {
            for obj in objs.values() {
                let Ok(view) = PodView::from_object(obj) else { continue };
                if !matches!((&view.node_name, view.phase), (None, PodPhase::Pending)) {
                    continue;
                }
                // Scheduling gates (k8s `spec.schedulingGates`): a pod
                // with any gate present is not scheduler-ready.
                // Admission layers (kueue, PR 2/3) set and clear their
                // own gates — the scheduler knows nothing about them.
                if !view.scheduling_gates.is_empty() {
                    gated += 1;
                    continue;
                }
                if reserved.contains(&view.name) {
                    continue;
                }
                origins.insert(
                    view.name.clone(),
                    (
                        obj.meta
                            .annotation(crate::obs::TRACE_ANNOTATION)
                            .and_then(crate::obs::TraceContext::parse_wire),
                        obj.meta
                            .annotation(crate::obs::CREATED_WALL_ANNOTATION)
                            .and_then(|s| s.parse::<u64>().ok()),
                    ),
                );
                pending.push(view);
            }
        });
        self.metrics.add("kube.sched.gated", gated);
        // Sort pending by creation (FIFO-ish, as the real scheduler's
        // priority queue without priorities).
        pending.sort_by(|a, b| a.name.cmp(&b.name));
        self.metrics.set_gauge("kube.sched.pending", pending.len() as i64);

        let mut placements: Vec<Placement> = Vec::new();
        for pod in pending {
            match self.index.select(&pod) {
                Ok(node) => {
                    // Reserve at selection: later pods in this cycle —
                    // and later cycles, while the commit is in flight —
                    // see the capacity as taken.
                    self.index.reserve(&pod.name, &node, pod.requests);
                    let (origin_trace, created_ns) =
                        origins.get(&pod.name).cloned().unwrap_or((None, None));
                    placements.push(Placement { pod: pod.name, node, origin_trace, created_ns });
                }
                Err(why) => {
                    self.metrics
                        .inc_with("kube.sched.unschedulable", &[("outcome", why.outcome())]);
                    let (origin_trace, _) =
                        origins.get(&pod.name).cloned().unwrap_or((None, None));
                    let trace_wire = origin_trace.map(|c| c.to_wire());
                    // Repeats coalesce into a count bump on the same Event
                    // (the reason is constant; only the diagnosis varies).
                    let _ = self.events.event_ref(
                        &self.client,
                        super::api::KIND_POD,
                        &pod.name,
                        trace_wire.as_deref(),
                        EVENT_WARNING,
                        "FailedScheduling",
                        &why.message(),
                    );
                }
            }
        }
        let placed = placements.len();
        if placed == 0 {
            self.metrics.observe("kube.sched.cycle_ns", t0.elapsed().as_nanos() as u64);
            return 0;
        }
        // Daemon mode queues the batch for the background committer;
        // direct stepping commits inline so the result is deterministic.
        let placements = match self.committer.lock().unwrap().as_ref() {
            Some(tx) => match tx.send(placements) {
                Ok(()) => {
                    self.metrics.observe("kube.sched.cycle_ns", t0.elapsed().as_nanos() as u64);
                    return placed;
                }
                // Committer gone (shutdown race): fall back to inline.
                Err(std::sync::mpsc::SendError(batch)) => batch,
            },
            None => placements,
        };
        let bound = commit_bindings(&self.client, &self.index, &self.metrics, &self.events, placements);
        self.metrics.observe("kube.sched.cycle_ns", t0.elapsed().as_nanos() as u64);
        bound
    }

    /// The pre-index scheduling pass, kept verbatim as the benchmark
    /// baseline (`benches/scheduler.rs`) and differential oracle for
    /// the index: O(nodes) filter/score per pod, linear `used` lookups,
    /// one `update_status` round trip per bind. Not for production use.
    pub fn run_cycle_brute(&self) -> usize {
        let t0 = std::time::Instant::now();
        let _actor = crate::obs::push_actor(COMPONENT);
        if let Err(e) = self.nodes.sync().and_then(|()| self.pods.sync()) {
            self.metrics.inc("kube.sched.list_errors");
            crate::warn!("kube-sched", "informer sync failed, skipping cycle: {e}");
            return 0;
        }
        // Decode node views straight off the cache (no KubeObject clones).
        let nodes: Vec<NodeView> = self
            .nodes
            .read(|objs| objs.values().filter_map(|o| NodeView::from_object(o).ok()).collect());
        // Usage per node from bound, non-terminal pods; pending pods
        // decoded in the same zero-copy pass.
        let mut used: Vec<(String, Resources)> =
            nodes.iter().map(|n| (n.name.clone(), Resources::ZERO)).collect();
        let mut pending: Vec<PodView> = Vec::new();
        let mut origins: std::collections::BTreeMap<
            String,
            (Option<crate::obs::TraceContext>, Option<u64>),
        > = std::collections::BTreeMap::new();
        let mut gated = 0u64;
        self.pods.read(|objs| {
            for obj in objs.values() {
                let Ok(view) = PodView::from_object(obj) else { continue };
                match (&view.node_name, view.phase) {
                    (Some(node), phase) if !phase.terminal() => {
                        if let Some((_, u)) = used.iter_mut().find(|(n, _)| n == node) {
                            *u += view.requests;
                        }
                    }
                    (None, PodPhase::Pending) => {
                        if !view.scheduling_gates.is_empty() {
                            gated += 1;
                            continue;
                        }
                        origins.insert(
                            view.name.clone(),
                            (
                                obj.meta
                                    .annotation(crate::obs::TRACE_ANNOTATION)
                                    .and_then(crate::obs::TraceContext::parse_wire),
                                obj.meta
                                    .annotation(crate::obs::CREATED_WALL_ANNOTATION)
                                    .and_then(|s| s.parse::<u64>().ok()),
                            ),
                        );
                        pending.push(view);
                    }
                    _ => {}
                }
            }
        });
        self.metrics.add("kube.sched.gated", gated);
        pending.sort_by(|a, b| a.name.cmp(&b.name));

        let mut bound = 0;
        for pod in pending {
            let mut candidates: Vec<(&NodeView, Resources)> = nodes
                .iter()
                .filter(|n| n.ready)
                .filter(|n| !n.unschedulable)
                .filter(|n| n.taints.iter().all(|t| pod.tolerations.contains(t)))
                .filter(|n| {
                    pod.node_selector.iter().all(|(k, v)| {
                        n.labels.iter().any(|(nk, nv)| nk == k && nv == v)
                    })
                })
                .filter_map(|n| {
                    let u = used
                        .iter()
                        .find(|(name, _)| name == &n.name)
                        .map(|(_, u)| *u)
                        .unwrap_or(Resources::ZERO);
                    let free = n.capacity.saturating_sub(&u);
                    free.fits(&pod.requests).then_some((n, u))
                })
                .collect();
            if candidates.is_empty() {
                self.metrics.inc("kube.sched.unschedulable");
                let (origin_trace, _) = origins.get(&pod.name).cloned().unwrap_or((None, None));
                let trace_wire = origin_trace.map(|c| c.to_wire());
                let _ = self.events.event_ref(
                    &self.client,
                    super::api::KIND_POD,
                    &pod.name,
                    trace_wire.as_deref(),
                    EVENT_WARNING,
                    "FailedScheduling",
                    &losing_predicate(&nodes, &used, &pod),
                );
                continue;
            }
            // Score: least allocated (lowest dominant fraction after adding).
            candidates.sort_by(|(na, ua), (nb, ub)| {
                let fa = (*ua + pod.requests).dominant_fraction(&na.capacity);
                let fb = (*ub + pod.requests).dominant_fraction(&nb.capacity);
                fa.partial_cmp(&fb).unwrap().then(na.name.cmp(&nb.name))
            });
            let chosen = candidates[0].0.name.clone();
            let (origin_trace, created_ns) =
                origins.get(&pod.name).cloned().unwrap_or((None, None));
            let _span = crate::obs::span_with_parent(
                "kube-sched",
                &format!("bind {}", pod.name),
                origin_trace,
            );
            let ok = self
                .client
                .update_status(super::api::KIND_POD, &pod.name, &|o| {
                    o.spec.insert("nodeName", chosen.clone());
                })
                .is_ok();
            if ok {
                if let Some((_, u)) = used.iter_mut().find(|(n, _)| n == &chosen) {
                    *u += pod.requests;
                }
                bound += 1;
                self.metrics.inc("kube.sched.bound");
                let _ = self.events.event_ref(
                    &self.client,
                    super::api::KIND_POD,
                    &pod.name,
                    origin_trace.map(|c| c.to_wire()).as_deref(),
                    EVENT_NORMAL,
                    "Scheduled",
                    &format!("Successfully assigned {} to {chosen}", pod.name),
                );
                if let Some(t_create) = created_ns {
                    let now_ns = std::time::SystemTime::now()
                        .duration_since(std::time::UNIX_EPOCH)
                        .map(|d| d.as_nanos() as u64)
                        .unwrap_or(0);
                    self.metrics.observe(
                        "slo.pod_create_to_bound_ns",
                        now_ns.saturating_sub(t_create),
                    );
                }
            }
        }
        self.metrics.observe("kube.sched.cycle_ns", t0.elapsed().as_nanos() as u64);
        bound
    }
}

/// Commit a cycle's placements as one batched write; returns the number
/// bound. Shared by the inline path and the daemon-mode committer
/// thread. Per-item failures (and a whole-batch transport failure)
/// un-reserve so the pods requeue; successful reservations stay until
/// the informer echo converts them to confirmed usage.
fn commit_bindings(
    client: &Arc<dyn ApiClient>,
    index: &SchedIndex,
    metrics: &Metrics,
    events: &EventRecorder,
    placements: Vec<Placement>,
) -> usize {
    let t0 = std::time::Instant::now();
    let items: Vec<BatchPatchItem> = placements
        .iter()
        .map(|p| {
            BatchPatchItem::new(
                super::api::KIND_POD,
                &p.pod,
                Value::map().with("spec", Value::map().with("nodeName", p.node.clone())),
            )
        })
        .collect();
    let results = match client.update_status_batch(&items) {
        Ok(r) => r,
        Err(e) => {
            // Transport-level failure: nothing landed. Release every
            // reservation — the pods are still Pending in every cache
            // and requeue on the next cycle.
            crate::warn!(
                "kube-sched",
                "bind batch failed, requeueing {} pod(s): {e}",
                placements.len()
            );
            for p in &placements {
                index.unreserve(&p.pod);
                metrics.inc_with("kube.sched.bind_failed", &[("outcome", "transport")]);
            }
            return 0;
        }
    };
    metrics.observe("kube.sched.bind_batch_ns", t0.elapsed().as_nanos() as u64);
    // Defensive: a short result list must not strand reservations.
    let answered = results.len().min(placements.len());
    for p in &placements[answered..] {
        index.unreserve(&p.pod);
    }
    let mut bound = 0;
    for (p, res) in placements.iter().zip(results) {
        // The bind span parents on the pod's originating trace, so the
        // batched bind still joins the create's tree in `hpcorc trace`.
        let _span = crate::obs::span_with_parent(
            "kube-sched",
            &format!("bind {}", p.pod),
            p.origin_trace,
        );
        match res {
            Ok(_) => {
                bound += 1;
                metrics.inc_with("kube.sched.bound", &[("outcome", "ok")]);
                let _ = events.event_ref(
                    client,
                    super::api::KIND_POD,
                    &p.pod,
                    p.origin_trace.map(|c| c.to_wire()).as_deref(),
                    EVENT_NORMAL,
                    "Scheduled",
                    &format!("Successfully assigned {} to {}", p.pod, p.node),
                );
                if let Some(t_create) = p.created_ns {
                    let now_ns = std::time::SystemTime::now()
                        .duration_since(std::time::UNIX_EPOCH)
                        .map(|d| d.as_nanos() as u64)
                        .unwrap_or(0);
                    metrics
                        .observe("slo.pod_create_to_bound_ns", now_ns.saturating_sub(t_create));
                }
            }
            Err(e) => {
                index.unreserve(&p.pod);
                let outcome = if e.is_conflict() || e.is_conflict_exhausted() {
                    "conflict"
                } else if e.is_not_found() {
                    "not_found"
                } else {
                    "error"
                };
                metrics.inc_with("kube.sched.bind_failed", &[("outcome", outcome)]);
                crate::warn!("kube-sched", "bind {} -> {} failed ({e}), requeued", p.pod, p.node);
            }
        }
    }
    bound
}

/// The FailedScheduling diagnosis: walk the filter chain once more,
/// counting where each node was eliminated — the k8s
/// `0/N nodes available: ...` message, naming the losing predicate(s).
/// The indexed path derives the same counts from bucket checks
/// ([`super::sched_index::Eliminations`]); this walk remains for the
/// brute path and as the byte-equality oracle in tests.
fn losing_predicate(
    nodes: &[NodeView],
    used: &[(String, Resources)],
    pod: &PodView,
) -> String {
    let (mut not_ready, mut cordoned, mut tainted, mut selector, mut no_fit) = (0, 0, 0, 0, 0);
    for n in nodes {
        if !n.ready {
            not_ready += 1;
        } else if n.unschedulable {
            cordoned += 1;
        } else if !n.taints.iter().all(|t| pod.tolerations.contains(t)) {
            tainted += 1;
        } else if !pod
            .node_selector
            .iter()
            .all(|(k, v)| n.labels.iter().any(|(nk, nv)| nk == k && nv == v))
        {
            selector += 1;
        } else {
            let u = used
                .iter()
                .find(|(name, _)| name == &n.name)
                .map(|(_, u)| *u)
                .unwrap_or(Resources::ZERO);
            if !n.capacity.saturating_sub(&u).fits(&pod.requests) {
                no_fit += 1;
            }
        }
    }
    let mut parts = Vec::new();
    for (count, what) in [
        (not_ready, "node(s) were not ready"),
        (cordoned, "node(s) were unschedulable"),
        (tainted, "node(s) had untolerated taints"),
        (selector, "node(s) didn't match the nodeSelector"),
        (no_fit, "node(s) had insufficient resources"),
    ] {
        if count > 0 {
            parts.push(format!("{count} {what}"));
        }
    }
    if parts.is_empty() {
        parts.push("no nodes registered".to_string());
    }
    format!("0/{} nodes available: {}", nodes.len(), parts.join(", "))
}

/// Helper for building schedulable pods in tests and the operator.
pub fn pod_with_tolerations(mut pod: KubeObject, tolerations: &[&str]) -> KubeObject {
    if !tolerations.is_empty() {
        pod.spec.insert(
            "tolerations",
            crate::encoding::Value::Seq(
                tolerations
                    .iter()
                    .map(|t| {
                        crate::encoding::Value::map()
                            .with("key", *t)
                            .with("operator", "Exists")
                    })
                    .collect(),
            ),
        );
    }
    pod
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kube::api::{NodeView, PodView, KIND_NODE, KIND_POD};
    use crate::kube::apiserver::ApiServer;

    fn setup() -> (ApiServer, KubeScheduler) {
        let api = ApiServer::new(Metrics::new());
        let informers = crate::kube::SharedInformerFactory::new(api.client(), Metrics::new());
        let sched = KubeScheduler::new(&informers, Metrics::new());
        (api, sched)
    }

    fn add_node(api: &ApiServer, name: &str, cores: u32) {
        api.create(NodeView::build(name, Resources::cores(cores, 32 << 30), &[])).unwrap();
    }

    fn add_pod(api: &ApiServer, name: &str, cpu_milli: u64) -> KubeObject {
        let pod = PodView::build(
            name,
            "lolcow_latest.sif",
            Resources::new(cpu_milli, 1 << 30, 0),
            &[],
        );
        api.create(pod).unwrap()
    }

    fn node_of(api: &ApiServer, pod: &str) -> Option<String> {
        api.get(KIND_POD, pod).unwrap().spec.opt_str("nodeName").map(String::from)
    }

    #[test]
    fn binds_pending_pods() {
        let (api, sched) = setup();
        add_node(&api, "w1", 8);
        add_pod(&api, "p1", 1000);
        assert_eq!(sched.run_cycle(), 1);
        assert_eq!(node_of(&api, "p1").as_deref(), Some("w1"));
        // Second cycle: nothing to do.
        assert_eq!(sched.run_cycle(), 0);
    }

    #[test]
    fn respects_capacity() {
        let (api, sched) = setup();
        add_node(&api, "w1", 2); // 2000m
        add_pod(&api, "p1", 1500);
        add_pod(&api, "p2", 1500); // doesn't fit alongside p1
        assert_eq!(sched.run_cycle(), 1);
        assert!(node_of(&api, "p2").is_none(), "p2 unschedulable");
        // Free capacity by completing p1.
        api.update_status(KIND_POD, "p1", |o| {
            o.status.insert("phase", "Succeeded");
        })
        .unwrap();
        assert_eq!(sched.run_cycle(), 1);
        assert_eq!(node_of(&api, "p2").as_deref(), Some("w1"));
    }

    #[test]
    fn least_allocated_spreads() {
        let (api, sched) = setup();
        add_node(&api, "w1", 8);
        add_node(&api, "w2", 8);
        add_pod(&api, "p1", 1000);
        add_pod(&api, "p2", 1000);
        sched.run_cycle();
        let n1 = node_of(&api, "p1").unwrap();
        let n2 = node_of(&api, "p2").unwrap();
        assert_ne!(n1, n2, "pods spread across nodes");
    }

    #[test]
    fn taints_require_toleration() {
        let (api, sched) = setup();
        api.create(NodeView::build(
            "vnode-batch",
            Resources::cores(64, 256 << 30),
            &["virtual-kubelet"],
        ))
        .unwrap();
        add_pod(&api, "plain", 100);
        assert_eq!(sched.run_cycle(), 0, "plain pod cannot land on tainted node");
        let dummy = pod_with_tolerations(
            PodView::build("dummy", "lolcow_latest.sif", Resources::ZERO, &[]),
            &["virtual-kubelet"],
        );
        api.create(dummy).unwrap();
        assert_eq!(sched.run_cycle(), 1);
        assert_eq!(node_of(&api, "dummy").as_deref(), Some("vnode-batch"));
    }

    #[test]
    fn node_selector_filters() {
        let (api, sched) = setup();
        add_node(&api, "w1", 8);
        let mut gpu_node = NodeView::build("w2", Resources::cores(8, 32 << 30), &[]);
        gpu_node.meta.set_label("accelerator", "gpu");
        api.create(gpu_node).unwrap();
        let mut pod = PodView::build("gp", "img", Resources::new(100, 0, 0), &[]);
        pod.spec.insert(
            "nodeSelector",
            crate::encoding::Value::map().with("accelerator", "gpu"),
        );
        api.create(pod).unwrap();
        sched.run_cycle();
        assert_eq!(node_of(&api, "gp").as_deref(), Some("w2"));
    }

    #[test]
    fn scheduling_gated_pod_held_until_gates_clear() {
        use crate::kube::api::{add_scheduling_gate, remove_scheduling_gate};
        let (api, sched) = setup();
        add_node(&api, "w1", 8);
        let mut pod = PodView::build("gated", "img", Resources::new(100, 1 << 20, 0), &[]);
        add_scheduling_gate(&mut pod, "kueue.x-k8s.io/admission");
        add_scheduling_gate(&mut pod, "other-layer");
        api.create(pod).unwrap();
        assert_eq!(sched.run_cycle(), 0, "gated pod must not bind");
        // One gate down, one to go: still held.
        api.update_status(KIND_POD, "gated", |o| {
            remove_scheduling_gate(o, "kueue.x-k8s.io/admission");
        })
        .unwrap();
        assert_eq!(sched.run_cycle(), 0, "every gate must clear");
        api.update_status(KIND_POD, "gated", |o| {
            remove_scheduling_gate(o, "other-layer");
        })
        .unwrap();
        assert_eq!(sched.run_cycle(), 1);
        assert_eq!(node_of(&api, "gated").as_deref(), Some("w1"));
    }

    #[test]
    fn cordoned_node_excluded() {
        let (api, sched) = setup();
        add_node(&api, "w1", 8);
        add_node(&api, "w2", 8);
        api.update_status(KIND_NODE, "w1", |o| {
            o.spec.insert("unschedulable", true);
        })
        .unwrap();
        add_pod(&api, "p1", 100);
        add_pod(&api, "p2", 100);
        assert_eq!(sched.run_cycle(), 2);
        assert_eq!(node_of(&api, "p1").as_deref(), Some("w2"), "cordoned node skipped");
        assert_eq!(node_of(&api, "p2").as_deref(), Some("w2"));
    }

    #[test]
    fn cycle_emits_scheduled_and_failed_scheduling_events() {
        use crate::kube::events::{EventView, KIND_EVENT};
        use crate::kube::ListOptions;
        let (api, sched) = setup();
        add_node(&api, "w1", 1); // 1000m
        add_pod(&api, "fits", 500);
        add_pod(&api, "huge", 4000);
        sched.run_cycle();
        sched.run_cycle(); // second failure for `huge` coalesces

        let events: Vec<EventView> = api
            .client()
            .list(KIND_EVENT, &ListOptions::all())
            .unwrap()
            .items
            .iter()
            .map(|o| EventView::from_object(o).unwrap())
            .collect();
        let scheduled = events.iter().find(|e| e.reason == "Scheduled").unwrap();
        assert_eq!(scheduled.regarding_name, "fits");
        assert_eq!(scheduled.etype, EVENT_NORMAL);
        assert_eq!(scheduled.reporting_controller, COMPONENT);
        assert!(scheduled.note.contains("w1"), "{}", scheduled.note);
        let failed = events.iter().find(|e| e.reason == "FailedScheduling").unwrap();
        assert_eq!(failed.regarding_name, "huge");
        assert_eq!(failed.etype, EVENT_WARNING);
        assert_eq!(failed.count, 2, "second failure bumps the count");
        assert!(
            failed.note.contains("0/1 nodes available") && failed.note.contains("insufficient"),
            "{}",
            failed.note
        );
        // Writes this cycle audited as the scheduler.
        let audited = api.audit_log().snapshot();
        assert!(audited.iter().any(|r| r.actor == COMPONENT && r.verb == "update_status"));
    }

    #[test]
    fn not_ready_node_excluded() {
        let (api, sched) = setup();
        add_node(&api, "w1", 8);
        api.update_status(KIND_NODE, "w1", |o| {
            o.status.insert("phase", "NotReady");
        })
        .unwrap();
        add_pod(&api, "p1", 100);
        assert_eq!(sched.run_cycle(), 0);
    }

    /// A mixed fleet driven through both implementations must produce
    /// identical assignments, pod for pod — the index is an exact
    /// replacement for the brute-force filter/score pass, not an
    /// approximation.
    #[test]
    fn indexed_cycle_matches_brute_force_assignments() {
        let build = || {
            let (api, sched) = setup();
            add_node(&api, "w1", 2);
            add_node(&api, "w2", 4);
            add_node(&api, "w3", 8);
            add_node(&api, "w4", 8);
            api.create(NodeView::build(
                "vnode",
                Resources::cores(64, 256 << 30),
                &["virtual-kubelet"],
            ))
            .unwrap();
            let mut gpu = NodeView::build("gpu1", Resources::cores(8, 32 << 30), &[]);
            gpu.meta.set_label("accelerator", "gpu");
            api.create(gpu).unwrap();
            api.update_status(KIND_NODE, "w4", |o| {
                o.spec.insert("unschedulable", true);
            })
            .unwrap();
            for (name, cpu) in
                [("a", 500u64), ("b", 1500), ("c", 3000), ("d", 1000), ("e", 9000), ("f", 100)]
            {
                add_pod(&api, name, cpu);
            }
            let mut sel = PodView::build("g", "img", Resources::new(200, 0, 0), &[]);
            sel.spec.insert(
                "nodeSelector",
                crate::encoding::Value::map().with("accelerator", "gpu"),
            );
            api.create(sel).unwrap();
            let tol = pod_with_tolerations(
                PodView::build("h", "img", Resources::new(4000, 1 << 30, 0), &[]),
                &["virtual-kubelet"],
            );
            api.create(tol).unwrap();
            (api, sched)
        };
        let (api_idx, sched_idx) = build();
        let (api_brute, sched_brute) = build();
        assert_eq!(sched_idx.run_cycle(), sched_brute.run_cycle_brute());
        for pod in ["a", "b", "c", "d", "e", "f", "g", "h"] {
            assert_eq!(
                node_of(&api_idx, pod),
                node_of(&api_brute, pod),
                "assignment diverged for {pod}"
            );
        }
    }

    /// Satellite guard: the indexed failure diagnosis must stay
    /// byte-identical to the legacy `losing_predicate` walk — consumers
    /// (and humans) pattern-match on this message.
    #[test]
    fn failed_scheduling_message_byte_identical_to_legacy_walk() {
        use crate::kube::events::{EventView, KIND_EVENT};
        use crate::kube::ListOptions;
        let (api, sched) = setup();
        add_node(&api, "tiny", 1);
        api.create(NodeView::build("tainted", Resources::cores(8, 32 << 30), &["gpu-only"]))
            .unwrap();
        add_node(&api, "down", 8);
        api.update_status(KIND_NODE, "down", |o| {
            o.status.insert("phase", "NotReady");
        })
        .unwrap();
        add_node(&api, "fenced", 8);
        api.update_status(KIND_NODE, "fenced", |o| {
            o.spec.insert("unschedulable", true);
        })
        .unwrap();
        add_pod(&api, "huge", 4000);
        assert_eq!(sched.run_cycle(), 0);
        let note = api
            .client()
            .list(KIND_EVENT, &ListOptions::all())
            .unwrap()
            .items
            .iter()
            .filter_map(|o| EventView::from_object(o).ok())
            .find(|e| e.reason == "FailedScheduling")
            .unwrap()
            .note;
        // Literal expectation first: predicates in filter order.
        assert_eq!(
            note,
            "0/4 nodes available: 1 node(s) were not ready, 1 node(s) were unschedulable, \
             1 node(s) had untolerated taints, 1 node(s) had insufficient resources"
        );
        // And equality with the legacy walk over the same world.
        let nodes: Vec<NodeView> = api
            .client()
            .list(KIND_NODE, &ListOptions::all())
            .unwrap()
            .items
            .iter()
            .filter_map(|o| NodeView::from_object(o).ok())
            .collect();
        let used: Vec<(String, Resources)> =
            nodes.iter().map(|n| (n.name.clone(), Resources::ZERO)).collect();
        let pod = PodView::from_object(&api.get(KIND_POD, "huge").unwrap()).unwrap();
        assert_eq!(note, losing_predicate(&nodes, &used, &pod));
    }
}
