#!/usr/bin/env bash
# End-to-end CLI smoke: drive the release binary the way a user would —
# trace generation, the simulator's elastic and kueue-quota paths, and a
# live testbed exercised through the kubectl table paths over the red-box
# socket. Run by the CI `smoke` job; runs locally too:
#
#   cargo build --release --manifest-path rust/Cargo.toml
#   scripts/smoke.sh rust/target/release/hpcorc
set -euo pipefail

HPCORC="${1:-rust/target/release/hpcorc}"
command -v "$HPCORC" >/dev/null || [ -x "$HPCORC" ] || {
  echo "smoke: binary not found: $HPCORC" >&2
  exit 1
}
WORK="$(mktemp -d)"
SOCK="$WORK/redbox.sock"
UP_PID=""
cleanup() {
  [ -n "$UP_PID" ] && kill "$UP_PID" 2>/dev/null || true
  rm -rf "$WORK"
}
trap cleanup EXIT

echo "== trace gen (diurnal) =="
"$HPCORC" trace gen --kind diurnal --jobs 80 --out "$WORK/diurnal.json"
test -s "$WORK/diurnal.json"

echo "== sim: static vs elastic on the diurnal trace =="
"$HPCORC" sim --trace "$WORK/diurnal.json" --policy easy --nodes 8
"$HPCORC" sim --trace "$WORK/diurnal.json" --policy easy \
  --elastic-max 8 --elastic-min 1 --provision-delay 30 --idle-window 300

echo "== sim: kueue quota admission over a generated tenants trace =="
"$HPCORC" sim --kind tenants --jobs 60 --policy easy --quota-nodes 4 --cohort

echo "== testbed up + kubectl table paths over the socket =="
"$HPCORC" up --socket "$SOCK" --run-for 120 >"$WORK/up.log" 2>&1 &
UP_PID=$!
for _ in $(seq 1 100); do
  [ -S "$SOCK" ] && break
  sleep 0.1
done
if ! [ -S "$SOCK" ]; then
  echo "smoke: red-box socket never appeared" >&2
  cat "$WORK/up.log" >&2
  exit 1
fi

cat >"$WORK/cq.yaml" <<'EOF'
apiVersion: kueue.x-k8s.io/v1beta1
kind: ClusterQueue
metadata:
  name: smoke-cq
spec:
  quota:
    nodes: 4
EOF
"$HPCORC" kubectl apply -f "$WORK/cq.yaml" --socket "$SOCK"
"$HPCORC" kubectl get cq --socket "$SOCK" | tee "$WORK/cq.out"
grep -q smoke-cq "$WORK/cq.out"

cat >"$WORK/tj.yaml" <<'EOF'
apiVersion: wlm.sylabs.io/v1alpha1
kind: TorqueJob
metadata:
  name: smoke-cow
spec:
  batch: |
    #!/bin/sh
    #PBS -l walltime=00:30:00
    #PBS -l nodes=1
    #PBS -e $HOME/smoke.err
    #PBS -o $HOME/smoke.out
    singularity run lolcow_latest.sif
  results:
    from: $HOME/smoke.out
  mount:
    name: data
    hostPath:
      path: $HOME/
      type: DirectoryOrCreate
EOF
"$HPCORC" kubectl apply -f "$WORK/tj.yaml" --socket "$SOCK"
for _ in $(seq 1 150); do
  "$HPCORC" kubectl get tj --socket "$SOCK" >"$WORK/tj.out"
  grep -Eq 'completed|failed' "$WORK/tj.out" && break
  sleep 0.2
done
cat "$WORK/tj.out"
grep -q smoke-cow "$WORK/tj.out"
grep -q completed "$WORK/tj.out"

"$HPCORC" kubectl get pods --socket "$SOCK" >/dev/null
"$HPCORC" kubectl get nodes --socket "$SOCK" >/dev/null

kill "$UP_PID" 2>/dev/null || true
wait "$UP_PID" 2>/dev/null || true
UP_PID=""
echo "smoke OK"
