//! Span recorder: trace contexts, RAII span guards, and a bounded ring
//! of completed spans exportable as Chrome trace-event JSON.
//!
//! A **trace** is one causal tree of work identified by a 64-bit
//! `trace_id`; each unit of work inside it is a **span** with its own
//! `span_id` and a `parent` link. Context lives in a thread-local stack:
//! [`span`] opens a child of whatever is current (or a new root),
//! [`span_with_parent`] adopts a context that arrived from elsewhere
//! (the red-box wire, an object annotation), and [`current`] reads the
//! active context so call sites — the red-box client, the logger — can
//! stamp it onto whatever they emit.
//!
//! Completed spans land in a global fixed-capacity ring under one mutex;
//! pushes are O(1) and allocation-free once the ring is warm, so the
//! recorder is safe to leave on inside hot loops. When tracing is
//! disabled ([`set_enabled`]) every guard is a no-op costing one atomic
//! load — benchmarked in `benches/obs.rs`.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// Object annotation carrying the originating trace context
/// (`<trace_id>-<span_id>` in hex, the same rendering as the wire field)
/// so every later hop of an object's lifecycle — admission, scheduling,
/// the operator — can parent its spans on the create that started it.
pub const TRACE_ANNOTATION: &str = "hpcorc.io/trace";

/// Object annotation holding the server's wall clock (nanoseconds since
/// the epoch) at create time — what the scheduler subtracts from to
/// observe the end-to-end create→bound SLO histogram regardless of which
/// transport carried the create.
pub const CREATED_WALL_ANNOTATION: &str = "hpcorc.io/created-wall-ns";

/// Completed spans retained in the ring (oldest overwritten first).
pub const RING_CAPACITY: usize = 8192;

/// The identity of one span within one trace. `parent == 0` means root.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceContext {
    pub trace_id: u64,
    pub span_id: u64,
    pub parent: u64,
}

impl TraceContext {
    /// Wire rendering carried on red-box requests and in the
    /// [`TRACE_ANNOTATION`]: `<16-hex trace_id>-<16-hex span_id>`. The
    /// receiver treats the sender's span as its parent.
    pub fn to_wire(&self) -> String {
        format!("{:016x}-{:016x}", self.trace_id, self.span_id)
    }

    /// Parse the wire rendering; `None` on anything malformed (old peers
    /// that never send the field simply yield no context).
    pub fn parse_wire(s: &str) -> Option<TraceContext> {
        let (t, sp) = s.split_once('-')?;
        let trace_id = u64::from_str_radix(t, 16).ok()?;
        let span_id = u64::from_str_radix(sp, 16).ok()?;
        if trace_id == 0 || span_id == 0 {
            return None;
        }
        Some(TraceContext { trace_id, span_id, parent: 0 })
    }
}

/// One completed span as recorded in the ring.
#[derive(Debug, Clone)]
pub struct Span {
    pub trace_id: u64,
    pub span_id: u64,
    pub parent: u64,
    /// Component that opened the span (Chrome `cat`), e.g. `apiserver`.
    pub component: String,
    /// Operation name (Chrome `name`), e.g. `kube.Api/Create`.
    pub name: String,
    /// Wall-clock start, microseconds since the Unix epoch (Chrome `ts`).
    pub start_us: u64,
    /// Duration in microseconds (Chrome `dur`).
    pub dur_us: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(true);
static NEXT: AtomicU64 = AtomicU64::new(1);
static SEED: AtomicU64 = AtomicU64::new(0);

struct Ring {
    spans: Vec<Span>,
    /// Next overwrite position once the ring is full.
    next: usize,
}

static RING: Mutex<Ring> = Mutex::new(Ring { spans: Vec::new(), next: 0 });

thread_local! {
    static STACK: RefCell<Vec<TraceContext>> = const { RefCell::new(Vec::new()) };
}

/// Whether spans are being recorded.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the recorder on/off process-wide. Off: every guard becomes a
/// no-op and [`current`] keeps answering for already-open spans only.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

fn seed() -> u64 {
    let s = SEED.load(Ordering::Relaxed);
    if s != 0 {
        return s;
    }
    let wall =
        SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_nanos() as u64;
    let mixed = splitmix64(wall ^ ((std::process::id() as u64) << 32)) | 1;
    // First writer wins so every thread derives ids from one seed.
    let _ = SEED.compare_exchange(0, mixed, Ordering::Relaxed, Ordering::Relaxed);
    SEED.load(Ordering::Relaxed)
}

/// A fresh non-zero id, unique within the process and seeded so two
/// processes (daemon + CLI) do not collide in practice.
fn new_id() -> u64 {
    let n = NEXT.fetch_add(1, Ordering::Relaxed);
    let id = splitmix64(seed().wrapping_add(n));
    if id == 0 {
        1
    } else {
        id
    }
}

/// The active trace context on this thread, if any.
pub fn current() -> Option<TraceContext> {
    STACK.with(|s| s.borrow().last().copied())
}

/// RAII span: pushed onto the thread's context stack at creation,
/// popped and recorded into the ring on drop. Obtained from [`span`] /
/// [`span_with_parent`]; a disabled recorder hands out inert guards.
pub struct SpanGuard {
    active: Option<ActiveSpan>,
}

struct ActiveSpan {
    ctx: TraceContext,
    component: String,
    name: String,
    start_us: u64,
    t0: Instant,
}

impl SpanGuard {
    /// The context this guard pushed (`None` for a disabled no-op guard).
    pub fn context(&self) -> Option<TraceContext> {
        self.active.as_ref().map(|a| a.ctx)
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(a) = self.active.take() else { return };
        STACK.with(|s| {
            let mut st = s.borrow_mut();
            // Pop our own frame; tolerate a foreign top (mismatched drop
            // order across an unwind) by searching from the back.
            if let Some(pos) = st.iter().rposition(|c| c.span_id == a.ctx.span_id) {
                st.remove(pos);
            }
        });
        push_span(Span {
            trace_id: a.ctx.trace_id,
            span_id: a.ctx.span_id,
            parent: a.ctx.parent,
            component: a.component,
            name: a.name,
            start_us: a.start_us,
            dur_us: a.t0.elapsed().as_micros() as u64,
        });
    }
}

fn wall_us() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_micros() as u64
}

fn open(component: &str, name: &str, parent: Option<TraceContext>) -> SpanGuard {
    if !enabled() {
        return SpanGuard { active: None };
    }
    let ctx = match parent {
        Some(p) => TraceContext { trace_id: p.trace_id, span_id: new_id(), parent: p.span_id },
        None => {
            let id = new_id();
            TraceContext { trace_id: id, span_id: id, parent: 0 }
        }
    };
    STACK.with(|s| s.borrow_mut().push(ctx));
    SpanGuard {
        active: Some(ActiveSpan {
            ctx,
            component: component.to_string(),
            name: name.to_string(),
            start_us: wall_us(),
            t0: Instant::now(),
        }),
    }
}

/// Open a span as a child of the thread's current context (or a new root
/// when none is active).
pub fn span(component: &str, name: &str) -> SpanGuard {
    open(component, name, current())
}

/// Open a span parented on an explicit context — the adoption point for
/// contexts that crossed a boundary (red-box wire field, object
/// annotation). `None` behaves like [`span`].
pub fn span_with_parent(component: &str, name: &str, parent: Option<TraceContext>) -> SpanGuard {
    open(component, name, parent.or_else(current))
}

fn push_span(s: Span) {
    let mut r = RING.lock().unwrap();
    if r.spans.len() < RING_CAPACITY {
        r.spans.push(s);
    } else {
        let i = r.next;
        r.spans[i] = s;
        r.next = (i + 1) % RING_CAPACITY;
    }
}

/// Every span currently retained, oldest first.
pub fn spans_snapshot() -> Vec<Span> {
    let r = RING.lock().unwrap();
    let mut out = Vec::with_capacity(r.spans.len());
    if r.spans.len() == RING_CAPACITY {
        out.extend_from_slice(&r.spans[r.next..]);
        out.extend_from_slice(&r.spans[..r.next]);
    } else {
        out.extend_from_slice(&r.spans);
    }
    out
}

/// Retained spans belonging to one trace, sorted by start time.
pub fn by_trace(trace_id: u64) -> Vec<Span> {
    let mut out: Vec<Span> =
        spans_snapshot().into_iter().filter(|s| s.trace_id == trace_id).collect();
    out.sort_by_key(|s| (s.start_us, s.span_id));
    out
}

/// Drop every retained span (test isolation).
pub fn clear() {
    let mut r = RING.lock().unwrap();
    r.spans.clear();
    r.next = 0;
}

/// Render spans as a Chrome trace-event JSON array (complete `"X"`
/// events) — loads directly into Perfetto / `chrome://tracing`. Each
/// trace renders as its own `tid` track; parent/span ids travel in
/// `args` so the causal tree survives the export.
pub fn chrome_json(spans: &[Span]) -> String {
    crate::encoding::json::to_string(&chrome_events(spans))
}

/// The same export as a [`Value`] array — what `obs.Spans` serves over
/// red-box so remote consumers get structure, not a string to re-parse.
pub fn chrome_events(spans: &[Span]) -> crate::encoding::Value {
    use crate::encoding::Value;
    let events: Vec<Value> = spans
        .iter()
        .map(|s| {
            Value::map()
                .with("name", s.name.clone())
                .with("cat", s.component.clone())
                .with("ph", "X")
                .with("ts", s.start_us)
                .with("dur", s.dur_us.max(1))
                .with("pid", 1u64)
                .with("tid", s.trace_id & 0x7fff_ffff)
                .with(
                    "args",
                    Value::map()
                        .with("trace_id", format!("{:016x}", s.trace_id))
                        .with("span_id", format!("{:016x}", s.span_id))
                        .with("parent", format!("{:016x}", s.parent)),
                )
        })
        .collect();
    Value::Seq(events)
}

/// [`chrome_json`] over the whole ring.
pub fn export_chrome_json() -> String {
    chrome_json(&spans_snapshot())
}

/// The recorder is process-global; tests (here and in sibling modules)
/// that toggle the enable flag or inspect the ring serialize on this.
#[cfg(test)]
pub(crate) static TEST_SERIAL: Mutex<()> = Mutex::new(());

#[cfg(test)]
pub(crate) fn test_serial() -> std::sync::MutexGuard<'static, ()> {
    TEST_SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn serial() -> std::sync::MutexGuard<'static, ()> {
        test_serial()
    }

    #[test]
    fn wire_roundtrip() {
        let ctx = TraceContext { trace_id: 0xdead_beef, span_id: 42, parent: 7 };
        let wire = ctx.to_wire();
        let back = TraceContext::parse_wire(&wire).unwrap();
        assert_eq!(back.trace_id, ctx.trace_id);
        assert_eq!(back.span_id, ctx.span_id);
        assert_eq!(back.parent, 0, "wire carries no grandparent");
        assert!(TraceContext::parse_wire("junk").is_none());
        assert!(TraceContext::parse_wire("0-0").is_none());
        assert!(TraceContext::parse_wire("12x-34").is_none());
    }

    #[test]
    fn nesting_links_parents() {
        let _s = serial();
        set_enabled(true);
        let root = span("test", "root");
        let root_ctx = root.context().unwrap();
        assert_eq!(root_ctx.parent, 0);
        assert_eq!(root_ctx.trace_id, root_ctx.span_id);
        {
            let child = span("test", "child");
            let c = child.context().unwrap();
            assert_eq!(c.trace_id, root_ctx.trace_id);
            assert_eq!(c.parent, root_ctx.span_id);
            assert_eq!(current().unwrap().span_id, c.span_id);
        }
        // Child popped; root is current again.
        assert_eq!(current().unwrap().span_id, root_ctx.span_id);
        drop(root);
        assert!(current().is_none());
        let tree = by_trace(root_ctx.trace_id);
        assert_eq!(tree.len(), 2);
        assert!(tree.iter().any(|s| s.name == "root" && s.parent == 0));
        assert!(
            tree.iter().any(|s| s.name == "child" && s.parent == root_ctx.span_id),
            "child links to root"
        );
    }

    #[test]
    fn adoption_joins_the_remote_trace() {
        let _s = serial();
        set_enabled(true);
        let remote = TraceContext { trace_id: 77, span_id: 99, parent: 0 };
        let g = span_with_parent("test", "handler", Some(remote));
        let ctx = g.context().unwrap();
        assert_eq!(ctx.trace_id, 77);
        assert_eq!(ctx.parent, 99);
        assert_ne!(ctx.span_id, 99, "adoption mints a fresh span id");
    }

    #[test]
    fn disabled_guards_are_inert() {
        let _s = serial();
        set_enabled(false);
        let g = span("test", "nope");
        assert!(g.context().is_none());
        assert!(current().is_none());
        drop(g);
        set_enabled(true);
    }

    #[test]
    fn chrome_export_is_valid_json() {
        let _s = serial();
        set_enabled(true);
        {
            let _g = span("test", "export-me");
        }
        let json = export_chrome_json();
        let v = crate::encoding::json::parse(&json).unwrap();
        let events = v.as_seq().expect("top-level array");
        assert!(!events.is_empty());
        let e = events.iter().find(|e| e.opt_str("name") == Some("export-me")).unwrap();
        assert_eq!(e.opt_str("ph"), Some("X"));
        assert!(e.get("ts").is_some() && e.get("dur").is_some());
        assert!(e.get("args").unwrap().opt_str("trace_id").is_some());
    }

    #[test]
    fn ring_overwrites_oldest() {
        let _s = serial();
        // Use a private burst larger than capacity and check bounds only
        // (other tests share the ring).
        set_enabled(true);
        for i in 0..(RING_CAPACITY + 10) {
            push_span(Span {
                trace_id: 1,
                span_id: i as u64 + 1,
                parent: 0,
                component: "t".into(),
                name: "n".into(),
                start_us: i as u64,
                dur_us: 1,
            });
        }
        assert!(spans_snapshot().len() <= RING_CAPACITY);
        clear();
        assert!(spans_snapshot().is_empty());
    }
}
