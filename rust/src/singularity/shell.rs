//! Tiny POSIX-sh-subset interpreter for PBS/Slurm batch script bodies.
//!
//! The paper's Fig. 3 batch body is:
//! ```text
//! export PATH=$PATH:/usr/local/bin
//! singularity run lolcow_latest.sif
//! ```
//! pbs_mom and slurmd hand the script body to this interpreter. Supported:
//! comments, `export K=V`, `echo` (with `>`/`>>` redirects into the shared
//! FS), `sleep N`, `singularity run IMAGE`, `cat FILE`, `true`/`false`,
//! `exit N`. Unknown commands behave like sh: an error on stderr, exit
//! status 127, execution continues; the script's status is the last
//! command's.

use super::runtime::{CancelToken, RunRequest, Runtime};
use crate::cluster::fs::expand_vars;
use crate::cluster::SharedFs;
use std::collections::BTreeMap;
use std::time::Duration;

pub struct ShellCtx {
    pub env: BTreeMap<String, String>,
    pub fs: SharedFs,
    pub runtime: Runtime,
    pub cancel: CancelToken,
    pub stdout: String,
    pub stderr: String,
    pub time_scale: f64,
    pub seed: u64,
}

impl ShellCtx {
    pub fn new(fs: SharedFs, runtime: Runtime, cancel: CancelToken) -> Self {
        let mut env = BTreeMap::new();
        env.insert("HOME".to_string(), fs.env("HOME").unwrap_or_else(|| "/home/user".into()));
        env.insert("PATH".to_string(), "/usr/bin:/bin".to_string());
        ShellCtx {
            env,
            fs,
            runtime,
            cancel,
            stdout: String::new(),
            stderr: String::new(),
            time_scale: 1.0,
            seed: 0,
        }
    }

    fn expand(&self, s: &str) -> String {
        expand_vars(s, |k| self.env.get(k).cloned())
    }

    /// Run all lines; returns the script's exit status.
    pub fn run_script(&mut self, lines: &[String]) -> i32 {
        let mut status = 0;
        for line in lines {
            if self.cancel.is_triggered() {
                return 137;
            }
            match self.run_line(line) {
                LineOutcome::Status(s) => status = s,
                LineOutcome::Exit(s) => return s,
                LineOutcome::Skip => {}
            }
        }
        status
    }

    fn run_line(&mut self, raw: &str) -> LineOutcome {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            return LineOutcome::Skip;
        }
        // Redirection: split on the FIRST unquoted `>` / `>>`.
        let (cmd_part, redirect) = split_redirect(line);
        let words = split_words(&cmd_part);
        if words.is_empty() {
            return LineOutcome::Skip;
        }
        let argv: Vec<String> = words.iter().map(|w| self.expand(w)).collect();
        let mut out = String::new();
        let status = match argv[0].as_str() {
            "export" => {
                for kv in &argv[1..] {
                    if let Some((k, v)) = kv.split_once('=') {
                        self.env.insert(k.to_string(), v.to_string());
                    }
                }
                0
            }
            "echo" => {
                out = argv[1..].join(" ");
                out.push('\n');
                0
            }
            "cat" => match argv.get(1) {
                Some(path) => match self.fs.read_string(path) {
                    Ok(content) => {
                        out = content;
                        0
                    }
                    Err(_) => {
                        self.stderr.push_str(&format!("cat: {path}: No such file\n"));
                        1
                    }
                },
                None => 0,
            },
            "sleep" => {
                let secs: f64 = argv.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.0);
                let scaled = Duration::from_secs_f64(secs * self.time_scale.max(0.0));
                if self.cancel.wait_timeout(scaled) {
                    return LineOutcome::Exit(137);
                }
                0
            }
            "true" => 0,
            "false" => 1,
            "exit" => {
                let code = argv.get(1).and_then(|s| s.parse().ok()).unwrap_or(0);
                return LineOutcome::Exit(code);
            }
            "singularity" => {
                // `singularity run IMAGE [key=value...]`, `exec` treated alike.
                if argv.len() < 3 || (argv[1] != "run" && argv[1] != "exec") {
                    self.stderr.push_str("usage: singularity run <image>\n");
                    2
                } else {
                    let mut req = RunRequest::new(argv[2].clone());
                    req.time_scale = self.time_scale;
                    req.seed = self.seed;
                    req.env =
                        self.env.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
                    match self.runtime.run(&req, &self.fs, &self.cancel) {
                        Ok(res) => {
                            out = res.stdout;
                            self.stderr.push_str(&res.stderr);
                            if res.cancelled {
                                return LineOutcome::Exit(137);
                            }
                            res.exit_code
                        }
                        Err(e) => {
                            self.stderr.push_str(&format!("singularity: {e}\n"));
                            255
                        }
                    }
                }
            }
            other => {
                self.stderr.push_str(&format!("{other}: command not found\n"));
                127
            }
        };
        match redirect {
            Some((path, append)) => {
                let target = self.expand(&path);
                let r = if append {
                    self.fs.append(&target, out.as_bytes())
                } else {
                    self.fs.write(&target, out.as_bytes())
                };
                if let Err(e) = r {
                    self.stderr.push_str(&format!("redirect: {e}\n"));
                    return LineOutcome::Status(1);
                }
            }
            None => self.stdout.push_str(&out),
        }
        LineOutcome::Status(status)
    }
}

enum LineOutcome {
    Status(i32),
    Exit(i32),
    Skip,
}

/// Split `cmd args > file` into (cmd part, Some((file, append))).
fn split_redirect(line: &str) -> (String, Option<(String, bool)>) {
    let bytes = line.as_bytes();
    let mut in_single = false;
    let mut in_double = false;
    for i in 0..bytes.len() {
        match bytes[i] {
            b'\'' if !in_double => in_single = !in_single,
            b'"' if !in_single => in_double = !in_double,
            b'>' if !in_single && !in_double => {
                let append = bytes.get(i + 1) == Some(&b'>');
                let target_start = if append { i + 2 } else { i + 1 };
                let target = line[target_start..].trim().to_string();
                return (line[..i].trim().to_string(), Some((target, append)));
            }
            _ => {}
        }
    }
    (line.to_string(), None)
}

/// Split a command line into words, honouring single/double quotes.
fn split_words(line: &str) -> Vec<String> {
    let mut words = Vec::new();
    let mut cur = String::new();
    let mut in_single = false;
    let mut in_double = false;
    let mut has_content = false;
    for c in line.chars() {
        match c {
            '\'' if !in_double => {
                in_single = !in_single;
                has_content = true;
            }
            '"' if !in_single => {
                in_double = !in_double;
                has_content = true;
            }
            c if c.is_whitespace() && !in_single && !in_double => {
                if has_content {
                    words.push(std::mem::take(&mut cur));
                    has_content = false;
                }
            }
            c => {
                cur.push(c);
                has_content = true;
            }
        }
    }
    if has_content {
        words.push(cur);
    }
    words
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Metrics;
    use crate::singularity::registry::ImageRegistry;
    use crate::singularity::runtime::RuntimeKind;

    fn ctx() -> ShellCtx {
        let fs = SharedFs::new();
        let rt = Runtime::new(RuntimeKind::Singularity, ImageRegistry::with_defaults(), Metrics::new());
        ShellCtx::new(fs, rt, CancelToken::new())
    }

    fn lines(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn paper_fig3_script_body() {
        let mut c = ctx();
        let status = c.run_script(&lines(&[
            "export PATH=$PATH:/usr/local/bin",
            "singularity run lolcow_latest.sif",
        ]));
        assert_eq!(status, 0);
        assert!(c.stdout.contains("Moo"));
        assert_eq!(c.env["PATH"], "/usr/bin:/bin:/usr/local/bin");
    }

    #[test]
    fn echo_with_redirect() {
        let mut c = ctx();
        let status = c.run_script(&lines(&[
            "echo hello world > $HOME/out.txt",
            "echo again >> $HOME/out.txt",
        ]));
        assert_eq!(status, 0);
        assert_eq!(c.fs.read_string("$HOME/out.txt").unwrap(), "hello world\nagain\n");
        assert!(c.stdout.is_empty());
    }

    #[test]
    fn cat_reads_fs() {
        let mut c = ctx();
        c.fs.write("$HOME/data", b"content\n").unwrap();
        assert_eq!(c.run_script(&lines(&["cat $HOME/data"])), 0);
        assert_eq!(c.stdout, "content\n");
        assert_eq!(c.run_script(&lines(&["cat $HOME/nope"])), 1);
    }

    #[test]
    fn unknown_command_is_127_but_continues() {
        let mut c = ctx();
        let status = c.run_script(&lines(&["frobnicate --fast", "echo ok"]));
        assert_eq!(status, 0, "last command wins");
        assert!(c.stderr.contains("frobnicate: command not found"));
        assert_eq!(c.stdout, "ok\n");
        let status = c.run_script(&lines(&["echo ok", "frobnicate"]));
        assert_eq!(status, 127);
    }

    #[test]
    fn exit_stops_script() {
        let mut c = ctx();
        let status = c.run_script(&lines(&["exit 3", "echo never"]));
        assert_eq!(status, 3);
        assert!(!c.stdout.contains("never"));
    }

    #[test]
    fn quoting() {
        let mut c = ctx();
        c.run_script(&lines(&["echo 'single quoted  spaces' \"double $HOME\""]));
        assert_eq!(c.stdout, "single quoted  spaces double /home/user\n");
    }

    #[test]
    fn sleep_scaled_and_cancellable() {
        let mut c = ctx();
        c.time_scale = 0.001;
        let t0 = std::time::Instant::now();
        assert_eq!(c.run_script(&lines(&["sleep 10"])), 0); // 10s -> 10ms
        assert!(t0.elapsed() < Duration::from_secs(1));

        let mut c2 = ctx();
        c2.cancel.trigger();
        assert_eq!(c2.run_script(&lines(&["sleep 100", "echo no"])), 137);
    }

    #[test]
    fn comments_and_shebang_skipped() {
        let mut c = ctx();
        let status = c.run_script(&lines(&["#!/bin/sh", "# a comment", "", "echo hi"]));
        assert_eq!(status, 0);
        assert_eq!(c.stdout, "hi\n");
    }

    #[test]
    fn split_words_quotes() {
        assert_eq!(split_words("a 'b c' \"d e\""), vec!["a", "b c", "d e"]);
        assert_eq!(split_words("  "), Vec::<String>::new());
        assert_eq!(split_words("x ''"), vec!["x", ""]);
    }

    #[test]
    fn split_redirect_quoted_gt() {
        let (cmd, r) = split_redirect("echo 'a > b'");
        assert_eq!(cmd, "echo 'a > b'");
        assert!(r.is_none());
        let (cmd, r) = split_redirect("echo x >> $HOME/f");
        assert_eq!(cmd, "echo x");
        assert_eq!(r, Some(("$HOME/f".to_string(), true)));
    }
}
