//! Singularity substrate: SIF-style images, a registry, the container
//! runtime (with Docker-sim and native baselines for bench E5), the batch
//! shell interpreter, and the Singularity-CRI shim for the kubelet.

pub mod cri;
pub mod image;
pub mod registry;
pub mod runtime;
pub mod shell;

pub use cri::{ContainerId, ContainerSpec, ContainerStatus, Cri, SingularityCri};
pub use image::{parse_definition, Payload, SifImage};
pub use registry::ImageRegistry;
pub use runtime::{
    CancelToken, ComputeEngine, ComputeSummary, RunRequest, RunResult, Runtime, RuntimeKind,
};
