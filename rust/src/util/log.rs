//! Tiny leveled logger (no `tracing`/`log` crates in the offline registry).
//!
//! Components log as `LEVEL ts component: message`. When a trace context
//! is active on the logging thread (PR 7, [`crate::obs`]), the line gains
//! a `[trace=<id>]` suffix — grep a trace ID across stderr and the span
//! export and you see the same causal story twice.
//!
//! Filtering is per component since PR 7. `HPCORC_LOG` takes a
//! comma-separated spec: a bare level is the default, and
//! `component=level` pairs override it by **longest-prefix** match on the
//! component name — so `HPCORC_LOG=info,kube.store=debug` turns the whole
//! tree to info but the store (and anything under `kube.store.`) to
//! debug. Default is `warn` so tests and benches stay quiet. Logging goes
//! to stderr; the CLI's user-facing output goes to stdout and never
//! through here.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

impl Level {
    fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Warn
static INIT: std::sync::Once = std::sync::Once::new();
/// `component-prefix → level` overrides, longest prefix wins. Empty for
/// the common single-level spec, so the per-line cost stays one atomic
/// load plus one (uncontended) lock only when overrides exist.
static OVERRIDES: Mutex<Vec<(String, u8)>> = Mutex::new(Vec::new());
static HAS_OVERRIDES: AtomicU8 = AtomicU8::new(0);

/// Initialize from the HPCORC_LOG env var (idempotent). Accepts
/// `level[,component=level]...` — e.g. `info,kube.store=debug,redbox=error`.
pub fn init_from_env() {
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("HPCORC_LOG") {
            set_spec(&v);
        }
    });
}

/// Apply a filter spec (`level[,component=level]...`). Unknown levels and
/// malformed clauses are ignored rather than fatal — a typo in an env var
/// must not take the daemon down.
pub fn set_spec(spec: &str) {
    let mut overrides: Vec<(String, u8)> = Vec::new();
    for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
        match clause.split_once('=') {
            None => {
                if let Some(l) = Level::parse(clause) {
                    LEVEL.store(l as u8, Ordering::Relaxed);
                }
            }
            Some((comp, lvl)) => {
                if let Some(l) = Level::parse(lvl) {
                    overrides.push((comp.trim().to_string(), l as u8));
                }
            }
        }
    }
    // Longest prefix first, so the first match in `component_level` is
    // the most specific one.
    overrides.sort_by(|a, b| b.0.len().cmp(&a.0.len()).then(a.0.cmp(&b.0)));
    HAS_OVERRIDES.store(if overrides.is_empty() { 0 } else { 1 }, Ordering::Relaxed);
    *OVERRIDES.lock().unwrap_or_else(|p| p.into_inner()) = overrides;
}

/// Set the default level (overrides from a previous spec stay in place).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// The threshold for `component`: its longest matching prefix override,
/// or the global default.
fn component_level(component: &str) -> u8 {
    if HAS_OVERRIDES.load(Ordering::Relaxed) != 0 {
        let overrides = OVERRIDES.lock().unwrap_or_else(|p| p.into_inner());
        for (prefix, lvl) in overrides.iter() {
            if component.starts_with(prefix.as_str()) {
                return *lvl;
            }
        }
    }
    LEVEL.load(Ordering::Relaxed)
}

/// Would a line at `l` pass the *default* level? (Component overrides are
/// applied in [`write`]; this keeps the cheap pre-format check usable.)
pub fn enabled(l: Level) -> bool {
    l as u8 >= LEVEL.load(Ordering::Relaxed)
}

/// Like [`enabled`] but honouring per-component overrides.
pub fn component_enabled(l: Level, component: &str) -> bool {
    l as u8 >= component_level(component)
}

#[doc(hidden)]
pub fn write(level: Level, component: &str, msg: std::fmt::Arguments<'_>) {
    if !component_enabled(level, component) {
        return;
    }
    let now = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let tag = match level {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    // Stamp the active trace so stderr lines join the span export.
    match crate::obs::current() {
        Some(ctx) => eprintln!(
            "{tag} {}.{:03} {component}: {msg} [trace={:016x}]",
            now.as_secs(),
            now.subsec_millis(),
            ctx.trace_id
        ),
        None => eprintln!(
            "{tag} {}.{:03} {component}: {msg}",
            now.as_secs(),
            now.subsec_millis()
        ),
    }
}

#[macro_export]
macro_rules! debug {
    ($comp:expr, $($arg:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Debug, $comp, format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! info {
    ($comp:expr, $($arg:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Info, $comp, format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! warn {
    ($comp:expr, $($arg:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Warn, $comp, format_args!($($arg)*))
    };
}
#[macro_export]
macro_rules! error {
    ($comp:expr, $($arg:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Error, $comp, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // Shares process-global level/override state with the other tests in
    // this module — serialize them.
    static LOG_SERIAL: Mutex<()> = Mutex::new(());

    #[test]
    fn level_gating() {
        let _s = LOG_SERIAL.lock().unwrap_or_else(|p| p.into_inner());
        set_spec("warn");
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        set_level(Level::Warn);
    }

    #[test]
    fn component_overrides_longest_prefix() {
        let _s = LOG_SERIAL.lock().unwrap_or_else(|p| p.into_inner());
        set_spec("info,kube=warn,kube.store=debug");
        assert!(component_enabled(Level::Info, "redbox")); // default: info
        assert!(!component_enabled(Level::Info, "kube.sched")); // kube=warn
        assert!(component_enabled(Level::Warn, "kube.sched"));
        assert!(component_enabled(Level::Debug, "kube.store")); // most specific wins
        assert!(component_enabled(Level::Debug, "kube.store.commit"));
        // Malformed clauses are ignored, the rest of the spec applies.
        set_spec("bogus,kube=nope,error");
        assert!(!component_enabled(Level::Warn, "kube.sched"));
        assert!(component_enabled(Level::Error, "kube.sched"));
        set_spec("warn");
    }
}
