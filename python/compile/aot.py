"""AOT export: lower the L2 programs to HLO *text* + a manifest for Rust.

HLO text (NOT `.serialize()`): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that xla_extension 0.5.1 (behind the published `xla` crate)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Artifacts per model variant:
  cropyield_init_<v>.hlo.txt    (seed:i32) -> (params...)
  cropyield_train_<v>.hlo.txt   (step:i32, params...) -> (params..., loss)
  cropyield_infer_<v>.hlo.txt   (step:i32, params...) -> (yhat, mse)
plus manifest.json describing shapes/dtypes and artifact roles — the Rust
runtime (`rust/src/runtime/`) is driven entirely by the manifest.

Usage: python -m compile.aot --out ../artifacts   [--full] [--report]
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import attention as attn_kernel
from .kernels import matmul_gelu as mm_kernel

DEFAULT_VARIANTS = ["tiny", "small"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_json(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def export_variant(variant: str, out_dir: str) -> dict:
    cfg = model.CONFIGS[variant]
    pspecs = model.param_specs(cfg)
    n_params = sum(int(jnp.prod(jnp.array(s.shape))) for s in pspecs)
    seed_spec = jax.ShapeDtypeStruct((), jnp.int32)

    entries = {}

    # init: seed -> params
    init_fn = model.make_init_fn(cfg)
    lowered = jax.jit(init_fn).lower(seed_spec)
    path = f"cropyield_init_{variant}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    entries[f"cropyield_init_{variant}"] = {
        "file": path,
        "role": "init",
        "inputs": [spec_json(seed_spec)],
        "outputs": [spec_json(s) for s in pspecs],
    }

    # train_step: (step, params...) -> (params..., loss)
    train_fn = model.make_train_step_fn(cfg)
    lowered = jax.jit(train_fn).lower(seed_spec, *pspecs)
    path = f"cropyield_train_{variant}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    entries[f"cropyield_train_{variant}"] = {
        "file": path,
        "role": "train_step",
        "init": f"cropyield_init_{variant}",
        "inputs": [spec_json(seed_spec)] + [spec_json(s) for s in pspecs],
        "outputs": [spec_json(s) for s in pspecs]
        + [{"shape": [], "dtype": "float32"}],
        "metric": "loss",
        "metricOutputIndex": len(pspecs),
        "paramCount": len(pspecs),
        "flopsPerStep": model.flops_per_step(cfg),
    }

    # infer: (step, params...) -> (yhat, mse)
    infer_fn = model.make_infer_fn(cfg)
    lowered = jax.jit(infer_fn).lower(seed_spec, *pspecs)
    path = f"cropyield_infer_{variant}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    entries[f"cropyield_infer_{variant}"] = {
        "file": path,
        "role": "infer",
        "init": f"cropyield_init_{variant}",
        "inputs": [spec_json(seed_spec)] + [spec_json(s) for s in pspecs],
        "outputs": [
            {"shape": [cfg["batch"]], "dtype": "float32"},
            {"shape": [], "dtype": "float32"},
        ],
        "metric": "mse",
        "metricOutputIndex": 1,
        "paramCount": len(pspecs),
    }

    print(
        f"  {variant}: d={cfg['d_model']} L={cfg['n_layers']} "
        f"params={n_params:,} ({len(pspecs)} arrays)",
        file=sys.stderr,
    )
    return entries


def report(variants):
    """--report: structural L1 analysis (VMEM footprint, MXU estimate) —
    the basis of EXPERIMENTS.md's TPU-perf *estimates* (interpret mode
    gives no hardware timing)."""
    out = {}
    for v in variants:
        cfg = model.CONFIGS[v]
        d, ff = cfg["d_model"], cfg["d_ff"]
        tokens = cfg["batch"] * cfg["seq"]
        hd = d // cfg["n_heads"]
        bh = cfg["batch"] * cfg["n_heads"]
        out[v] = {
            "mlp_kernel": {
                "shape": [tokens, d, ff],
                "vmem_bytes": mm_kernel.vmem_bytes(tokens, ff, d),
                "mxu_utilization": mm_kernel.mxu_utilization_estimate(tokens, ff, d),
            },
            "attention_kernel": {
                "shape": [bh, cfg["seq"], hd],
                "vmem_bytes": attn_kernel.vmem_bytes(bh, cfg["seq"], hd),
            },
            "flops_per_train_step": model.flops_per_step(cfg),
        }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    ap.add_argument("--full", action="store_true", help="also export `base`")
    ap.add_argument("--report", action="store_true", help="print L1 analysis")
    args = ap.parse_args()

    variants = DEFAULT_VARIANTS + (["base"] if args.full else [])
    if args.report:
        print(json.dumps(report(variants), indent=2))
        return

    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    print(f"exporting {variants} -> {out_dir}", file=sys.stderr)
    manifest = {"formatVersion": 1, "artifacts": {}}
    for v in variants:
        manifest["artifacts"].update(export_variant(v, out_dir))
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json", file=sys.stderr)


if __name__ == "__main__":
    main()
