//! The event loop: arrivals, completions, scheduling cycles.

use crate::sched::{NodeState, PendingJob, Placement, RunningJob, SchedPolicy};
use crate::util::Hist;
use crate::workload::{Trace, TraceJob};
use std::collections::BTreeMap;

/// Models the operator path's extra per-job latency (experiment E1's
/// "hybrid" series): admission through the K8s API + dummy-pod scheduling +
/// red-box hop, measured by bench E2 on the live path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OperatorModel {
    /// Added between a job's arrival and its visibility to the WLM.
    pub submit_delay_s: f64,
    /// Status-poll granularity (completion observed late by up to this).
    pub poll_s: f64,
}

impl OperatorModel {
    pub const NONE: OperatorModel = OperatorModel { submit_delay_s: 0.0, poll_s: 0.0 };
}

/// Elastic-cluster mode (autoscale layer, PR 3): the node count follows
/// load instead of being fixed. Mirrors the live cluster autoscaler's
/// policy — grow when pending work fits no active node (after a
/// provisioning delay), shrink a node that sat fully idle past the
/// window, never below `min_nodes` — so E1-style experiments can compare
/// a static partition against an elastic one on identical traces.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ElasticParams {
    pub min_nodes: usize,
    pub max_nodes: usize,
    /// Seconds between the grow decision and the node accepting work.
    pub provision_delay_s: f64,
    /// How long a node must sit fully idle before it is released.
    pub scale_down_idle_s: f64,
}

#[derive(Debug, Clone)]
pub struct SimParams {
    /// Node count (static mode), or the initial floor when `elastic` is
    /// set (ignored in favour of `elastic.min_nodes` then).
    pub nodes: usize,
    pub cores_per_node: u32,
    pub mem_per_node: u64,
    /// Scheduling cycle period (both WLMs run periodic cycles).
    pub sched_period_s: f64,
    pub operator: OperatorModel,
    pub elastic: Option<ElasticParams>,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            nodes: 16,
            cores_per_node: 8,
            mem_per_node: 64 << 30,
            sched_period_s: 1.0,
            operator: OperatorModel::NONE,
            elastic: None,
        }
    }
}

/// Aggregate results of one simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub policy: String,
    pub jobs: usize,
    pub completed: usize,
    pub killed_walltime: usize,
    /// Last completion time (seconds).
    pub makespan_s: f64,
    pub mean_wait_s: f64,
    pub p95_wait_s: f64,
    pub max_wait_s: f64,
    /// Mean bounded slowdown (wait+run)/max(run, 10s).
    pub mean_slowdown: f64,
    /// Core-seconds used / core-seconds provisioned (node-seconds ×
    /// cores). For a static cluster this is the classic
    /// capacity × makespan denominator; elastic runs are judged against
    /// what was actually kept on.
    pub utilization: f64,
    /// Scheduling cycles executed (cost proxy).
    pub sched_cycles: u64,
    /// Integral of the active node count over the run (= nodes × makespan
    /// for a static cluster).
    pub node_seconds: f64,
    /// Elastic mode only: grow/shrink event counts.
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// Whether the run was elastic (drives the extra row columns).
    pub elastic: bool,
}

impl SimReport {
    /// Mean active node count over the run.
    pub fn mean_nodes(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.node_seconds / self.makespan_s
        } else {
            0.0
        }
    }

    pub fn row(&self) -> String {
        let mut row = format!(
            "{:<14} jobs={:<5} done={:<5} killed={:<4} makespan={:>9.1}s wait(mean/p95/max)={:>7.1}/{:>7.1}/{:>7.1}s slowdown={:>6.2} util={:>5.1}%",
            self.policy,
            self.jobs,
            self.completed,
            self.killed_walltime,
            self.makespan_s,
            self.mean_wait_s,
            self.p95_wait_s,
            self.max_wait_s,
            self.mean_slowdown,
            self.utilization * 100.0
        );
        if self.elastic {
            row.push_str(&format!(
                " nodes(mean)={:>5.1} scale(up/down)={}/{}",
                self.mean_nodes(),
                self.scale_ups,
                self.scale_downs
            ));
        }
        row
    }
}

struct SimJob {
    spec: TraceJob,
    visible_s: f64,
    start_s: Option<f64>,
    end_s: Option<f64>,
    killed: bool,
    placement: Vec<Placement>,
}

/// Run `trace` through `policy` on the simulated cluster.
pub fn simulate(trace: &Trace, params: &SimParams, policy: &dyn SchedPolicy) -> SimReport {
    let mut jobs: BTreeMap<u64, SimJob> = trace
        .jobs
        .iter()
        .map(|j| {
            (
                j.id,
                SimJob {
                    spec: j.clone(),
                    visible_s: j.arrival_s + params.operator.submit_delay_s,
                    start_s: None,
                    end_s: None,
                    killed: false,
                    placement: Vec::new(),
                },
            )
        })
        .collect();

    // Node slots: a static cluster activates all of them forever; an
    // elastic one starts at `min_nodes` and grows/shrinks within
    // `max_nodes` slots.
    let total_slots = params.elastic.map(|e| e.max_nodes.max(1)).unwrap_or(params.nodes);
    let initial_active =
        params.elastic.map(|e| e.min_nodes.min(e.max_nodes)).unwrap_or(params.nodes);
    let mut free: Vec<NodeState> = (0..total_slots)
        .map(|i| NodeState::whole(i, params.cores_per_node, params.mem_per_node))
        .collect();
    let mut active: Vec<bool> = (0..total_slots).map(|i| i < initial_active).collect();
    // Fully idle (all cores free) since this time, while active.
    let mut idle_since: Vec<Option<f64>> = vec![Some(0.0); total_slots];
    // In-flight provisioning: (ready time, node slot).
    let mut provisioning: Vec<(f64, usize)> = Vec::new();
    let mut node_seconds = 0.0f64;
    let mut scale_ups = 0u64;
    let mut scale_downs = 0u64;
    let mut prev_now = 0.0f64;

    // Event times: job visibility, running-job ends, provisioned nodes
    // coming online, and idle windows expiring drive the clock; a
    // scheduling cycle runs at each event time (event-driven scheduling
    // with a minimum period to model cycle cost).
    let mut now = 0.0f64;
    let mut sched_cycles = 0u64;
    let mut pending_ids: Vec<u64> = Vec::new();
    let mut arrivals: Vec<u64> = {
        let mut v: Vec<u64> = jobs.keys().copied().collect();
        v.sort_by(|a, b| {
            jobs[a].visible_s.partial_cmp(&jobs[b].visible_s).unwrap().then(a.cmp(b))
        });
        v
    };
    arrivals.reverse(); // pop() from the back = earliest first
    // running: (end_s, id)
    let mut running: Vec<(f64, u64)> = Vec::new();

    loop {
        // Next event: earliest of next arrival / next completion / next
        // provisioned node coming online / next idle window expiring.
        let active_count = active.iter().filter(|a| **a).count();
        let mut next = f64::INFINITY;
        if let Some(id) = arrivals.last() {
            next = next.min(jobs[id].visible_s);
        }
        next = running.iter().map(|(e, _)| *e).fold(next, f64::min);
        next = provisioning.iter().map(|(t, _)| *t).fold(next, f64::min);
        if let Some(e) = params.elastic {
            if active_count > e.min_nodes {
                for i in 0..total_slots {
                    if let (true, Some(t)) = (active[i], idle_since[i]) {
                        next = next.min(t + e.scale_down_idle_s);
                    }
                }
            }
        }
        if !next.is_finite() {
            // Nothing will ever happen again: remaining pending jobs can
            // never run — drop them as killed.
            for id in pending_ids.drain(..) {
                jobs.get_mut(&id).unwrap().killed = true;
            }
            break;
        }
        now = next.max(now);
        node_seconds += active_count as f64 * (now - prev_now);
        prev_now = now;

        // Provisioned nodes come online.
        let mut i = 0;
        while i < provisioning.len() {
            if provisioning[i].0 <= now + 1e-9 {
                let (_, slot) = provisioning.swap_remove(i);
                active[slot] = true;
                idle_since[slot] = Some(now);
            } else {
                i += 1;
            }
        }

        // Process arrivals at `now`.
        while let Some(id) = arrivals.last().copied() {
            if jobs[&id].visible_s <= now + 1e-9 {
                arrivals.pop();
                pending_ids.push(id);
            } else {
                break;
            }
        }
        // Process completions at `now`.
        let mut i = 0;
        while i < running.len() {
            if running[i].0 <= now + 1e-9 {
                let (_, id) = running.swap_remove(i);
                let job = jobs.get_mut(&id).unwrap();
                job.end_s = Some(now.max(job.start_s.unwrap()));
                for p in &job.placement {
                    let n = &mut free[p.node];
                    n.free_cores += p.cores;
                    n.free_mem += p.mem;
                }
            } else {
                i += 1;
            }
        }

        // Scheduling cycle.
        if !pending_ids.is_empty() {
            let pending: Vec<PendingJob> = pending_ids
                .iter()
                .map(|id| {
                    let j = &jobs[id].spec;
                    PendingJob {
                        id: j.id,
                        nodes: j.nodes,
                        ppn: j.ppn,
                        mem: 0,
                        walltime: std::time::Duration::from_secs_f64(j.walltime_s),
                        priority: j.priority,
                        submit_s: jobs[id].visible_s,
                        queue: j.queue.clone(),
                    }
                })
                .collect();
            let running_view: Vec<RunningJob> = running
                .iter()
                .map(|(end, id)| RunningJob {
                    id: *id,
                    placement: jobs[id].placement.clone(),
                    expected_end_s: jobs[id].start_s.unwrap()
                        + jobs[id].spec.walltime_s.max(*end - jobs[id].start_s.unwrap()),
                })
                .collect();
            // Only active nodes are offered to the policy; slot ids are
            // stable, so assignments map straight back onto `free`.
            let avail: Vec<NodeState> =
                free.iter().filter(|n| active[n.id]).cloned().collect();
            let assignments = policy.schedule(now, &pending, &avail, &running_view);
            sched_cycles += 1;
            for a in assignments {
                let job = jobs.get_mut(&a.job).unwrap();
                job.start_s = Some(now);
                job.placement = a.placement.clone();
                for p in &a.placement {
                    let n = &mut free[p.node];
                    n.free_cores -= p.cores;
                    n.free_mem -= p.mem;
                }
                // Walltime enforcement: actual end is min(runtime, walltime).
                let dur = if job.spec.runtime_s > job.spec.walltime_s {
                    job.killed = true;
                    job.spec.walltime_s
                } else {
                    job.spec.runtime_s
                };
                // Operator completions observed late by up to poll_s.
                let end = now + dur + params.operator.poll_s;
                running.push((end, a.job));
                pending_ids.retain(|id| *id != a.job);
            }
        }

        // Elastic control arm: track idleness, grow for unplaceable
        // pending work, shrink nodes idle past the window.
        if let Some(e) = params.elastic {
            for n in &free {
                if !active[n.id] {
                    idle_since[n.id] = None;
                } else if n.free_cores < n.total_cores {
                    idle_since[n.id] = None;
                } else if idle_since[n.id].is_none() {
                    idle_since[n.id] = Some(now);
                }
            }
            // Grow: chunks demanded by shape-feasible pending jobs, minus
            // what idle active nodes and in-flight provisioning already
            // cover.
            let pending_chunks: usize = pending_ids
                .iter()
                .map(|id| &jobs[id].spec)
                .filter(|j| {
                    j.ppn <= params.cores_per_node && (j.nodes as usize) <= e.max_nodes
                })
                .map(|j| j.nodes as usize)
                .sum();
            let idle_active = free
                .iter()
                .filter(|n| active[n.id] && n.free_cores == n.total_cores)
                .count();
            let active_count = active.iter().filter(|a| **a).count();
            let deficit = pending_chunks
                .saturating_sub(idle_active)
                .saturating_sub(provisioning.len());
            let headroom =
                e.max_nodes.saturating_sub(active_count + provisioning.len());
            let grow = deficit.min(headroom);
            if grow > 0 {
                let slots: Vec<usize> = (0..total_slots)
                    .filter(|i| !active[*i] && !provisioning.iter().any(|(_, s)| s == i))
                    .take(grow)
                    .collect();
                for slot in slots {
                    provisioning.push((now + e.provision_delay_s, slot));
                    scale_ups += 1;
                }
            }
            // Shrink: fully idle past the window, never below the floor.
            let mut active_count = active.iter().filter(|a| **a).count();
            for i in 0..total_slots {
                if active_count <= e.min_nodes {
                    break;
                }
                if let (true, Some(t)) = (active[i], idle_since[i]) {
                    if now - t >= e.scale_down_idle_s - 1e-9 {
                        active[i] = false;
                        idle_since[i] = None;
                        active_count -= 1;
                        scale_downs += 1;
                    }
                }
            }
        }
        if arrivals.is_empty() && running.is_empty() && pending_ids.is_empty() {
            break;
        }
        // Safety: if nothing can ever be scheduled (pending jobs larger
        // than the machine, even fully scaled out), drop them.
        if !pending_ids.is_empty()
            && running.is_empty()
            && arrivals.is_empty()
            && provisioning.is_empty()
        {
            let can_run: Vec<u64> = pending_ids
                .iter()
                .copied()
                .filter(|id| {
                    let j = &jobs[id].spec;
                    (j.nodes as usize) <= total_slots && j.ppn <= params.cores_per_node
                })
                .collect();
            if can_run.is_empty() {
                for id in pending_ids.drain(..) {
                    jobs.get_mut(&id).unwrap().killed = true;
                }
                break;
            }
        }
    }

    // Aggregate.
    let mut wait_hist = Hist::new();
    let mut slowdowns = Vec::new();
    let mut core_seconds = 0.0;
    let mut makespan: f64 = 0.0;
    let mut completed = 0;
    let mut killed = 0;
    for job in jobs.values() {
        if job.spec.runtime_s > job.spec.walltime_s && job.start_s.is_some() {
            killed += 1;
        }
        let (Some(start), Some(end)) = (job.start_s, job.end_s) else {
            if job.killed {
                killed += 1;
            }
            continue;
        };
        completed += 1;
        let wait = (start - job.spec.arrival_s).max(0.0);
        wait_hist.record((wait * 1000.0) as u64); // ms resolution
        let run = end - start;
        slowdowns.push((wait + run) / run.max(10.0));
        core_seconds += (job.spec.nodes * job.spec.ppn) as f64 * run;
        makespan = makespan.max(end);
    }
    // Provisioned core-seconds: what was actually kept powered. A static
    // cluster integrates to nodes × makespan — the classic denominator.
    let provisioned_core_s = node_seconds * params.cores_per_node as f64;
    SimReport {
        policy: policy.name().to_string(),
        jobs: trace.len(),
        completed,
        killed_walltime: killed,
        makespan_s: makespan,
        mean_wait_s: wait_hist.mean() / 1000.0,
        p95_wait_s: wait_hist.p95() as f64 / 1000.0,
        max_wait_s: wait_hist.max() as f64 / 1000.0,
        mean_slowdown: if slowdowns.is_empty() {
            0.0
        } else {
            slowdowns.iter().sum::<f64>() / slowdowns.len() as f64
        },
        utilization: if provisioned_core_s > 0.0 {
            core_seconds / provisioned_core_s
        } else {
            0.0
        },
        sched_cycles,
        node_seconds,
        scale_ups,
        scale_downs,
        elastic: params.elastic.is_some(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{EasyBackfill, FifoPolicy, KubeGreedyPolicy};
    use crate::workload::{TraceGen, TraceJob};

    fn params(nodes: usize, cores: u32) -> SimParams {
        SimParams { nodes, cores_per_node: cores, ..SimParams::default() }
    }

    #[test]
    fn single_job_timing() {
        let trace = Trace::new("t", vec![TraceJob::sleep(1, 5.0, 1, 1, 100.0, 60.0)]);
        let r = simulate(&trace, &params(1, 1), &FifoPolicy);
        assert_eq!(r.completed, 1);
        assert!((r.makespan_s - 65.0).abs() < 1e-6, "{}", r.makespan_s);
        assert_eq!(r.mean_wait_s, 0.0);
        assert_eq!(r.killed_walltime, 0);
    }

    #[test]
    fn queueing_when_saturated() {
        // two 60s jobs on one core: second waits 60s.
        let trace = Trace::new(
            "t",
            vec![
                TraceJob::sleep(1, 0.0, 1, 1, 100.0, 60.0),
                TraceJob::sleep(2, 0.0, 1, 1, 100.0, 60.0),
            ],
        );
        let r = simulate(&trace, &params(1, 1), &FifoPolicy);
        assert_eq!(r.completed, 2);
        assert!((r.makespan_s - 120.0).abs() < 1e-6);
        assert!((r.max_wait_s - 60.0).abs() < 0.1, "{}", r.max_wait_s);
    }

    #[test]
    fn walltime_kill_counted() {
        let trace = Trace::new("t", vec![TraceJob::sleep(1, 0.0, 1, 1, 30.0, 100.0)]);
        let r = simulate(&trace, &params(1, 1), &FifoPolicy);
        assert_eq!(r.killed_walltime, 1);
        assert!((r.makespan_s - 30.0).abs() < 1e-6, "killed at walltime");
    }

    #[test]
    fn deterministic() {
        let trace = TraceGen::new(1).poisson_batch(200, 32, 0.8, 100.0);
        let a = simulate(&trace, &params(4, 8), &EasyBackfill);
        let b = simulate(&trace, &params(4, 8), &EasyBackfill);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.mean_wait_s, b.mean_wait_s);
    }

    /// The E1 headline shape: on a backfill-friendly trace, EASY beats
    /// strict FIFO on makespan and utilization.
    #[test]
    fn backfill_beats_fifo_on_showcase() {
        let trace = TraceGen::new(2).backfill_showcase(4, 8);
        let fifo = simulate(&trace, &params(8, 1), &FifoPolicy);
        let easy = simulate(&trace, &params(8, 1), &EasyBackfill);
        assert_eq!(fifo.completed, trace.len());
        assert_eq!(easy.completed, trace.len());
        assert!(
            easy.makespan_s < fifo.makespan_s * 0.95,
            "easy {} vs fifo {}",
            easy.makespan_s,
            fifo.makespan_s
        );
        assert!(easy.utilization > fifo.utilization);
    }

    /// K8s-greedy starves wide jobs: narrow jobs flow past, wide job waits
    /// far longer than under EASY (which reserves).
    #[test]
    fn kube_greedy_starves_wide_jobs() {
        let mut jobs = vec![TraceJob::sleep(1, 1.0, 4, 1, 700.0, 600.0)]; // wide
        // Sustainable narrow stream (load ~0.83): staggered arrivals keep
        // all-4-nodes-free moments rare, so greedy never clears room for
        // the wide job while EASY's reservation drains the nodes for it.
        for i in 0..60 {
            jobs.push(TraceJob::sleep(2 + i, 30.0 * i as f64, 1, 1, 150.0, 100.0));
        }
        let trace = Trace::new("starve", jobs);
        let easy = simulate(&trace, &params(4, 1), &EasyBackfill);
        let greedy = simulate(&trace, &params(4, 1), &KubeGreedyPolicy);
        let wide_wait = |r: &SimReport| r.max_wait_s; // wide job dominates max
        assert!(
            wide_wait(&greedy) > wide_wait(&easy) * 1.5,
            "greedy max wait {} vs easy {}",
            greedy.max_wait_s,
            easy.max_wait_s
        );
    }

    #[test]
    fn operator_overhead_shifts_waits() {
        let trace = TraceGen::new(3).poisson_batch(100, 32, 0.5, 60.0);
        let base = simulate(&trace, &params(4, 8), &EasyBackfill);
        let mut p = params(4, 8);
        p.operator = OperatorModel { submit_delay_s: 2.0, poll_s: 1.0 };
        let with_op = simulate(&trace, &p, &EasyBackfill);
        assert!(with_op.mean_wait_s >= base.mean_wait_s + 1.0,
            "operator delay visible: {} vs {}", with_op.mean_wait_s, base.mean_wait_s);
        assert!(with_op.makespan_s >= base.makespan_s);
    }

    #[test]
    fn impossible_job_dropped_not_hung() {
        let trace = Trace::new("t", vec![TraceJob::sleep(1, 0.0, 99, 1, 10.0, 10.0)]);
        let r = simulate(&trace, &params(2, 1), &EasyBackfill);
        assert_eq!(r.completed, 0);
        assert_eq!(r.killed_walltime, 1);
    }

    #[test]
    fn elastic_grows_for_burst_and_saves_node_seconds() {
        // 8 one-node jobs at t=0, runtime 100s: a static 8-node cluster
        // burns 8 nodes for the whole run; the elastic one starts at 1,
        // grows to 8 after the provisioning delay, and finishes almost as
        // fast on far fewer node-seconds.
        let jobs: Vec<TraceJob> =
            (0..8).map(|i| TraceJob::sleep(i + 1, 0.0, 1, 1, 200.0, 100.0)).collect();
        let trace = Trace::new("burst", jobs);
        let static_r = simulate(&trace, &params(8, 1), &FifoPolicy);
        let mut p = params(8, 1);
        p.elastic = Some(ElasticParams {
            min_nodes: 1,
            max_nodes: 8,
            provision_delay_s: 10.0,
            scale_down_idle_s: 1e9,
        });
        let elastic_r = simulate(&trace, &p, &FifoPolicy);
        assert_eq!(elastic_r.completed, 8, "elastic run completes everything");
        assert!(elastic_r.elastic && !static_r.elastic);
        assert_eq!(elastic_r.scale_ups, 7, "grew from 1 to 8");
        assert!(
            (elastic_r.makespan_s - 110.0).abs() < 1e-6,
            "one provisioning delay added: {}",
            elastic_r.makespan_s
        );
        assert!((static_r.makespan_s - 100.0).abs() < 1e-6);
    }

    #[test]
    fn elastic_shrinks_after_idle_window() {
        // A burst at t=0, then one straggler at t=300: the pool must
        // shrink in between and still serve the straggler.
        let mut jobs: Vec<TraceJob> =
            (0..4).map(|i| TraceJob::sleep(i + 1, 0.0, 1, 1, 100.0, 50.0)).collect();
        jobs.push(TraceJob::sleep(9, 300.0, 1, 1, 100.0, 50.0));
        let trace = Trace::new("spike", jobs);
        let static_r = simulate(&trace, &params(4, 1), &FifoPolicy);
        let mut p = params(4, 1);
        p.elastic = Some(ElasticParams {
            min_nodes: 1,
            max_nodes: 4,
            provision_delay_s: 5.0,
            scale_down_idle_s: 30.0,
        });
        let r = simulate(&trace, &p, &FifoPolicy);
        assert_eq!(r.completed, 5);
        assert!(r.scale_ups >= 3, "burst grew the pool: {}", r.scale_ups);
        assert!(r.scale_downs >= 3, "idle window shrank it back: {}", r.scale_downs);
        assert!(r.mean_nodes() < 3.0, "mean active nodes {}", r.mean_nodes());
        // The whole point: the idle trough costs a static partition
        // node-seconds the elastic one releases.
        assert!(
            r.node_seconds < static_r.node_seconds * 0.6,
            "elastic {} vs static {} node-seconds",
            r.node_seconds,
            static_r.node_seconds
        );
        assert!(r.utilization > static_r.utilization);
    }

    #[test]
    fn elastic_deterministic_and_impossible_job_still_dropped() {
        let trace = TraceGen::new(9).poisson_batch(150, 16, 0.8, 80.0);
        let mut p = params(4, 4);
        p.elastic = Some(ElasticParams {
            min_nodes: 1,
            max_nodes: 6,
            provision_delay_s: 3.0,
            scale_down_idle_s: 60.0,
        });
        let a = simulate(&trace, &p, &EasyBackfill);
        let b = simulate(&trace, &p, &EasyBackfill);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.scale_ups, b.scale_ups);
        assert_eq!(a.node_seconds, b.node_seconds);

        // A job wider than max_nodes can never run, elastic or not.
        let trace = Trace::new("t", vec![TraceJob::sleep(1, 0.0, 99, 1, 10.0, 10.0)]);
        let r = simulate(&trace, &p, &EasyBackfill);
        assert_eq!(r.completed, 0);
        assert_eq!(r.killed_walltime, 1);
    }

    #[test]
    fn utilization_bounded() {
        let trace = TraceGen::new(4).poisson_batch(300, 64, 0.9, 80.0);
        for policy in [&FifoPolicy as &dyn SchedPolicy, &EasyBackfill, &KubeGreedyPolicy] {
            let r = simulate(&trace, &params(8, 8), policy);
            assert!(r.utilization <= 1.0 + 1e-9, "{} util {}", r.policy, r.utilization);
            assert!(r.completed + r.killed_walltime >= trace.len() - 1);
        }
    }
}
