//! Remote telemetry services: `obs.Metrics`, `obs.Spans`, and
//! `obs.Audit` over the red-box socket.
//!
//! Registered next to `kube.Api` by the testbed (and anything else that
//! runs a [`RedboxServer`]), these are what `hpcorc metrics --socket`,
//! `hpcorc trace <kind>/<name>`, and `hpcorc audit` scrape — the
//! daemon's registry, span ring, and audit trail become remotely
//! visible without a second transport.
//!
//! Methods:
//! - `obs.Metrics/Snapshot` → structured JSON ([`super::prom::render_json`])
//! - `obs.Metrics/Prom` → `{"text": <Prometheus exposition>}`
//! - `obs.Spans/Export` → `{"events": [<Chrome trace events>]}` (whole ring)
//! - `obs.Spans/ByTrace` `{trace: "<16-hex id>"}` → same shape, one trace
//! - `obs.Audit/Query` `{since?, kind?}` → `{"records": [...]}`
//!   ([`super::audit::audit_service`])

use super::{audit, prom, trace};
use crate::cluster::Metrics;
use crate::encoding::Value;
use crate::redbox::server::{FnService, RedboxServer, Service};
use crate::util::{Error, Result};
use std::sync::Arc;

/// The `obs.Metrics` service over a registry handle.
pub fn metrics_service(metrics: Metrics) -> Arc<dyn Service> {
    Arc::new(FnService(move |method: &str, _body: &Value| match method {
        "Snapshot" => Ok(prom::render_json(&metrics)),
        "Prom" => Ok(Value::map().with("text", prom::render_prom(&metrics))),
        other => Err(Error::rpc(format!("obs.Metrics has no method `{other}`"))),
    }))
}

/// The `obs.Spans` service over the process-global span ring.
pub fn spans_service() -> Arc<dyn Service> {
    Arc::new(FnService(move |method: &str, body: &Value| match method {
        "Export" => Ok(Value::map().with("events", trace::chrome_events(&trace::spans_snapshot()))),
        "ByTrace" => {
            let id = body
                .opt_str("trace")
                .and_then(|s| u64::from_str_radix(s, 16).ok())
                .ok_or_else(|| Error::rpc("ByTrace needs `trace` (16-hex id)"))?;
            Ok(Value::map().with("events", trace::chrome_events(&trace::by_trace(id))))
        }
        other => Err(Error::rpc(format!("obs.Spans has no method `{other}`"))),
    }))
}

/// Register the telemetry services on a running server: metrics + spans,
/// plus `obs.Audit` over the given audit trail (typically the
/// ApiServer's — `api.audit_log().clone()`).
pub fn register(server: &RedboxServer, metrics: Metrics, audit_log: audit::AuditLog) {
    server.register("obs.Metrics", metrics_service(metrics));
    server.register("obs.Spans", spans_service());
    server.register("obs.Audit", audit::audit_service(audit_log));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::redbox::client::RedboxClient;
    use crate::rt::Shutdown;

    #[test]
    fn remote_scrape_roundtrip() {
        let _serial = trace::test_serial();
        trace::set_enabled(true);
        let path = std::env::temp_dir()
            .join(format!("hpcorc-obs-svc-{}.sock", std::process::id()));
        let metrics = Metrics::new();
        metrics.inc("obs.test.counter");
        metrics.observe("obs.test.lat_ns", 1234);
        let mut srv = RedboxServer::start(&path, Shutdown::new(), Metrics::new()).unwrap();
        let audit_log = audit::AuditLog::new();
        audit_log.record("create", "Pod", "p1", Some("ff".into()), "ok".into(), 7);
        register(&srv, metrics, audit_log);
        {
            let _g = trace::span("obs-test", "remote-scrape");
        }
        let client = RedboxClient::connect(&path).unwrap();

        let snap = client.call("obs.Metrics/Snapshot", Value::Null).unwrap();
        assert_eq!(snap.get("counters").unwrap().opt_int("obs.test.counter"), Some(1));

        let text = client.call("obs.Metrics/Prom", Value::Null).unwrap();
        let text = text.opt_str("text").unwrap();
        assert!(text.contains("obs_test_counter 1"), "{text}");
        assert!(text.contains("# TYPE obs_test_lat_ns histogram"), "{text}");

        let export = client.call("obs.Spans/Export", Value::Null).unwrap();
        let events = export.get("events").unwrap().as_seq().unwrap();
        let ev = events
            .iter()
            .find(|e| e.opt_str("name") == Some("remote-scrape"))
            .expect("recorded span is exported");
        let trace_hex = ev.get("args").unwrap().opt_str("trace_id").unwrap().to_string();

        let one = client
            .call("obs.Spans/ByTrace", Value::map().with("trace", trace_hex))
            .unwrap();
        let events = one.get("events").unwrap().as_seq().unwrap();
        assert!(events.iter().all(|e| {
            e.opt_str("name").is_some() && e.get("args").is_some()
        }));
        assert!(events.iter().any(|e| e.opt_str("name") == Some("remote-scrape")));

        let audit = client
            .call("obs.Audit/Query", Value::map().with("kind", "Pod"))
            .unwrap();
        let records = audit.get("records").unwrap().as_seq().unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].opt_str("verb"), Some("create"));
        assert_eq!(records[0].opt_str("trace"), Some("ff"));

        assert!(client.call("obs.Metrics/Nope", Value::Null).is_err());
        srv.stop();
    }
}
