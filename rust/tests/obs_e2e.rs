//! Observability end-to-end (PR 7 acceptance): one pod driven through
//! create → kueue-admit → schedule → bind over the red-box testbed must
//! yield ONE connected causal trace — rooted at the client's span,
//! joined by the API server, the admission controller, and the
//! scheduler — exportable as valid Chrome trace-event JSON, with the
//! create→bound SLO histogram scrapeable remotely in Prometheus text.

use hpcorc::cluster::Resources;
use hpcorc::encoding::{json, Value};
use hpcorc::hybrid::{Testbed, TestbedConfig};
use hpcorc::kube::{ApiClient, PodView, RemoteApi, KIND_POD};
use hpcorc::kueue::{ClusterQueueView, LocalQueueView, QueueResources};
use hpcorc::obs;
use hpcorc::redbox::RedboxClient;
use std::time::{Duration, Instant};

#[test]
fn pod_lifecycle_yields_one_connected_trace_and_remote_slo_histogram() {
    let tb = Testbed::start(TestbedConfig::default()).expect("testbed");
    let remote = RemoteApi::connect(tb.socket()).expect("remote client");

    // Queue topology first, so the admission controller has somewhere to
    // admit the pod into.
    remote
        .create(ClusterQueueView::build("e2e-cq", QueueResources::nodes(4)))
        .expect("cluster queue");
    remote.create(LocalQueueView::build("e2e-team", "e2e-cq")).expect("local queue");

    // The traced create: a client-side root span, exactly like the CLI's
    // `kubectl apply`. The trace id must survive the wire, the store, and
    // every control loop downstream.
    let root = {
        let guard = obs::span("e2e-test", "create traced pod");
        let root = guard.context().expect("tracing on by default");
        let mut p = PodView::build("e2e-pod", "img.sif", Resources::new(100, 1 << 20, 0), &[]);
        hpcorc::kueue::queue_workload(&mut p, "e2e-team");
        remote.create(p).expect("create pod");
        root
    };

    // Wait for the full admit → schedule → bind chain.
    let deadline = Instant::now() + Duration::from_secs(30);
    let bound = loop {
        let obj = remote.get(KIND_POD, "e2e-pod").expect("get pod");
        if obj.spec.opt_str("nodeName").is_some() {
            break obj;
        }
        assert!(Instant::now() < deadline, "pod never bound");
        std::thread::sleep(Duration::from_millis(5));
    };

    // -- the annotation carries the caller's trace -----------------------
    let wire = bound
        .meta
        .annotation(obs::TRACE_ANNOTATION)
        .expect("bound pod keeps hpcorc.io/trace");
    let ctx = obs::TraceContext::parse_wire(wire).expect("well-formed trace annotation");
    assert_eq!(ctx.trace_id, root.trace_id, "object joined a different trace");
    let trace_hex = format!("{:016x}", ctx.trace_id);

    // -- one connected tree, visible through the remote span service -----
    // Bind/admit spans land in the ring moments after the status write
    // becomes readable; poll briefly instead of racing them.
    let rpc = RedboxClient::connect(tb.socket()).expect("rpc client");
    let deadline = Instant::now() + Duration::from_secs(10);
    let events: Vec<Value> = loop {
        let out = rpc
            .call("obs.Spans/ByTrace", Value::map().with("trace", trace_hex.clone()))
            .expect("ByTrace");
        let events = out.get("events").and_then(Value::as_seq).unwrap_or(&[]).to_vec();
        let cats: Vec<&str> =
            events.iter().filter_map(|e| e.opt_str("cat")).collect();
        if ["apiserver", "kueue", "kube-sched"].iter().all(|c| cats.contains(c)) {
            break events;
        }
        assert!(
            Instant::now() < deadline,
            "trace never connected across components; saw {cats:?}"
        );
        std::thread::sleep(Duration::from_millis(10));
    };
    for e in &events {
        assert_eq!(
            e.get("args").and_then(|a| a.opt_str("trace_id")),
            Some(trace_hex.as_str()),
            "every exported span belongs to the one trace"
        );
    }
    // The remote create dispatched through the red-box server under the
    // same trace (wire-context adoption).
    assert!(
        events.iter().any(|e| e.opt_str("cat") == Some("redbox-server")),
        "server dispatch spans join the caller's trace"
    );

    // -- valid Chrome trace-event JSON (Perfetto-loadable) ---------------
    let spans = obs::by_trace(ctx.trace_id);
    assert!(spans.len() >= 4, "expected a multi-component tree, got {}", spans.len());
    let chrome = obs::chrome_json(&spans);
    let parsed = json::parse(&chrome).expect("chrome export is valid JSON");
    let arr = parsed.as_seq().expect("chrome export is a JSON array");
    assert_eq!(arr.len(), spans.len());
    for ev in arr {
        assert_eq!(ev.opt_str("ph"), Some("X"), "complete-event format");
        assert!(ev.opt_int("ts").is_some() && ev.opt_int("dur").is_some());
    }

    // -- the SLO histogram is scrapeable remotely in Prometheus text -----
    let prom = rpc.call("obs.Metrics/Prom", Value::Null).expect("Prom scrape");
    let text = prom.opt_str("text").expect("text body");
    assert!(
        text.contains("# TYPE slo_pod_create_to_bound_ns histogram"),
        "create->bound SLO histogram must be exposed"
    );
    assert!(text.contains("slo_pod_create_to_bound_ns_count 1"), "exactly the one e2e pod");
    assert!(text.contains("slo_pod_create_to_bound_ns_bucket{le=\"+Inf\"} 1"));
    // The commit path instrumentation fired too.
    assert!(text.contains("# TYPE kube_store_commit_ns histogram"));
    assert!(text.contains("# TYPE redbox_handle_ns histogram"));

    tb.stop();
}
