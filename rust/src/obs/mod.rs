//! # Observability layer (PR 7): causal tracing + remote telemetry
//!
//! Everything the control plane emits about itself lives here: a span
//! recorder with cross-process trace propagation ([`trace`]), Prometheus
//! text / JSON rendering of the [`crate::cluster::Metrics`] registry
//! ([`prom`]), and the red-box services that expose both remotely
//! ([`service`]).
//!
//! ## How a trace flows
//!
//! 1. A root span opens wherever work originates — e.g. the CLI's
//!    `kubectl apply`, or a test calling [`span`].
//! 2. The red-box client stamps [`current`] onto every outgoing
//!    [`crate::redbox::proto::Request`] as a `trace` field
//!    (`<trace_id>-<span_id>` hex). Old peers that don't know the field
//!    ignore it; requests without it simply start fresh server-side.
//! 3. The red-box server adopts the wire context around dispatch, so
//!    ApiServer handler spans parent on the remote caller.
//! 4. `ApiServer::create`/`apply` stamp the active context onto the
//!    object as the `hpcorc.io/trace` annotation (plus
//!    `hpcorc.io/created-wall-ns`, the server wall clock). Annotations
//!    ride inside the object through store → WAL → watch → informer, so
//!    every later consumer can rejoin the originating trace.
//! 5. Kueue admission, the scheduler's bind, and the operator's WLM
//!    submission each open spans parented on that annotation — one
//!    connected causal tree from `create` to `run`, reconstructable with
//!    `hpcorc trace <kind>/<name>` or exported via
//!    [`export_chrome_json`] straight into Perfetto.
//!
//! ## Metric-name catalog
//!
//! | Metric | Type | Meaning |
//! |---|---|---|
//! | `redbox.requests` | counter | request frames handled by the server |
//! | `redbox.handle_ns` | histogram | server-side dispatch latency (all methods) |
//! | `redbox.rpc.<Service.Method>_ns` | histogram | per-RPC-method dispatch latency |
//! | `redbox.streams` / `redbox.stream_items` | counter | server streams opened / items pushed |
//! | `kube.api.<verb>` | counter | ApiServer verb calls (create/get/update/...) |
//! | `kube.store.commit_ns` | histogram | whole store commit (WAL + fan-out + publish) |
//! | `kube.store.wal_append_ns` | histogram | WAL append inside the commit |
//! | `kube.store.fanout_ns` | histogram | watcher fan-out inside the commit |
//! | `kube.informer.deliver_ns` | histogram | informer event apply+forward latency |
//! | `kube.informer.{lists,resyncs,delta_relists,events}` | counter | reflector activity |
//! | `kueue.cycles` | counter | admission cycles run |
//! | `kueue.cycle_ns` | histogram | admission cycle duration |
//! | `kube.sched.cycle_ns` | histogram | scheduler cycle duration |
//! | `kube.sched.bound` | counter | pods bound |
//! | `slo.pod_create_to_bound_ns` | histogram | end-to-end pod create→bound latency |
//! | `operator.submit_ns` | histogram | operator → WLM submission latency |
//!
//! Scrape any of these remotely: `hpcorc metrics --socket <sock> --prom`
//! (Prometheus text) or `--json` (structured snapshot); span trees via
//! `hpcorc trace <kind>/<name> --socket <sock>`.
//!
//! ## Overhead
//!
//! `benches/obs.rs` measures span record cost (one mutex push), the
//! disabled path (one atomic load — effectively free), and snapshot
//! rendering at 10k metrics. Disable process-wide with [`set_enabled`].

pub mod prom;
pub mod service;
pub mod trace;

pub use prom::{render_json, render_prom, sanitize};
pub use service::{metrics_service, register, spans_service};
pub use trace::{
    by_trace, chrome_events, chrome_json, clear, current, enabled, export_chrome_json,
    set_enabled, span, span_with_parent, spans_snapshot, Span, SpanGuard, TraceContext,
    CREATED_WALL_ANNOTATION, TRACE_ANNOTATION,
};
