//! artifacts/manifest.json parsing — the contract between `python/compile/
//! aot.py` and the Rust runtime.

use crate::encoding::{json, Value};
use crate::util::{Error, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn decode(v: &Value) -> Result<TensorSpec> {
        Ok(TensorSpec {
            shape: v
                .req("shape")?
                .as_seq()
                .ok_or_else(|| Error::parse("shape must be a list"))?
                .iter()
                .filter_map(|d| d.as_int().map(|i| i as usize))
                .collect(),
            dtype: v.req_str("dtype")?.to_string(),
        })
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One exported artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    /// `init` | `train_step` | `infer`.
    pub role: String,
    /// Name of the init artifact producing this artifact's params.
    pub init: Option<String>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub metric: Option<String>,
    pub metric_output_index: Option<usize>,
    pub param_count: Option<usize>,
    pub flops_per_step: Option<u64>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::compute(format!("read {}: {e} (run `make artifacts`)", path.display())))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let v = json::parse(text)?;
        if v.opt_int("formatVersion") != Some(1) {
            return Err(Error::compute("unsupported manifest formatVersion"));
        }
        let arts = v
            .req("artifacts")?
            .as_map()
            .ok_or_else(|| Error::parse("artifacts must be a map"))?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in arts {
            let decode_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                entry
                    .req(key)?
                    .as_seq()
                    .ok_or_else(|| Error::parse(format!("{key} must be a list")))?
                    .iter()
                    .map(TensorSpec::decode)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    name: name.clone(),
                    file: entry.req_str("file")?.to_string(),
                    role: entry.req_str("role")?.to_string(),
                    init: entry.opt_str("init").map(String::from),
                    inputs: decode_specs("inputs")?,
                    outputs: decode_specs("outputs")?,
                    metric: entry.opt_str("metric").map(String::from),
                    metric_output_index: entry
                        .opt_int("metricOutputIndex")
                        .map(|i| i as usize),
                    param_count: entry.opt_int("paramCount").map(|i| i as usize),
                    flops_per_step: entry.opt_int("flopsPerStep").map(|i| i as u64),
                },
            );
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::compute(format!("unknown artifact `{name}`")))
    }

    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    pub fn names(&self) -> Vec<String> {
        self.artifacts.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "formatVersion": 1,
      "artifacts": {
        "m_init": {"file": "m_init.hlo.txt", "role": "init",
                   "inputs": [{"shape": [], "dtype": "int32"}],
                   "outputs": [{"shape": [4, 8], "dtype": "float32"}]},
        "m_train": {"file": "m_train.hlo.txt", "role": "train_step",
                    "init": "m_init",
                    "inputs": [{"shape": [], "dtype": "int32"},
                               {"shape": [4, 8], "dtype": "float32"}],
                    "outputs": [{"shape": [4, 8], "dtype": "float32"},
                                {"shape": [], "dtype": "float32"}],
                    "metric": "loss", "metricOutputIndex": 1,
                    "paramCount": 1, "flopsPerStep": 1000}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.names(), vec!["m_init", "m_train"]);
        let t = m.get("m_train").unwrap();
        assert_eq!(t.role, "train_step");
        assert_eq!(t.init.as_deref(), Some("m_init"));
        assert_eq!(t.param_count, Some(1));
        assert_eq!(t.metric_output_index, Some(1));
        assert_eq!(t.inputs[1].shape, vec![4, 8]);
        assert_eq!(t.inputs[1].element_count(), 32);
        assert_eq!(m.hlo_path(t), PathBuf::from("/tmp/m_train.hlo.txt"));
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_bad_version() {
        assert!(Manifest::parse(r#"{"formatVersion": 2, "artifacts": {}}"#, "/tmp".into())
            .is_err());
    }

    #[test]
    fn loads_real_manifest_when_built() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&dir).unwrap();
        let train = m.get("cropyield_train_tiny").unwrap();
        assert_eq!(train.role, "train_step");
        let pc = train.param_count.unwrap();
        assert_eq!(train.inputs.len(), pc + 1);
        assert_eq!(train.outputs.len(), pc + 1);
        assert!(m.hlo_path(train).exists());
    }
}
