//! Prometheus text exposition + structured JSON rendering of a
//! [`Metrics`] registry.
//!
//! The text format follows the Prometheus exposition conventions:
//! metric names sanitized to `[a-zA-Z0-9_:]`, one `# TYPE` line per
//! family, histograms rendered as cumulative `_bucket{le="..."}` series
//! plus `_sum`/`_count`. Values come straight from the registry's typed
//! snapshots, so a scrape never blocks a hot path for longer than the
//! per-map mutexes it already uses.

use crate::cluster::Metrics;
use crate::encoding::Value;
use crate::util::Hist;

/// Sanitize a registry name (`kube.api.create`, `redbox.rpc/Watch_ns`)
/// into a legal Prometheus metric name (`kube_api_create`).
pub fn sanitize(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for (i, c) in name.chars().enumerate() {
        let ok = c.is_ascii_alphanumeric() || c == '_' || c == ':';
        let ok = ok && !(i == 0 && c.is_ascii_digit());
        out.push(if ok { c } else { '_' });
    }
    out
}

/// Render the whole registry in Prometheus text exposition format.
pub fn render_prom(metrics: &Metrics) -> String {
    let mut out = String::new();
    for (name, v) in metrics.counters_snapshot() {
        let n = sanitize(&name);
        out.push_str(&format!("# TYPE {n} counter\n{n} {v}\n"));
    }
    for (name, v) in metrics.gauges_snapshot() {
        let n = sanitize(&name);
        out.push_str(&format!("# TYPE {n} gauge\n{n} {v}\n"));
    }
    for (name, h) in metrics.hists_snapshot() {
        render_hist(&mut out, &sanitize(&name), &h);
    }
    out
}

fn render_hist(out: &mut String, name: &str, h: &Hist) {
    out.push_str(&format!("# TYPE {name} histogram\n"));
    let mut cum = 0u64;
    for (le, count) in h.buckets_nonzero() {
        cum += count;
        out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
    }
    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
    out.push_str(&format!("{name}_sum {}\n", h.sum()));
    out.push_str(&format!("{name}_count {}\n", h.count()));
}

/// Render the registry as one structured JSON object:
/// `{"counters":{...},"gauges":{...},"hists":{name:{count,mean,p50,...}}}`.
pub fn render_json(metrics: &Metrics) -> Value {
    let mut counters = Value::map();
    for (name, v) in metrics.counters_snapshot() {
        counters.insert(&name, v);
    }
    let mut gauges = Value::map();
    for (name, v) in metrics.gauges_snapshot() {
        gauges.insert(&name, Value::Int(v));
    }
    let mut hists = Value::map();
    for (name, h) in metrics.hists_snapshot() {
        hists.insert(
            &name,
            Value::map()
                .with("count", h.count())
                .with("sum", h.sum() as u64)
                .with("mean", h.mean())
                .with("min", h.min())
                .with("p50", h.p50())
                .with("p95", h.p95())
                .with("p99", h.p99())
                .with("max", h.max()),
        );
    }
    Value::map().with("counters", counters).with("gauges", gauges).with("hists", hists)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitizes_names() {
        assert_eq!(sanitize("kube.api.create"), "kube_api_create");
        assert_eq!(sanitize("redbox.rpc.kube.Api/Create_ns"), "redbox_rpc_kube_Api_Create_ns");
        assert_eq!(sanitize("9lives"), "_lives");
    }

    #[test]
    fn renders_counters_gauges_hists() {
        let m = Metrics::new();
        m.add("kube.api.create", 3);
        m.set_gauge("queue.depth", -2);
        m.observe("commit.lat_ns", 100);
        m.observe("commit.lat_ns", 200_000);
        let text = render_prom(&m);
        assert!(text.contains("# TYPE kube_api_create counter\nkube_api_create 3\n"));
        assert!(text.contains("# TYPE queue_depth gauge\nqueue_depth -2\n"));
        assert!(text.contains("# TYPE commit_lat_ns histogram\n"));
        assert!(text.contains("commit_lat_ns_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("commit_lat_ns_sum 200100\n"));
        assert!(text.contains("commit_lat_ns_count 2\n"));
        // Cumulative buckets are monotone and end at the total count.
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.starts_with("commit_lat_ns_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "cumulative buckets must not decrease: {line}");
            last = v;
        }
        assert_eq!(last, 2);
    }

    #[test]
    fn json_snapshot_shape() {
        let m = Metrics::new();
        m.inc("c");
        m.set_gauge("g", 5);
        m.observe("h", 42);
        let v = render_json(&m);
        assert_eq!(v.get("counters").unwrap().opt_int("c"), Some(1));
        assert_eq!(v.get("gauges").unwrap().opt_int("g"), Some(5));
        let h = v.get("hists").unwrap().get("h").unwrap();
        assert_eq!(h.opt_int("count"), Some(1));
        // The whole thing survives a JSON round trip.
        let text = crate::encoding::json::to_string(&v);
        assert!(crate::encoding::json::parse(&text).is_ok());
    }
}
