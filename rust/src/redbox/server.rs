//! red-box server: a Unix-domain-socket RPC endpoint on the login node.
//!
//! "Red-box generates a Unix socket which allows data exchange among the
//! Kubernetes and Torque processes" (paper §III-B). Services register under
//! a name (`torque.Workload`); each accepted connection gets a handler
//! thread that reads request frames and dispatches `Service/Method` calls.
//!
//! Connections are **multiplexed**: the per-connection loop demultiplexes
//! concurrent requests and live server streams over one socket. A method
//! answers with a [`Reply`] — `Unary` writes the classic response;
//! `Stream` writes the response and then runs a producer on its own
//! thread, pushing [`Frame::StreamItem`] frames through a [`StreamSink`]
//! that shares the connection's writer. A client-sent `StreamEnd` cancels
//! the matching producer; connection loss cancels them all. Existing
//! unary services need no changes — [`Service::call_full`] defaults to
//! wrapping [`Service::call`].

use super::proto::{read_frame, write_frame, Frame, Request, Response};
use crate::cluster::Metrics;
use crate::encoding::Value;
use crate::rt::{self, Shutdown};
use crate::util::{Error, Result};
use std::collections::HashMap;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

/// What a method hands back through the streaming-capable dispatch path.
pub enum Reply {
    /// Classic one-shot response body.
    Unary(Value),
    /// Server-streaming: `initial` goes out as the response body, then
    /// `produce` runs on a dedicated thread pushing items via the sink.
    Stream { initial: Value, produce: Box<dyn FnOnce(StreamSink) + Send> },
}

impl Reply {
    /// Convenience constructor for the streaming arm.
    pub fn stream(initial: Value, produce: impl FnOnce(StreamSink) + Send + 'static) -> Reply {
        Reply::Stream { initial, produce: Box::new(produce) }
    }
}

/// The server half of one live stream: pushes `StreamItem`/`StreamEnd`
/// frames for its request id through the connection's shared writer.
/// Producers run on their own thread and must treat a `false` from
/// [`StreamSink::item`] (or [`StreamSink::is_cancelled`]) as "stop now":
/// the client cancelled, the connection died, or the server is stopping.
pub struct StreamSink {
    writer: Arc<Mutex<UnixStream>>,
    id: u64,
    seq: u64,
    cancel: Shutdown,
    metrics: Metrics,
}

impl StreamSink {
    /// Push one item; `false` means stop producing.
    pub fn item(&mut self, body: Value) -> bool {
        if self.cancel.is_triggered() {
            return false;
        }
        let frame = Frame::StreamItem { id: self.id, seq: self.seq, body };
        self.seq += 1;
        let mut w = self.writer.lock().unwrap();
        if write_frame(&mut *w, &frame.encode()).is_err() {
            self.cancel.trigger();
            return false;
        }
        self.metrics.inc("redbox.stream_items");
        true
    }

    /// End the stream with a reason (see [`super::proto::END_COMPLETE`]
    /// and friends). No-op if already cancelled — the peer is gone.
    pub fn end(self, reason: &str) {
        if self.cancel.is_triggered() {
            return;
        }
        let frame = Frame::StreamEnd { id: self.id, reason: reason.to_string() };
        {
            let mut w = self.writer.lock().unwrap();
            let _ = write_frame(&mut *w, &frame.encode());
        }
        // Mark finished so the connection loop can prune this stream's
        // cancel token — otherwise a long-lived connection accumulates
        // one entry per server-ended stream (e.g. repeated 410s).
        self.cancel.trigger();
    }

    /// True once the stream was cancelled (client cancel, connection
    /// loss, server shutdown).
    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_triggered()
    }

    /// Sleep up to `d`, returning early with `true` on cancellation — the
    /// idle tick for producers that emit periodic frames.
    pub fn wait_cancelled(&self, d: Duration) -> bool {
        self.cancel.wait_timeout(d)
    }
}

/// One RPC service: a bundle of methods under a service name.
pub trait Service: Send + Sync {
    /// Handle `method` (the part after the `/`).
    fn call(&self, method: &str, body: &Value) -> Result<Value>;

    /// Streaming-capable dispatch: override for methods that answer with
    /// a server stream. The default delegates to [`Service::call`], so
    /// unary services are written exactly as before.
    fn call_full(&self, method: &str, body: &Value) -> Result<Reply> {
        self.call(method, body).map(Reply::Unary)
    }
}

/// Plain function services for tests / small endpoints.
pub struct FnService<F>(pub F);

impl<F> Service for FnService<F>
where
    F: Fn(&str, &Value) -> Result<Value> + Send + Sync,
{
    fn call(&self, method: &str, body: &Value) -> Result<Value> {
        (self.0)(method, body)
    }
}

type Registry = Arc<RwLock<HashMap<String, Arc<dyn Service>>>>;

/// The listening server. Dropping does NOT stop it; trigger the shutdown.
pub struct RedboxServer {
    path: PathBuf,
    registry: Registry,
    shutdown: Shutdown,
    accept_thread: Option<JoinHandle<()>>,
    metrics: Metrics,
    /// Clones of accepted streams so stop() can unblock reader threads.
    conns: Arc<std::sync::Mutex<Vec<UnixStream>>>,
}

impl RedboxServer {
    /// Bind and start accepting. Removes a stale socket file first (as
    /// red-box does on restart).
    pub fn start(
        path: impl AsRef<Path>,
        shutdown: Shutdown,
        metrics: Metrics,
    ) -> Result<RedboxServer> {
        let path = path.as_ref().to_path_buf();
        if path.exists() {
            std::fs::remove_file(&path)?;
        }
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let listener = UnixListener::bind(&path)
            .map_err(|e| Error::rpc(format!("bind {}: {e}", path.display())))?;
        // Accept loop polls so shutdown is honored promptly.
        listener.set_nonblocking(true)?;
        let registry: Registry = Arc::new(RwLock::new(HashMap::new()));
        let conns: Arc<std::sync::Mutex<Vec<UnixStream>>> =
            Arc::new(std::sync::Mutex::new(Vec::new()));
        let reg2 = registry.clone();
        let sd2 = shutdown.clone();
        let m2 = metrics.clone();
        let conns2 = conns.clone();
        let accept_thread = rt::spawn_named("redbox-accept", move || {
            loop {
                if sd2.is_triggered() {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false).ok();
                        if let Ok(clone) = stream.try_clone() {
                            conns2.lock().unwrap().push(clone);
                        }
                        let reg = reg2.clone();
                        let sd = sd2.clone();
                        let m = m2.clone();
                        rt::spawn_named("redbox-conn", move || {
                            handle_conn(stream, reg, sd, m);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        if sd2.wait_timeout(std::time::Duration::from_millis(2)) {
                            return;
                        }
                    }
                    Err(_) => return,
                }
            }
        });
        Ok(RedboxServer {
            path,
            registry,
            shutdown,
            accept_thread: Some(accept_thread),
            metrics,
            conns,
        })
    }

    /// Register (or replace) a service.
    pub fn register(&self, name: &str, svc: Arc<dyn Service>) {
        self.registry.write().unwrap().insert(name.to_string(), svc);
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Stop accepting and join the accept loop (open connections drain on
    /// their own when clients disconnect or shutdown trips mid-read).
    pub fn stop(&mut self) {
        self.shutdown.trigger();
        // Unblock per-connection reader threads waiting in read_frame.
        for conn in self.conns.lock().unwrap().drain(..) {
            let _ = conn.shutdown(std::net::Shutdown::Both);
        }
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(&self.path);
    }
}

impl Drop for RedboxServer {
    fn drop(&mut self) {
        if self.accept_thread.is_some() {
            self.stop();
        }
    }
}

fn write_locked(writer: &Arc<Mutex<UnixStream>>, v: &Value) -> Result<()> {
    let mut w = writer.lock().unwrap();
    write_frame(&mut *w, v)
}

/// The per-connection demultiplexing loop: reads frames, answers unary
/// requests in order, spawns a producer thread per stream (all sharing
/// one writer), and routes client-sent `StreamEnd` frames to the matching
/// producer's cancel token. When the connection ends — client hangup,
/// transport error, or server stop — every stream it carried is
/// cancelled.
fn handle_conn(stream: UnixStream, registry: Registry, shutdown: Shutdown, metrics: Metrics) {
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    let mut reader = stream;
    // Cancel tokens of the streams opened on this connection.
    let mut streams: HashMap<u64, Shutdown> = HashMap::new();
    loop {
        if shutdown.is_triggered() {
            break;
        }
        let frame = match read_frame(&mut reader) {
            Ok(Some(v)) => v,
            Ok(None) => break, // client closed (or server stop() shut us down)
            Err(_) => break,   // transport error: drop connection
        };
        match Frame::decode(&frame) {
            Ok(Frame::Request(req)) => {
                metrics.inc("redbox.requests");
                let t0 = std::time::Instant::now();
                // Adopt the caller's trace and actor for the duration of
                // dispatch (dispatch runs inline on this conn thread, so
                // the thread-locals cover the whole handler). The server
                // span parents on the client's wire span — the
                // cross-process causal link; the actor is what the
                // ApiServer's audit middleware attributes the mutation to.
                let reply = {
                    let parent =
                        req.trace.as_deref().and_then(crate::obs::TraceContext::parse_wire);
                    let _span =
                        crate::obs::span_with_parent("redbox-server", &req.method, parent);
                    let _actor = req.actor.as_deref().map(crate::obs::push_actor);
                    dispatch(&req, &registry)
                };
                let elapsed = t0.elapsed().as_nanos() as u64;
                metrics.observe("redbox.handle_ns", elapsed);
                metrics.observe_with("redbox.rpc_ns", &[("method", &req.method)], elapsed);
                match reply {
                    Ok(Reply::Unary(body)) => {
                        if write_locked(&writer, &Response::ok(req.id, body).encode())
                            .is_err()
                        {
                            break;
                        }
                    }
                    Ok(Reply::Stream { initial, produce }) => {
                        // Response first, so the client observes stream
                        // acceptance before any item can arrive.
                        if write_locked(&writer, &Response::ok(req.id, initial).encode())
                            .is_err()
                        {
                            break;
                        }
                        let cancel = Shutdown::new();
                        // Prune tokens of streams that already finished
                        // (producers trigger theirs via StreamSink::end
                        // or on write failure) so the map only holds
                        // live streams, however long the conn lives.
                        streams.retain(|_, c| !c.is_triggered());
                        streams.insert(req.id, cancel.clone());
                        metrics.inc("redbox.streams");
                        let sink = StreamSink {
                            writer: writer.clone(),
                            id: req.id,
                            seq: 0,
                            cancel,
                            metrics: metrics.clone(),
                        };
                        rt::spawn_named("redbox-stream", move || produce(sink));
                    }
                    Err(e) => {
                        if write_locked(&writer, &Response::err_typed(req.id, &e).encode())
                            .is_err()
                        {
                            break;
                        }
                    }
                }
            }
            // Client cancel: stop that stream's producer.
            Ok(Frame::StreamEnd { id, .. }) => {
                if let Some(c) = streams.remove(&id) {
                    c.trigger();
                }
            }
            // Clients must not send responses or items; drop silently.
            Ok(Frame::Response(_)) | Ok(Frame::StreamItem { .. }) => {}
            Err(e) => {
                // Undecodable frame: report (id 0 = no request to echo).
                let resp = Response::err(0, format!("bad request: {e}"));
                if write_locked(&writer, &resp.encode()).is_err() {
                    break;
                }
            }
        }
    }
    // Connection over: cancel every stream it carried.
    for (_, c) in streams.drain() {
        c.trigger();
    }
}

fn dispatch(req: &Request, registry: &Registry) -> Result<Reply> {
    // Service failures travel typed (err_typed at the write site) so
    // remote callers can branch on is_not_found()/is_conflict() exactly
    // like in-process ones.
    let (service, method) = req.split_method()?;
    let svc = registry
        .read()
        .unwrap()
        .get(service)
        .cloned()
        .ok_or_else(|| Error::rpc(format!("unknown service `{service}`")))?;
    svc.call_full(method, &req.body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::redbox::client::RedboxClient;

    fn sock_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("hpcorc-test-{tag}-{}.sock", std::process::id()))
    }

    #[test]
    fn echo_service_roundtrip() {
        let sd = Shutdown::new();
        let mut srv =
            RedboxServer::start(sock_path("echo"), sd.clone(), Metrics::new()).unwrap();
        srv.register(
            "test.Echo",
            Arc::new(FnService(|method: &str, body: &Value| {
                Ok(Value::map().with("method", method).with("echo", body.clone()))
            })),
        );
        let client = RedboxClient::connect(srv.path()).unwrap();
        let out = client.call("test.Echo/Hi", Value::str("moo")).unwrap();
        assert_eq!(out.opt_str("method"), Some("Hi"));
        assert_eq!(out.get("echo"), Some(&Value::str("moo")));
        srv.stop();
    }

    #[test]
    fn unknown_service_and_error_paths() {
        let sd = Shutdown::new();
        let mut srv =
            RedboxServer::start(sock_path("unknown"), sd.clone(), Metrics::new()).unwrap();
        srv.register(
            "svc.Err",
            Arc::new(FnService(|_: &str, _: &Value| -> Result<Value> {
                Err(Error::wlm("queue not found"))
            })),
        );
        let client = RedboxClient::connect(srv.path()).unwrap();
        let err = client.call("nope.Svc/X", Value::Null).unwrap_err();
        assert!(err.to_string().contains("unknown service"));
        let err = client.call("svc.Err/X", Value::Null).unwrap_err();
        assert!(err.to_string().contains("queue not found"), "{err}");
        // Connection survives errors; a good call still works after.
        srv.register(
            "svc.Ok",
            Arc::new(FnService(|_: &str, _: &Value| Ok(Value::Bool(true)))),
        );
        assert_eq!(client.call("svc.Ok/X", Value::Null).unwrap(), Value::Bool(true));
        srv.stop();
    }

    #[test]
    fn concurrent_clients() {
        let sd = Shutdown::new();
        let mut srv =
            RedboxServer::start(sock_path("conc"), sd.clone(), Metrics::new()).unwrap();
        srv.register(
            "math.Add",
            Arc::new(FnService(|_: &str, body: &Value| {
                let a = body.req_int("a")?;
                let b = body.req_int("b")?;
                Ok(Value::Int(a + b))
            })),
        );
        let path = srv.path().to_path_buf();
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let p = path.clone();
                std::thread::spawn(move || {
                    let c = RedboxClient::connect(&p).unwrap();
                    for i in 0..50i64 {
                        let out = c
                            .call("math.Add/Run", Value::map().with("a", i).with("b", t as i64))
                            .unwrap();
                        assert_eq!(out, Value::Int(i + t as i64));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(srv.metrics().counter_value("redbox.requests"), 400);
        srv.stop();
    }

    #[test]
    fn stale_socket_replaced() {
        let path = sock_path("stale");
        std::fs::write(&path, b"stale").unwrap();
        let sd = Shutdown::new();
        let mut srv = RedboxServer::start(&path, sd, Metrics::new()).unwrap();
        srv.register("s.S", Arc::new(FnService(|_: &str, _: &Value| Ok(Value::Null))));
        let c = RedboxClient::connect(&path).unwrap();
        assert!(c.call("s.S/m", Value::Null).is_ok());
        srv.stop();
        assert!(!path.exists(), "socket removed on stop");
    }
}
