//! E6 — red-box Unix-socket RPC: latency and throughput of the bridge
//! every operator action crosses (paper §II/III-B).

use hpcorc::bench::{header, Bench};
use hpcorc::cluster::Metrics;
use hpcorc::encoding::Value;
use hpcorc::redbox::{FnService, RedboxClient, RedboxServer};
use hpcorc::rt::Shutdown;
use std::sync::Arc;

fn main() {
    println!("=== E6: red-box RPC over the Unix socket ===");
    println!("{}", header());
    let sd = Shutdown::new();
    let path = std::env::temp_dir().join(format!("hpcorc-bench-rb-{}.sock", std::process::id()));
    let mut srv = RedboxServer::start(&path, sd.clone(), Metrics::new()).unwrap();
    srv.register(
        "bench.Echo",
        Arc::new(FnService(|_: &str, body: &Value| Ok(body.clone()))),
    );

    let client = RedboxClient::connect(&path).unwrap();
    let small = Value::map().with("jobId", "42.torque-head");
    Bench::new("echo small payload (1 conn)").warmup(200).iters(5000).run(|| {
        client.call("bench.Echo/Run", small.clone()).unwrap();
    });

    // PBS-script-sized payload (the SubmitJob case).
    let script: String = hpcorc::kube::yaml::COW_JOB_YAML.repeat(4);
    let large = Value::map().with("script", script);
    Bench::new("echo 4KiB payload (1 conn)").warmup(100).iters(2000).run(|| {
        client.call("bench.Echo/Run", large.clone()).unwrap();
    });

    // Concurrent clients: aggregate throughput.
    for n_clients in [2usize, 8] {
        let per_client = 2000usize;
        let t0 = std::time::Instant::now();
        let handles: Vec<_> = (0..n_clients)
            .map(|_| {
                let p = path.clone();
                std::thread::spawn(move || {
                    let c = RedboxClient::connect(&p).unwrap();
                    let body = Value::map().with("jobId", "1.torque-head");
                    for _ in 0..2000 {
                        c.call("bench.Echo/Run", body.clone()).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed();
        let total = n_clients * per_client;
        println!(
            "{:<44} {:>10.0} req/s ({} clients, {} reqs, {:.2}s)",
            format!("concurrent throughput x{n_clients}"),
            total as f64 / wall.as_secs_f64(),
            n_clients,
            total,
            wall.as_secs_f64()
        );
    }
    srv.stop();
    sd.trigger();
}
