//! E4 — Torque-Operator vs WLM-Operator (Slurm backend): identical
//! workload through both bridges on one testbed (paper §II: "their
//! implementation varies significantly as Torque and Slurm have different
//! structures and parameters" — the latency cost of each dialect).

use hpcorc::bench::{header, Bench};
use hpcorc::hybrid::{Testbed, TestbedConfig};
use hpcorc::kube::{WlmJobView, KIND_SLURMJOB};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

fn main() {
    println!("=== E4: Torque-Operator vs WLM-Operator (Slurm) ===");
    println!("{}", header());
    let mut cfg = TestbedConfig::default();
    cfg.with_slurm = true;
    let tb = Testbed::start(cfg).expect("boot");
    static SEQ: AtomicU64 = AtomicU64::new(0);

    Bench::new("TorqueJob via Torque-Operator").warmup(3).iters(40).run(|| {
        let n = SEQ.fetch_add(1, Ordering::Relaxed);
        let name = format!("tq-{n}");
        tb.api
            .create(WlmJobView::build_torquejob(
                &name,
                &format!("#PBS -N {name}\nsingularity run lolcow_latest.sif\n"),
                "",
                "",
            ))
            .unwrap();
        assert_eq!(tb.wait_torquejob(&name, Duration::from_secs(30)).unwrap(), "completed");
    });

    Bench::new("SlurmJob via WLM-Operator").warmup(3).iters(40).run(|| {
        let n = SEQ.fetch_add(1, Ordering::Relaxed);
        let name = format!("sl-{n}");
        let mut obj = WlmJobView::build_torquejob(
            &name,
            &format!("#SBATCH -J {name}\nsingularity run lolcow_latest.sif\n"),
            "",
            "",
        );
        obj.kind = KIND_SLURMJOB.into();
        tb.api.create(obj).unwrap();
        assert_eq!(tb.wait_slurmjob(&name, Duration::from_secs(30)).unwrap(), "completed");
    });

    println!("\nshape: near-identical — the operator mechanism dominates; dialect costs are in parsing only.");
    tb.stop();
}
