//! The event loop: arrivals, completions, scheduling cycles.

use crate::sched::{NodeState, PendingJob, Placement, RunningJob, SchedPolicy};
use crate::util::Hist;
use crate::workload::{Trace, TraceJob};
use std::collections::BTreeMap;

/// Models the operator path's extra per-job latency (experiment E1's
/// "hybrid" series): admission through the K8s API + dummy-pod scheduling +
/// red-box hop, measured by bench E2 on the live path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OperatorModel {
    /// Added between a job's arrival and its visibility to the WLM.
    pub submit_delay_s: f64,
    /// Status-poll granularity (completion observed late by up to this).
    pub poll_s: f64,
}

impl OperatorModel {
    pub const NONE: OperatorModel = OperatorModel { submit_delay_s: 0.0, poll_s: 0.0 };
}

#[derive(Debug, Clone)]
pub struct SimParams {
    pub nodes: usize,
    pub cores_per_node: u32,
    pub mem_per_node: u64,
    /// Scheduling cycle period (both WLMs run periodic cycles).
    pub sched_period_s: f64,
    pub operator: OperatorModel,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            nodes: 16,
            cores_per_node: 8,
            mem_per_node: 64 << 30,
            sched_period_s: 1.0,
            operator: OperatorModel::NONE,
        }
    }
}

/// Aggregate results of one simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub policy: String,
    pub jobs: usize,
    pub completed: usize,
    pub killed_walltime: usize,
    /// Last completion time (seconds).
    pub makespan_s: f64,
    pub mean_wait_s: f64,
    pub p95_wait_s: f64,
    pub max_wait_s: f64,
    /// Mean bounded slowdown (wait+run)/max(run, 10s).
    pub mean_slowdown: f64,
    /// Core-seconds used / (capacity × makespan).
    pub utilization: f64,
    /// Scheduling cycles executed (cost proxy).
    pub sched_cycles: u64,
}

impl SimReport {
    pub fn row(&self) -> String {
        format!(
            "{:<14} jobs={:<5} done={:<5} killed={:<4} makespan={:>9.1}s wait(mean/p95/max)={:>7.1}/{:>7.1}/{:>7.1}s slowdown={:>6.2} util={:>5.1}%",
            self.policy,
            self.jobs,
            self.completed,
            self.killed_walltime,
            self.makespan_s,
            self.mean_wait_s,
            self.p95_wait_s,
            self.max_wait_s,
            self.mean_slowdown,
            self.utilization * 100.0
        )
    }
}

struct SimJob {
    spec: TraceJob,
    visible_s: f64,
    start_s: Option<f64>,
    end_s: Option<f64>,
    killed: bool,
    placement: Vec<Placement>,
}

/// Run `trace` through `policy` on the simulated cluster.
pub fn simulate(trace: &Trace, params: &SimParams, policy: &dyn SchedPolicy) -> SimReport {
    let mut jobs: BTreeMap<u64, SimJob> = trace
        .jobs
        .iter()
        .map(|j| {
            (
                j.id,
                SimJob {
                    spec: j.clone(),
                    visible_s: j.arrival_s + params.operator.submit_delay_s,
                    start_s: None,
                    end_s: None,
                    killed: false,
                    placement: Vec::new(),
                },
            )
        })
        .collect();

    let mut free: Vec<NodeState> = (0..params.nodes)
        .map(|i| NodeState::whole(i, params.cores_per_node, params.mem_per_node))
        .collect();

    // Event times: job visibility and running-job ends drive the clock; a
    // scheduling cycle runs at each event time (event-driven scheduling
    // with a minimum period to model cycle cost).
    let mut now = 0.0f64;
    let mut sched_cycles = 0u64;
    let mut pending_ids: Vec<u64> = Vec::new();
    let mut arrivals: Vec<u64> = {
        let mut v: Vec<u64> = jobs.keys().copied().collect();
        v.sort_by(|a, b| {
            jobs[a].visible_s.partial_cmp(&jobs[b].visible_s).unwrap().then(a.cmp(b))
        });
        v
    };
    arrivals.reverse(); // pop() from the back = earliest first
    // running: (end_s, id)
    let mut running: Vec<(f64, u64)> = Vec::new();

    loop {
        // Next event: earliest of next arrival / next completion.
        let next_arrival = arrivals.last().map(|id| jobs[id].visible_s);
        let next_end = running.iter().map(|(e, _)| *e).fold(f64::INFINITY, f64::min);
        let next = match (next_arrival, next_end.is_finite()) {
            (Some(a), true) => a.min(next_end),
            (Some(a), false) => a,
            (None, true) => next_end,
            (None, false) => {
                if pending_ids.is_empty() {
                    break;
                }
                // Pending jobs that can never run: drop them as killed.
                for id in pending_ids.drain(..) {
                    jobs.get_mut(&id).unwrap().killed = true;
                }
                break;
            }
        };
        now = next.max(now);

        // Process arrivals at `now`.
        while let Some(id) = arrivals.last().copied() {
            if jobs[&id].visible_s <= now + 1e-9 {
                arrivals.pop();
                pending_ids.push(id);
            } else {
                break;
            }
        }
        // Process completions at `now`.
        let mut i = 0;
        while i < running.len() {
            if running[i].0 <= now + 1e-9 {
                let (_, id) = running.swap_remove(i);
                let job = jobs.get_mut(&id).unwrap();
                job.end_s = Some(now.max(job.start_s.unwrap()));
                for p in &job.placement {
                    let n = &mut free[p.node];
                    n.free_cores += p.cores;
                    n.free_mem += p.mem;
                }
            } else {
                i += 1;
            }
        }

        // Scheduling cycle.
        if !pending_ids.is_empty() {
            let pending: Vec<PendingJob> = pending_ids
                .iter()
                .map(|id| {
                    let j = &jobs[id].spec;
                    PendingJob {
                        id: j.id,
                        nodes: j.nodes,
                        ppn: j.ppn,
                        mem: 0,
                        walltime: std::time::Duration::from_secs_f64(j.walltime_s),
                        priority: j.priority,
                        submit_s: jobs[id].visible_s,
                        queue: j.queue.clone(),
                    }
                })
                .collect();
            let running_view: Vec<RunningJob> = running
                .iter()
                .map(|(end, id)| RunningJob {
                    id: *id,
                    placement: jobs[id].placement.clone(),
                    expected_end_s: jobs[id].start_s.unwrap()
                        + jobs[id].spec.walltime_s.max(*end - jobs[id].start_s.unwrap()),
                })
                .collect();
            let assignments = policy.schedule(now, &pending, &free, &running_view);
            sched_cycles += 1;
            for a in assignments {
                let job = jobs.get_mut(&a.job).unwrap();
                job.start_s = Some(now);
                job.placement = a.placement.clone();
                for p in &a.placement {
                    let n = &mut free[p.node];
                    n.free_cores -= p.cores;
                    n.free_mem -= p.mem;
                }
                // Walltime enforcement: actual end is min(runtime, walltime).
                let dur = if job.spec.runtime_s > job.spec.walltime_s {
                    job.killed = true;
                    job.spec.walltime_s
                } else {
                    job.spec.runtime_s
                };
                // Operator completions observed late by up to poll_s.
                let end = now + dur + params.operator.poll_s;
                running.push((end, a.job));
                pending_ids.retain(|id| *id != a.job);
            }
        }
        if arrivals.is_empty() && running.is_empty() && pending_ids.is_empty() {
            break;
        }
        // Safety: if nothing can ever be scheduled (pending jobs larger
        // than the machine), drop them.
        if !pending_ids.is_empty() && running.is_empty() && arrivals.is_empty() {
            let can_run: Vec<u64> = pending_ids
                .iter()
                .copied()
                .filter(|id| {
                    let j = &jobs[id].spec;
                    (j.nodes as usize) <= params.nodes && j.ppn <= params.cores_per_node
                })
                .collect();
            if can_run.is_empty() {
                for id in pending_ids.drain(..) {
                    jobs.get_mut(&id).unwrap().killed = true;
                }
                break;
            }
        }
    }

    // Aggregate.
    let mut wait_hist = Hist::new();
    let mut slowdowns = Vec::new();
    let mut core_seconds = 0.0;
    let mut makespan: f64 = 0.0;
    let mut completed = 0;
    let mut killed = 0;
    for job in jobs.values() {
        if job.spec.runtime_s > job.spec.walltime_s && job.start_s.is_some() {
            killed += 1;
        }
        let (Some(start), Some(end)) = (job.start_s, job.end_s) else {
            if job.killed {
                killed += 1;
            }
            continue;
        };
        completed += 1;
        let wait = (start - job.spec.arrival_s).max(0.0);
        wait_hist.record((wait * 1000.0) as u64); // ms resolution
        let run = end - start;
        slowdowns.push((wait + run) / run.max(10.0));
        core_seconds += (job.spec.nodes * job.spec.ppn) as f64 * run;
        makespan = makespan.max(end);
    }
    let capacity = (params.nodes as u32 * params.cores_per_node) as f64;
    SimReport {
        policy: policy.name().to_string(),
        jobs: trace.len(),
        completed,
        killed_walltime: killed,
        makespan_s: makespan,
        mean_wait_s: wait_hist.mean() / 1000.0,
        p95_wait_s: wait_hist.p95() as f64 / 1000.0,
        max_wait_s: wait_hist.max() as f64 / 1000.0,
        mean_slowdown: if slowdowns.is_empty() {
            0.0
        } else {
            slowdowns.iter().sum::<f64>() / slowdowns.len() as f64
        },
        utilization: if makespan > 0.0 { core_seconds / (capacity * makespan) } else { 0.0 },
        sched_cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{EasyBackfill, FifoPolicy, KubeGreedyPolicy};
    use crate::workload::{TraceGen, TraceJob};

    fn params(nodes: usize, cores: u32) -> SimParams {
        SimParams { nodes, cores_per_node: cores, ..SimParams::default() }
    }

    #[test]
    fn single_job_timing() {
        let trace = Trace::new("t", vec![TraceJob::sleep(1, 5.0, 1, 1, 100.0, 60.0)]);
        let r = simulate(&trace, &params(1, 1), &FifoPolicy);
        assert_eq!(r.completed, 1);
        assert!((r.makespan_s - 65.0).abs() < 1e-6, "{}", r.makespan_s);
        assert_eq!(r.mean_wait_s, 0.0);
        assert_eq!(r.killed_walltime, 0);
    }

    #[test]
    fn queueing_when_saturated() {
        // two 60s jobs on one core: second waits 60s.
        let trace = Trace::new(
            "t",
            vec![
                TraceJob::sleep(1, 0.0, 1, 1, 100.0, 60.0),
                TraceJob::sleep(2, 0.0, 1, 1, 100.0, 60.0),
            ],
        );
        let r = simulate(&trace, &params(1, 1), &FifoPolicy);
        assert_eq!(r.completed, 2);
        assert!((r.makespan_s - 120.0).abs() < 1e-6);
        assert!((r.max_wait_s - 60.0).abs() < 0.1, "{}", r.max_wait_s);
    }

    #[test]
    fn walltime_kill_counted() {
        let trace = Trace::new("t", vec![TraceJob::sleep(1, 0.0, 1, 1, 30.0, 100.0)]);
        let r = simulate(&trace, &params(1, 1), &FifoPolicy);
        assert_eq!(r.killed_walltime, 1);
        assert!((r.makespan_s - 30.0).abs() < 1e-6, "killed at walltime");
    }

    #[test]
    fn deterministic() {
        let trace = TraceGen::new(1).poisson_batch(200, 32, 0.8, 100.0);
        let a = simulate(&trace, &params(4, 8), &EasyBackfill);
        let b = simulate(&trace, &params(4, 8), &EasyBackfill);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.mean_wait_s, b.mean_wait_s);
    }

    /// The E1 headline shape: on a backfill-friendly trace, EASY beats
    /// strict FIFO on makespan and utilization.
    #[test]
    fn backfill_beats_fifo_on_showcase() {
        let trace = TraceGen::new(2).backfill_showcase(4, 8);
        let fifo = simulate(&trace, &params(8, 1), &FifoPolicy);
        let easy = simulate(&trace, &params(8, 1), &EasyBackfill);
        assert_eq!(fifo.completed, trace.len());
        assert_eq!(easy.completed, trace.len());
        assert!(
            easy.makespan_s < fifo.makespan_s * 0.95,
            "easy {} vs fifo {}",
            easy.makespan_s,
            fifo.makespan_s
        );
        assert!(easy.utilization > fifo.utilization);
    }

    /// K8s-greedy starves wide jobs: narrow jobs flow past, wide job waits
    /// far longer than under EASY (which reserves).
    #[test]
    fn kube_greedy_starves_wide_jobs() {
        let mut jobs = vec![TraceJob::sleep(1, 1.0, 4, 1, 700.0, 600.0)]; // wide
        // Sustainable narrow stream (load ~0.83): staggered arrivals keep
        // all-4-nodes-free moments rare, so greedy never clears room for
        // the wide job while EASY's reservation drains the nodes for it.
        for i in 0..60 {
            jobs.push(TraceJob::sleep(2 + i, 30.0 * i as f64, 1, 1, 150.0, 100.0));
        }
        let trace = Trace::new("starve", jobs);
        let easy = simulate(&trace, &params(4, 1), &EasyBackfill);
        let greedy = simulate(&trace, &params(4, 1), &KubeGreedyPolicy);
        let wide_wait = |r: &SimReport| r.max_wait_s; // wide job dominates max
        assert!(
            wide_wait(&greedy) > wide_wait(&easy) * 1.5,
            "greedy max wait {} vs easy {}",
            greedy.max_wait_s,
            easy.max_wait_s
        );
    }

    #[test]
    fn operator_overhead_shifts_waits() {
        let trace = TraceGen::new(3).poisson_batch(100, 32, 0.5, 60.0);
        let base = simulate(&trace, &params(4, 8), &EasyBackfill);
        let mut p = params(4, 8);
        p.operator = OperatorModel { submit_delay_s: 2.0, poll_s: 1.0 };
        let with_op = simulate(&trace, &p, &EasyBackfill);
        assert!(with_op.mean_wait_s >= base.mean_wait_s + 1.0,
            "operator delay visible: {} vs {}", with_op.mean_wait_s, base.mean_wait_s);
        assert!(with_op.makespan_s >= base.makespan_s);
    }

    #[test]
    fn impossible_job_dropped_not_hung() {
        let trace = Trace::new("t", vec![TraceJob::sleep(1, 0.0, 99, 1, 10.0, 10.0)]);
        let r = simulate(&trace, &params(2, 1), &EasyBackfill);
        assert_eq!(r.completed, 0);
        assert_eq!(r.killed_walltime, 1);
    }

    #[test]
    fn utilization_bounded() {
        let trace = TraceGen::new(4).poisson_batch(300, 64, 0.9, 80.0);
        for policy in [&FifoPolicy as &dyn SchedPolicy, &EasyBackfill, &KubeGreedyPolicy] {
            let r = simulate(&trace, &params(8, 8), policy);
            assert!(r.utilization <= 1.0 + 1e-9, "{} util {}", r.policy, r.utilization);
            assert!(r.completed + r.killed_walltime >= trace.len() - 1);
        }
    }
}
