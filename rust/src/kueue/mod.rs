//! Queue layer: quota-aware gang admission in front of the scheduler and
//! the operator — a Kueue-style (kueue.x-k8s.io) admission subsystem.
//!
//! The paper's Torque-Operator bridges micro-services and batch jobs, but
//! without a queueing layer every workload races straight into
//! scheduling: no tenant quotas, no fairness, and no all-or-nothing gang
//! semantics — exactly the gap converged-computing systems close with an
//! admission layer (Kueue; the Flux Operator, arXiv:2309.17420) and that
//! High-Performance Kubernetes (arXiv:2409.16919) names as the blocker
//! for cloud-native workloads on HPC.
//!
//! # Object model ([`types`])
//!
//! Two CRDs, registered in [`crate::kube::default_scheme`] like any other
//! kind:
//!
//! - **ClusterQueue** (`kubectl get clusterqueues` / `cq`) — per-resource
//!   quotas over the `{nodes, cpu, memory}` vector: `nominal` (always
//!   usable) and an optional `borrowingLimit` (cap on overdraft), a
//!   `cohort` name pooling spare capacity with peer queues, `ordering`
//!   (`fifo` | `priority`), and a `preemption` policy.
//! - **LocalQueue** (`localqueues` / `lq`) — the user-facing binding that
//!   points at a ClusterQueue.
//!
//! Workloads (Pods, TorqueJobs, SlurmJobs) opt in with the
//! `kueue.x-k8s.io/queue-name` label; pods may additionally form gangs
//! via the pod-group label + count annotation.
//!
//! # Admission flow: suspend → reserve → admit → preempt
//!
//! 1. **suspend** — a labelled workload is born *gated*: its `Admitted`
//!    condition is unset. Pods additionally carry the
//!    `kueue.x-k8s.io/admission` entry in the generic
//!    `spec.schedulingGates` (set by [`queue_workload`] at creation,
//!    back-filled by the admission cycle), which is what
//!    [`crate::kube::KubeScheduler`] actually checks — the scheduler
//!    knows nothing about kueue (PR 3 inverted that dependency). The
//!    operator's dummy-pod path (for WlmJobs) still gates on the missing
//!    `Admitted` condition. Suspension is the *absence* of admission, so
//!    a crashed controller loses nothing.
//! 2. **reserve** — each [`admission::AdmissionCore::cycle`] reads
//!    queues and workloads from the shared informer caches (zero list
//!    RPCs; PR 4) and maintains an **incremental** [`quota::Ledger`]:
//!    admitted charges advance by charge/uncharge on watch deltas, with
//!    a full rebuild only on a ClusterQueue spec change or an informer
//!    resync epoch bump (the 410-Gone recovery). The cycle then walks
//!    each queue's pending gangs in (FIFO or priority) order, reserving
//!    quota for a gang only if its *entire* demand fits — nominal first,
//!    then borrowing from idle cohort capacity up to the borrowing
//!    limit. Pods born with a bare queue-name label are gated at
//!    creation by the ApiServer mutating hook
//!    ([`admission_mutating_hook`]); the cycle back-fills stragglers.
//! 3. **admit** — only after the whole gang is reserved are its members'
//!    `QuotaReserved`/`Admitted` conditions written; scheduler and
//!    operator then proceed (a multi-node TorqueJob submits over red-box
//!    exactly once, with all of its nodes).
//! 4. **preempt** — when a gang that fits within its own nominal quota is
//!    blocked, [`preemption::select_victims`] simulates evictions on a
//!    cloned ledger: cohort peers holding *borrowed* capacity are
//!    reclaimed first (`reclaimWithinCohort`), then lower-priority gangs
//!    in the same queue (`withinClusterQueue`) — cheapest victims first,
//!    whole gangs only, and nothing is evicted unless it actually makes
//!    the incoming gang fit. Evicted pods are unbound; evicted WlmJobs
//!    are cancelled over red-box by the operator and resubmitted when
//!    re-admitted.
//!
//! # Mapping to Kueue / Flux concepts
//!
//! | here                          | Kueue                      | Flux Operator         |
//! |-------------------------------|----------------------------|-----------------------|
//! | `queue-name` label            | `queue-name` label         | MiniCluster job spec  |
//! | gated (no `Admitted`)         | `spec.suspend=true`        | held in flux queue    |
//! | `Ledger` nominal/borrowing    | `nominalQuota`/`borrowingLimit` | bank accounting  |
//! | cohort                        | cohort                     | flux bank hierarchy   |
//! | gang (WlmJob / pod group)     | Workload with podSets      | MiniCluster gang      |
//! | `QuotaReserved`→`Admitted`    | same two conditions        | alloc in flux-sched   |
//!
//! The simulator mirrors the same semantics with
//! [`crate::sim::QueueAdmission`], a quota filter in front of any
//! `SchedPolicy`, so E1-style experiments can compare admitted vs raw
//! traces at scale.

pub mod admission;
pub mod controller;
pub mod preemption;
pub mod quota;
pub mod types;

pub use admission::{AdmissionCore, CycleReport};
pub use controller::{start_admission, KueueController};
pub use preemption::{evict_gang, select_victims, AdmittedGang};
pub use quota::{Fit, Ledger, QueueState};
pub use types::{
    admission_gated, admission_mutating_hook, get_condition, is_admitted, is_evicted,
    queue_name, queue_workload,
    set_condition, workload_demand, workload_priority, workload_terminal, ClusterQueueView,
    LocalQueueView, PreemptionPolicy, QueueOrdering, QueueResources, COND_ADMITTED,
    COND_EVICTED, COND_QUOTA_RESERVED, KIND_CLUSTERQUEUE, KIND_LOCALQUEUE,
    KUEUE_API_VERSION, POD_GROUP_COUNT_ANNOTATION, POD_GROUP_LABEL, PRIORITY_LABEL,
    QUEUE_NAME_LABEL, SCHEDULING_GATE, WORKLOAD_KINDS,
};
