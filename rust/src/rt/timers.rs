//! Timer service: schedule callbacks at deadlines with cancellation.
//!
//! One dedicated thread drives a min-heap of deadlines. Used for PBS
//! walltime enforcement, kubelet heartbeats, and controller requeue backoff.

use super::Shutdown;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::HashSet;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

type Callback = Box<dyn FnOnce() + Send + 'static>;

struct Entry {
    deadline: Instant,
    id: u64,
    cb: Callback,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.deadline == other.deadline && self.id == other.id
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.deadline, self.id).cmp(&(other.deadline, other.id))
    }
}

#[derive(Default)]
struct State {
    heap: BinaryHeap<Reverse<Entry>>,
    cancelled: HashSet<u64>,
    next_id: u64,
    closed: bool,
}

/// Handle to a scheduled timer; keep it to cancel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TimerId(u64);

/// The timer service. Clone the handle freely.
#[derive(Clone)]
pub struct Timers {
    state: Arc<(Mutex<State>, Condvar)>,
}

impl Timers {
    /// Start the timer thread. The returned JoinHandle ends on shutdown.
    pub fn start(shutdown: Shutdown) -> (Timers, JoinHandle<()>) {
        let timers = Timers { state: Arc::new((Mutex::new(State::default()), Condvar::new())) };
        let t2 = timers.clone();
        let sd = shutdown;
        let handle = super::spawn_named("timers", move || t2.run(sd));
        (timers, handle)
    }

    /// Schedule `cb` to run after `delay` on the timer thread. Callbacks must
    /// be short; offload heavy work to a [`super::Pool`].
    pub fn after<F: FnOnce() + Send + 'static>(&self, delay: Duration, cb: F) -> TimerId {
        self.at(Instant::now() + delay, cb)
    }

    /// Schedule `cb` at an absolute deadline.
    pub fn at<F: FnOnce() + Send + 'static>(&self, deadline: Instant, cb: F) -> TimerId {
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap();
        let id = st.next_id;
        st.next_id += 1;
        st.heap.push(Reverse(Entry { deadline, id, cb: Box::new(cb) }));
        cv.notify_one();
        TimerId(id)
    }

    /// Cancel a timer. Returns true if it had not fired yet.
    pub fn cancel(&self, id: TimerId) -> bool {
        let (lock, _) = &*self.state;
        let mut st = lock.lock().unwrap();
        let pending =
            st.heap.iter().any(|Reverse(e)| e.id == id.0) && !st.cancelled.contains(&id.0);
        if pending {
            st.cancelled.insert(id.0);
        }
        pending
    }

    /// Number of pending (non-cancelled) timers.
    pub fn pending(&self) -> usize {
        let (lock, _) = &*self.state;
        let st = lock.lock().unwrap();
        st.heap.iter().filter(|Reverse(e)| !st.cancelled.contains(&e.id)).count()
    }

    fn run(&self, shutdown: Shutdown) {
        let (lock, cv) = &*self.state;
        loop {
            let mut fired: Vec<Callback> = Vec::new();
            {
                let mut st = lock.lock().unwrap();
                loop {
                    if shutdown.is_triggered() {
                        st.closed = true;
                        return;
                    }
                    let now = Instant::now();
                    // Pop all due entries.
                    let mut popped_any = false;
                    while let Some(Reverse(top)) = st.heap.peek() {
                        if top.deadline <= now {
                            let Reverse(e) = st.heap.pop().unwrap();
                            if !st.cancelled.remove(&e.id) {
                                fired.push(e.cb);
                            }
                            popped_any = true;
                        } else {
                            break;
                        }
                    }
                    if popped_any && !fired.is_empty() {
                        break; // run callbacks outside the lock
                    }
                    // Sleep until next deadline or a new entry arrives.
                    let wait = st
                        .heap
                        .peek()
                        .map(|Reverse(e)| e.deadline.saturating_duration_since(now))
                        .unwrap_or(Duration::from_millis(50));
                    let wait = wait.min(Duration::from_millis(50)).max(Duration::from_micros(100));
                    let (ng, _) = cv.wait_timeout(st, wait).unwrap();
                    st = ng;
                }
            }
            for cb in fired {
                cb();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn setup() -> (Timers, Shutdown) {
        let sd = Shutdown::new();
        let (t, _h) = Timers::start(sd.clone());
        (t, sd)
    }

    #[test]
    fn fires_in_order() {
        let (t, sd) = setup();
        let log = Arc::new(Mutex::new(Vec::new()));
        for (i, d) in [(2u32, 30u64), (1, 15), (0, 5)] {
            let log = log.clone();
            t.after(Duration::from_millis(d), move || log.lock().unwrap().push(i));
        }
        std::thread::sleep(Duration::from_millis(120));
        assert_eq!(*log.lock().unwrap(), vec![0, 1, 2]);
        sd.trigger();
    }

    #[test]
    fn cancel_prevents_fire() {
        let (t, sd) = setup();
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        let id = t.after(Duration::from_millis(30), move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        assert!(t.cancel(id));
        assert!(!t.cancel(id), "second cancel is a no-op");
        std::thread::sleep(Duration::from_millis(80));
        assert_eq!(count.load(Ordering::SeqCst), 0);
        sd.trigger();
    }

    #[test]
    fn many_timers() {
        let (t, sd) = setup();
        let count = Arc::new(AtomicUsize::new(0));
        for i in 0..200 {
            let c = count.clone();
            t.after(Duration::from_millis(1 + (i % 20)), move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        std::thread::sleep(Duration::from_millis(300));
        assert_eq!(count.load(Ordering::SeqCst), 200);
        assert_eq!(t.pending(), 0);
        sd.trigger();
    }

    #[test]
    fn shutdown_stops_thread() {
        let sd = Shutdown::new();
        let (t, h) = Timers::start(sd.clone());
        t.after(Duration::from_secs(600), || panic!("should never fire"));
        sd.trigger();
        h.join().unwrap();
    }
}
