//! Slurm workload manager substrate — the baseline WLM-Operator targets
//! (paper §II). Shares the scheduling cores ([`crate::sched`]) and the node
//! execution daemon ([`crate::pbs::Mom`], `SLURM_*` flavor) with the Torque
//! implementation; differs in script dialect, partitions, and job states.

pub mod ctld;
pub mod script;

pub use ctld::{Partition, SlurmConfig, SlurmJob, SlurmJobState, Slurmctld};
pub use script::SlurmScript;
