//! Bench harness (criterion substitute — the offline registry has none).
//!
//! Measures a closure over warmup + timed iterations and prints
//! criterion-style rows. `cargo bench` binaries use `harness = false` and
//! call [`Bench`] directly. All benches print the table/figure they
//! regenerate (EXPERIMENTS.md cross-references these tags).

use crate::util::Hist;
use std::time::Instant;

/// One benchmark case.
pub struct Bench {
    name: String,
    warmup: u32,
    iters: u32,
}

/// Result statistics (nanoseconds).
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    pub min_ns: u64,
    pub max_ns: u64,
}

impl Stats {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10} {:>10} {:>10} {:>10}   n={}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns as f64),
            fmt_ns(self.p95_ns as f64),
            fmt_ns(self.max_ns as f64),
            self.iters
        )
    }

    pub fn per_sec(&self) -> f64 {
        if self.mean_ns > 0.0 {
            1e9 / self.mean_ns
        } else {
            0.0
        }
    }

    /// Machine-readable JSON line for the perf trajectory (CI logs grep
    /// these out; keys are stable).
    pub fn json(&self) -> String {
        format!(
            "{{\"bench\":\"{}\",\"iters\":{},\"mean_ns\":{:.0},\"p50_ns\":{},\"p95_ns\":{},\"p99_ns\":{},\"min_ns\":{},\"max_ns\":{}}}",
            self.name.replace('"', "'"),
            self.iters,
            self.mean_ns,
            self.p50_ns,
            self.p95_ns,
            self.p99_ns,
            self.min_ns,
            self.max_ns
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

pub fn header() -> String {
    format!(
        "{:<44} {:>10} {:>10} {:>10} {:>10}",
        "benchmark", "mean", "p50", "p95", "max"
    )
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Bench {
        Bench { name: name.into(), warmup: 3, iters: 30 }
    }

    pub fn warmup(mut self, n: u32) -> Bench {
        self.warmup = n;
        self
    }

    pub fn iters(mut self, n: u32) -> Bench {
        self.iters = n;
        self
    }

    /// Run and return stats; prints the row.
    pub fn run<F: FnMut()>(self, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let mut hist = Hist::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            hist.record(t0.elapsed().as_nanos() as u64);
        }
        let stats = Stats {
            name: self.name,
            iters: self.iters,
            mean_ns: hist.mean(),
            p50_ns: hist.p50(),
            p95_ns: hist.p95(),
            p99_ns: hist.p99(),
            min_ns: hist.min(),
            max_ns: hist.max(),
        };
        println!("{}", stats.row());
        stats
    }

    /// Run a batched workload: `f(batch)` processes `batch` items per call;
    /// reports per-item latency + items/sec.
    pub fn run_throughput<F: FnMut(u32)>(self, batch: u32, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f(batch);
        }
        let mut hist = Hist::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f(batch);
            hist.record((t0.elapsed().as_nanos() as u64) / batch.max(1) as u64);
        }
        let stats = Stats {
            name: self.name,
            iters: self.iters * batch,
            mean_ns: hist.mean(),
            p50_ns: hist.p50(),
            p95_ns: hist.p95(),
            p99_ns: hist.p99(),
            min_ns: hist.min(),
            max_ns: hist.max(),
        };
        println!("{}  ({:.0} items/s)", stats.row(), stats.per_sec());
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let stats = Bench::new("spin").warmup(1).iters(5).run(|| {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(stats.iters, 5);
        assert!(stats.mean_ns > 0.0);
        assert!(stats.min_ns <= stats.max_ns);
        assert!(stats.per_sec() > 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50ms");
        assert_eq!(fmt_ns(3_000_000_000.0), "3.00s");
    }
}
