//! Prometheus text exposition + structured JSON rendering of a
//! [`Metrics`] registry.
//!
//! The text format follows the Prometheus exposition conventions:
//! metric names sanitized to `[a-zA-Z0-9_:]`, **one `# TYPE` line per
//! family** (a labelled family renders every `{k="v"}` series under a
//! single header), histograms rendered as cumulative `_bucket{le="..."}`
//! series plus `_sum`/`_count` — family labels precede `le`. Families
//! and the label sets inside them render in sorted order (the registry's
//! canonical-key BTreeMap), so scrapes, smoke greps, and golden diffs
//! are stable across runs. Values come straight from the registry's
//! typed snapshots, so a scrape never blocks a hot path for longer than
//! the per-map mutexes it already uses.

use crate::cluster::{split_key, Metrics};
use crate::encoding::Value;
use crate::util::Hist;

/// Sanitize a registry name (`kube.api.create`, `redbox.rpc/Watch_ns`)
/// into a legal Prometheus metric name (`kube_api_create`).
pub fn sanitize(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for (i, c) in name.chars().enumerate() {
        let ok = c.is_ascii_alphanumeric() || c == '_' || c == ':';
        let ok = ok && !(i == 0 && c.is_ascii_digit());
        out.push(if ok { c } else { '_' });
    }
    out
}

/// Group a snapshot of canonical keys into sorted families, each holding
/// its series as `(label rendering, value)` in canonical (sorted) order.
fn families<T>(snap: Vec<(String, T)>) -> Vec<(String, Vec<(Option<String>, T)>)> {
    let mut out: std::collections::BTreeMap<String, Vec<(Option<String>, T)>> =
        std::collections::BTreeMap::new();
    for (key, v) in snap {
        let (family, labels) = split_key(&key);
        out.entry(sanitize(family)).or_default().push((labels.map(str::to_string), v));
    }
    out.into_iter().collect()
}

/// Render the whole registry in Prometheus text exposition format.
pub fn render_prom(metrics: &Metrics) -> String {
    let mut out = String::new();
    for (family, series) in families(metrics.counters_snapshot()) {
        out.push_str(&format!("# TYPE {family} counter\n"));
        for (labels, v) in series {
            match labels {
                Some(l) => out.push_str(&format!("{family}{{{l}}} {v}\n")),
                None => out.push_str(&format!("{family} {v}\n")),
            }
        }
    }
    for (family, series) in families(metrics.gauges_snapshot()) {
        out.push_str(&format!("# TYPE {family} gauge\n"));
        for (labels, v) in series {
            match labels {
                Some(l) => out.push_str(&format!("{family}{{{l}}} {v}\n")),
                None => out.push_str(&format!("{family} {v}\n")),
            }
        }
    }
    for (family, series) in families(metrics.hists_snapshot()) {
        out.push_str(&format!("# TYPE {family} histogram\n"));
        for (labels, h) in series {
            render_hist(&mut out, &family, labels.as_deref(), &h);
        }
    }
    out
}

fn render_hist(out: &mut String, name: &str, labels: Option<&str>, h: &Hist) {
    // Family labels come before `le` so an unlabelled histogram renders
    // exactly the pre-PR-8 shape (`_bucket{le="..."}`).
    let le_prefix = labels.map(|l| format!("{l},")).unwrap_or_default();
    let suffix = labels.map(|l| format!("{{{l}}}")).unwrap_or_default();
    let mut cum = 0u64;
    for (le, count) in h.buckets_nonzero() {
        cum += count;
        out.push_str(&format!("{name}_bucket{{{le_prefix}le=\"{le}\"}} {cum}\n"));
    }
    out.push_str(&format!("{name}_bucket{{{le_prefix}le=\"+Inf\"}} {}\n", h.count()));
    out.push_str(&format!("{name}_sum{suffix} {}\n", h.sum()));
    out.push_str(&format!("{name}_count{suffix} {}\n", h.count()));
}

/// Render the registry as one structured JSON object:
/// `{"counters":{...},"gauges":{...},"hists":{name:{count,mean,p50,...}}}`.
/// Keys are the registry's canonical series keys (labelled series keep
/// their `{k="v"}` suffix) in sorted order, so the JSON is byte-stable
/// for a given registry state.
pub fn render_json(metrics: &Metrics) -> Value {
    let mut counters = Value::map();
    for (name, v) in metrics.counters_snapshot() {
        counters.insert(&name, v);
    }
    let mut gauges = Value::map();
    for (name, v) in metrics.gauges_snapshot() {
        gauges.insert(&name, Value::Int(v));
    }
    let mut hists = Value::map();
    for (name, h) in metrics.hists_snapshot() {
        hists.insert(
            &name,
            Value::map()
                .with("count", h.count())
                .with("sum", h.sum() as u64)
                .with("mean", h.mean())
                .with("min", h.min())
                .with("p50", h.p50())
                .with("p95", h.p95())
                .with("p99", h.p99())
                .with("max", h.max()),
        );
    }
    Value::map().with("counters", counters).with("gauges", gauges).with("hists", hists)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitizes_names() {
        assert_eq!(sanitize("kube.api.create"), "kube_api_create");
        assert_eq!(sanitize("redbox.rpc.kube.Api/Create_ns"), "redbox_rpc_kube_Api_Create_ns");
        assert_eq!(sanitize("9lives"), "_lives");
    }

    #[test]
    fn renders_counters_gauges_hists() {
        let m = Metrics::new();
        m.add("kube.api.create", 3);
        m.set_gauge("queue.depth", -2);
        m.observe("commit.lat_ns", 100);
        m.observe("commit.lat_ns", 200_000);
        let text = render_prom(&m);
        assert!(text.contains("# TYPE kube_api_create counter\nkube_api_create 3\n"));
        assert!(text.contains("# TYPE queue_depth gauge\nqueue_depth -2\n"));
        assert!(text.contains("# TYPE commit_lat_ns histogram\n"));
        assert!(text.contains("commit_lat_ns_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("commit_lat_ns_sum 200100\n"));
        assert!(text.contains("commit_lat_ns_count 2\n"));
        // Cumulative buckets are monotone and end at the total count.
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.starts_with("commit_lat_ns_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "cumulative buckets must not decrease: {line}");
            last = v;
        }
        assert_eq!(last, 2);
    }

    #[test]
    fn renders_labelled_families_under_one_type_header() {
        let m = Metrics::new();
        m.inc_with("kube.api.create", &[("gvk", "pods")]);
        m.add_with("kube.api.create", &[("gvk", "nodes")], 2);
        m.inc("kube.api.create"); // bare series coexists with labelled ones
        m.observe_with("redbox.rpc_ns", &[("method", "kube.Api/Create")], 500);
        let text = render_prom(&m);
        assert_eq!(
            text.matches("# TYPE kube_api_create counter").count(),
            1,
            "one TYPE line per family: {text}"
        );
        assert!(text.contains("kube_api_create 1\n"));
        assert!(text.contains("kube_api_create{gvk=\"nodes\"} 2\n"));
        assert!(text.contains("kube_api_create{gvk=\"pods\"} 1\n"));
        // Histogram labels merge before `le`; _sum/_count carry them too.
        assert!(text.contains("# TYPE redbox_rpc_ns histogram\n"));
        assert!(
            text.contains("redbox_rpc_ns_bucket{method=\"kube.Api/Create\",le=\"+Inf\"} 1\n"),
            "{text}"
        );
        assert!(text.contains("redbox_rpc_ns_sum{method=\"kube.Api/Create\"} 500\n"));
        assert!(text.contains("redbox_rpc_ns_count{method=\"kube.Api/Create\"} 1\n"));
    }

    #[test]
    fn exposition_order_is_deterministic_and_sorted() {
        let text = |order: &[(&str, &str)]| {
            let m = Metrics::new();
            for (f, l) in order {
                m.inc_with(f, &[("k", l)]);
            }
            m.inc("alpha");
            m.observe("zz.lat_ns", 5);
            render_prom(&m)
        };
        let a = text(&[("mid", "b"), ("mid", "a"), ("aaa", "x")]);
        let b = text(&[("aaa", "x"), ("mid", "a"), ("mid", "b")]);
        assert_eq!(a, b, "exposition must not depend on recording order");
        let fam_lines: Vec<&str> =
            a.lines().filter(|l| l.starts_with("# TYPE")).collect();
        let mut sorted = fam_lines.clone();
        sorted.sort();
        assert_eq!(fam_lines, sorted, "families render in sorted order");
        let mid_series: Vec<&str> =
            a.lines().filter(|l| l.starts_with("mid{")).collect();
        assert_eq!(mid_series, vec![r#"mid{k="a"} 1"#, r#"mid{k="b"} 1"#]);
    }

    #[test]
    fn json_snapshot_shape() {
        let m = Metrics::new();
        m.inc("c");
        m.set_gauge("g", 5);
        m.observe("h", 42);
        m.inc_with("c", &[("gvk", "pods")]);
        let v = render_json(&m);
        assert_eq!(v.get("counters").unwrap().opt_int("c"), Some(1));
        assert_eq!(
            v.get("counters").unwrap().opt_int(r#"c{gvk="pods"}"#),
            Some(1),
            "labelled series keep their canonical key in JSON"
        );
        assert_eq!(v.get("gauges").unwrap().opt_int("g"), Some(5));
        let h = v.get("hists").unwrap().get("h").unwrap();
        assert_eq!(h.opt_int("count"), Some(1));
        // The whole thing survives a JSON round trip.
        let text = crate::encoding::json::to_string(&v);
        assert!(crate::encoding::json::parse(&text).is_ok());
    }
}
