//! red-box server: a Unix-domain-socket RPC endpoint on the login node.
//!
//! "Red-box generates a Unix socket which allows data exchange among the
//! Kubernetes and Torque processes" (paper §III-B). Services register under
//! a name (`torque.Workload`); each accepted connection gets a handler
//! thread that reads request frames and dispatches `Service/Method` calls.

use super::proto::{read_frame, write_frame, Request, Response};
use crate::cluster::Metrics;
use crate::encoding::Value;
use crate::rt::{self, Shutdown};
use crate::util::{Error, Result};
use std::collections::HashMap;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::{Arc, RwLock};
use std::thread::JoinHandle;

/// One RPC service: a bundle of methods under a service name.
pub trait Service: Send + Sync {
    /// Handle `method` (the part after the `/`).
    fn call(&self, method: &str, body: &Value) -> Result<Value>;
}

/// Plain function services for tests / small endpoints.
pub struct FnService<F>(pub F);

impl<F> Service for FnService<F>
where
    F: Fn(&str, &Value) -> Result<Value> + Send + Sync,
{
    fn call(&self, method: &str, body: &Value) -> Result<Value> {
        (self.0)(method, body)
    }
}

type Registry = Arc<RwLock<HashMap<String, Arc<dyn Service>>>>;

/// The listening server. Dropping does NOT stop it; trigger the shutdown.
pub struct RedboxServer {
    path: PathBuf,
    registry: Registry,
    shutdown: Shutdown,
    accept_thread: Option<JoinHandle<()>>,
    metrics: Metrics,
    /// Clones of accepted streams so stop() can unblock reader threads.
    conns: Arc<std::sync::Mutex<Vec<UnixStream>>>,
}

impl RedboxServer {
    /// Bind and start accepting. Removes a stale socket file first (as
    /// red-box does on restart).
    pub fn start(
        path: impl AsRef<Path>,
        shutdown: Shutdown,
        metrics: Metrics,
    ) -> Result<RedboxServer> {
        let path = path.as_ref().to_path_buf();
        if path.exists() {
            std::fs::remove_file(&path)?;
        }
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let listener = UnixListener::bind(&path)
            .map_err(|e| Error::rpc(format!("bind {}: {e}", path.display())))?;
        // Accept loop polls so shutdown is honored promptly.
        listener.set_nonblocking(true)?;
        let registry: Registry = Arc::new(RwLock::new(HashMap::new()));
        let conns: Arc<std::sync::Mutex<Vec<UnixStream>>> =
            Arc::new(std::sync::Mutex::new(Vec::new()));
        let reg2 = registry.clone();
        let sd2 = shutdown.clone();
        let m2 = metrics.clone();
        let conns2 = conns.clone();
        let accept_thread = rt::spawn_named("redbox-accept", move || {
            loop {
                if sd2.is_triggered() {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false).ok();
                        if let Ok(clone) = stream.try_clone() {
                            conns2.lock().unwrap().push(clone);
                        }
                        let reg = reg2.clone();
                        let sd = sd2.clone();
                        let m = m2.clone();
                        rt::spawn_named("redbox-conn", move || {
                            handle_conn(stream, reg, sd, m);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        if sd2.wait_timeout(std::time::Duration::from_millis(2)) {
                            return;
                        }
                    }
                    Err(_) => return,
                }
            }
        });
        Ok(RedboxServer {
            path,
            registry,
            shutdown,
            accept_thread: Some(accept_thread),
            metrics,
            conns,
        })
    }

    /// Register (or replace) a service.
    pub fn register(&self, name: &str, svc: Arc<dyn Service>) {
        self.registry.write().unwrap().insert(name.to_string(), svc);
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Stop accepting and join the accept loop (open connections drain on
    /// their own when clients disconnect or shutdown trips mid-read).
    pub fn stop(&mut self) {
        self.shutdown.trigger();
        // Unblock per-connection reader threads waiting in read_frame.
        for conn in self.conns.lock().unwrap().drain(..) {
            let _ = conn.shutdown(std::net::Shutdown::Both);
        }
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(&self.path);
    }
}

impl Drop for RedboxServer {
    fn drop(&mut self) {
        if self.accept_thread.is_some() {
            self.stop();
        }
    }
}

fn handle_conn(mut stream: UnixStream, registry: Registry, shutdown: Shutdown, metrics: Metrics) {
    loop {
        if shutdown.is_triggered() {
            return;
        }
        let frame = match read_frame(&mut stream) {
            Ok(Some(v)) => v,
            Ok(None) => return, // client closed (or server stop() shut us down)
            Err(_) => return,   // transport error: drop connection
        };
        let resp = match Request::decode(&frame) {
            Ok(req) => {
                metrics.inc("redbox.requests");
                let t0 = std::time::Instant::now();
                let resp = dispatch(&req, &registry);
                metrics.observe("redbox.handle_ns", t0.elapsed().as_nanos() as u64);
                resp
            }
            Err(e) => Response::err(0, format!("bad request: {e}")),
        };
        if write_frame(&mut stream, &resp.encode()).is_err() {
            return;
        }
    }
}

fn dispatch(req: &Request, registry: &Registry) -> Response {
    let (service, method) = match req.split_method() {
        Ok(x) => x,
        Err(e) => return Response::err_typed(req.id, &e),
    };
    let svc = registry.read().unwrap().get(service).cloned();
    match svc {
        // Service failures travel typed (err_typed) so remote callers can
        // branch on is_not_found()/is_conflict() like in-process ones.
        Some(svc) => match svc.call(method, &req.body) {
            Ok(body) => Response::ok(req.id, body),
            Err(e) => Response::err_typed(req.id, &e),
        },
        None => Response::err(req.id, format!("unknown service `{service}`")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::redbox::client::RedboxClient;

    fn sock_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("hpcorc-test-{tag}-{}.sock", std::process::id()))
    }

    #[test]
    fn echo_service_roundtrip() {
        let sd = Shutdown::new();
        let mut srv =
            RedboxServer::start(sock_path("echo"), sd.clone(), Metrics::new()).unwrap();
        srv.register(
            "test.Echo",
            Arc::new(FnService(|method: &str, body: &Value| {
                Ok(Value::map().with("method", method).with("echo", body.clone()))
            })),
        );
        let client = RedboxClient::connect(srv.path()).unwrap();
        let out = client.call("test.Echo/Hi", Value::str("moo")).unwrap();
        assert_eq!(out.opt_str("method"), Some("Hi"));
        assert_eq!(out.get("echo"), Some(&Value::str("moo")));
        srv.stop();
    }

    #[test]
    fn unknown_service_and_error_paths() {
        let sd = Shutdown::new();
        let mut srv =
            RedboxServer::start(sock_path("unknown"), sd.clone(), Metrics::new()).unwrap();
        srv.register(
            "svc.Err",
            Arc::new(FnService(|_: &str, _: &Value| -> Result<Value> {
                Err(Error::wlm("queue not found"))
            })),
        );
        let client = RedboxClient::connect(srv.path()).unwrap();
        let err = client.call("nope.Svc/X", Value::Null).unwrap_err();
        assert!(err.to_string().contains("unknown service"));
        let err = client.call("svc.Err/X", Value::Null).unwrap_err();
        assert!(err.to_string().contains("queue not found"), "{err}");
        // Connection survives errors; a good call still works after.
        srv.register(
            "svc.Ok",
            Arc::new(FnService(|_: &str, _: &Value| Ok(Value::Bool(true)))),
        );
        assert_eq!(client.call("svc.Ok/X", Value::Null).unwrap(), Value::Bool(true));
        srv.stop();
    }

    #[test]
    fn concurrent_clients() {
        let sd = Shutdown::new();
        let mut srv =
            RedboxServer::start(sock_path("conc"), sd.clone(), Metrics::new()).unwrap();
        srv.register(
            "math.Add",
            Arc::new(FnService(|_: &str, body: &Value| {
                let a = body.req_int("a")?;
                let b = body.req_int("b")?;
                Ok(Value::Int(a + b))
            })),
        );
        let path = srv.path().to_path_buf();
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let p = path.clone();
                std::thread::spawn(move || {
                    let c = RedboxClient::connect(&p).unwrap();
                    for i in 0..50i64 {
                        let out = c
                            .call("math.Add/Run", Value::map().with("a", i).with("b", t as i64))
                            .unwrap();
                        assert_eq!(out, Value::Int(i + t as i64));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(srv.metrics().counter_value("redbox.requests"), 400);
        srv.stop();
    }

    #[test]
    fn stale_socket_replaced() {
        let path = sock_path("stale");
        std::fs::write(&path, b"stale").unwrap();
        let sd = Shutdown::new();
        let mut srv = RedboxServer::start(&path, sd, Metrics::new()).unwrap();
        srv.register("s.S", Arc::new(FnService(|_: &str, _: &Value| Ok(Value::Null))));
        let c = RedboxClient::connect(&path).unwrap();
        assert!(c.call("s.S/m", Value::Null).is_ok());
        srv.stop();
        assert!(!path.exists(), "socket removed on stop");
    }
}
