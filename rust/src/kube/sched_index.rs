//! sched_index: the scheduler's incrementally-maintained fit/score index
//! (PR 9).
//!
//! Replaces the per-cycle O(pods × nodes) filter/score scan with a
//! structure updated from informer deltas. Nodes are **bucketed by
//! signature** — the sorted (taints, labels) pair — because every
//! match-predicate the scheduler evaluates (taint toleration,
//! nodeSelector) depends only on that pair: one check admits or
//! eliminates a whole bucket. Inside a bucket, members are ordered by
//! **fullness** (the dominant-fraction of confirmed + reserved usage
//! over capacity), so a selection walk can stop as soon as the next
//! node's fullness exceeds the best score found — adding a pod can only
//! raise a node's dominant fraction (`dominant_fraction` is monotone
//! under component-wise growth, and the `min(1.0)` clamp preserves
//! that), hence `score(n) ≥ fullness(n)` and nothing past the cut can
//! win. The walk therefore returns *exactly* the node the brute-force
//! sort would have picked, including the name tie-break, in
//! O(buckets + log n + matches-walked) instead of O(n log n).
//!
//! Usage is tracked in two maps, both keyed by pod name:
//!
//! * `confirmed` — bindings observed through the informer (pods with a
//!   `nodeName` in a non-terminal phase). The informer echo is the only
//!   thing that moves usage here.
//! * `reserved` — placements this scheduler made that the API has not
//!   echoed back yet. [`SchedIndex::reserve`] charges capacity the
//!   moment a node is chosen so neither later pods in the same cycle
//!   nor later cycles (while an async commit is in flight) double-place
//!   against it; the echo converts the reservation into confirmed
//!   usage, and a failed bind [`SchedIndex::unreserve`]s so the pod —
//!   still Pending in the cache — simply requeues.
//!
//! A `Resync` from either informer (epoch bump after stream loss)
//! triggers [`SchedIndex::rebuild`]: derived state is discarded and
//! reconstructed from the caches, converging to the same fixed point a
//! fresh start would reach. Reservations survive a rebuild *unless* the
//! relist already shows the pod bound (then the confirmed entry
//! supersedes) — an in-flight commit is the one thing the caches cannot
//! know about.

use super::api::{KubeObject, NodeView, PodPhase, PodView, KIND_NODE, KIND_POD};
use super::informer::{Informer, InformerEvent, SharedInformerFactory};
use crate::cluster::{Metrics, Resources};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Mutex;

/// A node's match signature: the sorted taint set and sorted label
/// pairs. Taint toleration and nodeSelector matching are functions of
/// the signature alone, so nodes sharing one are interchangeable for
/// filtering.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct Signature {
    taints: Vec<String>,
    labels: Vec<(String, String)>,
}

impl Signature {
    fn of(node: &NodeView) -> Signature {
        let mut taints = node.taints.clone();
        taints.sort();
        taints.dedup();
        let mut labels = node.labels.clone();
        labels.sort();
        labels.dedup();
        Signature { taints, labels }
    }

    /// The pod tolerates every taint in this signature.
    fn tolerated_by(&self, pod: &PodView) -> bool {
        self.taints.iter().all(|t| pod.tolerations.contains(t))
    }

    /// Every nodeSelector pair matches a label in this signature.
    fn selected_by(&self, pod: &PodView) -> bool {
        pod.node_selector
            .iter()
            .all(|(k, v)| self.labels.iter().any(|(nk, nv)| nk == k && nv == v))
    }
}

/// Fullness sort key: `dominant_fraction` is in `0..=1`, and
/// `f64::to_bits` is order-preserving for non-negative floats, so the
/// bit pattern sorts identically to the float without `Ord` gymnastics.
fn frac_bits(used: &Resources, capacity: &Resources) -> u64 {
    used.dominant_fraction(capacity).to_bits()
}

struct NodeEntry {
    view: NodeView,
    sig: Signature,
    /// Confirmed + reserved usage on this node.
    used: Resources,
}

#[derive(Default)]
struct IndexState {
    nodes: BTreeMap<String, NodeEntry>,
    /// Only ready, uncordoned nodes appear here, ordered within each
    /// bucket by `(fullness bits, name)`.
    buckets: BTreeMap<Signature, BTreeSet<(u64, String)>>,
    /// pod → (node, requests): usage observed through the informer.
    confirmed: BTreeMap<String, (String, Resources)>,
    /// pod → (node, requests): placements awaiting the API echo.
    reserved: BTreeMap<String, (String, Resources)>,
    /// Nodes excluded from every bucket, by reason (maintained
    /// incrementally so the failure diagnosis never re-walks nodes).
    not_ready: usize,
    cordoned: usize,
}

/// Per-predicate elimination counts for a pod no node could take — the
/// data behind the k8s `0/N nodes available: ...` FailedScheduling
/// message, derived from bucket checks instead of a per-node re-walk.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct Eliminations {
    pub total: usize,
    pub not_ready: usize,
    pub cordoned: usize,
    pub tainted: usize,
    pub selector: usize,
    pub no_fit: usize,
}

impl Eliminations {
    /// The FailedScheduling note. Byte-identical to the scheduler's
    /// historical `losing_predicate` walk (regression-tested there).
    pub fn message(&self) -> String {
        let mut parts = Vec::new();
        for (count, what) in [
            (self.not_ready, "node(s) were not ready"),
            (self.cordoned, "node(s) were unschedulable"),
            (self.tainted, "node(s) had untolerated taints"),
            (self.selector, "node(s) didn't match the nodeSelector"),
            (self.no_fit, "node(s) had insufficient resources"),
        ] {
            if count > 0 {
                parts.push(format!("{count} {what}"));
            }
        }
        if parts.is_empty() {
            parts.push("no nodes registered".to_string());
        }
        format!("0/{} nodes available: {}", self.total, parts.join(", "))
    }

    /// Low-cardinality outcome label: the predicate that eliminated the
    /// most nodes (first wins ties, in filter order).
    pub fn outcome(&self) -> &'static str {
        if self.total == 0 {
            return "no_nodes";
        }
        let ranked = [
            (self.not_ready, "not_ready"),
            (self.cordoned, "cordoned"),
            (self.tainted, "untolerated_taints"),
            (self.selector, "selector_mismatch"),
            (self.no_fit, "insufficient_resources"),
        ];
        let max = ranked.iter().map(|(c, _)| *c).max().unwrap_or(0);
        ranked.iter().find(|(c, _)| *c == max).map(|(_, l)| *l).unwrap_or("no_nodes")
    }
}

/// Detach a node from the index (bucket membership + exclusion
/// counters), returning its entry so usage can be edited and the node
/// re-[`attach`]ed.
fn detach(st: &mut IndexState, name: &str) -> Option<NodeEntry> {
    let e = st.nodes.remove(name)?;
    if !e.view.ready {
        st.not_ready -= 1;
    } else if e.view.unschedulable {
        st.cordoned -= 1;
    } else {
        let key = (frac_bits(&e.used, &e.view.capacity), name.to_string());
        if let Some(b) = st.buckets.get_mut(&e.sig) {
            b.remove(&key);
            if b.is_empty() {
                st.buckets.remove(&e.sig);
            }
        }
    }
    Some(e)
}

fn attach(st: &mut IndexState, e: NodeEntry) {
    if !e.view.ready {
        st.not_ready += 1;
    } else if e.view.unschedulable {
        st.cordoned += 1;
    } else {
        let key = (frac_bits(&e.used, &e.view.capacity), e.view.name.clone());
        st.buckets.entry(e.sig.clone()).or_default().insert(key);
    }
    st.nodes.insert(e.view.name.clone(), e);
}

/// Adjust a node's tracked usage (no-op for unknown nodes: their usage
/// is recomputed from the pod maps when they appear).
fn charge(st: &mut IndexState, node: &str, delta: Resources, add: bool) {
    let Some(mut e) = detach(st, node) else { return };
    e.used = if add { e.used + delta } else { e.used.saturating_sub(&delta) };
    attach(st, e);
}

/// Total usage the pod maps attribute to `node` — seeds a node that
/// (re)appears after its pods were already known.
fn usage_on(st: &IndexState, node: &str) -> Resources {
    let mut total = Resources::ZERO;
    for (n, r) in st.confirmed.values() {
        if n == node {
            total += *r;
        }
    }
    for (n, r) in st.reserved.values() {
        if n == node {
            total += *r;
        }
    }
    total
}

fn apply_node(st: &mut IndexState, obj: &KubeObject, deleted: bool) {
    let prev = detach(st, &obj.meta.name);
    if deleted {
        return;
    }
    // Undecodable nodes stay out of the index, exactly as the cycle's
    // `filter_map(NodeView::from_object(..).ok())` skipped them.
    let Ok(view) = NodeView::from_object(obj) else { return };
    let used = prev.map(|e| e.used).unwrap_or_else(|| usage_on(st, &view.name));
    attach(st, NodeEntry { sig: Signature::of(&view), used, view });
}

/// Fold one pod's cache state into the usage maps. `bound` is its
/// (node, requests) when it holds a node in a non-terminal phase.
fn apply_pod_state(st: &mut IndexState, pod: &str, bound: Option<(String, Resources)>) {
    // A confirmed binding (or the pod vanishing) settles any in-flight
    // reservation; a still-Pending echo (e.g. a label update before the
    // bind lands) must NOT release it.
    if bound.is_some() {
        if let Some((n, r)) = st.reserved.remove(pod) {
            charge(st, &n, r, false);
        }
    }
    let prev = st.confirmed.remove(pod);
    if prev == bound {
        if let Some(b) = prev {
            st.confirmed.insert(pod.to_string(), b);
        }
        return;
    }
    if let Some((n, r)) = prev {
        charge(st, &n, r, false);
    }
    if let Some((n, r)) = bound {
        charge(st, &n, r, true);
        st.confirmed.insert(pod.to_string(), (n, r));
    }
}

fn apply_pod(st: &mut IndexState, obj: &KubeObject, deleted: bool) {
    if deleted {
        if let Some((n, r)) = st.reserved.remove(&obj.meta.name) {
            charge(st, &n, r, false);
        }
        apply_pod_state(st, &obj.meta.name, None);
        return;
    }
    let bound = PodView::from_object(obj).ok().and_then(|v| match (&v.node_name, v.phase) {
        (Some(n), phase) if !phase.terminal() => Some((n.clone(), v.requests)),
        _ => None,
    });
    apply_pod_state(st, &obj.meta.name, bound);
}

/// The index handle. Interior-mutable and `Sync`: the scheduling cycle
/// and the background bind committer share one `Arc<SchedIndex>`.
pub struct SchedIndex {
    nodes: Informer,
    pods: Informer,
    rx: Mutex<Receiver<InformerEvent>>,
    state: Mutex<IndexState>,
    metrics: Metrics,
}

impl SchedIndex {
    /// Subscribes to the factory's node and pod informers (PR 4
    /// machinery): the current caches replay as `Applied` events, then
    /// live deltas stream — [`SchedIndex::refresh`] drains them.
    pub fn new(informers: &SharedInformerFactory, metrics: Metrics) -> SchedIndex {
        let nodes = informers.informer(KIND_NODE);
        let pods = informers.informer(KIND_POD);
        let (tx, rx) = channel();
        nodes.subscribe_with(tx.clone());
        pods.subscribe_with(tx);
        SchedIndex {
            nodes,
            pods,
            rx: Mutex::new(rx),
            state: Mutex::new(IndexState::default()),
            metrics,
        }
    }

    /// Drain pending informer deltas into the index. O(log n) per
    /// delta; a `Resync` from either informer discards the drained
    /// batch and rebuilds from the caches instead (they are already
    /// past every queued event).
    pub fn refresh(&self) {
        let events: Vec<InformerEvent> = {
            let rx = self.rx.lock().unwrap();
            let mut v = Vec::new();
            while let Ok(ev) = rx.try_recv() {
                v.push(ev);
            }
            v
        };
        if events.iter().any(|e| matches!(e, InformerEvent::Resync { .. })) {
            self.rebuild();
            return;
        }
        let mut st = self.state.lock().unwrap();
        for ev in &events {
            let t0 = std::time::Instant::now();
            match ev {
                InformerEvent::Applied(o) if o.kind == KIND_NODE => apply_node(&mut st, o, false),
                InformerEvent::Deleted(o) if o.kind == KIND_NODE => apply_node(&mut st, o, true),
                InformerEvent::Applied(o) if o.kind == KIND_POD => apply_pod(&mut st, o, false),
                InformerEvent::Deleted(o) if o.kind == KIND_POD => apply_pod(&mut st, o, true),
                _ => {}
            }
            self.metrics.observe("kube.sched.index_update_ns", t0.elapsed().as_nanos() as u64);
        }
    }

    /// Full reconstruction from the informer caches — the Resync
    /// contract: event-derived state must converge to what a fresh
    /// start over the same caches would hold. Reservations are
    /// re-applied only where the relist does not already show the pod
    /// bound (in-flight commits are invisible to any cache).
    pub fn rebuild(&self) {
        let t0 = std::time::Instant::now();
        let mut st = self.state.lock().unwrap();
        let reserved = std::mem::take(&mut st.reserved);
        *st = IndexState::default();
        self.nodes.read(|objs| {
            for o in objs.values() {
                apply_node(&mut st, o, false);
            }
        });
        self.pods.read(|objs| {
            for o in objs.values() {
                apply_pod(&mut st, o, false);
            }
        });
        for (pod, (node, req)) in reserved {
            if !st.confirmed.contains_key(&pod) {
                charge(&mut st, &node, req, true);
                st.reserved.insert(pod, (node, req));
            }
        }
        drop(st);
        self.metrics.observe("kube.sched.index_update_ns", t0.elapsed().as_nanos() as u64);
    }

    /// The least-allocated node that fits `pod` — exactly the node the
    /// brute-force filter+score pass picks (same score, same name
    /// tie-break) — or the per-predicate elimination counts when no
    /// node can take it.
    pub fn select(&self, pod: &PodView) -> std::result::Result<String, Eliminations> {
        let st = self.state.lock().unwrap();
        let mut best: Option<(f64, String)> = None;
        for (sig, members) in &st.buckets {
            if !sig.tolerated_by(pod) || !sig.selected_by(pod) {
                continue;
            }
            for (bits, name) in members {
                let fullness = f64::from_bits(*bits);
                if let Some((best_score, _)) = &best {
                    // Everything later in the bucket is at least this
                    // full, and score(n) ≥ fullness(n): nothing past
                    // here can beat the incumbent. Equal fullness must
                    // still be walked for the name tie-break.
                    if fullness > *best_score {
                        break;
                    }
                }
                let Some(e) = st.nodes.get(name) else { continue };
                let free = e.view.capacity.saturating_sub(&e.used);
                if !free.fits(&pod.requests) {
                    continue;
                }
                let score = (e.used + pod.requests).dominant_fraction(&e.view.capacity);
                let wins = match &best {
                    Some((bs, bn)) => score < *bs || (score == *bs && name < bn),
                    None => true,
                };
                if wins {
                    best = Some((score, name.clone()));
                }
            }
        }
        match best {
            Some((_, name)) => Ok(name),
            None => Err(self.eliminations_locked(&st, pod)),
        }
    }

    /// Elimination counts for a pod `select` found no node for. Only
    /// valid in that case: every node in a matching bucket is then
    /// known to have failed the fit check, so whole buckets are counted
    /// without revisiting members.
    fn eliminations_locked(&self, st: &IndexState, pod: &PodView) -> Eliminations {
        let mut e = Eliminations {
            total: st.nodes.len(),
            not_ready: st.not_ready,
            cordoned: st.cordoned,
            ..Eliminations::default()
        };
        for (sig, members) in &st.buckets {
            if !sig.tolerated_by(pod) {
                e.tainted += members.len();
            } else if !sig.selected_by(pod) {
                e.selector += members.len();
            } else {
                e.no_fit += members.len();
            }
        }
        e
    }

    /// Charge `requests` against `node` for `pod` ahead of the bind
    /// commit. Idempotent: an already-reserved or already-confirmed pod
    /// is left alone (returns false).
    pub fn reserve(&self, pod: &str, node: &str, requests: Resources) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.reserved.contains_key(pod) || st.confirmed.contains_key(pod) {
            return false;
        }
        charge(&mut st, node, requests, true);
        st.reserved.insert(pod.to_string(), (node.to_string(), requests));
        true
    }

    /// Release a reservation whose bind failed (or was skipped). The
    /// pod is still Pending in every cache, so the next cycle requeues
    /// it naturally. Idempotent.
    pub fn unreserve(&self, pod: &str) -> bool {
        let mut st = self.state.lock().unwrap();
        match st.reserved.remove(pod) {
            Some((n, r)) => {
                charge(&mut st, &n, r, false);
                true
            }
            None => false,
        }
    }

    pub fn is_reserved(&self, pod: &str) -> bool {
        self.state.lock().unwrap().reserved.contains_key(pod)
    }

    /// Names of all pods with in-flight reservations (pending-pod
    /// selection must skip them).
    pub fn reserved_pods(&self) -> BTreeSet<String> {
        self.state.lock().unwrap().reserved.keys().cloned().collect()
    }

    pub fn node_count(&self) -> usize {
        self.state.lock().unwrap().nodes.len()
    }

    /// Tracked usage (confirmed + reserved) for a node, for tests and
    /// diagnostics.
    pub fn used_on(&self, node: &str) -> Option<Resources> {
        self.state.lock().unwrap().nodes.get(node).map(|e| e.used)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kube::apiserver::ApiServer;
    use crate::kube::SharedInformerFactory;

    fn setup() -> (ApiServer, SharedInformerFactory, SchedIndex) {
        let api = ApiServer::new(Metrics::new());
        let informers = SharedInformerFactory::new(api.client(), Metrics::new());
        let index = SchedIndex::new(&informers, Metrics::new());
        (api, informers, index)
    }

    fn sync(informers: &SharedInformerFactory, index: &SchedIndex) {
        informers.informer(KIND_NODE).sync().unwrap();
        informers.informer(KIND_POD).sync().unwrap();
        index.refresh();
    }

    fn probe(name: &str, cpu_milli: u64) -> PodView {
        PodView::from_object(&PodView::build(
            name,
            "img",
            Resources::new(cpu_milli, 1 << 30, 0),
            &[],
        ))
        .unwrap()
    }

    /// Reference implementation: the scheduler's original filter+score
    /// pass, for differential checks against `select`.
    fn brute_select(api: &ApiServer, index: &SchedIndex, pod: &PodView) -> Option<String> {
        let nodes: Vec<NodeView> = api
            .client()
            .list(KIND_NODE, &crate::kube::ListOptions::all())
            .unwrap()
            .items
            .iter()
            .filter_map(|o| NodeView::from_object(o).ok())
            .collect();
        let mut candidates: Vec<(&NodeView, Resources)> = nodes
            .iter()
            .filter(|n| n.ready)
            .filter(|n| !n.unschedulable)
            .filter(|n| n.taints.iter().all(|t| pod.tolerations.contains(t)))
            .filter(|n| {
                pod.node_selector
                    .iter()
                    .all(|(k, v)| n.labels.iter().any(|(nk, nv)| nk == k && nv == v))
            })
            .filter_map(|n| {
                let u = index.used_on(&n.name).unwrap_or(Resources::ZERO);
                n.capacity.saturating_sub(&u).fits(&pod.requests).then_some((n, u))
            })
            .collect();
        candidates.sort_by(|(na, ua), (nb, ub)| {
            let fa = (*ua + pod.requests).dominant_fraction(&na.capacity);
            let fb = (*ub + pod.requests).dominant_fraction(&nb.capacity);
            fa.partial_cmp(&fb).unwrap().then(na.name.cmp(&nb.name))
        });
        candidates.first().map(|(n, _)| n.name.clone())
    }

    #[test]
    fn select_matches_brute_force_over_mixed_fleet() {
        let (api, informers, index) = setup();
        // A mixed fleet: varying capacity, a tainted node, a labelled
        // node, a cordoned node, a not-ready node.
        for (i, cores) in [4u32, 8, 8, 16, 2].iter().enumerate() {
            api.create(NodeView::build(&format!("n{i}"), Resources::cores(*cores, 32 << 30), &[]))
                .unwrap();
        }
        api.create(NodeView::build("t0", Resources::cores(64, 64 << 30), &["virtual-kubelet"]))
            .unwrap();
        let mut labelled = NodeView::build("l0", Resources::cores(8, 32 << 30), &[]);
        labelled.meta.set_label("zone", "a");
        api.create(labelled).unwrap();
        api.update_status(KIND_NODE, "n4", |o| {
            o.spec.insert("unschedulable", true);
        })
        .unwrap();
        api.update_status(KIND_NODE, "n3", |o| {
            o.status.insert("phase", "NotReady");
        })
        .unwrap();
        // Pre-existing bound pods skew the usage map.
        for (i, node) in [("a", "n0"), ("b", "n1"), ("c", "n1")] {
            let mut pod =
                PodView::build(&format!("pre-{i}"), "img", Resources::new(1500, 1 << 30, 0), &[]);
            pod.spec.insert("nodeName", node);
            api.create(pod).unwrap();
        }
        sync(&informers, &index);
        assert_eq!(index.node_count(), 7);
        for cpu in [100, 1000, 3000, 7000, 9000] {
            let pod = probe(&format!("probe-{cpu}"), cpu);
            assert_eq!(
                index.select(&pod).ok(),
                brute_select(&api, &index, &pod),
                "divergence at {cpu}m"
            );
        }
    }

    #[test]
    fn eliminations_count_every_predicate() {
        let (api, informers, index) = setup();
        api.create(NodeView::build("ready", Resources::cores(1, 1 << 30), &[])).unwrap();
        api.create(NodeView::build("tainted", Resources::cores(8, 32 << 30), &["gpu-only"]))
            .unwrap();
        api.create(NodeView::build("down", Resources::cores(8, 32 << 30), &[])).unwrap();
        api.update_status(KIND_NODE, "down", |o| {
            o.status.insert("phase", "NotReady");
        })
        .unwrap();
        api.create(NodeView::build("fenced", Resources::cores(8, 32 << 30), &[])).unwrap();
        api.update_status(KIND_NODE, "fenced", |o| {
            o.spec.insert("unschedulable", true);
        })
        .unwrap();
        sync(&informers, &index);
        let why = index.select(&probe("big", 4000)).unwrap_err();
        assert_eq!(
            why,
            Eliminations {
                total: 4,
                not_ready: 1,
                cordoned: 1,
                tainted: 1,
                selector: 0,
                no_fit: 1,
            }
        );
        assert_eq!(
            why.message(),
            "0/4 nodes available: 1 node(s) were not ready, 1 node(s) were unschedulable, \
             1 node(s) had untolerated taints, 1 node(s) had insufficient resources"
        );
        let (_, _, empty_index) = setup();
        assert_eq!(
            empty_index.select(&probe("p", 1)).unwrap_err().message(),
            "0/0 nodes available: no nodes registered"
        );
    }

    #[test]
    fn reserve_confirm_unreserve_lifecycle() {
        let (api, informers, index) = setup();
        api.create(NodeView::build("w1", Resources::cores(2, 32 << 30), &[])).unwrap();
        sync(&informers, &index);
        let req = Resources::new(1500, 1 << 30, 0);
        assert!(index.reserve("p1", "w1", req));
        assert!(!index.reserve("p1", "w1", req), "double reserve is a no-op");
        assert_eq!(index.used_on("w1").unwrap().cpu_milli, 1500);
        // While reserved, nothing else fits.
        assert!(index.select(&probe("p2", 1000)).is_err());
        // The informer echo (pod bound) converts the reservation.
        let mut pod = PodView::build("p1", "img", req, &[]);
        pod.spec.insert("nodeName", "w1");
        api.create(pod).unwrap();
        sync(&informers, &index);
        assert!(!index.is_reserved("p1"));
        assert_eq!(index.used_on("w1").unwrap().cpu_milli, 1500, "no double charge");
        // Terminal phase releases confirmed usage.
        api.update_status(KIND_POD, "p1", |o| {
            o.status.insert("phase", "Succeeded");
        })
        .unwrap();
        sync(&informers, &index);
        assert_eq!(index.used_on("w1").unwrap().cpu_milli, 0);
        // And a failed bind path: reserve then unreserve restores all.
        assert!(index.reserve("p3", "w1", req));
        assert!(index.unreserve("p3"));
        assert!(!index.unreserve("p3"), "unreserve is idempotent");
        assert_eq!(index.used_on("w1").unwrap().cpu_milli, 0);
        assert!(index.select(&probe("p4", 2000)).is_ok());
    }

    #[test]
    fn node_churn_keeps_usage() {
        let (api, informers, index) = setup();
        api.create(NodeView::build("w1", Resources::cores(4, 32 << 30), &[])).unwrap();
        let mut pod = PodView::build("p1", "img", Resources::new(1000, 1 << 30, 0), &[]);
        pod.spec.insert("nodeName", "w1");
        api.create(pod).unwrap();
        sync(&informers, &index);
        assert_eq!(index.used_on("w1").unwrap().cpu_milli, 1000);
        // A node status heartbeat must not reset tracked usage.
        api.update_status(KIND_NODE, "w1", |o| {
            o.status.insert("heartbeat", 1u64);
        })
        .unwrap();
        sync(&informers, &index);
        assert_eq!(index.used_on("w1").unwrap().cpu_milli, 1000);
        // Delete + recreate: usage is recomputed from the pod maps.
        api.delete(KIND_NODE, "w1").unwrap();
        sync(&informers, &index);
        assert_eq!(index.node_count(), 0);
        api.create(NodeView::build("w1", Resources::cores(4, 32 << 30), &[])).unwrap();
        sync(&informers, &index);
        assert_eq!(index.used_on("w1").unwrap().cpu_milli, 1000);
    }

    #[test]
    fn rebuild_reaches_fresh_start_fixed_point() {
        let (api, informers, index) = setup();
        api.create(NodeView::build("w1", Resources::cores(8, 32 << 30), &[])).unwrap();
        api.create(NodeView::build("w2", Resources::cores(8, 32 << 30), &[])).unwrap();
        let mut pod = PodView::build("p1", "img", Resources::new(2000, 1 << 30, 0), &[]);
        pod.spec.insert("nodeName", "w2");
        api.create(pod).unwrap();
        sync(&informers, &index);
        index.reserve("inflight", "w1", Resources::new(500, 0, 0));
        index.rebuild();
        // Confirmed usage rebuilt from the cache; the in-flight
        // reservation survived (no cache can know about it yet).
        assert_eq!(index.used_on("w2").unwrap().cpu_milli, 2000);
        assert_eq!(index.used_on("w1").unwrap().cpu_milli, 500);
        assert!(index.is_reserved("inflight"));
        // Once the bind lands and echoes, rebuild drops the reservation
        // in favour of the confirmed entry — same totals as fresh start.
        let mut bound = PodView::build("inflight", "img", Resources::new(500, 0, 0), &[]);
        bound.spec.insert("nodeName", "w1");
        api.create(bound).unwrap();
        sync(&informers, &index);
        index.rebuild();
        assert!(!index.is_reserved("inflight"));
        assert_eq!(index.used_on("w1").unwrap().cpu_milli, 500);
    }
}
