//! Informer-layer cost: what does moving control loops off per-cycle
//! lists actually buy, at 1k and 10k objects?
//!
//! - **per-cycle full list** — the pre-PR-4 read every control loop paid
//!   per cycle (deep-clones every object out of the store);
//! - **per-cycle field-selected list** — the old kubelet read (server
//!   walks every object of the kind, returns one node's share);
//! - **informer cached list** — same result set off the shared cache;
//! - **informer indexed read** — the new kubelet read (`spec.nodeName`
//!   index: clones only the matching objects);
//! - **informer zero-copy scan** — the new scheduler read (decode in
//!   place, clone nothing);
//! - **event fan-out** — per-event cost of draining one watch delta into
//!   the cache and delivering it to 8 subscribers.
//! - **remote watch: streaming vs poll** (ISSUE 5) — idle RPC traffic
//!   over a fixed window and end-to-end event-delivery latency of the
//!   server-push streaming watch against the legacy poll fallback, over
//!   a real red-box socket.
//!
//! Ends with one JSON line per stat (`{"bench":...}`) for the perf
//! trajectory, including the acceptance ratio (cached read vs per-cycle
//! list at 10k — must be ≥10×) and the streaming idle-traffic floor
//! (must be zero RPCs).

use hpcorc::bench::{header, Bench, Stats};
use hpcorc::cluster::{Metrics, Resources};
use hpcorc::kube::{
    ApiClient, ApiServer, ListOptions, PodPhase, PodView, RemoteApi, SharedInformerFactory,
    WatchConfig, KIND_POD,
};
use hpcorc::redbox::RedboxServer;
use hpcorc::rt::Shutdown;
use std::sync::Arc;
use std::time::Duration;

const NODES: usize = 20;

fn setup(n: usize) -> ApiServer {
    let api = ApiServer::new(Metrics::new());
    for i in 0..n {
        let mut pod = PodView::build(
            &format!("pod-{i:06}"),
            "img.sif",
            Resources::new(100, 1 << 20, 0),
            &[],
        );
        pod.spec.insert("nodeName", format!("w{:02}", i % NODES));
        if i % 3 == 0 {
            pod.status.insert("phase", "Running");
        }
        api.create(pod).unwrap();
    }
    api
}

fn main() {
    println!("=== informer layer: cached reads vs per-cycle lists ===");
    println!("{}", header());
    let mut stats: Vec<Stats> = Vec::new();
    let mut full_list_10k = 0.0f64;
    let mut scan_10k = 0.0f64;
    let mut indexed_10k = 0.0f64;

    for n in [1_000usize, 10_000] {
        let api = setup(n);
        let client: Arc<dyn ApiClient> = api.client();
        let informers = SharedInformerFactory::new(client.clone(), Metrics::new());
        let pods = informers.informer(KIND_POD);
        pods.ensure_field_index("spec.nodeName");
        pods.sync().unwrap();

        // The pre-PR-4 control-loop read: one full list per cycle.
        let s = Bench::new(format!("per-cycle full list ({n})")).warmup(2).iters(15).run(|| {
            let list = client.list(KIND_POD, &ListOptions::all()).unwrap();
            assert_eq!(list.items.len(), n);
        });
        if n == 10_000 {
            full_list_10k = s.mean_ns;
        }
        stats.push(s);

        // The old kubelet read: server-side field selector (walks all n).
        stats.push(
            Bench::new(format!("per-cycle field-selected list ({n})"))
                .warmup(2)
                .iters(15)
                .run(|| {
                    let opts = ListOptions::all().with_field("spec.nodeName", "w00");
                    let list = client.list(KIND_POD, &opts).unwrap();
                    assert_eq!(list.items.len(), n / NODES);
                }),
        );

        // Cached equivalents.
        stats.push(Bench::new(format!("informer cached list ({n})")).warmup(2).iters(15).run(
            || {
                pods.sync().unwrap();
                assert_eq!(pods.list().len(), n);
            },
        ));
        let s = Bench::new(format!("informer indexed read ({n})")).warmup(2).iters(15).run(
            || {
                pods.sync().unwrap();
                assert_eq!(pods.list_by_field("spec.nodeName", "w00").len(), n / NODES);
            },
        );
        if n == 10_000 {
            indexed_10k = s.mean_ns;
        }
        stats.push(s);
        let s = Bench::new(format!("informer zero-copy scan ({n})")).warmup(2).iters(15).run(
            || {
                pods.sync().unwrap();
                let running = pods.read(|objs| {
                    objs.values()
                        .filter(|o| {
                            PodPhase::parse(o.status.opt_str("phase").unwrap_or(""))
                                == PodPhase::Running
                        })
                        .count()
                });
                assert_eq!(running, n.div_ceil(3));
            },
        );
        if n == 10_000 {
            scan_10k = s.mean_ns;
        }
        stats.push(s);
    }

    // Event fan-out: one write → sync → delivery to 8 subscribers.
    let api = setup(1_000);
    let informers = SharedInformerFactory::new(api.client(), Metrics::new());
    let pods = informers.informer(KIND_POD);
    pods.sync().unwrap();
    let subs: Vec<_> = (0..8).map(|_| pods.subscribe()).collect();
    for rx in &subs {
        let _ = rx.try_iter().count(); // drain the replay
    }
    let mut flip = 0u64;
    stats.push(Bench::new("event fan-out (8 subscribers)").warmup(100).iters(2000).run(
        || {
            flip += 1;
            api.update_status(KIND_POD, "pod-000000", |o| {
                o.status.insert("beat", flip);
            })
            .unwrap();
            pods.sync().unwrap();
            for rx in &subs {
                assert!(rx.try_iter().count() >= 1, "every subscriber sees the event");
            }
        },
    ));

    // Remote watch over a real socket: idle traffic + delivery latency,
    // streaming vs the poll fallback (ISSUE 5).
    let sd = Shutdown::new();
    let sock = std::env::temp_dir()
        .join(format!("hpcorc-bench-informer-{}.sock", std::process::id()));
    let server_metrics = Metrics::new();
    let mut srv = RedboxServer::start(&sock, sd.clone(), server_metrics.clone()).unwrap();
    let api = ApiServer::new(Metrics::new());
    srv.register("kube.Api", api.rpc_service());
    api.create(PodView::build("wp", "img.sif", Resources::new(100, 1 << 20, 0), &[]))
        .unwrap();
    const IDLE_WINDOW_MS: u64 = 300;
    for (label, force_poll) in [("streaming", false), ("poll", true)] {
        let remote = RemoteApi::connect(&sock)
            .unwrap()
            .with_watch_config(WatchConfig { force_poll, ..WatchConfig::default() });
        let rx = ApiClient::watch(&remote, Some(KIND_POD), api.current_version()).unwrap();
        // Idle traffic: requests crossing the socket while nothing happens.
        let base = server_metrics.counter_value("redbox.requests");
        std::thread::sleep(Duration::from_millis(IDLE_WINDOW_MS));
        let idle_rpcs = server_metrics.counter_value("redbox.requests") - base;
        println!(
            "{{\"bench\":\"remote watch idle traffic ({label})\",\"window_ms\":{IDLE_WINDOW_MS},\"rpcs\":{idle_rpcs}}}"
        );
        if !force_poll {
            assert_eq!(
                idle_rpcs, 0,
                "an idle streaming watch must issue zero RPCs (got {idle_rpcs})"
            );
        }
        // End-to-end delivery latency: write → pushed/polled event seen.
        let mut beat = 0i64;
        stats.push(
            Bench::new(format!("remote watch event delivery ({label})"))
                .warmup(10)
                .iters(200)
                .run(|| {
                    beat += 1;
                    api.update_status(KIND_POD, "wp", |o| {
                        o.status.insert("beat", beat as u64);
                    })
                    .unwrap();
                    loop {
                        match rx.recv_timeout(Duration::from_secs(5)) {
                            Ok(ev) => {
                                if ev.object().status.opt_int("beat") == Some(beat) {
                                    break;
                                }
                            }
                            Err(e) => panic!("watch ({label}) died: {e}"),
                        }
                    }
                }),
        );
    }
    srv.stop();

    println!();
    for s in &stats {
        println!("{}", s.json());
    }
    // Acceptance (ISSUE 4): the cached read path must be ≥10× cheaper
    // than a per-cycle list at 10k objects.
    let scan_ratio = full_list_10k / scan_10k.max(1.0);
    let indexed_ratio = full_list_10k / indexed_10k.max(1.0);
    println!(
        "{{\"bench\":\"informer speedup vs full list (10k)\",\"zero_copy_scan_x\":{scan_ratio:.1},\"indexed_read_x\":{indexed_ratio:.1}}}"
    );
    assert!(
        scan_ratio >= 10.0,
        "cached zero-copy read must be >=10x cheaper than a per-cycle list at 10k \
         (got {scan_ratio:.1}x)"
    );
}
