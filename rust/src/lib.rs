//! # hpcorc — Container Orchestration on HPC Systems, reproduced
//!
//! A full-system reproduction of Zhou et al., *Container Orchestration on
//! HPC Systems* (CS.DC 2020): the **Torque-Operator** bridging a
//! Kubernetes-like orchestrator ([`kube`]) and a Torque/PBS-like HPC
//! workload manager ([`pbs`]), with a Slurm baseline ([`slurm`]) for the
//! WLM-Operator comparison, Singularity-style containers ([`singularity`]),
//! the red-box Unix-socket RPC bridge ([`redbox`]), and AOT-compiled
//! JAX/Pallas compute payloads executed from Rust via PJRT ([`runtime`]).
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! reproduction results. Python never runs on the request path: all
//! artifacts under `artifacts/` are produced once by `make artifacts`.

pub mod autoscale;
pub mod bench;
pub mod chaos;
pub mod cli;
pub mod cluster;
pub mod encoding;
pub mod hybrid;
pub mod kube;
pub mod kueue;
pub mod obs;
pub mod operator;
pub mod pbs;
pub mod redbox;
pub mod rt;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod singularity;
pub mod slurm;
pub mod util;
pub mod workload;

pub use util::{Error, Result};
