//! Preemption: evict borrowing or lower-priority gangs so a
//! within-nominal gang can take the quota it was promised.
//!
//! Two mechanisms, enabled per-ClusterQueue (the *incoming* gang's queue
//! decides what it may evict):
//!
//! - **reclaimWithinCohort** — cohort peers holding capacity beyond their
//!   nominal quota (borrowers) lose it back when the lender needs it;
//! - **withinClusterQueue** — lower-priority gangs admitted through the
//!   same queue make room for a higher-priority arrival.
//!
//! Victims are whole gangs (pod groups / multi-node WlmJobs are evicted
//! atomically — admitting gangs all-or-nothing and then evicting them one
//! member at a time would break the invariant the layer exists for).
//! Selection is a greedy search over a cloned [`Ledger`]: cheapest-to-kill
//! first (lowest priority, then newest), stopping as soon as the incoming
//! gang fits; if the search cannot make it fit, nothing is evicted.

use super::quota::Ledger;
use super::types::{
    set_condition, workload_terminal, ClusterQueueView, QueueResources, COND_ADMITTED,
    COND_EVICTED, COND_QUOTA_RESERVED, SCHEDULING_GATE,
};
use crate::kube::{ApiClient, EvictionMode, KIND_POD};
use crate::util::Result;

/// One admitted gang as the preemption search sees it.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmittedGang {
    /// Member objects: (kind, name).
    pub members: Vec<(String, String)>,
    /// ClusterQueue the gang's demand is charged to.
    pub queue: String,
    /// The raw queue-name label (LocalQueue it was submitted through).
    pub label: String,
    pub demand: QueueResources,
    pub priority: i64,
    /// Admission order proxy (min member uid): newer gangs evict first.
    pub uid: u64,
}

/// Pick gangs to evict so `demand` fits in `cq`; `None` when preemption
/// cannot clear the blockage (the gang keeps waiting instead of evicting
/// uselessly). Callers only invoke this for `Fit::BlockedWithinNominal`.
pub fn select_victims(
    ledger: &Ledger,
    admitted: &[AdmittedGang],
    cq: &ClusterQueueView,
    demand: &QueueResources,
    priority: i64,
) -> Option<Vec<AdmittedGang>> {
    if !cq.preemption.reclaim_within_cohort && !cq.preemption.within_queue {
        return None;
    }
    let mut candidates: Vec<&AdmittedGang> = admitted
        .iter()
        .filter(|g| {
            if g.queue == cq.name {
                cq.preemption.within_queue && g.priority < priority
            } else {
                cq.preemption.reclaim_within_cohort
                    && cq.cohort.is_some()
                    && ledger
                        .queue(&g.queue)
                        .map(|q| q.view.cohort == cq.cohort && q.is_borrowing())
                        .unwrap_or(false)
            }
        })
        .collect();
    // Cheapest victims first: lowest priority, then newest admission.
    candidates.sort_by(|a, b| a.priority.cmp(&b.priority).then(b.uid.cmp(&a.uid)));

    let mut scratch = ledger.clone();
    let mut victims: Vec<AdmittedGang> = Vec::new();
    for g in candidates {
        if scratch.fit(&cq.name, demand).admissible() {
            break;
        }
        // Reclaim only takes back borrowed capacity: once a peer is back
        // within nominal (in the simulated state), leave it alone.
        if g.queue != cq.name
            && !scratch.queue(&g.queue).map(|q| q.is_borrowing()).unwrap_or(false)
        {
            continue;
        }
        scratch.uncharge(&g.queue, &g.demand);
        victims.push(g.clone());
    }
    if scratch.fit(&cq.name, demand).admissible() && !victims.is_empty() {
        Some(victims)
    } else {
        None
    }
}

/// Evict one gang: flip its members back to suspended (conditions
/// `Admitted=False`, `QuotaReserved=False`, `Evicted=True`) and unbind
/// evicted pods so the node scheduler's capacity frees immediately. WLM
/// jobs already submitted over red-box are cancelled by the operator when
/// it observes the eviction (see `operator::core`).
///
/// Pod members go through the `pods/eviction` subresource in `Requeue`
/// mode — the server unbinds and re-gates atomically and enforces any
/// `PodDisruptionBudget` covering the victim. A budget refusal surfaces
/// as `DisruptionBudgetExceeded`; the admission loop treats it as "this
/// gang cannot be preempted this cycle", not as a hard error.
pub fn evict_gang(api: &dyn ApiClient, gang: &AdmittedGang) -> Result<()> {
    for (kind, name) in &gang.members {
        if kind == KIND_POD {
            // Finished between the cycle's snapshot and now: its result
            // (phase/exitCode/log) must survive — there is nothing left
            // to evict, and its charge is already released.
            if workload_terminal(&api.get(kind, name)?) {
                continue;
            }
            api.evict(
                name,
                &EvictionMode::Requeue {
                    gate: SCHEDULING_GATE.to_string(),
                },
            )?;
        }
        api.update_status(kind, name, &|o| {
            if workload_terminal(o) {
                return;
            }
            set_condition(&mut o.status, COND_ADMITTED, false);
            set_condition(&mut o.status, COND_QUOTA_RESERVED, false);
            set_condition(&mut o.status, COND_EVICTED, true);
            o.status.remove("clusterQueue");
        })?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kueue::types::{PreemptionPolicy, QueueOrdering};

    fn cq_view(
        name: &str,
        cohort: Option<&str>,
        nominal_nodes: u32,
        preemption: PreemptionPolicy,
    ) -> ClusterQueueView {
        ClusterQueueView::from_object(&ClusterQueueView::build_full(
            name,
            cohort,
            QueueResources::nodes(nominal_nodes),
            None,
            QueueOrdering::Fifo,
            preemption,
        ))
        .unwrap()
    }

    fn gang(name: &str, queue: &str, nodes: u32, priority: i64, uid: u64) -> AdmittedGang {
        AdmittedGang {
            members: vec![(KIND_POD.to_string(), name.to_string())],
            queue: queue.to_string(),
            label: queue.to_string(),
            demand: QueueResources { nodes, cpu_milli: 0, mem_bytes: 0 },
            priority,
            uid,
        }
    }

    fn demand(nodes: u32) -> QueueResources {
        QueueResources { nodes, cpu_milli: 0, mem_bytes: 0 }
    }

    #[test]
    fn reclaims_borrowing_peer() {
        let reclaim = PreemptionPolicy { reclaim_within_cohort: true, within_queue: false };
        let a = cq_view("a", Some("pool"), 2, PreemptionPolicy::default());
        let b = cq_view("b", Some("pool"), 2, reclaim);
        let mut ledger = Ledger::new(vec![a, b.clone()]);
        let borrower = gang("big", "a", 3, 0, 5);
        ledger.charge("a", &borrower.demand);
        let victims =
            select_victims(&ledger, &[borrower.clone()], &b, &demand(2), 0).expect("reclaims");
        assert_eq!(victims, vec![borrower]);
    }

    #[test]
    fn does_not_evict_peer_within_nominal() {
        let reclaim = PreemptionPolicy { reclaim_within_cohort: true, within_queue: false };
        let a = cq_view("a", Some("pool"), 2, PreemptionPolicy::default());
        let b = cq_view("b", Some("pool"), 2, reclaim);
        let mut ledger = Ledger::new(vec![a, b.clone()]);
        // a uses exactly its nominal — not borrowing, untouchable.
        let within = gang("fair", "a", 2, 0, 5);
        ledger.charge("a", &within.demand);
        assert!(ledger.fit("b", &demand(2)).admissible(), "b still fits without eviction");
        // Over-subscribe the cohort from a THIRD queue to force blockage.
        let c = cq_view("c", Some("pool"), 0, PreemptionPolicy::default());
        let mut ledger = Ledger::new(vec![
            cq_view("a", Some("pool"), 2, PreemptionPolicy::default()),
            b.clone(),
            c,
        ]);
        ledger.charge("a", &demand(2)); // within nominal
        ledger.charge("c", &demand(2)); // c's nominal is 0: pure borrower
        let fair = gang("fair", "a", 2, 0, 1);
        let borrower = gang("freeloader", "c", 2, 0, 2);
        let victims = select_victims(
            &ledger,
            &[fair.clone(), borrower.clone()],
            &b,
            &demand(2),
            0,
        )
        .expect("evicts only the borrower");
        assert_eq!(victims, vec![borrower], "the within-nominal gang survives");
    }

    #[test]
    fn within_queue_priority_eviction_prefers_cheapest() {
        let pol = PreemptionPolicy { reclaim_within_cohort: false, within_queue: true };
        let q = cq_view("q", None, 2, pol);
        let mut ledger = Ledger::new(vec![q.clone()]);
        let low_old = gang("low-old", "q", 1, 1, 1);
        let low_new = gang("low-new", "q", 1, 1, 9);
        ledger.charge("q", &low_old.demand);
        ledger.charge("q", &low_new.demand);
        // 1-node high-priority arrival: only ONE victim needed — the
        // newest of the lowest-priority gangs.
        let victims = select_victims(
            &ledger,
            &[low_old.clone(), low_new.clone()],
            &q,
            &demand(1),
            10,
        )
        .expect("preempts");
        assert_eq!(victims, vec![low_new]);
    }

    #[test]
    fn equal_or_higher_priority_is_safe() {
        let pol = PreemptionPolicy { reclaim_within_cohort: false, within_queue: true };
        let q = cq_view("q", None, 2, pol);
        let mut ledger = Ledger::new(vec![q.clone()]);
        let peer = gang("peer", "q", 2, 5, 1);
        ledger.charge("q", &peer.demand);
        assert!(select_victims(&ledger, &[peer.clone()], &q, &demand(1), 5).is_none());
        assert!(select_victims(&ledger, &[peer], &q, &demand(1), 4).is_none());
    }

    #[test]
    fn no_useless_eviction_when_it_cannot_fit() {
        let pol = PreemptionPolicy { reclaim_within_cohort: false, within_queue: true };
        let q = cq_view("q", None, 2, pol);
        let mut ledger = Ledger::new(vec![q.clone()]);
        let small = gang("small", "q", 1, 0, 1);
        ledger.charge("q", &small.demand);
        // Demand 3 exceeds nominal 2: even a clean queue cannot host it.
        assert!(select_victims(&ledger, &[small], &q, &demand(3), 10).is_none());
    }

    #[test]
    fn disabled_policy_never_evicts() {
        let q = cq_view("q", None, 1, PreemptionPolicy::default());
        let mut ledger = Ledger::new(vec![q.clone()]);
        let peer = gang("peer", "q", 1, -5, 1);
        ledger.charge("q", &peer.demand);
        assert!(select_victims(&ledger, &[peer], &q, &demand(1), 10).is_none());
    }
}
