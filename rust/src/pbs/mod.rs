//! Torque/PBS workload manager substrate.
//!
//! "Two main-stream workload managers are TORQUE and Slurm … originally
//! Torque only incorporates resource managers and later extends with job
//! schedulers" (paper §I). The pieces: [`script`] (#PBS parsing),
//! [`queue`] (queues + limits), [`server`] (pbs_server job state machine +
//! the scheduling loop), [`mom`] (per-node execution daemon). Scheduling
//! *policies* live in [`crate::sched`], shared with Slurm and the sim.

pub mod mom;
pub mod queue;
pub mod script;
pub mod server;

pub use mom::{JobDone, LaunchSpec, Mom};
pub use queue::{QueueConfig, QueueSet};
pub use script::PbsScript;
pub use server::{AcctRecord, Job, JobState, PbsConfig, PbsServer};
