//! Crate-wide error type.
//!
//! Every subsystem reports failures through [`Error`]; the variants mirror
//! the boundaries of the system (API server, WLM, RPC, runtime, parsing) so
//! callers can branch on *where* something failed without string matching.

use crate::encoding::Value;
use std::fmt;

/// Unified error for all hpcorc subsystems.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Error {
    /// Malformed input: YAML/JSON/PBS script/manifest parse failures.
    Parse(String),
    /// Object/store errors from the kube API server (not found, conflict...).
    Api(ApiError),
    /// Workload-manager rejections (unknown queue, limit exceeded, bad state).
    Wlm(String),
    /// red-box / RPC transport failures.
    Rpc(String),
    /// Container image / runtime failures.
    Container(String),
    /// PJRT / XLA execution failures.
    Compute(String),
    /// I/O wrapper (socket, file staging).
    Io(String),
    /// Configuration errors (testbed topology, CLI args).
    Config(String),
    /// Internal invariant violations — a bug, not a user error.
    Internal(String),
}

/// Kubernetes-style API error reasons.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ApiError {
    NotFound { kind: String, name: String },
    AlreadyExists { kind: String, name: String },
    /// Optimistic-concurrency failure: resourceVersion mismatch.
    Conflict { kind: String, name: String },
    /// A bounded retry-on-conflict loop gave up: `attempts` consecutive
    /// conflicts. Distinct from [`ApiError::Conflict`] so operator logs show
    /// "pathological contention" rather than a routine single conflict.
    ConflictExhausted { kind: String, name: String, attempts: u32 },
    /// An eviction was refused because it would violate a
    /// PodDisruptionBudget (the 429 `DisruptionBudgetExceeded` cause in
    /// real Kubernetes). `budget` names the PDB that blocked it. Callers
    /// treat this as retryable-later, never as a hard failure.
    DisruptionBudgetExceeded { kind: String, name: String, budget: String },
    Invalid(String),
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApiError::NotFound { kind, name } => write!(f, "{kind} \"{name}\" not found"),
            ApiError::AlreadyExists { kind, name } => {
                write!(f, "{kind} \"{name}\" already exists")
            }
            ApiError::Conflict { kind, name } => write!(
                f,
                "operation cannot be fulfilled on {kind} \"{name}\": object was modified"
            ),
            ApiError::ConflictExhausted { kind, name, attempts } => write!(
                f,
                "operation on {kind} \"{name}\" gave up after {attempts} consecutive \
                 conflicts: pathological write contention"
            ),
            ApiError::DisruptionBudgetExceeded { kind, name, budget } => write!(
                f,
                "cannot evict {kind} \"{name}\": disruption budget \"{budget}\" would be \
                 violated (too many requests, retry later)"
            ),
            ApiError::Invalid(msg) => write!(f, "invalid object: {msg}"),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Api(e) => write!(f, "api error: {e}"),
            Error::Wlm(m) => write!(f, "wlm error: {m}"),
            Error::Rpc(m) => write!(f, "rpc error: {m}"),
            Error::Container(m) => write!(f, "container error: {m}"),
            Error::Compute(m) => write!(f, "compute error: {m}"),
            Error::Io(m) => write!(f, "io error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Internal(m) => write!(f, "internal error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

impl From<ApiError> for Error {
    fn from(e: ApiError) -> Self {
        Error::Api(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Shorthand constructors used across the crate.
impl Error {
    pub fn parse(m: impl Into<String>) -> Self {
        Error::Parse(m.into())
    }
    pub fn wlm(m: impl Into<String>) -> Self {
        Error::Wlm(m.into())
    }
    pub fn rpc(m: impl Into<String>) -> Self {
        Error::Rpc(m.into())
    }
    pub fn container(m: impl Into<String>) -> Self {
        Error::Container(m.into())
    }
    pub fn compute(m: impl Into<String>) -> Self {
        Error::Compute(m.into())
    }
    pub fn config(m: impl Into<String>) -> Self {
        Error::Config(m.into())
    }
    pub fn internal(m: impl Into<String>) -> Self {
        Error::Internal(m.into())
    }
    pub fn not_found(kind: impl Into<String>, name: impl Into<String>) -> Self {
        Error::Api(ApiError::NotFound { kind: kind.into(), name: name.into() })
    }
    pub fn already_exists(kind: impl Into<String>, name: impl Into<String>) -> Self {
        Error::Api(ApiError::AlreadyExists { kind: kind.into(), name: name.into() })
    }
    pub fn conflict(kind: impl Into<String>, name: impl Into<String>) -> Self {
        Error::Api(ApiError::Conflict { kind: kind.into(), name: name.into() })
    }
    pub fn conflict_exhausted(
        kind: impl Into<String>,
        name: impl Into<String>,
        attempts: u32,
    ) -> Self {
        Error::Api(ApiError::ConflictExhausted {
            kind: kind.into(),
            name: name.into(),
            attempts,
        })
    }
    pub fn disruption_budget_exceeded(
        kind: impl Into<String>,
        name: impl Into<String>,
        budget: impl Into<String>,
    ) -> Self {
        Error::Api(ApiError::DisruptionBudgetExceeded {
            kind: kind.into(),
            name: name.into(),
            budget: budget.into(),
        })
    }

    /// True if this is a NotFound API error (common branch in controllers).
    pub fn is_not_found(&self) -> bool {
        matches!(self, Error::Api(ApiError::NotFound { .. }))
    }
    /// True if this is an optimistic-concurrency conflict (controllers retry).
    /// Deliberately excludes [`ApiError::ConflictExhausted`]: a retry loop
    /// that already gave up must not be retried blindly by an outer loop.
    pub fn is_conflict(&self) -> bool {
        matches!(self, Error::Api(ApiError::Conflict { .. }))
    }
    /// True if a bounded retry-on-conflict loop exhausted its attempts.
    pub fn is_conflict_exhausted(&self) -> bool {
        matches!(self, Error::Api(ApiError::ConflictExhausted { .. }))
    }
    /// True if an eviction was refused by a PodDisruptionBudget — the
    /// drain/preemption caller should defer and retry a later cycle.
    pub fn is_disruption_budget_exceeded(&self) -> bool {
        matches!(self, Error::Api(ApiError::DisruptionBudgetExceeded { .. }))
    }

    pub fn is_invalid(&self) -> bool {
        matches!(self, Error::Api(ApiError::Invalid(_)))
    }

    /// Structured wire form for the red-box envelope, so errors survive
    /// the socket *typed* — a remote caller's `is_not_found()` /
    /// `is_conflict()` behave exactly like an in-process caller's.
    pub fn encode_wire(&self) -> Value {
        fn tagged(tag: &str, msg: &str) -> Value {
            Value::map().with("type", tag).with("msg", msg)
        }
        match self {
            Error::Api(api) => {
                let v = Value::map().with("type", "api");
                match api {
                    ApiError::NotFound { kind, name } => v
                        .with("reason", "NotFound")
                        .with("kind", kind.clone())
                        .with("name", name.clone()),
                    ApiError::AlreadyExists { kind, name } => v
                        .with("reason", "AlreadyExists")
                        .with("kind", kind.clone())
                        .with("name", name.clone()),
                    ApiError::Conflict { kind, name } => v
                        .with("reason", "Conflict")
                        .with("kind", kind.clone())
                        .with("name", name.clone()),
                    ApiError::ConflictExhausted { kind, name, attempts } => v
                        .with("reason", "ConflictExhausted")
                        .with("kind", kind.clone())
                        .with("name", name.clone())
                        .with("attempts", *attempts as u64),
                    ApiError::DisruptionBudgetExceeded { kind, name, budget } => v
                        .with("reason", "DisruptionBudgetExceeded")
                        .with("kind", kind.clone())
                        .with("name", name.clone())
                        .with("budget", budget.clone()),
                    ApiError::Invalid(m) => {
                        v.with("reason", "Invalid").with("msg", m.clone())
                    }
                }
            }
            Error::Parse(m) => tagged("parse", m),
            Error::Wlm(m) => tagged("wlm", m),
            Error::Rpc(m) => tagged("rpc", m),
            Error::Container(m) => tagged("container", m),
            Error::Compute(m) => tagged("compute", m),
            Error::Io(m) => tagged("io", m),
            Error::Config(m) => tagged("config", m),
            Error::Internal(m) => tagged("internal", m),
        }
    }

    /// Decode [`Error::encode_wire`] output; `None` for unknown shapes
    /// (callers fall back to an untyped transport error).
    pub fn decode_wire(v: &Value) -> Option<Error> {
        let msg = || v.opt_str("msg").unwrap_or("").to_string();
        match v.opt_str("type")? {
            "api" => {
                let kind = || v.opt_str("kind").unwrap_or("").to_string();
                let name = || v.opt_str("name").unwrap_or("").to_string();
                match v.opt_str("reason")? {
                    "NotFound" => Some(Error::not_found(kind(), name())),
                    "AlreadyExists" => Some(Error::already_exists(kind(), name())),
                    "Conflict" => Some(Error::conflict(kind(), name())),
                    "ConflictExhausted" => Some(Error::conflict_exhausted(
                        kind(),
                        name(),
                        v.opt_int("attempts").unwrap_or(0) as u32,
                    )),
                    "DisruptionBudgetExceeded" => Some(Error::disruption_budget_exceeded(
                        kind(),
                        name(),
                        v.opt_str("budget").unwrap_or("").to_string(),
                    )),
                    "Invalid" => Some(Error::Api(ApiError::Invalid(msg()))),
                    _ => None,
                }
            }
            "parse" => Some(Error::Parse(msg())),
            "wlm" => Some(Error::Wlm(msg())),
            "rpc" => Some(Error::Rpc(msg())),
            "container" => Some(Error::Container(msg())),
            "compute" => Some(Error::Compute(msg())),
            "io" => Some(Error::Io(msg())),
            "config" => Some(Error::Config(msg())),
            "internal" => Some(Error::Internal(msg())),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = Error::not_found("TorqueJob", "cow");
        assert_eq!(e.to_string(), "api error: TorqueJob \"cow\" not found");
        assert!(e.is_not_found());
        assert!(!e.is_conflict());
    }

    #[test]
    fn conflict_detection() {
        let e = Error::conflict("Pod", "p1");
        assert!(e.is_conflict());
        assert!(!e.is_conflict_exhausted());
        let io: Error = std::io::Error::new(std::io::ErrorKind::Other, "boom").into();
        assert!(matches!(io, Error::Io(_)));
    }

    #[test]
    fn wire_roundtrip_preserves_every_variant() {
        let errors = vec![
            Error::not_found("Pod", "p1"),
            Error::already_exists("Pod", "p1"),
            Error::conflict("Pod", "p1"),
            Error::conflict_exhausted("Pod", "p1", 16),
            Error::disruption_budget_exceeded("Pod", "p1", "keep-two"),
            Error::Api(ApiError::Invalid("bad spec".into())),
            Error::parse("x"),
            Error::wlm("queue not found"),
            Error::rpc("boom"),
            Error::container("no image"),
            Error::compute("xla"),
            Error::Io("eof".into()),
            Error::config("bad flag"),
            Error::internal("bug"),
        ];
        for e in errors {
            let back = Error::decode_wire(&e.encode_wire());
            assert_eq!(back.as_ref(), Some(&e), "roundtrip {e}");
        }
        assert!(Error::decode_wire(&Value::map()).is_none());
        assert!(Error::decode_wire(&Value::map().with("type", "novel")).is_none());
    }

    #[test]
    fn conflict_exhausted_is_distinct() {
        let e = Error::conflict_exhausted("Pod", "p1", 16);
        assert!(e.is_conflict_exhausted());
        assert!(!e.is_conflict(), "exhaustion must not look like a retryable conflict");
        assert!(e.to_string().contains("16 consecutive"));
    }
}
