//! E5 — container runtime overhead: Singularity's user-privilege,
//! daemonless start vs the Docker daemon model vs a bare process — the
//! quantitative version of the paper's §III argument for Singularity.

use hpcorc::bench::{header, Bench};
use hpcorc::cluster::{Metrics, SharedFs};
use hpcorc::singularity::{
    CancelToken, ContainerSpec, Cri, ImageRegistry, RunRequest, Runtime, RuntimeKind,
    SingularityCri,
};
use std::time::Duration;

fn main() {
    println!("=== E5: container runtime start/run overhead ===");
    println!("{}", header());
    let fs = SharedFs::new();
    for kind in [RuntimeKind::Native, RuntimeKind::Singularity, RuntimeKind::DockerSim] {
        let rt = Runtime::new(kind, ImageRegistry::with_defaults(), Metrics::new());
        let req = RunRequest::new("lolcow_latest.sif");
        Bench::new(format!("{:<12} run echo container", kind.as_str()))
            .warmup(10)
            .iters(200)
            .run(|| {
                let res = rt.run(&req, &fs, &CancelToken::new()).unwrap();
                assert!(res.success());
            });
    }

    // Through the CRI (what the kubelet pays per pod).
    let rt = Runtime::new(RuntimeKind::Singularity, ImageRegistry::with_defaults(), Metrics::new());
    let cri = SingularityCri::new(rt);
    Bench::new("singularity-cri start+wait+remove").warmup(5).iters(100).run(|| {
        let id = cri
            .start(ContainerSpec::new("b", "lolcow_latest.sif"), fs.clone())
            .unwrap();
        cri.wait(id, Duration::from_secs(10)).unwrap();
        cri.remove(id).unwrap();
    });

    println!("\nshape: native < singularity << docker-sim (daemon round-trip + root setup);");
    println!("ratios mirror the real runtimes' published start costs (see DESIGN.md).");
}
