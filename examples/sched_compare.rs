//! E1 preview — "compare efficiency of scheduling the container jobs by
//! Kubernetes and Torque" (paper §V future work), on the discrete-event
//! simulator. The full sweep lives in `cargo bench --bench sched_compare`.
//!
//! Run: cargo run --release --example sched_compare

use hpcorc::sched::{EasyBackfill, FifoPolicy, KubeGreedyPolicy, SchedPolicy};
use hpcorc::sim::{simulate, OperatorModel, SimParams};
use hpcorc::workload::TraceGen;

fn main() {
    println!("=== scheduling-efficiency comparison (sim; same policy code as the live daemons) ===\n");
    let params = SimParams { nodes: 16, cores_per_node: 8, ..SimParams::default() };
    let policies: Vec<Box<dyn SchedPolicy>> =
        vec![Box::new(FifoPolicy), Box::new(EasyBackfill), Box::new(KubeGreedyPolicy)];

    for (label, trace) in [
        ("poisson batch (load 0.8)", TraceGen::new(1).poisson_batch(800, 128, 0.8, 120.0)),
        ("backfill showcase", TraceGen::new(2).backfill_showcase(20, 16)),
        ("bursty service churn", TraceGen::new(3).bursty(30, 25, 45.0)),
        ("cybele pilots", TraceGen::new(4).cybele_pilots(20, 200, 2000.0)),
    ] {
        println!("--- {label} ({} jobs) ---", trace.len());
        for policy in &policies {
            let report = simulate(&trace, &params, policy.as_ref());
            println!("  {}", report.row());
        }
        // Hybrid path: Torque backfill + modeled operator overhead (E2).
        let hybrid = SimParams {
            operator: OperatorModel { submit_delay_s: 0.5, poll_s: 0.25 },
            ..params.clone()
        };
        let mut report = simulate(&trace, &hybrid, &EasyBackfill);
        report.policy = "hybrid-op".into();
        println!("  {}", report.row());
        println!();
    }
    println!("shape check (paper expectation): easy-backfill wins makespan/util on batch;");
    println!("kube-greedy matches on churn but starves wide jobs (max wait); hybrid ≈ easy + ms-scale overhead.");
}
