//! Persistence-layer integration (PR 6 acceptance):
//!
//! 1. **Total recovery** — a WAL-backed ApiServer killed and reopened on
//!    the same directory recovers every object *and every resource
//!    version*, byte-for-byte, and its version counter resumes (no
//!    resource-version reuse across the restart).
//! 2. **Restart mid-workload converges without a full relist** — kueue
//!    tenant admitted + scheduled, server killed after a blind-spot
//!    write, a second server opened over the same WAL dir. The informer
//!    caches recover over a **delta relist** (no epoch bump, no Resync,
//!    no ledger rebuild, zero additional full-list RPCs), the freed
//!    quota admits the waiting pod, the scheduler binds it, and a
//!    brand-new controller stack over the recovered server agrees
//!    completely — the fresh-start fixed point of `tests/informer.rs`.

use hpcorc::cluster::{Metrics, Resources};
use hpcorc::encoding::Value;
use hpcorc::kube::{
    ApiClient, ApiServer, KubeObject, KubeScheduler, ListOptions, NodeView, ObjectList,
    PodView, SharedInformerFactory, WalBackend, WatchEvent, KIND_NODE, KIND_POD,
};
use hpcorc::kueue::{
    is_admitted, AdmissionCore, ClusterQueueView, LocalQueueView, QueueResources,
    KIND_CLUSTERQUEUE, KIND_LOCALQUEUE,
};
use hpcorc::rt::Shutdown;
use hpcorc::util::Result;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn wal_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("hpcorc-persist-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn wal_server(dir: &Path) -> ApiServer {
    ApiServer::with_backend(Metrics::new(), Box::new(WalBackend::open(dir).unwrap()), 4096)
        .unwrap()
}

fn queued_pod(name: &str, queue: &str, cpu: u64) -> KubeObject {
    let mut p = PodView::build(name, "img.sif", Resources::new(cpu, 1 << 20, 0), &[]);
    hpcorc::kueue::queue_workload(&mut p, queue);
    p
}

/// ApiClient wrapper whose backing ApiServer can be swapped mid-flight —
/// the client-side shape of "the API server restarted". Swapping severs
/// every live watch stream (the forwarder threads drop their senders) and
/// routes all subsequent calls to the new server. Full-list RPCs
/// (`delta_floor` absent) are counted separately so tests can prove
/// recovery never paid for one.
struct SwappableApi {
    inner: Mutex<ApiServer>,
    full_lists: AtomicU64,
    taps: Mutex<Vec<Shutdown>>,
}

impl SwappableApi {
    fn new(api: ApiServer) -> Arc<SwappableApi> {
        Arc::new(SwappableApi {
            inner: Mutex::new(api),
            full_lists: AtomicU64::new(0),
            taps: Mutex::new(Vec::new()),
        })
    }

    fn api(&self) -> ApiServer {
        self.inner.lock().unwrap().clone()
    }

    fn full_lists(&self) -> u64 {
        self.full_lists.load(Ordering::SeqCst)
    }

    /// The restart: sever every stream, then serve from `next`.
    fn swap(&self, next: ApiServer) {
        for sd in self.taps.lock().unwrap().drain(..) {
            sd.trigger();
        }
        std::thread::sleep(Duration::from_millis(10));
        *self.inner.lock().unwrap() = next;
    }
}

impl ApiClient for SwappableApi {
    fn create(&self, obj: KubeObject) -> Result<KubeObject> {
        self.api().create(obj)
    }
    fn get(&self, kind: &str, name: &str) -> Result<KubeObject> {
        self.api().get(kind, name)
    }
    fn update(&self, obj: KubeObject) -> Result<KubeObject> {
        ApiServer::update(&self.api(), obj)
    }
    fn update_status(
        &self,
        kind: &str,
        name: &str,
        f: &dyn Fn(&mut KubeObject),
    ) -> Result<KubeObject> {
        self.api().update_status(kind, name, f)
    }
    fn patch_merge(&self, kind: &str, name: &str, patch: &Value) -> Result<KubeObject> {
        self.api().patch_merge(kind, name, patch)
    }
    fn delete(&self, kind: &str, name: &str) -> Result<KubeObject> {
        self.api().delete(kind, name)
    }
    fn apply(&self, obj: KubeObject) -> Result<KubeObject> {
        self.api().apply(obj)
    }
    fn list(&self, kind: &str, opts: &ListOptions) -> Result<ObjectList> {
        if opts.delta_floor.is_none() {
            self.full_lists.fetch_add(1, Ordering::SeqCst);
        }
        self.api().list_opts(kind, opts)
    }
    fn watch(&self, kind: Option<&str>, from: u64) -> Result<Receiver<WatchEvent>> {
        let upstream = ApiServer::watch(&self.api(), kind, from);
        let (tx, rx) = channel();
        let sd = Shutdown::new();
        self.taps.lock().unwrap().push(sd.clone());
        hpcorc::rt::spawn_named("swappable-watch", move || loop {
            if sd.is_triggered() {
                return; // drops tx: the server "restarted"
            }
            match upstream.recv_timeout(Duration::from_millis(1)) {
                Ok(ev) => {
                    if tx.send(ev).is_err() {
                        return;
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(_) => return,
            }
        });
        Ok(rx)
    }
    fn server_time_s(&self) -> Result<f64> {
        Ok(self.api().now_s())
    }
}

/// Acceptance: kill + reopen recovers every object with its exact
/// resource version, and the version counter resumes past the old head.
#[test]
fn restart_recovers_every_object_and_resource_version() {
    let dir = wal_dir("total");
    let first = wal_server(&dir);
    first.create(NodeView::build("w1", Resources::cores(8, 64 << 30), &[])).unwrap();
    for i in 0..20 {
        first
            .create(PodView::build(
                &format!("p{i}"),
                "img.sif",
                Resources::new(100, 1 << 20, 0),
                &[],
            ))
            .unwrap();
    }
    // Mixed history: status updates, a label patch, and a deletion, so
    // recovery has to replay more than straight creations.
    for i in 0..5 {
        first
            .update_status(KIND_POD, &format!("p{i}"), |o| {
                o.status.insert("phase", "Running");
            })
            .unwrap();
    }
    first
        .patch_merge(
            KIND_POD,
            "p7",
            &Value::map().with("metadata", Value::map().with("labels", Value::map().with("t", "x"))),
        )
        .unwrap();
    first.delete(KIND_POD, "p9").unwrap();

    let before: Vec<KubeObject> = {
        let mut all = first.list(KIND_NODE, &[]);
        all.extend(first.list(KIND_POD, &[]));
        all
    };
    let version = first.current_version();
    drop(first); // the "kill" — per-commit flushes mean nothing is lost

    let second = wal_server(&dir);
    assert_eq!(second.current_version(), version, "version counter survives the restart");
    let after: Vec<KubeObject> = {
        let mut all = second.list(KIND_NODE, &[]);
        all.extend(second.list(KIND_POD, &[]));
        all
    };
    assert_eq!(after.len(), before.len(), "p9 stays deleted; everything else survives");
    for (a, b) in after.iter().zip(before.iter()) {
        assert_eq!(a, b, "{}/{} must recover byte-identical", b.kind, b.meta.name);
    }
    assert!(second.get(KIND_POD, "p9").is_err(), "deletions are durable too");

    // New writes resume the counter — no resource-version reuse.
    let created = second
        .create(PodView::build("post", "img.sif", Resources::new(100, 1 << 20, 0), &[]))
        .unwrap();
    assert!(created.meta.resource_version > version);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance (satellite 4): restart mid-workload. Informer caches, the
/// kueue ledger, and the scheduler converge to the fresh-start fixed
/// point — over a delta relist, with no epoch bump, no Resync-driven
/// ledger rebuild, and zero additional full-list RPCs.
#[test]
fn restart_mid_workload_converges_over_delta_relist() {
    let dir = wal_dir("workload");
    let first = wal_server(&dir);
    let swap = SwappableApi::new(first.clone());
    let informer_metrics = Metrics::new();
    let informers =
        SharedInformerFactory::new(swap.clone() as Arc<dyn ApiClient>, informer_metrics.clone());
    let core = AdmissionCore::new(&informers, Metrics::new());
    let sched = KubeScheduler::new(&informers, Metrics::new());

    swap.create(NodeView::build("w1", Resources::cores(8, 64 << 30), &[])).unwrap();
    swap.create(ClusterQueueView::build("cq", QueueResources::nodes(2))).unwrap();
    swap.create(LocalQueueView::build("team", "cq")).unwrap();
    swap.create(queued_pod("p0", "team", 100)).unwrap();
    swap.create(queued_pod("p1", "team", 100)).unwrap();
    swap.create(queued_pod("p2", "team", 100)).unwrap();

    // Converge before the restart: quota admits p0+p1, scheduler binds.
    let r = core.cycle(swap.as_ref() as &dyn ApiClient).unwrap();
    assert_eq!(r.admitted, 2, "2-node quota admits p0+p1");
    assert_eq!(sched.run_cycle(), 2, "admitted pods bind to w1");
    assert!(!is_admitted(&first.get(KIND_POD, "p2").unwrap()));
    assert_eq!(core.ledger_rebuilds(), 1, "cold start built the ledger once");
    let pod_epoch = informers.informer(KIND_POD).epoch();
    let full_lists = swap.full_lists();

    // Blind-spot write, then the kill: p0 completes (freeing quota) in
    // the instant before the server dies — the reflectors never see the
    // event over their severed streams, only via recovery.
    first
        .update_status(KIND_POD, "p0", |o| {
            o.status.insert("phase", "Succeeded");
        })
        .unwrap();
    let second = wal_server(&dir);
    assert_eq!(second.current_version(), first.current_version(), "full recovery");
    swap.swap(second.clone());

    // Recovery: the recovered WAL tail seeds the new server's watch
    // histories, so every reflector resumes with a delta relist — the
    // pre-restart bookmarks are still inside the window.
    let r = core.cycle(swap.as_ref() as &dyn ApiClient).unwrap();
    assert_eq!(r.admitted, 1, "freed quota admits p2 after the restart");
    assert_eq!(sched.run_cycle(), 1, "recovered scheduler binds p2");
    assert!(is_admitted(&second.get(KIND_POD, "p1").unwrap()));
    assert!(is_admitted(&second.get(KIND_POD, "p2").unwrap()));
    assert_eq!(
        informers.informer(KIND_POD).epoch(),
        pod_epoch,
        "delta relist: the resync epoch must not bump"
    );
    assert_eq!(core.ledger_rebuilds(), 1, "no Resync: the ledger never rebuilt");
    assert!(
        informer_metrics.counter_value("kube.informer.delta_relists") >= 1,
        "recovery must have gone through the delta-relist path"
    );
    assert_eq!(
        swap.full_lists(),
        full_lists,
        "restart recovery must not issue a single full-list RPC"
    );

    // Steady state on the recovered server: nothing left to do.
    let r = core.cycle(swap.as_ref() as &dyn ApiClient).unwrap();
    assert_eq!((r.admitted, r.preempted), (0, 0));
    assert_eq!(sched.run_cycle(), 0);

    // Fresh-start fixed point: a brand-new controller stack over the
    // recovered server must agree completely — no admissions, no
    // preemptions, no binds, no writes.
    let fresh_informers =
        SharedInformerFactory::new(swap.clone() as Arc<dyn ApiClient>, Metrics::new());
    let fresh_core = AdmissionCore::new(&fresh_informers, Metrics::new());
    let fresh_sched = KubeScheduler::new(&fresh_informers, Metrics::new());
    let version_before = second.current_version();
    let r = fresh_core.cycle(swap.as_ref() as &dyn ApiClient).unwrap();
    assert_eq!((r.admitted, r.preempted), (0, 0), "fresh start finds nothing to change");
    assert_eq!(fresh_sched.run_cycle(), 0);
    assert_eq!(
        second.current_version(),
        version_before,
        "fresh start writes nothing: recovered state is already the fixed point"
    );
    let cq = ClusterQueueView::from_object(&second.get(KIND_CLUSTERQUEUE, "cq").unwrap())
        .unwrap();
    assert_eq!((cq.pending, cq.admitted), (0, 2), "counts reflect the converged set");
    assert!(second.get(KIND_LOCALQUEUE, "team").is_ok());
    let _ = std::fs::remove_dir_all(&dir);
}

/// A bookmark that predates the recovered WAL window must still reset:
/// compaction moves the floor, and a reflector whose version fell below
/// it takes the classic full-relist path (the delta contract degrades
/// gracefully, never silently skips events).
#[test]
fn restart_past_compacted_window_falls_back_to_full_relist() {
    let dir = wal_dir("compacted");
    let first = ApiServer::with_backend(
        Metrics::new(),
        Box::new(WalBackend::open(&dir).unwrap().with_compact_threshold(8)),
        4096,
    )
    .unwrap();
    first
        .create(PodView::build("p0", "img.sif", Resources::new(100, 1 << 20, 0), &[]))
        .unwrap();
    let old_bookmark = first.current_version();
    // Enough churn to force at least one snapshot + log truncation.
    for i in 0..32u64 {
        first
            .update_status(KIND_POD, "p0", |o| {
                o.status.insert("n", i);
            })
            .unwrap();
    }
    drop(first);

    let second = wal_server(&dir);
    let l = second
        .list_opts(KIND_POD, &ListOptions::all().delta_since(old_bookmark))
        .unwrap();
    assert!(!l.delta, "pre-compaction bookmark is out of the window: full list");
    assert_eq!(l.items.len(), 1);
    let (_, _, reset) = second.events_since(Some(KIND_POD), old_bookmark);
    assert!(reset, "watch from the stale bookmark resets (410-Gone)");
    let _ = std::fs::remove_dir_all(&dir);
}

/// PR 8 satellite: completed spans persist next to the WAL
/// (`<wal_dir>/spans.jsonl`), so `hpcorc trace KIND/NAME` still
/// reconstructs a timeline from a rebooted daemon — the object comes
/// back from the WAL, the spans from the replayed span log.
#[test]
fn restart_recovers_span_timeline_through_wal_dir() {
    use hpcorc::encoding::Value as V;
    use hpcorc::hybrid::{Testbed, TestbedConfig};
    use hpcorc::obs;

    let dir = wal_dir("spans");
    let trace_id = {
        let mut cfg = TestbedConfig::default();
        cfg.wal_dir = Some(dir.clone());
        let tb = Testbed::start(cfg).unwrap();
        let trace_id = {
            let guard = obs::span("persist-test", "create traced pod");
            let id = guard.context().unwrap().trace_id;
            tb.api
                .create(PodView::build("sp", "img.sif", Resources::new(100, 1 << 20, 0), &[]))
                .unwrap();
            id
        };
        // Wait for the bind so the scheduler's span joins the trace.
        let deadline = std::time::Instant::now() + Duration::from_secs(20);
        loop {
            let obj = tb.api.get(KIND_POD, "sp").unwrap();
            if obj.spec.opt_str("nodeName").is_some() {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "pod never bound");
            std::thread::sleep(Duration::from_millis(5));
        }
        // Give the bind span a moment to close into the sink file.
        std::thread::sleep(Duration::from_millis(50));
        tb.stop();
        trace_id
    };

    // The "restart": wipe the in-memory ring — only the WAL dir remains.
    obs::clear();
    assert!(obs::by_trace(trace_id).is_empty(), "ring wiped; spans only on disk now");

    let mut cfg = TestbedConfig::default();
    cfg.wal_dir = Some(dir.clone());
    let tb = Testbed::start(cfg).unwrap();
    // The object recovered with its trace annotation intact…
    let obj = tb.api.get(KIND_POD, "sp").unwrap();
    let wire = obj.meta.annotation(obs::TRACE_ANNOTATION).unwrap();
    let ctx = obs::TraceContext::parse_wire(wire).unwrap();
    assert_eq!(ctx.trace_id, trace_id, "annotation survives the WAL");
    // …and the replayed span log reconstructs its timeline, both
    // in-process and over the socket (the `hpcorc trace` path).
    let spans = obs::by_trace(trace_id);
    assert!(spans.len() >= 3, "replayed timeline is multi-span, got {}", spans.len());
    let rpc = hpcorc::redbox::RedboxClient::connect(tb.socket()).unwrap();
    let out = rpc
        .call("obs.Spans/ByTrace", V::map().with("trace", format!("{trace_id:016x}")))
        .unwrap();
    let events = out.get("events").and_then(V::as_seq).unwrap_or(&[]).to_vec();
    assert!(!events.is_empty(), "remote span service serves the replayed trace");
    tb.stop();
    let _ = std::fs::remove_dir_all(&dir);
}
