//! Resource vectors: the common currency of both schedulers.
//!
//! Torque thinks in nodes×ppn (+mem); Kubernetes in per-pod cpu/memory
//! requests. Both reduce to a [`Resources`] vector that node capacities are
//! checked and charged against.

use crate::encoding::{Decode, Encode, Value};
use crate::util::{Error, Result};
use std::fmt;
use std::ops::{Add, AddAssign, Sub, SubAssign};

/// A resource quantity vector. `cpu_milli` uses Kubernetes millicore units
/// (1000 = one core) so fractional requests (`500m`) are exact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default, Hash)]
pub struct Resources {
    pub cpu_milli: u64,
    pub mem_bytes: u64,
    pub gpus: u32,
}

impl Resources {
    pub const ZERO: Resources = Resources { cpu_milli: 0, mem_bytes: 0, gpus: 0 };

    pub fn new(cpu_milli: u64, mem_bytes: u64, gpus: u32) -> Self {
        Resources { cpu_milli, mem_bytes, gpus }
    }

    /// Whole cores + mem, the common case.
    pub fn cores(cores: u32, mem_bytes: u64) -> Self {
        Resources { cpu_milli: cores as u64 * 1000, mem_bytes, gpus: 0 }
    }

    /// Does `self` (a capacity) fit `req` on every dimension?
    pub fn fits(&self, req: &Resources) -> bool {
        self.cpu_milli >= req.cpu_milli
            && self.mem_bytes >= req.mem_bytes
            && self.gpus >= req.gpus
    }

    pub fn is_zero(&self) -> bool {
        *self == Resources::ZERO
    }

    /// Saturating subtraction (free = capacity - used with clamping).
    pub fn saturating_sub(&self, other: &Resources) -> Resources {
        Resources {
            cpu_milli: self.cpu_milli.saturating_sub(other.cpu_milli),
            mem_bytes: self.mem_bytes.saturating_sub(other.mem_bytes),
            gpus: self.gpus.saturating_sub(other.gpus),
        }
    }

    /// Dominant-share fraction of `self` relative to a capacity (for
    /// least-allocated scoring). Returns 0..=1.
    pub fn dominant_fraction(&self, capacity: &Resources) -> f64 {
        let mut frac: f64 = 0.0;
        if capacity.cpu_milli > 0 {
            frac = frac.max(self.cpu_milli as f64 / capacity.cpu_milli as f64);
        }
        if capacity.mem_bytes > 0 {
            frac = frac.max(self.mem_bytes as f64 / capacity.mem_bytes as f64);
        }
        if capacity.gpus > 0 {
            frac = frac.max(self.gpus as f64 / capacity.gpus as f64);
        }
        frac.min(1.0)
    }

    /// Parse a Kubernetes-style cpu quantity: `2`, `500m`, `1.5`.
    pub fn parse_cpu(s: &str) -> Result<u64> {
        let s = s.trim();
        if let Some(m) = s.strip_suffix('m') {
            m.parse::<u64>().map_err(|_| Error::parse(format!("bad cpu quantity `{s}`")))
        } else {
            let v: f64 =
                s.parse().map_err(|_| Error::parse(format!("bad cpu quantity `{s}`")))?;
            if v < 0.0 {
                return Err(Error::parse(format!("negative cpu `{s}`")));
            }
            Ok((v * 1000.0).round() as u64)
        }
    }

    /// Parse a Kubernetes-style memory quantity: `128Mi`, `4Gi`, `1024Ki`, bytes.
    pub fn parse_mem_k8s(s: &str) -> Result<u64> {
        let s = s.trim();
        let (num, mult) = if let Some(n) = s.strip_suffix("Ti") {
            (n, 1u64 << 40)
        } else if let Some(n) = s.strip_suffix("Gi") {
            (n, 1u64 << 30)
        } else if let Some(n) = s.strip_suffix("Mi") {
            (n, 1u64 << 20)
        } else if let Some(n) = s.strip_suffix("Ki") {
            (n, 1u64 << 10)
        } else {
            (s, 1)
        };
        let v: f64 =
            num.parse().map_err(|_| Error::parse(format!("bad memory quantity `{s}`")))?;
        if v < 0.0 {
            return Err(Error::parse(format!("negative memory `{s}`")));
        }
        Ok((v * mult as f64) as u64)
    }
}

impl Add for Resources {
    type Output = Resources;
    fn add(self, o: Resources) -> Resources {
        Resources {
            cpu_milli: self.cpu_milli + o.cpu_milli,
            mem_bytes: self.mem_bytes + o.mem_bytes,
            gpus: self.gpus + o.gpus,
        }
    }
}

impl AddAssign for Resources {
    fn add_assign(&mut self, o: Resources) {
        *self = *self + o;
    }
}

impl Sub for Resources {
    type Output = Resources;
    fn sub(self, o: Resources) -> Resources {
        Resources {
            cpu_milli: self.cpu_milli - o.cpu_milli,
            mem_bytes: self.mem_bytes - o.mem_bytes,
            gpus: self.gpus - o.gpus,
        }
    }
}

impl SubAssign for Resources {
    fn sub_assign(&mut self, o: Resources) {
        *self = *self - o;
    }
}

impl fmt::Display for Resources {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "cpu={}m mem={} gpu={}",
            self.cpu_milli,
            crate::util::fmt_mem(self.mem_bytes),
            self.gpus
        )
    }
}

impl Encode for Resources {
    fn encode(&self) -> Value {
        Value::map()
            .with("cpuMilli", self.cpu_milli)
            .with("memBytes", self.mem_bytes)
            .with("gpus", self.gpus as u64)
    }
}

impl Decode for Resources {
    fn decode(v: &Value) -> Result<Self> {
        Ok(Resources {
            cpu_milli: v.opt_int("cpuMilli").unwrap_or(0) as u64,
            mem_bytes: v.opt_int("memBytes").unwrap_or(0) as u64,
            gpus: v.opt_int("gpus").unwrap_or(0) as u32,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_and_arith() {
        let cap = Resources::cores(8, 16 << 30);
        let req = Resources::cores(2, 4 << 30);
        assert!(cap.fits(&req));
        let free = cap - req;
        assert_eq!(free.cpu_milli, 6000);
        assert!(!req.fits(&cap));
        let back = free + req;
        assert_eq!(back, cap);
    }

    #[test]
    fn saturating() {
        let a = Resources::cores(1, 1 << 30);
        let b = Resources::cores(4, 8 << 30);
        assert_eq!(a.saturating_sub(&b), Resources::ZERO);
    }

    #[test]
    fn parse_cpu_quantities() {
        assert_eq!(Resources::parse_cpu("2").unwrap(), 2000);
        assert_eq!(Resources::parse_cpu("500m").unwrap(), 500);
        assert_eq!(Resources::parse_cpu("1.5").unwrap(), 1500);
        assert!(Resources::parse_cpu("abc").is_err());
        assert!(Resources::parse_cpu("-1").is_err());
    }

    #[test]
    fn parse_mem_quantities() {
        assert_eq!(Resources::parse_mem_k8s("128Mi").unwrap(), 128 << 20);
        assert_eq!(Resources::parse_mem_k8s("4Gi").unwrap(), 4u64 << 30);
        assert_eq!(Resources::parse_mem_k8s("1024").unwrap(), 1024);
        assert!(Resources::parse_mem_k8s("x").is_err());
    }

    #[test]
    fn dominant_fraction() {
        let cap = Resources::cores(10, 100 << 30);
        let half_cpu = Resources::cores(5, 10 << 30);
        assert!((half_cpu.dominant_fraction(&cap) - 0.5).abs() < 1e-9);
        let mem_heavy = Resources::cores(1, 90 << 30);
        assert!((mem_heavy.dominant_fraction(&cap) - 0.9).abs() < 1e-9);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let r = Resources::new(1500, 3 << 30, 2);
        let v = r.encode();
        assert_eq!(Resources::decode(&v).unwrap(), r);
    }
}
