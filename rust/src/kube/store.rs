//! Versioned object store with watch streams — etcd + the API machinery's
//! watch cache, distilled.
//!
//! Every mutation bumps a global `resourceVersion`, is applied with
//! optimistic concurrency (update must carry the current version), and is
//! appended to a bounded history so watchers can replay from a version.

use super::api::KubeObject;
use crate::encoding::Value;
use crate::util::{Error, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Watch event types (mirrors the k8s watch API).
#[derive(Debug, Clone, PartialEq)]
pub enum WatchEvent {
    Added(KubeObject),
    Modified(KubeObject),
    Deleted(KubeObject),
}

impl WatchEvent {
    pub fn object(&self) -> &KubeObject {
        match self {
            WatchEvent::Added(o) | WatchEvent::Modified(o) | WatchEvent::Deleted(o) => o,
        }
    }

    /// The k8s wire tag for this event type.
    pub fn type_str(&self) -> &'static str {
        match self {
            WatchEvent::Added(_) => "ADDED",
            WatchEvent::Modified(_) => "MODIFIED",
            WatchEvent::Deleted(_) => "DELETED",
        }
    }

    /// Encode for the RPC transport: `{type, object}`.
    pub fn encode(&self) -> Value {
        Value::map().with("type", self.type_str()).with("object", self.object().encode())
    }

    pub fn decode(v: &Value) -> Result<WatchEvent> {
        let obj = KubeObject::decode(v.req("object")?)?;
        match v.req_str("type")? {
            "ADDED" => Ok(WatchEvent::Added(obj)),
            "MODIFIED" => Ok(WatchEvent::Modified(obj)),
            "DELETED" => Ok(WatchEvent::Deleted(obj)),
            other => Err(Error::parse(format!("unknown watch event type `{other}`"))),
        }
    }
}

/// Default watch-history window. Small deployments never notice it; a
/// testbed expecting event bursts (every kubelet sync, admission cycle,
/// and autoscaler pass is a potential write) should size it explicitly
/// via [`Store::with_history_cap`] — a burst larger than the window
/// forces every watcher whose bookmark predates the trim into a spurious
/// relist (the 410-Gone path), which is exactly the O(cluster) cost the
/// informer layer exists to avoid.
pub const DEFAULT_HISTORY_CAP: usize = 4096;

struct StoreInner {
    /// (kind, name) → object.
    objects: BTreeMap<(String, String), KubeObject>,
    version: u64,
    uid: u64,
    history: VecDeque<(u64, WatchEvent)>,
    history_cap: usize,
    /// Highest event version evicted from `history` (0 = nothing evicted).
    /// Replays from at or below this version may have lost events.
    trimmed_through: u64,
    watchers: Vec<Watcher>,
}

struct Watcher {
    kind: Option<String>,
    tx: Sender<WatchEvent>,
}

/// The object store handle.
#[derive(Clone)]
pub struct Store {
    inner: Arc<Mutex<StoreInner>>,
    epoch: Instant,
}

impl Default for Store {
    fn default() -> Self {
        Self::new()
    }
}

impl Store {
    pub fn new() -> Store {
        Store::with_history_cap(DEFAULT_HISTORY_CAP)
    }

    /// A store with an explicit watch-history window. `cap` bounds how
    /// many events watchers (and the RPC watch poll) can replay before a
    /// stale bookmark turns into the 410-Gone reset; size it above the
    /// largest event burst expected between watcher polls.
    pub fn with_history_cap(cap: usize) -> Store {
        Store {
            inner: Arc::new(Mutex::new(StoreInner {
                objects: BTreeMap::new(),
                version: 0,
                uid: 0,
                history: VecDeque::new(),
                history_cap: cap.max(1),
                trimmed_through: 0,
                watchers: Vec::new(),
            })),
            epoch: Instant::now(),
        }
    }

    /// The configured watch-history window.
    pub fn history_cap(&self) -> usize {
        self.inner.lock().unwrap().history_cap
    }

    /// Seconds since store creation (object creation timestamps).
    pub fn now_s(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Create; fails if (kind, name) exists. Returns the stored object
    /// (with uid/resourceVersion/creation assigned).
    pub fn create(&self, mut obj: KubeObject) -> Result<KubeObject> {
        let now = self.now_s();
        let mut inner = self.inner.lock().unwrap();
        let key = (obj.kind.clone(), obj.meta.name.clone());
        if inner.objects.contains_key(&key) {
            return Err(Error::already_exists(&obj.kind, &obj.meta.name));
        }
        inner.version += 1;
        inner.uid += 1;
        obj.meta.uid = inner.uid;
        obj.meta.resource_version = inner.version;
        obj.meta.creation_s = now;
        inner.objects.insert(key, obj.clone());
        let v = inner.version;
        Self::publish(&mut inner, v, WatchEvent::Added(obj.clone()));
        Ok(obj)
    }

    pub fn get(&self, kind: &str, name: &str) -> Result<KubeObject> {
        self.inner
            .lock()
            .unwrap()
            .objects
            .get(&(kind.to_string(), name.to_string()))
            .cloned()
            .ok_or_else(|| Error::not_found(kind, name))
    }

    /// Update with optimistic concurrency: `obj.meta.resource_version` must
    /// match the stored version.
    pub fn update(&self, mut obj: KubeObject) -> Result<KubeObject> {
        let mut inner = self.inner.lock().unwrap();
        let key = (obj.kind.clone(), obj.meta.name.clone());
        let current = inner
            .objects
            .get(&key)
            .ok_or_else(|| Error::not_found(&obj.kind, &obj.meta.name))?;
        if current.meta.resource_version != obj.meta.resource_version {
            return Err(Error::conflict(&obj.kind, &obj.meta.name));
        }
        obj.meta.uid = current.meta.uid;
        obj.meta.creation_s = current.meta.creation_s;
        inner.version += 1;
        obj.meta.resource_version = inner.version;
        inner.objects.insert(key, obj.clone());
        let v = inner.version;
        Self::publish(&mut inner, v, WatchEvent::Modified(obj.clone()));
        Ok(obj)
    }

    pub fn delete(&self, kind: &str, name: &str) -> Result<KubeObject> {
        let mut inner = self.inner.lock().unwrap();
        let key = (kind.to_string(), name.to_string());
        let obj = inner
            .objects
            .remove(&key)
            .ok_or_else(|| Error::not_found(kind, name))?;
        inner.version += 1;
        let v = inner.version;
        Self::publish(&mut inner, v, WatchEvent::Deleted(obj.clone()));
        Ok(obj)
    }

    /// List objects of a kind, optionally filtered by a label selector
    /// (all pairs must match).
    pub fn list(&self, kind: &str, selector: &[(String, String)]) -> Vec<KubeObject> {
        self.inner
            .lock()
            .unwrap()
            .objects
            .range((kind.to_string(), String::new())..)
            .take_while(|((k, _), _)| k == kind)
            .map(|(_, o)| o.clone())
            .filter(|o| {
                selector.iter().all(|(k, v)| o.meta.label(k) == Some(v.as_str()))
            })
            .collect()
    }

    pub fn list_all(&self) -> Vec<KubeObject> {
        self.inner.lock().unwrap().objects.values().cloned().collect()
    }

    pub fn current_version(&self) -> u64 {
        self.inner.lock().unwrap().version
    }

    /// Highest event version evicted from the watch history (0 = nothing
    /// evicted yet). A watch bookmark at or below this is stale: replaying
    /// from it may silently miss events.
    pub fn trimmed_through(&self) -> u64 {
        self.inner.lock().unwrap().trimmed_through
    }

    /// Watch events for `kind` (None = all kinds) from `from_version`
    /// (exclusive). Replays history first, then streams live events. A
    /// bookmark older than the retained window cannot be replayed
    /// faithfully: the returned stream is already ended (no watcher
    /// registered) — the 410-Gone signal — so the caller relists and
    /// rewatches. The staleness check happens under the same lock as the
    /// replay + registration, so it cannot race a concurrent trim.
    pub fn watch(&self, kind: Option<&str>, from_version: u64) -> Receiver<WatchEvent> {
        match self.try_watch(kind, from_version) {
            (_, Some(rx)) => rx,
            (_, None) => channel().1, // tx dropped: ended stream (410)
        }
    }

    /// Watch with an explicit 410 verdict: `None` when `from_version` has
    /// fallen out of the retained history window (the caller must relist
    /// instead of trusting a replay), otherwise the replay-then-live
    /// receiver of [`Store::watch`]. Also returns the store version at
    /// registration — the stream's starting bookmark. The staleness
    /// check, the replay, and the registration all happen under one lock,
    /// so they cannot race a concurrent trim.
    pub fn try_watch(
        &self,
        kind: Option<&str>,
        from_version: u64,
    ) -> (u64, Option<Receiver<WatchEvent>>) {
        let (tx, rx) = channel();
        let mut inner = self.inner.lock().unwrap();
        if from_version < inner.trimmed_through {
            return (inner.version, None);
        }
        for (v, ev) in inner.history.iter() {
            if *v > from_version
                && kind.map(|k| ev.object().kind == k).unwrap_or(true)
            {
                let _ = tx.send(ev.clone());
            }
        }
        inner.watchers.push(Watcher { kind: kind.map(String::from), tx });
        (inner.version, Some(rx))
    }

    /// One-shot replay: events for `kind` (None = all) newer than
    /// `from_version`, plus the store version they bring the caller up to,
    /// plus a `reset` flag. This is the poll primitive behind the RPC
    /// transport's watch — no watcher is registered, so it is safe to call
    /// at any rate. `reset = true` means `from_version` has fallen out of
    /// the retained history window, so events may have been lost — the
    /// 410-Gone signal of the k8s watch API; the caller must relist and
    /// rewatch rather than trust the replay.
    pub fn events_since(
        &self,
        kind: Option<&str>,
        from_version: u64,
    ) -> (u64, Vec<WatchEvent>, bool) {
        let inner = self.inner.lock().unwrap();
        let reset = from_version < inner.trimmed_through;
        let events = inner
            .history
            .iter()
            .filter(|(v, ev)| {
                *v > from_version && kind.map(|k| ev.object().kind == k).unwrap_or(true)
            })
            .map(|(_, ev)| ev.clone())
            .collect();
        (inner.version, events, reset)
    }

    fn publish(inner: &mut StoreInner, version: u64, event: WatchEvent) {
        inner.history.push_back((version, event.clone()));
        if inner.history.len() > inner.history_cap {
            if let Some((evicted, _)) = inner.history.pop_front() {
                inner.trimmed_through = evicted;
            }
        }
        inner.watchers.retain(|w| match w.kind.as_deref() {
            // Not subscribed to this kind: keep (dead ones are dropped on
            // their next matching event).
            Some(k) if event.object().kind != k => true,
            _ => w.tx.send(event.clone()).is_ok(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::Value;
    use crate::kube::api::KIND_POD;

    fn pod(name: &str) -> KubeObject {
        KubeObject::new(KIND_POD, name, Value::map().with("x", 1i64))
    }

    #[test]
    fn create_get_delete() {
        let s = Store::new();
        let stored = s.create(pod("a")).unwrap();
        assert_eq!(stored.meta.uid, 1);
        assert!(stored.meta.resource_version > 0);
        assert!(s.create(pod("a")).is_err(), "duplicate");
        assert_eq!(s.get(KIND_POD, "a").unwrap().meta.uid, 1);
        s.delete(KIND_POD, "a").unwrap();
        assert!(s.get(KIND_POD, "a").unwrap_err().is_not_found());
        assert!(s.delete(KIND_POD, "a").is_err());
    }

    #[test]
    fn optimistic_concurrency() {
        let s = Store::new();
        let a = s.create(pod("a")).unwrap();
        let mut fresh = a.clone();
        fresh.spec.insert("x", 2i64);
        let updated = s.update(fresh).unwrap();
        assert!(updated.meta.resource_version > a.meta.resource_version);
        // Updating with the stale version conflicts.
        let mut stale = a;
        stale.spec.insert("x", 3i64);
        assert!(s.update(stale).unwrap_err().is_conflict());
    }

    #[test]
    fn list_with_selector() {
        let s = Store::new();
        let mut a = pod("a");
        a.meta.set_label("app", "web");
        let mut b = pod("b");
        b.meta.set_label("app", "db");
        s.create(a).unwrap();
        s.create(b).unwrap();
        s.create(KubeObject::new("Node", "n1", Value::map())).unwrap();
        assert_eq!(s.list(KIND_POD, &[]).len(), 2);
        let sel = vec![("app".to_string(), "web".to_string())];
        let filtered = s.list(KIND_POD, &sel);
        assert_eq!(filtered.len(), 1);
        assert_eq!(filtered[0].meta.name, "a");
        assert_eq!(s.list("Node", &[]).len(), 1);
    }

    #[test]
    fn watch_receives_live_events() {
        let s = Store::new();
        let rx = s.watch(Some(KIND_POD), s.current_version());
        s.create(pod("a")).unwrap();
        let mut a2 = s.get(KIND_POD, "a").unwrap();
        a2.status = Value::map().with("phase", "Running");
        s.update(a2).unwrap();
        s.delete(KIND_POD, "a").unwrap();
        let events: Vec<WatchEvent> = rx.try_iter().collect();
        assert_eq!(events.len(), 3);
        assert!(matches!(events[0], WatchEvent::Added(_)));
        assert!(matches!(events[1], WatchEvent::Modified(_)));
        assert!(matches!(events[2], WatchEvent::Deleted(_)));
    }

    #[test]
    fn watch_replays_history_from_version() {
        let s = Store::new();
        s.create(pod("a")).unwrap();
        let v = s.current_version();
        s.create(pod("b")).unwrap();
        let rx = s.watch(Some(KIND_POD), v);
        let events: Vec<WatchEvent> = rx.try_iter().collect();
        assert_eq!(events.len(), 1, "only b replayed");
        assert_eq!(events[0].object().meta.name, "b");
    }

    #[test]
    fn watch_filters_kind() {
        let s = Store::new();
        let rx = s.watch(Some("Node"), 0);
        s.create(pod("a")).unwrap();
        s.create(KubeObject::new("Node", "n1", Value::map())).unwrap();
        let events: Vec<WatchEvent> = rx.try_iter().collect();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].object().kind, "Node");
    }

    #[test]
    fn events_since_replays_without_subscribing() {
        let s = Store::new();
        s.create(pod("a")).unwrap();
        let v = s.current_version();
        s.create(pod("b")).unwrap();
        s.create(KubeObject::new("Node", "n1", Value::map())).unwrap();
        let (rv, events, reset) = s.events_since(Some(KIND_POD), v);
        assert_eq!(rv, s.current_version());
        assert!(!reset);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].object().meta.name, "b");
        // All kinds, from the beginning.
        let (_, all, _) = s.events_since(None, 0);
        assert_eq!(all.len(), 3);
        // Caught up: nothing new.
        let (rv2, none, reset) = s.events_since(None, rv);
        assert_eq!(rv2, rv);
        assert!(none.is_empty());
        assert!(!reset);
    }

    #[test]
    fn watch_with_stale_bookmark_returns_ended_stream() {
        let s = Store::new();
        let first = s.create(pod("seed")).unwrap().meta.resource_version;
        for i in 0..DEFAULT_HISTORY_CAP + 8 {
            let mut o = s.get(KIND_POD, "seed").unwrap();
            o.status.insert("n", i as u64);
            s.update(o).unwrap();
        }
        let rx = s.watch(Some(KIND_POD), first);
        assert!(
            matches!(rx.try_recv(), Err(std::sync::mpsc::TryRecvError::Disconnected)),
            "stale bookmark must get the 410-Gone ended stream"
        );
        // A fresh bookmark still gets a live stream.
        let rx = s.watch(Some(KIND_POD), s.current_version());
        s.create(pod("later")).unwrap();
        assert_eq!(rx.try_iter().count(), 1);
    }

    #[test]
    fn try_watch_reports_gone_explicitly() {
        let s = Store::new();
        let first = s.create(pod("seed")).unwrap().meta.resource_version;
        for i in 0..DEFAULT_HISTORY_CAP + 8 {
            let mut o = s.get(KIND_POD, "seed").unwrap();
            o.status.insert("n", i as u64);
            s.update(o).unwrap();
        }
        // Stale bookmark: an explicit None (the streaming RPC path turns
        // this into a `gone` StreamEnd), with the current version so the
        // caller can relist from it.
        let (rv, maybe) = s.try_watch(Some(KIND_POD), first);
        assert_eq!(rv, s.current_version());
        assert!(maybe.is_none(), "stale bookmark must be an explicit 410");
        // Fresh bookmark: a live stream.
        let (rv2, live) = s.try_watch(Some(KIND_POD), s.current_version());
        assert_eq!(rv2, s.current_version());
        let live = live.unwrap();
        s.create(pod("later")).unwrap();
        assert_eq!(live.try_iter().count(), 1);
    }

    #[test]
    fn events_since_signals_reset_past_history_window() {
        let s = Store::new();
        let first = s.create(pod("seed")).unwrap().meta.resource_version;
        // Push enough writes to evict the seed event from history.
        for i in 0..DEFAULT_HISTORY_CAP + 8 {
            let mut o = s.get(KIND_POD, "seed").unwrap();
            o.status.insert("n", i as u64);
            s.update(o).unwrap();
        }
        let (_, _, reset) = s.events_since(None, first);
        assert!(reset, "bookmark older than the window must signal reset");
        let (rv, events, reset) = s.events_since(None, s.current_version() - 1);
        assert!(!reset, "fresh bookmark replays normally");
        assert_eq!(events.len(), 1);
        assert_eq!(rv, s.current_version());
    }

    /// Regression (ISSUE 4 satellite): the watch-history window used to be
    /// a hardcoded 4096 — an event burst larger than that between two
    /// watch polls trimmed the bookmark out of history and forced a
    /// spurious relist. A store sized above the burst replays it cleanly.
    #[test]
    fn sized_history_window_survives_burst_that_overflows_old_default() {
        let burst = DEFAULT_HISTORY_CAP + 100;
        // Old default: the burst trims the bookmark out of the window.
        let small = Store::new();
        let bookmark = small.create(pod("seed")).unwrap().meta.resource_version;
        for i in 0..burst {
            let mut o = small.get(KIND_POD, "seed").unwrap();
            o.status.insert("n", i as u64);
            small.update(o).unwrap();
        }
        let (_, _, reset) = small.events_since(None, bookmark);
        assert!(reset, "old default window loses a {burst}-event burst");

        // Sized window: the same burst replays without a reset.
        let big = Store::with_history_cap(2 * DEFAULT_HISTORY_CAP);
        assert_eq!(big.history_cap(), 2 * DEFAULT_HISTORY_CAP);
        let bookmark = big.create(pod("seed")).unwrap().meta.resource_version;
        for i in 0..burst {
            let mut o = big.get(KIND_POD, "seed").unwrap();
            o.status.insert("n", i as u64);
            big.update(o).unwrap();
        }
        let (rv, events, reset) = big.events_since(None, bookmark);
        assert!(!reset, "sized window must absorb the burst");
        assert_eq!(events.len(), burst);
        assert_eq!(rv, big.current_version());
    }

    #[test]
    fn watch_event_wire_roundtrip() {
        let s = Store::new();
        let o = s.create(pod("a")).unwrap();
        for ev in [
            WatchEvent::Added(o.clone()),
            WatchEvent::Modified(o.clone()),
            WatchEvent::Deleted(o),
        ] {
            let back = WatchEvent::decode(&ev.encode()).unwrap();
            assert_eq!(back, ev);
        }
        assert!(WatchEvent::decode(&Value::map().with("type", "BOGUS")).is_err());
    }

    #[test]
    fn update_preserves_identity() {
        let s = Store::new();
        let a = s.create(pod("a")).unwrap();
        let mut mod_a = a.clone();
        mod_a.meta.uid = 999; // attempts to forge identity are ignored
        mod_a.meta.creation_s = -1.0;
        let updated = s.update(mod_a).unwrap();
        assert_eq!(updated.meta.uid, a.meta.uid);
        assert_eq!(updated.meta.creation_s, a.meta.creation_s);
    }
}
