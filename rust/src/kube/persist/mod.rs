//! Persistence layer (PR 6): pluggable durability behind the [`Store`].
//!
//! The store commits every mutation through a [`StoreBackend`] before the
//! event becomes visible to readers or watchers (append-on-commit, the
//! write-ahead-log discipline). Two backends ship:
//!
//! * [`MemoryBackend`] — no-op durability; the store behaves exactly like
//!   the pre-PR-6 in-memory store.
//! * [`WalBackend`] — a directory holding `snapshot.json` (full object
//!   set, written atomically via temp-file + rename) and `wal.log` (one
//!   JSON line per committed event). Replay-on-open restores every
//!   object, the resource-version/uid counters, and the store clock, and
//!   hands back the WAL tail so the store can repopulate its watch
//!   histories — watchers reconnecting with pre-restart bookmarks get a
//!   delta replay instead of a 410-Gone full relist.
//!
//! WAL format — one record per line, in commit order:
//!
//! ```text
//! {"v":<resourceVersion>,"uid":<uid counter>,"s":<store seconds>,
//!  "type":"ADDED"|"MODIFIED"|"DELETED","object":{...}}
//! ```
//!
//! Crash safety: records are flushed per commit, so a killed process
//! loses nothing it acknowledged. A torn final line (crash mid-write) is
//! detected by its parse failure, dropped, and truncated away before new
//! appends. Snapshots are compacted every [`DEFAULT_COMPACT_THRESHOLD`]
//! appends: the full object set goes to `snapshot.json.tmp`, is renamed
//! over `snapshot.json`, and only then is the log truncated — a crash
//! between the two replays WAL records already covered by the snapshot,
//! which recovery skips by version (idempotent).

use super::api::KubeObject;
use super::store::WatchEvent;
use crate::encoding::{json, Value};
use crate::util::{Error, Result};
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};

/// One committed store mutation, as handed to [`StoreBackend::append`].
#[derive(Debug, Clone, PartialEq)]
pub struct WalRecord {
    /// The store's resource version after this commit.
    pub version: u64,
    /// The store's uid counter after this commit.
    pub uid: u64,
    /// The store clock (seconds) at commit time.
    pub seconds: f64,
    pub event: WatchEvent,
}

impl WalRecord {
    pub fn encode(&self) -> Value {
        Value::map()
            .with("v", self.version)
            .with("uid", self.uid)
            .with("s", self.seconds)
            .with("type", self.event.type_str())
            .with("object", self.event.object().encode())
    }

    pub fn decode(v: &Value) -> Result<WalRecord> {
        Ok(WalRecord {
            version: v.req_int("v")? as u64,
            uid: v.req_int("uid")? as u64,
            seconds: v.get("s").and_then(|s| s.as_f64()).unwrap_or(0.0),
            event: WatchEvent::decode(v)?,
        })
    }
}

/// Everything a backend recovered on open; the store rebuilds its shards
/// from this before serving.
#[derive(Debug, Default)]
pub struct RecoveredState {
    /// The surviving object set (creations minus deletions, last write
    /// wins), in (kind, name) order.
    pub objects: Vec<KubeObject>,
    /// Resource-version counter to resume from.
    pub version: u64,
    /// Uid counter to resume from.
    pub uid: u64,
    /// Last persisted store clock; the recovered store's clock continues
    /// from here so creation timestamps (and `kubectl get` AGE columns)
    /// stay consistent across restarts.
    pub seconds: f64,
    /// The WAL tail — every event with version > `tail_floor`, in commit
    /// order. The store seeds its watch histories from this so watchers
    /// with pre-restart bookmarks ≥ `tail_floor` replay deltas instead of
    /// resetting.
    pub tail: Vec<(u64, WatchEvent)>,
    /// Versions at or below this may be missing from `tail` (the last
    /// snapshot's version): bookmarks below it must reset (410-Gone).
    pub tail_floor: u64,
}

/// The full store image a backend snapshots during compaction.
pub struct Snapshot {
    pub version: u64,
    pub uid: u64,
    pub seconds: f64,
    pub objects: Vec<KubeObject>,
}

/// Durability boundary of the [`Store`]. All calls are made under the
/// store's commit lock, so implementations see a strictly ordered,
/// single-threaded stream of records.
pub trait StoreBackend: Send {
    /// Recover persisted state on open. `None` means a fresh (or
    /// non-durable) store.
    fn load(&mut self) -> Result<Option<RecoveredState>>;

    /// Persist one committed event. Called *before* the mutation becomes
    /// visible; an `Err` aborts the commit (the client sees the error and
    /// no state changes).
    fn append(&mut self, record: &WalRecord) -> Result<()>;

    /// True when the backend wants [`StoreBackend::compact`] called (e.g.
    /// the WAL grew past its threshold). The store checks after each
    /// commit.
    fn wants_compaction(&self) -> bool {
        false
    }

    /// Write a full snapshot and drop the log it covers. Failure is
    /// non-fatal (the commit already succeeded; the log just keeps
    /// growing until the next attempt).
    fn compact(&mut self, snap: &Snapshot) -> Result<()> {
        let _ = snap;
        Ok(())
    }
}

/// No-op durability: the pre-PR-6 in-memory behavior.
#[derive(Default)]
pub struct MemoryBackend;

impl MemoryBackend {
    pub fn new() -> MemoryBackend {
        MemoryBackend
    }
}

impl StoreBackend for MemoryBackend {
    fn load(&mut self) -> Result<Option<RecoveredState>> {
        Ok(None)
    }

    fn append(&mut self, _record: &WalRecord) -> Result<()> {
        Ok(())
    }
}

/// Appends between snapshots before the backend asks for compaction.
/// Matches the store's default watch-history window: the WAL tail a
/// recovered store can replay to watchers is never shorter than what the
/// live store would have retained.
pub const DEFAULT_COMPACT_THRESHOLD: usize = 4096;

const SNAPSHOT_FILE: &str = "snapshot.json";
const SNAPSHOT_TMP: &str = "snapshot.json.tmp";
const WAL_FILE: &str = "wal.log";

/// Write-ahead log + periodic snapshot backend over a directory.
pub struct WalBackend {
    dir: PathBuf,
    writer: Option<BufWriter<File>>,
    /// Appends since the last snapshot (seeded from the recovered WAL
    /// tail length, so a reopened store compacts on schedule too).
    appended: usize,
    compact_threshold: usize,
}

fn io_err(what: &str, path: &Path, e: std::io::Error) -> Error {
    Error::internal(format!("wal {what} {}: {e}", path.display()))
}

impl WalBackend {
    /// Open (creating if needed) a WAL directory. State is read lazily by
    /// [`StoreBackend::load`].
    pub fn open(dir: impl AsRef<Path>) -> Result<WalBackend> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).map_err(|e| io_err("create dir", &dir, e))?;
        Ok(WalBackend {
            dir,
            writer: None,
            appended: 0,
            compact_threshold: DEFAULT_COMPACT_THRESHOLD,
        })
    }

    /// Override the snapshot-compaction threshold (appends between
    /// snapshots).
    pub fn with_compact_threshold(mut self, threshold: usize) -> WalBackend {
        self.compact_threshold = threshold.max(1);
        self
    }

    fn snapshot_path(&self) -> PathBuf {
        self.dir.join(SNAPSHOT_FILE)
    }

    fn wal_path(&self) -> PathBuf {
        self.dir.join(WAL_FILE)
    }

    fn writer(&mut self) -> Result<&mut BufWriter<File>> {
        if self.writer.is_none() {
            let path = self.wal_path();
            let f = OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
                .map_err(|e| io_err("open", &path, e))?;
            self.writer = Some(BufWriter::new(f));
        }
        Ok(self.writer.as_mut().unwrap())
    }
}

impl StoreBackend for WalBackend {
    fn load(&mut self) -> Result<Option<RecoveredState>> {
        let snap_path = self.snapshot_path();
        let wal_path = self.wal_path();
        let mut objects: BTreeMap<(String, String), KubeObject> = BTreeMap::new();
        let mut version = 0u64;
        let mut uid = 0u64;
        let mut seconds = 0f64;
        let mut found = false;

        if snap_path.exists() {
            found = true;
            let text = std::fs::read_to_string(&snap_path)
                .map_err(|e| io_err("read", &snap_path, e))?;
            let v = json::parse(&text)?;
            version = v.req_int("version")? as u64;
            uid = v.req_int("uid")? as u64;
            seconds = v.get("seconds").and_then(|s| s.as_f64()).unwrap_or(0.0);
            for item in v.req("objects")?.as_seq().unwrap_or(&[]) {
                let obj = KubeObject::decode(item)?;
                objects.insert((obj.kind.clone(), obj.meta.name.clone()), obj);
            }
        }
        let tail_floor = version;

        let mut tail = Vec::new();
        if wal_path.exists() {
            found = true;
            let text =
                std::fs::read_to_string(&wal_path).map_err(|e| io_err("read", &wal_path, e))?;
            // Byte offset of the end of the last intact record: a crash
            // mid-append leaves a torn final line, detected by its parse
            // failure and truncated away below.
            let mut good_end = 0usize;
            for line in text.split_inclusive('\n') {
                let trimmed = line.trim_end();
                if trimmed.is_empty() {
                    good_end += line.len();
                    continue;
                }
                let rec = match json::parse(trimmed).and_then(|v| WalRecord::decode(&v)) {
                    Ok(r) => r,
                    Err(_) => break, // torn tail
                };
                good_end += line.len();
                if rec.version <= tail_floor {
                    // Already covered by the snapshot (crash between the
                    // snapshot rename and the log truncate): skip.
                    continue;
                }
                let obj = rec.event.object();
                let key = (obj.kind.clone(), obj.meta.name.clone());
                match rec.event {
                    WatchEvent::Added(_) | WatchEvent::Modified(_) => {
                        objects.insert(key, obj.clone());
                    }
                    WatchEvent::Deleted(_) => {
                        objects.remove(&key);
                    }
                }
                version = version.max(rec.version);
                uid = uid.max(rec.uid);
                if rec.seconds > seconds {
                    seconds = rec.seconds;
                }
                tail.push((rec.version, rec.event));
            }
            if good_end < text.len() {
                let f = OpenOptions::new()
                    .write(true)
                    .open(&wal_path)
                    .map_err(|e| io_err("open", &wal_path, e))?;
                f.set_len(good_end as u64).map_err(|e| io_err("truncate", &wal_path, e))?;
            }
        }

        if !found {
            return Ok(None);
        }
        self.appended = tail.len();
        Ok(Some(RecoveredState {
            objects: objects.into_values().collect(),
            version,
            uid,
            seconds,
            tail,
            tail_floor,
        }))
    }

    fn append(&mut self, record: &WalRecord) -> Result<()> {
        let path = self.wal_path();
        let w = self.writer()?;
        let line = json::to_string(&record.encode());
        w.write_all(line.as_bytes())
            .and_then(|_| w.write_all(b"\n"))
            .and_then(|_| w.flush())
            .map_err(|e| io_err("append", &path, e))?;
        self.appended += 1;
        Ok(())
    }

    fn wants_compaction(&self) -> bool {
        self.appended >= self.compact_threshold
    }

    fn compact(&mut self, snap: &Snapshot) -> Result<()> {
        let tmp = self.dir.join(SNAPSHOT_TMP);
        let mut doc = Value::map()
            .with("version", snap.version)
            .with("uid", snap.uid)
            .with("seconds", snap.seconds);
        doc.insert(
            "objects",
            Value::Seq(snap.objects.iter().map(|o| o.encode()).collect()),
        );
        std::fs::write(&tmp, json::to_string(&doc)).map_err(|e| io_err("write", &tmp, e))?;
        let snap_path = self.snapshot_path();
        std::fs::rename(&tmp, &snap_path).map_err(|e| io_err("rename", &snap_path, e))?;
        // Snapshot durable under its final name: the log it covers can go.
        self.writer = None;
        let wal_path = self.wal_path();
        File::create(&wal_path).map_err(|e| io_err("truncate", &wal_path, e))?;
        self.appended = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kube::api::KIND_POD;

    fn tmp_dir(tag: &str) -> PathBuf {
        static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let d = std::env::temp_dir().join(format!(
            "hpcorc-wal-{tag}-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn pod(name: &str, v: u64, uid: u64) -> KubeObject {
        let mut o = KubeObject::new(KIND_POD, name, Value::map().with("x", 1i64));
        o.meta.resource_version = v;
        o.meta.uid = uid;
        o
    }

    fn rec(v: u64, uid: u64, ev: WatchEvent) -> WalRecord {
        WalRecord { version: v, uid, seconds: v as f64, event: ev }
    }

    #[test]
    fn wal_record_wire_roundtrip() {
        let r = rec(7, 3, WatchEvent::Modified(pod("a", 7, 3)));
        let back = WalRecord::decode(&json::parse(&json::to_string(&r.encode())).unwrap())
            .unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn replay_restores_objects_counters_and_tail() {
        let dir = tmp_dir("replay");
        let mut b = WalBackend::open(&dir).unwrap();
        assert!(b.load().unwrap().is_none(), "fresh dir recovers nothing");
        b.append(&rec(1, 1, WatchEvent::Added(pod("a", 1, 1)))).unwrap();
        b.append(&rec(2, 2, WatchEvent::Added(pod("b", 2, 2)))).unwrap();
        b.append(&rec(3, 2, WatchEvent::Modified(pod("a", 3, 1)))).unwrap();
        b.append(&rec(4, 2, WatchEvent::Deleted(pod("b", 2, 2)))).unwrap();
        drop(b);

        let mut b2 = WalBackend::open(&dir).unwrap();
        let rec = b2.load().unwrap().expect("state recovered");
        assert_eq!(rec.version, 4);
        assert_eq!(rec.uid, 2);
        assert_eq!(rec.seconds, 4.0);
        assert_eq!(rec.tail_floor, 0, "no snapshot: full tail");
        assert_eq!(rec.tail.len(), 4);
        assert_eq!(rec.objects.len(), 1, "b deleted; only a survives");
        assert_eq!(rec.objects[0].meta.name, "a");
        assert_eq!(rec.objects[0].meta.resource_version, 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_dropped_and_truncated() {
        let dir = tmp_dir("torn");
        let mut b = WalBackend::open(&dir).unwrap();
        b.append(&rec(1, 1, WatchEvent::Added(pod("a", 1, 1)))).unwrap();
        b.append(&rec(2, 2, WatchEvent::Added(pod("b", 2, 2)))).unwrap();
        drop(b);
        // Simulate a crash mid-append: garbage half-record at the tail.
        let wal = dir.join(WAL_FILE);
        let mut f = OpenOptions::new().append(true).open(&wal).unwrap();
        f.write_all(b"{\"v\":3,\"uid\":3,\"type\":\"ADD").unwrap();
        drop(f);

        let mut b2 = WalBackend::open(&dir).unwrap();
        let rec1 = b2.load().unwrap().unwrap();
        assert_eq!(rec1.version, 2, "torn record ignored");
        assert_eq!(rec1.objects.len(), 2);
        // The torn bytes were truncated: appending then reloading sees a
        // clean log.
        b2.append(&rec(3, 3, WatchEvent::Added(pod("c", 3, 3)))).unwrap();
        drop(b2);
        let rec2 = WalBackend::open(&dir).unwrap().load().unwrap().unwrap();
        assert_eq!(rec2.version, 3);
        assert_eq!(rec2.objects.len(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_snapshots_and_truncates_log() {
        let dir = tmp_dir("compact");
        let mut b = WalBackend::open(&dir).unwrap().with_compact_threshold(3);
        b.append(&rec(1, 1, WatchEvent::Added(pod("a", 1, 1)))).unwrap();
        b.append(&rec(2, 2, WatchEvent::Added(pod("b", 2, 2)))).unwrap();
        assert!(!b.wants_compaction());
        b.append(&rec(3, 2, WatchEvent::Deleted(pod("b", 2, 2)))).unwrap();
        assert!(b.wants_compaction());
        b.compact(&Snapshot {
            version: 3,
            uid: 2,
            seconds: 3.0,
            objects: vec![pod("a", 1, 1)],
        })
        .unwrap();
        assert!(!b.wants_compaction());
        // Post-compaction appends land in the fresh log.
        b.append(&rec(4, 3, WatchEvent::Added(pod("c", 4, 3)))).unwrap();
        drop(b);

        let rec1 = WalBackend::open(&dir).unwrap().load().unwrap().unwrap();
        assert_eq!(rec1.version, 4);
        assert_eq!(rec1.uid, 3);
        assert_eq!(rec1.tail_floor, 3, "bookmarks below the snapshot reset");
        assert_eq!(rec1.tail.len(), 1, "only the post-snapshot tail replays");
        assert_eq!(rec1.objects.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn replay_skips_records_covered_by_snapshot() {
        // Crash window: snapshot renamed but the log not yet truncated —
        // recovery must not double-apply (or double-count) covered records.
        let dir = tmp_dir("idem");
        let mut b = WalBackend::open(&dir).unwrap();
        b.append(&rec(1, 1, WatchEvent::Added(pod("a", 1, 1)))).unwrap();
        b.append(&rec(2, 2, WatchEvent::Added(pod("b", 2, 2)))).unwrap();
        drop(b);
        let snap = Value::map()
            .with("version", 2u64)
            .with("uid", 2u64)
            .with("seconds", 2.0)
            .with(
                "objects",
                Value::Seq(vec![pod("a", 1, 1).encode(), pod("b", 2, 2).encode()]),
            );
        std::fs::write(dir.join(SNAPSHOT_FILE), json::to_string(&snap)).unwrap();

        let rec1 = WalBackend::open(&dir).unwrap().load().unwrap().unwrap();
        assert_eq!(rec1.version, 2);
        assert_eq!(rec1.objects.len(), 2);
        assert!(rec1.tail.is_empty(), "covered records do not re-enter the tail");
        assert_eq!(rec1.tail_floor, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
