//! Controller wiring: run the admission cycle on the existing
//! watch → workqueue → reconcile runtime.
//!
//! Admission is level-triggered and global (one cycle looks at every
//! queue and workload), so each watched kind gets a thin [`Controller`]
//! whose reconcile simply runs a full cycle — any ClusterQueue,
//! LocalQueue, or workload event converges the whole system, and the
//! runner's dedup/backoff machinery rate-limits the work for free.

use super::admission::AdmissionCore;
use super::types::{KIND_CLUSTERQUEUE, KIND_LOCALQUEUE, WORKLOAD_KINDS};
use crate::cluster::Metrics;
use crate::kube::{
    ApiClient, Controller, ControllerRunner, Reconcile, SharedInformerFactory,
};
use crate::rt::Shutdown;
use crate::util::Result;
use std::sync::Arc;

/// One watched kind's hook into the shared admission core.
pub struct KueueController {
    core: Arc<AdmissionCore>,
    kind: &'static str,
}

impl KueueController {
    pub fn new(core: Arc<AdmissionCore>, kind: &'static str) -> KueueController {
        KueueController { core, kind }
    }
}

impl Controller for KueueController {
    fn kind(&self) -> &str {
        self.kind
    }

    /// Any event on any watched kind runs one global cycle; the name is
    /// irrelevant because admission decisions are inherently relative to
    /// every other queued workload.
    fn reconcile(&self, api: &dyn ApiClient, _name: &str) -> Result<Reconcile> {
        self.core.cycle(api)?;
        Ok(Reconcile::Ok)
    }
}

/// Start the admission controller: one runner per watched kind (the two
/// queue CRDs plus every workload kind), each fed by the factory's
/// shared informer for that kind. Returns the shared core so callers can
/// also step cycles deterministically.
pub fn start_admission(
    informers: &SharedInformerFactory,
    metrics: Metrics,
    shutdown: Shutdown,
) -> Arc<AdmissionCore> {
    let api: Arc<dyn ApiClient> = informers.client();
    let core = Arc::new(AdmissionCore::new(informers, metrics.clone()));
    let kinds = [KIND_CLUSTERQUEUE, KIND_LOCALQUEUE]
        .into_iter()
        .chain(WORKLOAD_KINDS.iter().copied());
    for kind in kinds {
        Arc::new(ControllerRunner::new(
            api.clone(),
            Arc::new(KueueController::new(core.clone(), kind)),
            metrics.clone(),
        ))
        .start(informers.informer(kind), shutdown.clone());
    }
    core
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Resources;
    use crate::kube::{ApiServer, PodView, KIND_POD};
    use crate::kueue::types::{
        is_admitted, ClusterQueueView, LocalQueueView, QueueResources, QUEUE_NAME_LABEL,
    };
    use std::time::{Duration, Instant};

    /// End-to-end through the daemonized runners: creating a queue and a
    /// labelled pod admits it without any manual stepping.
    #[test]
    fn daemon_admits_on_events() {
        let api = ApiServer::new(Metrics::new());
        let sd = Shutdown::new();
        let informers = SharedInformerFactory::new(api.client(), Metrics::new());
        let _core = start_admission(&informers, Metrics::new(), sd.clone());
        api.create(ClusterQueueView::build("cq", QueueResources::nodes(1))).unwrap();
        api.create(LocalQueueView::build("team", "cq")).unwrap();
        let mut pod = PodView::build("p", "img.sif", Resources::new(100, 1 << 20, 0), &[]);
        pod.meta.set_label(QUEUE_NAME_LABEL, "team");
        api.create(pod).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while !is_admitted(&api.get(KIND_POD, "p").unwrap()) {
            assert!(Instant::now() < deadline, "admission daemon never admitted the pod");
            std::thread::sleep(Duration::from_millis(5));
        }
        sd.trigger();
    }
}
