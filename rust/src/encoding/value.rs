//! Dynamic value tree shared by the YAML parser, the JSON codec, the kube
//! object store, and the red-box wire format.
//!
//! Mappings preserve insertion order (kube manifests are written for humans;
//! `kubectl get -o yaml` output should not scramble keys), implemented as an
//! association list — manifests are small, so linear key lookup is fine.

use crate::util::{Error, Result};
use std::fmt;

/// A JSON/YAML-style dynamic value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// Integers kept distinct from floats so job counts etc. round-trip.
    Int(i64),
    Float(f64),
    Str(String),
    Seq(Vec<Value>),
    /// Insertion-ordered mapping.
    Map(Vec<(String, Value)>),
}

impl Value {
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn map() -> Value {
        Value::Map(Vec::new())
    }

    /// Builder-style insert; replaces an existing key in place.
    pub fn with(mut self, key: &str, v: impl Into<Value>) -> Value {
        self.insert(key, v.into());
        self
    }

    pub fn insert(&mut self, key: &str, v: impl Into<Value>) {
        if let Value::Map(entries) = self {
            let v = v.into();
            for (k, slot) in entries.iter_mut() {
                if k == key {
                    *slot = v;
                    return;
                }
            }
            entries.push((key.to_string(), v));
        } else {
            panic!("insert on non-map Value");
        }
    }

    pub fn remove(&mut self, key: &str) -> Option<Value> {
        if let Value::Map(entries) = self {
            let idx = entries.iter().position(|(k, _)| k == key)?;
            Some(entries.remove(idx).1)
        } else {
            None
        }
    }

    /// Mapping lookup (None on non-maps and missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Map(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn get_mut(&mut self, key: &str) -> Option<&mut Value> {
        match self {
            Value::Map(entries) => {
                entries.iter_mut().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// Nested lookup: `v.path(&["spec", "batch"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_seq(&self) -> Option<&[Value]> {
        match self {
            Value::Seq(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_map(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Map(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    // ---- "required field" accessors producing parse errors, for decoders ----

    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| Error::parse(format!("missing field `{key}`")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::parse(format!("field `{key}` must be a string")))
    }

    pub fn req_int(&self, key: &str) -> Result<i64> {
        self.req(key)?
            .as_int()
            .ok_or_else(|| Error::parse(format!("field `{key}` must be an integer")))
    }

    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }

    pub fn opt_int(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Value::as_int)
    }

    pub fn opt_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Value::as_bool)
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Value {
        Value::Str(s)
    }
}
impl From<i64> for Value {
    fn from(i: i64) -> Value {
        Value::Int(i)
    }
}
impl From<u64> for Value {
    fn from(i: u64) -> Value {
        Value::Int(i as i64)
    }
}
impl From<u32> for Value {
    fn from(i: u32) -> Value {
        Value::Int(i as i64)
    }
}
impl From<usize> for Value {
    fn from(i: usize) -> Value {
        Value::Int(i as i64)
    }
}
impl From<f64> for Value {
    fn from(f: f64) -> Value {
        Value::Float(f)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Value {
        Value::Bool(b)
    }
}
impl From<Vec<Value>> for Value {
    fn from(v: Vec<Value>) -> Value {
        Value::Seq(v)
    }
}
impl<T: Into<Value>> From<Option<T>> for Value {
    fn from(v: Option<T>) -> Value {
        match v {
            Some(x) => x.into(),
            None => Value::Null,
        }
    }
}

impl fmt::Display for Value {
    /// Display as compact JSON (the canonical wire form).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&super::json::to_string(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_ops_preserve_order() {
        let mut v = Value::map().with("b", 1i64).with("a", 2i64);
        v.insert("c", "x");
        let keys: Vec<&str> =
            v.as_map().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["b", "a", "c"]);
        v.insert("b", 9i64); // replace in place keeps position
        let keys: Vec<&str> =
            v.as_map().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["b", "a", "c"]);
        assert_eq!(v.get("b").unwrap().as_int(), Some(9));
    }

    #[test]
    fn nested_path() {
        let v = Value::map().with("spec", Value::map().with("batch", "#!/bin/sh"));
        assert_eq!(v.path(&["spec", "batch"]).unwrap().as_str(), Some("#!/bin/sh"));
        assert!(v.path(&["spec", "nope"]).is_none());
    }

    #[test]
    fn req_accessors() {
        let v = Value::map().with("name", "cow").with("n", 3i64);
        assert_eq!(v.req_str("name").unwrap(), "cow");
        assert_eq!(v.req_int("n").unwrap(), 3);
        assert!(v.req_str("missing").is_err());
        assert!(v.req_int("name").is_err());
    }

    #[test]
    fn conversions() {
        assert_eq!(Value::from(3u32), Value::Int(3));
        assert_eq!(Value::from(Some("x")), Value::str("x"));
        assert_eq!(Value::from(None::<i64>), Value::Null);
        assert_eq!(Value::from(true), Value::Bool(true));
    }

    #[test]
    fn remove_entry() {
        let mut v = Value::map().with("a", 1i64).with("b", 2i64);
        assert_eq!(v.remove("a"), Some(Value::Int(1)));
        assert_eq!(v.remove("a"), None);
        assert_eq!(v.as_map().unwrap().len(), 1);
    }
}
