//! Scheduler bind-failure reconciliation + index convergence (PR 9
//! satellite acceptance):
//!
//! 1. **Transport failure** — a whole bind batch that never reaches the
//!    server must release every reservation; the pods stay Pending and
//!    rebind on a later cycle once the transport heals.
//! 2. **Per-item failure** — one poisoned bind in a batch requeues only
//!    its own pod and leaves no phantom usage behind: the freed capacity
//!    is immediately placeable, down to the last millicore.
//! 3. **Resync convergence** — severing the watch streams and
//!    overflowing the pod shard's retained history forces a relist +
//!    epoch bump; the index must rebuild to exactly the fixed point a
//!    fresh-start scheduler computes (same shape as `tests/informer.rs`).

use hpcorc::cluster::{Metrics, Resources};
use hpcorc::encoding::Value;
use hpcorc::kube::{
    ApiClient, ApiServer, BatchPatchItem, KubeObject, KubeScheduler, ListOptions, NodeView,
    ObjectList, PodView, SharedInformerFactory, WatchEvent, KIND_POD,
};
use hpcorc::rt::Shutdown;
use hpcorc::util::{Error, Result};
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// ApiClient wrapper with three failure injectors: whole-batch transport
/// failures, per-item bind poisoning (the poisoned item is NOT applied
/// server-side — a failed bind must not secretly land), and severable
/// watch streams (the `tests/informer.rs` resync shape).
struct FaultyApi {
    api: ApiServer,
    fail_batches: AtomicBool,
    poison: Mutex<BTreeSet<String>>,
    taps: Mutex<Vec<Shutdown>>,
}

impl FaultyApi {
    fn new(api: ApiServer) -> Arc<FaultyApi> {
        Arc::new(FaultyApi {
            api,
            fail_batches: AtomicBool::new(false),
            poison: Mutex::new(BTreeSet::new()),
            taps: Mutex::new(Vec::new()),
        })
    }

    fn fail_batches(&self, on: bool) {
        self.fail_batches.store(on, Ordering::SeqCst);
    }

    fn poison(&self, pod: &str) {
        self.poison.lock().unwrap().insert(pod.to_string());
    }

    fn heal(&self, pod: &str) {
        self.poison.lock().unwrap().remove(pod);
    }

    fn kill_streams(&self) {
        for sd in self.taps.lock().unwrap().drain(..) {
            sd.trigger();
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

impl ApiClient for FaultyApi {
    fn create(&self, obj: KubeObject) -> Result<KubeObject> {
        self.api.create(obj)
    }
    fn get(&self, kind: &str, name: &str) -> Result<KubeObject> {
        self.api.get(kind, name)
    }
    fn update(&self, obj: KubeObject) -> Result<KubeObject> {
        ApiServer::update(&self.api, obj)
    }
    fn update_status(
        &self,
        kind: &str,
        name: &str,
        f: &dyn Fn(&mut KubeObject),
    ) -> Result<KubeObject> {
        self.api.update_status(kind, name, f)
    }
    fn patch_merge(&self, kind: &str, name: &str, patch: &Value) -> Result<KubeObject> {
        self.api.patch_merge(kind, name, patch)
    }
    fn update_status_batch(
        &self,
        items: &[BatchPatchItem],
    ) -> Result<Vec<Result<KubeObject>>> {
        if self.fail_batches.load(Ordering::SeqCst) {
            return Err(Error::rpc("injected: bind batch lost in transit"));
        }
        // Poisoned items are rejected *without* applying — the server
        // only ever sees the clean subset.
        let poison = self.poison.lock().unwrap().clone();
        let clean: Vec<BatchPatchItem> =
            items.iter().filter(|it| !poison.contains(&it.name)).cloned().collect();
        let mut applied = self.api.update_status_batch(&clean).into_iter();
        Ok(items
            .iter()
            .map(|it| {
                if poison.contains(&it.name) {
                    Err(Error::conflict(it.kind.as_str(), it.name.as_str()))
                } else {
                    applied.next().expect("one result per forwarded item")
                }
            })
            .collect())
    }
    fn delete(&self, kind: &str, name: &str) -> Result<KubeObject> {
        self.api.delete(kind, name)
    }
    fn apply(&self, obj: KubeObject) -> Result<KubeObject> {
        self.api.apply(obj)
    }
    fn list(&self, kind: &str, opts: &ListOptions) -> Result<ObjectList> {
        self.api.list_opts(kind, opts)
    }
    fn watch(&self, kind: Option<&str>, from: u64) -> Result<Receiver<WatchEvent>> {
        let upstream = ApiServer::watch(&self.api, kind, from);
        let (tx, rx) = channel();
        let sd = Shutdown::new();
        self.taps.lock().unwrap().push(sd.clone());
        hpcorc::rt::spawn_named("faulty-watch", move || loop {
            if sd.is_triggered() {
                return; // drops tx: stream severed
            }
            match upstream.recv_timeout(Duration::from_millis(1)) {
                Ok(ev) => {
                    if tx.send(ev).is_err() {
                        return;
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(_) => return,
            }
        });
        Ok(rx)
    }
    fn server_time_s(&self) -> Result<f64> {
        Ok(self.api.now_s())
    }
}

fn setup(api: ApiServer) -> (Arc<FaultyApi>, SharedInformerFactory, KubeScheduler, Metrics) {
    let faulty = FaultyApi::new(api);
    let client: Arc<dyn ApiClient> = faulty.clone();
    let informers = SharedInformerFactory::new(client, Metrics::new());
    let metrics = Metrics::new();
    let sched = KubeScheduler::new(&informers, metrics.clone());
    (faulty, informers, sched, metrics)
}

fn add_pod(api: &ApiServer, name: &str, cpu_milli: u64) {
    api.create(PodView::build(name, "img.sif", Resources::new(cpu_milli, 1 << 20, 0), &[]))
        .unwrap();
}

fn node_of(api: &ApiServer, pod: &str) -> Option<String> {
    api.get(KIND_POD, pod).unwrap().spec.opt_str("nodeName").map(String::from)
}

/// ApiClient wrapper whose `update_status_batch` blocks on a shared gate
/// — models a committer stuck in a slow API round trip while the
/// scheduler keeps producing placements behind it.
struct GatedApi {
    api: ApiServer,
    gate: Arc<Mutex<()>>,
    batch_calls: std::sync::atomic::AtomicUsize,
}

impl ApiClient for GatedApi {
    fn create(&self, obj: KubeObject) -> Result<KubeObject> {
        self.api.create(obj)
    }
    fn get(&self, kind: &str, name: &str) -> Result<KubeObject> {
        self.api.get(kind, name)
    }
    fn update(&self, obj: KubeObject) -> Result<KubeObject> {
        ApiServer::update(&self.api, obj)
    }
    fn update_status(
        &self,
        kind: &str,
        name: &str,
        f: &dyn Fn(&mut KubeObject),
    ) -> Result<KubeObject> {
        self.api.update_status(kind, name, f)
    }
    fn patch_merge(&self, kind: &str, name: &str, patch: &Value) -> Result<KubeObject> {
        self.api.patch_merge(kind, name, patch)
    }
    fn update_status_batch(&self, items: &[BatchPatchItem]) -> Result<Vec<Result<KubeObject>>> {
        self.batch_calls.fetch_add(1, Ordering::SeqCst);
        let _held = self.gate.lock().unwrap(); // blocks while the test holds it
        Ok(self.api.update_status_batch(items))
    }
    fn delete(&self, kind: &str, name: &str) -> Result<KubeObject> {
        self.api.delete(kind, name)
    }
    fn apply(&self, obj: KubeObject) -> Result<KubeObject> {
        self.api.apply(obj)
    }
    fn list(&self, kind: &str, opts: &ListOptions) -> Result<ObjectList> {
        self.api.list_opts(kind, opts)
    }
    fn watch(&self, kind: Option<&str>, from: u64) -> Result<Receiver<WatchEvent>> {
        Ok(ApiServer::watch(&self.api, kind, from))
    }
    fn server_time_s(&self) -> Result<f64> {
        Ok(self.api.now_s())
    }
}

/// PR 10 satellite: backpressure coalescing in the committer. While one
/// commit is stuck in its API round trip, every batch the scheduler
/// queues behind it must merge into ONE follow-up commit (counted by
/// `kube.sched.commit_batches_coalesced`) — and every pod still binds
/// exactly once.
#[test]
fn committer_coalesces_batches_queued_behind_a_slow_commit() {
    let raw = ApiServer::new(Metrics::new());
    let gate = Arc::new(Mutex::new(()));
    let gated = Arc::new(GatedApi {
        api: raw.clone(),
        gate: gate.clone(),
        batch_calls: std::sync::atomic::AtomicUsize::new(0),
    });
    let client: Arc<dyn ApiClient> = gated.clone();
    let informers = SharedInformerFactory::new(client, Metrics::new());
    let metrics = Metrics::new();
    let sched = KubeScheduler::new(&informers, metrics.clone());
    raw.create(NodeView::build("big", Resources::cores(64, 32 << 30), &[])).unwrap();

    let shutdown = hpcorc::rt::Shutdown::new();
    let held = gate.lock().unwrap(); // committer will block on its first batch
    sched.start(Duration::from_millis(1), shutdown.clone());

    // First wave: one pod -> one batch -> the committer blocks on it.
    add_pod(&raw, "q0", 500);
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while gated.batch_calls.load(Ordering::SeqCst) == 0 {
        assert!(std::time::Instant::now() < deadline, "committer never picked up a batch");
        std::thread::sleep(Duration::from_millis(2));
    }
    // Two more waves, each given ample time to be scheduled into its own
    // queued batch while the committer is still stuck on wave one.
    add_pod(&raw, "q1", 500);
    std::thread::sleep(Duration::from_millis(100));
    add_pod(&raw, "q2", 500);
    std::thread::sleep(Duration::from_millis(100));

    drop(held); // API round trip completes; the backlog drains

    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let bound = ["q0", "q1", "q2"]
            .iter()
            .filter(|p| node_of(&raw, p).as_deref() == Some("big"))
            .count();
        if bound == 3 {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "pods never all bound: {bound}/3");
        std::thread::sleep(Duration::from_millis(5));
    }
    shutdown.trigger();

    assert!(
        metrics.counter_value("kube.sched.commit_batches_coalesced") >= 1,
        "batches queued behind the stuck commit must coalesce"
    );
    assert_eq!(
        gated.batch_calls.load(Ordering::SeqCst),
        2,
        "the whole backlog must drain as one merged commit"
    );
}

/// A bind batch lost in transit releases every reservation; the pods
/// rebind as soon as the transport heals — no lost pods, no phantom
/// usage.
#[test]
fn transport_failure_unreserves_and_rebinds() {
    let raw = ApiServer::new(Metrics::new());
    let (faulty, _informers, sched, metrics) = setup(raw.clone());
    raw.create(NodeView::build("w1", Resources::cores(8, 32 << 30), &[])).unwrap();
    add_pod(&raw, "p1", 500);
    add_pod(&raw, "p2", 500);

    faulty.fail_batches(true);
    assert_eq!(sched.run_cycle(), 0, "nothing binds through a dead transport");
    assert!(node_of(&raw, "p1").is_none());
    assert!(node_of(&raw, "p2").is_none());
    assert!(!sched.index().is_reserved("p1"), "failed batch must release reservations");
    assert!(!sched.index().is_reserved("p2"));
    assert_eq!(
        metrics.counter_value_with("kube.sched.bind_failed", &[("outcome", "transport")]),
        2
    );

    faulty.fail_batches(false);
    assert_eq!(sched.run_cycle(), 2, "healed transport: both pods rebind");
    assert_eq!(node_of(&raw, "p1").as_deref(), Some("w1"));
    assert_eq!(node_of(&raw, "p2").as_deref(), Some("w1"));
    // The echo converts reservations to confirmed usage; nothing stays
    // reserved once the informers have caught up.
    sched.run_cycle();
    assert!(!sched.index().is_reserved("p1"));
    assert!(!sched.index().is_reserved("p2"));
}

/// One poisoned bind inside a batch requeues only its own pod — and the
/// un-reservation is exact: after the victim finally lands, the node is
/// full to the last millicore and a pod sized for the exact remainder
/// still fits (phantom usage would push it out).
#[test]
fn per_item_failure_requeues_only_the_victim() {
    let raw = ApiServer::new(Metrics::new());
    let (faulty, _informers, sched, metrics) = setup(raw.clone());
    raw.create(NodeView::build("n1", Resources::cores(1, 32 << 30), &[])).unwrap(); // 1000m
    add_pod(&raw, "pa", 600);
    add_pod(&raw, "pb", 300);

    faulty.poison("pa");
    assert_eq!(sched.run_cycle(), 1, "pb binds; pa's conflict only hits pa");
    assert_eq!(node_of(&raw, "pb").as_deref(), Some("n1"));
    assert!(node_of(&raw, "pa").is_none(), "poisoned bind must not land");
    assert!(!sched.index().is_reserved("pa"));
    assert_eq!(
        metrics.counter_value_with("kube.sched.bind_failed", &[("outcome", "conflict")]),
        1
    );

    faulty.heal("pa");
    assert_eq!(sched.run_cycle(), 1, "pa requeues and binds");
    assert_eq!(node_of(&raw, "pa").as_deref(), Some("n1"));

    // 600 + 300 committed: exactly 100m left. If pa's failed first
    // attempt had leaked usage, this pod could never fit.
    add_pod(&raw, "pc", 100);
    assert_eq!(sched.run_cycle(), 1);
    assert_eq!(node_of(&raw, "pc").as_deref(), Some("n1"));
}

/// Watch loss + a write burst past the pod shard's retained history
/// forces a true resync (epoch bump). The rebuilt index must reach the
/// fresh-start fixed point: capacity freed during the outage is
/// placeable, and a brand-new scheduler over the same world finds
/// nothing left to do.
#[test]
fn resync_rebuilds_index_to_fresh_start_fixed_point() {
    let raw = ApiServer::with_history_cap(Metrics::new(), 64);
    let (faulty, informers, sched, _metrics) = setup(raw.clone());
    raw.create(NodeView::build("n1", Resources::cores(1, 32 << 30), &[])).unwrap(); // 1000m
    add_pod(&raw, "hold", 800);
    assert_eq!(sched.run_cycle(), 1);
    assert_eq!(node_of(&raw, "hold").as_deref(), Some("n1"));
    add_pod(&raw, "big", 500);
    assert_eq!(sched.run_cycle(), 0, "800m held: 500m cannot fit");

    let epoch_before = informers.informer(KIND_POD).epoch();
    faulty.kill_streams();
    // While the scheduler is blind: free the capacity, then bury the
    // bookmark under a burst larger than the retained window, so
    // recovery cannot be a quiet delta relist.
    raw.update_status(KIND_POD, "hold", |o| {
        o.status.insert("phase", "Succeeded");
    })
    .unwrap();
    for i in 0..200u64 {
        raw.update_status(KIND_POD, "hold", |o| {
            o.status.insert("burst", i);
        })
        .unwrap();
    }

    assert_eq!(sched.run_cycle(), 1, "resync frees the held capacity; big binds");
    assert_eq!(node_of(&raw, "big").as_deref(), Some("n1"));
    assert!(
        informers.informer(KIND_POD).epoch() > epoch_before,
        "history overflow must force a real resync, not a delta relist"
    );

    // Fixed point: a fresh-start scheduler over the same world agrees —
    // nothing to place, identical tracked usage.
    let fresh_informers = SharedInformerFactory::new(raw.client(), Metrics::new());
    let fresh = KubeScheduler::new(&fresh_informers, Metrics::new());
    assert_eq!(fresh.run_cycle(), 0);
    sched.run_cycle(); // let the echo confirm big's reservation
    assert_eq!(
        sched.index().used_on("n1"),
        fresh.index().used_on("n1"),
        "rebuilt index and fresh index must track identical usage"
    );
    assert_eq!(sched.index().node_count(), fresh.index().node_count());
}
