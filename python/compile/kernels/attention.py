"""L1 Pallas kernel: blockwise attention with online softmax (FlashAttention
re-thought for TPU, per DESIGN.md §Hardware-Adaptation).

Where the CUDA original assigns a threadblock per query tile and streams
K/V tiles through shared memory, here the grid is (batch*heads, seq/bq):
each step holds one (bq, d) query tile in VMEM and streams (bk, d) K/V
tiles with a fori_loop, maintaining the online-softmax running max `m`,
normaliser `l`, and accumulator — never materialising the (seq, seq)
score matrix in HBM.

interpret=True as everywhere (CPU PJRT cannot execute Mosaic); numerics
are validated against ref.attention_ref. Autodiff via custom_vjp with the
standard analytic backward in plain XLA.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile(dim: int, preferred: int) -> int:
    t = min(dim, preferred)
    while dim % t != 0:
        t -= 1
    return max(t, 1)


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bk, causal, bq):
    q = q_ref[0]  # (bq, d)
    d = q.shape[-1]
    seq = k_ref.shape[1]
    nk = seq // bk
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    q_row0 = pl.program_id(1) * bq

    def body(i, carry):
        m, l, acc = carry
        k = k_ref[0, pl.dslice(i * bk, bk), :]  # (bk, d) — one K tile
        v = v_ref[0, pl.dslice(i * bk, bk), :]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            rows = q_row0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(cols <= rows, s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((q.shape[0],), -1e30, dtype=jnp.float32)
    l0 = jnp.zeros((q.shape[0],), dtype=jnp.float32)
    acc0 = jnp.zeros_like(q)
    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, acc0))
    o_ref[0] = acc / l[:, None]


def attention_fwd(q, k, v, *, causal=False, bq=None, bk=None):
    """softmax(q k^T / sqrt(d)) v over (bh, seq, d) float32 operands."""
    bh, seq, d = q.shape
    assert k.shape == (bh, seq, d) and v.shape == (bh, seq, d)
    bq = bq or _tile(seq, 128)
    bk = bk or _tile(seq, 128)
    return pl.pallas_call(
        functools.partial(_kernel, bk=bk, causal=causal, bq=bq),
        grid=(bh, seq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, seq, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, seq, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, d), jnp.float32),
        interpret=True,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention(q, k, v, causal=False):
    """Differentiable blockwise attention with a Pallas forward."""
    return attention_fwd(q, k, v, causal=causal)


def _softmax_scores(q, k, causal):
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / jnp.sqrt(jnp.float32(d))
    if causal:
        seq = q.shape[1]
        mask = jnp.tril(jnp.ones((seq, seq), dtype=bool))
        s = jnp.where(mask[None, :, :], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    return p / p.sum(-1, keepdims=True)


def _vjp_fwd(q, k, v, causal):
    out = attention_fwd(q, k, v, causal=causal)
    return out, (q, k, v)


def _vjp_bwd(causal, res, g):
    q, k, v = res
    d = q.shape[-1]
    p = _softmax_scores(q, k, causal)  # (bh, sq, sk), rematerialised
    dv = jnp.einsum("bqk,bqd->bkd", p, g)
    dp = jnp.einsum("bqd,bkd->bqk", g, v)
    # softmax backward: ds = p * (dp - sum_k p*dp)
    ds = p * (dp - (p * dp).sum(-1, keepdims=True))
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    dq = jnp.einsum("bqk,bkd->bqd", ds, k) * scale
    dk = jnp.einsum("bqk,bqd->bkd", ds, q) * scale
    return dq, dk, dv


attention.defvjp(_vjp_fwd, _vjp_bwd)


def vmem_bytes(bh, seq, d, bq=None, bk=None):
    """Estimated VMEM per grid step: Q tile + streamed K/V tiles + running
    stats + output tile, f32."""
    bq = bq or _tile(seq, 128)
    bk = bk or _tile(seq, 128)
    return 4 * (bq * d + 2 * bk * d + 2 * bq + 2 * bq * d + bq * bk)
