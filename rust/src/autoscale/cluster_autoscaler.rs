//! Cluster autoscaler: grow the real Kubernetes node pool under pressure,
//! burst the overflow onto the WLM partition, shrink when idle.
//!
//! Each cycle is level-triggered over the API (the scheduler's
//! `run_cycle` shape) and walks three arms:
//!
//! 1. **Scale up** — pods that are Pending, unbound, scheduler-ready (no
//!    `schedulingGates` — suspended kueue workloads are *not* capacity
//!    pressure) and that fit no schedulable node are bin-packed into
//!    hypothetical pool-shaped nodes; that many nodes are provisioned
//!    through the [`NodeProvisioner`] (the testbed registers a real
//!    simulated kubelet per node), up to `max_nodes`.
//! 2. **Burst to WLM** — when the pool is at its cap, unschedulable pods
//!    that opted in with the [`BURST_LABEL`] label are flipped onto the
//!    tainted virtual WLM node: the pod is bound to the virtual node and
//!    a `TorqueJob`/`SlurmJob` wrapping its container is created (owned
//!    by the pod), which the operator ships to the WLM over red-box —
//!    the virtual-kubelet path of High-Performance Kubernetes
//!    (arXiv:2409.16919). The pod's phase mirrors the WLM job's until
//!    completion.
//! 3. **Scale down** — a pool node that has held no work (or only
//!    *movable* work: Deployment-owned pods that are not kueue-admitted)
//!    below 50% utilization for `scale_down_idle` is cordoned
//!    (`spec.unschedulable`), its movable pods are deleted (their
//!    Deployment recreates them elsewhere), and once empty the Node
//!    object is deleted and the kubelet deprovisioned — never below
//!    `min_nodes`, and never a node hosting a gang-admitted kueue
//!    workload: evicting one member mid-run would break the
//!    all-or-nothing guarantee the queue layer provides, so such nodes
//!    are simply not drain candidates (their quota charges are the
//!    kueue ledger's to release, not ours).

use crate::cluster::{Metrics, Resources};
use crate::encoding::Value;
use crate::kube::{
    ApiClient, EventRecorder, EvictionMode, Informer, KubeObject, NodeView, PodPhase, PodView,
    SharedInformerFactory, EVENT_NORMAL, KIND_DEPLOYMENT, KIND_NODE, KIND_POD, KIND_SLURMJOB,
    KIND_TORQUEJOB,
};
use crate::operator::{phase, LABEL_QUEUE, LABEL_WLM, VIRTUAL_KUBELET_TAINT};
use crate::util::{Error, Result};
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Component name stamped on events and audit records this controller
/// writes.
const COMPONENT: &str = "cluster-autoscaler";

/// Label marking a node as autoscaler-managed (value: the pool name).
pub const POOL_LABEL: &str = "autoscale.hpcorc.io/pool";
/// Opt-in label: an unschedulable pod carrying `burst-to-wlm: "true"` may
/// be shipped to the WLM partition when the Kubernetes pool is at its cap.
pub const BURST_LABEL: &str = "autoscale.hpcorc.io/burst-to-wlm";

/// Provisions and tears down pool nodes. The testbed implementation
/// registers/stops a real simulated kubelet; tests may create bare Node
/// objects.
pub trait NodeProvisioner: Send + Sync {
    /// Bring up a node: after this returns, a Node object named `name`
    /// carrying `labels` must exist (or be about to register itself).
    fn provision(&self, name: &str, labels: &[(&str, &str)]) -> Result<()>;
    /// Tear down the node's agent. The Node object is deleted by the
    /// autoscaler before this is called.
    fn deprovision(&self, name: &str) -> Result<()>;
}

#[derive(Debug, Clone)]
pub struct CaConfig {
    /// Pool node name prefix (`{prefix}-{index}`).
    pub pool_prefix: String,
    /// Shape of every provisioned node.
    pub node_capacity: Resources,
    /// Pool size bounds (managed nodes only; static nodes don't count).
    pub min_nodes: usize,
    pub max_nodes: usize,
    /// How long a node must stay empty/movable-underutilized before it is
    /// drained.
    pub scale_down_idle: Duration,
    /// WLM backend bursted pods are shipped to (`torque` / `slurm`);
    /// None disables bursting.
    pub burst_wlm: Option<String>,
    /// Walltime stamped on burst job scripts.
    pub burst_walltime: Duration,
}

impl Default for CaConfig {
    fn default() -> Self {
        CaConfig {
            pool_prefix: "ka".into(),
            node_capacity: Resources::cores(8, 64 << 30),
            min_nodes: 0,
            max_nodes: 4,
            scale_down_idle: Duration::from_secs(10),
            burst_wlm: Some("torque".into()),
            burst_walltime: Duration::from_secs(3600),
        }
    }
}

/// What one cycle did.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CaReport {
    pub provisioned: Vec<String>,
    pub bursted: Vec<String>,
    pub cordoned: Vec<String>,
    pub removed: Vec<String>,
    pub unschedulable: usize,
}

struct CaState {
    /// Node name → when it first became a drain candidate.
    idle_since: HashMap<String, Instant>,
    next_index: u64,
}

pub struct ClusterAutoscaler {
    api: std::sync::Arc<dyn ApiClient>,
    /// Shared caches: nodes + pods drive every arm; the WLM job caches
    /// serve burst-phase mirroring. A cycle issues zero list RPCs.
    nodes: Informer,
    pods: Informer,
    torquejobs: Informer,
    slurmjobs: Informer,
    provisioner: std::sync::Arc<dyn NodeProvisioner>,
    cfg: CaConfig,
    events: EventRecorder,
    metrics: Metrics,
    state: Mutex<CaState>,
}

impl ClusterAutoscaler {
    pub fn new(
        informers: &SharedInformerFactory,
        provisioner: std::sync::Arc<dyn NodeProvisioner>,
        cfg: CaConfig,
        metrics: Metrics,
    ) -> ClusterAutoscaler {
        ClusterAutoscaler {
            api: informers.client(),
            nodes: informers.informer(KIND_NODE),
            pods: informers.informer(KIND_POD),
            torquejobs: informers.informer(KIND_TORQUEJOB),
            slurmjobs: informers.informer(KIND_SLURMJOB),
            provisioner,
            cfg,
            events: EventRecorder::new(COMPONENT, metrics.clone()),
            metrics,
            state: Mutex::new(CaState { idle_since: HashMap::new(), next_index: 0 }),
        }
    }

    /// Run as a daemon.
    pub fn start(self, period: Duration, shutdown: crate::rt::Shutdown) {
        crate::rt::pool::spawn_ticker("cluster-autoscaler", period, shutdown, move || {
            if let Err(e) = self.run_cycle() {
                crate::warn!("autoscale", "cluster-autoscaler cycle failed: {e}");
            }
        });
    }

    /// One full cycle; public for deterministic stepping.
    pub fn run_cycle(&self) -> Result<CaReport> {
        let t0 = Instant::now();
        // Every write this cycle makes is attributed to the autoscaler in
        // the API server's audit trail (PR 8).
        let _actor = crate::obs::push_actor(COMPONENT);
        let mut report = CaReport::default();
        self.nodes.sync()?;
        self.pods.sync()?;
        let nodes = self.nodes.list();
        let pods = self.pods.list();
        let views: Vec<NodeView> =
            nodes.iter().filter_map(|n| NodeView::from_object(n).ok()).collect();

        // Usage per node from bound, non-terminal pods.
        let mut used: HashMap<&str, Resources> =
            views.iter().map(|n| (n.name.as_str(), Resources::ZERO)).collect();
        for obj in &pods {
            let Ok(v) = PodView::from_object(obj) else { continue };
            if let (Some(node), false) = (&v.node_name, v.phase.terminal()) {
                if let Some(u) = used.get_mut(node.as_str()) {
                    *u += v.requests;
                }
            }
        }

        // Scheduler-ready pending pods that fit nowhere right now. The fit
        // simulation charges each placed pod so a burst of pending pods is
        // assessed against total capacity, not each against the same free
        // space. Tainted nodes (virtual WLM nodes) are never fit targets —
        // the pods that belong there (the operator's dummy pods, via
        // toleration + nodeSelector) are placed by the real scheduler.
        struct FreeNode<'a> {
            view: &'a NodeView,
            free: Resources,
        }
        let mut free: Vec<FreeNode> = views
            .iter()
            .filter(|n| n.ready && !n.unschedulable && n.taints.is_empty())
            .map(|n| {
                let u = used.get(n.name.as_str()).copied().unwrap_or(Resources::ZERO);
                FreeNode { view: n, free: n.capacity.saturating_sub(&u) }
            })
            .collect();
        let mut unschedulable: Vec<&KubeObject> = Vec::new();
        let mut pending: Vec<&KubeObject> = pods
            .iter()
            .filter(|o| {
                PodView::from_object(o)
                    .map(|v| {
                        v.phase == PodPhase::Pending
                            && v.node_name.is_none()
                            && v.scheduling_gates.is_empty()
                    })
                    .unwrap_or(false)
            })
            .collect();
        pending.sort_by_key(|o| o.meta.name.clone());
        for obj in pending {
            let view = PodView::from_object(obj).expect("filtered above");
            let slot = free.iter_mut().find(|fnode| {
                fnode.free.fits(&view.requests)
                    && view.node_selector.iter().all(|(k, v)| {
                        fnode.view.labels.iter().any(|(nk, nv)| nk == k && nv == v)
                    })
            });
            match slot {
                Some(fnode) => fnode.free = fnode.free.saturating_sub(&view.requests),
                None => unschedulable.push(obj),
            }
        }
        report.unschedulable = unschedulable.len();
        self.metrics
            .set_gauge("autoscale.ca.unschedulable", unschedulable.len() as i64);

        // ---- arm 1: grow the pool ------------------------------------
        let pool: Vec<&NodeView> = views
            .iter()
            .filter(|n| n.labels.iter().any(|(k, _)| k == POOL_LABEL))
            .collect();
        let mut pool_size = pool.len();
        // Bin-pack the poolable unschedulable pods into virtual new nodes.
        let mut new_bins: Vec<Resources> = Vec::new();
        for obj in &unschedulable {
            let view = PodView::from_object(obj).expect("filtered above");
            if !view.node_selector.is_empty() || !self.cfg.node_capacity.fits(&view.requests) {
                continue; // a pool node could never host it
            }
            match new_bins.iter_mut().find(|b| b.fits(&view.requests)) {
                Some(b) => *b = b.saturating_sub(&view.requests),
                None => new_bins.push(self.cfg.node_capacity.saturating_sub(&view.requests)),
            }
        }
        let grow = new_bins.len().min(self.cfg.max_nodes.saturating_sub(pool_size));
        for _ in 0..grow {
            let name = self.next_node_name(&views);
            let labels = [(POOL_LABEL, self.cfg.pool_prefix.as_str())];
            self.provisioner.provision(&name, &labels)?;
            self.metrics.inc("autoscale.ca.nodes_provisioned");
            let _ = self.events.event_ref(
                &self.api,
                KIND_NODE,
                &name,
                None,
                EVENT_NORMAL,
                "Provisioned",
                &format!(
                    "Provisioned pool node {name} for {} unschedulable pod(s)",
                    unschedulable.len()
                ),
            );
            pool_size += 1;
            report.provisioned.push(name);
        }

        // ---- arm 2: burst to the WLM partition -----------------------
        if let Some(wlm) = &self.cfg.burst_wlm {
            let vnode = views.iter().find(|n| {
                n.taints.iter().any(|t| t == VIRTUAL_KUBELET_TAINT)
                    && n.labels.iter().any(|(k, v)| k == LABEL_WLM && v == wlm)
            });
            // The K8s partition counts as exhausted for a pod when the
            // pool is at its cap (and nothing just came up that the next
            // scheduler pass might use), or when no pool node could ever
            // host the pod's shape — growing would not help it.
            let pool_capped =
                pool_size >= self.cfg.max_nodes && report.provisioned.is_empty();
            if let Some(vnode) = vnode {
                for obj in &unschedulable {
                    if obj.meta.label(BURST_LABEL) != Some("true")
                        || obj.status.opt_str("burstJob").is_some()
                    {
                        continue;
                    }
                    let view = PodView::from_object(obj).expect("filtered above");
                    let pool_unfittable = !self.cfg.node_capacity.fits(&view.requests);
                    if pool_capped || pool_unfittable {
                        self.burst_pod(obj, vnode, wlm)?;
                        report.bursted.push(obj.meta.name.clone());
                    }
                }
            }
            self.mirror_bursted(&pods)?;
        }

        // ---- arm 3: shrink the pool ----------------------------------
        self.scale_down(&views, &pods, &used, pool_size, &mut report)?;

        self.metrics.set_gauge("autoscale.ca.pool_nodes", pool_size as i64);
        self.metrics.observe("autoscale.ca.cycle_ns", t0.elapsed().as_nanos() as u64);
        Ok(report)
    }

    fn next_node_name(&self, views: &[NodeView]) -> String {
        let mut st = self.state.lock().unwrap();
        loop {
            let name = format!("{}-{:03}", self.cfg.pool_prefix, st.next_index);
            st.next_index += 1;
            if !views.iter().any(|n| n.name == name) {
                return name;
            }
        }
    }

    /// Bind a burst-eligible pod to the virtual node and create the WLM
    /// job object that carries its container to the HPC partition.
    fn burst_pod(&self, pod: &KubeObject, vnode: &NodeView, wlm: &str) -> Result<()> {
        let view = PodView::from_object(pod)?;
        let job_name = format!("burst-{}", view.name);
        let ppn = (view.requests.cpu_milli.div_ceil(1000)).max(1);
        let wall = crate::util::fmt_walltime(self.cfg.burst_walltime);
        let queue = vnode.labels.iter().find(|(k, _)| k == LABEL_QUEUE).map(|(_, v)| v.clone());
        let (kind, script) = if wlm == "slurm" {
            let mut s = format!(
                "#!/bin/sh\n#SBATCH -J {job_name}\n#SBATCH --nodes=1\n#SBATCH --ntasks-per-node={ppn}\n#SBATCH --time={wall}\n"
            );
            if let Some(q) = &queue {
                s.push_str(&format!("#SBATCH -p {q}\n"));
            }
            s.push_str(&format!("singularity run {}\n", view.image));
            (KIND_SLURMJOB, s)
        } else {
            let mut s = format!(
                "#!/bin/sh\n#PBS -N {job_name}\n#PBS -l nodes=1:ppn={ppn}\n#PBS -l walltime={wall}\n"
            );
            if let Some(q) = &queue {
                s.push_str(&format!("#PBS -q {q}\n"));
            }
            s.push_str(&format!("singularity run {}\n", view.image));
            (KIND_TORQUEJOB, s)
        };
        let mut job = KubeObject::new(kind, &job_name, Value::map().with("batch", script));
        job.api_version = crate::kube::WLM_API_VERSION.into();
        job.meta.owner = Some((KIND_POD.to_string(), view.name.clone()));
        job.meta.set_label("burst-pod", &view.name);
        match self.api.create(job) {
            Ok(_) => {}
            Err(ref e) if matches!(e, Error::Api(crate::util::ApiError::AlreadyExists { .. })) => {}
            Err(e) => return Err(e),
        }
        let vnode_name = vnode.name.clone();
        self.api.update_status(KIND_POD, &view.name, &|o| {
            o.spec.insert("nodeName", vnode_name.clone());
            o.status.insert("burstJob", job_name.clone());
            o.status.insert("burstKind", kind);
        })?;
        self.metrics.inc("autoscale.ca.pods_bursted");
        let _ = self.events.event(
            &self.api,
            pod,
            EVENT_NORMAL,
            "BurstToWlm",
            &format!(
                "Burst to the {wlm} partition as {kind} {job_name} via {}",
                vnode.name
            ),
        );
        Ok(())
    }

    /// Mirror WLM job phases back onto bursted pods (the virtual-kubelet
    /// "node agent" duty for pods bound to the virtual node). Job phases
    /// are read from the shared TorqueJob/SlurmJob caches.
    fn mirror_bursted(&self, pods: &[KubeObject]) -> Result<()> {
        self.torquejobs.sync()?;
        self.slurmjobs.sync()?;
        for pod in pods {
            let (Some(job), false) = (
                pod.status.opt_str("burstJob"),
                PodPhase::parse(pod.status.opt_str("phase").unwrap_or("")).terminal(),
            ) else {
                continue;
            };
            let kind = pod.status.opt_str("burstKind").unwrap_or(KIND_TORQUEJOB);
            let cache = if kind == KIND_SLURMJOB { &self.slurmjobs } else { &self.torquejobs };
            let Some(job_obj) = cache.get(job) else {
                continue; // job object gone (owner cascade) — nothing to mirror
            };
            let job_phase = job_obj.status.opt_str("phase").unwrap_or("").to_string();
            let exit = job_obj.status.opt_int("exitCode");
            let pod_phase = match job_phase.as_str() {
                phase::RUNNING => Some("Running"),
                phase::TRANSFERRING | phase::COMPLETED => Some("Succeeded"),
                phase::FAILED | phase::CANCELLED | phase::TIMEOUT => Some("Failed"),
                _ => None,
            };
            let Some(pod_phase) = pod_phase else { continue };
            if pod.status.opt_str("phase") == Some(pod_phase) {
                continue;
            }
            let job_phase_c = job_phase.clone();
            self.api.update_status(KIND_POD, &pod.meta.name, &move |o| {
                o.status.insert("phase", pod_phase);
                o.status.insert("log", format!("bursted to WLM ({job_phase_c})"));
                if pod_phase == "Succeeded" {
                    o.status.insert("exitCode", 0i64);
                } else if let Some(code) = exit {
                    o.status.insert("exitCode", code);
                }
            })?;
            if pod_phase == "Succeeded" || pod_phase == "Failed" {
                self.metrics.inc("autoscale.ca.bursts_finished");
            }
        }
        Ok(())
    }

    /// A pod the drain may delete: Deployment-owned (its controller
    /// recreates it elsewhere) and not holding a kueue admission.
    fn movable(pod: &KubeObject) -> bool {
        pod.meta.owner.as_ref().map(|(k, _)| k == KIND_DEPLOYMENT).unwrap_or(false)
            && !crate::kueue::is_admitted(pod)
            && crate::kueue::queue_name(pod).is_none()
    }

    fn scale_down(
        &self,
        views: &[NodeView],
        pods: &[KubeObject],
        used: &HashMap<&str, Resources>,
        pool_size: usize,
        report: &mut CaReport,
    ) -> Result<()> {
        let now = Instant::now();
        let mut removable_budget = pool_size.saturating_sub(self.cfg.min_nodes);
        let mut st = self.state.lock().unwrap();
        for node in views {
            if !node.labels.iter().any(|(k, _)| k == POOL_LABEL) {
                continue;
            }
            let resident: Vec<&KubeObject> = pods
                .iter()
                .filter(|p| {
                    p.spec.opt_str("nodeName") == Some(node.name.as_str())
                        && !PodPhase::parse(p.status.opt_str("phase").unwrap_or("")).terminal()
                })
                .collect();
            let u = used.get(node.name.as_str()).copied().unwrap_or(Resources::ZERO);
            let underutilized = u.dominant_fraction(&node.capacity) < 0.5;
            let candidate =
                resident.is_empty() || (underutilized && resident.iter().all(|p| Self::movable(p)));
            if !candidate {
                st.idle_since.remove(&node.name);
                continue;
            }
            let since = *st.idle_since.entry(node.name.clone()).or_insert(now);
            if now.duration_since(since) < self.cfg.scale_down_idle || removable_budget == 0 {
                continue;
            }
            // Drain: cordon first so the scheduler stops feeding it, then
            // clear movable pods; the node is removed once empty.
            if !node.unschedulable {
                self.api.update_status(KIND_NODE, &node.name, &|o| {
                    o.spec.insert("unschedulable", true);
                })?;
                self.metrics.inc("autoscale.ca.nodes_cordoned");
                report.cordoned.push(node.name.clone());
            }
            // Drain through the eviction subresource so PodDisruptionBudgets
            // are honoured: a vetoed eviction leaves the node cordoned (no
            // new pods land) and the drain retries on a later cycle when
            // the budget has headroom again.
            let mut budget_blocked = false;
            for pod in &resident {
                match self.api.evict(&pod.meta.name, &EvictionMode::Delete) {
                    Ok(_) | Err(Error::Api(crate::util::ApiError::NotFound { .. })) => {}
                    Err(e) if e.is_disruption_budget_exceeded() => {
                        self.metrics.inc("autoscale.ca.evictions_budget_blocked");
                        budget_blocked = true;
                        break;
                    }
                    Err(e) => return Err(e),
                }
            }
            if budget_blocked {
                continue;
            }
            if resident.is_empty() {
                self.api.delete(KIND_NODE, &node.name)?;
                self.provisioner.deprovision(&node.name)?;
                st.idle_since.remove(&node.name);
                removable_budget -= 1;
                self.metrics.inc("autoscale.ca.nodes_removed");
                report.removed.push(node.name.clone());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kube::ApiServer;
    use std::sync::Arc;
    use std::sync::Mutex as StdMutex;

    /// Provisioner that registers bare Node objects (no kubelet).
    struct FakeProvisioner {
        api: ApiServer,
        capacity: Resources,
        provisioned: StdMutex<Vec<String>>,
        deprovisioned: StdMutex<Vec<String>>,
    }

    impl NodeProvisioner for FakeProvisioner {
        fn provision(&self, name: &str, labels: &[(&str, &str)]) -> Result<()> {
            let mut node = NodeView::build(name, self.capacity, &[]);
            for (k, v) in labels {
                node.meta.set_label(k, v);
            }
            self.api.create(node)?;
            self.provisioned.lock().unwrap().push(name.to_string());
            Ok(())
        }
        fn deprovision(&self, name: &str) -> Result<()> {
            self.deprovisioned.lock().unwrap().push(name.to_string());
            Ok(())
        }
    }

    fn setup(cfg: CaConfig) -> (ApiServer, Arc<FakeProvisioner>, ClusterAutoscaler) {
        let api = ApiServer::new(Metrics::new());
        let prov = Arc::new(FakeProvisioner {
            api: api.clone(),
            capacity: cfg.node_capacity,
            provisioned: StdMutex::new(Vec::new()),
            deprovisioned: StdMutex::new(Vec::new()),
        });
        let informers =
            SharedInformerFactory::new(api.client(), Metrics::new());
        let ca = ClusterAutoscaler::new(&informers, prov.clone(), cfg, Metrics::new());
        (api, prov, ca)
    }

    fn pending_pod(api: &ApiServer, name: &str, cpu: u64) {
        api.create(PodView::build(name, "img.sif", Resources::new(cpu, 1 << 20, 0), &[]))
            .unwrap();
    }

    #[test]
    fn provisions_for_unschedulable_pods_up_to_max() {
        let mut cfg = CaConfig::default();
        cfg.node_capacity = Resources::cores(2, 8 << 30);
        cfg.max_nodes = 2;
        let (api, prov, ca) = setup(cfg);
        // 5 one-core pods, no nodes at all: needs 3 bins, capped at 2.
        for i in 0..5 {
            pending_pod(&api, &format!("p{i}"), 1000);
        }
        let r = ca.run_cycle().unwrap();
        assert_eq!(r.unschedulable, 5);
        assert_eq!(r.provisioned.len(), 2, "capped at max_nodes");
        assert_eq!(prov.provisioned.lock().unwrap().len(), 2);
        // Next cycle: pool at cap, no further growth.
        let r = ca.run_cycle().unwrap();
        assert!(r.provisioned.is_empty());
    }

    #[test]
    fn schedulable_and_gated_pods_trigger_nothing() {
        let mut cfg = CaConfig::default();
        cfg.node_capacity = Resources::cores(2, 8 << 30);
        let (api, _prov, ca) = setup(cfg);
        api.create(NodeView::build("static", Resources::cores(8, 32 << 30), &[])).unwrap();
        pending_pod(&api, "fits", 1000);
        let mut gated = PodView::build("gated", "img.sif", Resources::new(1000, 1 << 20, 0), &[]);
        crate::kube::add_scheduling_gate(&mut gated, "kueue.x-k8s.io/admission");
        api.create(gated).unwrap();
        let r = ca.run_cycle().unwrap();
        assert_eq!(r.unschedulable, 0, "fits on the static node; gated pod ignored");
        assert!(r.provisioned.is_empty());
    }

    #[test]
    fn bursts_labelled_pod_when_pool_capped() {
        let mut cfg = CaConfig::default();
        cfg.node_capacity = Resources::cores(1, 8 << 30);
        cfg.max_nodes = 1;
        let (api, _prov, ca) = setup(cfg);
        // Virtual node for the torque batch queue.
        let mut vnode =
            NodeView::build("vnode-torque-batch", Resources::cores(1024, 1 << 40), &[VIRTUAL_KUBELET_TAINT]);
        vnode.meta.set_label(LABEL_QUEUE, "batch");
        vnode.meta.set_label(LABEL_WLM, "torque");
        api.create(vnode).unwrap();

        let mut burstable =
            PodView::build("hpc-ok", "work.sif", Resources::new(1000, 1 << 20, 0), &[]);
        burstable.meta.set_label(BURST_LABEL, "true");
        api.create(burstable).unwrap();
        // Sorts ahead of "hpc-ok", so the fit simulation hands it the one
        // provisioned node and leaves the burstable pod unschedulable.
        pending_pod(&api, "a-plain", 1000);

        // Cycle 1 provisions the single allowed node.
        let r = ca.run_cycle().unwrap();
        assert_eq!(r.provisioned.len(), 1);
        assert!(r.bursted.is_empty(), "burst only once the pool is capped");
        // Cycle 2: pool at cap, one pod still unschedulable -> burst the
        // labelled one.
        let r = ca.run_cycle().unwrap();
        assert_eq!(r.bursted, vec!["hpc-ok"]);
        let pod = api.get(KIND_POD, "hpc-ok").unwrap();
        assert_eq!(pod.spec.opt_str("nodeName"), Some("vnode-torque-batch"));
        assert_eq!(pod.status.opt_str("burstJob"), Some("burst-hpc-ok"));
        let job = api.get(KIND_TORQUEJOB, "burst-hpc-ok").unwrap();
        let script = job.spec.opt_str("batch").unwrap();
        assert!(script.contains("#PBS -l nodes=1:ppn=1"), "{script}");
        assert!(script.contains("#PBS -q batch"));
        assert!(script.contains("singularity run work.sif"));
        assert_eq!(job.meta.owner, Some((KIND_POD.to_string(), "hpc-ok".to_string())));
        // Both scaling decisions are narrated as events.
        let events: Vec<crate::kube::EventView> = api
            .list(crate::kube::KIND_EVENT, &[])
            .iter()
            .map(|o| crate::kube::EventView::from_object(o).unwrap())
            .collect();
        let prov = events.iter().find(|e| e.reason == "Provisioned").unwrap();
        assert_eq!(prov.regarding_kind, KIND_NODE);
        assert_eq!(prov.reporting_controller, COMPONENT);
        let burst = events.iter().find(|e| e.reason == "BurstToWlm").unwrap();
        assert_eq!(burst.regarding_name, "hpc-ok");
        assert!(burst.note.contains("torque"), "{}", burst.note);
        assert!(burst.note.contains("burst-hpc-ok"), "{}", burst.note);

        // Mirror: job runs, then completes -> pod follows.
        api.update_status(KIND_TORQUEJOB, "burst-hpc-ok", |o| {
            o.status.insert("phase", phase::RUNNING);
        })
        .unwrap();
        ca.run_cycle().unwrap();
        assert_eq!(api.get(KIND_POD, "hpc-ok").unwrap().status.opt_str("phase"), Some("Running"));
        api.update_status(KIND_TORQUEJOB, "burst-hpc-ok", |o| {
            o.status.insert("phase", phase::COMPLETED);
        })
        .unwrap();
        ca.run_cycle().unwrap();
        let pod = api.get(KIND_POD, "hpc-ok").unwrap();
        assert_eq!(pod.status.opt_str("phase"), Some("Succeeded"));
        assert_eq!(pod.status.opt_int("exitCode"), Some(0));
    }

    /// A burst-eligible pod no pool node shape could ever host must not
    /// wait for unrelated load to cap the pool — it bursts immediately.
    #[test]
    fn pool_unfittable_pod_bursts_below_cap() {
        let mut cfg = CaConfig::default();
        cfg.node_capacity = Resources::cores(2, 8 << 30);
        cfg.max_nodes = 4; // plenty of pool headroom
        let (api, prov, ca) = setup(cfg);
        let mut vnode = NodeView::build(
            "vnode-torque-batch",
            Resources::cores(1024, 1 << 40),
            &[VIRTUAL_KUBELET_TAINT],
        );
        vnode.meta.set_label(LABEL_WLM, "torque");
        api.create(vnode).unwrap();
        let mut wide =
            PodView::build("wide", "work.sif", Resources::new(16_000, 1 << 20, 0), &[]);
        wide.meta.set_label(BURST_LABEL, "true");
        api.create(wide).unwrap();
        let r = ca.run_cycle().unwrap();
        assert!(r.provisioned.is_empty(), "growing cannot host a 16-core pod");
        assert_eq!(r.bursted, vec!["wide"]);
        assert!(prov.provisioned.lock().unwrap().is_empty());
        assert_eq!(
            api.get(KIND_POD, "wide").unwrap().spec.opt_str("nodeName"),
            Some("vnode-torque-batch")
        );
    }

    #[test]
    fn unlabelled_pod_never_bursts() {
        let mut cfg = CaConfig::default();
        cfg.max_nodes = 0; // pool permanently at cap
        let (api, _prov, ca) = setup(cfg);
        let mut vnode =
            NodeView::build("vnode-torque-batch", Resources::cores(1024, 1 << 40), &[VIRTUAL_KUBELET_TAINT]);
        vnode.meta.set_label(LABEL_WLM, "torque");
        api.create(vnode).unwrap();
        pending_pod(&api, "plain", 1000);
        let r = ca.run_cycle().unwrap();
        assert_eq!(r.unschedulable, 1);
        assert!(r.bursted.is_empty());
        assert!(api.get(KIND_POD, "plain").unwrap().spec.opt_str("nodeName").is_none());
    }

    #[test]
    fn scales_down_idle_node_but_not_below_min_or_admitted_work() {
        let mut cfg = CaConfig::default();
        cfg.node_capacity = Resources::cores(2, 8 << 30);
        cfg.max_nodes = 3;
        cfg.min_nodes = 0;
        cfg.scale_down_idle = Duration::from_millis(5);
        let (api, prov, ca) = setup(cfg);
        // Provision two pool nodes by pressure, then let the pods finish.
        for i in 0..2 {
            pending_pod(&api, &format!("p{i}"), 2000);
        }
        let r = ca.run_cycle().unwrap();
        assert_eq!(r.provisioned.len(), 2);
        // Pin an *admitted* kueue pod to the first pool node.
        let first = r.provisioned[0].clone();
        let mut gang = PodView::build("gang", "img.sif", Resources::new(100, 1 << 20, 0), &[]);
        gang.meta.set_label(crate::kueue::QUEUE_NAME_LABEL, "team");
        api.create(gang).unwrap();
        api.update_status(KIND_POD, "gang", |o| {
            crate::kueue::set_condition(&mut o.status, crate::kueue::COND_ADMITTED, true);
            o.spec.insert("nodeName", first.clone());
            o.status.insert("phase", "Running");
        })
        .unwrap();
        // The pressure pods complete.
        for i in 0..2 {
            api.update_status(KIND_POD, &format!("p{i}"), |o| {
                o.status.insert("phase", "Succeeded");
            })
            .unwrap();
        }
        // First cycle after the drop starts the idle clock; the next one
        // past the window drains.
        let r = ca.run_cycle().unwrap();
        assert!(r.removed.is_empty(), "idle window not yet elapsed");
        std::thread::sleep(Duration::from_millis(10));
        let r = ca.run_cycle().unwrap();
        let second = prov.provisioned.lock().unwrap()[1].clone();
        assert_eq!(r.removed, vec![second.clone()], "only the empty node drains");
        assert!(api.get(KIND_NODE, &second).is_err(), "node object deleted");
        assert!(api.get(KIND_NODE, &first).is_ok(), "admitted workload's node survives");
        assert!(!NodeView::from_object(&api.get(KIND_NODE, &first).unwrap())
            .unwrap()
            .unschedulable);
        assert_eq!(prov.deprovisioned.lock().unwrap().as_slice(), &[second]);
        // The admitted pod is untouched.
        let gang = api.get(KIND_POD, "gang").unwrap();
        assert!(crate::kueue::is_admitted(&gang));
        assert_eq!(gang.status.opt_str("phase"), Some("Running"));
    }

    #[test]
    fn drains_movable_deployment_pods_with_cordon() {
        let mut cfg = CaConfig::default();
        cfg.node_capacity = Resources::cores(8, 32 << 30);
        cfg.max_nodes = 2;
        cfg.scale_down_idle = Duration::from_millis(1);
        let (api, _prov, ca) = setup(cfg);
        pending_pod(&api, "seed", 1000);
        let r = ca.run_cycle().unwrap();
        let node = r.provisioned[0].clone();
        api.delete(KIND_POD, "seed").unwrap();
        // A lightly-loaded deployment pod lands on the pool node.
        let mut web = PodView::build("web-0", "svc.sif", Resources::new(500, 1 << 20, 0), &[]);
        web.meta.owner = Some((KIND_DEPLOYMENT.to_string(), "web".to_string()));
        api.create(web).unwrap();
        api.update_status(KIND_POD, "web-0", |o| {
            o.spec.insert("nodeName", node.clone());
            o.status.insert("phase", "Running");
        })
        .unwrap();
        ca.run_cycle().unwrap(); // starts the idle clock
        std::thread::sleep(Duration::from_millis(5));
        let r = ca.run_cycle().unwrap();
        assert_eq!(r.cordoned, vec![node.clone()], "cordon before eviction");
        assert!(api.get(KIND_POD, "web-0").is_err(), "movable pod deleted for its controller");
        assert!(
            NodeView::from_object(&api.get(KIND_NODE, &node).unwrap()).unwrap().unschedulable
        );
        // Node is empty now; the next elapsed cycle removes it.
        std::thread::sleep(Duration::from_millis(5));
        let r = ca.run_cycle().unwrap();
        assert_eq!(r.removed, vec![node.clone()]);
        assert!(api.get(KIND_NODE, &node).is_err());
    }
}
