//! Torque-Operator and WLM-Operator — the paper's system contribution.
//!
//! [`core`] holds the generic operator state machine; [`redbox_svc`] the
//! login-node RPC services and client bridges; [`virtual_node`] the
//! virtual-kubelet node registration. `TorqueOperator` extends
//! WLM-Operator with Torque support exactly as the paper describes: same
//! mechanism, different script dialect, submission binary, and status
//! mapping.

pub mod core;
pub mod redbox_svc;
pub mod virtual_node;

pub use core::{phase, OperatorConfig, WlmJobOperator};
pub use redbox_svc::{
    RedboxBridge, SlurmLoginService, TorqueLoginService, WlmBridge, WlmStatus,
};
pub use virtual_node::{
    lookup_vnode, register_virtual_nodes, vnode_name, LABEL_QUEUE, LABEL_WLM,
    VIRTUAL_KUBELET_TAINT,
};

use std::sync::Arc;

/// Convenience constructors mirroring the paper's names.
pub fn torque_operator(
    bridge: Arc<dyn WlmBridge>,
    metrics: crate::cluster::Metrics,
) -> Arc<WlmJobOperator> {
    WlmJobOperator::new(OperatorConfig::torque(), bridge, metrics)
}

pub fn wlm_operator(
    bridge: Arc<dyn WlmBridge>,
    metrics: crate::cluster::Metrics,
) -> Arc<WlmJobOperator> {
    WlmJobOperator::new(OperatorConfig::slurm(), bridge, metrics)
}
