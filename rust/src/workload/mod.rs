//! Workload substrate: job traces and generators for the evaluation
//! (paper §V future work: "The pilots of CYBELE project will be adopted
//! as the benchmarks" — we synthesise equivalent mixes).

pub mod gen;
pub mod trace;

pub use gen::TraceGen;
pub use trace::{JobKind, Trace, TraceJob};
