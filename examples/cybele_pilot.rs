//! E7 — the end-to-end driver: a CYBELE-pilot workload through the full
//! stack with REAL compute.
//!
//! Containerised crop-yield jobs (Pallas kernels → JAX train step → AOT
//! HLO → PJRT from Rust) are submitted as TorqueJobs through the
//! Kubernetes side, scheduled onto the Torque cluster by the operator,
//! executed by pbs_mom inside the Singularity runtime, and their loss
//! curves staged back through the results pods. Proves all three layers
//! compose; numbers recorded in EXPERIMENTS.md §E7.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example cybele_pilot

use hpcorc::hybrid::{Testbed, TestbedConfig};
use hpcorc::kube::{Api, WlmJobView};
use std::time::{Duration, Instant};

fn main() {
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(2);
    }
    println!("=== CYBELE pilot workload on the hybrid testbed (E7) ===\n");

    let mut cfg = TestbedConfig::default();
    cfg.torque_nodes = 4;
    cfg.artifacts_dir = Some(artifacts);
    // Compute payloads run in REAL time (PJRT steps cannot be compressed),
    // so this testbed runs uncompressed: walltimes mean what they say.
    cfg.time_scale = 1.0;
    let tb = Testbed::start(cfg).expect("testbed boot");
    // Typed handle over the unified ApiClient (default kind: TorqueJob).
    let jobs: Api<WlmJobView> = Api::new(tb.client());

    // Pilot mix: 2 training jobs (300 steps, tiny model) + 6 inference
    // bursts (20 steps each), all as TorqueJobs through the operator.
    let t0 = Instant::now();
    let mut names = Vec::new();
    for i in 0..2 {
        let name = format!("train-{i}");
        let batch = format!(
            "#!/bin/sh\n#PBS -N {name}\n#PBS -l walltime=00:30:00\n#PBS -l nodes=1:ppn=4\n#PBS -o $HOME/{name}.out\nsingularity run cropyield_train_tiny_300.sif\n"
        );
        let obj = WlmJobView::build_torquejob(&name, &batch, &format!("$HOME/{name}.out"), "$HOME/pilot/");
        jobs.create(obj).expect("create");
        names.push(name);
    }
    for i in 0..6 {
        let name = format!("infer-{i}");
        let batch = format!(
            "#!/bin/sh\n#PBS -N {name}\n#PBS -l walltime=00:10:00\n#PBS -l nodes=1:ppn=1\n#PBS -o $HOME/{name}.out\nsingularity run cropyield_infer_tiny_20.sif\n"
        );
        let obj = WlmJobView::build_torquejob(&name, &batch, &format!("$HOME/{name}.out"), "$HOME/pilot/");
        jobs.create(obj).expect("create");
        names.push(name);
    }
    println!("submitted {} TorqueJobs (2 train x300 steps, 6 infer x20 steps)", names.len());

    let mut completed = 0;
    let mut failed = 0;
    for name in &names {
        match tb.wait_torquejob(name, Duration::from_secs(600)) {
            Ok(phase) if phase == "completed" => completed += 1,
            Ok(phase) => {
                eprintln!("  {name}: terminal phase `{phase}`");
                failed += 1;
            }
            Err(e) => {
                eprintln!("  {name}: {e}");
                failed += 1;
            }
        }
    }
    let wall = t0.elapsed();
    println!("\nall jobs terminal in {:.2}s wall: {completed} completed, {failed} failed", wall.as_secs_f64());

    // The headline proof: training losses decrease.
    println!("\n--- loss curves (staged via results pods, Fig. 5 mechanism) ---");
    for i in 0..2 {
        let out = tb
            .fs
            .read_string(&format!("$HOME/pilot/train-{i}.out"))
            .expect("staged train output");
        let lines: Vec<&str> = out.lines().collect();
        println!("train-{i}: first   {}", lines.first().unwrap_or(&""));
        println!("         last    {}", lines.get(lines.len().saturating_sub(2)).unwrap_or(&""));
        println!("         summary {}", lines.last().unwrap_or(&""));
        let summary = lines.last().unwrap_or(&"");
        // "loss: a -> b over N steps"
        let decreased = summary
            .split(&[' ', ':'][..])
            .filter_map(|t| t.parse::<f32>().ok())
            .collect::<Vec<f32>>();
        if let [first, last, ..] = decreased.as_slice() {
            assert!(last < first, "loss did not decrease: {first} -> {last}");
            println!("         loss decreased {:.4} -> {:.4}  ✓", first, last);
        }
    }

    // Throughput/latency report.
    println!("\n--- throughput ---");
    println!(
        "jobs/s (wall)          : {:.2}",
        names.len() as f64 / wall.as_secs_f64()
    );
    for (k, v) in tb.metrics.snapshot() {
        if k.starts_with("pjrt.") || k.starts_with("operator.") || k == "container.starts" {
            println!("{k:<28} {v}");
        }
    }
    tb.stop();
    println!("\ncybele_pilot OK");
}
