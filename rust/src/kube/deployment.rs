//! Deployment controller: replicas of a pod template.
//!
//! Torque-Operator itself is "set as a Kubernetes deployment" and "builds
//! four Singularity containers which are deployed by Kubernetes on its
//! worker nodes to perform the corresponding services" (paper §III-B), so
//! the testbed needs a working Deployment kind, not just bare pods.

use super::api::{KubeObject, PodPhase, PodView, KIND_DEPLOYMENT, KIND_POD};
use super::client::ApiClient;
use super::controller::{Controller, Reconcile};
use super::informer::{Informer, SharedInformerFactory};
use crate::cluster::Resources;
use crate::encoding::{decode_str_map, Value};
use crate::util::Result;

pub struct DeploymentController {
    /// Shared pod cache; the `deployment` label index serves "my pods"
    /// without a list RPC.
    pods: Informer,
}

impl DeploymentController {
    pub fn new(informers: &SharedInformerFactory) -> DeploymentController {
        DeploymentController { pods: informers.informer(KIND_POD) }
    }

    /// Build a Deployment object.
    pub fn build(name: &str, replicas: u32, image: &str, requests: Resources) -> KubeObject {
        let mut req = Value::map();
        if requests.cpu_milli > 0 {
            req.insert("cpu", format!("{}m", requests.cpu_milli));
        }
        if requests.mem_bytes > 0 {
            req.insert("memory", format!("{}Mi", requests.mem_bytes >> 20));
        }
        let template = Value::map()
            .with("image", image)
            .with("resources", Value::map().with("requests", req));
        let spec = Value::map()
            .with("replicas", replicas as u64)
            .with("template", template);
        KubeObject::new(KIND_DEPLOYMENT, name, spec)
    }
}

impl Controller for DeploymentController {
    fn kind(&self) -> &str {
        KIND_DEPLOYMENT
    }

    fn reconcile(&self, api: &dyn ApiClient, name: &str) -> Result<Reconcile> {
        let deploy = match api.get(KIND_DEPLOYMENT, name) {
            Ok(d) => d,
            // Deleted: cascade handled by the API server's owner logic.
            Err(e) if e.is_not_found() => return Ok(Reconcile::Ok),
            Err(e) => return Err(e),
        };
        let want = deploy.spec.opt_int("replicas").unwrap_or(0).max(0) as usize;
        let template = deploy.spec.req("template")?;
        let image = template.req_str("image")?;
        let requests = template
            .path(&["resources", "requests"])
            .map(|r| -> Result<Resources> {
                Ok(Resources {
                    cpu_milli: r
                        .opt_str("cpu")
                        .map(Resources::parse_cpu)
                        .transpose()?
                        .unwrap_or(0),
                    mem_bytes: r
                        .opt_str("memory")
                        .map(Resources::parse_mem_k8s)
                        .transpose()?
                        .unwrap_or(0),
                    gpus: 0,
                })
            })
            .transpose()?
            .unwrap_or(Resources::ZERO);
        let env = template.get("env").map(decode_str_map).unwrap_or_default();

        // Current pods owned by this deployment, off the shared cache's
        // label index (no list RPC).
        self.pods.sync()?;
        let mut pods = self.pods.list_labelled("deployment", name);
        // Replace failed pods (restartPolicy: Always, distilled).
        let mut running = 0usize;
        for pod in pods.clone() {
            let view = PodView::from_object(&pod)?;
            if view.phase == PodPhase::Failed {
                api.delete(KIND_POD, &pod.meta.name)?;
                pods.retain(|p| p.meta.name != pod.meta.name);
            } else {
                running += 1;
                let _ = view;
            }
        }
        // Scale up.
        let mut created = 0;
        let mut idx = 0;
        while running + created < want {
            let pod_name = format!("{name}-{idx}");
            idx += 1;
            if pods.iter().any(|p| p.meta.name == pod_name) {
                continue;
            }
            let mut pod = PodView::build(&pod_name, image, requests, &env);
            pod.meta.set_label("deployment", name);
            pod.meta.owner = Some((KIND_DEPLOYMENT.to_string(), name.to_string()));
            match api.create(pod) {
                Ok(_) => created += 1,
                Err(e) if matches!(e, crate::util::Error::Api(_)) => continue,
                Err(e) => return Err(e),
            }
        }
        // Scale down (highest index first).
        let mut excess: Vec<String> = pods.iter().map(|p| p.meta.name.clone()).collect();
        excess.sort();
        while running > want {
            if let Some(victim) = excess.pop() {
                api.delete(KIND_POD, &victim)?;
                running -= 1;
            } else {
                break;
            }
        }
        // Status. Re-sync so the creates/deletes above are reflected.
        self.pods.sync()?;
        let ready = self
            .pods
            .list_labelled("deployment", name)
            .iter()
            .filter_map(|p| PodView::from_object(p).ok())
            .filter(|v| matches!(v.phase, PodPhase::Running | PodPhase::Succeeded))
            .count();
        api.update_status(KIND_DEPLOYMENT, name, &|o| {
            o.status.insert("replicas", want as u64);
            o.status.insert("readyReplicas", ready as u64);
        })?;
        // Poll until all replicas are ready (pods may still be Pending).
        if ready < want {
            Ok(Reconcile::RequeueAfter(std::time::Duration::from_millis(10)))
        } else {
            Ok(Reconcile::Ok)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Metrics;
    use crate::kube::apiserver::ApiServer;

    fn setup() -> (ApiServer, DeploymentController) {
        let api = ApiServer::new(Metrics::new());
        let informers =
            crate::kube::SharedInformerFactory::new(api.client(), Metrics::new());
        let ctrl = DeploymentController::new(&informers);
        (api, ctrl)
    }

    #[test]
    fn creates_replica_pods() {
        let (api, ctrl) = setup();
        api.create(DeploymentController::build("web", 3, "svc.sif", Resources::ZERO))
            .unwrap();
        ctrl.reconcile(&api, "web").unwrap();
        let pods = api.list(KIND_POD, &[]);
        assert_eq!(pods.len(), 3);
        assert!(pods.iter().all(|p| p.meta.label("deployment") == Some("web")));
        assert!(pods.iter().all(|p| p.meta.owner.is_some()));
    }

    #[test]
    fn scale_up_and_down() {
        let (api, ctrl) = setup();
        api.create(DeploymentController::build("web", 2, "svc.sif", Resources::ZERO))
            .unwrap();
        ctrl.reconcile(&api, "web").unwrap();
        assert_eq!(api.list(KIND_POD, &[]).len(), 2);
        // Scale to 4.
        api.update_status(KIND_DEPLOYMENT, "web", |o| {
            o.spec.insert("replicas", 4u64);
        })
        .unwrap();
        ctrl.reconcile(&api, "web").unwrap();
        assert_eq!(api.list(KIND_POD, &[]).len(), 4);
        // Scale to 1.
        api.update_status(KIND_DEPLOYMENT, "web", |o| {
            o.spec.insert("replicas", 1u64);
        })
        .unwrap();
        ctrl.reconcile(&api, "web").unwrap();
        assert_eq!(api.list(KIND_POD, &[]).len(), 1);
    }

    /// HPA edge (PR 3): minReplicas can legally be 0 — every pod goes,
    /// and scaling back up from zero works.
    #[test]
    fn scale_to_zero_and_back() {
        let (api, ctrl) = setup();
        api.create(DeploymentController::build("web", 3, "svc.sif", Resources::ZERO))
            .unwrap();
        ctrl.reconcile(&api, "web").unwrap();
        assert_eq!(api.list(KIND_POD, &[]).len(), 3);
        api.update_status(KIND_DEPLOYMENT, "web", |o| {
            o.spec.insert("replicas", 0u64);
        })
        .unwrap();
        let r = ctrl.reconcile(&api, "web").unwrap();
        assert_eq!(api.list(KIND_POD, &[]).len(), 0, "scaled to zero");
        assert_eq!(r, Reconcile::Ok, "0 of 0 ready is converged, not a requeue loop");
        let d = api.get(KIND_DEPLOYMENT, "web").unwrap();
        assert_eq!(d.status.opt_int("replicas"), Some(0));
        assert_eq!(d.status.opt_int("readyReplicas"), Some(0));
        // Back up from zero.
        api.update_status(KIND_DEPLOYMENT, "web", |o| {
            o.spec.insert("replicas", 2u64);
        })
        .unwrap();
        ctrl.reconcile(&api, "web").unwrap();
        assert_eq!(api.list(KIND_POD, &[]).len(), 2);
    }

    /// HPA edge: rapid up → down flapping between reconciles must
    /// converge on the final size without leaking or double-deleting.
    #[test]
    fn rapid_up_down_flapping_converges() {
        let (api, ctrl) = setup();
        api.create(DeploymentController::build("web", 1, "svc.sif", Resources::ZERO))
            .unwrap();
        ctrl.reconcile(&api, "web").unwrap();
        for want in [6u64, 2, 5, 1, 4] {
            api.update_status(KIND_DEPLOYMENT, "web", |o| {
                o.spec.insert("replicas", want);
            })
            .unwrap();
            ctrl.reconcile(&api, "web").unwrap();
            let pods = api.list(KIND_POD, &[]);
            assert_eq!(pods.len(), want as usize, "converged to {want}");
            // Names stay unique and owner references intact.
            let mut names: Vec<&str> =
                pods.iter().map(|p| p.meta.name.as_str()).collect();
            names.sort();
            names.dedup();
            assert_eq!(names.len(), want as usize);
            assert!(pods.iter().all(|p| p.meta.owner.is_some()));
        }
    }

    /// HPA edge: a surge while earlier replicas are still Pending must
    /// only add the difference — Pending pods count toward the target.
    #[test]
    fn surge_while_pods_still_pending() {
        let (api, ctrl) = setup();
        api.create(DeploymentController::build("web", 2, "svc.sif", Resources::ZERO))
            .unwrap();
        ctrl.reconcile(&api, "web").unwrap();
        let pods = api.list(KIND_POD, &[]);
        assert_eq!(pods.len(), 2);
        assert!(pods
            .iter()
            .all(|p| PodView::from_object(p).unwrap().phase == PodPhase::Pending));
        // Surge to 5 with both originals still Pending (unschedulable,
        // exactly what a scale-up into a full cluster looks like).
        api.update_status(KIND_DEPLOYMENT, "web", |o| {
            o.spec.insert("replicas", 5u64);
        })
        .unwrap();
        let r = ctrl.reconcile(&api, "web").unwrap();
        let pods = api.list(KIND_POD, &[]);
        assert_eq!(pods.len(), 5, "adds exactly the 3 missing replicas");
        assert!(matches!(r, Reconcile::RequeueAfter(_)), "still waiting for readiness");
        // And a partial scale-down with everything Pending removes the
        // surplus, not the originals' count.
        api.update_status(KIND_DEPLOYMENT, "web", |o| {
            o.spec.insert("replicas", 3u64);
        })
        .unwrap();
        ctrl.reconcile(&api, "web").unwrap();
        assert_eq!(api.list(KIND_POD, &[]).len(), 3);
    }

    #[test]
    fn replaces_failed_pods() {
        let (api, ctrl) = setup();
        api.create(DeploymentController::build("web", 1, "svc.sif", Resources::ZERO))
            .unwrap();
        ctrl.reconcile(&api, "web").unwrap();
        api.update_status(KIND_POD, "web-0", |o| {
            o.status.insert("phase", "Failed");
        })
        .unwrap();
        ctrl.reconcile(&api, "web").unwrap();
        let pods = api.list(KIND_POD, &[]);
        assert_eq!(pods.len(), 1);
        let view = PodView::from_object(&pods[0]).unwrap();
        assert_eq!(view.phase, PodPhase::Pending, "fresh replacement");
    }

    #[test]
    fn status_counts_ready() {
        let (api, ctrl) = setup();
        api.create(DeploymentController::build("web", 2, "svc.sif", Resources::ZERO))
            .unwrap();
        let r = ctrl.reconcile(&api, "web").unwrap();
        assert!(matches!(r, Reconcile::RequeueAfter(_)), "pods still pending");
        for p in api.list(KIND_POD, &[]) {
            api.update_status(KIND_POD, &p.meta.name, |o| {
                o.status.insert("phase", "Running");
            })
            .unwrap();
        }
        let r = ctrl.reconcile(&api, "web").unwrap();
        assert_eq!(r, Reconcile::Ok);
        let d = api.get(KIND_DEPLOYMENT, "web").unwrap();
        assert_eq!(d.status.opt_int("readyReplicas"), Some(2));
    }

    #[test]
    fn deleted_deployment_reconciles_ok() {
        let (api, ctrl) = setup();
        assert_eq!(ctrl.reconcile(&api, "ghost").unwrap(), Reconcile::Ok);
    }
}
