//! The named scenarios and their shared machinery.
//!
//! Every scenario follows the same skeleton: run the workload clean →
//! golden transcript; run it under the fault schedule → faulted
//! transcript; both through [`transcript`], which renders the converged
//! end state in `kubectl get` table shape with the load-dependent
//! columns (AGE, and pod NODE assignment) stripped — the fixed point is
//! *what* the cluster converged to, not *where* the scheduler happened
//! to place things while faults were flying.

use super::fault::{FaultLog, FaultPlan, FaultyApi, FaultyWlm};
use super::ChaosReport;
use crate::cluster::Resources;
use crate::encoding::Value;
use crate::hybrid::{Testbed, TestbedConfig};
use crate::kube::{
    add_scheduling_gate, ApiClient, CrdView, EvictionMode, KubeObject, ListOptions, NodeView,
    PdbView, PodPhase, PodView, RemoteApi, KIND_NODE, KIND_POD, KIND_PODDISRUPTIONBUDGET,
    KIND_TORQUEJOB,
};
use crate::operator::WlmBridge;
use crate::singularity::{Payload, SifImage};
use crate::util::{Error, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Independent PCG streams off the scenario seed — one per boundary so
/// adding draws at one boundary never shifts another's schedule.
const STREAM_API: u64 = 1;
const STREAM_WLM: u64 = 2;

const CONVERGE_TIMEOUT: Duration = Duration::from_secs(30);

// ------------------------------------------------------------ transcript

/// AGE-stripped `kubectl get`-style rendering of the cluster's fixed
/// point: pods (NAME STATUS), nodes (NAME READY CORDONED), torquejobs
/// (NAME PHASE), each section sorted by name. Pod NODE assignment is
/// deliberately omitted — placement is load-order-dependent under
/// faults; the fixed point the harness pins is object-and-phase level.
pub fn transcript(api: &dyn ApiClient) -> String {
    let mut out = String::new();
    let list = |kind: &str| -> Vec<KubeObject> {
        let mut items = api.list(kind, &ListOptions::all()).map(|l| l.items).unwrap_or_default();
        items.sort_by(|a, b| a.meta.name.cmp(&b.meta.name));
        items
    };
    out.push_str("== pods ==\n");
    for o in list(KIND_POD) {
        let phase = o.status.opt_str("phase").unwrap_or("Pending");
        out.push_str(&format!("{} {}\n", o.meta.name, phase));
    }
    out.push_str("== nodes ==\n");
    for o in list(KIND_NODE) {
        if let Ok(n) = NodeView::from_object(&o) {
            out.push_str(&format!("{} ready={} cordoned={}\n", n.name, n.ready, n.unschedulable));
        }
    }
    out.push_str("== torquejobs ==\n");
    for o in list(KIND_TORQUEJOB) {
        let phase = o.status.opt_str("phase").unwrap_or("");
        out.push_str(&format!("{} {}\n", o.meta.name, phase));
    }
    out
}

// --------------------------------------------------------------- helpers

fn check(checks: &mut Vec<String>, cond: bool, what: &str) -> Result<()> {
    if cond {
        checks.push(what.to_string());
        Ok(())
    } else {
        Err(Error::internal(format!("chaos check failed: {what}")))
    }
}

/// `apply` with retry — the write path a consumer on a lossy transport
/// actually uses (apply is idempotent, so duplicates are harmless too).
fn apply_retry(api: &dyn ApiClient, obj: &KubeObject, timeout: Duration) -> Result<()> {
    let deadline = Instant::now() + timeout;
    loop {
        match api.apply(obj.clone()) {
            Ok(_) => return Ok(()),
            Err(e) if Instant::now() >= deadline => return Err(e),
            Err(_) => std::thread::sleep(Duration::from_millis(1)),
        }
    }
}

/// Poll (fault-tolerantly) until every named pod reaches `want`.
fn wait_pods(
    api: &dyn ApiClient,
    names: &[String],
    want: PodPhase,
    timeout: Duration,
) -> Result<()> {
    let deadline = Instant::now() + timeout;
    loop {
        let mut missing = None;
        for n in names {
            match api.get(KIND_POD, n) {
                Ok(o) if PodPhase::parse(o.status.opt_str("phase").unwrap_or("")) == want => {}
                _ => {
                    missing = Some(n.clone());
                    break;
                }
            }
        }
        match missing {
            None => return Ok(()),
            Some(n) if Instant::now() >= deadline => {
                return Err(Error::internal(format!(
                    "chaos: pod {n} never reached {want:?}"
                )))
            }
            Some(_) => std::thread::sleep(Duration::from_millis(3)),
        }
    }
}

fn echo_pod(name: &str, cpu_milli: u64) -> KubeObject {
    PodView::build(name, "chaos-echo.sif", Resources::new(cpu_milli, 32 << 20, 0), &[])
}

fn push_chaos_images(tb: &Testbed) {
    tb.images.push(SifImage::new("chaos-echo.sif", Payload::Echo { message: "chaos".into() }));
    // Nominal 800s ≈ 0.8s real at the default 0.001 time scale.
    tb.images.push(SifImage::new("chaos-sleep.sif", Payload::Sleep { millis: 800_000 }));
}

// ------------------------------------------------------- 1. redbox-drop

/// Red-box transport faults: the scenario drives its whole workload
/// through a [`FaultyApi`] over a real `RemoteApi` socket connection —
/// creates, gets, everything subject to seeded drops/delays/duplicates —
/// and must still converge to the clean run's fixed point on retries.
pub(super) fn redbox_drop(seed: u64) -> Result<ChaosReport> {
    let n_pods = 5 + (seed % 3) as usize;
    let drive = |faults: Option<(FaultPlan, FaultLog)>| -> Result<(String, Vec<String>)> {
        let tb = Testbed::start(TestbedConfig::default())?;
        push_chaos_images(&tb);
        let remote: Arc<dyn ApiClient> = Arc::new(RemoteApi::connect(tb.socket())?);
        let api: Arc<dyn ApiClient> = match faults {
            Some((plan, log)) => Arc::new(FaultyApi::new(remote, plan, log)),
            None => remote,
        };
        let names: Vec<String> = (0..n_pods).map(|i| format!("cp{i}")).collect();
        for name in &names {
            apply_retry(api.as_ref(), &echo_pod(name, 500), Duration::from_secs(10))?;
        }
        wait_pods(api.as_ref(), &names, PodPhase::Succeeded, CONVERGE_TIMEOUT)?;
        // Read the fixed point through the clean in-process client: the
        // faulted transport proved itself by driving the workload home.
        let t = transcript(tb.client().as_ref());
        tb.stop();
        Ok((t, names))
    };

    let (golden, _) = drive(None)?;
    let log = FaultLog::new();
    let plan = FaultPlan::new(seed, STREAM_API);
    let (faulted, names) = drive(Some((plan, log.clone())))?;

    let mut checks = Vec::new();
    let faults = log.take();
    check(&mut checks, !faults.is_empty(), "transport faults were injected")?;
    check(
        &mut checks,
        faults.iter().all(|f| !f.trace.is_empty()),
        "every fault carries a trace id",
    )?;
    check(
        &mut checks,
        names.len() == n_pods,
        "all pods were driven through the faulty transport",
    )?;
    Ok(ChaosReport {
        scenario: "redbox-drop".into(),
        seed,
        faults,
        golden,
        transcript: faulted,
        checks,
    })
}

// -------------------------------------------------- 2. apiserver-restart

const HOLD_GATE: &str = "chaos.hpcorc.io/hold";

/// API server killed mid-admission: workloads are created *gated* (the
/// mid-admission state — objects durable, nothing scheduled), the whole
/// testbed is torn down, then rebooted over the same WAL directory. The
/// recovered server must hold every object — including a CRD registered
/// through the API, whose short name must resolve again post-restart —
/// and, once ungated, converge to the no-restart fixed point.
pub(super) fn apiserver_restart(seed: u64) -> Result<ChaosReport> {
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let n_pods = 3 + (seed % 3) as usize;
    let names: Vec<String> = (0..n_pods).map(|i| format!("rp{i}")).collect();
    let wal = |tag: &str| {
        std::env::temp_dir().join(format!(
            "hpcorc-chaos-restart-{}-{}-{}-{}",
            std::process::id(),
            seed,
            tag,
            SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ))
    };

    let gated_pod = |name: &str| {
        let mut p = echo_pod(name, 500);
        add_scheduling_gate(&mut p, HOLD_GATE);
        p
    };
    let ungate = |api: &dyn ApiClient, name: &str| -> Result<()> {
        // Merge-patch with null deletes the key — retried on conflict
        // server-side, so this survives racing status writers.
        api.patch_merge(
            KIND_POD,
            name,
            &Value::map().with("spec", Value::map().with("schedulingGates", Value::Null)),
        )?;
        Ok(())
    };

    // Golden: same gated-create → ungate → converge flow, no restart.
    let golden_dir = wal("golden");
    let golden = {
        let mut cfg = TestbedConfig::default();
        cfg.wal_dir = Some(golden_dir.clone());
        let tb = Testbed::start(cfg)?;
        push_chaos_images(&tb);
        for n in &names {
            tb.api.create(gated_pod(n))?;
        }
        for n in &names {
            ungate(tb.client().as_ref(), n)?;
        }
        wait_pods(tb.client().as_ref(), &names, PodPhase::Succeeded, CONVERGE_TIMEOUT)?;
        let t = transcript(tb.client().as_ref());
        tb.stop();
        t
    };

    let mut checks = Vec::new();
    let dir = wal("faulted");
    // Phase 1: create everything gated (mid-admission), then kill.
    {
        let mut cfg = TestbedConfig::default();
        cfg.wal_dir = Some(dir.clone());
        let tb = Testbed::start(cfg)?;
        push_chaos_images(&tb);
        for n in &names {
            tb.api.create(gated_pod(n))?;
        }
        // A CRD registered through the API, plus an instance of it: both
        // must survive the restart, and the short name must resolve.
        tb.api.create(CrdView::build("chaos.hpcorc.io", "v1", "Gizmo", "gizmos", &["gz"]))?;
        let mut gizmo = KubeObject::new("Gizmo", "g1", Value::map().with("x", 1u64));
        gizmo.api_version = "chaos.hpcorc.io/v1".into();
        tb.api.create(gizmo)?;
        tb.stop(); // kill mid-admission: nothing scheduled yet
    }
    // Phase 2: reboot over the same WAL, verify recovery, release.
    let faulted = {
        let mut cfg = TestbedConfig::default();
        cfg.wal_dir = Some(dir.clone());
        let tb = Testbed::start(cfg)?;
        push_chaos_images(&tb);
        let api = tb.client();
        for n in &names {
            let p = api.get(KIND_POD, n)?;
            check(
                &mut checks,
                p.status.opt_str("phase").unwrap_or("Pending") == "Pending",
                &format!("pod {n} recovered still un-admitted"),
            )?;
        }
        check(
            &mut checks,
            api.get("gz", "g1").is_ok(),
            "CRD short name resolves after WAL recovery (gz -> Gizmo)",
        )?;
        for n in &names {
            ungate(api.as_ref(), n)?;
        }
        wait_pods(api.as_ref(), &names, PodPhase::Succeeded, CONVERGE_TIMEOUT)?;
        let t = transcript(api.as_ref());
        tb.stop();
        t
    };
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&golden_dir);
    Ok(ChaosReport {
        scenario: "apiserver-restart".into(),
        seed,
        faults: Vec::new(), // the fault is the kill itself; nothing probabilistic
        golden,
        transcript: faulted,
        checks,
    })
}

// ------------------------------------------------------------ 3. wlm-slow

/// Slow, lossy WLM backend: every bridge call under the operator is
/// subject to seeded transient failures and stalls. The operator's
/// backoff-and-retry reconcile loop must absorb all of it — the paper's
/// Fig. 3 cow job still completes and stages its results.
pub(super) fn wlm_slow(seed: u64) -> Result<ChaosReport> {
    let drive = |shim: Option<(u64, FaultLog)>| -> Result<String> {
        let mut cfg = TestbedConfig::default();
        if let Some((seed, log)) = shim {
            cfg.wlm_shim = Some(Arc::new(move |inner: Arc<dyn WlmBridge>| {
                Arc::new(FaultyWlm::new(
                    inner,
                    FaultPlan::new(seed, STREAM_WLM)
                        .with_mix(0.25, 0.30, 0.0)
                        .with_max_delay(Duration::from_millis(3)),
                    log.clone(),
                )) as Arc<dyn WlmBridge>
            }));
        }
        let tb = Testbed::start(cfg)?;
        tb.kubectl_apply(crate::kube::yaml::COW_JOB_YAML)?;
        let phase = tb.wait_torquejob("cow", CONVERGE_TIMEOUT)?;
        if phase != "completed" {
            return Err(Error::internal(format!("chaos: cow job ended `{phase}`")));
        }
        let out = tb.fs.read_string("$HOME/low.out")?;
        if !out.contains("Moo") {
            return Err(Error::internal("chaos: cow job output not staged"));
        }
        let t = transcript(tb.client().as_ref());
        tb.stop();
        Ok(t)
    };

    let golden = drive(None)?;
    let log = FaultLog::new();
    let faulted = drive(Some((seed, log.clone())))?;

    let mut checks = Vec::new();
    let faults = log.take();
    check(&mut checks, !faults.is_empty(), "WLM faults were injected")?;
    check(
        &mut checks,
        faults.iter().all(|f| f.boundary == "wlm"),
        "faults confined to the WLM boundary",
    )?;
    checks.push("cow job completed and staged results despite lossy WLM".into());
    Ok(ChaosReport {
        scenario: "wlm-slow".into(),
        seed,
        faults,
        golden,
        transcript: faulted,
        checks,
    })
}

// -------------------------------------------------- 4. kubelet-death

/// A kubelet dies under running pods. Its containers keep running
/// unmanaged, its pods' status freezes — orphans. Recovery is the typed
/// disruption path end to end: eviction through `pods/eviction` (first
/// vetoed by a PodDisruptionBudget, proving budgets bind the chaos path
/// too), node deletion, recreation, convergence on the surviving nodes.
pub(super) fn kubelet_death(seed: u64) -> Result<ChaosReport> {
    const N_PODS: usize = 5;
    let names: Vec<String> = (0..N_PODS).map(|i| format!("kd{i}")).collect();
    let kd_pod = |name: &str| {
        // 4000m each: 5 pods cannot fit on two 8-core nodes, so every
        // node of the 3-worker faulted run holds at least one — the dead
        // node is guaranteed residents to orphan.
        let mut p = PodView::build(name, "chaos-sleep.sif", Resources::new(4000, 32 << 20, 0), &[]);
        p.meta.labels.push(("chaos".into(), "kd".into()));
        p
    };

    // Golden: the post-recovery world — the same workload completing on
    // the surviving node set (kw00 + login) with no third worker.
    let golden = {
        let mut cfg = TestbedConfig::default();
        cfg.kube_workers = 1;
        let tb = Testbed::start(cfg)?;
        push_chaos_images(&tb);
        for n in &names {
            tb.api.create(kd_pod(n))?;
        }
        wait_pods(tb.client().as_ref(), &names, PodPhase::Succeeded, CONVERGE_TIMEOUT)?;
        let t = transcript(tb.client().as_ref());
        tb.stop();
        t
    };

    let mut checks = Vec::new();
    let faulted = {
        let mut cfg = TestbedConfig::default();
        cfg.kube_workers = 2; // kw00, kw01 (the victim), login
        let tb = Testbed::start(cfg)?;
        push_chaos_images(&tb);
        let api = tb.client();
        for n in &names {
            api.create(kd_pod(n))?;
        }
        wait_pods(api.as_ref(), &names, PodPhase::Running, CONVERGE_TIMEOUT)?;

        // Kill the node agent. Containers on kw01 are now orphaned.
        let _actor = crate::obs::push_actor("chaos");
        let span = crate::obs::span("chaos", "fault kubelet-death kw01");
        let trace = span.context().map(|c| c.to_wire()).unwrap_or_default();
        check(&mut checks, tb.kill_kubelet("kw01"), "kubelet kw01 killed")?;
        drop(span);

        let orphans: Vec<String> = api
            .list(KIND_POD, &ListOptions::all())?
            .items
            .iter()
            .filter(|p| {
                p.spec.opt_str("nodeName") == Some("kw01")
                    && !PodPhase::parse(p.status.opt_str("phase").unwrap_or("")).terminal()
            })
            .map(|p| p.meta.name.clone())
            .collect();
        check(&mut checks, !orphans.is_empty(), "dead node had resident pods to orphan")?;

        // A budget covering the whole workload vetoes the drain: the
        // chaos path takes `pods/eviction` like every other disruptor
        // and gets the typed refusal.
        api.create(PdbView::build_min_available(
            "kd-keep",
            &[("chaos".to_string(), "kd".to_string())],
            N_PODS as i64,
        ))?;
        let err = api.evict(&orphans[0], &EvictionMode::Delete).unwrap_err();
        check(
            &mut checks,
            err.is_disruption_budget_exceeded(),
            "PDB vetoed orphan eviction with the typed DisruptionBudgetExceeded",
        )?;
        api.delete(KIND_PODDISRUPTIONBUDGET, "kd-keep")?;
        for n in &orphans {
            api.evict(n, &EvictionMode::Delete)?;
        }
        checks.push(format!(
            "{} orphans drained through pods/eviction (trace {trace})",
            orphans.len()
        ));
        api.delete(KIND_NODE, "kw01")?;
        // Recreate the lost workload; it must land on the survivors.
        for n in &orphans {
            api.create(kd_pod(n))?;
        }
        wait_pods(api.as_ref(), &names, PodPhase::Succeeded, CONVERGE_TIMEOUT)?;
        for n in &names {
            let p = api.get(KIND_POD, n)?;
            if p.spec.opt_str("nodeName") == Some("kw01") {
                return Err(Error::internal(format!("chaos: pod {n} still on the dead node")));
            }
        }
        checks.push("no pod remained bound to the dead node".into());
        let t = transcript(api.as_ref());
        tb.stop();
        t
    };

    Ok(ChaosReport {
        scenario: "kubelet-death".into(),
        seed,
        faults: Vec::new(), // the fault is the kill; injected explicitly
        golden,
        transcript: faulted,
        checks,
    })
}

// ------------------------------------------------- 5. watch-overflow

/// The server's watch-history window is sized far below the write load:
/// every reflector that blinks falls out of the retained window and must
/// take the 410-Gone relist road (PR 4/6 recovery machinery) — and the
/// cluster still converges. An explicit probe watch from an ancient
/// bookmark proves the overflow is real.
pub(super) fn watch_overflow(seed: u64) -> Result<ChaosReport> {
    const TINY_CAP: usize = 4;
    let n_pods = 10 + (seed % 4) as usize;
    let names: Vec<String> = (0..n_pods).map(|i| format!("wp{i}")).collect();
    let drive = |cap: Option<usize>| -> Result<(String, usize)> {
        let mut cfg = TestbedConfig::default();
        if let Some(cap) = cap {
            cfg.watch_history_cap = cap;
        }
        let tb = Testbed::start(cfg)?;
        push_chaos_images(&tb);
        let api = tb.client();
        for n in &names {
            api.create(echo_pod(n, 500))?;
        }
        wait_pods(api.as_ref(), &names, PodPhase::Succeeded, CONVERGE_TIMEOUT)?;
        // Probe: a watch from bookmark 1 after all this churn. With the
        // tiny window the replay is truncated (history gone) — the
        // stream ends after at most `cap` replayed events.
        let rx = api.watch(Some(KIND_POD), 1)?;
        let mut replayed = 0usize;
        while rx.recv_timeout(Duration::from_millis(250)).is_ok() {
            replayed += 1;
            if replayed > 10 * n_pods {
                break; // live tail, not replay — enough proof either way
            }
        }
        let t = transcript(api.as_ref());
        tb.stop();
        Ok((t, replayed))
    };

    let (golden, golden_replayed) = drive(None)?;
    let (faulted, faulted_replayed) = drive(Some(TINY_CAP))?;

    let mut checks = Vec::new();
    check(
        &mut checks,
        faulted_replayed <= TINY_CAP,
        "overflowed window truncated the ancient-bookmark replay (410-Gone)",
    )?;
    check(
        &mut checks,
        golden_replayed > faulted_replayed,
        "default-sized window replayed more history than the overflowed one",
    )?;
    checks.push(format!(
        "cluster converged under a {TINY_CAP}-event window ({n_pods} pods of churn)"
    ));
    Ok(ChaosReport {
        scenario: "watch-overflow".into(),
        seed,
        faults: Vec::new(), // the fault is the undersized window
        golden,
        transcript: faulted,
        checks,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Metrics;
    use crate::kube::ApiServer;

    #[test]
    fn transcript_is_sorted_and_age_free() {
        let api = ApiServer::new(Metrics::new());
        api.create(crate::kube::NodeView::build("n1", Resources::cores(8, 1 << 30), &[]))
            .unwrap();
        api.create(echo_pod("b", 100)).unwrap();
        api.create(echo_pod("a", 100)).unwrap();
        let t = transcript(api.client().as_ref());
        let a = t.find("a Pending").unwrap();
        let b = t.find("b Pending").unwrap();
        assert!(a < b, "pods sorted by name:\n{t}");
        assert!(t.contains("n1 ready="));
        assert!(!t.to_lowercase().contains("age"));
        // Stable across time: re-rendering later yields the same bytes.
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(t, transcript(api.client().as_ref()));
    }

    #[test]
    fn registry_names_are_unique_and_runnable() {
        let names: Vec<&str> = crate::chaos::scenarios().iter().map(|s| s.name).collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len());
        assert!(crate::chaos::run_scenario("no-such-scenario", 1).is_err());
    }
}
