//! The admission cycle: suspend → reserve → admit → preempt, level-
//! triggered over any [`ApiClient`].
//!
//! Each cycle rebuilds the whole picture from the API (queues, admitted
//! usage, pending gangs) and converges it one step — the same
//! crash-tolerant shape as the scheduler's `run_cycle`. Workloads whose
//! quota cannot be reserved are simply *left alone* (their missing
//! `Admitted` condition is the suspension — scheduler and operator gate
//! on it), so a crashed controller resumes from the objects themselves.
//!
//! Gangs are atomic throughout: a multi-node WlmJob is one indivisible
//! demand, a pod group only becomes admissible once all declared members
//! exist, and the `Admitted` conditions of a gang's members are only ever
//! written after the *entire* gang's quota was reserved in the ledger.

use super::preemption::{evict_gang, select_victims, AdmittedGang};
use super::quota::{Fit, Ledger};
use super::types::{
    is_admitted, queue_name, set_condition, workload_demand, workload_priority,
    workload_terminal, ClusterQueueView, LocalQueueView, QueueOrdering, QueueResources,
    COND_ADMITTED, COND_EVICTED, COND_QUOTA_RESERVED, KIND_CLUSTERQUEUE, KIND_LOCALQUEUE,
    POD_GROUP_COUNT_ANNOTATION, POD_GROUP_LABEL, SCHEDULING_GATE, WORKLOAD_KINDS,
};
use crate::cluster::Metrics;
use crate::kube::{
    add_scheduling_gate, remove_scheduling_gate, scheduling_gates, ApiClient, KubeObject,
    ListOptions, KIND_POD,
};
use crate::util::Result;
use std::collections::BTreeMap;

/// What one cycle did (workload-object granularity).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CycleReport {
    /// Workload objects newly admitted this cycle.
    pub admitted: usize,
    /// Workload objects evicted by preemption this cycle.
    pub preempted: usize,
    /// Workload objects still gated after this cycle.
    pub pending: usize,
}

/// A not-yet-admitted gang under consideration.
#[derive(Debug, Clone)]
struct PendingGang {
    members: Vec<(String, String)>,
    /// ClusterQueue charged on admission.
    cq: String,
    /// The raw queue-name label (LocalQueue counts key).
    label: String,
    demand: QueueResources,
    priority: i64,
    /// Min member uid: FIFO key (uids are assigned in creation order).
    uid: u64,
    /// Pod groups: all declared members present?
    complete: bool,
}

/// The admission controller core. Stateless between cycles by design;
/// cycles themselves are serialized (see [`AdmissionCore::cycle`]).
pub struct AdmissionCore {
    metrics: Metrics,
    /// Serializes cycles: the shared core is driven from one runner
    /// thread per watched kind, and two concurrent cycles holding
    /// divergent list snapshots could each admit a different gang
    /// against the same quota headroom (the reservation lives only in
    /// the running cycle's ledger). Under the lock, every cycle lists
    /// *after* the previous cycle's admission writes landed.
    serial: std::sync::Mutex<()>,
}

impl AdmissionCore {
    pub fn new(metrics: Metrics) -> AdmissionCore {
        AdmissionCore { metrics, serial: std::sync::Mutex::new(()) }
    }

    /// One full admission cycle. Public for deterministic stepping in
    /// tests and benches; the controller runtime calls it on every queue
    /// or workload event.
    pub fn cycle(&self, api: &dyn ApiClient) -> Result<CycleReport> {
        let _one_at_a_time = self.serial.lock().unwrap();
        let t0 = std::time::Instant::now();
        self.metrics.inc("kueue.cycles");

        // ---- the queue topology -------------------------------------
        let cq_objs = api.list(KIND_CLUSTERQUEUE, &ListOptions::all())?.items;
        let cqs: Vec<ClusterQueueView> = cq_objs
            .iter()
            .filter_map(|o| ClusterQueueView::from_object(o).ok())
            .collect();
        let lq_objs = api.list(KIND_LOCALQUEUE, &ListOptions::all())?.items;
        let lqs: Vec<LocalQueueView> =
            lq_objs.iter().filter_map(|o| LocalQueueView::from_object(o).ok()).collect();
        if cqs.is_empty() && lqs.is_empty() {
            // No queue topology: nothing can be admitted and no counts
            // can change. Skip the workload listing entirely so clusters
            // that never opted into queueing pay ~nothing per event.
            return Ok(CycleReport::default());
        }
        let resolve = |label: &str| -> Option<String> {
            lqs.iter()
                .find(|lq| lq.name == label)
                .map(|lq| lq.cluster_queue.clone())
                .or_else(|| {
                    cqs.iter().find(|cq| cq.name == label).map(|cq| cq.name.clone())
                })
                .filter(|cq| cqs.iter().any(|c| &c.name == cq))
        };

        // ---- workloads ----------------------------------------------
        // Group by (queue label, pod group); solitary workloads are their
        // own group. Admitted and pending members of the same group
        // accumulate separately (keyed by the admitted flag): a
        // partially-admitted group (crash mid-write) thus splits — the
        // admitted members charge the ledger, the remainder forms a
        // pending gang — and re-running the cycle completes the admission.
        let mut gangs: BTreeMap<(bool, String, String), PendingGang> = BTreeMap::new();
        let mut declared_counts: BTreeMap<(String, String), usize> = BTreeMap::new();
        let mut group_sizes: BTreeMap<(String, String), usize> = BTreeMap::new();
        let mut poisoned: std::collections::BTreeSet<(String, String)> =
            std::collections::BTreeSet::new();
        for kind in WORKLOAD_KINDS {
            for obj in api.list(kind, &ListOptions::all())?.items {
                let Some(label) = queue_name(&obj).map(String::from) else { continue };
                // Back-fill the scheduling gate on labelled pods created
                // without one (the [`super::types::queue_workload`]
                // builder sets it at birth; this converges stragglers so
                // the scheduler cannot race a suspended pod onto a node).
                if *kind == KIND_POD
                    && !is_admitted(&obj)
                    && !workload_terminal(&obj)
                    && !scheduling_gates(&obj).iter().any(|g| g == SCHEDULING_GATE)
                {
                    let _ = api.update_status(KIND_POD, &obj.meta.name, &|o| {
                        if !is_admitted(o) {
                            add_scheduling_gate(o, SCHEDULING_GATE);
                        }
                    });
                    self.metrics.inc("kueue.gates_backfilled");
                }
                // Admitted workloads charge the ClusterQueue stamped on
                // them at admission time — deleting or retargeting a
                // LocalQueue must not drop live charges (overcommit);
                // pending workloads resolve through the live topology.
                let stamped = obj.status.opt_str("clusterQueue").map(String::from);
                let resolved = if is_admitted(&obj) {
                    stamped.or_else(|| resolve(&label))
                } else {
                    resolve(&label)
                };
                let Some(cq) = resolved else {
                    self.metrics.inc("kueue.unresolved_queue");
                    continue; // stays suspended until its queue exists
                };
                let group = obj
                    .meta
                    .label(POD_GROUP_LABEL)
                    .map(String::from)
                    .unwrap_or_else(|| format!("__solo/{}/{}", obj.kind, obj.meta.name));
                let key = (label.clone(), group);
                *group_sizes.entry(key.clone()).or_insert(0) += 1;
                if let Some(count) = annotation(&obj, POD_GROUP_COUNT_ANNOTATION)
                    .and_then(|v| v.parse::<usize>().ok())
                {
                    let slot = declared_counts.entry(key.clone()).or_insert(0);
                    *slot = (*slot).max(count);
                }
                // Terminal members release their quota charge but still
                // count toward the declared group size above — a gang must
                // not become permanently "incomplete" (and unadmittable)
                // because one member already finished.
                if workload_terminal(&obj) {
                    continue;
                }
                let Ok(demand) = workload_demand(&obj) else {
                    // An undecodable member can never be admitted, so its
                    // whole gang must be held — admitting the decodable
                    // remainder would be a partial gang.
                    self.metrics.inc("kueue.undecodable_workload");
                    poisoned.insert(key);
                    continue;
                };
                let priority = workload_priority(&obj);
                let g = gangs
                    .entry((is_admitted(&obj), key.0, key.1))
                    .or_insert_with(|| PendingGang {
                        members: Vec::new(),
                        cq,
                        label: label.clone(),
                        demand: QueueResources::ZERO,
                        priority,
                        uid: obj.meta.uid,
                        complete: true,
                    });
                g.members.push((obj.kind.clone(), obj.meta.name.clone()));
                g.demand = g.demand.saturating_add(&demand);
                g.priority = g.priority.max(priority);
                g.uid = g.uid.min(obj.meta.uid);
            }
        }

        // ---- the ledger ---------------------------------------------
        // Split the accumulated gangs; admitted demand charges the ledger,
        // pending gangs get their completeness verdict (all declared
        // members present, admitted + pending + terminal).
        let mut ledger = Ledger::new(cqs.clone());
        let mut admitted: Vec<AdmittedGang> = Vec::new();
        let mut pending_gangs: Vec<PendingGang> = Vec::new();
        for ((is_adm, label, group), mut gang) in gangs {
            if is_adm {
                let g = AdmittedGang {
                    members: gang.members,
                    queue: gang.cq,
                    label: gang.label,
                    demand: gang.demand,
                    priority: gang.priority,
                    uid: gang.uid,
                };
                ledger.charge(&g.queue, &g.demand);
                admitted.push(g);
            } else {
                let grouped = !group.starts_with("__solo/");
                let key = (label, group);
                gang.complete = !poisoned.contains(&key)
                    && match declared_counts.get(&key) {
                        Some(declared) => {
                            group_sizes.get(&key).copied().unwrap_or(0) >= *declared
                        }
                        // A grouped gang whose declared size is not yet
                        // known (the annotated member hasn't been created)
                        // must be held — admitting early members one by one
                        // is exactly the partial admission gangs exist to
                        // prevent. Solo workloads carry no annotation and
                        // are always ready.
                        None => !grouped,
                    };
                pending_gangs.push(gang);
            }
        }

        // ---- admit, strictly ordered per queue ----------------------
        let mut report = CycleReport::default();
        let mut pending: Vec<PendingGang> = pending_gangs;
        for cq in &cqs {
            let mut queue_gangs: Vec<&PendingGang> =
                pending.iter().filter(|g| g.cq == cq.name).collect();
            match cq.ordering {
                QueueOrdering::Fifo => queue_gangs.sort_by_key(|g| g.uid),
                QueueOrdering::Priority => {
                    queue_gangs.sort_by(|a, b| b.priority.cmp(&a.priority).then(a.uid.cmp(&b.uid)))
                }
            }
            let mut decisions: Vec<PendingGang> = Vec::new();
            for gang in queue_gangs {
                if !gang.complete {
                    continue; // waiting for members; does not block the queue
                }
                let fit = ledger.fit(&cq.name, &gang.demand);
                match fit {
                    Fit::Ok { borrowed } => {
                        if borrowed {
                            self.metrics.inc("kueue.admitted_borrowing");
                        }
                        ledger.charge(&cq.name, &gang.demand);
                        decisions.push(gang.clone());
                    }
                    Fit::BlockedWithinNominal => {
                        let Some(victims) =
                            select_victims(&ledger, &admitted, cq, &gang.demand, gang.priority)
                        else {
                            break; // strict: a blocked head holds the queue
                        };
                        for v in &victims {
                            evict_gang(api, v)?;
                            ledger.uncharge(&v.queue, &v.demand);
                            report.preempted += v.members.len();
                            self.metrics.inc("kueue.gangs_preempted");
                        }
                        admitted.retain(|a| !victims.contains(a));
                        ledger.charge(&cq.name, &gang.demand);
                        decisions.push(gang.clone());
                    }
                    Fit::Blocked | Fit::UnknownQueue => break,
                }
            }
            for gang in decisions {
                self.admit(api, &gang.members, &cq.name)?;
                report.admitted += gang.members.len();
                self.metrics.inc("kueue.gangs_admitted");
                // Move into the admitted set so counts (and later queues'
                // preemption searches) see it; drop from pending.
                pending.retain(|g| g.members != gang.members);
                admitted.push(AdmittedGang {
                    members: gang.members,
                    queue: gang.cq,
                    label: gang.label,
                    demand: gang.demand,
                    priority: gang.priority,
                    uid: gang.uid,
                });
            }
        }
        report.pending = pending.iter().map(|g| g.members.len()).sum();

        // ---- queue status counts (write only on change) --------------
        let mut cq_counts: BTreeMap<&str, (u64, u64)> = BTreeMap::new();
        let mut lq_counts: BTreeMap<&str, (u64, u64)> = BTreeMap::new();
        for g in &pending {
            count_into(&mut cq_counts, &g.cq, g.members.len() as u64, 0);
            if lqs.iter().any(|l| l.name == g.label) {
                count_into(&mut lq_counts, &g.label, g.members.len() as u64, 0);
            }
        }
        for g in &admitted {
            count_into(&mut cq_counts, &g.queue, 0, g.members.len() as u64);
            if lqs.iter().any(|l| l.name == g.label) {
                count_into(&mut lq_counts, &g.label, 0, g.members.len() as u64);
            }
        }
        for cq in &cqs {
            let (p, a) = cq_counts.get(cq.name.as_str()).copied().unwrap_or((0, 0));
            if cq.pending != p || cq.admitted != a {
                update_counts(api, KIND_CLUSTERQUEUE, &cq.name, p, a)?;
            }
        }
        for lq in &lqs {
            let (p, a) = lq_counts.get(lq.name.as_str()).copied().unwrap_or((0, 0));
            if lq.pending != p || lq.admitted != a {
                update_counts(api, KIND_LOCALQUEUE, &lq.name, p, a)?;
            }
        }

        self.metrics.observe("kueue.cycle_ns", t0.elapsed().as_nanos() as u64);
        Ok(report)
    }

    /// Flip a whole gang's members to admitted, stamping the ClusterQueue
    /// their demand is charged to. Only called after the full gang was
    /// reserved in the ledger — this write order is what the
    /// "all-or-nothing" guarantee rests on.
    fn admit(&self, api: &dyn ApiClient, members: &[(String, String)], cq: &str) -> Result<()> {
        for (i, (kind, name)) in members.iter().enumerate() {
            let res = api.update_status(kind, name, &|o| {
                set_condition(&mut o.status, COND_QUOTA_RESERVED, true);
                set_condition(&mut o.status, COND_ADMITTED, true);
                set_condition(&mut o.status, COND_EVICTED, false);
                o.status.insert("clusterQueue", cq);
                // Admission is what releases the pod to the scheduler.
                remove_scheduling_gate(o, SCHEDULING_GATE);
            });
            match res {
                Ok(_) => {}
                // Deleted between list and write: its charge vanishes
                // next cycle; nothing to unwind.
                Err(e) if e.is_not_found() => {}
                Err(e) => {
                    // Best-effort unwind: a partially-admitted gang must
                    // not survive the cycle — the reservation lives only
                    // in this cycle's ledger, so stranded members would
                    // run while the remainder can never re-fit. Roll the
                    // already-written members back to suspended.
                    for (k, n) in &members[..i] {
                        let _ = api.update_status(k, n, &|o| {
                            set_condition(&mut o.status, COND_ADMITTED, false);
                            set_condition(&mut o.status, COND_QUOTA_RESERVED, false);
                            o.status.remove("clusterQueue");
                            if o.kind == KIND_POD {
                                add_scheduling_gate(o, SCHEDULING_GATE);
                            }
                        });
                    }
                    self.metrics.inc("kueue.admit_unwound");
                    return Err(e);
                }
            }
        }
        Ok(())
    }
}

fn annotation<'a>(obj: &'a KubeObject, key: &str) -> Option<&'a str> {
    obj.meta.annotations.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

fn count_into<'a>(
    counts: &mut BTreeMap<&'a str, (u64, u64)>,
    key: &'a str,
    pending: u64,
    admitted: u64,
) {
    let slot = counts.entry(key).or_insert((0, 0));
    slot.0 += pending;
    slot.1 += admitted;
}

fn update_counts(
    api: &dyn ApiClient,
    kind: &str,
    name: &str,
    pending: u64,
    admitted: u64,
) -> Result<()> {
    match api.update_status(kind, name, &|o| {
        o.status.insert("pending", pending);
        o.status.insert("admitted", admitted);
    }) {
        Ok(_) => Ok(()),
        Err(e) if e.is_not_found() => Ok(()),
        Err(e) => Err(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Resources;
    use crate::kube::{ApiServer, PodView, KIND_POD};
    use crate::kueue::types::QUEUE_NAME_LABEL;

    fn api() -> ApiServer {
        ApiServer::new(Metrics::new())
    }

    fn labelled_pod(name: &str, queue: &str, cpu: u64) -> KubeObject {
        let mut p = PodView::build(name, "img.sif", Resources::new(cpu, 1 << 20, 0), &[]);
        p.meta.set_label(QUEUE_NAME_LABEL, queue);
        p
    }

    #[test]
    fn unlabelled_workloads_ignored_and_unknown_queue_held() {
        let a = api();
        let core = AdmissionCore::new(Metrics::new());
        a.create(PodView::build("plain", "img.sif", Resources::ZERO, &[])).unwrap();
        a.create(labelled_pod("orphan", "no-such-queue", 100)).unwrap();
        let r = core.cycle(&a).unwrap();
        assert_eq!(r, CycleReport::default(), "nothing admitted, nothing counted");
        assert!(!is_admitted(&a.get(KIND_POD, "orphan").unwrap()));
        assert!(!is_admitted(&a.get(KIND_POD, "plain").unwrap()));
    }

    #[test]
    fn admits_within_quota_and_reports_counts() {
        let a = api();
        let core = AdmissionCore::new(Metrics::new());
        a.create(ClusterQueueView::build("cq-a", QueueResources::nodes(2))).unwrap();
        a.create(LocalQueueView::build("team", "cq-a")).unwrap();
        for i in 0..3 {
            a.create(labelled_pod(&format!("p{i}"), "team", 100)).unwrap();
        }
        let r = core.cycle(&a).unwrap();
        assert_eq!(r.admitted, 2, "FIFO: first two fit the 2-node quota");
        assert_eq!(r.pending, 1);
        assert!(is_admitted(&a.get(KIND_POD, "p0").unwrap()));
        assert!(is_admitted(&a.get(KIND_POD, "p1").unwrap()));
        assert!(!is_admitted(&a.get(KIND_POD, "p2").unwrap()));
        // Status counts landed on both queue objects.
        let cq = ClusterQueueView::from_object(&a.get(KIND_CLUSTERQUEUE, "cq-a").unwrap()).unwrap();
        assert_eq!((cq.pending, cq.admitted), (1, 2));
        let lq = LocalQueueView::from_object(&a.get(KIND_LOCALQUEUE, "team").unwrap()).unwrap();
        assert_eq!(lq.pending, 1);
        // A second cycle is a no-op (stability: no write storms).
        let v = a.current_version();
        let r = core.cycle(&a).unwrap();
        assert_eq!(r.admitted, 0);
        assert_eq!(a.current_version(), v, "steady state writes nothing");
        // Completion releases quota for the straggler.
        a.update_status(KIND_POD, "p0", |o| o.status.insert("phase", "Succeeded")).unwrap();
        let r = core.cycle(&a).unwrap();
        assert_eq!(r.admitted, 1);
        assert!(is_admitted(&a.get(KIND_POD, "p2").unwrap()));
    }

    #[test]
    fn direct_cluster_queue_label_resolves() {
        let a = api();
        let core = AdmissionCore::new(Metrics::new());
        a.create(ClusterQueueView::build("cq-direct", QueueResources::nodes(1))).unwrap();
        a.create(labelled_pod("p", "cq-direct", 100)).unwrap();
        assert_eq!(core.cycle(&a).unwrap().admitted, 1);
    }

    #[test]
    fn strict_fifo_blocks_behind_wide_gang() {
        let a = api();
        let core = AdmissionCore::new(Metrics::new());
        a.create(ClusterQueueView::build("cq", QueueResources::nodes(3))).unwrap();
        // Head gang needs 2 nodes via a pod group; only 1 node free after
        // an earlier admission -> the whole queue waits behind it.
        a.create(labelled_pod("first", "cq", 100)).unwrap();
        a.create(labelled_pod("second", "cq", 100)).unwrap();
        assert_eq!(core.cycle(&a).unwrap().admitted, 2); // 1 node left
        let mut g0 = labelled_pod("wide-0", "cq", 100);
        g0.meta.set_label(POD_GROUP_LABEL, "wide");
        g0.meta
            .annotations
            .push((POD_GROUP_COUNT_ANNOTATION.to_string(), "2".to_string()));
        let mut g1 = labelled_pod("wide-1", "cq", 100);
        g1.meta.set_label(POD_GROUP_LABEL, "wide");
        a.create(g0).unwrap();
        a.create(g1).unwrap();
        a.create(labelled_pod("small", "cq", 100)).unwrap();
        let r = core.cycle(&a).unwrap();
        assert_eq!(r.admitted, 0, "wide gang blocked; strict FIFO holds `small` too");
        assert_eq!(r.pending, 3);
        assert!(!is_admitted(&a.get(KIND_POD, "small").unwrap()));
    }

    #[test]
    fn group_without_declared_count_is_held() {
        let a = api();
        let core = AdmissionCore::new(Metrics::new());
        a.create(ClusterQueueView::build("cq", QueueResources::nodes(10))).unwrap();
        // First member arrives WITHOUT the count annotation (the docs
        // allow it on any member): the group must be held, not admitted
        // one member at a time.
        let mut g0 = labelled_pod("h-0", "cq", 100);
        g0.meta.set_label(POD_GROUP_LABEL, "h");
        a.create(g0).unwrap();
        let r = core.cycle(&a).unwrap();
        assert_eq!(r.admitted, 0, "unknown gang size: held");
        // The annotated member lands: both admit together.
        let mut g1 = labelled_pod("h-1", "cq", 100);
        g1.meta.set_label(POD_GROUP_LABEL, "h");
        g1.meta
            .annotations
            .push((POD_GROUP_COUNT_ANNOTATION.to_string(), "2".to_string()));
        a.create(g1).unwrap();
        assert_eq!(core.cycle(&a).unwrap().admitted, 2);
    }

    #[test]
    fn completed_group_member_still_counts_for_completeness() {
        let a = api();
        let core = AdmissionCore::new(Metrics::new());
        a.create(ClusterQueueView::build("cq", QueueResources::nodes(2))).unwrap();
        for i in 0..2 {
            let mut g = labelled_pod(&format!("g-{i}"), "cq", 100);
            g.meta.set_label(POD_GROUP_LABEL, "g");
            g.meta
                .annotations
                .push((POD_GROUP_COUNT_ANNOTATION.to_string(), "2".to_string()));
            a.create(g).unwrap();
        }
        assert_eq!(core.cycle(&a).unwrap().admitted, 2);
        // g-0 finishes; g-1 loses its admission (eviction shape). The
        // survivor must re-admit: the finished member still counts toward
        // the declared group size.
        a.update_status(KIND_POD, "g-0", |o| o.status.insert("phase", "Succeeded")).unwrap();
        a.update_status(KIND_POD, "g-1", |o| {
            set_condition(&mut o.status, COND_ADMITTED, false);
        })
        .unwrap();
        let r = core.cycle(&a).unwrap();
        assert_eq!(r.admitted, 1, "remainder of a partially-completed gang re-admits");
        assert!(is_admitted(&a.get(KIND_POD, "g-1").unwrap()));
    }

    #[test]
    fn scheduling_gate_backfilled_then_cleared_on_admission() {
        let a = api();
        let core = AdmissionCore::new(Metrics::new());
        a.create(ClusterQueueView::build("cq", QueueResources::nodes(1))).unwrap();
        // Born gated through the builder.
        let mut first = PodView::build("first", "img.sif", Resources::new(100, 1 << 20, 0), &[]);
        crate::kueue::queue_workload(&mut first, "cq");
        a.create(first).unwrap();
        // Created with a bare label (no gate): the cycle back-fills it.
        a.create(labelled_pod("second", "cq", 100)).unwrap();
        let r = core.cycle(&a).unwrap();
        assert_eq!(r.admitted, 1, "1-node quota admits only the head");
        let first = a.get(KIND_POD, "first").unwrap();
        assert!(is_admitted(&first));
        assert!(
            crate::kube::scheduling_gates(&first).is_empty(),
            "admission clears the gate"
        );
        let second = a.get(KIND_POD, "second").unwrap();
        assert!(!is_admitted(&second));
        assert_eq!(
            crate::kube::scheduling_gates(&second),
            vec![crate::kueue::SCHEDULING_GATE.to_string()],
            "suspended straggler gets the gate back-filled"
        );
    }

    #[test]
    fn priority_ordering_reorders_admission() {
        use crate::kueue::types::{PreemptionPolicy, PRIORITY_LABEL};
        let a = api();
        let core = AdmissionCore::new(Metrics::new());
        a.create(ClusterQueueView::build_full(
            "cq",
            None,
            QueueResources::nodes(1),
            None,
            QueueOrdering::Priority,
            PreemptionPolicy::default(),
        ))
        .unwrap();
        a.create(labelled_pod("old-low", "cq", 100)).unwrap();
        let mut vip = labelled_pod("new-high", "cq", 100);
        vip.meta.set_label(PRIORITY_LABEL, "5");
        a.create(vip).unwrap();
        let r = core.cycle(&a).unwrap();
        assert_eq!(r.admitted, 1);
        assert!(is_admitted(&a.get(KIND_POD, "new-high").unwrap()), "priority jumps FIFO");
        assert!(!is_admitted(&a.get(KIND_POD, "old-low").unwrap()));
    }
}
