//! red-box: the Unix-socket RPC bridge between the Kubernetes side and the
//! Torque side of the login node (paper §II/§III-B).
//!
//! WLM-Operator implements red-box as a gRPC proxy; this is the same
//! three-piece shape — a service definition ([`proto`]), a server that
//! listens and dispatches ([`server`]), and clients that mirror the methods
//! ([`client`]) — over length-prefixed JSON frames on a real Unix domain
//! socket.
//!
//! Since ISSUE 5 the wire is **multiplexed**: one connection carries
//! concurrent requests *and* server-push streams ([`Frame`]), so gRPC
//! server-streaming methods (the kube watch) push events instead of being
//! polled — an idle connection transmits nothing.

pub mod client;
pub mod proto;
pub mod server;

pub use client::{ClientStream, RedboxClient, StreamMsg};
pub use proto::{Frame, Request, Response, END_CANCELLED, END_COMPLETE, END_GONE};
pub use server::{FnService, RedboxServer, Reply, Service, StreamSink};
