//! API audit trail (PR 8): who mutated what, when, on whose behalf.
//!
//! Every mutating ApiServer verb appends one [`AuditRecord`] — verb,
//! kind/name, **actor** (the component or user the request ran as),
//! trace id, outcome, latency — to a bounded in-memory ring
//! ([`AuditLog`]) with an optional WAL-style JSON-line file sink
//! (`hpcorc up --audit-log FILE`). The ring is queryable remotely via
//! the `obs.Audit` red-box service ([`audit_service`]) and the
//! `hpcorc audit [--since SEQ] [--kind KIND]` CLI verb.
//!
//! Actor attribution is a thread-local, mirroring how trace context
//! travels: a component's control loop pins its identity with
//! [`push_actor`] at the top of each cycle (scheduler, kubelet, kueue,
//! operator, HPA/CA all do), the red-box client stamps
//! [`current_actor`] onto every outgoing request as an optional `actor`
//! field, and the server adopts it around dispatch — so a remote
//! `kubectl apply` audits as `kubectl` and an in-process bind audits as
//! `kube-scheduler`, through one code path.

use crate::encoding::Value;
use crate::redbox::server::{FnService, Service};
use crate::util::Result;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{SystemTime, UNIX_EPOCH};

/// Records retained in the in-memory ring (oldest evicted first).
pub const AUDIT_RING_CAPACITY: usize = 4096;

/// Actor recorded when no component pinned one (e.g. a bare test client).
pub const UNATTRIBUTED: &str = "unattributed";

thread_local! {
    static ACTOR: RefCell<Option<String>> = const { RefCell::new(None) };
}

/// The actor pinned on this thread, if any.
pub fn current_actor() -> Option<String> {
    ACTOR.with(|a| a.borrow().clone())
}

/// RAII actor scope: restores the previously pinned actor on drop.
pub struct ActorGuard {
    prev: Option<String>,
}

impl Drop for ActorGuard {
    fn drop(&mut self) {
        ACTOR.with(|a| *a.borrow_mut() = self.prev.take());
    }
}

/// Pin `name` as this thread's actor until the guard drops. Components
/// call this at the top of a reconcile/sync cycle; servers call it
/// around dispatch with the wire-carried actor.
pub fn push_actor(name: &str) -> ActorGuard {
    ACTOR.with(|a| {
        let prev = a.borrow_mut().replace(name.to_string());
        ActorGuard { prev }
    })
}

/// One audited mutating API request.
#[derive(Debug, Clone)]
pub struct AuditRecord {
    /// Monotone sequence number (1-based) — the `--since` cursor.
    pub seq: u64,
    /// Wall clock at completion, nanoseconds since the Unix epoch.
    pub wall_ns: u64,
    /// API verb: create/update/update_status/patch/delete/apply.
    pub verb: String,
    pub kind: String,
    pub name: String,
    /// Requesting component/user ([`UNATTRIBUTED`] when none was pinned).
    pub actor: String,
    /// Originating trace id (16-hex), when the request ran under a span.
    pub trace: Option<String>,
    /// `ok`, or the error rendering of a failed request.
    pub outcome: String,
    pub latency_ns: u64,
}

impl AuditRecord {
    pub fn to_value(&self) -> Value {
        let mut v = Value::map()
            .with("seq", self.seq)
            .with("wallNs", self.wall_ns)
            .with("verb", self.verb.clone())
            .with("kind", self.kind.clone())
            .with("name", self.name.clone())
            .with("actor", self.actor.clone())
            .with("outcome", self.outcome.clone())
            .with("latencyNs", self.latency_ns);
        if let Some(t) = &self.trace {
            v.insert("trace", t.clone());
        }
        v
    }

    pub fn from_value(v: &Value) -> Option<AuditRecord> {
        Some(AuditRecord {
            seq: v.opt_int("seq")? as u64,
            wall_ns: v.opt_int("wallNs")? as u64,
            verb: v.opt_str("verb")?.to_string(),
            kind: v.opt_str("kind")?.to_string(),
            name: v.opt_str("name")?.to_string(),
            actor: v.opt_str("actor")?.to_string(),
            trace: v.opt_str("trace").map(String::from),
            outcome: v.opt_str("outcome")?.to_string(),
            latency_ns: v.opt_int("latencyNs")? as u64,
        })
    }
}

struct AuditInner {
    ring: Mutex<VecDeque<AuditRecord>>,
    seq: AtomicU64,
    cap: usize,
    sink: Mutex<Option<std::fs::File>>,
}

/// Bounded, cloneable audit ring with an optional file sink. One lives
/// inside every `ApiServer`; clones share state.
#[derive(Clone)]
pub struct AuditLog {
    inner: Arc<AuditInner>,
}

impl Default for AuditLog {
    fn default() -> Self {
        Self::with_capacity(AUDIT_RING_CAPACITY)
    }
}

impl AuditLog {
    pub fn new() -> AuditLog {
        AuditLog::default()
    }

    pub fn with_capacity(cap: usize) -> AuditLog {
        AuditLog {
            inner: Arc::new(AuditInner {
                ring: Mutex::new(VecDeque::with_capacity(cap.min(1024))),
                seq: AtomicU64::new(0),
                cap: cap.max(1),
                sink: Mutex::new(None),
            }),
        }
    }

    /// Attach a WAL-style file sink: every subsequent record appends one
    /// JSON line to `path` (created if missing), flushed per record.
    pub fn attach_file_sink(&self, path: &std::path::Path) -> Result<()> {
        let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        *self.inner.sink.lock().unwrap() = Some(file);
        Ok(())
    }

    /// Append one record; the middleware entry point. Fills seq + wall
    /// clock + thread-local actor itself.
    pub fn record(
        &self,
        verb: &str,
        kind: &str,
        name: &str,
        trace: Option<String>,
        outcome: String,
        latency_ns: u64,
    ) {
        let rec = AuditRecord {
            seq: self.inner.seq.fetch_add(1, Ordering::Relaxed) + 1,
            wall_ns: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .unwrap_or_default()
                .as_nanos() as u64,
            verb: verb.to_string(),
            kind: kind.to_string(),
            name: name.to_string(),
            actor: current_actor().unwrap_or_else(|| UNATTRIBUTED.to_string()),
            trace,
            outcome,
            latency_ns,
        };
        if let Some(f) = self.inner.sink.lock().unwrap().as_mut() {
            use std::io::Write;
            let _ = writeln!(f, "{}", crate::encoding::json::to_string(&rec.to_value()));
            let _ = f.flush();
        }
        let mut ring = self.inner.ring.lock().unwrap();
        if ring.len() == self.inner.cap {
            ring.pop_front();
        }
        ring.push_back(rec);
    }

    /// Records with `seq > since` (0 = everything retained), optionally
    /// kind-filtered, oldest first.
    pub fn query(&self, since: u64, kind: Option<&str>) -> Vec<AuditRecord> {
        self.inner
            .ring
            .lock()
            .unwrap()
            .iter()
            .filter(|r| r.seq > since && kind.map_or(true, |k| r.kind == k))
            .cloned()
            .collect()
    }

    /// Every retained record, oldest first.
    pub fn snapshot(&self) -> Vec<AuditRecord> {
        self.query(0, None)
    }

    /// Highest sequence number handed out so far.
    pub fn last_seq(&self) -> u64 {
        self.inner.seq.load(Ordering::Relaxed)
    }
}

/// The `obs.Audit` red-box service over an [`AuditLog`].
///
/// - `obs.Audit/Query` `{since?: N, kind?: "Pod"}` → `{records: [...]}`
pub fn audit_service(log: AuditLog) -> Arc<dyn Service> {
    Arc::new(FnService(move |method: &str, body: &Value| match method {
        "Query" => {
            let since = body.opt_int("since").unwrap_or(0).max(0) as u64;
            let kind = body.opt_str("kind");
            let records: Vec<Value> =
                log.query(since, kind).iter().map(AuditRecord::to_value).collect();
            Ok(Value::map().with("records", Value::Seq(records)))
        }
        other => Err(crate::util::Error::rpc(format!("obs.Audit has no method `{other}`"))),
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn actor_guard_nests_and_restores() {
        assert_eq!(current_actor(), None);
        {
            let _a = push_actor("scheduler");
            assert_eq!(current_actor().as_deref(), Some("scheduler"));
            {
                let _b = push_actor("kubectl");
                assert_eq!(current_actor().as_deref(), Some("kubectl"));
            }
            assert_eq!(current_actor().as_deref(), Some("scheduler"));
        }
        assert_eq!(current_actor(), None);
    }

    #[test]
    fn ring_bounds_and_query_filters() {
        let log = AuditLog::with_capacity(3);
        for i in 0..5u64 {
            let kind = if i % 2 == 0 { "Pod" } else { "Node" };
            let _a = push_actor("test");
            log.record("create", kind, &format!("o{i}"), None, "ok".into(), i);
        }
        let all = log.snapshot();
        assert_eq!(all.len(), 3, "ring is bounded");
        assert_eq!(all[0].seq, 3, "oldest evicted first");
        assert_eq!(log.last_seq(), 5);
        assert_eq!(log.query(4, None).len(), 1, "--since is an exclusive cursor");
        let pods = log.query(0, Some("Pod"));
        assert!(pods.iter().all(|r| r.kind == "Pod"));
        assert_eq!(all[0].actor, "test");
    }

    #[test]
    fn record_value_roundtrip() {
        let rec = AuditRecord {
            seq: 9,
            wall_ns: 123,
            verb: "patch".into(),
            kind: "Pod".into(),
            name: "p1".into(),
            actor: "kubectl".into(),
            trace: Some("00000000deadbeef".into()),
            outcome: "ok".into(),
            latency_ns: 42,
        };
        let back = AuditRecord::from_value(&rec.to_value()).unwrap();
        assert_eq!(back.seq, 9);
        assert_eq!(back.trace.as_deref(), Some("00000000deadbeef"));
        assert_eq!(back.outcome, "ok");
        // Absent trace stays absent.
        let rec2 = AuditRecord { trace: None, ..rec };
        assert_eq!(AuditRecord::from_value(&rec2.to_value()).unwrap().trace, None);
    }

    #[test]
    fn file_sink_appends_json_lines() {
        let path = std::env::temp_dir()
            .join(format!("hpcorc-audit-sink-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let log = AuditLog::new();
        log.attach_file_sink(&path).unwrap();
        log.record("create", "Pod", "p1", Some("ff".into()), "ok".into(), 1);
        log.record("delete", "Pod", "p1", None, "ok".into(), 2);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let rec = AuditRecord::from_value(
            &crate::encoding::json::parse(lines[1]).unwrap(),
        )
        .unwrap();
        assert_eq!(rec.verb, "delete");
        let _ = std::fs::remove_file(&path);
    }
}
