"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every kernel in this package must match its oracle to float32 tolerance;
python/tests/test_kernel.py sweeps shapes with hypothesis against these.
"""

import jax.numpy as jnp

SQRT_2_OVER_PI = 0.7978845608028654


def gelu(y):
    """tanh-approximation GELU (what the fused kernel applies)."""
    return 0.5 * y * (1.0 + jnp.tanh(SQRT_2_OVER_PI * (y + 0.044715 * y**3)))


def d_gelu(y):
    """Derivative of the tanh-approximation GELU wrt its input."""
    inner = SQRT_2_OVER_PI * (y + 0.044715 * y**3)
    t = jnp.tanh(inner)
    dinner = SQRT_2_OVER_PI * (1.0 + 3 * 0.044715 * y**2)
    return 0.5 * (1.0 + t) + 0.5 * y * (1.0 - t**2) * dinner


def matmul_gelu_ref(x, w, b, activation="gelu"):
    """Reference for kernels.matmul_gelu: act(x @ w + b).

    x: (m, k) float32, w: (k, n) float32, b: (1, n) float32.
    """
    y = x @ w + b
    if activation == "gelu":
        return gelu(y)
    if activation == "none":
        return y
    raise ValueError(f"unknown activation {activation!r}")


def attention_ref(q, k, v, causal=False):
    """Reference for kernels.attention: softmax(q k^T / sqrt(d)) v.

    q, k, v: (bh, seq, d) float32.
    """
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k) / jnp.sqrt(jnp.float32(d))
    if causal:
        seq = q.shape[1]
        mask = jnp.tril(jnp.ones((seq, seq), dtype=bool))
        s = jnp.where(mask[None, :, :], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v)
