//! API-layer overhead: what does each abstraction level cost on the hot
//! read/write paths? raw `Store` → `ApiServer` (metrics + cascade logic) →
//! typed `Api<PodView>` (dynamic-tree decode) → `RemoteApi` (red-box
//! socket). Keeps the cost of the unified `ApiClient` surface visible in
//! the perf trajectory.

use hpcorc::bench::{header, Bench};
use hpcorc::cluster::{Metrics, Resources};
use hpcorc::kube::{
    Api, ApiClient, ApiServer, KubeObject, ListOptions, PodView, RemoteApi, Store, KIND_POD,
};
use hpcorc::redbox::RedboxServer;
use hpcorc::rt::Shutdown;

const N: usize = 512;

fn pod(i: usize) -> KubeObject {
    let mut p = PodView::build(
        &format!("pod-{i:05}"),
        "lolcow_latest.sif",
        Resources::new(100, 1 << 20, 0),
        &[],
    );
    if i % 2 == 0 {
        p.meta.set_label("parity", "even");
    }
    p
}

fn main() {
    println!("=== kube API overhead: store vs ApiServer vs Api<K> vs RPC ({N} pods) ===");
    println!("{}", header());
    let mid = format!("pod-{:05}", N / 2);

    // Raw store (etcd-analogue floor).
    let store = Store::new();
    for i in 0..N {
        store.create(pod(i)).unwrap();
    }
    Bench::new("store.get").warmup(100).iters(2000).run(|| {
        store.get(KIND_POD, &mid).unwrap();
    });

    // ApiServer in-process.
    let api = ApiServer::new(Metrics::new());
    for i in 0..N {
        api.create(pod(i)).unwrap();
    }
    Bench::new("ApiServer.get").warmup(100).iters(2000).run(|| {
        api.get(KIND_POD, &mid).unwrap();
    });
    Bench::new("ApiServer.update_status").warmup(50).iters(500).run(|| {
        api.update_status(KIND_POD, &mid, |o| {
            o.status.insert("phase", "Running");
        })
        .unwrap();
    });
    Bench::new("ApiServer.list label-selector").warmup(20).iters(200).run(|| {
        let items = api.list_opts(
            KIND_POD,
            &ListOptions::all().with_label("parity", "even"),
        );
        assert_eq!(items.unwrap().items.len(), N / 2);
    });
    Bench::new("ApiServer.list field-selector").warmup(20).iters(200).run(|| {
        let items = api.list_opts(
            KIND_POD,
            &ListOptions::all().with_field("metadata.name", &mid),
        );
        assert_eq!(items.unwrap().items.len(), 1);
    });

    // Typed handle (adds the dynamic-tree decode per object).
    let pods: Api<PodView> = Api::new(api.client());
    Bench::new("Api<PodView>.get").warmup(100).iters(2000).run(|| {
        pods.get(&mid).unwrap();
    });
    Bench::new("Api<PodView>.list label-selector").warmup(20).iters(200).run(|| {
        let views = pods.list(&ListOptions::all().with_label("parity", "even")).unwrap();
        assert_eq!(views.len(), N / 2);
    });

    // Remote transport (socket hop + JSON codec on top of everything).
    let sd = Shutdown::new();
    let path = std::env::temp_dir()
        .join(format!("hpcorc-bench-kubeapi-{}.sock", std::process::id()));
    let mut srv = RedboxServer::start(&path, sd.clone(), Metrics::new()).unwrap();
    srv.register("kube.Api", api.rpc_service());
    let remote = RemoteApi::connect(&path).unwrap();
    Bench::new("RemoteApi.get (socket)").warmup(50).iters(500).run(|| {
        ApiClient::get(&remote, KIND_POD, &mid).unwrap();
    });
    let remote_pods: Api<PodView> = Api::new(std::sync::Arc::new(remote));
    Bench::new("Api<PodView>.get (socket)").warmup(50).iters(500).run(|| {
        remote_pods.get(&mid).unwrap();
    });
    srv.stop();
    sd.trigger();
}
