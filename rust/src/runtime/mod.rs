//! Runtime: loads AOT-compiled HLO artifacts (produced once by
//! `make artifacts` → `python/compile/aot.py`) and executes them via the
//! PJRT C API from the Rust hot path. Python never runs here.

pub mod manifest;
pub mod pjrt;

pub use manifest::{ArtifactEntry, Manifest, TensorSpec};
pub use pjrt::{start_pjrt_host, PjrtHandle};
