//! Torque queues: "nodes are grouped into queues. Each queue is associated
//! with resource limits such as walltime, job size. One node can be
//! included in multiple queues." (paper §III-A)

use super::script::PbsScript;
use crate::util::{Error, Result};
use std::time::Duration;

/// Configuration of one queue.
#[derive(Debug, Clone, PartialEq)]
pub struct QueueConfig {
    pub name: String,
    /// Jobs exceeding this are rejected at qsub time.
    pub max_walltime: Option<Duration>,
    /// Max node-chunks per job.
    pub max_nodes: Option<u32>,
    /// Per-queue base priority added to job priority.
    pub priority: i64,
    /// Node names that belong to this queue (a node may be in several).
    pub nodes: Vec<String>,
    /// Max jobs in the queue (queued + running); None = unlimited.
    pub max_queuable: Option<usize>,
    /// Whether this is the default destination queue.
    pub is_default: bool,
    /// Users allowed to submit; empty = everyone.
    pub acl_users: Vec<String>,
}

impl QueueConfig {
    pub fn new(name: impl Into<String>) -> Self {
        QueueConfig {
            name: name.into(),
            max_walltime: None,
            max_nodes: None,
            priority: 0,
            nodes: Vec::new(),
            max_queuable: None,
            is_default: false,
            acl_users: Vec::new(),
        }
    }

    /// The paper's Fig. 1 queue.
    pub fn batch(nodes: &[&str]) -> Self {
        let mut q = QueueConfig::new("batch");
        q.max_walltime = Some(Duration::from_secs(24 * 3600));
        q.nodes = nodes.iter().map(|s| s.to_string()).collect();
        q.is_default = true;
        q
    }

    pub fn with_walltime_limit(mut self, d: Duration) -> Self {
        self.max_walltime = Some(d);
        self
    }

    pub fn with_max_nodes(mut self, n: u32) -> Self {
        self.max_nodes = Some(n);
        self
    }

    pub fn with_priority(mut self, p: i64) -> Self {
        self.priority = p;
        self
    }

    pub fn with_nodes(mut self, nodes: &[&str]) -> Self {
        self.nodes = nodes.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn default_queue(mut self) -> Self {
        self.is_default = true;
        self
    }

    /// Enforce queue limits on a submitted script (Torque rejects at qsub).
    pub fn admit(&self, script: &PbsScript, user: &str, current_depth: usize) -> Result<()> {
        if let Some(max) = self.max_walltime {
            if script.walltime > max {
                return Err(Error::wlm(format!(
                    "job walltime {} exceeds queue `{}` limit {}",
                    crate::util::fmt_walltime(script.walltime),
                    self.name,
                    crate::util::fmt_walltime(max)
                )));
            }
        }
        if let Some(max) = self.max_nodes {
            if script.nodes > max {
                return Err(Error::wlm(format!(
                    "job requests {} nodes, queue `{}` allows {max}",
                    script.nodes, self.name
                )));
            }
        }
        if let Some(max) = self.max_queuable {
            if current_depth >= max {
                return Err(Error::wlm(format!("queue `{}` is full ({max} jobs)", self.name)));
            }
        }
        if !self.acl_users.is_empty() && !self.acl_users.iter().any(|u| u == user) {
            return Err(Error::wlm(format!(
                "user `{user}` not authorized for queue `{}`",
                self.name
            )));
        }
        Ok(())
    }
}

/// The queue set of a pbs_server.
#[derive(Debug, Clone, Default)]
pub struct QueueSet {
    queues: Vec<QueueConfig>,
}

impl QueueSet {
    pub fn new(queues: Vec<QueueConfig>) -> Result<QueueSet> {
        if queues.is_empty() {
            return Err(Error::config("pbs_server needs at least one queue"));
        }
        let defaults = queues.iter().filter(|q| q.is_default).count();
        if defaults > 1 {
            return Err(Error::config("multiple default queues"));
        }
        let mut names: Vec<&str> = queues.iter().map(|q| q.name.as_str()).collect();
        names.sort();
        names.dedup();
        if names.len() != queues.len() {
            return Err(Error::config("duplicate queue names"));
        }
        Ok(QueueSet { queues })
    }

    pub fn get(&self, name: &str) -> Option<&QueueConfig> {
        self.queues.iter().find(|q| q.name == name)
    }

    /// Resolve a job's destination: explicit `-q`, else the default queue.
    pub fn resolve(&self, requested: Option<&str>) -> Result<&QueueConfig> {
        match requested {
            Some(name) => self
                .get(name)
                .ok_or_else(|| Error::wlm(format!("unknown queue `{name}`"))),
            None => self
                .queues
                .iter()
                .find(|q| q.is_default)
                .or_else(|| self.queues.first())
                .ok_or_else(|| Error::wlm("no default queue")),
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = &QueueConfig> {
        self.queues.iter()
    }

    pub fn names(&self) -> Vec<String> {
        self.queues.iter().map(|q| q.name.clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn script(wall_s: u64, nodes: u32) -> PbsScript {
        PbsScript {
            walltime: Duration::from_secs(wall_s),
            nodes,
            ..PbsScript::default()
        }
    }

    #[test]
    fn admit_enforces_limits() {
        let q = QueueConfig::new("test")
            .with_walltime_limit(Duration::from_secs(600))
            .with_max_nodes(2);
        assert!(q.admit(&script(600, 2), "alice", 0).is_ok());
        assert!(q.admit(&script(601, 1), "alice", 0).is_err());
        assert!(q.admit(&script(60, 3), "alice", 0).is_err());
    }

    #[test]
    fn admit_acl_and_depth() {
        let mut q = QueueConfig::new("restricted");
        q.acl_users = vec!["alice".into()];
        q.max_queuable = Some(2);
        assert!(q.admit(&script(60, 1), "alice", 0).is_ok());
        assert!(q.admit(&script(60, 1), "bob", 0).is_err());
        assert!(q.admit(&script(60, 1), "alice", 2).is_err());
    }

    #[test]
    fn queue_set_validation() {
        assert!(QueueSet::new(vec![]).is_err());
        let dup = vec![QueueConfig::new("a"), QueueConfig::new("a")];
        assert!(QueueSet::new(dup).is_err());
        let two_defaults =
            vec![QueueConfig::new("a").default_queue(), QueueConfig::new("b").default_queue()];
        assert!(QueueSet::new(two_defaults).is_err());
    }

    #[test]
    fn resolve_default_and_named() {
        let qs = QueueSet::new(vec![
            QueueConfig::new("batch").default_queue(),
            QueueConfig::new("gpu"),
        ])
        .unwrap();
        assert_eq!(qs.resolve(None).unwrap().name, "batch");
        assert_eq!(qs.resolve(Some("gpu")).unwrap().name, "gpu");
        assert!(qs.resolve(Some("nope")).is_err());
    }

    #[test]
    fn resolve_falls_back_to_first_without_default() {
        let qs = QueueSet::new(vec![QueueConfig::new("only")]).unwrap();
        assert_eq!(qs.resolve(None).unwrap().name, "only");
    }

    #[test]
    fn paper_batch_queue() {
        let q = QueueConfig::batch(&["cn1", "cn2"]);
        assert_eq!(q.name, "batch");
        assert!(q.is_default);
        assert_eq!(q.nodes.len(), 2);
    }
}
